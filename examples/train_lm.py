"""End-to-end training driver: a real LM through the fault-tolerant loop.

  PYTHONPATH=src python examples/train_lm.py [--arch internlm2-1.8b]
      [--steps 100] [--width 256] [--layers 4]

Uses a width-scaled (same-family) config so it converges visibly on CPU in
minutes; on a TPU fleet the identical driver runs the full config (see
repro/launch/train.py -- this example adds fault injection to demonstrate the
checkpoint/restart path end-to-end).
"""

import argparse
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig, StageConfig
from repro.configs.registry import ARCH_IDS, get_arch
from repro.data.synthetic import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models.model import model_spec
from repro.models.sharding import BASE_RULES
from repro.models.spec import count_params, init_params
from repro.optim import cosine_schedule, make_optimizer
from repro.train import TrainLoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=sorted(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--inject-fault", action="store_true",
                    help="kill the run at 60%% and prove bitwise recovery")
    args = ap.parse_args()

    base = get_arch(args.arch).reduced()
    cfg = replace(
        base,
        d_model=args.width, head_dim=None, n_heads=max(4, args.width // 64),
        kv_heads=max(2, args.width // 128), d_ff=args.width * 4,
        stages=tuple(StageConfig(repeats=args.layers, layers=s.layers)
                     for s in base.stages),
        attn_q_chunk=args.seq, attn_kv_chunk=args.seq,
    )
    spec = model_spec(cfg)
    print(f"{cfg.name}: {count_params(spec):,} params, "
          f"{args.batch * args.seq} tokens/step")

    shape = ShapeConfig("ex", args.seq, args.batch, "train")
    data = SyntheticLM(cfg, shape, seed=0)
    opt = make_optimizer(cfg.optimizer,
                         cosine_schedule(3e-3, warmup_steps=10,
                                         total_steps=args.steps))
    step_jit = jax.jit(make_train_step(cfg, BASE_RULES, opt))

    def init_state():
        params = init_params(spec, seed=0, dtype=jnp.float32)
        return params, opt.init(params)

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in data.batch(step).items()}

    def step_fn(params, opt_state, step, batch):
        return step_jit(params, opt_state, jnp.int32(int(step)), batch)

    fired = {"n": 0}

    def fault(step):
        if args.inject_fault and step == int(args.steps * 0.6) and fired["n"] == 0:
            fired["n"] += 1
            raise RuntimeError("injected node failure (example)")

    out = train_loop(
        step_fn, init_state, batch_fn,
        TrainLoopConfig(total_steps=args.steps, ckpt_every=max(args.steps // 5, 1),
                        ckpt_dir="/tmp/repro_example_ckpt", log_every=10),
        fault_hook=fault,
    )
    hist = out["history"]
    print(f"loss: {hist[0][1]:.4f} -> {hist[-1][1]:.4f} over {len(hist)} steps "
          f"(restarts={out['restarts']})")
    assert hist[-1][1] < hist[0][1], "training should reduce loss"


if __name__ == "__main__":
    main()
