"""Operator- and application-level DSE on the paper's signed 8x8 multiplier.

  PYTHONPATH=src python examples/operator_dse.py [--const-sf 0.5] [--gens 40]
  PYTHONPATH=src python examples/operator_dse.py --app mnist --backend jax
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python examples/operator_dse.py --backend jax --devices 8

Compares GA-only (AppAxO-style), MaP-only, and MaP+GA (AxOMaP) and prints the
validated Pareto fronts + hypervolumes, plus the EvoApprox-style frozen-library
baseline under the same constraints.  ``--app {ecg,mnist,gauss,ffn}`` switches
the BEHAV objective to an application metric (paper Figs. 16-19).

Execution policy is one ``ExecutionContext`` built from the engine flags:
``--backend jax`` runs characterization and application BEHAV through the
accelerator-native fastchar/fastapp engines (and, by default, the whole
NSGA-II generation loop through the fastmoo device engine; ``--ga-backend
numpy`` keeps the host GA while characterizing on device); ``--devices N``
shards the ``--shard`` axes (config batches and/or sweep lanes) over a 1-D
mesh of the first N devices.
"""

import argparse

import numpy as np

from repro.apps import APPLICATIONS
from repro.core.dataset import BEHAV_KEY, PPA_KEY, build_training_dataset
from repro.core.dse import (
    DSESettings,
    fixed_library,
    hv_reference,
    map_solution_pool,
    run_dse,
)
from repro.core.engine import KERNEL_IMPLS, SHARD_AXES, ExecutionContext
from repro.core.moo import hypervolume_2d
from repro.core.operator_model import spec_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--const-sf", type=float, default=0.5)
    ap.add_argument("--gens", type=int, default=40)
    ap.add_argument("--n-random", type=int, default=1200)
    ap.add_argument("--app", choices=sorted(APPLICATIONS), default=None,
                    help="application-level DSE target (default: operator-level)")
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="characterization/app-BEHAV engine")
    ap.add_argument("--ga-backend", choices=("numpy", "jax"), default=None,
                    help="NSGA-II engine (default: follow --backend; 'jax' runs "
                         "the whole generation loop as one compiled dispatch)")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard over the first N JAX devices (requires "
                         "--backend jax; on CPU hosts force devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    ap.add_argument("--shard", choices=SHARD_AXES + ("all",), default="all",
                    help="which batch axes ride the mesh: 'configs' "
                         "(characterization/app scoring), 'lanes' (sweep "
                         "lanes), or both (default)")
    ap.add_argument("--kernel-impl", choices=KERNEL_IMPLS + ("list",),
                    default=None, help="preferred kernel impl where an engine "
                                       "offers a menu (default: auto); 'list' "
                                       "prints the registered impls per engine "
                                       "and exits")
    ap.add_argument("--tuning", choices=("off", "cached", "search"),
                    default="off",
                    help="kernel block-shape autotune policy: 'cached' reuses "
                         "(or searches once and persists) per-device tile "
                         "winners, 'search' ignores persisted winners and "
                         "re-tunes once per bucket")
    ap.add_argument("--telemetry", choices=("on", "off"), default=None,
                    help="'on' collects spans/counters (and per-generation "
                         "GA hypervolume under --ga-backend jax); 'off' is a "
                         "guaranteed no-op; default: ambient sink")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON of the DSE spans to PATH "
                         "(load at ui.perfetto.dev); implies --telemetry on")
    args = ap.parse_args()

    if args.kernel_impl == "list":
        from repro.kernels import registry

        print(registry.describe())
        return

    telemetry = args.telemetry
    if args.trace is not None and telemetry is None:
        telemetry = "on"
    ctx = ExecutionContext(
        backend=args.backend,
        ga_backend=args.ga_backend,
        n_devices=args.devices,
        shard_axes=SHARD_AXES if args.shard == "all" else (args.shard,),
        kernel_impl=args.kernel_impl,
        tuning=args.tuning,
        telemetry=telemetry,
    )
    if ctx.device_count > 1:
        print(f"execution: {ctx.backend} on {ctx.device_count} devices, "
              f"sharding {','.join(ctx.shard_axes)}")

    spec = spec_for(8)
    print(f"signed 8x8 multiplier: L={spec.n_luts} -> 2^36 designs")
    ds = build_training_dataset(
        spec, n_random=args.n_random, seed=0,
        cache_path=f"experiments/cache/ds8_{args.n_random}_0.npz",
        backend=ctx,
    )
    print(f"training dataset: {len(ds)} characterized configs")

    app = None
    behav_key = BEHAV_KEY
    if args.app is not None:
        app = APPLICATIONS[args.app]()
        behav_key = app.behav_metric_name()
        ds = app.characterized_dataset(spec, ds, backend=ctx)
        print(f"application target: {args.app} (BEHAV = {behav_key}, "
              f"backend = {args.backend})")

    st = DSESettings(const_sf=args.const_sf, pop_size=48, n_gen=args.gens,
                     n_quad_grid=(0, 4, 16), pool_size=6, seed=0,
                     behav_key=behav_key, context=ctx)
    ref = hv_reference(ds, st)
    pool = map_solution_pool(spec, ds, st)
    print(f"MaP pool: {len(pool)} configs (const_sf={args.const_sf})")

    results = {}
    for method in ("ga", "map", "map+ga"):
        r = run_dse(spec, ds, method, settings=st, map_pool=pool, ref=ref, app=app)
        results[method] = r
        stages = " ".join(f"{k}={v:.2f}s" for k, v in r.timings.items())
        print(f"{method:7s} hv_ppf={r.hv_ppf:.5g} hv_vpf={r.hv_vpf:.5g} "
              f"front={len(r.vpf_objs)} evals={r.n_evals} ({r.wall_s:.1f}s: "
              f"{stages})")

    lib = fixed_library(spec)
    if app is not None:
        objs = app.characterize_fn(spec, backend=ctx)(lib)
    else:
        from repro.core.dataset import characterize

        objs = characterize(spec, lib, backend=ctx).objectives()
    max_b = args.const_sf * ds.metrics[behav_key].max()
    max_p = args.const_sf * ds.metrics[PPA_KEY].max()
    feas = (objs[:, 0] <= max_b) & (objs[:, 1] <= max_p)
    hv_lib = hypervolume_2d(objs[feas], ref) if feas.any() else 0.0
    print(f"library hv_vpf={hv_lib:.5g} (feasible {int(feas.sum())}/{len(lib)})"
          " <- EvoApprox-style frozen baseline")

    ga, best = results["ga"], max(results["map"].hv_vpf, results["map+ga"].hv_vpf)
    print(f"\nAxOMaP vs GA-only: {100*(best - ga.hv_vpf)/max(ga.hv_vpf,1e-9):+.1f}% "
          f"validated hypervolume (paper reports up to +21% / +116% tight)")

    tel = ctx.tel
    if args.trace is not None:
        tel.to_chrome_trace(args.trace)
        print(f"chrome trace: {args.trace} ({len(tel.spans)} spans; "
              "load at ui.perfetto.dev)")
    if telemetry == "on":
        disp = {k: v for k, v in sorted(tel.counters.items())
                if k.startswith(("dispatch.", "registry.dispatch."))}
        print(f"telemetry: {len(tel.spans)} spans, dispatch counters {disp}")
        hv_taps = tel.series.get("fastmoo.gen", ())
        if hv_taps:
            print(f"per-generation hv taps: {len(hv_taps)} "
                  f"(final hv={float(hv_taps[-1]['hv']):.5g})")


if __name__ == "__main__":
    main()
