"""Operator-level DSE on the paper's signed 8x8 multiplier (paper §5.3/5.4).

  PYTHONPATH=src python examples/operator_dse.py [--const-sf 0.5] [--gens 40]

Compares GA-only (AppAxO-style), MaP-only, and MaP+GA (AxOMaP) and prints the
validated Pareto fronts + hypervolumes, plus the EvoApprox-style frozen-library
baseline under the same constraints.
"""

import argparse

import numpy as np

from repro.core.dataset import BEHAV_KEY, PPA_KEY, build_training_dataset, characterize
from repro.core.dse import (
    DSESettings,
    fixed_library,
    hv_reference,
    map_solution_pool,
    run_dse,
)
from repro.core.moo import hypervolume_2d
from repro.core.operator_model import spec_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--const-sf", type=float, default=0.5)
    ap.add_argument("--gens", type=int, default=40)
    ap.add_argument("--n-random", type=int, default=1200)
    args = ap.parse_args()

    spec = spec_for(8)
    print(f"signed 8x8 multiplier: L={spec.n_luts} -> 2^36 designs")
    ds = build_training_dataset(
        spec, n_random=args.n_random, seed=0,
        cache_path=f"experiments/cache/ds8_{args.n_random}_0.npz",
    )
    print(f"training dataset: {len(ds)} characterized configs")

    st = DSESettings(const_sf=args.const_sf, pop_size=48, n_gen=args.gens,
                     n_quad_grid=(0, 4, 16), pool_size=6, seed=0)
    ref = hv_reference(ds, st)
    pool = map_solution_pool(spec, ds, st)
    print(f"MaP pool: {len(pool)} configs (const_sf={args.const_sf})")

    results = {}
    for method in ("ga", "map", "map+ga"):
        r = run_dse(spec, ds, method, settings=st, map_pool=pool, ref=ref)
        results[method] = r
        print(f"{method:7s} hv_ppf={r.hv_ppf:.5g} hv_vpf={r.hv_vpf:.5g} "
              f"front={len(r.vpf_objs)} evals={r.n_evals} ({r.wall_s:.1f}s)")

    lib = fixed_library(spec)
    objs = characterize(spec, lib).objectives()
    max_b = args.const_sf * ds.metrics[BEHAV_KEY].max()
    max_p = args.const_sf * ds.metrics[PPA_KEY].max()
    feas = (objs[:, 0] <= max_b) & (objs[:, 1] <= max_p)
    hv_lib = hypervolume_2d(objs[feas], ref) if feas.any() else 0.0
    print(f"library hv_vpf={hv_lib:.5g} (feasible {int(feas.sum())}/{len(lib)})"
          " <- EvoApprox-style frozen baseline")

    ga, best = results["ga"], max(results["map"].hv_vpf, results["map+ga"].hv_vpf)
    print(f"\nAxOMaP vs GA-only: {100*(best - ga.hv_vpf)/max(ga.hv_vpf,1e-9):+.1f}% "
          f"validated hypervolume (paper reports up to +21% / +116% tight)")


if __name__ == "__main__":
    main()
