"""End-to-end driver: serve a (reduced) LM with batched requests through
prefill + KV-cache decode, with the paper's approximate operators deployed in
EVERY linear layer (attention q/k/v/o, MLP, LM head) via ``deploy_axo`` -- and
measure what the approximation does to the generations.

The comparison is on *actual generations*: the AxO model free-runs greedily
(its own tokens feed back) and is also replayed teacher-forced along the exact
model's trajectory, so top-1 agreement and logit error are scored where serving
actually lives -- not on random synthetic hidden states.

  PYTHONPATH=src python examples/axo_serving.py [--arch granite-3-2b]
      [--batch 4] [--prompt-len 24] [--gen 24] [--ranks 1 4 16]
      [--layers attn mlp moe head] [--impl xla|pallas]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.axo import AXO_LAYERS, AxOOperator, deploy_axo
from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCH_IDS, get_arch
from repro.core.dataset import build_training_dataset
from repro.core.dse import DSESettings, map_solution_pool, run_dse
from repro.core.operator_model import accurate_config, spec_for
from repro.data.synthetic import SyntheticLM
from repro.kernels.ops import on_tpu
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.model import model_spec
from repro.models.sharding import BASE_RULES
from repro.models.spec import init_params


def pick_operator(seed: int = 0, behav_cap: float = 1.0) -> np.ndarray:
    """Quick 8x8 DSE + library, validated exactly; cheapest design under cap.

    Serving needs the *accurate* corner of the Pareto space, which a
    demo-budget GA (pop 32, 15 generations over 2^36 configs) never reaches
    on its own -- so the DSE's validated front is merged with the
    deterministic column-truncation library, every candidate is re-scored
    with the exact behavioral + PPA models, and the cheapest (min PDPLUT)
    design with BEHAV <= ``behav_cap`` % is deployed (min-BEHAV fallback if
    none qualifies).
    """
    from repro.core.metrics import behav_metrics
    from repro.core.ppa import ppa_metrics

    spec = spec_for(8)
    ds = build_training_dataset(
        spec, n_random=600, seed=seed,
        cache_path="experiments/cache/ds8_serving.npz")
    st = DSESettings(const_sf=1.5, pop_size=32, n_gen=15, n_quad_grid=(0, 4),
                     pool_size=4, seed=seed)
    pool = map_solution_pool(spec, ds, st)
    res = run_dse(spec, ds, "map+ga", settings=st, map_pool=pool)
    library = []
    for t in range(spec.rows + 1):           # accurate, t1 .. full truncation
        cfgv = accurate_config(spec)
        for r in range(t):
            cfgv[r * spec.cols_removable] = 0
        library.append(cfgv)
    cands = np.concatenate([np.atleast_2d(res.vpf_configs),
                            np.stack(library)], axis=0).astype(np.uint8)
    behav = behav_metrics(spec, cands)["AVG_ABS_REL_ERR"]
    pdplut = ppa_metrics(spec, cands)["PDPLUT"]
    ok = behav <= behav_cap
    idx = (int(np.flatnonzero(ok)[np.argmin(pdplut[ok])]) if ok.any()
           else int(np.argmin(behav)))
    src = "dse-front" if idx < len(res.vpf_configs) else "library"
    print(f"picked {src} design: BEHAV={behav[idx]:.3f}% "
          f"PDPLUT={pdplut[idx]:.0f} (cap {behav_cap}%, "
          f"{len(cands)} validated candidates)")
    return cands[idx]


def build_steps(cfg, rules, max_seq, axo=None):
    """jit'd (prefill, decode) step pair, optionally AxO-deployed."""
    prefill = jax.jit(make_prefill_step(cfg, rules, max_seq=max_seq, axo=axo))
    decode = jax.jit(make_decode_step(cfg, rules, axo=axo))
    return prefill, decode


def generate(prefill, decode, params, toks, gen: int):
    """Greedy decode ``gen`` tokens.  Returns (tokens (B,gen), logits list)."""
    prompt_len = toks.shape[1]
    logits, cache = prefill(params, toks)
    nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out, lgs = [nxt], [logits[:, -1]]
    for i in range(prompt_len, prompt_len + gen - 1):
        logits, cache = decode(params, cache, nxt, jnp.int32(i))
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(nxt)
        lgs.append(logits[:, -1])
    return jnp.concatenate(out, 1), lgs


def replay(prefill, decode, params, toks, trajectory):
    """Teacher-forced logits along a fixed generated ``trajectory`` (B, gen)."""
    prompt_len = toks.shape[1]
    logits, cache = prefill(params, toks)
    lgs = [logits[:, -1]]
    for j in range(trajectory.shape[1] - 1):
        tok = trajectory[:, j:j + 1]
        logits, cache = decode(params, cache, tok, jnp.int32(prompt_len + j))
        lgs.append(logits[:, -1])
    return lgs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=sorted(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--ranks", type=int, nargs="+", default=[1, 4, 16])
    ap.add_argument("--layers", nargs="+", default=list(AXO_LAYERS),
                    choices=list(AXO_LAYERS))
    ap.add_argument("--impl", default=None, choices=["xla", "pallas"],
                    help="AxO matmul impl (default: pallas on TPU, else the "
                         "identical-math xla contraction)")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    rules = BASE_RULES
    max_seq = args.prompt_len + args.gen
    impl = args.impl or ("pallas" if on_tpu() else "xla")
    params = init_params(model_spec(cfg), seed=0, dtype=jnp.float32)
    data = SyntheticLM(cfg, ShapeConfig("serve", max_seq, args.batch, "train"))
    toks = jnp.asarray(data.batch(0)["tokens"])[:, : args.prompt_len]

    prefill, decode = build_steps(cfg, rules, max_seq)
    generate(prefill, decode, params, toks, args.gen)  # warm the exact steps
    t0 = time.time()
    exact_toks, exact_lgs = generate(prefill, decode, params, toks, args.gen)
    dt = time.time() - t0
    print(f"exact serving: {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")

    op_cfg = pick_operator()
    for rank in args.ranks:
        op = AxOOperator.from_config(op_cfg, rank=rank)
        dep = deploy_axo(params, op, cfg, layers=tuple(args.layers), impl=impl)
        pre_a, dec_a = build_steps(cfg, rules, max_seq, axo=dep)
        generate(pre_a, dec_a, params, toks, args.gen)  # warm
        t0 = time.time()
        axo_toks, _ = generate(pre_a, dec_a, params, toks, args.gen)
        dt = time.time() - t0

        # free-running agreement: do the two serving paths emit the same tokens?
        match = float((axo_toks == exact_toks).mean())
        # teacher-forced: AxO logits along the exact trajectory, scored per step
        axo_replay = replay(pre_a, dec_a, params, toks, exact_toks)
        top1 = float(np.mean([
            (jnp.argmax(a, -1) == jnp.argmax(e, -1)).mean()
            for a, e in zip(axo_replay, exact_lgs)
        ]))
        rel = float(np.mean([
            jnp.linalg.norm(a - e) / jnp.maximum(jnp.linalg.norm(e), 1e-9)
            for a, e in zip(axo_replay, exact_lgs)
        ]))
        print(f"rank={rank:3d} ({dep.n_entries} deployed projections, {impl}): "
              f"{args.batch * args.gen / dt:.1f} tok/s  "
              f"free-run match={match:.1%}  teacher-forced top1={top1:.1%}  "
              f"logit rel_err={rel:.4f}  "
              f"(factorization cost {op.rank_behav()['AVG_ABS_REL_ERR']:.3f}% "
              f"AVG_ABS_REL_ERR)")

    print("generated ids (exact, row 0):",
          np.asarray(exact_toks[0, :12]).tolist(), "...")


if __name__ == "__main__":
    main()
