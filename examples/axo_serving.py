"""End-to-end driver: serve a (reduced) LM with batched requests through
prefill + KV-cache decode, with the paper's approximate operators deployed on
the LM head -- and measure what the approximation does to the generations.

  PYTHONPATH=src python examples/axo_serving.py [--arch granite-3-2b]
      [--batch 4] [--prompt-len 24] [--gen 24] [--ranks 1 4 16]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.axo import AxOOperator, axo_linear
from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCH_IDS, get_arch
from repro.core.dataset import build_training_dataset
from repro.core.dse import DSESettings, map_solution_pool, run_dse
from repro.core.operator_model import spec_for
from repro.data.synthetic import SyntheticLM
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.model import model_spec
from repro.models.sharding import BASE_RULES
from repro.models.spec import init_params


def pick_operator(seed: int = 0) -> AxOOperator:
    """Run a quick 8x8 DSE and deploy the most accurate Pareto design."""
    spec = spec_for(8)
    ds = build_training_dataset(
        spec, n_random=600, seed=seed,
        cache_path="experiments/cache/ds8_serving.npz")
    st = DSESettings(const_sf=1.0, pop_size=32, n_gen=15, n_quad_grid=(0, 4),
                     pool_size=4, seed=seed)
    pool = map_solution_pool(spec, ds, st)
    res = run_dse(spec, ds, "map+ga", settings=st, map_pool=pool)
    best = res.vpf_configs[int(np.argmin(res.vpf_objs[:, 0]))]
    print(f"DSE picked config with BEHAV={res.vpf_objs[:,0].min():.3f}% "
          f"PDPLUT={res.vpf_objs[np.argmin(res.vpf_objs[:,0]), 1]:.0f}")
    return best


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=sorted(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--ranks", type=int, nargs="+", default=[1, 4, 16])
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    rules = BASE_RULES
    max_seq = args.prompt_len + args.gen
    params = init_params(model_spec(cfg), seed=0)
    data = SyntheticLM(cfg, ShapeConfig("serve", max_seq, args.batch, "train"))
    toks = jnp.asarray(data.batch(0)["tokens"])[:, : args.prompt_len]

    prefill = jax.jit(make_prefill_step(cfg, rules, max_seq=max_seq))
    decode = jax.jit(make_decode_step(cfg, rules))

    unemb = (params["embed"]["tok"].T if cfg.tie_embeddings
             else params["embed"]["unembed"]).astype(jnp.float32)

    def generate(head_fn):
        """Greedy decode; ``head_fn(hidden) -> logits`` is swappable."""
        logits, cache = prefill(params, toks)
        # the serving head: re-run the last hidden state through head_fn is
        # equivalent here to replacing the final matmul
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out = [nxt]
        for i in range(args.prompt_len, max_seq - 1):
            logits, cache = decode(params, cache, nxt, jnp.int32(i))
            nxt = jnp.argmax(head_fn(logits), -1)[:, None].astype(jnp.int32)
            out.append(nxt)
        return jnp.concatenate(out, 1)

    t0 = time.time()
    exact = generate(lambda lg: lg[:, -1])
    print(f"exact serving: {args.batch}x{args.gen} tokens in {time.time()-t0:.1f}s")

    op_cfg = pick_operator()
    for rank in args.ranks:
        op = AxOOperator.from_config(op_cfg, rank=rank)
        # AxO arithmetic on the head: logits = axo_linear(hidden, W_unemb)
        # (demonstrated on the final matmul; any linear layer can be swapped)
        rng = np.random.default_rng(1)
        h = jnp.asarray(rng.standard_normal((64, cfg.d_model)), jnp.float32)
        lg_axo = axo_linear(h, unemb, op)
        lg_ref = h @ unemb
        top1 = float((jnp.argmax(lg_axo, -1) == jnp.argmax(lg_ref, -1)).mean())
        rel = float(jnp.linalg.norm(lg_axo - lg_ref) / jnp.linalg.norm(lg_ref))
        print(f"rank={rank:3d}: LM-head rel_err={rel:.4f} top1_agreement={top1:.1%} "
              f"(factorization cost {op.rank_behav()['AVG_ABS_REL_ERR']:.3f}% AVG_ABS_REL_ERR)")

    print("generated ids (exact, row 0):", np.asarray(exact[0, :12]).tolist(), "...")


if __name__ == "__main__":
    main()
