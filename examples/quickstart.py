"""Quickstart: the whole AxOMaP loop on the signed 4x4 multiplier in ~a minute.

  PYTHONPATH=src python examples/quickstart.py

1. characterize the design space (simulated synthesis + exhaustive behavior)
2. correlation analysis -> correlation-ranked quadratic terms
3. MIQCP battery -> MaP solution pool
4. MaP-augmented NSGA-II -> validated Pareto front
5. deploy the best config as TPU serving arithmetic (rank-R axo_linear)
"""

import numpy as np

from repro.axo import AxOOperator, axo_linear
from repro.core.correlation import bivariate_correlation
from repro.core.dataset import build_training_dataset
from repro.core.dse import DSESettings, map_solution_pool, run_dse
from repro.core.operator_model import spec_for

import jax.numpy as jnp


def main():
    spec = spec_for(4)
    print(f"operator: signed {spec.n_bits}x{spec.n_bits} multiplier, "
          f"L={spec.n_luts} removable LUTs, {2**spec.n_luts} designs")

    # 1. characterization dataset (RANDOM + PATTERN)
    ds = build_training_dataset(spec, n_random=300, seed=0)
    print(f"characterized {len(ds)} configs; "
          f"PDPLUT range [{ds.metrics['PDPLUT'].min():.0f}, "
          f"{ds.metrics['PDPLUT'].max():.0f}]")

    # 2. correlation analysis
    r = bivariate_correlation(ds.configs.astype(float), ds.metrics["PDPLUT"])
    print("top-3 PDPLUT-correlated LUTs:",
          ", ".join(f"LUT_{i} (r={r[i]:+.2f})" for i in np.argsort(-np.abs(r))[:3]))

    # 3 + 4. MaP pool -> MaP-augmented GA -> validated Pareto front
    st = DSESettings(const_sf=1.2, pop_size=32, n_gen=20, n_quad_grid=(0, 4),
                     pool_size=6, seed=0)
    pool = map_solution_pool(spec, ds, st)
    print(f"MaP solution pool: {len(pool)} configs")
    ga = run_dse(spec, ds, "ga", settings=st)
    mapga = run_dse(spec, ds, "map+ga", settings=st, map_pool=pool)
    print(f"hypervolume  GA-only={ga.hv_vpf:.4g}  MaP+GA={mapga.hv_vpf:.4g} "
          f"({100 * (mapga.hv_vpf - ga.hv_vpf) / max(ga.hv_vpf, 1e-9):+.1f}%)")
    print("validated Pareto front (BEHAV %, PDPLUT):")
    for (b, p), c in zip(mapga.vpf_objs[:6], mapga.vpf_configs[:6]):
        print(f"  {b:8.3f}  {p:10.1f}   config={''.join(map(str, c))}")

    # 5. deploy the most accurate front design on the TPU path
    best = mapga.vpf_configs[int(np.argmin(mapga.vpf_objs[:, 0]))]
    op = AxOOperator.from_config(best, rank=8, n_bits=4)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    y = axo_linear(x, w, op)
    rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
    print(f"deployed via rank-{op.rank} axo_linear: "
          f"relative deviation from exact fp32 matmul = {rel:.3%} "
          f"(int4 quantization + approximation)")


if __name__ == "__main__":
    main()
