"""Behavioral-accuracy (BEHAV) metrics for approximate operator configs.

Metrics follow AxOMaP Table 3: AVG_ABS_ERR, AVG_ABS_REL_ERR (percent), PROB_ERR
(percent of input pairs producing any error), plus MAX_ABS_ERR and MSE.  All are
computed exhaustively over all ``2^{2N}`` input pairs, as in the paper.

Two backends share this entry point: the numpy path below is the bit-exact
oracle; ``backend="jax"`` routes to :mod:`repro.core.fastchar`, which evaluates
the same statistics as batched device dispatches (tiled Pallas/XLA reductions,
no float64 error tables).  AVG_ABS_ERR/PROB_ERR/MAX_ABS_ERR/MSE are
bit-identical across backends; AVG_ABS_REL_ERR agrees to ~1e-6 relative
(float32 accumulation of the relative-error weights on device).
"""

from __future__ import annotations

import numpy as np

from .operator_model import OperatorSpec, exact_table, product_tables

BEHAV_METRICS = ("AVG_ABS_ERR", "AVG_ABS_REL_ERR", "PROB_ERR", "MAX_ABS_ERR", "MSE")

__all__ = ["BEHAV_METRICS", "behav_metrics"]


def behav_metrics(
    spec: OperatorSpec, configs: np.ndarray, batch_size: int = 256,
    backend="numpy",
) -> dict[str, np.ndarray]:
    """Exhaustive BEHAV metrics for a batch of configs.

    Returns a dict of float64 arrays of shape (D,).  ``backend`` is a legacy
    string (``"jax"`` runs the accelerator fast path, ``"numpy"`` the oracle)
    or an :class:`repro.core.engine.ExecutionContext`, which additionally
    selects the kernel impl and the config-axis device sharding.
    """
    from .engine import as_context

    ctx = as_context(backend)
    if ctx.is_jax:
        from .fastchar import behav_metrics_jax  # lazy: keeps numpy path JAX-free

        return behav_metrics_jax(spec, configs, batch_size=batch_size, ctx=ctx)
    configs = np.atleast_2d(np.asarray(configs))
    d = configs.shape[0]
    exact = exact_table(spec)
    denom = np.maximum(np.abs(exact), 1).astype(np.float64)

    out = {k: np.empty(d, dtype=np.float64) for k in BEHAV_METRICS}
    for lo in range(0, d, batch_size):
        hi = min(lo + batch_size, d)
        approx = product_tables(spec, configs[lo:hi]).astype(np.int64)
        err = approx - exact[None]
        abs_err = np.abs(err).astype(np.float64)
        out["AVG_ABS_ERR"][lo:hi] = abs_err.mean(axis=(1, 2))
        out["AVG_ABS_REL_ERR"][lo:hi] = 100.0 * (abs_err / denom[None]).mean(axis=(1, 2))
        out["PROB_ERR"][lo:hi] = 100.0 * (err != 0).mean(axis=(1, 2))
        out["MAX_ABS_ERR"][lo:hi] = abs_err.max(axis=(1, 2))
        out["MSE"][lo:hi] = (abs_err**2).mean(axis=(1, 2))
    return out
