"""Multi-objective optimization utilities: Pareto fronts, hypervolume, NSGA-II.

The GA matches the paper's setup (§4.3.2): binary chromosomes, tournament
selection, single-point crossover, bit-flip mutation, up to 250 generations, with
constraint-domination (feasibility-first) handling of the ``const_sf`` bounds.
``initial_population`` is how MaP augmentation enters (paper Fig. 6): MaP solutions
are injected alongside random configs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

__all__ = [
    "pareto_mask",
    "hypervolume_2d",
    "fast_nondominated_sort",
    "crowding_distance",
    "nsga2",
    "GAResult",
]


def pareto_mask(points: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows (all objectives minimized)."""
    pts = np.asarray(points, dtype=np.float64)
    n = pts.shape[0]
    mask = np.ones(n, dtype=bool)
    order = np.lexsort(pts.T[::-1])  # sort by first objective, then others
    pts_sorted = pts[order]
    if pts.shape[1] == 2:
        best_y = np.inf
        for rank, i in enumerate(order):
            y = pts_sorted[rank, 1]
            if y < best_y:
                best_y = y
            else:
                mask[i] = False  # weakly dominated by an earlier (<= x, <= y) point
        return mask
    for i in range(n):
        if not mask[i]:
            continue
        dominated = np.all(pts <= pts[i], axis=1) & np.any(pts < pts[i], axis=1)
        if dominated.any():
            mask[i] = False
    return mask


def hypervolume_2d(points: np.ndarray, ref: np.ndarray) -> float:
    """Exact 2-D hypervolume (minimization) w.r.t. reference point ``ref``."""
    pts = np.asarray(points, dtype=np.float64).reshape(-1, 2)
    ref = np.asarray(ref, dtype=np.float64)
    pts = pts[np.all(pts <= ref, axis=1)]
    if pts.size == 0:
        return 0.0
    pts = pts[pareto_mask(pts)]
    pts = pts[np.argsort(pts[:, 0])]
    hv = 0.0
    prev_y = ref[1]
    for x, y in pts:
        if y < prev_y:
            hv += (ref[0] - x) * (prev_y - y)
            prev_y = y
    return float(hv)


def fast_nondominated_sort(objs: np.ndarray, feas_viol: np.ndarray | None = None) -> np.ndarray:
    """Rank (0 = best front) with constraint domination: any feasible point
    dominates any infeasible one; infeasible points compare by violation."""
    n = objs.shape[0]
    if feas_viol is None:
        feas_viol = np.zeros(n)
    rank = np.full(n, -1, dtype=np.int64)

    dom = np.zeros((n, n), dtype=bool)
    le = (objs[:, None, :] <= objs[None, :, :]).all(-1)
    lt = (objs[:, None, :] < objs[None, :, :]).any(-1)
    obj_dom = le & lt
    fi = feas_viol <= 0
    both_feas = fi[:, None] & fi[None, :]
    both_infeas = ~fi[:, None] & ~fi[None, :]
    dom |= both_feas & obj_dom
    dom |= fi[:, None] & ~fi[None, :]
    dom |= both_infeas & (feas_viol[:, None] < feas_viol[None, :])

    n_dominators = dom.sum(axis=0)
    current = np.where(n_dominators == 0)[0]
    r = 0
    remaining = n_dominators.copy()
    assigned = np.zeros(n, dtype=bool)
    while current.size:
        rank[current] = r
        assigned[current] = True
        for i in current:
            remaining[dom[i]] -= 1
        current = np.where((remaining == 0) & ~assigned)[0]
        r += 1
    return rank


def crowding_distance(objs: np.ndarray) -> np.ndarray:
    n, m = objs.shape
    dist = np.zeros(n)
    if n <= 2:
        return np.full(n, np.inf)
    for k in range(m):
        order = np.argsort(objs[:, k])
        dist[order[0]] = dist[order[-1]] = np.inf
        span = objs[order[-1], k] - objs[order[0], k]
        if span <= 0:
            continue
        dist[order[1:-1]] += (objs[order[2:], k] - objs[order[:-2], k]) / span
    return dist


@dataclass
class GAResult:
    population: np.ndarray                 # (P, L) final population
    objectives: np.ndarray                 # (P, 2)
    archive_configs: np.ndarray            # all evaluated configs
    archive_objs: np.ndarray
    archive_viol: np.ndarray
    hv_history: list[tuple[int, float]] = field(default_factory=list)
    # (fitness evaluations, hypervolume of feasible archive pareto front)


def nsga2(
    eval_fn: Callable[[np.ndarray], np.ndarray] | None,
    n_bits: int,
    pop_size: int = 64,
    n_gen: int = 250,
    seed: int = 0,
    initial_population: np.ndarray | None = None,
    violation_fn: Callable[[np.ndarray], np.ndarray] | None = None,
    hv_ref: np.ndarray | None = None,
    crossover_p: float = 0.9,
    mutation_p: float | None = None,
    eval_viol_fn: Callable[[np.ndarray], tuple[np.ndarray, np.ndarray]] | None = None,
    backend="numpy",
    objs_device_fn: Callable | None = None,
    max_behav: float | None = None,
    max_ppa: float | None = None,
) -> GAResult:
    """NSGA-II for binary chromosomes; ``eval_fn`` maps (B, L) -> (B, n_obj).

    ``eval_viol_fn`` is the batched fast path: a single callable returning
    ``(objectives, violations)`` for a whole generation, letting a jit-compiled
    surrogate (``repro.core.fastchar.compile_surrogate_batch``) evaluate each
    generation in one device dispatch.  When given it replaces both ``eval_fn``
    and ``violation_fn``.

    ``backend`` is a legacy string or an ``ExecutionContext`` (whose
    ``resolved_ga_backend`` decides the engine and whose PRNG policy / rank
    kernel preference carry into it).  ``"jax"`` runs the *whole* GA --
    operators, sorting, environmental selection, archive hypervolume -- as
    one compiled device program
    (``repro.core.fastmoo``).  It requires ``objs_device_fn``, a pure jnp
    ``(B, L) -> (B, 2)`` objective closure (e.g.
    ``fastchar.surrogate_objs_device`` or the ``.objs_fn`` attribute of
    ``compile_surrogate_batch``'s result), with optional constraint bounds
    ``max_behav``/``max_ppa`` (the normalized-overflow violation used by the
    DSE layer).  RNG streams differ from numpy's, so results match the numpy
    oracle in hypervolume, not bit-for-bit.
    """
    from .engine import ExecutionContext, as_context

    ctx = as_context(backend)
    if ctx.resolved_ga_backend == "jax":
        from .fastmoo import UNBOUNDED, nsga2_jax  # lazy JAX import

        if objs_device_fn is None:
            raise ValueError("backend='jax' requires objs_device_fn")
        if violation_fn is not None or eval_viol_fn is not None:
            raise ValueError(
                "backend='jax' evaluates constraints on device: pass "
                "max_behav/max_ppa bounds instead of violation_fn/eval_viol_fn"
            )
        return nsga2_jax(
            objs_device_fn,
            n_bits=n_bits,
            pop_size=pop_size,
            n_gen=n_gen,
            seed=seed,
            initial_population=initial_population,
            hv_ref=hv_ref,
            crossover_p=crossover_p,
            mutation_p=mutation_p,
            max_behav=UNBOUNDED if max_behav is None else max_behav,
            max_ppa=UNBOUNDED if max_ppa is None else max_ppa,
            ctx=backend if isinstance(backend, ExecutionContext) else None,
        )
    rng = np.random.default_rng(seed)
    mutation_p = mutation_p if mutation_p is not None else 1.0 / n_bits
    if eval_fn is None and eval_viol_fn is None:
        raise ValueError("one of eval_fn / eval_viol_fn is required")

    pop = rng.integers(0, 2, size=(pop_size, n_bits)).astype(np.uint8)
    if initial_population is not None and len(initial_population):
        k = min(len(initial_population), pop_size)
        pop[:k] = initial_population[:k]

    def evaluate(P):
        if eval_viol_fn is not None:
            objs, viol = eval_viol_fn(P)
            return (
                np.asarray(objs, dtype=np.float64),
                np.asarray(viol, dtype=np.float64),
            )
        objs = np.asarray(eval_fn(P), dtype=np.float64)
        viol = (
            np.asarray(violation_fn(P), dtype=np.float64)
            if violation_fn is not None
            else np.zeros(len(P))
        )
        return objs, viol

    objs, viol = evaluate(pop)
    arc_c, arc_o, arc_v = [pop.copy()], [objs.copy()], [viol.copy()]
    n_evals = pop_size
    hv_hist: list[tuple[int, float]] = []

    def record_hv():
        if hv_ref is None:
            return
        ac = np.concatenate(arc_o)
        av = np.concatenate(arc_v)
        feas = av <= 0
        hv = hypervolume_2d(ac[feas], hv_ref) if feas.any() else 0.0
        hv_hist.append((n_evals, hv))

    record_hv()

    for gen in range(n_gen):
        rank = fast_nondominated_sort(objs, viol)
        crowd = np.zeros(pop_size)
        for r in np.unique(rank):
            idx = np.where(rank == r)[0]
            crowd[idx] = crowding_distance(objs[idx])

        # binary tournament selection
        cand = rng.integers(0, pop_size, size=(pop_size, 2))
        a, b = cand[:, 0], cand[:, 1]
        better = (rank[a] < rank[b]) | ((rank[a] == rank[b]) & (crowd[a] > crowd[b]))
        parents = np.where(better, a, b)

        # single-point crossover
        children = pop[parents].copy()
        for i in range(0, pop_size - 1, 2):
            if rng.random() < crossover_p:
                cut = rng.integers(1, n_bits)
                tmp = children[i, cut:].copy()
                children[i, cut:] = children[i + 1, cut:]
                children[i + 1, cut:] = tmp
        # bit-flip mutation
        flip = rng.random(children.shape) < mutation_p
        children = children ^ flip.astype(np.uint8)

        c_objs, c_viol = evaluate(children)
        n_evals += pop_size
        arc_c.append(children.copy())
        arc_o.append(c_objs.copy())
        arc_v.append(c_viol.copy())

        # environmental selection on combined population
        all_pop = np.concatenate([pop, children])
        all_objs = np.concatenate([objs, c_objs])
        all_viol = np.concatenate([viol, c_viol])
        all_rank = fast_nondominated_sort(all_objs, all_viol)
        order = []
        for r in np.unique(all_rank):
            idx = np.where(all_rank == r)[0]
            if len(order) + len(idx) <= pop_size:
                order.extend(idx.tolist())
            else:
                cd = crowding_distance(all_objs[idx])
                keep = idx[np.argsort(-cd)][: pop_size - len(order)]
                order.extend(keep.tolist())
                break
        sel = np.array(order[:pop_size])
        pop, objs, viol = all_pop[sel], all_objs[sel], all_viol[sel]
        if gen % 10 == 9 or gen == n_gen - 1:
            record_hv()

    return GAResult(
        population=pop,
        objectives=objs,
        archive_configs=np.concatenate(arc_c),
        archive_objs=np.concatenate(arc_o),
        archive_viol=np.concatenate(arc_v),
        hv_history=hv_hist,
    )
