"""Simulated-synthesis PPA model (the "Vivado" stage of AxOMaP, see DESIGN.md §3.1).

The paper characterizes every sampled config with Xilinx Vivado (synthesis +
simulation-driven switching activity + power analysis) on a Virtex-7 device.  No FPGA
toolchain exists here, so this module is a *deterministic analytical synthesis model*
with the same interface and the same qualitative structure:

  * LUTS  -- kept removable LUTs + always-present logic (per-row sign column +
             row-merge adder tree).
  * CPD   -- dominated by the longest surviving carry-chain run (MUXCY segments are
             fast but serial); removal of a mid-row LUT *shortens* the chain.  This
             is a step-like nonlinear function of the config, which is why CPD is
             the hardest metric to regress (paper Table 3: R2 ~ 0.82-0.88).
  * POWER -- dynamic switching power from the exact per-bit toggle statistics of the
             behavioral model under uniform inputs (2*p*(1-p) activity per net),
             plus per-LUT static/clock overhead.
  * PDP = POWER * CPD  (fJ);  PDPLUT = PDP * LUTS  (the paper's headline PPA metric).

All constants are in ``SynthesisModel`` so tests/benchmarks can use alternative
technology points.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from .operator_model import OperatorSpec, config_to_masks, row_tables

PPA_METRICS = ("POWER", "CPD", "LUTS", "PDP", "PDPLUT")

__all__ = ["PPA_METRICS", "SynthesisModel", "ppa_metrics", "merge_tree_luts"]


@dataclass(frozen=True)
class SynthesisModel:
    """Technology constants (loosely modeled on a Virtex-7 speedgrade -2)."""

    t_route: float = 0.60   # ns, input routing + net delay
    t_lut: float = 0.45     # ns, LUT6 logic delay
    t_mux: float = 0.065    # ns, MUXCY carry hop
    t_fan: float = 0.004    # ns per kept LUT (routing congestion term)
    p_base: float = 40.0    # uW, clock tree + static
    k_sum: float = 9.0      # uW per unit of row sum-bit activity
    k_merge: float = 7.0    # uW per unit of merge-adder input activity
    k_lut: float = 1.4      # uW per kept LUT


DEFAULT_SYNTH = SynthesisModel()


def merge_tree_luts(spec: OperatorSpec) -> tuple[int, float, int]:
    """(total merge LUTs, merge delay ns, levels) for the always-accurate adder tree."""
    synth = DEFAULT_SYNTH
    n_vals = spec.rows
    width = spec.width
    luts = 0
    delay = 0.0
    levels = 0
    offset = 2
    while n_vals > 1:
        n_adders = n_vals // 2
        width = width + offset * 2  # operands are offset by 2*2^level bit positions
        luts += n_adders * width
        delay += synth.t_lut + width * synth.t_mux
        n_vals = n_adders + (n_vals % 2)
        levels += 1
        offset *= 2
    return luts, delay, levels


@functools.lru_cache(maxsize=None)
def _longest_run_table(cols: int) -> np.ndarray:
    """For every row mask, the longest run of consecutive kept carry cells.

    The always-kept top (sign) column extends the chain by one, so the run is
    computed over ``bits(mask) + [1]``.
    """
    n_mask = 1 << cols
    out = np.zeros(n_mask, dtype=np.int64)
    for m in range(n_mask):
        best = run = 0
        for j in range(cols):
            if (m >> j) & 1:
                run += 1
            else:
                best = max(best, run)
                run = 0
        out[m] = max(best, run + 1)  # +1: top sign column is always kept
    return out


@functools.lru_cache(maxsize=None)
def _activity_tables(n_bits: int) -> tuple[np.ndarray, np.ndarray]:
    """(act_sum, act_merge), each (2[top], 2^(N+1)[mask]) float64.

    act_sum   = sum_j 2 p (1-p) over the row's carry-chain sum bits.
    act_merge = sum_j 2 p (1-p) over the row-output bits feeding the merge tree.
    """
    tabs = row_tables(n_bits)
    act_sum = (2.0 * tabs.sum_p1 * (1.0 - tabs.sum_p1)).sum(axis=-1)
    act_merge = (2.0 * tabs.out_p1 * (1.0 - tabs.out_p1)).sum(axis=-1)
    return act_sum, act_merge


def ppa_metrics(
    spec: OperatorSpec,
    configs: np.ndarray,
    synth: SynthesisModel = DEFAULT_SYNTH,
) -> dict[str, np.ndarray]:
    """Deterministic PPA metrics for a batch of configs; dict of (D,) float64."""
    configs = np.atleast_2d(np.asarray(configs))
    masks = config_to_masks(spec, configs)            # (D, R)
    kept = configs.sum(axis=-1).astype(np.float64)    # (D,)

    run_tab = _longest_run_table(spec.cols_removable)
    max_run = run_tab[masks].max(axis=-1).astype(np.float64)  # (D,)

    merge_luts, merge_delay, _ = merge_tree_luts(spec)
    luts = kept + spec.rows + merge_luts

    cpd = (
        synth.t_route
        + synth.t_lut
        + synth.t_mux * max_run
        + merge_delay
        + synth.t_fan * kept
    )

    act_sum, act_merge = _activity_tables(spec.n_bits)
    top_idx = np.zeros(spec.rows, dtype=np.int64)
    top_idx[-1] = 1
    a_sum = act_sum[top_idx[None, :], masks].sum(axis=-1)      # (D,)
    a_merge = act_merge[top_idx[None, :], masks].sum(axis=-1)  # (D,)
    power = synth.p_base + synth.k_sum * a_sum + synth.k_merge * a_merge + synth.k_lut * kept

    pdp = power * cpd
    return {
        "POWER": power,
        "CPD": cpd,
        "LUTS": luts,
        "PDP": pdp,
        "PDPLUT": pdp * luts,
    }
