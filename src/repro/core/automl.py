"""Mini-AutoML estimator selection (AxOMaP §4.1.3, Table 3).

The paper uses MLJAR AutoML to pick per-metric estimators (CatBoost/LightGBM win).
Here the candidate pool is {ridge-linear, ridge-poly2 (correlation-ranked quadratic
features), small/large GBT}; selection is by validation R^2 and the winner is
refitted on the full dataset -- same role: PPA/BEHAV surrogates for DSE fitness and
Pareto filtering.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .correlation import rank_quadratic_terms
from .gbt import GBTRegressor
from .regression import fit_poly, mae, mse, r2_score

__all__ = ["EstimatorReport", "AutoMLRegressor", "fit_estimators"]


@dataclass
class EstimatorReport:
    metric: str
    selected: str
    mse_train: float
    mse_test: float
    mae_train: float
    mae_test: float
    r2_train: float
    r2_test: float


class AutoMLRegressor:
    """Fit-and-select across candidate model families."""

    def __init__(self, n_quad: int = 48, seed: int = 0):
        self.n_quad = n_quad
        self.seed = seed
        self.model = None
        self.name = "unfit"
        self.report: EstimatorReport | None = None

    def fit(self, X: np.ndarray, y: np.ndarray, metric_name: str = "") -> "AutoMLRegressor":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        rng = np.random.default_rng(self.seed)
        n = X.shape[0]
        perm = rng.permutation(n)
        n_test = max(1, n // 5)
        test, train = perm[:n_test], perm[n_test:]
        Xtr, ytr, Xte, yte = X[train], y[train], X[test], y[test]

        quad = rank_quadratic_terms(Xtr, ytr)[: self.n_quad]
        candidates = {
            "ridge-linear": lambda: fit_poly(Xtr, ytr, quad_pairs=[]),
            "ridge-poly2": lambda: fit_poly(Xtr, ytr, quad_pairs=quad),
            "gbt-small": lambda: GBTRegressor(
                n_trees=80, max_depth=3, seed=self.seed
            ).fit(Xtr, ytr),
            "gbt-large": lambda: GBTRegressor(
                n_trees=200, max_depth=4, learning_rate=0.08, seed=self.seed
            ).fit(Xtr, ytr),
        }

        best_name, best_model, best_r2 = None, None, -np.inf
        for name, make in candidates.items():
            model = make()
            r2 = r2_score(yte, model.predict(Xte))
            if r2 > best_r2:
                best_name, best_model, best_r2 = name, model, r2

        # Test-set numbers come from the held-out fit; then refit on everything.
        pred_tr = best_model.predict(Xtr)
        pred_te = best_model.predict(Xte)
        self.report = EstimatorReport(
            metric=metric_name,
            selected=best_name,
            mse_train=mse(ytr, pred_tr),
            mse_test=mse(yte, pred_te),
            mae_train=mae(ytr, pred_tr),
            mae_test=mae(yte, pred_te),
            r2_train=r2_score(ytr, pred_tr),
            r2_test=r2_score(yte, pred_te),
        )

        quad_full = rank_quadratic_terms(X, y)[: self.n_quad]
        refit = {
            "ridge-linear": lambda: fit_poly(X, y, quad_pairs=[]),
            "ridge-poly2": lambda: fit_poly(X, y, quad_pairs=quad_full),
            "gbt-small": lambda: GBTRegressor(
                n_trees=80, max_depth=3, seed=self.seed
            ).fit(X, y),
            "gbt-large": lambda: GBTRegressor(
                n_trees=200, max_depth=4, learning_rate=0.08, seed=self.seed
            ).fit(X, y),
        }
        self.model = refit[best_name]()
        self.name = best_name
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self.model.predict(np.asarray(X, dtype=np.float64))


def fit_estimators(
    X: np.ndarray, metrics: dict[str, np.ndarray], n_quad: int = 48, seed: int = 0
) -> dict[str, AutoMLRegressor]:
    """One selected estimator per metric name."""
    return {
        name: AutoMLRegressor(n_quad=n_quad, seed=seed).fit(X, y, metric_name=name)
        for name, y in metrics.items()
    }
