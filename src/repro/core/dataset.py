"""Characterization dataset generation (AxOMaP §4.1.1, Figs. 5/7/8).

The paper observes that uniform random sampling of LUT configs concentrates the PPA
metrics in a narrow band, and augments RANDOM sampling with PATTERN sampling --
"moving windows of consecutive and/or alternating ones and zeros" -- to widen the
metric distribution.  ``gen_pattern`` reproduces that scheme.

``characterize`` accepts ``backend="numpy"`` (bit-exact oracle, default) or
``"jax"`` (the batched ``repro.core.fastchar`` engine) for the BEHAV half of
the characterization; PPA always uses the shared numpy synthesis tables.

Config *generation* follows the execution context's PRNG policy end to end:
``gen_random`` (and ``build_training_dataset``, which forwards its
``backend`` context) keeps the legacy numpy ``default_rng`` stream under the
default policy -- existing datasets and caches stay bit-identical -- and
switches to device-side ``jax.random`` generation under a context with a
named ``prng_impl`` (``"rbg"``/``"unsafe_rbg"``: the TPU-native generators,
the ROADMAP follow-on), keyed by ``ExecutionContext.prng_key`` so the same
typed-key family drives dataset sampling and the GA engine.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field

import numpy as np

from .metrics import BEHAV_METRICS, behav_metrics
from .operator_model import OperatorSpec, accurate_config
from .ppa import PPA_METRICS, SynthesisModel, DEFAULT_SYNTH, ppa_metrics

# Headline objectives used throughout the paper's DSE experiments.
PPA_KEY = "PDPLUT"
BEHAV_KEY = "AVG_ABS_REL_ERR"

ALL_METRICS = tuple(BEHAV_METRICS) + tuple(PPA_METRICS)

__all__ = [
    "PPA_KEY",
    "BEHAV_KEY",
    "ALL_METRICS",
    "Dataset",
    "gen_random",
    "gen_pattern",
    "characterize",
    "dedup_configs",
    "build_training_dataset",
]


@dataclass
class Dataset:
    """A characterized set of operator configs."""

    configs: np.ndarray                       # (D, L) uint8
    metrics: dict[str, np.ndarray]            # name -> (D,) float64
    source: np.ndarray = field(default=None)  # (D,) uint8: 0=random 1=pattern 2=dse

    def __post_init__(self) -> None:
        if self.source is None:
            self.source = np.zeros(len(self.configs), dtype=np.uint8)

    def __len__(self) -> int:
        return len(self.configs)

    def subset(self, idx: np.ndarray) -> "Dataset":
        return Dataset(
            configs=self.configs[idx],
            metrics={k: v[idx] for k, v in self.metrics.items()},
            source=self.source[idx],
        )

    def concat(self, other: "Dataset") -> "Dataset":
        keys = [k for k in self.metrics if k in other.metrics]
        return Dataset(
            configs=np.concatenate([self.configs, other.configs]),
            metrics={k: np.concatenate([self.metrics[k], other.metrics[k]]) for k in keys},
            source=np.concatenate([self.source, other.source]),
        )

    def objectives(self, ppa_key: str = PPA_KEY, behav_key: str = BEHAV_KEY) -> np.ndarray:
        """(D, 2) [BEHAV, PPA] objective matrix (both minimized)."""
        return np.stack([self.metrics[behav_key], self.metrics[ppa_key]], axis=-1)

    def save(self, path: str) -> None:
        if not path.endswith(".npz"):
            raise ValueError("dataset path must end with .npz")
        tmp = path + ".tmp.npz"
        np.savez_compressed(
            tmp, configs=self.configs, source=self.source,
            **{f"metric_{k}": v for k, v in self.metrics.items()},
        )
        os.replace(tmp, path)

    @staticmethod
    def load(path: str) -> "Dataset":
        with np.load(path) as z:
            metrics = {
                k[len("metric_"):]: z[k] for k in z.files if k.startswith("metric_")
            }
            return Dataset(configs=z["configs"], metrics=metrics, source=z["source"])


def gen_random(spec: OperatorSpec, n: int, seed: int = 0, ctx=None) -> np.ndarray:
    """Uniform random configs (the paper's RANDOM set).

    ``ctx`` (an ``ExecutionContext`` or None) selects the generator: the
    default PRNG policy (no context, numpy backend, or ``prng_impl=None``)
    keeps the legacy numpy stream bit-identical to every earlier release;
    a jax context with a *named* ``prng_impl`` samples on device under that
    family (typed keys from ``ctx.prng_key``), so TPU-native rbg generation
    flows from dataset sampling through the GA with one policy knob.
    """
    if ctx is None or not getattr(ctx, "is_jax", False) or ctx.prng_impl is None:
        rng = np.random.default_rng(seed)
        return rng.integers(0, 2, size=(n, spec.n_luts)).astype(np.uint8)
    import jax

    bits = jax.random.randint(
        ctx.prng_key(seed), (n, spec.n_luts), 0, 2, dtype="uint8"
    )
    return np.asarray(bits, dtype=np.uint8)


def gen_pattern(spec: OperatorSpec) -> np.ndarray:
    """PATTERN configs: moving windows of consecutive / alternating ones and zeros."""
    L = spec.n_luts
    rows: list[np.ndarray] = []

    # Moving windows of zeros in a field of ones and vice versa, all widths/offsets.
    for width in range(1, L + 1):
        for off in range(0, L - width + 1):
            c = np.ones(L, dtype=np.uint8)
            c[off : off + width] = 0
            rows.append(c)
            rows.append(1 - c)

    # Alternating patterns at strides 1..4 and both phases.
    idx = np.arange(L)
    for stride in range(1, 5):
        for phase in range(stride + 1):
            rows.append(((idx + phase) // max(stride, 1) % 2).astype(np.uint8))

    # Whole-row removal patterns (each subset of rows removed is too many for 8x8;
    # use single-row and prefix-of-rows removals).
    cpr = spec.cols_removable
    for r in range(spec.rows):
        c = np.ones(L, dtype=np.uint8)
        c[r * cpr : (r + 1) * cpr] = 0
        rows.append(c)
        c2 = np.ones(L, dtype=np.uint8)
        c2[: (r + 1) * cpr] = 0
        rows.append(c2)

    # Per-row truncation ladders (drop lowest j columns of every row) -- the classic
    # truncated-multiplier family; gives very low PPA corners.
    for j in range(1, cpr + 1):
        c = np.ones(L, dtype=np.uint8)
        for r in range(spec.rows):
            c[r * cpr : r * cpr + j] = 0
        rows.append(c)

    out = np.stack(rows)
    return dedup_configs(out)


def dedup_configs(configs: np.ndarray) -> np.ndarray:
    """Remove duplicate rows, preserving first-seen order."""
    _, idx = np.unique(configs, axis=0, return_index=True)
    return configs[np.sort(idx)]


def characterize(
    spec: OperatorSpec,
    configs: np.ndarray,
    synth: SynthesisModel = DEFAULT_SYNTH,
    source: int = 0,
    batch_size: int = 256,
    backend="numpy",
) -> Dataset:
    """Full characterization (exhaustive BEHAV + simulated-synthesis PPA).

    ``backend`` is a legacy string or an ``ExecutionContext``; the jax backend
    evaluates the BEHAV metrics with the batched ``repro.core.fastchar``
    engine (config-sharded over the context's mesh when one is set; PPA stays
    on the cheap numpy tables).  The default ``"numpy"`` path is the bit-exact
    oracle.
    """
    configs = np.atleast_2d(np.asarray(configs)).astype(np.uint8)
    metrics = dict(
        behav_metrics(spec, configs, batch_size=batch_size, backend=backend)
    )
    metrics.update(ppa_metrics(spec, configs, synth))
    return Dataset(
        configs=configs,
        metrics=metrics,
        source=np.full(len(configs), source, dtype=np.uint8),
    )


def build_training_dataset(
    spec: OperatorSpec,
    n_random: int = 2000,
    seed: int = 0,
    include_pattern: bool = True,
    cache_path: str | None = None,
    include_accurate: bool = True,
    backend="numpy",
) -> Dataset:
    """RANDOM + PATTERN training dataset (cached to ``cache_path`` if given).

    ``backend`` (a legacy string or an ``ExecutionContext``) is forwarded to
    :func:`characterize` for the BEHAV half *and* to :func:`gen_random` for
    the RANDOM half, so a context's ``prng_impl`` policy governs generation
    end to end.  Under the default PRNG policy the generated configs are
    bit-identical to every earlier release; when naming a device PRNG
    family, point ``cache_path`` somewhere impl-specific -- the cache key
    does not encode the generator.
    """
    if cache_path is not None and os.path.exists(cache_path):
        return Dataset.load(cache_path)

    from .engine import as_context

    ctx = as_context(backend)
    parts = [gen_random(spec, n_random, seed=seed, ctx=ctx)]
    sources = [np.zeros(n_random, dtype=np.uint8)]
    if include_pattern:
        pat = gen_pattern(spec)
        parts.append(pat)
        sources.append(np.ones(len(pat), dtype=np.uint8))
    if include_accurate:
        parts.append(accurate_config(spec)[None])
        sources.append(np.zeros(1, dtype=np.uint8))

    configs = np.concatenate(parts)
    source = np.concatenate(sources)
    # dedup while keeping source labels of first occurrence
    _, idx = np.unique(configs, axis=0, return_index=True)
    idx = np.sort(idx)
    configs, source = configs[idx], source[idx]

    ds = characterize(spec, configs, backend=backend)
    ds.source = source
    if cache_path is not None:
        os.makedirs(os.path.dirname(cache_path) or ".", exist_ok=True)
        ds.save(cache_path)
    return ds
