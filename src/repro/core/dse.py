"""End-to-end DSE pipelines (AxOMaP §4.3, Figs. 11-19).

Three search methods over the binary LUT-config space, all sharing one
surrogate-estimator stack and one hypervolume accounting:

  * ``map``     -- solve the MaP problem battery (wt_B sweep x quad-term sweep x
                   const_sf bounds) and take the union solution pool.
  * ``ga``      -- problem-agnostic NSGA-II on surrogate fitness, random init
                   (this is the AppAxO-style baseline).
  * ``map+ga``  -- NSGA-II seeded with the MaP pool (the paper's contribution).

PPF (pseudo Pareto front) = Pareto filter under *estimated* metrics of everything
the search evaluated; VPF (validated Pareto front) = the PPF re-characterized with
the actual synthesis+behavioral models and Pareto-filtered again.  Hypervolumes for
both are reported against a shared reference point derived from the training set.

``fixed_library`` is the EvoApprox-style baseline: a frozen, search-free library of
classic truncation/removal designs, only feasibility-filtered per problem.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .automl import AutoMLRegressor, fit_estimators
from .correlation import rank_quadratic_terms
from .dataset import BEHAV_KEY, PPA_KEY, Dataset, characterize, gen_random
from .engine import ExecutionContext, as_context
from .miqcp import MapProblem, build_problems, solve_pool
from .moo import GAResult, hypervolume_2d, nsga2, pareto_mask
from .operator_model import OperatorSpec
from .regression import fit_poly

__all__ = [
    "DSESettings",
    "DSEResult",
    "hv_reference",
    "map_solution_pool",
    "run_dse",
    "run_dse_sweep",
    "fixed_library",
    "CONST_SF_GRID",
]

# The paper's constraint-scaling grid (Eq. 8).
CONST_SF_GRID = (0.2, 0.5, 0.8, 1.0, 1.2, 1.5)


@dataclass
class DSESettings:
    """Knobs shared by every method (defaults sized for the 8x8 operator).

    ``context`` is the unified execution policy
    (:class:`repro.core.engine.ExecutionContext`): backend selection, device
    mesh + shard axes, kernel-impl preference and PRNG policy, consumed by
    every engine ``run_dse``/``run_dse_sweep`` touches.

    ``backend`` / ``ga_backend`` are the legacy string shims: they construct
    the equivalent context when ``context`` is not given (``"jax"`` routes VPF
    re-characterization through ``repro.core.fastchar``, batches the MaP
    solver scoring on device, and runs the GA on ``repro.core.fastmoo``;
    ``ga_backend=None`` follows ``backend``).  ``tuning`` is the kernel
    block-shape autotune policy (``repro.kernels.tuning``): like the strings
    it seeds the constructed context, and like them it must agree with an
    explicitly-passed one.  Passing both a context and conflicting
    strings/policies is an eager error, as is any invalid mesh/axis combo
    (unknown backend, sharding under numpy, more devices than exist).
    """

    ppa_key: str = PPA_KEY
    behav_key: str = BEHAV_KEY
    const_sf: float = 1.0
    pop_size: int = 64
    n_gen: int = 100                     # paper uses up to 250; 100 is the default budget here
    n_quad_grid: tuple[int, ...] = (0, 4, 8, 16, 32)
    wt_step: float = 0.05
    pool_size: int = 8
    seed: int = 0
    n_estimator_quad: int = 48
    backend: str | None = None           # None = follow context (default numpy)
    ga_backend: str | None = None
    tuning: str | None = None            # None = follow context (default "off")
    telemetry: object | None = None      # None = follow context ("on"/"off"/sink)
    context: ExecutionContext | None = None

    def __post_init__(self) -> None:
        # fail at construction, not deep inside characterize with an opaque error
        ctx = self.context
        if ctx is None:
            ctx = ExecutionContext(
                backend=self.backend if self.backend is not None else "numpy",
                ga_backend=self.ga_backend,
                tuning=self.tuning if self.tuning is not None else "off",
                telemetry=self.telemetry,
            )
        else:
            if not isinstance(ctx, ExecutionContext):
                raise TypeError(
                    f"context must be an ExecutionContext, got {type(ctx).__name__}"
                )
            if (
                (self.backend is not None and self.backend != ctx.backend)
                or (
                    self.ga_backend is not None
                    and self.ga_backend != ctx.resolved_ga_backend
                )
                or (self.tuning is not None and self.tuning != ctx.tuning)
            ):
                raise ValueError(
                    "conflicting execution policy: pass either context= or the "
                    "legacy backend=/ga_backend=/tuning= knobs, not "
                    "disagreeing both"
                )
            if self.telemetry is not None:
                if ctx.telemetry is None:
                    # telemetry knob + default-telemetry context: adopt it
                    ctx = dataclasses.replace(ctx, telemetry=self.telemetry)
                elif ctx.telemetry is not self.telemetry:
                    raise ValueError(
                        "conflicting telemetry: pass it on the context or as "
                        "the settings knob, not disagreeing both"
                    )
        # mirror the context into the legacy string fields for old readers
        self.context = ctx
        self.backend = ctx.backend
        self.ga_backend = ctx.ga_backend
        self.tuning = ctx.tuning
        self.telemetry = ctx.telemetry

    @property
    def resolved_ga_backend(self) -> str:
        return self.context.resolved_ga_backend


@dataclass
class DSEResult:
    method: str
    settings: DSESettings
    ppf_configs: np.ndarray              # (P, L)
    ppf_objs_est: np.ndarray             # (P, 2) [BEHAV, PPA] estimated
    vpf_configs: np.ndarray              # (V, L)
    vpf_objs: np.ndarray                 # (V, 2) characterized
    hv_ppf: float
    hv_vpf: float
    n_evals: int
    wall_s: float                        # total (back-compat; = sum over stages + overhead)
    hv_history: list[tuple[int, float]] = field(default_factory=list)
    ref_point: np.ndarray | None = None
    # per-stage wall clock (perf_counter seconds): "characterize" (estimator
    # fit + surrogate build), "map" (MaP battery; absent for method="ga"),
    # "ga" (search/eval + PPF), "validate" (ground-truth re-characterization).
    # In sweep results the shared stages carry the whole-sweep duration and
    # "validate" is per-lane.
    timings: dict[str, float] = field(default_factory=dict)


def hv_reference(train_ds: Dataset, settings: DSESettings, margin: float = 1.05) -> np.ndarray:
    """Shared hypervolume reference point: training-set maxima with a margin."""
    b = train_ds.metrics[settings.behav_key].max()
    p = train_ds.metrics[settings.ppa_key].max()
    return np.array([margin * b, margin * p], dtype=np.float64)


def _constraint_bounds(train_ds: Dataset, settings: DSESettings) -> tuple[float, float]:
    """(max_behav, max_ppa) in original units: const_sf x training maxima (Eq. 8)."""
    b_max = float(train_ds.metrics[settings.behav_key].max())
    p_max = float(train_ds.metrics[settings.ppa_key].max())
    return settings.const_sf * b_max, settings.const_sf * p_max


def map_solution_pool(
    spec: OperatorSpec,
    train_ds: Dataset,
    settings: DSESettings,
    backend=None,
) -> np.ndarray:
    """Union MaP solution pool over the wt_B x n_quad battery (§4.3.1).

    ``backend`` (default ``settings.context``; a legacy string is also
    accepted) is forwarded to the MaP solvers; under the jax backend the
    exhaustive-enumeration scoring of each problem runs as one batched device
    dispatch (``fastchar.map_problem_values_jax``), and tabu-sized batteries
    (L > 16) advance all problems' starts in lockstep
    (``miqcp.solve_tabu_multi``).
    """
    backend = as_context(backend, default=settings.context)
    X = train_ds.configs.astype(np.float64)
    yb = train_ds.metrics[settings.behav_key]
    yp = train_ds.metrics[settings.ppa_key]
    b_max, p_max = float(yb.max()), float(yp.max())

    ranked_b = rank_quadratic_terms(X, yb)
    ranked_p = rank_quadratic_terms(X, yp)

    wt_grid = np.arange(0.0, 1.0 + 1e-9, settings.wt_step)
    problems: list[MapProblem] = []
    for n_quad in settings.n_quad_grid:
        bm = fit_poly(X, yb, quad_pairs=ranked_b[:n_quad])
        pm = fit_poly(X, yp, quad_pairs=ranked_p[:n_quad])
        problems.extend(
            build_problems(
                bm, pm, b_max, p_max, settings.const_sf,
                wt_grid=wt_grid, n_quad=n_quad,
            )
        )
    return solve_pool(
        problems, seed=settings.seed, pool_size=settings.pool_size, backend=backend
    )


def _surrogate_eval(
    estimators: dict[str, AutoMLRegressor], settings: DSESettings
) -> Callable[[np.ndarray], np.ndarray]:
    eb = estimators[settings.behav_key]
    ep = estimators[settings.ppa_key]

    def eval_fn(configs: np.ndarray) -> np.ndarray:
        X = configs.astype(np.float64)
        return np.stack([eb.predict(X), ep.predict(X)], axis=-1)

    return eval_fn


def _violation_fn(
    estimators: dict[str, AutoMLRegressor],
    settings: DSESettings,
    max_behav: float,
    max_ppa: float,
) -> Callable[[np.ndarray], np.ndarray]:
    eb = estimators[settings.behav_key]
    ep = estimators[settings.ppa_key]

    def viol(configs: np.ndarray) -> np.ndarray:
        X = configs.astype(np.float64)
        vb = np.maximum(0.0, eb.predict(X) - max_behav) / max(abs(max_behav), 1e-9)
        vp = np.maximum(0.0, ep.predict(X) - max_ppa) / max(abs(max_ppa), 1e-9)
        return vb + vp

    return viol


def _ppf_from_archive(
    configs: np.ndarray,
    objs_est: np.ndarray,
    viol: np.ndarray,
    max_front: int = 64,
) -> tuple[np.ndarray, np.ndarray]:
    """Feasible estimated-Pareto subset of everything a search evaluated."""
    feas = viol <= 0
    if not feas.any():
        return configs[:0], objs_est[:0]
    c, o = configs[feas], objs_est[feas]
    c, idx = np.unique(c, axis=0, return_index=True)
    o = o[idx]
    mask = pareto_mask(o)
    c, o = c[mask], o[mask]
    if len(c) > max_front:  # cap the synthesis bill, keep extremes + spread
        order = np.argsort(o[:, 0])
        keep = np.unique(np.linspace(0, len(c) - 1, max_front).astype(int))
        c, o = c[order][keep], o[order][keep]
    return c, o


def _validate(
    spec: OperatorSpec,
    configs: np.ndarray,
    settings: DSESettings,
    ref: np.ndarray,
    characterize_fn: Callable[[np.ndarray], np.ndarray],
    max_behav: float,
    max_ppa: float,
) -> tuple[np.ndarray, np.ndarray, float]:
    """Re-characterize PPF configs -> VPF (+ its hypervolume)."""
    if len(configs) == 0:
        return configs, np.zeros((0, 2)), 0.0
    objs = characterize_fn(configs)
    feas = (objs[:, 0] <= max_behav + 1e-9) & (objs[:, 1] <= max_ppa + 1e-9)
    configs, objs = configs[feas], objs[feas]
    if len(configs) == 0:
        return configs, objs, 0.0
    mask = pareto_mask(objs)
    configs, objs = configs[mask], objs[mask]
    return configs, objs, hypervolume_2d(objs, ref)


def _default_characterize(
    spec: OperatorSpec, settings: DSESettings
) -> Callable[[np.ndarray], np.ndarray]:
    def fn(configs: np.ndarray) -> np.ndarray:
        ds = characterize(spec, configs, backend=settings.context)
        return ds.objectives(ppa_key=settings.ppa_key, behav_key=settings.behav_key)

    return fn


def _app_name(app) -> str | None:
    return getattr(app, "name", app) if app is not None else None


def _configs_from_bits(bitstrings: list[str], n_luts: int) -> np.ndarray:
    if not bitstrings:
        return np.zeros((0, n_luts), np.uint8)
    return np.stack([
        np.frombuffer(s.encode("ascii"), np.uint8) - ord("0") for s in bitstrings
    ]).astype(np.uint8)


def _result_from_record(
    rec: dict, method: str, settings: DSESettings, ref: np.ndarray,
    spec: OperatorSpec, t0: float,
) -> DSEResult:
    """Rehydrate a cached front record into a DSEResult (request-cache hit)."""
    return DSEResult(
        method=method,
        settings=settings,
        ppf_configs=_configs_from_bits(rec["ppf_configs"], spec.n_luts),
        ppf_objs_est=np.asarray(rec["ppf_objs"], np.float64).reshape(-1, 2),
        vpf_configs=_configs_from_bits(rec["configs"], spec.n_luts),
        vpf_objs=np.asarray(rec["objs"], np.float64).reshape(-1, 2),
        hv_ppf=float(rec["hv_ppf"]),
        hv_vpf=float(rec["hv"]),
        n_evals=int(rec["n_evals"]),
        wall_s=time.perf_counter() - t0,
        hv_history=[],
        ref_point=ref,
        timings={"store": time.perf_counter() - t0},
    )


def _store_front(store, spec, app_name, st: DSESettings, method: str,
                 res: DSEResult, request: str | None) -> None:
    store.put_front(
        spec, app_name, st.const_sf, st.seed, method,
        res.vpf_configs, res.vpf_objs, res.hv_vpf,
        ppf_configs=res.ppf_configs, ppf_objs=res.ppf_objs_est,
        hv_ppf=res.hv_ppf, n_evals=res.n_evals, request=request,
    )


def _surrogate_eval_viol_jax(
    estimators: dict[str, AutoMLRegressor],
    settings: DSESettings,
    max_behav: float,
    max_ppa: float,
) -> Callable[[np.ndarray], tuple[np.ndarray, np.ndarray]]:
    """One jit-compiled (objectives, violation) dispatch per candidate batch."""
    from .fastchar import compile_surrogate_batch  # lazy JAX import

    return compile_surrogate_batch(
        estimators, settings.behav_key, settings.ppa_key, max_behav, max_ppa,
        ctx=settings.context,
    )


def run_dse(
    spec: OperatorSpec,
    train_ds: Dataset,
    method: str,
    settings: DSESettings | None = None,
    estimators: dict[str, AutoMLRegressor] | None = None,
    map_pool: np.ndarray | None = None,
    characterize_fn: Callable[[np.ndarray], np.ndarray] | None = None,
    ref: np.ndarray | None = None,
    app=None,
    telemetry=None,
    store=None,
) -> DSEResult:
    """One full DSE run (one method, one const_sf).

    ``characterize_fn`` maps (D, L) configs -> (D, 2) true [BEHAV, PPA]; defaults to
    the operator-level exhaustive characterization.  Pass an application's objective
    function for application-specific DSE -- or pass the ``repro.apps`` application
    itself as ``app``, which builds that objective with ``settings.backend``
    forwarded (the accelerator-native app engine under ``backend="jax"``).

    ``telemetry`` (``"on"``/``"off"``/a ``repro.obs.Telemetry``) overrides the
    context's sink for this run; with ``"on"`` every stage records a span and
    the sink can be exported (``settings.context.tel.to_chrome_trace(path)``).
    Per-stage wall clock lands in ``DSEResult.timings`` regardless of
    telemetry state.

    ``store`` (a :class:`repro.service.OperatorStore`) activates the persistent
    operator library: already-characterized configs skip the fastchar dispatch
    during validation, a repeated identical request returns its cached front
    without searching, and the GA warm-starts from the library's nearest
    cached fronts.  Only honored when ``characterize_fn`` is not caller-
    supplied (the library is content-addressed by ``(spec, app)``; an opaque
    objective would poison it).  With an empty library every path is
    bit-identical to ``store=None``.
    """
    settings = settings or DSESettings()
    if telemetry is not None:
        settings = dataclasses.replace(
            settings,
            context=dataclasses.replace(settings.context, telemetry=telemetry),
            telemetry=None,
        )
    ctx = settings.context
    tel = ctx.tel
    if method not in ("ga", "map", "map+ga"):
        raise ValueError(f"unknown method {method!r}")

    t0 = time.perf_counter()
    app_name = _app_name(app)
    store_active = store is not None and characterize_fn is None
    req_key = None
    if store_active:
        from ..service.store import request_key, train_fingerprint

        req_key = request_key(
            spec, app_name, settings.const_sf, settings.seed, method,
            settings, train_fingerprint(train_ds),
        )
        rec = store.lookup_result(req_key)
        if rec is not None:
            ref = hv_reference(train_ds, settings) if ref is None else ref
            return _result_from_record(rec, method, settings, ref, spec, t0)
    timings: dict[str, float] = {}
    with tel.span("dse.run", method=method, backend=ctx.backend,
                  const_sf=settings.const_sf):
        ts = time.perf_counter()
        with tel.span("dse.characterize"):
            if app is not None and characterize_fn is None:
                characterize_fn = app.characterize_fn(
                    spec, ppa_key=settings.ppa_key, backend=ctx
                )
            if estimators is None:
                estimators = fit_estimators(
                    train_ds.configs.astype(np.float64),
                    {
                        settings.behav_key: train_ds.metrics[settings.behav_key],
                        settings.ppa_key: train_ds.metrics[settings.ppa_key],
                    },
                    n_quad=settings.n_estimator_quad,
                    seed=settings.seed,
                )
            characterize_fn = characterize_fn or _default_characterize(spec, settings)
            if store_active:
                characterize_fn = store.cached_characterize(
                    spec, characterize_fn, app_name
                )
            ref = hv_reference(train_ds, settings) if ref is None else ref
            max_behav, max_ppa = _constraint_bounds(train_ds, settings)

            use_jax = ctx.is_jax
            if use_jax:
                eval_viol_fn = _surrogate_eval_viol_jax(
                    estimators, settings, max_behav, max_ppa
                )
                eval_fn = viol_fn = None
            else:
                eval_viol_fn = None
                eval_fn = _surrogate_eval(estimators, settings)
                viol_fn = _violation_fn(estimators, settings, max_behav, max_ppa)
        timings["characterize"] = time.perf_counter() - ts

        n_evals = 0
        hv_history: list[tuple[int, float]] = []

        if method in ("map", "map+ga") and map_pool is None:
            ts = time.perf_counter()
            with tel.span("dse.map"):
                map_pool = map_solution_pool(spec, train_ds, settings)
            timings["map"] = time.perf_counter() - ts

        ts = time.perf_counter()
        with tel.span("dse.ga"):
            if method == "map":
                pool = map_pool
                if len(pool) == 0:
                    pool = gen_random(spec, 1, seed=settings.seed)  # degenerate fallback
                if use_jax:
                    objs_est, viol = eval_viol_fn(pool)
                else:
                    objs_est = eval_fn(pool)
                    viol = viol_fn(pool)
                n_evals = len(pool)
                ppf_c, ppf_o = _ppf_from_archive(pool, objs_est, viol)
            else:
                init = map_pool if method == "map+ga" else None
                if store_active:
                    warm = store.warm_pool(
                        spec, app_name, settings.const_sf,
                        limit=settings.pop_size,
                    )
                    if warm is not None and len(warm):
                        init = (
                            warm
                            if init is None or not len(init)
                            else np.concatenate(
                                [np.asarray(init), warm]
                            )[: settings.pop_size]
                        )
                ga: GAResult
                if ctx.resolved_ga_backend == "jax":
                    from .fastchar import surrogate_objs_device  # lazy JAX import

                    objs_fn = (
                        eval_viol_fn.objs_fn
                        if eval_viol_fn is not None
                        else surrogate_objs_device(
                            estimators, settings.behav_key, settings.ppa_key
                        )
                    )
                    ga = nsga2(
                        None,
                        n_bits=spec.n_luts,
                        pop_size=settings.pop_size,
                        n_gen=settings.n_gen,
                        seed=settings.seed,
                        initial_population=init,
                        hv_ref=ref,
                        backend=ctx,
                        objs_device_fn=objs_fn,
                        max_behav=max_behav,
                        max_ppa=max_ppa,
                    )
                else:
                    ga = nsga2(
                        eval_fn,
                        n_bits=spec.n_luts,
                        pop_size=settings.pop_size,
                        n_gen=settings.n_gen,
                        seed=settings.seed,
                        initial_population=init,
                        violation_fn=viol_fn,
                        hv_ref=ref,
                        eval_viol_fn=eval_viol_fn,
                    )
                n_evals = len(ga.archive_configs)
                hv_history = ga.hv_history
                ppf_c, ppf_o = _ppf_from_archive(
                    ga.archive_configs, ga.archive_objs, ga.archive_viol
                )
            hv_ppf = hypervolume_2d(ppf_o, ref) if len(ppf_o) else 0.0
        timings["ga"] = time.perf_counter() - ts

        ts = time.perf_counter()
        with tel.span("dse.validate"):
            vpf_c, vpf_o, hv_vpf = _validate(
                spec, ppf_c, settings, ref, characterize_fn, max_behav, max_ppa
            )
        timings["validate"] = time.perf_counter() - ts
    result = DSEResult(
        method=method,
        settings=settings,
        ppf_configs=ppf_c,
        ppf_objs_est=ppf_o,
        vpf_configs=vpf_c,
        vpf_objs=vpf_o,
        hv_ppf=hv_ppf,
        hv_vpf=hv_vpf,
        n_evals=n_evals,
        wall_s=time.perf_counter() - t0,
        hv_history=hv_history,
        ref_point=ref,
        timings=timings,
    )
    if store_active:
        _store_front(store, spec, app_name, settings, method, result, req_key)
    return result


def run_dse_sweep(
    spec: OperatorSpec,
    train_ds: Dataset,
    method: str = "ga",
    settings: DSESettings | None = None,
    seeds=(0,),
    const_sf_grid=None,
    estimators: dict[str, AutoMLRegressor] | None = None,
    characterize_fn: Callable[[np.ndarray], np.ndarray] | None = None,
    app=None,
    store=None,
) -> list[DSEResult]:
    """A (seeds x const_sf) restart/constraint grid as ONE batched GA dispatch.

    The host-loop equivalent -- calling ``run_dse`` once per (seed, const_sf)
    -- re-runs the whole generation loop per lane; here every lane shares one
    ``fastmoo.CompiledNSGA2`` program and the full grid executes as a single
    vmapped device dispatch (estimators fitted once, MaP pools solved once per
    const_sf for ``method="map+ga"``, each pool's tabu battery advancing in
    one cross-problem lockstep batch under a jax context).  Requires a
    resolved ``ga_backend="jax"``.  When ``settings.context`` shards the
    ``"lanes"`` axis, the lane batch is split over the context's device mesh
    (bit-identical per-lane results; host-concat combine).  Lane order:
    ``for const_sf in const_sf_grid: for seed in seeds``.

    ``store`` (a :class:`repro.service.OperatorStore`) activates the persistent
    operator library for the whole sweep: lanes whose exact request was served
    before are answered from the cache and dropped from the device dispatch,
    the remaining lanes warm-start from the library's nearest fronts, and
    validation dedups already-characterized configs.  Same caveats as
    :func:`run_dse`: caller-supplied ``characterize_fn`` disables it, and an
    empty library is bit-identical to ``store=None``.
    """
    from .fastchar import surrogate_objs_device  # lazy JAX import
    from .fastmoo import CompiledNSGA2

    settings = settings or DSESettings()
    ctx = settings.context
    tel = ctx.tel
    if ctx.resolved_ga_backend != "jax":
        raise ValueError("run_dse_sweep requires ga_backend='jax'")
    if method not in ("ga", "map+ga"):
        raise ValueError(f"unsupported sweep method {method!r}")
    t0 = time.perf_counter()
    app_name = _app_name(app)
    store_active = store is not None and characterize_fn is None
    fingerprint = None
    if store_active:
        from ..service.store import train_fingerprint

        fingerprint = train_fingerprint(train_ds)
    const_sf_grid = (
        (settings.const_sf,) if const_sf_grid is None else tuple(const_sf_grid)
    )
    shared: dict[str, float] = {}
    with tel.span("dse.sweep", method=method, n_sf=len(const_sf_grid),
                  n_seeds=len(seeds)):
        ts = time.perf_counter()
        with tel.span("dse.characterize"):
            if app is not None and characterize_fn is None:
                characterize_fn = app.characterize_fn(
                    spec, ppa_key=settings.ppa_key, backend=ctx
                )
            if estimators is None:
                estimators = fit_estimators(
                    train_ds.configs.astype(np.float64),
                    {
                        settings.behav_key: train_ds.metrics[settings.behav_key],
                        settings.ppa_key: train_ds.metrics[settings.ppa_key],
                    },
                    n_quad=settings.n_estimator_quad,
                    seed=settings.seed,
                )
            characterize_fn = characterize_fn or _default_characterize(
                spec, settings
            )
            if store_active:
                characterize_fn = store.cached_characterize(
                    spec, characterize_fn, app_name
                )
            ref = hv_reference(train_ds, settings)
        shared["characterize"] = time.perf_counter() - ts

        lane_settings: list[DSESettings] = []
        bounds: list[tuple[float, float]] = []
        pools: list = []
        lane_seeds: list[int] = []
        cached: list[dict | None] = []   # per-lane request-cache hit
        req_keys: list[str | None] = []
        ts = time.perf_counter()
        with tel.span("dse.map") if method == "map+ga" else tel.span("dse.lanes"):
            for sf in const_sf_grid:
                st_sf = dataclasses.replace(settings, const_sf=sf)
                mb, mp = _constraint_bounds(train_ds, st_sf)
                pool = (
                    map_solution_pool(spec, train_ds, st_sf)
                    if method == "map+ga"
                    else None
                )
                warm = (
                    store.warm_pool(spec, app_name, sf, limit=settings.pop_size)
                    if store_active
                    else None
                )
                for seed in seeds:
                    lane_settings.append(
                        dataclasses.replace(st_sf, seed=int(seed))
                    )
                    bounds.append((mb, mp))
                    # per-lane seed pools: MaP pool first, then the library's
                    # warm pool (fastmoo concatenates; cold lanes see exactly
                    # the old single-pool path)
                    if warm is not None and len(warm):
                        pools.append(
                            (pool, warm) if pool is not None else warm
                        )
                    else:
                        pools.append(pool)
                    lane_seeds.append(int(seed))
                    if store_active:
                        from ..service.store import request_key

                        rk = request_key(
                            spec, app_name, sf, int(seed), method,
                            settings, fingerprint,
                        )
                        req_keys.append(rk)
                        cached.append(store.lookup_result(rk))
                    else:
                        req_keys.append(None)
                        cached.append(None)
        if method == "map+ga":
            shared["map"] = time.perf_counter() - ts

        # Lanes answered by the request cache drop out of the device dispatch.
        live = [i for i in range(len(lane_seeds)) if cached[i] is None]
        use_pools = method == "map+ga" or any(
            isinstance(p, tuple) or (p is not None and len(p))
            for p in pools
        )
        ts = time.perf_counter()
        gas: list = [None] * len(lane_seeds)
        with tel.span("dse.ga", n_lanes=len(live)):
            if live:
                runner = CompiledNSGA2(
                    surrogate_objs_device(
                        estimators, settings.behav_key, settings.ppa_key
                    ),
                    n_bits=spec.n_luts,
                    pop_size=settings.pop_size,
                    n_gen=settings.n_gen,
                    hv_ref=ref,
                    ctx=ctx,
                )
                live_gas = runner.run_sweep(
                    [lane_seeds[i] for i in live],
                    [bounds[i] for i in live],
                    [pools[i] for i in live] if use_pools else None,
                )
                for i, ga in zip(live, live_gas):
                    gas[i] = ga
        shared["ga"] = time.perf_counter() - ts

        results: list[DSEResult] = []
        with tel.span("dse.validate", n_lanes=len(live)):
            for i, (st, (mb, mp), ga) in enumerate(
                zip(lane_settings, bounds, gas)
            ):
                if ga is None:   # request-cache hit: rehydrate, no search
                    results.append(
                        _result_from_record(cached[i], method, st, ref, spec, t0)
                    )
                    continue
                tv = time.perf_counter()
                ppf_c, ppf_o = _ppf_from_archive(
                    ga.archive_configs, ga.archive_objs, ga.archive_viol
                )
                hv_ppf = hypervolume_2d(ppf_o, ref) if len(ppf_o) else 0.0
                vpf_c, vpf_o, hv_vpf = _validate(
                    spec, ppf_c, st, ref, characterize_fn, mb, mp
                )
                # shared stages ran once for the whole sweep; validate is
                # genuinely per-lane
                timings = dict(shared)
                timings["validate"] = time.perf_counter() - tv
                res = DSEResult(
                    method=method,
                    settings=st,
                    ppf_configs=ppf_c,
                    ppf_objs_est=ppf_o,
                    vpf_configs=vpf_c,
                    vpf_objs=vpf_o,
                    hv_ppf=hv_ppf,
                    hv_vpf=hv_vpf,
                    n_evals=len(ga.archive_configs),
                    wall_s=time.perf_counter() - t0,
                    hv_history=ga.hv_history,
                    ref_point=ref,
                    timings=timings,
                )
                if store_active:
                    _store_front(
                        store, spec, app_name, st, method, res, req_keys[i]
                    )
                results.append(res)
    return results


def fixed_library(spec: OperatorSpec, n_random_fixed: int = 64) -> np.ndarray:
    """EvoApprox-style frozen design library (no search, ASIC-derived heuristics).

    Classic truncation schemes + whole-row removals + a small frozen random set:
    the library is independent of the DSE problem, so under tight constraints many
    (or all) members are infeasible -- exactly the failure mode the paper reports
    for EvoApprox designs on FPGAs (Figs. 14, 17-19).
    """
    L = spec.n_luts
    cpr = spec.cols_removable
    rows: list[np.ndarray] = [np.ones(L, dtype=np.uint8)]

    # Uniform per-row LSB truncation (classic truncated multiplier ladder).
    for j in range(1, cpr + 1):
        c = np.ones(L, dtype=np.uint8)
        for r in range(spec.rows):
            c[r * cpr : r * cpr + j] = 0
        rows.append(c)
    # Diagonal truncation: row r loses j - 2r columns (column-weight aligned).
    for j in range(1, cpr + 1):
        c = np.ones(L, dtype=np.uint8)
        for r in range(spec.rows):
            k = max(0, j - 2 * r)
            c[r * cpr : r * cpr + k] = 0
        rows.append(c)
    # Whole-row removals.
    for r in range(spec.rows):
        c = np.ones(L, dtype=np.uint8)
        c[r * cpr : (r + 1) * cpr] = 0
        rows.append(c)
    # Frozen random members (seeded: the library never changes between problems).
    rng = np.random.default_rng(1234)
    rows.extend(rng.integers(0, 2, size=(n_random_fixed, L)).astype(np.uint8))

    out = np.stack(rows)
    _, idx = np.unique(out, axis=0, return_index=True)
    return out[np.sort(idx)]
