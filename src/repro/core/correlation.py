"""Correlation analysis of characterization data (AxOMaP §4.1.2, Alg. 1, Figs. 1/9).

* Bivariate: Pearson correlation of each LUT-usage bit with a metric.
* Multivariate (paper Alg. 1): for a LUT pair (x, y), fit the 2-variable linear
  regression ``M = c0 + c1*l_x + c2*l_y`` and report ``r = sqrt(R^2)``.
* ``rank_quadratic_terms``: pairs (i < j) ranked by multivariate correlation --
  the order in which quadratic features are added to the polynomial-regression
  models that seed the MIQCP formulations.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "bivariate_correlation",
    "multivariate_correlation",
    "rank_quadratic_terms",
]


def bivariate_correlation(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Pearson r of each column of X (D, L) against y (D,).  Zero-variance -> 0."""
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    xc = X - X.mean(axis=0)
    yc = y - y.mean()
    sx = np.sqrt((xc**2).sum(axis=0))
    sy = np.sqrt((yc**2).sum())
    denom = sx * sy
    num = xc.T @ yc
    with np.errstate(invalid="ignore", divide="ignore"):
        r = np.where(denom > 0, num / np.maximum(denom, 1e-30), 0.0)
    return r


def multivariate_correlation(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """(L, L) matrix: entry (i, j) = sqrt(R^2) of regressing y on [1, x_i, x_j].

    Diagonal holds |bivariate r|.  Closed form via the 2x2 covariance system, fully
    vectorized over all pairs (paper Alg. 1 computes this per selected pair).
    """
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    d, L = X.shape
    xc = X - X.mean(axis=0)
    yc = y - y.mean()
    var_y = (yc**2).mean()
    if var_y <= 0:
        return np.zeros((L, L))

    S = (xc.T @ xc) / d          # (L, L) feature covariance
    c = (xc.T @ yc) / d          # (L,)   feature-target covariance

    sii = np.diag(S)[:, None]    # (L, 1)
    sjj = np.diag(S)[None, :]
    sij = S
    det = sii * sjj - sij**2

    ci = c[:, None]
    cj = c[None, :]
    # beta = S_pair^{-1} c_pair; explained variance = c' beta
    with np.errstate(invalid="ignore", divide="ignore"):
        explained = (sjj * ci**2 - 2 * sij * ci * cj + sii * cj**2) / det
    r2 = explained / var_y

    # Degenerate pairs (collinear / zero-variance): fall back to best single-feature.
    biv = bivariate_correlation(X, y)
    r2_single = np.maximum(biv[:, None] ** 2, biv[None, :] ** 2)
    bad = ~np.isfinite(r2) | (det <= 1e-12)
    r2 = np.where(bad, r2_single, r2)
    r2 = np.clip(r2, 0.0, 1.0)

    out = np.sqrt(r2)
    np.fill_diagonal(out, np.abs(biv))
    return out


def rank_quadratic_terms(
    X: np.ndarray, y: np.ndarray, descending: bool = True
) -> list[tuple[int, int]]:
    """All pairs (i < j) ordered by multivariate correlation with y."""
    m = multivariate_correlation(X, y)
    L = m.shape[0]
    iu, ju = np.triu_indices(L, k=1)
    order = np.argsort(m[iu, ju])
    if descending:
        order = order[::-1]
    return [(int(iu[k]), int(ju[k])) for k in order]
