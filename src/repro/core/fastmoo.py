"""Device-resident NSGA-II engine (the GA's ``backend="jax"`` path).

After PRs 1-2 only the surrogate *fitness* of each NSGA-II generation was
compiled (``fastchar.compile_surrogate_batch``); non-dominated sorting,
tournament selection, crossover, mutation and environmental selection still
round-tripped to host numpy every generation, making the GA loop the serial
bottleneck of ``run_dse``.  This module runs the entire search as **one
compiled computation**:

  * ``jax.random``-keyed initialization (seed rows from a MaP pool supported
    via a traced ``init_count`` prefix mask),
  * constraint-dominated ranks via a batched dominance matrix peeled front by
    front inside a ``lax.while_loop`` (or, with ``rank_impl="pallas"``, via
    the tiled dominance-count kernel in ``kernels.moo_kernels`` that never
    materializes the (P, P, n_obj) comparison tensor),
  * crowding distance over all fronts at once (rank-segmented sort + segment
    min/max spans),
  * binary tournament selection, single-point crossover, bit-flip mutation,
  * combined-population environmental selection as a single rank-then-crowding
    ``lexsort`` truncation,
  * an on-device feasible-archive tracker: every evaluated individual lands in
    a preallocated device archive and the exact 2-D hypervolume of its
    feasible subset is computed on device at the same checkpoints the numpy
    oracle records -- ``hv_history`` needs no host sync inside the loop,

all inside one jitted ``lax.fori_loop`` fused with the surrogate evaluator.
A ``vmap`` axis over (seed, constraint-bound) turns a whole multi-restart,
multi-constraint DSE sweep into a single batched GA dispatch
(``CompiledNSGA2.run_sweep`` / ``dse.run_dse_sweep``); under an
:class:`repro.core.engine.ExecutionContext` that shards the ``"lanes"`` axis,
that vmapped program is additionally ``shard_map``-ped over the context's
device mesh (lanes are independent, so per-lane results stay bit-identical
and the combine is the host concat the caller already does).  The context
also supplies the PRNG policy (typed keys under a named ``prng_impl``) and
the default rank-kernel impl.

The numpy ``moo.nsga2`` stays the behavioral oracle: identical operators and
selection semantics, but ``jax.random`` streams differ from numpy's, so the
contract is *hypervolume parity* (tests assert the feasible-archive
hypervolume within 2%), not bit parity.

Everything is opt-in: importing this module pulls in JAX; ``moo.nsga2`` only
imports it lazily when a caller passes ``backend="jax"``.
"""

from __future__ import annotations

import functools
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from .engine import MESH_AXIS, ExecutionContext
from .moo import GAResult
from ..obs import device as obs_device
from ..obs import telemetry as obs

__all__ = [
    "UNBOUNDED",
    "dominance_matrix",
    "constraint_ranks",
    "crowding_distance_jax",
    "hypervolume_2d_jax",
    "front_update",
    "front_hypervolume",
    "CompiledNSGA2",
    "nsga2_jax",
]

# Effectively-unconstrained bound: max(0, y - 1e30) == 0 for any real metric,
# and 1e30 stays finite in f32 so the normalized violation is an exact 0.
UNBOUNDED = 1e30


# ---------------------------------------------------------------------------
# Device building blocks (each the jnp twin of a moo.py function)
# ---------------------------------------------------------------------------


def dominance_matrix(objs: jnp.ndarray, viol: jnp.ndarray) -> jnp.ndarray:
    """(n, n) bool, [i, j] = i constraint-dominates j (moo's exact rule)."""
    le = (objs[:, None, :] <= objs[None, :, :]).all(-1)
    lt = (objs[:, None, :] < objs[None, :, :]).any(-1)
    fi = viol <= 0
    dom = (fi[:, None] & fi[None, :]) & (le & lt)
    dom |= fi[:, None] & ~fi[None, :]
    dom |= (~fi[:, None] & ~fi[None, :]) & (viol[:, None] < viol[None, :])
    return dom


def constraint_ranks(
    objs: jnp.ndarray,
    viol: jnp.ndarray,
    impl: str = "xla",
    interpret: bool | None = None,
    tile_map: dict | None = None,
) -> jnp.ndarray:
    """(n,) int32 fronts (0 = best), constraint domination; jnp twin of
    ``moo.fast_nondominated_sort``.

    Only *feasible* fronts are peeled sequentially (``count_fn(active)`` ->
    per-point count of active dominators, a ``lax.while_loop`` round per
    front -- identical fronts to the oracle, which subtracts assigned
    dominators incrementally).  Infeasible points are totally ordered by
    violation and dominated by every feasible point, so their ranks are the
    closed form ``n_feasible_fronts + dense_rank(violation)`` -- without this
    split a tightly-constrained population degenerates into one
    front-per-distinct-violation and hundreds of sequential peel rounds.

    ``impl="xla"`` builds the (n, n) bool dominance matrix once and counts by
    masked column sums; ``impl="pallas"`` recounts dominators each round with
    the tiled kernel and never materializes the matrix.  ``tile_map`` maps a
    population size ``n`` to the kernel's ``{"tile", "j_tile"}`` block shapes
    (``CompiledNSGA2`` pre-resolves tuned tiles there *before* tracing its
    generation loop -- a ``tuning="search"`` resolution launches kernels and
    must not happen inside a trace); unmapped sizes fall back to the registry
    defaults for the population bucket.
    """
    n = objs.shape[0]
    feas = viol <= 0
    if impl == "xla":
        dom = dominance_matrix(objs, viol)
        count_fn = lambda active: (dom & active[:, None]).sum(0)
    elif impl == "pallas":
        from ..kernels import registry as _registry
        from ..kernels.moo_kernels import dominance_counts_pallas
        from ..kernels.ops import on_tpu

        interpret = (not on_tpu()) if interpret is None else interpret
        tiles = (tile_map or {}).get(n)
        if tiles is None:
            kspec = _registry.get("fastmoo.pallas")
            tiles = kspec.default_tiles(kspec.bucket(p=n, n_obj=objs.shape[1]))
        # tiles are powers of two, so padding n to a multiple of the larger
        # one makes the padded P divisible by both
        tile, j_tile = tiles["tile"], tiles["j_tile"]
        pad = (-n) % max(tile, j_tile)
        if pad:  # +inf-violation pad rows: infeasible, inactive, never counted
            objs_p = jnp.concatenate([objs, jnp.zeros((pad, objs.shape[1]), objs.dtype)])
            viol_p = jnp.concatenate([viol, jnp.full((pad,), jnp.inf, viol.dtype)])
        else:
            objs_p, viol_p = objs, viol

        def count_fn(active):
            act = jnp.concatenate([active, jnp.zeros(pad, bool)]) if pad else active
            return dominance_counts_pallas(
                objs_p, viol_p, act, tile=tile, j_tile=j_tile,
                interpret=interpret,
            )[:n]
    else:
        raise ValueError(f"unknown fastmoo rank impl {impl!r}")

    def cond(state):
        _, assigned, r = state
        return (~assigned).any() & (r <= n)

    def body(state):
        rank, assigned, r = state
        counts = count_fn(~assigned)
        front = (counts == 0) & ~assigned
        rank = jnp.where(front, r, rank)
        return rank, assigned | front, r + 1

    rank0 = jnp.zeros(n, jnp.int32)
    # infeasible points start pre-assigned: they never block a feasible one
    rank, _, n_feas_fronts = jax.lax.while_loop(cond, body, (rank0, ~feas, 0))

    vio = jnp.where(feas, -jnp.inf, viol.astype(jnp.float32))
    order = jnp.argsort(vio)
    vs = vio[order]
    prev = jnp.concatenate([jnp.full((1,), -jnp.inf, vs.dtype), vs[:-1]])
    dense = jnp.cumsum((vs > prev).astype(jnp.int32))  # 1-based distinct-value id
    rank = rank.at[order].set(
        jnp.where(feas[order], rank[order], n_feas_fronts + dense - 1)
    )
    return rank


def crowding_distance_jax(objs: jnp.ndarray, rank: jnp.ndarray) -> jnp.ndarray:
    """Per-front crowding distance for all fronts in one pass.

    Equivalent to calling ``moo.crowding_distance`` on each front: a stable
    (rank, objective) lexsort makes front members contiguous, so segment
    boundaries are the per-front extremes (inf) and interior members take
    span-normalized neighbor gaps.  Fronts of <= 2 members are all-boundary,
    reproducing the oracle's all-inf case.
    """
    n, m = objs.shape
    dist = jnp.zeros(n, jnp.float32)
    for k in range(m):
        o = objs[:, k]
        span = (
            jax.ops.segment_max(o, rank, num_segments=n)
            - jax.ops.segment_min(o, rank, num_segments=n)
        )
        order = jnp.lexsort((o, rank))
        ro = rank[order]
        oo = o[order]
        brk = ro[1:] != ro[:-1]
        first = jnp.concatenate([jnp.ones(1, bool), brk])
        last = jnp.concatenate([brk, jnp.ones(1, bool)])
        prev = jnp.concatenate([oo[:1], oo[:-1]])
        nxt = jnp.concatenate([oo[1:], oo[-1:]])
        sp = span[ro]
        gap = jnp.where(sp > 0, (nxt - prev) / jnp.where(sp > 0, sp, 1.0), 0.0)
        dist = dist.at[order].add(jnp.where(first | last, jnp.inf, gap))
    return dist


def hypervolume_2d_jax(
    objs: jnp.ndarray, valid: jnp.ndarray, ref: jnp.ndarray
) -> jnp.ndarray:
    """Exact 2-D hypervolume of the valid subset w.r.t. ``ref`` (minimized).

    jnp twin of ``moo.hypervolume_2d``: invalid / beyond-reference points sort
    to +inf and contribute nothing; a (x, then y) lexsort plus an exclusive
    running y-minimum reproduces the oracle's Pareto staircase sweep without
    an explicit Pareto filter (weakly dominated points fail ``y < prev``).
    """
    valid = valid & (objs[:, 0] <= ref[0]) & (objs[:, 1] <= ref[1])
    x = jnp.where(valid, objs[:, 0], jnp.inf)
    y = jnp.where(valid, objs[:, 1], jnp.inf)
    # single-key sort: for tied x the staircase contributions telescope to the
    # same total whatever the y order, so no secondary sort key is needed
    order = jnp.argsort(x)
    xs, ys = x[order], y[order]
    run = jnp.minimum(jax.lax.cummin(ys), ref[1])
    prev = jnp.concatenate([ref[1][None], run[:-1]])
    contrib = (ref[0] - xs) * (prev - ys)
    return jnp.where(jnp.isfinite(xs) & (ys < prev), contrib, 0.0).sum()


# ---------------------------------------------------------------------------
# Incremental nondominated-front buffer (the per-generation hv tap's state)
# ---------------------------------------------------------------------------
#
# The tapped GA needs the feasible-archive hypervolume EVERY generation, but
# re-sorting the whole (P*(G+1),) archive per generation is O(M log M) work
# on an array that is ~99% +inf padding early in the run (the +43.7% tapped
# overhead of PR 7).  Only the strict Pareto staircase contributes to the
# 2-D hv, so a fixed-capacity buffer holding exactly that staircase -- sorted
# by x, strictly decreasing in y -- is sufficient state: merging P children
# into it each generation is O((F+P) log (F+P)) with F << M.

def front_update(
    buf_x: jnp.ndarray,
    buf_y: jnp.ndarray,
    objs: jnp.ndarray,
    viol: jnp.ndarray,
    ref: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge candidate points into the sorted nondominated-front buffer.

    ``buf_x``/``buf_y`` are ``(F,)`` f32 holding the current staircase
    (x ascending, y strictly descending), +inf-padded.  Candidates are
    filtered to feasible (``viol <= 0``) within-reference points, merged,
    and the strict staircase re-extracted: after an (x, then y) lexsort an
    exclusive running y-minimum keeps exactly the points that contribute to
    the hypervolume (x-ties keep the smallest y; weakly dominated points
    fail ``y < prev`` -- the same rule :func:`hypervolume_2d_jax` uses to
    zero their contribution).  Kept points compact to the buffer head via a
    stable sort on x (dropped points become +inf), so the invariant holds
    for the next merge.  If the true front outgrows F, the largest-x tail
    is truncated (the tap reports the front size so saturation at F is
    observable; capacity defaults to 4P, generous for 2-obj populations).
    """
    feas = (viol <= 0) & (objs[:, 0] <= ref[0]) & (objs[:, 1] <= ref[1])
    xs = jnp.concatenate([buf_x, jnp.where(feas, objs[:, 0], jnp.inf)])
    ys = jnp.concatenate([buf_y, jnp.where(feas, objs[:, 1], jnp.inf)])
    order = jnp.lexsort((ys, xs))
    xs, ys = xs[order], ys[order]
    run = jax.lax.cummin(ys)
    prev = jnp.concatenate([jnp.full((1,), jnp.inf, ys.dtype), run[:-1]])
    keep = jnp.isfinite(xs) & (ys < prev)
    xs = jnp.where(keep, xs, jnp.inf)
    ys = jnp.where(keep, ys, jnp.inf)
    compact = jnp.argsort(xs)  # stable: kept points stay x-sorted, pads sink
    f = buf_x.shape[0]
    return xs[compact][:f], ys[compact][:f]


def front_hypervolume(
    buf_x: jnp.ndarray, buf_y: jnp.ndarray, ref: jnp.ndarray
) -> jnp.ndarray:
    """Exact 2-D hypervolume of a :func:`front_update` buffer w.r.t. ``ref``.

    The buffer already IS the sorted staircase, so this is one O(F) sweep --
    no sort.  Mathematically equal to :func:`hypervolume_2d_jax` over every
    point ever merged (dropped points contribute zero there); only the f32
    summation order differs, so equality is to ~1 ulp, not bitwise.
    """
    run = jnp.minimum(jax.lax.cummin(buf_y), ref[1])
    prev = jnp.concatenate([ref[1][None], run[:-1]])
    contrib = (ref[0] - buf_x) * (prev - buf_y)
    return jnp.where(jnp.isfinite(buf_x) & (buf_y < prev), contrib, 0.0).sum()


# ---------------------------------------------------------------------------
# The compiled GA
# ---------------------------------------------------------------------------

# Tapped-program flush chunk: per-generation tap rows accumulate in a
# (_TAP_CHUNK, n_fields) f32 device buffer and flush with ONE io_callback per
# chunk (the per-generation callback round-trips dominated quick-scale tapped
# runs: ~+42% wall overhead before batching).
_TAP_CHUNK = 32


class CompiledNSGA2:
    """One NSGA-II run (or a vmapped sweep of runs) as a single dispatch.

    ``objs_fn`` is a pure jnp function ``(B, L) f32 -> (B, n_obj=2) f32`` --
    e.g. ``fastchar.surrogate_objs_device`` -- traced *inside* the generation
    loop so fitness evaluation fuses with the GA operators.  Constraint bounds
    ``(max_behav, max_ppa)`` are traced arguments, which is what lets
    ``run_sweep`` vmap one compiled program over a (seed x bound) grid.

    Construct once and reuse: the jitted single-run and sweep closures are
    cached on the instance, so repeated ``run`` calls (a DSE battery, a
    benchmark loop) pay compilation once per population shape.
    """

    def __init__(
        self,
        objs_fn: Callable[[jnp.ndarray], jnp.ndarray],
        n_bits: int,
        pop_size: int = 64,
        n_gen: int = 250,
        crossover_p: float = 0.9,
        mutation_p: float | None = None,
        hv_ref: np.ndarray | None = None,
        record_every: int = 10,
        front_capacity: int | None = None,
        rank_impl: str | None = None,
        interpret: bool | None = None,
        ctx: ExecutionContext | None = None,
    ) -> None:
        if pop_size % 2:
            raise ValueError(f"pop_size must be even, got {pop_size}")
        if rank_impl is None:
            rank_impl = (
                ctx.resolve_impl("fastmoo", "xla") if ctx else "xla"
            )
        if rank_impl not in ("xla", "pallas"):
            raise ValueError(f"unknown rank_impl {rank_impl!r}")
        if interpret is None and ctx is not None:
            interpret = ctx.interpret
        self.n_bits = int(n_bits)
        self.pop_size = int(pop_size)
        self.n_gen = int(n_gen)
        self.crossover_p = float(crossover_p)
        self.mutation_p = float(
            mutation_p if mutation_p is not None else 1.0 / n_bits
        )
        self.record_every = int(record_every)
        # nondominated-front buffer capacity for the tapped per-generation hv
        # (4P is generous for a 2-obj staircase; the tap's "front" field
        # makes saturation observable)
        self.front_capacity = (
            int(front_capacity) if front_capacity is not None else 4 * int(pop_size)
        )
        self.hv_ref = None if hv_ref is None else np.asarray(hv_ref, np.float64)
        # rank-kernel tiles are resolved *now*, before the generation loop is
        # traced: the GA ranks populations of P (gen step) and 2P (env
        # selection), and a tuning="search" resolution launches kernels, which
        # must not happen mid-trace
        tile_map = None
        if rank_impl == "pallas":
            from ..kernels.tuning import tiles_for

            tile_map = {
                n: tiles_for(ctx, "fastmoo.pallas", p=n, n_obj=2)
                for n in (pop_size, 2 * pop_size)
            }
        self._rank_tiles = tile_map
        self._ranks = functools.partial(
            constraint_ranks, impl=rank_impl, interpret=interpret,
            tile_map=tile_map,
        )
        self._objs_fn = objs_fn
        self._ctx = ctx
        self._prng_key = ctx.prng_key if ctx is not None else jax.random.PRNGKey
        self._tel = ctx.tel if ctx is not None else obs.current()
        run = self._build()
        self._run = run
        # on-device per-generation hv tap: only when the context's telemetry
        # explicitly opted into device taps (the tap computes the archive hv
        # EVERY generation instead of at checkpoints, so it must not ride
        # along silently), and only on the single-run program -- under vmap
        # the io_callback fires once per lane and the lanes' generations
        # would interleave into one series
        self._tapped = track = self.hv_ref is not None and self._tel.device_taps
        self._single = jax.jit(self._build(tap=True) if track else run)
        self._sweep = jax.jit(jax.vmap(run))
        self._sweep_sharded = None  # built lazily; needs the context's mesh

    # -- trace-time program ---------------------------------------------------

    def _build(self, tap: bool = False):
        P, L, G = self.pop_size, self.n_bits, self.n_gen
        M = P * (G + 1)
        objs_fn = self._objs_fn
        ranks_fn = self._ranks
        cx_p = self.crossover_p
        mut_p = self.mutation_p
        rec = self.record_every
        track_hv = self.hv_ref is not None
        ref = (
            None if not track_hv else jnp.asarray(self.hv_ref, jnp.float32)
        )
        # per-generation feasible-archive hv + constraint-violation stats,
        # accumulated in a (C, 6) device row-buffer and flushed with one
        # batched io_callback per C-generation chunk (fires once per
        # dispatch, not per trace); None when untapped so the compiled
        # program contains no callback at all
        tap_fn = None
        F = self.front_capacity
        C = min(G, _TAP_CHUNK) if G else 1
        tap_fields = ("gen", "hv", "arc_feasible", "pop_viol_mean",
                      "pop_feas", "front")
        if tap and track_hv:
            tap_fn = self._tel.device_batched_tap("fastmoo.gen", tap_fields)

        def evaluate(pop, max_b, max_p):
            objs = objs_fn(pop.astype(jnp.float32))
            yb, yp = objs[:, 0], objs[:, 1]
            vb = jnp.maximum(0.0, yb - max_b) / jnp.maximum(jnp.abs(max_b), 1e-9)
            vp = jnp.maximum(0.0, yp - max_p) / jnp.maximum(jnp.abs(max_p), 1e-9)
            return objs, vb + vp

        def archive_hv(arc_objs, arc_viol):
            return hypervolume_2d_jax(arc_objs, arc_viol <= 0, ref)

        def gen_step(g, state):
            if tap_fn is not None:
                (key, pop, objs, viol, arc_c, arc_o, arc_v, hv_arr,
                 buf_x, buf_y, tap_buf, max_b, max_p) = state
            else:
                (key, pop, objs, viol, arc_c, arc_o, arc_v, hv_arr,
                 max_b, max_p) = state
            rank = ranks_fn(objs, viol)
            crowd = crowding_distance_jax(objs, rank)

            key, k_cand, k_cx, k_cut, k_mut = jax.random.split(key, 5)

            # binary tournament selection
            cand = jax.random.randint(k_cand, (P, 2), 0, P)
            a, b = cand[:, 0], cand[:, 1]
            better = (rank[a] < rank[b]) | (
                (rank[a] == rank[b]) & (crowd[a] > crowd[b])
            )
            parents = pop[jnp.where(better, a, b)]

            # single-point crossover on consecutive pairs
            do_cx = jax.random.uniform(k_cx, (P // 2,)) < cx_p
            cut = jax.random.randint(k_cut, (P // 2,), 1, L)
            swap = (jnp.arange(L)[None, :] >= cut[:, None]) & do_cx[:, None]
            p1, p2 = parents[0::2], parents[1::2]
            c1 = jnp.where(swap, p2, p1)
            c2 = jnp.where(swap, p1, p2)
            children = jnp.stack([c1, c2], axis=1).reshape(P, L)

            # bit-flip mutation
            flip = jax.random.uniform(k_mut, (P, L)) < mut_p
            children = children ^ flip.astype(jnp.uint8)

            c_objs, c_viol = evaluate(children, max_b, max_p)
            arc_c = jax.lax.dynamic_update_slice(arc_c, children, ((g + 1) * P, 0))
            arc_o = jax.lax.dynamic_update_slice(arc_o, c_objs, ((g + 1) * P, 0))
            arc_v = jax.lax.dynamic_update_slice(arc_v, c_viol, ((g + 1) * P,))

            # environmental selection: whole fronts, boundary front by crowding
            all_pop = jnp.concatenate([pop, children])
            all_objs = jnp.concatenate([objs, c_objs])
            all_viol = jnp.concatenate([viol, c_viol])
            rank2 = ranks_fn(all_objs, all_viol)
            crowd2 = crowding_distance_jax(all_objs, rank2)
            sel = jnp.lexsort((-crowd2, rank2))[:P]
            pop, objs, viol = all_pop[sel], all_objs[sel], all_viol[sel]

            if track_hv:
                record = ((g % rec) == rec - 1) | (g == G - 1)
                if tap_fn is not None:
                    # tapped program: the per-generation hv comes from the
                    # incremental nondominated-front buffer -- O(F) instead
                    # of re-sorting the whole (P*(G+1),) archive each
                    # generation.  Only the children need merging: pop is a
                    # subset of last generation's pop+children, all already
                    # in the buffer.  The stats row lands in the chunk's
                    # device buffer; the outer chunk loop flushes it.
                    buf_x, buf_y = front_update(buf_x, buf_y, c_objs, c_viol,
                                                ref)
                    row = jnp.stack([
                        jnp.asarray(g, jnp.float32),
                        front_hypervolume(buf_x, buf_y, ref),
                        (arc_v <= 0).sum().astype(jnp.float32),
                        viol.mean(),
                        (viol <= 0).mean(),
                        jnp.isfinite(buf_x).sum().astype(jnp.float32),
                    ])
                    tap_buf = tap_buf.at[g % C].set(row)
                # the checkpoint history stays archive-based in BOTH programs
                # (identical archive_hv computation on identical inputs), so
                # hv_history is bit-identical tapped vs untapped; the buffer
                # hv only feeds the tap (equal to ~1 ulp, not bitwise -- the
                # f32 summation order differs)
                hv = jax.lax.cond(
                    record,
                    lambda: archive_hv(arc_o, arc_v),
                    lambda: jnp.float32(0.0),
                )
                hv_arr = hv_arr.at[g].set(hv)

            if tap_fn is not None:
                return (key, pop, objs, viol, arc_c, arc_o, arc_v, hv_arr,
                        buf_x, buf_y, tap_buf, max_b, max_p)
            return key, pop, objs, viol, arc_c, arc_o, arc_v, hv_arr, max_b, max_p

        def run(key, init_pop, init_count, max_b, max_p):
            obs.note_trace("fastmoo.run")  # body executes once per (re)trace
            key, k_init = jax.random.split(key)
            pop = jax.random.randint(k_init, (P, L), 0, 2, dtype=jnp.uint8)
            seeded = jnp.arange(P)[:, None] < init_count
            pop = jnp.where(seeded, init_pop, pop)
            objs, viol = evaluate(pop, max_b, max_p)

            arc_c = jnp.zeros((M, L), jnp.uint8)
            arc_o = jnp.full((M, 2), jnp.inf, jnp.float32)
            arc_v = jnp.full((M,), jnp.inf, jnp.float32)
            arc_c = jax.lax.dynamic_update_slice(arc_c, pop, (0, 0))
            arc_o = jax.lax.dynamic_update_slice(arc_o, objs, (0, 0))
            arc_v = jax.lax.dynamic_update_slice(arc_v, viol, (0,))

            hv0 = archive_hv(arc_o, arc_v) if track_hv else jnp.float32(0.0)
            hv_arr = jnp.zeros((G,), jnp.float32)

            if tap_fn is not None:
                # seed the front buffer with the initial population (the
                # archive holds exactly init pop + every generation's
                # children, which is what the buffer accumulates)
                buf_x = jnp.full((F,), jnp.inf, jnp.float32)
                buf_y = jnp.full((F,), jnp.inf, jnp.float32)
                buf_x, buf_y = front_update(buf_x, buf_y, objs, viol, ref)
                state = (key, pop, objs, viol, arc_c, arc_o, arc_v, hv_arr,
                         buf_x, buf_y, max_b, max_p)

                def chunk_step(c, state):
                    # nested loop: C generations fill a fresh (C, 6) row
                    # buffer, then ONE io_callback flushes it.  gen == -1.0
                    # marks never-written rows in a ragged final chunk; the
                    # flush mask drops them host-side.
                    lo = c * C
                    hi = jnp.minimum(G, lo + C)
                    tap_buf = jnp.full((C, 6), -1.0, jnp.float32)
                    inner = state[:10] + (tap_buf,) + state[10:]
                    inner = jax.lax.fori_loop(lo, hi, gen_step, inner)
                    tap_fn(inner[10], inner[10][:, 0] >= 0.0)
                    return inner[:10] + inner[11:]

                state = jax.lax.fori_loop(0, -(-G // C), chunk_step, state)
                (_, pop, objs, viol, arc_c, arc_o, arc_v, hv_arr,
                 _, _, _, _) = state
            else:
                state = (key, pop, objs, viol, arc_c, arc_o, arc_v, hv_arr,
                         max_b, max_p)
                state = jax.lax.fori_loop(0, G, gen_step, state)
                _, pop, objs, viol, arc_c, arc_o, arc_v, hv_arr, _, _ = state
            return {
                "population": pop,
                "objectives": objs,
                "violations": viol,
                "archive_configs": arc_c,
                "archive_objs": arc_o,
                "archive_viol": arc_v,
                "hv0": hv0,
                "hv": hv_arr,
            }

        return run

    # -- host API -------------------------------------------------------------

    def _prep_init(
        self, initial_population
    ) -> tuple[np.ndarray, int]:
        """Seed rows for the initial population.

        Accepts one (k, n_bits) array or a list/tuple of pools (e.g. a MaP
        solution pool followed by the operator library's warm-start pool):
        pools concatenate in order and truncate to ``pop_size``.  An empty /
        None pool contributes nothing, so a cold start (no seeds at all)
        keeps ``k = 0`` and the run stays bit-identical to the unseeded GA.
        """
        if isinstance(initial_population, (list, tuple)):
            parts = [
                np.asarray(p, np.uint8)
                for p in initial_population
                if p is not None and len(p)
            ]
            initial_population = np.concatenate(parts) if parts else None
        init = np.zeros((self.pop_size, self.n_bits), np.uint8)
        k = 0
        if initial_population is not None and len(initial_population):
            k = min(len(initial_population), self.pop_size)
            init[:k] = np.asarray(initial_population)[:k]
        return init, k

    def _to_result(self, out: dict) -> GAResult:
        hv_hist: list[tuple[int, float]] = []
        if self.hv_ref is not None:
            P = self.pop_size
            hv = np.asarray(out["hv"], np.float64)
            hv_hist.append((P, float(out["hv0"])))
            for g in range(self.n_gen):
                if g % self.record_every == self.record_every - 1 or g == self.n_gen - 1:
                    hv_hist.append(((g + 2) * P, float(hv[g])))
        return GAResult(
            population=np.asarray(out["population"], np.uint8),
            objectives=np.asarray(out["objectives"], np.float64),
            archive_configs=np.asarray(out["archive_configs"], np.uint8),
            archive_objs=np.asarray(out["archive_objs"], np.float64),
            archive_viol=np.asarray(out["archive_viol"], np.float64),
            hv_history=hv_hist,
        )

    def run(
        self,
        seed: int = 0,
        max_behav: float = UNBOUNDED,
        max_ppa: float = UNBOUNDED,
        initial_population: np.ndarray | None = None,
    ) -> GAResult:
        """One full GA run as a single device dispatch."""
        init, k = self._prep_init(initial_population)
        tel = self._tel
        tel.count("dispatch.fastmoo.run")
        with tel.span("fastmoo.run", pop=self.pop_size, n_gen=self.n_gen,
                      seed=seed):
            out = self._single(
                self._prng_key(seed),
                jnp.asarray(init),
                jnp.int32(k),
                jnp.float32(max_behav),
                jnp.float32(max_ppa),
            )
            host = {k_: np.asarray(v) for k_, v in out.items()}
            if self._tapped:
                obs_device.flush()  # tap callbacks are async; drain the series
        return self._to_result(host)

    def _sharded_sweep(self):
        """jit(shard_map(vmap(run))): lanes sharded over the context's mesh.

        Each device runs the identical vmapped GA program on its contiguous
        lane slice -- lanes never interact, so per-lane results are
        bit-identical to the unsharded vmap and the combine is the host concat
        the caller already does.

        Tuned rank-kernel tiles are baked into the traced program at
        construction (``__init__`` resolves them before any trace), so an
        instance's sharded sweep can never go stale -- re-tuned winners
        arrive via a fresh ``CompiledNSGA2``; the (context, shape bucket)
        keyed caches live where tiles *can* change under a long-lived
        context, ``fastchar._sharded_partials`` and fastapp's take-path
        builders.
        """
        if self._sweep_sharded is None:
            from jax.sharding import PartitionSpec as P

            self._tel.count("shard.rebuild.fastmoo")
            self._sweep_sharded = jax.jit(
                self._ctx.shard_call(
                    jax.vmap(self._run),
                    in_specs=P(MESH_AXIS),
                    out_specs=P(MESH_AXIS),
                )
            )
        return self._sweep_sharded

    def run_sweep(
        self,
        seeds,
        bounds,
        initial_populations=None,
    ) -> list[GAResult]:
        """A (seed x constraint-bound) sweep as ONE vmapped GA dispatch.

        ``seeds``: (S,) ints; ``bounds``: (S, 2) [max_behav, max_ppa] rows;
        ``initial_populations``: optional per-lane seed pools (list of arrays,
        entries may be None/empty).  Returns one GAResult per lane.

        When the context shards the ``"lanes"`` axis, the lane batch is padded
        (by repeating lane 0) to a whole number of per-device slices and
        dispatched over the mesh; the padding lanes are dropped on the host.
        """
        seeds = list(seeds)
        n_lanes = len(seeds)
        bounds = np.asarray(bounds, np.float64).reshape(n_lanes, 2)
        inits, counts = [], []
        for i in range(n_lanes):
            pool = None if initial_populations is None else initial_populations[i]
            init, k = self._prep_init(pool)
            inits.append(init)
            counts.append(k)
        keys = jnp.stack([self._prng_key(s) for s in seeds])
        args = (
            keys,
            jnp.asarray(np.stack(inits)),
            jnp.asarray(np.asarray(counts, np.int32)),
            jnp.asarray(bounds[:, 0], jnp.float32),
            jnp.asarray(bounds[:, 1], jnp.float32),
        )
        tel = self._tel
        tel.count("dispatch.fastmoo.sweep")
        with tel.span("fastmoo.sweep", n_lanes=n_lanes, pop=self.pop_size,
                      n_gen=self.n_gen):
            if self._ctx is not None and self._ctx.shards("lanes"):
                pad = (-n_lanes) % self._ctx.device_count
                if pad:
                    args = tuple(
                        jnp.concatenate([a, jnp.repeat(a[:1], pad, axis=0)])
                        for a in args
                    )
                out = self._sharded_sweep()(*args)
            else:
                out = self._sweep(*args)
            host = {k_: np.asarray(v)[:n_lanes] for k_, v in out.items()}
        return [
            self._to_result({k_: v[i] for k_, v in host.items()})
            for i in range(n_lanes)
        ]


def nsga2_jax(
    objs_fn: Callable[[jnp.ndarray], jnp.ndarray],
    n_bits: int,
    pop_size: int = 64,
    n_gen: int = 250,
    seed: int = 0,
    initial_population: np.ndarray | None = None,
    hv_ref: np.ndarray | None = None,
    crossover_p: float = 0.9,
    mutation_p: float | None = None,
    max_behav: float = UNBOUNDED,
    max_ppa: float = UNBOUNDED,
    rank_impl: str | None = None,
    ctx: ExecutionContext | None = None,
) -> GAResult:
    """One-shot convenience wrapper; ``moo.nsga2(backend="jax")`` lands here.

    Builds a :class:`CompiledNSGA2` and runs it once (compilation included);
    batteries and benchmarks should hold a ``CompiledNSGA2`` and reuse it.
    """
    runner = CompiledNSGA2(
        objs_fn,
        n_bits=n_bits,
        pop_size=pop_size,
        n_gen=n_gen,
        crossover_p=crossover_p,
        mutation_p=mutation_p,
        hv_ref=hv_ref,
        rank_impl=rank_impl,
        ctx=ctx,
    )
    return runner.run(
        seed=seed,
        max_behav=max_behav,
        max_ppa=max_ppa,
        initial_population=initial_population,
    )
