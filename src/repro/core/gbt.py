"""Gradient-boosted regression trees (numpy) for binary LUT-usage features.

Stands in for the paper's CatBoost/LightGBM estimators (Table 3): the features are
categorical {0,1} bits, so exact greedy splits on ``x_f == 1`` with depth-limited
trees recover the same model class those libraries reduce to on this data.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["GBTRegressor"]


@dataclass
class _Tree:
    feature: np.ndarray         # (n_nodes,) int; -1 => leaf
    left: np.ndarray            # child when x[f] == 0
    right: np.ndarray           # child when x[f] == 1
    value: np.ndarray           # (n_nodes,) leaf/internal mean

    def predict(self, X: np.ndarray) -> np.ndarray:
        node = np.zeros(X.shape[0], dtype=np.int64)
        for _ in range(32):  # depth bound; loop exits early when all at leaves
            feat = self.feature[node]
            active = feat >= 0
            if not active.any():
                break
            f = np.where(active, feat, 0)
            go_right = X[np.arange(X.shape[0]), f].astype(bool) & active
            go_left = (~X[np.arange(X.shape[0]), f].astype(bool)) & active
            node = np.where(go_right, self.right[node], node)
            node = np.where(go_left, self.left[node], node)
        return self.value[node]


def _fit_tree(
    X: np.ndarray, y: np.ndarray, max_depth: int, min_leaf: int
) -> _Tree:
    feature: list[int] = []
    left: list[int] = []
    right: list[int] = []
    value: list[float] = []

    def new_node(mean: float) -> int:
        feature.append(-1)
        left.append(-1)
        right.append(-1)
        value.append(mean)
        return len(feature) - 1

    def build(idx: np.ndarray, depth: int) -> int:
        yn = y[idx]
        node = new_node(float(yn.mean()))
        if depth >= max_depth or idx.size < 2 * min_leaf:
            return node
        Xn = X[idx]
        n = idx.size
        s_tot = yn.sum()
        q_tot = (yn**2).sum()
        n1 = Xn.sum(axis=0).astype(np.float64)             # (L,)
        s1 = Xn.T.astype(np.float64) @ yn                  # (L,)
        n0 = n - n1
        s0 = s_tot - s1
        with np.errstate(invalid="ignore", divide="ignore"):
            sse_split = (
                q_tot
                - np.where(n0 > 0, s0**2 / np.maximum(n0, 1), 0.0)
                - np.where(n1 > 0, s1**2 / np.maximum(n1, 1), 0.0)
            )
        valid = (n0 >= min_leaf) & (n1 >= min_leaf)
        if not valid.any():
            return node
        sse_split = np.where(valid, sse_split, np.inf)
        f = int(np.argmin(sse_split))
        sse_parent = q_tot - s_tot**2 / n
        if sse_parent - sse_split[f] <= 1e-12:
            return node
        mask = Xn[:, f].astype(bool)
        feature[node] = f
        left[node] = build(idx[~mask], depth + 1)
        right[node] = build(idx[mask], depth + 1)
        return node

    build(np.arange(X.shape[0]), 0)
    return _Tree(
        feature=np.array(feature, dtype=np.int64),
        left=np.array(left, dtype=np.int64),
        right=np.array(right, dtype=np.int64),
        value=np.array(value, dtype=np.float64),
    )


@dataclass
class GBTRegressor:
    n_trees: int = 120
    max_depth: int = 3
    learning_rate: float = 0.1
    subsample: float = 0.8
    min_leaf: int = 8
    seed: int = 0
    base: float = field(default=0.0, init=False)
    trees: list[_Tree] = field(default_factory=list, init=False)

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GBTRegressor":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        rng = np.random.default_rng(self.seed)
        self.base = float(y.mean())
        self.trees = []
        pred = np.full(y.shape, self.base)
        n = X.shape[0]
        for _ in range(self.n_trees):
            resid = y - pred
            if self.subsample < 1.0:
                idx = rng.choice(n, size=max(2 * self.min_leaf, int(n * self.subsample)),
                                 replace=False)
            else:
                idx = np.arange(n)
            tree = _fit_tree(X[idx], resid[idx], self.max_depth, self.min_leaf)
            self.trees.append(tree)
            pred = pred + self.learning_rate * tree.predict(X)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        pred = np.full(X.shape[0], self.base)
        for tree in self.trees:
            pred = pred + self.learning_rate * tree.predict(X)
        return pred
