"""Accelerator-native batched characterization engine (the ``backend="jax"`` path).

The numpy oracle (``metrics.behav_metrics``) characterizes a ``(D, L)`` config
batch by materializing ``(D, 2^N, 2^N)`` float64 error tables and reducing them
on the host.  This module evaluates the same exhaustive BEHAV statistics as one
(or a few) device dispatches:

  1. **Vectorized table gathers** -- the per-row config tables are pulled out of
     the precomputed ``RowTables`` with a single ``jnp.take`` per row
     (``(R, D, 4, B)`` int32, ~4096 ints per config), instead of numpy fancy
     indexing per batch chunk.
  2. **Tiled reduction** -- either the Pallas kernel
     (``repro.kernels.char_kernels.behav_stats_pallas``; TPU path, interpret
     mode on CPU) or a jit-compiled XLA implementation of the *same* tiling
     (``impl="xla"``; the fast path on CPU hosts) reduces error-table tiles to
     per-A-tile partial statistics without ever keeping a float64 table.
  3. **Exact host combine** -- integer partials are summed in int64 and divided
     by the (power-of-two) pair count in float64, which makes AVG_ABS_ERR,
     PROB_ERR, MAX_ABS_ERR and MSE **bit-identical** to the numpy oracle.
     AVG_ABS_REL_ERR accumulates ``|e| * (1/denom)`` in f32 on device and
     combines tiles in f64; it matches the oracle to ~1e-6 relative.

Also here: jit-compiled batched surrogate evaluation
(``compile_surrogate_batch``) so one NSGA-II generation is a single device
dispatch, and batched MaP quadratic-form evaluation
(``map_problem_values_jax``) used by ``miqcp.solve_enumerate`` under
``backend="jax"``, plus its vmapped cross-problem twin
(``tabu_neighbor_values_multi_jax``) that scores a whole MaP battery's tabu
neighborhoods per iteration for ``miqcp.solve_tabu_multi``.

Execution policy comes from :class:`repro.core.engine.ExecutionContext`: a
context that shards the ``"configs"`` axis splits the (D,) batch of
``behav_partials`` over its device mesh via ``shard_map`` (bit-identical --
per-config partials are independent and the int64 host combine is unchanged).

Everything is opt-in: importing this module pulls in JAX; the numpy modules
only import it lazily when a caller passes ``backend="jax"``.
"""

from __future__ import annotations

import functools
import math

import numpy as np

import jax
import jax.numpy as jnp

from .engine import MESH_AXIS, ExecutionContext
from .metrics import BEHAV_METRICS
from ..obs import telemetry as obs
from .operator_model import (
    OperatorSpec,
    _entry_product,
    _entry_row_values,
    _synth_small,
    config_to_masks,
    exact_product_table,
    exact_table,
    row_tables,
    spec_for,
)

__all__ = [
    "max_abs_error_bound",
    "default_a_tile",
    "entry_fn",
    "behav_partials",
    "behav_metrics_jax",
    "behav_metrics_sampled",
    "surrogate_objs_device",
    "compile_surrogate_batch",
    "map_problem_values_jax",
    "tabu_neighbor_values_jax",
    "tabu_neighbor_values_multi_jax",
]

# Exhaustive engine menu: "xla"/"pallas" gather the per-row tables out of the
# precomputed RowTables; "entry"/"entry_pallas" are the table-free twins that
# synthesize them on device from the (D, R) config masks (no HBM table build).
CHAR_IMPLS = ("xla", "pallas", "entry", "entry_pallas")


# ---------------------------------------------------------------------------
# BEHAV characterization
# ---------------------------------------------------------------------------


def max_abs_error_bound(spec: OperatorSpec) -> int:
    """Static bound on ``|approx - exact|`` for any config and input pair."""
    row_mag = 1 << (spec.width - 1)
    if spec.op == "add":
        return row_mag + (1 << spec.n_bits)
    approx = row_mag * ((4**spec.rows - 1) // 3)
    exact = 1 << (2 * spec.n_bits - 2)
    return approx + exact


def default_a_tile(spec: OperatorSpec) -> int:
    """Largest power-of-two A-tile keeping every int32 tile partial < 2^30."""
    b = spec.n_inputs
    bound = max_abs_error_bound(spec)
    tile = spec.n_inputs
    while tile > 1 and tile * b * bound >= (1 << 30):
        tile //= 2
    return tile


@functools.lru_cache(maxsize=None)
def _device_tables(n_bits: int):
    """Characterization constants as host numpy arrays (safe to cache: jit
    traces embed them as constants; caching jnp arrays here would leak tracers
    when the first call happens inside another trace)."""
    spec = spec_for(n_bits)
    tabs = row_tables(n_bits)
    n_in = spec.n_inputs
    # (2[top], 4[pair], B, M): pair index = 2*a0 + a1, matching product_tables.
    row_tab = np.ascontiguousarray(
        tabs.value.reshape(2, 4, n_in, spec.n_row_masks), dtype=np.int32
    )
    exact = exact_product_table(n_bits).astype(np.int32)
    denom = np.maximum(np.abs(exact_product_table(n_bits)).astype(np.float64), 1.0)
    w = (1.0 / denom).astype(np.float32)
    a_codes = np.arange(n_in, dtype=np.int32)
    pair_idx = np.stack(
        [
            2 * ((a_codes >> (2 * r)) & 1) + ((a_codes >> (2 * r + 1)) & 1)
            for r in range(spec.rows)
        ]
    ).astype(np.int32)
    return row_tab, exact, w, pair_idx


@functools.partial(jax.jit, static_argnames=("n_bits",))
def _gather_small(masks: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """(D, R) per-row masks -> (R, D, 4, B) int32 row tables, one take per row."""
    spec = spec_for(n_bits)
    row_tab, _, _, _ = _device_tables(n_bits)
    smalls = []
    for r in range(spec.rows):
        top = 1 if r == spec.rows - 1 else 0
        sel = jnp.take(row_tab[top], masks[:, r], axis=2)  # (4, B, D)
        smalls.append(sel.transpose(2, 0, 1))              # (D, 4, B)
    return jnp.stack(smalls)                               # (R, D, 4, B)


@functools.partial(jax.jit, static_argnames=("n_bits",))
def _synth_small_jax(masks: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Table-free twin of ``_gather_small``: carry-chain synthesis from masks.

    (D, R) masks -> (R, D, 4, B) int32, bit-identical to the RowTables gather
    but with no host table build and no (2, 4, B, 2^(N+1)) constant staged to
    the device -- R*4*B*W lane-ops per config instead.
    """
    spec = spec_for(n_bits)
    smalls = _synth_small(spec, masks, jnp, jnp.int32)     # R x (D, 4, B)
    return jnp.stack(smalls)                               # (R, D, 4, B)


@functools.partial(jax.jit, static_argnames=("n_bits", "a_tile", "d_block", "source"))
def _partials_xla(masks: jnp.ndarray, n_bits: int, a_tile: int, d_block: int,
                  source: str = "table"):
    """XLA twin of the Pallas kernel: same tiling, same output channels.

    A ``lax.map`` over ``d_block``-sized config chunks keeps the reconstructed
    error tables cache-resident (a (Db, 2^N, 2^N) int32 chunk is ~2 MB at N=8
    vs 67 MB for the whole batch) while the whole batch remains one device
    dispatch -- this is worth ~4x over the naive vectorized form on CPU hosts.

    ``source`` picks where the per-row small tables come from: ``"table"``
    gathers them from the precomputed RowTables, ``"entry"`` synthesizes them
    from the masks inside the same program (the table-free engine).  The
    reduction is identical, so both are bit-exact vs the numpy oracle.
    """
    obs.note_trace("fastchar.partials_xla")  # body executes once per (re)trace
    spec = spec_for(n_bits)
    _, exact, w, pair_idx = _device_tables(n_bits)
    if source == "entry":
        small = _synth_small_jax(masks, n_bits)            # (R, D, 4, B)
    else:
        small = _gather_small(masks, n_bits)               # (R, D, 4, B)
    d = small.shape[1]
    n_in = spec.n_inputs
    n_ta = n_in // a_tile
    sm = small.transpose(1, 0, 2, 3).reshape(
        d // d_block, d_block, spec.rows, 4, n_in
    )

    def chunk_stats(sm_c):  # (Db, R, 4, B) -> per-tile partials (n_ta, Db, 8)
        approx = None
        for r in range(spec.rows):
            term = jnp.take(sm_c[:, r], pair_idx[r], axis=1) << (2 * r)
            approx = term if approx is None else approx + term
        err = approx - exact[None]                         # (Db, A, B)
        abs_e = jnp.abs(err)
        hi = abs_e >> 8
        lo = abs_e & 255

        def ts(x):  # per-A-tile int32 partial sums, (n_ta, Db)
            return x.reshape(d_block, n_ta, a_tile, -1).sum(axis=(2, 3)).T

        mx = abs_e.reshape(d_block, n_ta, a_tile, -1).max(axis=(2, 3)).T
        zero = jnp.zeros((n_ta, d_block), jnp.int32)
        int_p = jnp.stack(
            [ts(abs_e), ts((err != 0).astype(jnp.int32)), mx,
             ts(hi * hi), ts(hi * lo), ts(lo * lo), zero, zero],
            axis=-1,
        )
        rel = (abs_e.astype(jnp.float32) * w[None]).reshape(
            d_block, n_ta, a_tile, -1
        ).sum(axis=(2, 3)).T
        zf = jnp.zeros_like(rel)
        rel_p = jnp.stack([rel, zf, zf, zf, zf, zf, zf, zf], axis=-1)
        return int_p, rel_p

    int_p, rel_p = jax.lax.map(chunk_stats, sm)            # (n_chunks, n_ta, Db, 8)

    def merge(x):  # chunk-major D blocks -> contiguous (n_ta, D, 8)
        return x.transpose(1, 0, 2, 3).reshape(n_ta, d, x.shape[-1])

    return merge(int_p), merge(rel_p)


def _partials_dispatch(n_bits: int, impl: str, a_tile: int, d_block: int,
                       interpret: bool | None):
    """The per-device (or whole-batch) partials computation as a closure."""

    def dispatch(m):
        if impl == "xla":
            return _partials_xla(m, n_bits, a_tile, d_block)
        if impl == "entry":
            return _partials_xla(m, n_bits, a_tile, d_block, source="entry")
        from ..kernels.ops import on_tpu

        interp = (not on_tpu()) if interpret is None else interpret
        if impl == "entry_pallas":
            from ..kernels.char_kernels import behav_stats_entry_pallas

            return behav_stats_entry_pallas(
                m, n_bits, d_block=d_block, a_tile=a_tile, interpret=interp
            )
        from ..kernels.char_kernels import behav_stats_pallas

        _, exact, w, _ = _device_tables(n_bits)
        small = _gather_small(m, n_bits)
        return behav_stats_pallas(
            small, exact, w, d_block=d_block, a_tile=a_tile, interpret=interp
        )

    return dispatch


# jit(shard_map(partials)) cached per (context, shape bucket) -- a fresh
# shard_map per call would retrace (and recompile) every dispatch.  The tile
# shapes live in the *value*, not the key: when the autotuner hands a bucket
# new winners, the bucket's entry is rebuilt in place instead of a stale
# entry pinning the old compiled executable forever.
_SHARDED_PARTIALS: dict = {}


def _sharded_partials(ctx: ExecutionContext, n_bits: int, impl: str,
                      a_tile: int, d_block: int, interpret: bool | None,
                      bucket):
    from jax.sharding import PartitionSpec as P

    key = (ctx, n_bits, impl, interpret, bucket)
    hit = _SHARDED_PARTIALS.get(key)
    if hit is not None and hit[0] == (a_tile, d_block):
        return hit[1]
    obs.of(ctx).count("shard.rebuild.fastchar")
    fn = jax.jit(
        ctx.shard_call(
            _partials_dispatch(n_bits, impl, a_tile, d_block, interpret),
            in_specs=(P(MESH_AXIS),),
            out_specs=(P(None, MESH_AXIS), P(None, MESH_AXIS)),
        )
    )
    _SHARDED_PARTIALS[key] = ((a_tile, d_block), fn)
    return fn


def behav_partials(
    spec: OperatorSpec,
    masks: jnp.ndarray,
    impl: str = "xla",
    a_tile: int | None = None,
    d_block: int | None = None,
    interpret: bool | None = None,
    ctx: ExecutionContext | None = None,
):
    """Dispatch one device evaluation of a (padded) mask batch -> partials.

    ``None`` tiles resolve through the kernel registry under the context's
    ``tuning`` policy (registry defaults when untuned -- ``a_tile`` stays the
    int32-safe bound, ``d_block=8``).  When ``ctx`` shards the ``"configs"``
    axis and the batch divides evenly into ``n_devices x d_block`` blocks,
    the D axis is ``shard_map``-ped over the context's mesh: each device runs
    the identical per-chunk reduction on its contiguous config slice, so the
    (n_ta, D, 8) partials are bit-identical to the unsharded dispatch (the
    int64 host combine is unchanged).
    """
    if impl not in CHAR_IMPLS:
        raise ValueError(f"unknown fastchar impl {impl!r}")
    obs.of(ctx).count(f"dispatch.fastchar.{impl}")
    masks = jnp.asarray(masks)
    from ..kernels import registry
    from ..kernels.tuning import tiles_for

    kspec = registry.get(f"fastchar.{impl}")
    bucket = kspec.bucket(n_bits=spec.n_bits, d=int(masks.shape[0]))
    if a_tile is None or d_block is None:
        tiles = tiles_for(ctx, f"fastchar.{impl}",
                          n_bits=spec.n_bits, d=int(masks.shape[0]))
        a_tile = tiles["a_tile"] if a_tile is None else a_tile
        d_block = tiles["d_block"] if d_block is None else d_block
    if ctx is not None and interpret is None:
        interpret = ctx.interpret

    if (
        ctx is not None
        and ctx.shards("configs")
        and masks.shape[0] % (ctx.device_count * d_block) == 0
    ):
        fn = _sharded_partials(
            ctx, spec.n_bits, impl, a_tile, d_block, interpret, bucket
        )
        return fn(masks)
    return _partials_dispatch(spec.n_bits, impl, a_tile, d_block, interpret)(masks)


def _combine(spec: OperatorSpec, int_p: np.ndarray, rel_p: np.ndarray, d: int):
    """Exact int64/f64 host combine of per-tile partials -> BEHAV metric dict."""
    ip = np.asarray(int_p, dtype=np.int64)[:, :d, :]
    rp = np.asarray(rel_p, dtype=np.float64)[:, :d, 0]
    n2 = float(spec.n_inputs) ** 2

    s_abs = ip[..., 0].sum(axis=0)
    cnt = ip[..., 1].sum(axis=0)
    mx = ip[..., 2].max(axis=0)
    sq = 65536 * ip[..., 3].sum(axis=0) + 512 * ip[..., 4].sum(axis=0) + ip[..., 5].sum(axis=0)
    return {
        "AVG_ABS_ERR": s_abs.astype(np.float64) / n2,
        "AVG_ABS_REL_ERR": 100.0 * (rp.sum(axis=0) / n2),
        "PROB_ERR": 100.0 * (cnt.astype(np.float64) / n2),
        "MAX_ABS_ERR": mx.astype(np.float64),
        "MSE": sq.astype(np.float64) / n2,
    }


def behav_metrics_jax(
    spec: OperatorSpec,
    configs: np.ndarray,
    impl: str | None = None,
    batch_size: int = 1024,
    a_tile: int | None = None,
    d_block: int | None = None,
    interpret: bool | None = None,
    ctx: ExecutionContext | None = None,
) -> dict[str, np.ndarray]:
    """Exhaustive BEHAV metrics on accelerator; drop-in for ``behav_metrics``.

    ``impl`` defaults to the context's kernel preference when one applies
    (resolved against the registry's fastchar menu), then to the Pallas
    kernel on TPU and the jit-compiled XLA twin elsewhere (interpret-mode
    Pallas is a correctness path, not a fast path).  ``None`` tiles resolve
    through the registry under the context's ``tuning`` policy.  Large
    batches are chunked by ``batch_size`` configs per dispatch to bound the
    (D, 2^N, 2^N) int32 working set of the XLA impl; under a config-sharded
    ``ctx`` each chunk is padded to a whole number of per-device blocks and
    dispatched over the mesh (see :func:`behav_partials`).
    """
    if impl is None and ctx is not None:
        impl = ctx.resolve_impl("fastchar")
    if impl is None:
        from ..kernels.ops import on_tpu

        impl = "pallas" if on_tpu() else "xla"
    if impl not in CHAR_IMPLS:
        raise ValueError(f"unknown fastchar impl {impl!r}")
    if spec.op != "mul" or spec.n_bits > 8:
        raise ValueError(
            f"exhaustive device characterization supports signed multipliers "
            f"up to 8 bits (got op={spec.op!r}, n_bits={spec.n_bits}): the "
            f"(D, 2^N, 2^N) working set / int32 tile partials do not fit -- "
            f"use behav_metrics_sampled for wider operators"
        )
    configs = np.atleast_2d(np.asarray(configs)).astype(np.uint8)
    d = configs.shape[0]
    masks = config_to_masks(spec, configs).astype(np.int32)

    if a_tile is None or d_block is None:
        from ..kernels.tuning import tiles_for

        tiles = tiles_for(ctx, f"fastchar.{impl}",
                          n_bits=spec.n_bits, d=min(batch_size, d))
        a_tile = tiles["a_tile"] if a_tile is None else a_tile
        d_block = tiles["d_block"] if d_block is None else d_block

    block = d_block
    if ctx is not None and ctx.shards("configs"):
        block = d_block * ctx.device_count
    out = {k: np.empty(d, dtype=np.float64) for k in BEHAV_METRICS}
    with obs.of(ctx).span("fastchar.behav", d=d, impl=impl):
        for lo_i in range(0, d, batch_size):
            hi_i = min(lo_i + batch_size, d)
            chunk = masks[lo_i:hi_i]
            pad = (-len(chunk)) % block
            if pad:
                chunk = np.concatenate(
                    [chunk, np.zeros((pad, spec.rows), np.int32)]
                )
            int_p, rel_p = behav_partials(
                spec, jnp.asarray(chunk), impl=impl, a_tile=a_tile,
                d_block=d_block, interpret=interpret, ctx=ctx,
            )
            part = _combine(spec, int_p, rel_p, hi_i - lo_i)
            for k in BEHAV_METRICS:
                out[k][lo_i:hi_i] = part[k]
    return out


# ---------------------------------------------------------------------------
# Table-free entry function + sampled/streamed characterization (12/16-bit)
# ---------------------------------------------------------------------------


def entry_fn(spec: OperatorSpec):
    """jittable ``fn(config, a, b) -> product`` device function for one family.

    ``config`` is the (L,) {0,1} LUT tuple; ``a``/``b`` are int32
    two's-complement codes (equivalently signed operand values -- negative
    int32 inputs carry the same low bits) of any mutually broadcastable shape.
    Every product entry is synthesized from the carry-chain model on device;
    there is no table anywhere.  Exact in int32 for adders at any supported
    width and multipliers up to N=14; 16-bit multiplier *products* can exceed
    int32, so that family must stream per-row values instead (see
    ``behav_metrics_sampled``).
    """
    if spec.op == "mul" and spec.n_bits > 14:
        raise ValueError(
            f"{spec.n_bits}-bit multiplier products overflow int32; use the "
            f"streamed per-row path (behav_metrics_sampled)"
        )
    cpr = spec.cols_removable

    @jax.jit
    def fn(config, a, b):
        c = config.astype(jnp.int32).reshape(spec.rows, cpr)
        shifts = jnp.arange(cpr, dtype=jnp.int32)
        masks = (c << shifts[None, :]).sum(axis=1)         # (R,)
        return _entry_product(
            spec, masks, jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32),
            jnp, jnp.int32,
        )

    return fn


@functools.partial(jax.jit, static_argnames=("n_bits", "op"))
def _sampled_row_values(masks, a_codes, b_codes, n_bits: int, op: str):
    """(D, R) masks x (S,) code samples -> (D, S, R) int32 per-row values.

    The device half of the streamed reduction: row values always fit int32, so
    the host can combine ``sum_r vals << 2r`` exactly in int64 even for 16-bit
    multipliers whose products overflow int32.
    """
    obs.note_trace("fastchar.sampled_rows")
    spec = spec_for(n_bits, op)
    vals = _entry_row_values(
        spec, masks[:, None, :], a_codes[None, :], b_codes[None, :],
        jnp, jnp.int32,
    )
    d, s = masks.shape[0], a_codes.shape[0]
    return jnp.stack([jnp.broadcast_to(v, (d, s)) for v in vals], axis=-1)


def behav_metrics_sampled(
    spec: OperatorSpec,
    configs: np.ndarray,
    n_samples: int = 32768,
    seed: int = 0,
    s_block: int = 4096,
    b_block: int = 512,
    n_boot: int = 200,
    ci_level: float = 0.95,
    ctx: ExecutionContext | None = None,
) -> tuple[dict[str, np.ndarray], dict[str, tuple[np.ndarray, np.ndarray]]]:
    """Monte-Carlo BEHAV metrics for operators too wide for the exhaustive path.

    Draws ``n_samples`` (rounded up to whole ``s_block`` chunks) input pairs
    uniformly with replacement -- *shared across configs* (common random
    numbers, so config deltas are low-variance) -- and streams them through the
    table-free entry function in ``(D, s_block)`` chunks: device memory is
    bounded by ``D * s_block * R`` int32 regardless of bitwidth (no
    ``(D, 2^N, 2^N)`` anything).  The device returns per-row int32 values; the
    host combines products and errors exactly in int64 (at 16-bit-mul the
    squared errors can exceed int64, so MSE accumulates in float64 there --
    every other width keeps the exact integer accounting of the exhaustive
    combine).

    Returns ``(metrics, ci)``: ``metrics`` has the BEHAV_METRICS keys
    (estimates of the exhaustive values; MAX_ABS_ERR is a sample max, i.e. a
    lower bound); ``ci`` maps each mean-type metric to a ``(lo, hi)`` pair of
    (D,) arrays -- a ``ci_level`` percentile block-bootstrap interval over
    partial sums at ``b_block``-sample granularity (``n_boot`` resamples of
    the block axis; ``b_block`` is accounting-only and does not change the
    device dispatch size ``s_block`` or the point estimates).  Caveat: the
    relative-error channel is heavy-tailed (|err| / max(|exact|, 1) spikes
    where the exact product is near zero), so its percentile interval
    undercovers at small sample counts -- treat it as a diagnostic band, not
    a guarantee; the absolute-error channels are well-behaved.
    """
    configs = np.atleast_2d(np.asarray(configs)).astype(np.uint8)
    d = configs.shape[0]
    masks = jnp.asarray(config_to_masks(spec, configs).astype(np.int32))
    n_chunks = max(1, -(-n_samples // s_block))
    total = n_chunks * s_block

    rng = np.random.default_rng(seed)
    a_codes = rng.integers(0, spec.n_inputs, size=total).astype(np.int32)
    b_codes = rng.integers(0, spec.n_inputs, size=total).astype(np.int32)
    half = spec.n_inputs // 2
    a_s = np.where(a_codes >= half, a_codes.astype(np.int64) - 2 * half, a_codes)
    b_s = np.where(b_codes >= half, b_codes.astype(np.int64) - 2 * half, b_codes)
    exact = a_s + b_s if spec.op == "add" else a_s * b_s   # int64, exact
    denom = np.maximum(np.abs(exact), 1).astype(np.float64)

    bound = max_abs_error_bound(spec)
    sq_exact = bound * bound * total < (1 << 62)           # int64-exact totals

    # bootstrap accounting blocks: finer than the device chunks (a percentile
    # bootstrap over n_chunks ~ 8 blocks is far too coarse), always dividing
    # s_block so each device chunk contributes whole blocks
    b_block = math.gcd(s_block, max(1, b_block))
    n_sub = s_block // b_block
    n_blocks = n_chunks * n_sub

    p_abs = np.empty((n_blocks, d), np.int64)
    p_cnt = np.empty((n_blocks, d), np.int64)
    p_max = np.empty((n_chunks, d), np.int64)
    p_sq = np.empty((n_blocks, d), np.int64 if sq_exact else np.float64)
    p_rel = np.empty((n_blocks, d), np.float64)
    with obs.of(ctx).span("fastchar.behav_sampled", d=d, n=total,
                          n_bits=spec.n_bits, op=spec.op):
        for c in range(n_chunks):
            sl = slice(c * s_block, (c + 1) * s_block)
            vals = np.asarray(
                _sampled_row_values(
                    masks, jnp.asarray(a_codes[sl]), jnp.asarray(b_codes[sl]),
                    spec.n_bits, spec.op,
                ),
                dtype=np.int64,
            )                                              # (D, s, R)
            approx = vals[..., 0]
            for r in range(1, spec.rows):
                approx = approx + (vals[..., r] << (2 * r))
            abs_e = np.abs(approx - exact[None, sl])       # (D, s) int64
            blk = slice(c * n_sub, (c + 1) * n_sub)
            by_block = abs_e.reshape(d, n_sub, b_block)
            p_abs[blk] = by_block.sum(axis=2).T
            p_cnt[blk] = (by_block != 0).sum(axis=2).T
            p_max[c] = abs_e.max(axis=1)
            sq = by_block * by_block if sq_exact \
                else by_block.astype(np.float64) ** 2
            p_sq[blk] = sq.sum(axis=2).T
            p_rel[blk] = (
                (abs_e / denom[None, sl]).reshape(d, n_sub, b_block)
                .sum(axis=2).T
            )

    inv = 1.0 / total
    metrics = {
        "AVG_ABS_ERR": p_abs.sum(axis=0).astype(np.float64) * inv,
        "AVG_ABS_REL_ERR": 100.0 * p_rel.sum(axis=0) * inv,
        "PROB_ERR": 100.0 * p_cnt.sum(axis=0).astype(np.float64) * inv,
        "MAX_ABS_ERR": p_max.max(axis=0).astype(np.float64),
        "MSE": p_sq.sum(axis=0).astype(np.float64) * inv,
    }

    boot_rng = np.random.default_rng(seed + 1)
    idx = boot_rng.integers(0, n_blocks, size=(n_boot, n_blocks))
    q_lo, q_hi = 100.0 * (1 - ci_level) / 2, 100.0 * (1 + ci_level) / 2

    def _boot(partials, scale):
        est = partials[idx].sum(axis=1).astype(np.float64) * (scale * inv)
        return (np.percentile(est, q_lo, axis=0), np.percentile(est, q_hi, axis=0))

    ci = {
        "AVG_ABS_ERR": _boot(p_abs, 1.0),
        "AVG_ABS_REL_ERR": _boot(p_rel, 100.0),
        "PROB_ERR": _boot(p_cnt, 100.0),
        "MSE": _boot(p_sq, 1.0),
    }
    return metrics, ci


# ---------------------------------------------------------------------------
# Batched surrogate evaluation (NSGA-II fitness in one dispatch per generation)
# ---------------------------------------------------------------------------


def _poly_predict_jax(model):
    """PolyRegModel -> jnp closure over its coefficients."""
    qi = jnp.asarray([p[0] for p in model.quad_pairs], jnp.int32)
    qj = jnp.asarray([p[1] for p in model.quad_pairs], jnp.int32)
    lin = jnp.asarray(model.linear, jnp.float32)
    quad = jnp.asarray(model.quad, jnp.float32)
    c0 = jnp.float32(model.intercept)
    lo = jnp.float32(model.scaler.lo)
    span = jnp.float32(model.scaler.hi - model.scaler.lo)
    has_quad = len(model.quad_pairs) > 0

    def predict(X):
        y = c0 + X @ lin
        if has_quad:
            y = y + (X[:, qi] * X[:, qj]) @ quad
        return y * span + lo

    return predict


def _gbt_predict_jax(model):
    """GBTRegressor -> jnp closure over padded tree arrays."""
    n_nodes = max(t.feature.shape[0] for t in model.trees)

    def pack(attr, fill):
        out = np.full((len(model.trees), n_nodes), fill, dtype=np.float64)
        for i, t in enumerate(model.trees):
            a = getattr(t, attr)
            out[i, : a.shape[0]] = a
        return out

    feature = jnp.asarray(pack("feature", -1), jnp.int32)
    left = jnp.asarray(np.maximum(pack("left", 0), 0), jnp.int32)
    right = jnp.asarray(np.maximum(pack("right", 0), 0), jnp.int32)
    value = jnp.asarray(pack("value", 0.0), jnp.float32)
    base = jnp.float32(model.base)
    lr = jnp.float32(model.learning_rate)
    n_trees = len(model.trees)
    depth = model.max_depth

    def predict(X):
        b = X.shape[0]
        node = jnp.zeros((n_trees, b), jnp.int32)
        xb = jnp.broadcast_to(X[None], (n_trees, b, X.shape[1]))
        for _ in range(depth):  # static: a root-to-leaf path has <= depth edges
            feat = jnp.take_along_axis(feature, node, axis=1)      # (T, B)
            active = feat >= 0
            xf = jnp.take_along_axis(
                xb, jnp.maximum(feat, 0)[..., None], axis=2
            )[..., 0]
            nxt = jnp.where(
                xf > 0.5,
                jnp.take_along_axis(right, node, axis=1),
                jnp.take_along_axis(left, node, axis=1),
            )
            node = jnp.where(active, nxt, node)
        leaves = jnp.take_along_axis(value, node, axis=1)          # (T, B)
        return base + lr * leaves.sum(axis=0)

    return predict


def _estimator_predict_jax(est):
    """AutoMLRegressor -> jnp predict closure for whichever family won."""
    from .gbt import GBTRegressor
    from .regression import PolyRegModel

    model = est.model
    if isinstance(model, PolyRegModel):
        return _poly_predict_jax(model)
    if isinstance(model, GBTRegressor):
        return _gbt_predict_jax(model)
    raise TypeError(f"no JAX path for estimator {type(model).__name__}")


def surrogate_objs_device(estimators: dict, behav_key: str, ppa_key: str):
    """(B, L) f32 -> (B, 2) f32 device surrogate-objective closure (un-jitted).

    This is the fusion hook for the device GA engine: ``fastmoo`` traces it
    *inside* its generation loop so NSGA-II fitness evaluation compiles into
    the same program as selection/crossover/mutation (poly models become fused
    matmuls, GBT forests become batched gather walks).
    """
    pb = _estimator_predict_jax(estimators[behav_key])
    pp = _estimator_predict_jax(estimators[ppa_key])

    def objs_fn(X):
        X = X.astype(jnp.float32)
        return jnp.stack([pb(X), pp(X)], axis=-1)

    return objs_fn


def compile_surrogate_batch(
    estimators: dict,
    behav_key: str,
    ppa_key: str,
    max_behav: float,
    max_ppa: float,
    ctx: ExecutionContext | None = None,
):
    """jit one (B, L) -> ((B, 2) objectives, (B,) violation) surrogate dispatch.

    This is the host-loop NSGA-II fast path (``ga_backend="numpy"`` with
    ``backend="jax"``): fitness + constraint violation of a whole generation
    in a single compiled call.  Results are float32; the numpy estimators
    remain the reference implementation.  The underlying device closure is
    exposed as ``fn.objs_fn`` for the fully-fused ``fastmoo`` engine.

    ``ctx`` is accepted for signature uniformity with the other engine entry
    points; a generation batch is a single small dispatch, so the context's
    mesh is never consulted here (the GA engine shards *lanes*, not fitness).
    """
    del ctx  # policy carrier only: no per-batch sharding of surrogate eval
    objs_fn = surrogate_objs_device(estimators, behav_key, ppa_key)
    nb = jnp.float32(max(abs(max_behav), 1e-9))
    np_ = jnp.float32(max(abs(max_ppa), 1e-9))
    mb = jnp.float32(max_behav)
    mp = jnp.float32(max_ppa)

    @jax.jit
    def eval_viol(X):
        objs = objs_fn(X)
        yb, yp = objs[:, 0], objs[:, 1]
        viol = jnp.maximum(0.0, yb - mb) / nb + jnp.maximum(0.0, yp - mp) / np_
        return objs, viol

    def fn(configs: np.ndarray):
        objs, viol = eval_viol(jnp.asarray(np.asarray(configs), jnp.float32))
        return (
            np.asarray(objs, dtype=np.float64),
            np.asarray(viol, dtype=np.float64),
        )

    fn.objs_fn = objs_fn
    return fn


# ---------------------------------------------------------------------------
# Batched MaP quadratic-form evaluation (miqcp.solve_enumerate backend="jax")
# ---------------------------------------------------------------------------


@jax.jit
def _quad_values(configs, const, lin, quad):
    """configs (D, L); const (K,), lin (K, L), quad (K, L, L) -> (K, D)."""
    lin_t = configs @ lin.T                                       # (D, K)
    quad_t = jnp.einsum("di,kij,dj->dk", configs, quad, configs)
    return (const[None] + lin_t + quad_t).T


def map_problem_values_jax(problem, configs: np.ndarray) -> tuple[np.ndarray, ...]:
    """(obj, behav, ppa) values of a MapProblem over a config batch, one dispatch."""
    exprs = (problem.obj, problem.behav, problem.ppa)
    const = jnp.asarray([e.const for e in exprs], jnp.float32)
    lin = jnp.asarray(np.stack([e.lin for e in exprs]), jnp.float32)
    quad = jnp.asarray(np.stack([e.quad for e in exprs]), jnp.float32)
    vals = _quad_values(jnp.asarray(configs, jnp.float32), const, lin, quad)
    v = np.asarray(vals, dtype=np.float64)
    return v[0], v[1], v[2]


@jax.jit
def _tabu_step_values(states, const, lin, quad, sym):
    """states (S, L); expr stacks (K,), (K, L), (K, L, L) -> values + deltas.

    Returns ``vals (K, S)`` -- each expression at each start's current point --
    and ``deltas (K, S, L)`` -- the change from flipping each single bit
    (``QuadExpr.flip_deltas`` batched over starts and expressions).
    """
    lin_t = states @ lin.T                                        # (S, K)
    quad_t = jnp.einsum("si,kij,sj->sk", states, quad, states)
    vals = (const[None] + lin_t + quad_t).T                       # (K, S)
    grad = lin[:, None, :] + jnp.einsum("kij,sj->ksi", sym, states)
    deltas = (1.0 - 2.0 * states)[None] * grad                    # (K, S, L)
    return vals, deltas


def tabu_neighbor_values_jax(problem):
    """Batched multi-start neighborhood scorer for ``miqcp.solve_tabu``.

    Returns ``step(states (S, L)) -> (vals (3, S), deltas (3, S, L))`` float64
    numpy arrays with expression order (obj, behav, ppa): every start's full
    single-flip neighborhood scored in one device dispatch, reusing the same
    quadratic-form evaluation ``solve_enumerate(backend="jax")`` batches.
    The jitted core is shared across problems (coefficients are traced
    arguments), so a wt_B x n_quad problem battery compiles once per (S, L).
    """
    exprs = (problem.obj, problem.behav, problem.ppa)
    const = jnp.asarray([e.const for e in exprs], jnp.float32)
    lin = jnp.asarray(np.stack([e.lin for e in exprs]), jnp.float32)
    quad = jnp.asarray(np.stack([e.quad for e in exprs]), jnp.float32)
    sym = jnp.asarray(
        np.stack([e.quad + e.quad.T for e in exprs]), jnp.float32
    )

    def step(states: np.ndarray):
        vals, deltas = _tabu_step_values(
            jnp.asarray(states, jnp.float32), const, lin, quad, sym
        )
        return np.asarray(vals, np.float64), np.asarray(deltas, np.float64)

    return step


# vmap of the jitted per-problem scorer: one dispatch scores every problem's
# every start's full single-flip neighborhood -- the (problems x starts, L)
# lockstep batch used by ``miqcp.solve_tabu_multi``.
_tabu_step_values_multi = jax.jit(jax.vmap(_tabu_step_values))


def _expr_stacks(problems):
    """(P, 3[obj,behav,ppa]) expression-coefficient stacks as jnp f32."""
    exprs = [(p.obj, p.behav, p.ppa) for p in problems]
    const = jnp.asarray([[e.const for e in row] for row in exprs], jnp.float32)
    lin = jnp.asarray(
        np.stack([np.stack([e.lin for e in row]) for row in exprs]), jnp.float32
    )
    quad = jnp.asarray(
        np.stack([np.stack([e.quad for e in row]) for row in exprs]), jnp.float32
    )
    sym = jnp.asarray(
        np.stack([np.stack([e.quad + e.quad.T for e in row]) for row in exprs]),
        jnp.float32,
    )
    return const, lin, quad, sym


def tabu_neighbor_values_multi_jax(problems):
    """Cross-problem lockstep neighborhood scorer for ``miqcp.solve_tabu_multi``.

    Returns ``step(states (P, S, L)) -> (vals (P, 3, S), deltas (P, 3, S, L))``
    float64 numpy arrays: the whole MaP battery's every start's single-flip
    neighborhood scored in ONE device dispatch (a ``vmap`` of the per-problem
    ``_tabu_step_values`` over the problem axis).  The jitted core is shared
    across batteries -- coefficients are traced arguments, so a wt_B x n_quad
    battery compiles once per (P, S, L).
    """
    const, lin, quad, sym = _expr_stacks(problems)

    def step(states: np.ndarray):
        vals, deltas = _tabu_step_values_multi(
            jnp.asarray(states, jnp.float32), const, lin, quad, sym
        )
        return np.asarray(vals, np.float64), np.asarray(deltas, np.float64)

    return step
