"""Unified execution policy for the whole DSE stack (the ``ExecutionContext``).

PRs 1-3 grew three device engines -- ``core.fastchar`` (characterization),
``apps.fastapp`` (application BEHAV) and ``core.fastmoo`` (NSGA-II) -- and each
grew its own ``backend="numpy"|"jax"`` string plumbing plus per-engine impl /
interpret knobs.  That left no single place to hang a device mesh, which is
exactly what the remaining scale items need (sharding the config axis of
characterization and the lane axis of ``run_dse_sweep`` batteries).

:class:`ExecutionContext` is the one execution-policy object threaded through
every engine:

  * ``backend`` / ``ga_backend`` -- which engine family runs (the old strings);
  * ``n_devices`` + ``shard_axes`` -- a 1-D device mesh and which batch axes
    are sharded over it (``"configs"``: the D axis of ``fastchar.
    behav_partials`` and the fastapp table primitives; ``"lanes"``: the
    independent (seed x const_sf) axis of ``fastmoo.CompiledNSGA2.run_sweep``);
  * ``kernel_impl`` -- preferred kernel implementation where an engine offers a
    menu; the menus live in the kernel registry (``repro.kernels.registry``:
    ``fastchar``: xla/pallas/entry/entry_pallas; ``fastapp``:
    gemm/xla/pallas/entry/entry_pallas; ``fastmoo`` rank kernel: xla/pallas)
    and :meth:`ExecutionContext.resolve_impl` resolves a
    preference against an engine's registered menu; engines fall back to
    their own default when the named impl is not on their menu;
  * ``tuning`` -- block-shape autotune policy for the registered kernels
    (``"off"``: registry defaults; ``"cached"``: per-(shape bucket, device)
    winners from the on-disk cache, searching once on a miss; ``"search"``:
    ignore persisted winners and re-search once per process per bucket).
    Consumed by ``repro.kernels.tuning.tiles_for``;
  * ``interpret`` -- Pallas interpret-mode override (None = auto off-TPU);
  * ``prng_impl`` -- the JAX PRNG family used for GA keys *and* for device-
    side dataset generation (None = default threefry2x32 for keys and the
    legacy numpy generator for datasets; ``"rbg"``/``"unsafe_rbg"`` for
    TPU-friendly generators end to end);
  * ``telemetry`` -- where this context's engines report spans/counters/
    device taps (``repro.obs``).  ``None`` (default) follows the process-
    wide sink; ``"on"`` creates a fresh per-run sink with on-device metric
    taps enabled (counters still chain to the global aggregate); ``"off"``
    is the no-op sink (compiled programs contain no taps at all); an
    explicit :class:`repro.obs.Telemetry` is used as-is.  Engines read it
    via :attr:`ExecutionContext.tel`, never the raw field.

The legacy ``backend=``/``ga_backend=`` string parameters everywhere in the
code base are **deprecated shims**: they still work, and they resolve to the
equivalent context via :func:`as_context` -- every dispatch decision is made by
the context, nowhere else.

Sharding model: the mesh is 1-D (axis name :data:`MESH_AXIS`) over the first
``n_devices`` of ``jax.devices()``.  Batch entries are fully independent in
every engine (per-config characterization/scoring, per-lane GA runs), so
sharded execution is the *same* per-entry program on ``1/n``-th of the batch
and results are bit-identical to the unsharded dispatch; the existing tiny
int64 host combines are unchanged.  Multi-device CPU validation uses
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the same trick
``launch/mesh.py`` documents), which must be set before JAX first initializes.

This module imports JAX lazily -- constructing a numpy-backend context (the
default everywhere) keeps the numpy modules JAX-free.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

__all__ = [
    "BACKENDS",
    "KERNEL_IMPLS",
    "SHARD_AXES",
    "PRNG_IMPLS",
    "TUNING_POLICIES",
    "MESH_AXIS",
    "ExecutionContext",
    "as_context",
]

BACKENDS = ("numpy", "jax")
# "entry"/"entry_pallas" are the table-free engines: product entries are
# synthesized on device from the LUT config masks (no HBM table build).
KERNEL_IMPLS = ("xla", "pallas", "gemm", "entry", "entry_pallas")
SHARD_AXES = ("configs", "lanes")
PRNG_IMPLS = ("threefry2x32", "rbg", "unsafe_rbg")
TUNING_POLICIES = ("off", "cached", "search")
MESH_AXIS = "shard"


@functools.lru_cache(maxsize=None)
def _mesh_for(n_devices: int):
    """1-D mesh over the first ``n_devices`` devices (cached per size)."""
    import jax

    devices = jax.devices()
    if n_devices > len(devices):
        raise ValueError(
            f"n_devices={n_devices} but only {len(devices)} JAX devices are "
            "available -- for CPU validation set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 before JAX "
            "first initializes"
        )
    return jax.make_mesh((n_devices,), (MESH_AXIS,), devices=devices[:n_devices])


@dataclass(frozen=True)
class ExecutionContext:
    """The single execution-policy object consumed by every DSE engine."""

    backend: str = "numpy"
    ga_backend: str | None = None
    n_devices: int | None = None
    shard_axes: tuple[str, ...] = SHARD_AXES
    kernel_impl: str | None = None
    interpret: bool | None = None
    prng_impl: str | None = None
    tuning: str = "off"
    telemetry: object | None = None

    def __post_init__(self) -> None:
        if self.telemetry is not None:
            # normalize "on"/"off" to sink objects at construction so the
            # field is stable (hashable, and "on" allocates its sink once)
            from ..obs.telemetry import Telemetry, as_telemetry

            if not isinstance(self.telemetry, Telemetry):
                object.__setattr__(
                    self, "telemetry", as_telemetry(self.telemetry)
                )
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be 'numpy' or 'jax', got {self.backend!r}"
            )
        if self.ga_backend not in (None,) + BACKENDS:
            raise ValueError(
                f"ga_backend must be None, 'numpy' or 'jax', got {self.ga_backend!r}"
            )
        if self.kernel_impl not in (None,) + KERNEL_IMPLS:
            raise ValueError(
                f"kernel_impl must be one of {(None,) + KERNEL_IMPLS}, "
                f"got {self.kernel_impl!r}"
            )
        if self.prng_impl not in (None,) + PRNG_IMPLS:
            raise ValueError(
                f"prng_impl must be one of {(None,) + PRNG_IMPLS}, "
                f"got {self.prng_impl!r}"
            )
        if self.tuning not in TUNING_POLICIES:
            raise ValueError(
                f"tuning must be one of {TUNING_POLICIES}, got {self.tuning!r}"
            )
        axes = self.shard_axes
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(axes)
        object.__setattr__(self, "shard_axes", axes)
        bad = [a for a in axes if a not in SHARD_AXES]
        if bad or len(set(axes)) != len(axes):
            raise ValueError(
                f"shard_axes must be distinct names from {SHARD_AXES}, got {axes!r}"
            )
        if self.n_devices is not None:
            if not isinstance(self.n_devices, int) or self.n_devices < 1:
                raise ValueError(
                    f"n_devices must be a positive int or None, got {self.n_devices!r}"
                )
            if self.n_devices > 1:
                if self.backend != "jax":
                    raise ValueError(
                        "sharded execution (n_devices > 1) requires backend='jax', "
                        f"got backend={self.backend!r}"
                    )
                if not axes:
                    raise ValueError(
                        "n_devices > 1 with empty shard_axes: nothing to shard "
                        "-- name at least one of "
                        f"{SHARD_AXES} or drop the mesh"
                    )
                _mesh_for(self.n_devices)  # eager: fail at construction

    # -- resolution helpers --------------------------------------------------

    @property
    def is_jax(self) -> bool:
        return self.backend == "jax"

    @property
    def tel(self):
        """This context's telemetry sink (never None): the explicit sink, or
        the process-wide current one when the field was left default."""
        from ..obs.telemetry import current

        return current() if self.telemetry is None else self.telemetry

    @property
    def resolved_ga_backend(self) -> str:
        return self.backend if self.ga_backend is None else self.ga_backend

    @property
    def device_count(self) -> int:
        return 1 if self.n_devices is None else self.n_devices

    def shards(self, axis: str) -> bool:
        """Whether batch axis ``axis`` ('configs' | 'lanes') is mesh-sharded."""
        if axis not in SHARD_AXES:
            raise ValueError(f"unknown shard axis {axis!r} (not in {SHARD_AXES})")
        return self.device_count > 1 and axis in self.shard_axes

    def resolve_impl(
        self, choices: "str | tuple[str, ...]", default: str | None = None
    ) -> str | None:
        """The context's kernel impl if the engine offers it, else ``default``.

        ``choices`` is an engine name (``"fastchar"``/``"fastapp"``/
        ``"fastmoo"`` -- the menu is read from the kernel registry, the one
        source of truth for what each engine can run) or, for backward
        compatibility, an explicit tuple of impl names.  Engines have
        different menus (fastchar has no 'gemm'; fastapp has no rank kernel),
        so a context-level preference only applies where it names something
        the calling engine can actually run.
        """
        if isinstance(choices, str):
            from ..kernels import registry

            choices = registry.impl_names(choices)
        if self.kernel_impl in choices:
            return self.kernel_impl
        return default

    def tuned_tiles(self, kernel: str, **shape) -> dict:
        """Block shapes of registered kernel ``kernel`` for ``shape`` under
        this context's ``tuning`` policy (registry defaults when "off")."""
        from ..kernels.tuning import tiles_for

        return tiles_for(self, kernel, **shape)

    # -- device handles (JAX imported lazily) --------------------------------

    def mesh(self):
        """The 1-D device mesh (axis :data:`MESH_AXIS`) for sharded dispatch."""
        return _mesh_for(self.device_count)

    def devices(self) -> list:
        import jax

        return jax.devices()[: self.device_count]

    def shard_call(self, fn, in_specs, out_specs):
        """``shard_map`` of ``fn`` over this context's mesh (portable wrapper)."""
        from ..models.sharding import shard_map

        return shard_map(fn, self.mesh(), in_specs, out_specs)

    def prng_key(self, seed: int):
        """A JAX PRNG key under this context's PRNG policy.

        ``None`` keeps the legacy raw ``PRNGKey`` (bit-compatible with the
        engines' historical streams); a named impl returns a typed key array
        so the generator choice travels with the key through jit/vmap/
        shard_map instead of being re-guessed from raw uint32 data.
        """
        import jax

        if self.prng_impl is None:
            return jax.random.PRNGKey(seed)
        return jax.random.key(seed, impl=self.prng_impl)


def as_context(
    backend: "str | ExecutionContext | None",
    ga_backend: str | None = None,
    default: ExecutionContext | None = None,
) -> ExecutionContext:
    """Normalize a legacy ``backend`` string (or an existing context) to an
    :class:`ExecutionContext` -- the single deprecated-shim entry point.

    ``backend=None`` returns ``default`` (or a fresh numpy context).  Passing a
    context alongside a conflicting ``ga_backend`` string is an error; matching
    or ``None`` strings are accepted so shim call sites can forward both.
    """
    if isinstance(backend, ExecutionContext):
        if ga_backend is not None and ga_backend != backend.resolved_ga_backend:
            raise ValueError(
                f"conflicting ga_backend={ga_backend!r} with context "
                f"{backend.resolved_ga_backend!r} -- pass one or the other"
            )
        return backend
    if backend is None:
        if default is not None:
            return as_context(default, ga_backend=ga_backend)
        backend = "numpy"
    return ExecutionContext(backend=backend, ga_backend=ga_backend)
