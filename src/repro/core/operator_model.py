"""LUT-level behavioral model of FPGA-style signed multipliers (AppAxO operator model).

The operator model follows AxOMaP / AppAxO: an approximate operator is an ordered
binary tuple ``O_i(l_0 .. l_{L-1})`` where ``l_k = 1`` keeps LUT ``k`` of the accurate
implementation and ``l_k = 0`` removes it.  Removing a LUT zeroes its sum output AND
truncates the carry out of the associated carry-chain cell (paper Fig. 3 semantics).

Architecture (row-paired partial products, matching the published removable-LUT
counts: signed 4x4 -> L=10, signed 8x8 -> L=36):

  * ``R = N/2`` rows.  Row ``r`` covers multiplier bits ``a_{2r}, a_{2r+1}``.
  * Row value ``V_r = coeff_r * B`` with ``coeff_r = a_{2r} + 2*a_{2r+1}`` for
    ``r < R-1`` and ``coeff_r = a_{2r} - 2*a_{2r+1}`` for the top (sign) row, so that
    ``sum_r 4^r V_r = A * B`` exactly for two's-complement ``A``.
  * Each row is computed as a ``W = N+2`` bit carry-chain addition of the two partial
    products ``T1 = a_{2r} ? B : 0`` and ``T2 = a_{2r+1} ? (+/-B << 1) : 0`` using one
    LUT + carry cell per column (propagate/generate + MUXCY semantics).
  * Columns ``0 .. N`` of every row (``N+1`` per row) are REMOVABLE; the top column
    ``W-1`` (sign handling) and the row-merge adder tree are always accurate.
    ``L = R * (N+1)``:  4x4 -> 2*5 = 10,  8x8 -> 4*9 = 36.

Removal of column ``j`` in a row forces ``sum_j = 0`` and ``carry_{j+1} = 0``.

Everything is vectorized through a precomputed "row table" over
``(top?, a0, a1, B, row_mask)`` so that characterizing thousands of configs over all
``2^{2N}`` input pairs is a handful of numpy gathers.

Beyond the paper's 8x8 signed multiplier (the AxOSyn generalization), the model
is parameterized over operator kind via ``OperatorSpec.op``:

  * ``op="mul"`` -- the row-paired signed multiplier above (any even N).
  * ``op="add"`` -- a signed N-bit carry-chain adder: a single row of width
    ``W = N+1`` adding ``A + B`` with columns ``0..N-1`` removable (the top
    sign column is always accurate), so ``L = N``.

The config -> product mapping is also exposed as a *device function*
(:func:`entry_product` / the ``xp``-generic ``_entry_product``): given the per-row
masks it synthesizes any ``(a, b)`` entry of the product table directly from the
carry-chain model, with no precomputed table.  This is what lets kernels
reconstruct their VMEM tile from the ``(D, L)`` config bits instead of gathering
from an HBM-resident ``(D, 2^N, 2^N)`` table -- the only viable route at 12/16
bits, where that table cannot be materialized at all.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "OperatorSpec",
    "spec_for",
    "RowTables",
    "row_tables",
    "config_to_masks",
    "masks_to_config",
    "accurate_config",
    "product_tables",
    "exact_product_table",
    "exact_table",
    "entry_product",
    "entry_row_values",
    "error_tables",
    "simulate_product",
]

OPERATOR_KINDS = ("mul", "add")


@dataclass(frozen=True)
class OperatorSpec:
    """Static description of one approximate-operator family.

    ``signed=True`` (the paper's case) interprets operand codes as two's
    complement and gives the multiplier a Booth-style negated top row;
    ``signed=False`` keeps the same carry-chain/removable-LUT structure but
    reads codes as plain unsigned integers -- no sign row, no wrap -- so the
    accurate config computes the exact unsigned product/sum.
    """

    n_bits: int                       # operand width N
    op: str = "mul"                   # operator kind: "mul" | "add"
    signed: bool = True               # two's-complement (True) or unsigned codes
    rows: int = field(init=False)     # partial-product rows (R = N/2 mul, 1 add)
    width: int = field(init=False)    # per-row adder width (N+2 mul, N+1 add)
    cols_removable: int = field(init=False)  # removable columns per row
    n_luts: int = field(init=False)   # total removable LUTs L

    def __post_init__(self) -> None:
        if self.op not in OPERATOR_KINDS:
            raise ValueError(f"op must be one of {OPERATOR_KINDS}, got {self.op!r}")
        if self.op == "mul":
            if self.n_bits % 2 != 0 or self.n_bits < 2:
                raise ValueError(
                    f"n_bits must be even and >= 2 for op='mul', got {self.n_bits}"
                )
            object.__setattr__(self, "rows", self.n_bits // 2)
            object.__setattr__(self, "width", self.n_bits + 2)
            object.__setattr__(self, "cols_removable", self.n_bits + 1)
        else:  # add: one carry chain of width N+1, sign column accurate
            if self.n_bits < 2:
                raise ValueError(f"n_bits must be >= 2, got {self.n_bits}")
            object.__setattr__(self, "rows", 1)
            object.__setattr__(self, "width", self.n_bits + 1)
            object.__setattr__(self, "cols_removable", self.n_bits)
        object.__setattr__(self, "n_luts", self.rows * self.cols_removable)

    @property
    def n_inputs(self) -> int:
        """Number of distinct values of one operand."""
        return 1 << self.n_bits

    @property
    def operand_values(self) -> np.ndarray:
        """All operand values in code order 0 .. 2^N-1 (two's complement when
        signed, identity when unsigned)."""
        u = np.arange(self.n_inputs, dtype=np.int64)
        if not self.signed:
            return u
        return np.where(u >= self.n_inputs // 2, u - self.n_inputs, u)

    @property
    def n_row_masks(self) -> int:
        return 1 << self.cols_removable

    @property
    def tag(self) -> str:
        """Short stable family name, e.g. ``mul8`` / ``add6u`` (library keys)."""
        return f"{self.op}{self.n_bits}{'' if self.signed else 'u'}"


@functools.lru_cache(maxsize=None)
def spec_for(n_bits: int, op: str = "mul", signed: bool = True) -> OperatorSpec:
    return OperatorSpec(n_bits, op, signed)


# ---------------------------------------------------------------------------
# Row tables
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RowTables:
    """Precomputed per-row behavior, indexed ``[top, a0, a1, b_idx, mask]``.

    value:   signed row output (int32) after carry-truncated addition.
    sum_p1:  P(sum bit j == 1) per column, indexed ``[top, mask, j]`` under uniform
             (a0, a1, B) -- used by the switching-activity power model.
    out_p1:  P(output bit j == 1) of the (two's complement, width-16) row value,
             indexed ``[top, mask, j]`` -- drives the merge-adder activity model.
    """

    spec: OperatorSpec
    value: np.ndarray      # (2, 2, 2, 2^N, 2^(N+1)) int32
    sum_p1: np.ndarray     # (2, 2^(N+1), W) float64
    out_p1: np.ndarray     # (2, 2^(N+1), 16) float64


def _row_values(spec: OperatorSpec) -> np.ndarray:
    """Exhaustive carry-chain evaluation of one row for every mask.

    Returns int32 array of shape (2[top], 2[a0], 2[a1], 2^N[b], 2^(N+1)[mask]).
    """
    n, w = spec.n_bits, spec.width
    n_b = spec.n_inputs
    n_mask = spec.n_row_masks

    b = spec.operand_values.astype(np.int64)  # (n_b,) signed values

    top = np.arange(2).reshape(2, 1, 1, 1, 1)
    a0 = np.arange(2).reshape(1, 2, 1, 1, 1)
    a1 = np.arange(2).reshape(1, 1, 2, 1, 1)
    bv = b.reshape(1, 1, 1, n_b, 1)
    mask = np.arange(n_mask, dtype=np.int64).reshape(1, 1, 1, 1, n_mask)

    modw = (1 << w) - 1
    t1 = np.where(a0 == 1, bv & modw, 0)
    bx = np.where(top == 1, -bv, bv)
    t2 = np.where(a1 == 1, (bx << 1) & modw, 0)

    s = np.zeros(np.broadcast_shapes(t1.shape, t2.shape, mask.shape), dtype=np.int64)
    c = np.zeros_like(s)
    for j in range(w):
        t1j = (t1 >> j) & 1
        t2j = (t2 >> j) & 1
        p = t1j ^ t2j
        g = t1j & t2j
        sj = p ^ c
        c_next = np.where(p == 1, c, g)
        if j < spec.cols_removable:
            kept = (mask >> j) & 1
            sj = sj * kept
            c_next = c_next * kept
        s = s | (sj << j)
        c = c_next

    # Interpret W-bit two's complement.
    sign = 1 << (w - 1)
    val = np.where(s & sign != 0, s - (1 << w), s)
    return val.astype(np.int32)


@functools.lru_cache(maxsize=None)
def row_tables(n_bits: int) -> RowTables:
    spec = spec_for(n_bits)
    value = _row_values(spec)  # (2,2,2,n_b,n_mask)
    w = spec.width
    n_mask = spec.n_row_masks

    # --- per-column sum-bit statistics (for the power model) ------------------
    # Reconstruct W-bit unsigned pattern of the row output.
    u = value.astype(np.int64) & ((1 << w) - 1)
    sum_p1 = np.empty((2, n_mask, w), dtype=np.float64)
    out_p1 = np.empty((2, n_mask, 16), dtype=np.float64)
    u16 = value.astype(np.int64) & 0xFFFF
    for t in range(2):
        # average over a0, a1, b -> (n_mask,)
        for j in range(w):
            bits = (u[t] >> j) & 1
            sum_p1[t, :, j] = bits.mean(axis=(0, 1, 2))
        for j in range(16):
            bits = (u16[t] >> j) & 1
            out_p1[t, :, j] = bits.mean(axis=(0, 1, 2))

    return RowTables(spec=spec, value=value, sum_p1=sum_p1, out_p1=out_p1)


# ---------------------------------------------------------------------------
# Config <-> per-row masks
# ---------------------------------------------------------------------------


def config_to_masks(spec: OperatorSpec, configs: np.ndarray) -> np.ndarray:
    """(..., L) {0,1} array -> (..., R) integer per-row masks."""
    configs = np.asarray(configs)
    if configs.shape[-1] != spec.n_luts:
        raise ValueError(f"config length {configs.shape[-1]} != L={spec.n_luts}")
    cpr = spec.cols_removable
    out = np.zeros(configs.shape[:-1] + (spec.rows,), dtype=np.int64)
    for r in range(spec.rows):
        for j in range(cpr):
            out[..., r] |= configs[..., r * cpr + j].astype(np.int64) << j
    return out


def masks_to_config(spec: OperatorSpec, masks: np.ndarray) -> np.ndarray:
    """(..., R) int masks -> (..., L) {0,1} uint8 config."""
    masks = np.asarray(masks, dtype=np.int64)
    cpr = spec.cols_removable
    out = np.zeros(masks.shape[:-1] + (spec.n_luts,), dtype=np.uint8)
    for r in range(spec.rows):
        for j in range(cpr):
            out[..., r * cpr + j] = (masks[..., r] >> j) & 1
    return out


def accurate_config(spec: OperatorSpec) -> np.ndarray:
    return np.ones(spec.n_luts, dtype=np.uint8)


# ---------------------------------------------------------------------------
# Table-free entry synthesis (config -> product as a device function)
# ---------------------------------------------------------------------------
#
# ``xp`` is the array module (numpy or jax.numpy): the same code is the numpy
# oracle (int64, exact at any width) and the traced device function (int32 --
# exact for every intermediate as long as the *row values* fit, i.e. any
# supported width; the combined product additionally fits int32 for mul up to
# N=14 and add at any width; 16-bit multiplies must stream the per-row values
# and combine them host-side in int64, see ``entry_row_values``).


def _chain_eval(t1, t2, mask, w: int, cpr: int, xp, dtype, signed_out: bool = True):
    """Carry-truncated ``W``-bit add of ``t1 + t2`` under a per-column keep mask.

    ``t1``/``t2`` are W-bit unsigned patterns, ``mask`` the per-row integer
    keep-mask (bit ``j`` keeps column ``j``; columns ``>= cpr`` are always
    kept).  Broadcasts over any common shape; returns the W-bit value, read
    as two's complement when ``signed_out`` (the default) and as a plain
    unsigned pattern otherwise (unsigned operator families).
    """
    t1 = t1.astype(dtype)
    t2 = t2.astype(dtype)
    mask = mask.astype(dtype)
    shape = np.broadcast_shapes(np.shape(t1), np.shape(t2), np.shape(mask))
    s = xp.zeros(shape, dtype)
    c = xp.zeros(shape, dtype)
    for j in range(w):
        t1j = (t1 >> j) & 1
        t2j = (t2 >> j) & 1
        p = t1j ^ t2j
        g = t1j & t2j
        sj = p ^ c
        c_next = xp.where(p == 1, c, g)
        if j < cpr:
            kept = (mask >> j) & 1
            sj = sj * kept
            c_next = c_next * kept
        s = s | (sj << j)
        c = c_next
    if not signed_out:
        return s
    sign = 1 << (w - 1)
    return xp.where((s & sign) != 0, s - (1 << w), s)


def _entry_row_values(spec: OperatorSpec, masks, a_codes, b_codes, xp, dtype):
    """Per-row signed values of the approximate op at ``(a, b)``, pre-shift.

    ``masks[..., r]`` must broadcast against ``a_codes``/``b_codes`` (two's
    complement input codes).  Returns a list of ``spec.rows`` arrays; the full
    product is ``sum_r vals[r] << 2r`` (mul) / ``vals[0]`` (add).  Row values
    fit int32 at every supported width, which is what makes this the streaming
    payload for 16-bit multipliers.
    """
    n, w, cpr = spec.n_bits, spec.width, spec.cols_removable
    half = spec.n_inputs // 2
    modw = (1 << w) - 1
    a = a_codes.astype(dtype)
    b = b_codes.astype(dtype)
    if spec.signed:
        a_s = xp.where(a >= half, a - 2 * half, a)
        b_s = xp.where(b >= half, b - 2 * half, b)
    else:  # unsigned codes ARE the values; chain outputs read unsigned too
        a_s, b_s = a, b
    if spec.op == "add":
        return [
            _chain_eval(a_s & modw, b_s & modw, masks[..., 0], w, cpr, xp,
                        dtype, signed_out=spec.signed)
        ]
    vals = []
    for r in range(spec.rows):
        top = spec.signed and r == spec.rows - 1
        a0 = (a >> (2 * r)) & 1
        a1 = (a >> (2 * r + 1)) & 1
        t1 = xp.where(a0 == 1, b_s & modw, 0)
        bx = -b_s if top else b_s
        t2 = xp.where(a1 == 1, (bx << 1) & modw, 0)
        vals.append(_chain_eval(t1, t2, masks[..., r], w, cpr, xp, dtype,
                                signed_out=spec.signed))
    return vals


def _entry_product(spec: OperatorSpec, masks, a_codes, b_codes, xp, dtype):
    """Full approximate product/sum from per-row masks (``xp``-generic)."""
    vals = _entry_row_values(spec, masks, a_codes, b_codes, xp, dtype)
    total = vals[0]
    for r in range(1, spec.rows):
        total = total + (vals[r] << (2 * r))
    return total


def entry_product(spec: OperatorSpec, masks, a_codes, b_codes) -> np.ndarray:
    """Numpy oracle of the table-free entry function (int64, exact any width).

    ``masks``: (..., R) per-row masks; ``a_codes``/``b_codes``: two's-complement
    input codes broadcasting against ``masks[..., r]``.
    """
    return _entry_product(
        spec,
        np.asarray(masks, dtype=np.int64),
        np.asarray(a_codes, dtype=np.int64),
        np.asarray(b_codes, dtype=np.int64),
        np,
        np.int64,
    )


def entry_row_values(spec: OperatorSpec, masks, a_codes, b_codes) -> np.ndarray:
    """Numpy twin of the streamed per-row payload: (..., R) int64 row values."""
    vals = _entry_row_values(
        spec,
        np.asarray(masks, dtype=np.int64),
        np.asarray(a_codes, dtype=np.int64),
        np.asarray(b_codes, dtype=np.int64),
        np,
        np.int64,
    )
    return np.stack(np.broadcast_arrays(*vals), axis=-1)


def _synth_small(spec: OperatorSpec, masks, xp, dtype):
    """Per-row small tables synthesized from masks: list of (..., 4, B) arrays.

    ``small[r][..., p, b] `` is row ``r``'s value for multiplier-bit pair
    ``p = 2*a0 + a1`` and operand code ``b`` -- the same ``(4, B)`` layout the
    table-build path gathers out of ``RowTables``, but computed from the
    ``(..., R)`` masks by ``R * 4`` carry-chain evaluations over the B axis
    (``R*4*B*W`` lane-ops total, vs materializing/gathering a
    ``(2, 4, B, 2^(N+1))`` HBM table).  mul only.
    """
    if spec.op != "mul" or not spec.signed:
        raise ValueError(
            f"_synth_small covers the signed multiplier only, got {spec.tag}"
        )
    w, cpr = spec.width, spec.cols_removable
    n_in = spec.n_inputs
    modw = (1 << w) - 1
    b_s = xp.arange(n_in, dtype=dtype)
    b_s = xp.where(b_s >= n_in // 2, b_s - n_in, b_s)
    smalls = []
    for r in range(spec.rows):
        top = r == spec.rows - 1
        bx = -b_s if top else b_s
        mask_r = masks[..., r][..., None]  # broadcast over the B axis
        planes = []
        for p in range(4):
            a0, a1 = (p >> 1) & 1, p & 1
            t1 = (b_s & modw) if a0 else xp.zeros_like(b_s)
            t2 = ((bx << 1) & modw) if a1 else xp.zeros_like(b_s)
            planes.append(_chain_eval(t1, t2, mask_r, w, cpr, xp, dtype))
        smalls.append(xp.stack(planes, axis=-2))  # (..., 4, B)
    return smalls


# ---------------------------------------------------------------------------
# Product / error tables
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def exact_product_table(n_bits: int) -> np.ndarray:
    """(2^N, 2^N) int32 exact signed products, indexed by two's-complement codes."""
    spec = spec_for(n_bits)
    v = spec.operand_values
    return np.multiply.outer(v, v).astype(np.int32)


@functools.lru_cache(maxsize=None)
def exact_table(spec: OperatorSpec) -> np.ndarray:
    """(2^N, 2^N) int64 exact results of ``spec.op``, two's-complement indexed."""
    v = spec.operand_values
    if spec.op == "add":
        return np.add.outer(v, v).astype(np.int64)
    return np.multiply.outer(v, v).astype(np.int64)


def product_tables(spec: OperatorSpec, configs: np.ndarray) -> np.ndarray:
    """Approximate product tables for a batch of configs.

    Args:
      configs: (D, L) {0,1} array.
    Returns:
      (D, 2^N, 2^N) int32; axis 1 indexes operand A's two's-complement code,
      axis 2 operand B's.
    """
    configs = np.atleast_2d(np.asarray(configs))
    if spec.op == "add" or not spec.signed:
        # adders and unsigned families synthesize entries directly (the
        # precomputed RowTables are the signed multiplier's fast path)
        masks = config_to_masks(spec, configs)            # (D, R)
        codes = np.arange(spec.n_inputs, dtype=np.int64)
        return entry_product(
            spec, masks[:, None, None, :], codes[:, None], codes[None, :]
        ).astype(np.int32)
    tabs = row_tables(spec.n_bits)
    masks = config_to_masks(spec, configs)  # (D, R)
    n_in = spec.n_inputs

    a_codes = np.arange(n_in, dtype=np.int64)

    d = configs.shape[0]
    out = np.zeros((d, n_in, n_in), dtype=np.int32)
    for r in range(spec.rows):
        top = 1 if r == spec.rows - 1 else 0
        # (a0, a1) takes only 4 values: gather the small (4, B, D) slab first,
        # then expand over the A axis -- ~65x fewer large-table gathers.
        # reshape(4, ...) flattens (a0, a1) with a0 major -> index = 2*a0 + a1.
        pair_idx = ((((a_codes >> (2 * r)) & 1) << 1) | ((a_codes >> (2 * r + 1)) & 1))
        tab = tabs.value[top].reshape(4, n_in, spec.n_row_masks)  # (4, B, M)
        small = tab[:, :, masks[:, r]]                            # (4, B, D)
        small = np.ascontiguousarray(small.transpose(2, 0, 1))    # (D, 4, B)
        out += small[:, pair_idx, :] << (2 * r)                   # (D, A, B)
    return out


def error_tables(spec: OperatorSpec, configs: np.ndarray) -> np.ndarray:
    """approx - exact, (D, 2^N, 2^N) int32."""
    return (
        product_tables(spec, configs).astype(np.int64)
        - exact_table(spec)[None]
    ).astype(np.int32)


# ---------------------------------------------------------------------------
# Direct (slow) single-pair simulation -- independent oracle used by tests.
# ---------------------------------------------------------------------------


def simulate_product(spec: OperatorSpec, a: int, b: int, config: np.ndarray) -> int:
    """Bit-level simulation of one op, independent of the table machinery."""
    config = np.asarray(config).astype(np.int64)
    n, w = spec.n_bits, spec.width
    if spec.signed:
        half = 1 << (n - 1)
        if not (-half <= a < half and -half <= b < half):
            raise ValueError("operand out of range")
    else:
        if not (0 <= a < (1 << n) and 0 <= b < (1 << n)):
            raise ValueError("operand out of range")
    cpr = spec.cols_removable
    modw = (1 << w) - 1
    if spec.op == "add":
        s = 0
        c = 0
        t1, t2 = a & modw, b & modw
        for j in range(w):
            t1j = (t1 >> j) & 1
            t2j = (t2 >> j) & 1
            p = t1j ^ t2j
            g = t1j & t2j
            sj = p ^ c
            c_next = c if p else g
            if j < cpr and config[j] == 0:
                sj = 0
                c_next = 0
            s |= sj << j
            c = c_next
        if spec.signed and s & (1 << (w - 1)):
            s -= 1 << w
        return int(s)
    total = 0
    for r in range(spec.rows):
        top = spec.signed and r == spec.rows - 1
        a0 = (a >> (2 * r)) & 1
        a1 = (a >> (2 * r + 1)) & 1
        t1 = (b & modw) if a0 else 0
        bx = -b if top else b
        t2 = ((bx << 1) & modw) if a1 else 0
        s = 0
        c = 0
        for j in range(w):
            t1j = (t1 >> j) & 1
            t2j = (t2 >> j) & 1
            p = t1j ^ t2j
            g = t1j & t2j
            sj = p ^ c
            c_next = c if p else g
            if j < cpr and config[r * cpr + j] == 0:
                sj = 0
                c_next = 0
            s |= sj << j
            c = c_next
        if spec.signed and s & (1 << (w - 1)):
            s -= 1 << w
        total += s << (2 * r)
    return int(total)
