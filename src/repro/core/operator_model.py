"""LUT-level behavioral model of FPGA-style signed multipliers (AppAxO operator model).

The operator model follows AxOMaP / AppAxO: an approximate operator is an ordered
binary tuple ``O_i(l_0 .. l_{L-1})`` where ``l_k = 1`` keeps LUT ``k`` of the accurate
implementation and ``l_k = 0`` removes it.  Removing a LUT zeroes its sum output AND
truncates the carry out of the associated carry-chain cell (paper Fig. 3 semantics).

Architecture (row-paired partial products, matching the published removable-LUT
counts: signed 4x4 -> L=10, signed 8x8 -> L=36):

  * ``R = N/2`` rows.  Row ``r`` covers multiplier bits ``a_{2r}, a_{2r+1}``.
  * Row value ``V_r = coeff_r * B`` with ``coeff_r = a_{2r} + 2*a_{2r+1}`` for
    ``r < R-1`` and ``coeff_r = a_{2r} - 2*a_{2r+1}`` for the top (sign) row, so that
    ``sum_r 4^r V_r = A * B`` exactly for two's-complement ``A``.
  * Each row is computed as a ``W = N+2`` bit carry-chain addition of the two partial
    products ``T1 = a_{2r} ? B : 0`` and ``T2 = a_{2r+1} ? (+/-B << 1) : 0`` using one
    LUT + carry cell per column (propagate/generate + MUXCY semantics).
  * Columns ``0 .. N`` of every row (``N+1`` per row) are REMOVABLE; the top column
    ``W-1`` (sign handling) and the row-merge adder tree are always accurate.
    ``L = R * (N+1)``:  4x4 -> 2*5 = 10,  8x8 -> 4*9 = 36.

Removal of column ``j`` in a row forces ``sum_j = 0`` and ``carry_{j+1} = 0``.

Everything is vectorized through a precomputed "row table" over
``(top?, a0, a1, B, row_mask)`` so that characterizing thousands of configs over all
``2^{2N}`` input pairs is a handful of numpy gathers.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "OperatorSpec",
    "spec_for",
    "RowTables",
    "row_tables",
    "config_to_masks",
    "masks_to_config",
    "accurate_config",
    "product_tables",
    "exact_product_table",
    "error_tables",
    "simulate_product",
]


@dataclass(frozen=True)
class OperatorSpec:
    """Static description of one signed multiplier operator family."""

    n_bits: int                       # operand width N (signed)
    rows: int = field(init=False)     # number of partial-product rows R = N/2
    width: int = field(init=False)    # per-row adder width W = N + 2
    cols_removable: int = field(init=False)  # removable columns per row = N + 1
    n_luts: int = field(init=False)   # total removable LUTs L = R * (N+1)

    def __post_init__(self) -> None:
        if self.n_bits % 2 != 0 or self.n_bits < 2:
            raise ValueError(f"n_bits must be even and >= 2, got {self.n_bits}")
        object.__setattr__(self, "rows", self.n_bits // 2)
        object.__setattr__(self, "width", self.n_bits + 2)
        object.__setattr__(self, "cols_removable", self.n_bits + 1)
        object.__setattr__(self, "n_luts", self.rows * (self.n_bits + 1))

    @property
    def n_inputs(self) -> int:
        """Number of distinct values of one signed operand."""
        return 1 << self.n_bits

    @property
    def operand_values(self) -> np.ndarray:
        """All signed operand values in index order 0 .. 2^N-1 (two's complement)."""
        u = np.arange(self.n_inputs, dtype=np.int64)
        return np.where(u >= self.n_inputs // 2, u - self.n_inputs, u)

    @property
    def n_row_masks(self) -> int:
        return 1 << self.cols_removable


@functools.lru_cache(maxsize=None)
def spec_for(n_bits: int) -> OperatorSpec:
    return OperatorSpec(n_bits)


# ---------------------------------------------------------------------------
# Row tables
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RowTables:
    """Precomputed per-row behavior, indexed ``[top, a0, a1, b_idx, mask]``.

    value:   signed row output (int32) after carry-truncated addition.
    sum_p1:  P(sum bit j == 1) per column, indexed ``[top, mask, j]`` under uniform
             (a0, a1, B) -- used by the switching-activity power model.
    out_p1:  P(output bit j == 1) of the (two's complement, width-16) row value,
             indexed ``[top, mask, j]`` -- drives the merge-adder activity model.
    """

    spec: OperatorSpec
    value: np.ndarray      # (2, 2, 2, 2^N, 2^(N+1)) int32
    sum_p1: np.ndarray     # (2, 2^(N+1), W) float64
    out_p1: np.ndarray     # (2, 2^(N+1), 16) float64


def _row_values(spec: OperatorSpec) -> np.ndarray:
    """Exhaustive carry-chain evaluation of one row for every mask.

    Returns int32 array of shape (2[top], 2[a0], 2[a1], 2^N[b], 2^(N+1)[mask]).
    """
    n, w = spec.n_bits, spec.width
    n_b = spec.n_inputs
    n_mask = spec.n_row_masks

    b = spec.operand_values.astype(np.int64)  # (n_b,) signed values

    top = np.arange(2).reshape(2, 1, 1, 1, 1)
    a0 = np.arange(2).reshape(1, 2, 1, 1, 1)
    a1 = np.arange(2).reshape(1, 1, 2, 1, 1)
    bv = b.reshape(1, 1, 1, n_b, 1)
    mask = np.arange(n_mask, dtype=np.int64).reshape(1, 1, 1, 1, n_mask)

    modw = (1 << w) - 1
    t1 = np.where(a0 == 1, bv & modw, 0)
    bx = np.where(top == 1, -bv, bv)
    t2 = np.where(a1 == 1, (bx << 1) & modw, 0)

    s = np.zeros(np.broadcast_shapes(t1.shape, t2.shape, mask.shape), dtype=np.int64)
    c = np.zeros_like(s)
    for j in range(w):
        t1j = (t1 >> j) & 1
        t2j = (t2 >> j) & 1
        p = t1j ^ t2j
        g = t1j & t2j
        sj = p ^ c
        c_next = np.where(p == 1, c, g)
        if j < spec.cols_removable:
            kept = (mask >> j) & 1
            sj = sj * kept
            c_next = c_next * kept
        s = s | (sj << j)
        c = c_next

    # Interpret W-bit two's complement.
    sign = 1 << (w - 1)
    val = np.where(s & sign != 0, s - (1 << w), s)
    return val.astype(np.int32)


@functools.lru_cache(maxsize=None)
def row_tables(n_bits: int) -> RowTables:
    spec = spec_for(n_bits)
    value = _row_values(spec)  # (2,2,2,n_b,n_mask)
    w = spec.width
    n_mask = spec.n_row_masks

    # --- per-column sum-bit statistics (for the power model) ------------------
    # Reconstruct W-bit unsigned pattern of the row output.
    u = value.astype(np.int64) & ((1 << w) - 1)
    sum_p1 = np.empty((2, n_mask, w), dtype=np.float64)
    out_p1 = np.empty((2, n_mask, 16), dtype=np.float64)
    u16 = value.astype(np.int64) & 0xFFFF
    for t in range(2):
        # average over a0, a1, b -> (n_mask,)
        for j in range(w):
            bits = (u[t] >> j) & 1
            sum_p1[t, :, j] = bits.mean(axis=(0, 1, 2))
        for j in range(16):
            bits = (u16[t] >> j) & 1
            out_p1[t, :, j] = bits.mean(axis=(0, 1, 2))

    return RowTables(spec=spec, value=value, sum_p1=sum_p1, out_p1=out_p1)


# ---------------------------------------------------------------------------
# Config <-> per-row masks
# ---------------------------------------------------------------------------


def config_to_masks(spec: OperatorSpec, configs: np.ndarray) -> np.ndarray:
    """(..., L) {0,1} array -> (..., R) integer per-row masks."""
    configs = np.asarray(configs)
    if configs.shape[-1] != spec.n_luts:
        raise ValueError(f"config length {configs.shape[-1]} != L={spec.n_luts}")
    cpr = spec.cols_removable
    out = np.zeros(configs.shape[:-1] + (spec.rows,), dtype=np.int64)
    for r in range(spec.rows):
        for j in range(cpr):
            out[..., r] |= configs[..., r * cpr + j].astype(np.int64) << j
    return out


def masks_to_config(spec: OperatorSpec, masks: np.ndarray) -> np.ndarray:
    """(..., R) int masks -> (..., L) {0,1} uint8 config."""
    masks = np.asarray(masks, dtype=np.int64)
    cpr = spec.cols_removable
    out = np.zeros(masks.shape[:-1] + (spec.n_luts,), dtype=np.uint8)
    for r in range(spec.rows):
        for j in range(cpr):
            out[..., r * cpr + j] = (masks[..., r] >> j) & 1
    return out


def accurate_config(spec: OperatorSpec) -> np.ndarray:
    return np.ones(spec.n_luts, dtype=np.uint8)


# ---------------------------------------------------------------------------
# Product / error tables
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def exact_product_table(n_bits: int) -> np.ndarray:
    """(2^N, 2^N) int32 exact signed products, indexed by two's-complement codes."""
    spec = spec_for(n_bits)
    v = spec.operand_values
    return np.multiply.outer(v, v).astype(np.int32)


def product_tables(spec: OperatorSpec, configs: np.ndarray) -> np.ndarray:
    """Approximate product tables for a batch of configs.

    Args:
      configs: (D, L) {0,1} array.
    Returns:
      (D, 2^N, 2^N) int32; axis 1 indexes operand A's two's-complement code,
      axis 2 operand B's.
    """
    configs = np.atleast_2d(np.asarray(configs))
    tabs = row_tables(spec.n_bits)
    masks = config_to_masks(spec, configs)  # (D, R)
    n_in = spec.n_inputs

    a_codes = np.arange(n_in, dtype=np.int64)

    d = configs.shape[0]
    out = np.zeros((d, n_in, n_in), dtype=np.int32)
    for r in range(spec.rows):
        top = 1 if r == spec.rows - 1 else 0
        # (a0, a1) takes only 4 values: gather the small (4, B, D) slab first,
        # then expand over the A axis -- ~65x fewer large-table gathers.
        # reshape(4, ...) flattens (a0, a1) with a0 major -> index = 2*a0 + a1.
        pair_idx = ((((a_codes >> (2 * r)) & 1) << 1) | ((a_codes >> (2 * r + 1)) & 1))
        tab = tabs.value[top].reshape(4, n_in, spec.n_row_masks)  # (4, B, M)
        small = tab[:, :, masks[:, r]]                            # (4, B, D)
        small = np.ascontiguousarray(small.transpose(2, 0, 1))    # (D, 4, B)
        out += small[:, pair_idx, :] << (2 * r)                   # (D, A, B)
    return out


def error_tables(spec: OperatorSpec, configs: np.ndarray) -> np.ndarray:
    """approx - exact, (D, 2^N, 2^N) int32."""
    return (
        product_tables(spec, configs).astype(np.int64)
        - exact_product_table(spec.n_bits)[None].astype(np.int64)
    ).astype(np.int32)


# ---------------------------------------------------------------------------
# Direct (slow) single-pair simulation -- independent oracle used by tests.
# ---------------------------------------------------------------------------


def simulate_product(spec: OperatorSpec, a: int, b: int, config: np.ndarray) -> int:
    """Bit-level simulation of one multiply, independent of the table machinery."""
    config = np.asarray(config).astype(np.int64)
    n, w = spec.n_bits, spec.width
    half = 1 << (n - 1)
    if not (-half <= a < half and -half <= b < half):
        raise ValueError("operand out of range")
    cpr = spec.cols_removable
    modw = (1 << w) - 1
    total = 0
    for r in range(spec.rows):
        top = r == spec.rows - 1
        a0 = (a >> (2 * r)) & 1
        a1 = (a >> (2 * r + 1)) & 1
        t1 = (b & modw) if a0 else 0
        bx = -b if top else b
        t2 = ((bx << 1) & modw) if a1 else 0
        s = 0
        c = 0
        for j in range(w):
            t1j = (t1 >> j) & 1
            t2j = (t2 >> j) & 1
            p = t1j ^ t2j
            g = t1j & t2j
            sj = p ^ c
            c_next = c if p else g
            if j < cpr and config[r * cpr + j] == 0:
                sj = 0
                c_next = 0
            s |= sj << j
            c = c_next
        if s & (1 << (w - 1)):
            s -= 1 << w
        total += s << (2 * r)
    return int(total)
