"""Polynomial regression over LUT-usage bits (AxOMaP §4.2, Figs. 2/10).

A PR model over binary decision variables ``l_i`` is

    M(l) = c0 + sum_i c_i l_i + sum_{(i,j) in Q} c_ij l_i l_j

where the quadratic pair set ``Q`` is chosen by multivariate-correlation ranking
(``correlation.rank_quadratic_terms``).  Targets are MinMax-scaled before fitting
(paper Fig. 10 caption); coefficients are kept in scaled space -- the MaP problems
of ``miqcp.py`` consume them directly, and predictions can be inverted.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["MinMaxScaler", "PolyRegModel", "fit_poly", "r2_score", "mae", "mse"]


def r2_score(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    y_true = np.asarray(y_true, dtype=np.float64)
    y_pred = np.asarray(y_pred, dtype=np.float64)
    ss_res = ((y_true - y_pred) ** 2).sum()
    ss_tot = ((y_true - y_true.mean()) ** 2).sum()
    if ss_tot <= 0:
        return 1.0 if ss_res <= 0 else 0.0
    return float(1.0 - ss_res / ss_tot)


def mae(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    return float(np.abs(np.asarray(y_true) - np.asarray(y_pred)).mean())


def mse(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    return float(((np.asarray(y_true) - np.asarray(y_pred)) ** 2).mean())


@dataclass
class MinMaxScaler:
    lo: float = 0.0
    hi: float = 1.0

    @staticmethod
    def fit(y: np.ndarray) -> "MinMaxScaler":
        lo = float(np.min(y))
        hi = float(np.max(y))
        if hi <= lo:
            hi = lo + 1.0
        return MinMaxScaler(lo, hi)

    def transform(self, y: np.ndarray) -> np.ndarray:
        return (np.asarray(y, dtype=np.float64) - self.lo) / (self.hi - self.lo)

    def inverse(self, y: np.ndarray) -> np.ndarray:
        return np.asarray(y, dtype=np.float64) * (self.hi - self.lo) + self.lo


def _design_matrix(X: np.ndarray, quad_pairs: list[tuple[int, int]]) -> np.ndarray:
    X = np.asarray(X, dtype=np.float64)
    cols = [np.ones((X.shape[0], 1)), X]
    if quad_pairs:
        qi = np.array([p[0] for p in quad_pairs])
        qj = np.array([p[1] for p in quad_pairs])
        cols.append(X[:, qi] * X[:, qj])
    return np.concatenate(cols, axis=1)


@dataclass
class PolyRegModel:
    """Fitted polynomial-regression model in MinMax-scaled target space."""

    n_features: int
    quad_pairs: list[tuple[int, int]]
    intercept: float
    linear: np.ndarray                 # (L,)
    quad: np.ndarray                   # (len(quad_pairs),)
    scaler: MinMaxScaler = field(default_factory=MinMaxScaler)

    def predict_scaled(self, X: np.ndarray) -> np.ndarray:
        A = _design_matrix(X, self.quad_pairs)
        w = np.concatenate([[self.intercept], self.linear, self.quad])
        return A @ w

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self.scaler.inverse(self.predict_scaled(X))

    def map_terms(self) -> tuple[float, np.ndarray, list[tuple[int, int, float]]]:
        """(const, linear (L,), [(i, j, coef)]) in scaled space, for MaP building."""
        quads = [
            (i, j, float(c)) for (i, j), c in zip(self.quad_pairs, self.quad)
        ]
        return float(self.intercept), self.linear.copy(), quads


def fit_poly(
    X: np.ndarray,
    y: np.ndarray,
    quad_pairs: list[tuple[int, int]] | None = None,
    alpha: float = 1e-6,
    scale_y: bool = True,
) -> PolyRegModel:
    """Ridge-regularized least squares on [1, l, l_i l_j] features."""
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    quad_pairs = list(quad_pairs or [])
    scaler = MinMaxScaler.fit(y) if scale_y else MinMaxScaler(0.0, 1.0)
    ys = scaler.transform(y)

    A = _design_matrix(X, quad_pairs)
    n_col = A.shape[1]
    reg = alpha * np.eye(n_col)
    reg[0, 0] = 0.0  # do not penalize the intercept
    w = np.linalg.solve(A.T @ A + reg, A.T @ ys)

    L = X.shape[1]
    return PolyRegModel(
        n_features=L,
        quad_pairs=quad_pairs,
        intercept=float(w[0]),
        linear=w[1 : 1 + L],
        quad=w[1 + L :],
        scaler=scaler,
    )
