"""MILP / MIQCP formulation and solvers (AxOMaP §4.2-4.3.1).

A MaP problem over binary LUT variables ``l`` (paper Eqs. 3-8):

    minimize    wt_B * v_behav + (1 - wt_B) * v_ppa
    subject to  v_behav <= max_behav,   v_ppa <= max_ppa,   l_i in {0, 1}

where ``v_ppa``/``v_behav`` are polynomial-regression expressions (linear -> MILP;
with correlation-ranked quadratic terms -> MIQCP), and the bounds come from
``const_sf`` scaling of the training-set maxima (Eq. 8).

The paper uses a commercial MIQCP solver; none is available offline, so three
solvers with the same contract (best feasible point + a pool of good feasible
points -- the paper consumes solution *pools*, not certified optima):

  * ``solve_enumerate`` -- exact, fully vectorized, for L <= 22 (covers the 4x4
    operator's 2^10 space exhaustively).
  * ``solve_bnb``       -- depth-first branch-and-bound with partial-fix bounds;
    exact on MILP given budget, anytime otherwise.
  * ``solve_tabu``      -- multi-start steepest-descent tabu search with adaptive
    constraint penalties, for the 8x8 operator's L = 36 MIQCPs.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .engine import as_context
from .regression import PolyRegModel

__all__ = [
    "QuadExpr",
    "MapProblem",
    "build_problems",
    "solve",
    "solve_enumerate",
    "solve_bnb",
    "solve_tabu",
    "solve_tabu_multi",
    "solve_pool",
]


@dataclass
class QuadExpr:
    """c + b.l + l'Q l  with Q upper-triangular (i < j) plus diagonal folded into b."""

    const: float
    lin: np.ndarray                   # (L,)
    quad: np.ndarray                  # (L, L) upper-triangular, zero diagonal

    @staticmethod
    def from_model(model: PolyRegModel) -> "QuadExpr":
        L = model.n_features
        const, lin, quads = model.map_terms()
        lin = lin.astype(np.float64).copy()
        Q = np.zeros((L, L))
        for i, j, c in quads:
            if i == j:
                # l_i^2 == l_i for binaries (paper notes this folding)
                lin[i] += c
            else:
                a, b = min(i, j), max(i, j)
                Q[a, b] += c
        return QuadExpr(const=float(const), lin=lin, quad=Q)

    @property
    def n(self) -> int:
        return self.lin.shape[0]

    def value(self, l: np.ndarray) -> np.ndarray:
        """Evaluate on (..., L) binary array."""
        l = np.asarray(l, dtype=np.float64)
        lin_term = l @ self.lin
        quad_term = np.einsum("...i,ij,...j->...", l, self.quad, l)
        return self.const + lin_term + quad_term

    def flip_deltas(self, l: np.ndarray) -> np.ndarray:
        """Change in value for flipping each bit of a single config l (L,)."""
        l = np.asarray(l, dtype=np.float64)
        sym = self.quad + self.quad.T
        grad = self.lin + sym @ l
        return (1.0 - 2.0 * l) * grad

    def lower_bound_free(self, fixed_mask: np.ndarray, fixed_val: np.ndarray) -> float:
        """Cheap lower bound with some variables fixed (for branch and bound)."""
        l0 = np.where(fixed_mask, fixed_val, 0.0)
        base = self.value(l0)
        sym = self.quad + self.quad.T
        # Contribution of each free variable if set to 1, taking only negative
        # interactions with other FREE variables (optimistic).
        free = ~fixed_mask
        inter_fixed = sym @ l0
        neg_free_inter = np.where(free[None, :], np.minimum(sym, 0.0), 0.0).sum(axis=1)
        gain = self.lin + inter_fixed + neg_free_inter
        return float(base + np.minimum(gain, 0.0)[free].sum())


@dataclass
class MapProblem:
    """One scalarized, constrained MaP instance."""

    obj: QuadExpr
    behav: QuadExpr
    ppa: QuadExpr
    max_behav: float
    max_ppa: float
    wt_b: float
    const_sf: float
    n_quad: int
    meta: dict = field(default_factory=dict)

    @property
    def n(self) -> int:
        return self.obj.n

    def feasible(self, l: np.ndarray) -> np.ndarray:
        return (self.behav.value(l) <= self.max_behav + 1e-9) & (
            self.ppa.value(l) <= self.max_ppa + 1e-9
        )

    def violation(self, l: np.ndarray) -> np.ndarray:
        vb = np.maximum(0.0, self.behav.value(l) - self.max_behav)
        vp = np.maximum(0.0, self.ppa.value(l) - self.max_ppa)
        return vb / max(abs(self.max_behav), 1e-9) + vp / max(abs(self.max_ppa), 1e-9)


def build_problems(
    behav_model: PolyRegModel,
    ppa_model: PolyRegModel,
    behav_max: float,
    ppa_max: float,
    const_sf: float,
    wt_grid: np.ndarray | None = None,
    n_quad: int | None = None,
) -> list[MapProblem]:
    """The paper's wt_B sweep (0 -> 1 step 0.05) for one (const_sf, #quad) setting.

    ``behav_max`` / ``ppa_max`` are in *original* units; they are mapped through the
    models' MinMax scalers since expressions live in scaled space (Eq. 8).
    """
    if wt_grid is None:
        wt_grid = np.arange(0.0, 1.0001, 0.05)
    b_expr = QuadExpr.from_model(behav_model)
    p_expr = QuadExpr.from_model(ppa_model)
    maxb = behav_model.scaler.transform(np.array([const_sf * behav_max]))[0]
    maxp = ppa_model.scaler.transform(np.array([const_sf * ppa_max]))[0]
    problems = []
    for wt in wt_grid:
        obj = QuadExpr(
            const=wt * b_expr.const + (1 - wt) * p_expr.const,
            lin=wt * b_expr.lin + (1 - wt) * p_expr.lin,
            quad=wt * b_expr.quad + (1 - wt) * p_expr.quad,
        )
        problems.append(
            MapProblem(
                obj=obj,
                behav=b_expr,
                ppa=p_expr,
                max_behav=float(maxb),
                max_ppa=float(maxp),
                wt_b=float(wt),
                const_sf=float(const_sf),
                n_quad=int(n_quad if n_quad is not None else len(behav_model.quad_pairs)),
            )
        )
    return problems


# ---------------------------------------------------------------------------
# Solvers
# ---------------------------------------------------------------------------


@dataclass
class SolveResult:
    best: np.ndarray | None           # (L,) uint8 or None if infeasible
    best_obj: float
    pool: np.ndarray                  # (P, L) uint8 feasible pool (may be empty)
    solver: str


def _all_configs(L: int) -> np.ndarray:
    codes = np.arange(1 << L, dtype=np.uint64)
    out = np.zeros((codes.size, L), dtype=np.uint8)
    for j in range(L):
        out[:, j] = (codes >> np.uint64(j)) & np.uint64(1)
    return out


def solve_enumerate(
    problem: MapProblem, pool_size: int = 16, backend="numpy"
) -> SolveResult:
    """Exact vectorized enumeration; only for L <= 22.

    ``backend`` is a legacy string or an ``ExecutionContext``; under the jax
    backend all 2^L configs (objective + both constraint expressions) are
    scored in one jit-compiled device dispatch
    (``fastchar.map_problem_values_jax``); selection stays on the host.  Values
    are float32 on that path, so near-ties may order differently than numpy.
    """
    use_jax = as_context(backend).is_jax
    L = problem.n
    if L > 22:
        raise ValueError(f"enumeration infeasible for L={L}")
    cfgs = _all_configs(L)
    if use_jax:
        from .fastchar import map_problem_values_jax  # lazy JAX import

        objs, vb, vp = map_problem_values_jax(problem, cfgs)
        feas = (vb <= problem.max_behav + 1e-9) & (vp <= problem.max_ppa + 1e-9)
    else:
        feas = problem.feasible(cfgs)
        objs = problem.obj.value(cfgs)
    if not feas.any():
        return SolveResult(None, np.inf, np.empty((0, L), dtype=np.uint8), "enum")
    objs = np.where(feas, objs, np.inf)
    order = np.argsort(objs)[: 2 * pool_size if use_jax else pool_size]
    order = order[np.isfinite(objs[order])]
    if use_jax:
        # f32 scoring can misclassify configs within ~1e-6 of a bound; the pool
        # contract is float64 feasibility, so re-validate the few selected and
        # report the float64 objective of the winner.
        order = order[problem.feasible(cfgs[order])][:pool_size]
        if order.size == 0:
            return SolveResult(None, np.inf, np.empty((0, L), dtype=np.uint8), "enum")
        best_obj = float(problem.obj.value(cfgs[order[0]]))
        return SolveResult(cfgs[order[0]], best_obj, cfgs[order], "enum")
    return SolveResult(cfgs[order[0]], float(objs[order[0]]), cfgs[order], "enum")


def _tabu_starts(problem: MapProblem, n_starts: int, seed: int) -> list[np.ndarray]:
    """The shared multi-start battery: all-ones, all-zeros, then seeded random."""
    L = problem.n
    rng = np.random.default_rng(seed)
    starts = [np.ones(L, dtype=np.float64), np.zeros(L, dtype=np.float64)]
    while len(starts) < n_starts:
        starts.append(rng.integers(0, 2, L).astype(np.float64))
    return starts


def _tabu_pool_result(
    pool: list[tuple[float, bytes]],
    best: np.ndarray | None,
    best_obj: float,
    pool_size: int,
    L: int,
) -> SolveResult:
    if best is None:
        return SolveResult(None, np.inf, np.empty((0, L), dtype=np.uint8), "tabu")
    seen: dict[bytes, float] = {}
    for obj, key in sorted(pool):
        if key not in seen:
            seen[key] = obj
        if len(seen) >= pool_size:
            break
    pool_arr = np.stack(
        [np.frombuffer(k, dtype=np.uint8) for k in seen]
    ) if seen else np.empty((0, L), dtype=np.uint8)
    return SolveResult(best, best_obj, pool_arr, "tabu")


def _solve_tabu_jax(
    problem: MapProblem,
    n_starts: int,
    n_iters: int,
    tabu_tenure: int,
    pool_size: int,
    seed: int,
) -> SolveResult:
    """Lockstep multi-start tabu: every start's full single-flip neighborhood
    scored per iteration in ONE device dispatch (``fastchar.
    tabu_neighbor_values_jax``, the same batched quadratic-form scorer that
    ``solve_enumerate(backend="jax")`` uses).

    Same starts, operators, penalties and stopping rules as the numpy path,
    but starts advance together instead of serially, so the shared aspiration
    threshold sees cross-start bests in *iteration* order rather than start
    order, and neighborhood scoring is f32 (feasibility/pool bookkeeping is
    re-validated in host float64, like the enumerate jax path).  The returned
    pool matches numpy's in feasibility and objective quality; membership can
    differ on near-ties.
    """
    from .fastchar import tabu_neighbor_values_jax  # lazy JAX import

    L = problem.n
    states = np.stack(_tabu_starts(problem, n_starts, seed))      # (S, L)
    S = len(states)
    step = tabu_neighbor_values_jax(problem)
    den_b = max(abs(problem.max_behav), 1e-9)
    den_p = max(abs(problem.max_ppa), 1e-9)

    rho = np.ones(S)
    tabu = np.zeros((S, L), dtype=np.int64)
    active = np.ones(S, dtype=bool)
    cur_pen = problem.obj.value(states) + rho * problem.violation(states)
    pool: list[tuple[float, bytes]] = []
    best, best_obj = None, np.inf

    for it in range(n_iters):
        if not active.any():
            break
        vals, deltas = step(states)
        obj_v, vb, vp = vals
        d_obj, d_b, d_p = deltas
        nb = np.maximum(0.0, vb[:, None] + d_b - problem.max_behav) / den_b
        np_ = np.maximum(0.0, vp[:, None] + d_p - problem.max_ppa) / den_p
        cand_pen = obj_v[:, None] + d_obj + rho[:, None] * (nb + np_)
        blocked = tabu > it
        asp = (cand_pen < best_obj) & (nb + np_ <= 0)
        score = np.where(blocked & ~asp, np.inf, cand_pen)
        k = np.argmin(score, axis=1)
        k_score = score[np.arange(S), k]
        active &= np.isfinite(k_score)
        rows = np.where(active)[0]
        if rows.size == 0:
            break
        move_gain = cur_pen - k_score
        states[rows, k[rows]] = 1.0 - states[rows, k[rows]]
        tabu[rows, k[rows]] = it + tabu_tenure
        cur_pen = np.where(active, k_score, cur_pen)

        # float64 bookkeeping of the moved states (feasibility, pool, best)
        viol_new = problem.violation(states[rows])
        obj_new = problem.obj.value(states[rows])
        for ri, v, o in zip(rows, viol_new, obj_new):
            if v <= 0:
                key = states[ri].astype(np.uint8).tobytes()
                pool.append((float(o), key))
                if o < best_obj:
                    best_obj, best = float(o), states[ri].astype(np.uint8).copy()
            else:
                rho[ri] *= 1.05
        brk = (move_gain[rows] <= 1e-12) & (it > 20) & (rho[rows] > 100)
        active[rows[brk]] = False

    return _tabu_pool_result(pool, best, best_obj, pool_size, L)


def solve_tabu_multi(
    problems: list[MapProblem],
    seeds,
    n_starts: int = 8,
    n_iters: int = 400,
    tabu_tenure: int = 7,
    pool_size: int = 16,
) -> list[SolveResult]:
    """Cross-problem lockstep tabu: one device dispatch per iteration scores
    EVERY problem's every start's full single-flip neighborhood.

    ``_solve_tabu_jax`` already locksteps the starts of one problem; a MaP
    battery (wt_B x n_quad x const_sf) still re-entered it once per problem,
    paying one small dispatch per (problem, iteration).  Here the whole
    battery advances as a single (problems x starts, L) batch through the
    vmapped scorer ``fastchar.tabu_neighbor_values_multi_jax``.  Problems are
    fully independent (per-problem penalties, aspiration thresholds, pools),
    so each problem's trajectory matches ``_solve_tabu_jax`` run alone, modulo
    f32 summation order inside the batched einsum.  ``seeds`` gives each
    problem its own start battery, matching ``solve_pool``'s ``seed + k``.
    """
    from .fastchar import tabu_neighbor_values_multi_jax  # lazy JAX import

    if not problems:
        return []
    L = problems[0].n
    if any(p.n != L for p in problems):
        raise ValueError("solve_tabu_multi requires a same-L problem battery")
    seeds = list(seeds)
    if len(seeds) != len(problems):
        raise ValueError(f"{len(problems)} problems but {len(seeds)} seeds")
    P = len(problems)
    states = np.stack(
        [np.stack(_tabu_starts(pb, n_starts, sd)) for pb, sd in zip(problems, seeds)]
    )  # (P, S, L)
    S = states.shape[1]
    step = tabu_neighbor_values_multi_jax(problems)
    max_b = np.array([pb.max_behav for pb in problems])[:, None, None]
    max_p = np.array([pb.max_ppa for pb in problems])[:, None, None]
    den_b = np.maximum(np.abs(max_b), 1e-9)
    den_p = np.maximum(np.abs(max_p), 1e-9)

    rho = np.ones((P, S))
    tabu = np.zeros((P, S, L), dtype=np.int64)
    active = np.ones((P, S), dtype=bool)
    cur_pen = np.stack(
        [pb.obj.value(states[p]) + rho[p] * pb.violation(states[p])
         for p, pb in enumerate(problems)]
    )
    pools: list[list[tuple[float, bytes]]] = [[] for _ in range(P)]
    bests: list[np.ndarray | None] = [None] * P
    best_obj = np.full(P, np.inf)

    for it in range(n_iters):
        if not active.any():
            break
        vals, deltas = step(states)                       # (P, 3, S), (P, 3, S, L)
        obj_v, vb, vp = vals[:, 0], vals[:, 1], vals[:, 2]
        d_obj, d_b, d_p = deltas[:, 0], deltas[:, 1], deltas[:, 2]
        nb = np.maximum(0.0, vb[:, :, None] + d_b - max_b) / den_b
        np_ = np.maximum(0.0, vp[:, :, None] + d_p - max_p) / den_p
        cand_pen = obj_v[:, :, None] + d_obj + rho[:, :, None] * (nb + np_)
        blocked = tabu > it
        asp = (cand_pen < best_obj[:, None, None]) & (nb + np_ <= 0)
        score = np.where(blocked & ~asp, np.inf, cand_pen)
        k = np.argmin(score, axis=2)                      # (P, S)
        k_score = np.take_along_axis(score, k[:, :, None], axis=2)[:, :, 0]
        active &= np.isfinite(k_score)
        pi, si = np.nonzero(active)
        if pi.size == 0:
            break
        move_gain = cur_pen - k_score
        states[pi, si, k[pi, si]] = 1.0 - states[pi, si, k[pi, si]]
        tabu[pi, si, k[pi, si]] = it + tabu_tenure
        cur_pen = np.where(active, k_score, cur_pen)

        # float64 bookkeeping of the moved states (feasibility, pool, best),
        # per problem in start order -- identical to the single-problem path
        for p in range(P):
            rows = si[pi == p]
            if rows.size == 0:
                continue
            pb = problems[p]
            viol_new = pb.violation(states[p, rows])
            obj_new = pb.obj.value(states[p, rows])
            for ri, v, o in zip(rows, viol_new, obj_new):
                if v <= 0:
                    key = states[p, ri].astype(np.uint8).tobytes()
                    pools[p].append((float(o), key))
                    if o < best_obj[p]:
                        best_obj[p] = float(o)
                        bests[p] = states[p, ri].astype(np.uint8).copy()
                else:
                    rho[p, ri] *= 1.05
        brk = (move_gain[pi, si] <= 1e-12) & (it > 20) & (rho[pi, si] > 100)
        active[pi[brk], si[brk]] = False

    return [
        _tabu_pool_result(pools[p], bests[p], best_obj[p], pool_size, L)
        for p in range(P)
    ]


def solve_tabu(
    problem: MapProblem,
    n_starts: int = 8,
    n_iters: int = 400,
    tabu_tenure: int = 7,
    pool_size: int = 16,
    seed: int = 0,
    backend="numpy",
) -> SolveResult:
    """Multi-start steepest-descent tabu search with adaptive constraint penalty.

    ``backend`` is a legacy string or an ``ExecutionContext``; the jax backend
    advances all starts in lockstep, scoring every start's single-flip
    neighborhood as one batched device dispatch per iteration (see
    ``_solve_tabu_jax``); ``"numpy"`` is the serial per-start oracle.
    """
    if as_context(backend).is_jax:
        return _solve_tabu_jax(
            problem, n_starts, n_iters, tabu_tenure, pool_size, seed
        )
    L = problem.n
    pool: list[tuple[float, bytes]] = []
    best, best_obj = None, np.inf

    for s_idx, l in enumerate(_tabu_starts(problem, n_starts, seed)):
        l = l.copy()
        rho = 1.0
        tabu = np.zeros(L, dtype=np.int64)
        cur_pen = problem.obj.value(l) + rho * problem.violation(l)
        for it in range(n_iters):
            d_obj = problem.obj.flip_deltas(l)
            # violation deltas require candidate evaluation; vectorize: build all
            # single-flip neighbors lazily through expression deltas.
            d_b = problem.behav.flip_deltas(l)
            d_p = problem.ppa.flip_deltas(l)
            vb = problem.behav.value(l)
            vp = problem.ppa.value(l)
            nb = np.maximum(0.0, vb + d_b - problem.max_behav) / max(abs(problem.max_behav), 1e-9)
            np_ = np.maximum(0.0, vp + d_p - problem.max_ppa) / max(abs(problem.max_ppa), 1e-9)
            cand_pen = problem.obj.value(l) + d_obj + rho * (nb + np_)
            blocked = tabu > it
            # aspiration: allow tabu move if it beats the global best and is feasible
            asp = (cand_pen < best_obj) & (nb + np_ <= 0)
            score = np.where(blocked & ~asp, np.inf, cand_pen)
            k = int(np.argmin(score))
            if not np.isfinite(score[k]):
                break
            move_gain = cur_pen - score[k]
            l[k] = 1.0 - l[k]
            tabu[k] = it + tabu_tenure
            cur_pen = score[k]
            if problem.violation(l[None])[0] <= 0:
                obj = float(problem.obj.value(l))
                key = l.astype(np.uint8).tobytes()
                pool.append((obj, key))
                if obj < best_obj:
                    best_obj, best = obj, l.astype(np.uint8).copy()
            else:
                rho *= 1.05  # infeasible: tighten the penalty
            if move_gain <= 1e-12 and it > 20 and rho > 100:
                break

    return _tabu_pool_result(pool, best, best_obj, pool_size, L)


def solve_bnb(
    problem: MapProblem,
    node_budget: int = 200_000,
    pool_size: int = 16,
) -> SolveResult:
    """Depth-first branch-and-bound; exact within budget, anytime beyond it."""
    L = problem.n
    # Branch variables in order of |objective influence| (largest first).
    sym = problem.obj.quad + problem.obj.quad.T
    influence = np.abs(problem.obj.lin) + np.abs(sym).sum(axis=1)
    order = np.argsort(-influence)

    best, best_obj = None, np.inf
    pool: list[tuple[float, bytes]] = []
    fixed_mask = np.zeros(L, dtype=bool)
    fixed_val = np.zeros(L, dtype=np.float64)
    nodes = 0

    def behav_lb(mask, val):
        return problem.behav.lower_bound_free(mask, val)

    def ppa_lb(mask, val):
        return problem.ppa.lower_bound_free(mask, val)

    def rec(depth: int):
        nonlocal nodes, best, best_obj
        nodes += 1
        if nodes > node_budget:
            return
        lb = problem.obj.lower_bound_free(fixed_mask, fixed_val)
        if lb >= best_obj - 1e-12:
            return
        if behav_lb(fixed_mask, fixed_val) > problem.max_behav + 1e-9:
            return
        if ppa_lb(fixed_mask, fixed_val) > problem.max_ppa + 1e-9:
            return
        if depth == L:
            l = fixed_val.copy()
            if problem.violation(l[None])[0] <= 0:
                obj = float(problem.obj.value(l))
                pool.append((obj, l.astype(np.uint8).tobytes()))
                if obj < best_obj:
                    best_obj, best = obj, l.astype(np.uint8).copy()
            return
        k = order[depth]
        fixed_mask[k] = True
        # Greedy child order: try the sign-suggested value first.
        sym_k = sym[k]
        first = 0.0 if (problem.obj.lin[k] + sym_k @ fixed_val) > 0 else 1.0
        for v in (first, 1.0 - first):
            fixed_val[k] = v
            rec(depth + 1)
        fixed_mask[k] = False
        fixed_val[k] = 0.0

    rec(0)
    if best is None:
        return SolveResult(None, np.inf, np.empty((0, L), dtype=np.uint8), "bnb")
    seen = {}
    for obj, key in sorted(pool):
        if key not in seen:
            seen[key] = obj
        if len(seen) >= pool_size:
            break
    pool_arr = np.stack([np.frombuffer(k, dtype=np.uint8) for k in seen])
    return SolveResult(best, best_obj, pool_arr, "bnb")


def solve(
    problem: MapProblem, seed: int = 0, pool_size: int = 16, backend="numpy"
) -> SolveResult:
    """Dispatch: exact enumeration when tractable, tabu otherwise."""
    if problem.n <= 16:
        return solve_enumerate(problem, pool_size=pool_size, backend=backend)
    return solve_tabu(problem, seed=seed, pool_size=pool_size, backend=backend)


def solve_pool(
    problems: list[MapProblem],
    seed: int = 0,
    pool_size: int = 8,
    backend="numpy",
) -> np.ndarray:
    """Union of solution pools over a problem list (dedup) -- the MaP config pool.

    Under a jax ``backend``/context on tabu-sized instances (L > 16) the whole
    battery is solved by :func:`solve_tabu_multi`: one lockstep
    (problems x starts, L) batch, one neighborhood dispatch per iteration for
    ALL problems, instead of re-entering the solver once per problem.
    """
    from ..obs import telemetry as obs

    ctx = as_context(backend)
    tel = ctx.tel
    same_l_tabu = (
        bool(problems)
        and problems[0].n > 16
        and all(p.n == problems[0].n for p in problems)
    )
    with tel.span("miqcp.solve_pool", n_problems=len(problems),
                  lockstep=bool(ctx.is_jax and same_l_tabu)):
        if ctx.is_jax and same_l_tabu:
            tel.count("dispatch.miqcp.tabu_multi")
            results = solve_tabu_multi(
                problems,
                seeds=[seed + k for k in range(len(problems))],
                pool_size=pool_size,
            )
        else:
            tel.count("dispatch.miqcp.solve", len(problems))
            results = [
                solve(prob, seed=seed + k, pool_size=pool_size, backend=ctx)
                for k, prob in enumerate(problems)
            ]
    configs = [res.pool for res in results if len(res.pool)]
    if not configs:
        return np.empty((0, problems[0].n if problems else 0), dtype=np.uint8)
    allc = np.concatenate(configs)
    _, idx = np.unique(allc, axis=0, return_index=True)
    return allc[np.sort(idx)]
