from .ckpt import CheckpointManager, restore_tree, save_tree

__all__ = ["CheckpointManager", "save_tree", "restore_tree"]
