"""Atomic, mesh-independent checkpointing with elastic restore.

Layout: one ``.npz`` per checkpoint step holding every leaf under its tree
path, plus a JSON manifest (step, leaf paths, dtypes, wall time).  Writes go to
``<name>.tmp`` and are ``os.replace``d -- a crash mid-write never corrupts the
latest checkpoint (atomic-rename durability).

Elastic restore: leaves are saved *unsharded* (host-gathered), so a checkpoint
written on one mesh restores onto any other -- restore takes target shardings
and ``jax.device_put``s each leaf accordingly.  On a multi-host deployment the
same layout is produced per-process for the process-local shards with a shared
manifest; that variant only changes the gather step, not the format.

``CheckpointManager`` adds retention, async save (background thread -- the
train loop never blocks on I/O), and ``latest_step`` discovery for restarts.
"""

from __future__ import annotations

import json
import os
import threading
import time

import jax
import numpy as np

__all__ = ["save_tree", "restore_tree", "CheckpointManager"]


def _flatten_with_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten_with_paths(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten_with_paths(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def _unflatten_like(template, values: dict, prefix=""):
    if isinstance(template, dict):
        return {
            k: _unflatten_like(template[k], values, f"{prefix}/{k}")
            for k in template
        }
    if isinstance(template, (list, tuple)):
        seq = [
            _unflatten_like(v, values, f"{prefix}/{i}") for i, v in enumerate(template)
        ]
        return type(template)(seq)
    return values[prefix]


_STD_KINDS = "biufc?"


def _encode_leaf(v: np.ndarray) -> tuple[np.ndarray, str]:
    """npz-compatible encoding: ml_dtypes (bf16, fp8, ...) as raw-byte views."""
    if v.dtype.kind in _STD_KINDS and v.dtype.name in np.sctypeDict:
        return v, ""
    raw = np.ascontiguousarray(v).view(np.uint8).reshape(v.shape + (v.dtype.itemsize,))
    return raw, v.dtype.name


def _decode_leaf(raw: np.ndarray, dtype_name: str) -> np.ndarray:
    if not dtype_name:
        return raw
    import ml_dtypes  # ships with jax

    dt = np.dtype(getattr(ml_dtypes, dtype_name, dtype_name))
    return raw.view(dt).reshape(raw.shape[:-1])


def save_tree(path: str, step: int, tree, extra: dict | None = None) -> None:
    """Atomic save of a pytree (+ manifest) to ``<path>/step_<step>.npz``."""
    os.makedirs(path, exist_ok=True)
    leaves = dict(_flatten_with_paths(tree))
    arrays = {}
    for k, v in leaves.items():
        enc, dtype_name = _encode_leaf(np.asarray(jax.device_get(v)))
        key = k.replace("/", "|")
        arrays[f"{dtype_name}::{key}" if dtype_name else key] = enc

    npz_tmp = os.path.join(path, f"step_{step:08d}.npz.tmp.npz")
    npz_final = os.path.join(path, f"step_{step:08d}.npz")
    np.savez(npz_tmp, **arrays)
    os.replace(npz_tmp, npz_final)

    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "time": time.time(),
        "extra": extra or {},
    }
    man_tmp = os.path.join(path, f"step_{step:08d}.json.tmp")
    man_final = os.path.join(path, f"step_{step:08d}.json")
    with open(man_tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(man_tmp, man_final)


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [
        int(f[len("step_"):-len(".json")])
        for f in os.listdir(path)
        if f.startswith("step_") and f.endswith(".json")
    ]
    return max(steps) if steps else None


def restore_tree(path: str, step: int, template, shardings=None, dtypes=None):
    """Restore into the structure of ``template``; optionally device_put with
    target shardings (elastic restore onto any mesh)."""
    npz = os.path.join(path, f"step_{step:08d}.npz")
    values = {}
    with np.load(npz) as z:
        for k in z.files:
            dtype_name, _, key = k.rpartition("::")
            values[key.replace("|", "/")] = _decode_leaf(z[k], dtype_name)
    tree = _unflatten_like(template, values)
    if dtypes is not None:
        tree = jax.tree.map(lambda v, d: v.astype(d), tree, dtypes)
    if shardings is not None:
        tree = jax.tree.map(lambda v, s: jax.device_put(v, s), tree, shardings)
    return tree


class CheckpointManager:
    """Retention + async save + restart discovery."""

    def __init__(self, path: str, keep: int = 3, async_save: bool = True):
        self.path = path
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(path, exist_ok=True)

    def latest_step(self) -> int | None:
        return latest_step(self.path)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()
        # Materialize on host *before* returning so the caller may mutate.
        host_tree = jax.tree.map(lambda v: np.asarray(jax.device_get(v)), tree)

        def work():
            save_tree(self.path, step, host_tree, extra)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, template, step: int | None = None, shardings=None, dtypes=None):
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        return restore_tree(self.path, step, template, shardings, dtypes), step

    def _gc(self) -> None:
        steps = sorted(
            int(f[len("step_"):-len(".json")])
            for f in os.listdir(self.path)
            if f.startswith("step_") and f.endswith(".json")
        )
        for s in steps[: -self.keep] if self.keep else []:
            for ext in (".npz", ".json"):
                try:
                    os.remove(os.path.join(self.path, f"step_{s:08d}{ext}"))
                except FileNotFoundError:
                    pass
