import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices back the production meshes; every step function is lowered from
ShapeDtypeStructs (no allocation), compiled through the full SPMD partitioner,
and its memory_analysis / cost_analysis / collective schedule are recorded for
§Dry-run and §Roofline of EXPERIMENTS.md.

``--probe`` additionally runs the loop-corrected cost probes (see costprobe.py)
-- XLA counts while bodies once, so scan-over-layers programs under-report
FLOPs without them.  The roofline table uses probe-corrected numbers.

Usage:
  python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--out DIR]
  python -m repro.launch.dryrun --list
"""

import argparse
import json
import sys
import time
import traceback

from ..configs.base import SHAPES
from ..configs.registry import (
    ARCH_IDS,
    arch_for_shape,
    cell_status,
    get_arch,
    rules_for,
)
from .accounting import param_counts
from .costprobe import corrected_costs, measure_compiled, probe_variants
from .lowering import lower_step
from .mesh import make_production_mesh
from .roofline import Roofline, model_flops


def run_cell(
    arch_id: str,
    shape_name: str,
    multi_pod: bool,
    verbose: bool = True,
    probe: bool = False,
):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    status = cell_status(arch_id, shape_name)
    if status != "run":
        return {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                "status": status}

    shape = SHAPES[shape_name]
    cfg = arch_for_shape(get_arch(arch_id), shape)
    rules = rules_for(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    t0 = time.time()
    lowered = lower_step(cfg, shape, mesh, rules)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    raw = measure_compiled(compiled)

    corrected = None
    t_probe = 0.0
    if probe:
        t0 = time.time()
        measures = {}
        for tag, pcfg in probe_variants(cfg).items():
            plow = lower_step(pcfg, shape, mesh, rules)
            measures[tag] = measure_compiled(plow.compile())
        corrected = corrected_costs(cfg, measures)
        t_probe = time.time() - t0

    counts = param_counts(cfg)
    n_active = counts["active_nonemb"] + counts["embedding"] // (
        2 if not cfg.tie_embeddings else 1
    )
    mfl = model_flops(cfg, shape, n_active, shape.kind)

    use = corrected if corrected is not None else raw
    rl = Roofline(
        arch=arch_id, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=use["flops"], hlo_bytes=use["bytes"],
        coll_bytes=use["coll_total"],
        coll_breakdown={k[5:]: v for k, v in use.items() if k.startswith("coll_")
                        and k != "coll_total"},
        model_flops=mfl,
    )

    if verbose:
        print(f"--- {arch_id} x {shape_name} x {mesh_name} ---")
        print(f"memory_analysis: {mem}")
        print("cost (raw):       flops=%.3e bytes=%.3e coll=%.3e" %
              (raw["flops"], raw["bytes"], raw["coll_total"]))
        if corrected:
            print("cost (corrected): flops=%.3e bytes=%.3e coll=%.3e" %
                  (corrected["flops"], corrected["bytes"], corrected["coll_total"]))
        print("roofline: t_comp=%.4fs t_mem=%.4fs t_coll=%.4fs -> %s" %
              (rl.t_compute, rl.t_memory, rl.t_collective, rl.bottleneck))

    rec = {"status": "ok", "t_lower_s": t_lower, "t_compile_s": t_compile,
           "t_probe_s": t_probe, "probe_corrected": bool(corrected)}
    rec.update(rl.row())
    rec["raw_flops"] = raw["flops"]
    rec["raw_bytes"] = raw["bytes"]
    rec["raw_coll_bytes"] = raw["coll_total"]
    rec["coll_breakdown"] = rl.coll_breakdown
    rec["params_total"] = counts["total"]
    rec["params_active"] = counts["active"]
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            rec[attr] = int(v)
    # "fits" check: args + temps minus donated aliases vs 16 GiB HBM of v5e
    hbm = 16 * 1024**3
    need = (rec.get("argument_size_in_bytes", 0) + rec.get("temp_size_in_bytes", 0)
            - rec.get("alias_size_in_bytes", 0))
    rec["hbm_need_bytes"] = need
    rec["fits_v5e_hbm"] = bool(need <= hbm)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="run loop-corrected cost probes (roofline-grade costs)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose JSON already records status=ok/skip")
    args = ap.parse_args(argv)

    cells = []
    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    if args.list:
        for a, s, m in cells:
            print(a, s, "2x16x16" if m else "16x16", cell_status(a, s))
        return 0

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for a, s, m in cells:
        mesh_name = "2x16x16" if m else "16x16"
        out_path = os.path.join(args.out, f"{a}__{s}__{mesh_name}.json")
        if args.skip_existing and os.path.exists(out_path):
            with open(out_path) as f:
                prev = json.load(f)
            st = str(prev.get("status", ""))
            if st == "ok" and (prev.get("probe_corrected") or not args.probe):
                print(f"[cached] {a} {s} {mesh_name}")
                continue
            if st.startswith("skip"):
                print(f"[cached-skip] {a} {s} {mesh_name}")
                continue
        try:
            rec = run_cell(a, s, m, verbose=not args.quiet, probe=args.probe)
        except Exception as exc:  # noqa: BLE001
            traceback.print_exc()
            rec = {"arch": a, "shape": s, "mesh": mesh_name,
                   "status": f"FAIL: {type(exc).__name__}: {exc}"}
            failures += 1
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
        print(f"[{rec.get('status', '?')}] {a} {s} {mesh_name}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
