"""Three-term roofline analysis from a compiled dry-run artifact.

    compute term    = HLO_FLOPs   / (chips x peak_FLOP/s)
    memory term     = HLO_bytes   / (chips x HBM_bw)
    collective term = coll_bytes  / (chips x link_bw)

``cost_analysis()`` supplies FLOPs/bytes of the (already partitioned,
per-device) program; collective bytes are NOT in cost_analysis, so they are
parsed from the optimized HLO text by summing operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["HW", "Roofline", "collective_bytes", "compiled_cost",
           "roofline_from_compiled", "model_flops"]


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    link_bw: float = 50e9             # bytes/s per ICI link


V5E = HW()

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "u1": 1, "s1": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Matches the op name right before its '(' -- plain or async '-start' form.
# '-done' ops are skipped (their operand is the in-flight handle, not data).
_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind operand bytes summed over the per-device program."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        rhs = s.split(" = ", 1)[1]
        m = _COLL_RE.search(rhs)
        if m is None or "-done" in rhs.split("(", 1)[0]:
            continue
        kind = m.group(1)
        # operand shapes appear inside the call parens in optimized HLO text;
        # fall back to the result shape when operands are untyped names.
        shapes = _SHAPE_RE.findall(rhs[m.end():])
        if not shapes:
            shapes = _SHAPE_RE.findall(rhs[: m.start()])
        out[kind] += sum(_shape_bytes(dt, dims) for dt, dims in shapes)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                   # per-device FLOPs from cost_analysis
    hlo_bytes: float                   # per-device bytes accessed
    coll_bytes: float                  # per-device collective operand bytes
    coll_breakdown: dict = field(default_factory=dict)
    bytes_per_device: float = 0.0      # peak memory from memory_analysis
    model_flops: float = 0.0           # 6*N*D useful flops (global)
    hw: HW = V5E

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.hw.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): how much compiled compute is useful."""
        tot = self.hlo_flops * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def mfu_bound(self) -> float:
        """Roofline-implied MFU upper bound: useful flops / (chips*peak*t_bound)."""
        denom = self.chips * self.hw.peak_flops * self.t_bound
        return self.model_flops / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "bottleneck": self.bottleneck,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "bytes_per_device": self.bytes_per_device,
            "model_flops": self.model_flops,
            "useful_fraction": self.useful_fraction,
            "mfu_bound": self.mfu_bound,
        }


def compiled_cost(compiled) -> dict:
    """XLA's own accounting of a compiled artifact, as plain floats.

    Normalizes ``compiled.cost_analysis()`` (dict or single-element list
    depending on backend) and ``compiled.memory_analysis()`` into one flat
    record; missing analyses (some backends return None) read as zeros.
    Shared by the roofline model and ``obs.profile``'s cost gauges.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, list):           # some backends return [dict]
        cost = cost[0] if cost else {}
    if cost is None:
        cost = {}
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "temp_bytes": 0.0,
        "argument_bytes": 0.0,
        "output_bytes": 0.0,
        "peak_bytes": 0.0,
    }
    try:
        mem = compiled.memory_analysis()
    except Exception:
        mem = None
    if mem is not None:
        out["temp_bytes"] = float(getattr(mem, "temp_size_in_bytes", 0.0) or 0.0)
        out["argument_bytes"] = float(
            getattr(mem, "argument_size_in_bytes", 0.0) or 0.0
        )
        out["output_bytes"] = float(
            getattr(mem, "output_size_in_bytes", 0.0) or 0.0
        )
        out["peak_bytes"] = out["temp_bytes"] + out["argument_bytes"]
    return out


def roofline_from_compiled(
    compiled, arch: str, shape: str, mesh_name: str, chips: int,
    model_fl: float, hw: HW = V5E,
) -> Roofline:
    cost = compiled_cost(compiled)
    coll = collective_bytes(compiled.as_text())
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=cost["flops"], hlo_bytes=cost["bytes_accessed"],
        coll_bytes=float(sum(coll.values())), coll_breakdown=coll,
        bytes_per_device=cost["peak_bytes"], model_flops=model_fl, hw=hw,
    )


# ---------------------------------------------------------------------------
# Useful-FLOPs accounting
# ---------------------------------------------------------------------------


def model_flops(cfg, shape, n_params_active: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*D for a forward-only shape,
    with N = active params (MoE: routed active + shared + dense)."""
    tokens = shape.global_batch * shape.seq_len
    if kind == "train":
        return 6.0 * n_params_active * tokens
    if kind == "prefill":
        return 2.0 * n_params_active * tokens
    # decode: one new token per sequence
    return 2.0 * n_params_active * shape.global_batch
