"""Production meshes.  A FUNCTION (not a module constant) so importing this
module never touches jax device state -- the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init."""

from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "POD_SHAPE", "MULTIPOD_SHAPE"]

POD_SHAPE = (16, 16)                    # 256 chips / pod
MULTIPOD_SHAPE = (2, 16, 16)            # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTIPOD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} -- "
            "did you forget XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(set as the very first line of dryrun.py)?"
        )
    return jax.make_mesh(shape, axes, devices=devices[:need])
