"""Parameter accounting: total vs active (MoE) non-embedding params."""

from __future__ import annotations

from ..configs.base import ModelConfig

__all__ = ["param_counts"]


def _attn_params(cfg: ModelConfig, mixer: str) -> int:
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.resolved_head_dim
    if mixer == "mla":
        m = cfg.mla
        qd = m.nope_head_dim + m.rope_head_dim
        return (
            d * m.q_lora_rank + m.q_lora_rank * h * qd
            + d * (m.kv_lora_rank + m.rope_head_dim)
            + m.kv_lora_rank * h * (m.nope_head_dim + m.v_head_dim)
            + h * m.v_head_dim * d
        )
    if mixer == "mamba":
        s = cfg.ssm
        di = s.expand * d
        nh = di // s.head_dim
        cd = di + 2 * s.n_groups * s.d_state
        dip = 2 * di + 2 * s.n_groups * s.d_state + nh
        return d * dip + s.d_conv * cd + di * d + di + cd + 3 * nh
    qkv = d * h * hd + 2 * d * g * hd + h * hd * d
    if mixer == "attn_x":           # self + cross
        return 2 * qkv
    return qkv                      # attn, attn_nc, xattn


def _mlp_params(cfg: ModelConfig, mlp: str) -> tuple[int, int]:
    """(total, active) params of one MLP."""
    d = cfg.d_model
    if mlp == "none":
        return 0, 0
    if mlp == "dense":
        n = (3 if cfg.act == "swiglu" else 2) * d * cfg.d_ff
        return n, n
    e = cfg.moe
    per_exp = 3 * d * e.d_ff_expert
    total = e.n_experts * per_exp + d * e.n_experts
    active = e.top_k * per_exp + d * e.n_experts
    if e.n_shared:
        shared = (3 if cfg.act == "swiglu" else 2) * d * (e.n_shared * e.d_ff_expert)
        total += shared
        active += shared
    return total, active


def param_counts(cfg: ModelConfig) -> dict:
    """{"total", "active", "embedding"} parameter counts (analytic)."""
    total = active = 0
    for stage in cfg.stages:
        for mixer, mlp in stage.layers:
            a = _attn_params(cfg, mixer)
            mt, ma = _mlp_params(cfg, mlp)
            total += stage.repeats * (a + mt + 2 * cfg.d_model)
            active += stage.repeats * (a + ma + 2 * cfg.d_model)
    if cfg.encoder is not None:
        enc = cfg.encoder.n_layers * (
            _attn_params(cfg, "attn_nc") + _mlp_params(cfg, "dense")[0]
            + 2 * cfg.d_model
        )
        total += enc
        active += enc
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return {"total": total + emb, "active": active + emb, "embedding": emb,
            "total_nonemb": total, "active_nonemb": active}
