"""Step builders: train / prefill / decode, plus sharding trees for jit.

These are the functions the dry-run lowers and the drivers execute.  All of
them close over (cfg, rules) and take only arrays, so ``jax.jit(fn).lower()``
with ShapeDtypeStructs never allocates.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models.model import cache_spec, compute_loss, forward, logits_fn
from ..models.sharding import ShardingRules, named_sharding
from ..models.spec import abstract_params, init_params, param_shardings
from ..optim import Optimizer, apply_updates, clip_by_global_norm
from ..optim.compress import compress_int8, decompress_int8

__all__ = [
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "train_state_shardings",
    "batch_shardings",
    "cache_shardings",
    "abstract_cache",
    "init_cache",
]


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------


def _split_microbatches(batch: dict, accum: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % accum == 0, f"batch {b} not divisible by accum {accum}"
        return x.reshape(accum, b // accum, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(
    cfg: ModelConfig,
    rules: ShardingRules,
    opt: Optimizer,
    accum_steps: int = 1,
    clip_norm: float = 1.0,
    int8_accum: bool = False,
):
    """(params, opt_state, step, batch) -> (params, opt_state, metrics).

    ``accum_steps > 1`` runs microbatched gradient accumulation via lax.scan;
    ``int8_accum`` stores the accumulator int8 + error feedback (4x less HBM).
    """

    def loss_fn(params, mb):
        return compute_loss(params, cfg, rules, mb)

    def train_step(params, opt_state, step, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            mbs = _split_microbatches(batch, accum_steps)

            def one_mb(carry, mb):
                (loss_aux, metrics_aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                if int8_accum:
                    # accumulate in an fp32 view, re-compress with error feedback
                    acc_q, acc_s, err = carry
                    gl, tdef = jax.tree.flatten(g)
                    ql = tdef.flatten_up_to(acc_q)
                    sl = tdef.flatten_up_to(acc_s)
                    el = tdef.flatten_up_to(err)
                    qs, ss, es = [], [], []
                    for gi, qa, sa, ei in zip(gl, ql, sl, el):
                        tot = decompress_int8(qa, sa) + gi.astype(jnp.float32)
                        q, s, e = compress_int8(tot, ei)
                        qs.append(q)
                        ss.append(s)
                        es.append(e)
                    carry = (
                        tdef.unflatten(qs), tdef.unflatten(ss), tdef.unflatten(es)
                    )
                else:
                    carry = jax.tree.map(
                        lambda a, gi: a + gi.astype(jnp.float32), carry, g
                    )
                return carry, (loss_aux, metrics_aux)

            if int8_accum:
                zero_q = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.int8), params)
                zero_s = jax.tree.map(lambda p: jnp.ones((), jnp.float32), params)
                zero_e = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (acc_q, acc_s, _), (losses, metrics_s) = jax.lax.scan(
                    one_mb, (zero_q, zero_s, zero_e), mbs
                )
                grads = jax.tree.map(
                    lambda q, s: decompress_int8(q, s) / accum_steps, acc_q, acc_s
                )
            else:
                zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                acc, (losses, metrics_s) = jax.lax.scan(one_mb, zero, mbs)
                grads = jax.tree.map(lambda a: a / accum_steps, acc)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), metrics_s)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return abstract_params(cache_spec(cfg, batch, max_seq), dtype=dtype)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return init_params(cache_spec(cfg, batch, max_seq), dtype=dtype)  # all zeros


def make_prefill_step(cfg: ModelConfig, rules: ShardingRules, max_seq: int,
                      axo=None):
    """(params, tokens[, frontend embeds]) -> (last-position logits, cache).

    ``frontend`` is the stubbed modality input -- frame embeddings for the
    enc-dec family, patch embeddings for the VLM family (cfg decides which).
    The cache is created inside the step (zeros) at capacity ``max_seq`` and
    filled by the prefill pass -- one compiled program per (batch, capacity).

    ``axo`` (an ``axo.deploy.AxODeployment``) is closed over: its cached weight
    codes/factors become jit constants, so the compiled step serves every token
    through the approximate operator with no per-call requantization.
    """

    def prefill_step(params, tokens, frontend=None):
        from ..obs.telemetry import note_trace

        note_trace("launch.prefill_step")  # runs once per (re)trace
        b = tokens.shape[0]
        cache = init_cache(cfg, b, max_seq, dtype=params["norm_f"].dtype)
        enc = frontend if cfg.encoder is not None else None
        img = frontend if cfg.n_img_tokens else None
        x, _, cache = forward(
            params, cfg, rules, tokens, mode="prefill",
            cache=cache, cache_index=jnp.zeros((), jnp.int32),
            enc_embeds=enc, img_embeds=img, axo=axo,
        )
        logits = logits_fn(params, cfg, rules, x[:, -1:], axo=axo)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, rules: ShardingRules, axo=None):
    """(params, cache, tokens (B,1), index ()) -> (logits (B,1,V), new cache).

    ``axo`` as in :func:`make_prefill_step`."""

    def decode_step(params, cache, tokens, index):
        from ..obs.telemetry import note_trace

        note_trace("launch.decode_step")  # runs once per (re)trace
        x, _, cache = forward(
            params, cfg, rules, tokens, mode="decode",
            cache=cache, cache_index=index, axo=axo,
        )
        logits = logits_fn(params, cfg, rules, x, axo=axo)
        return logits, cache

    return decode_step


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------


def train_state_shardings(cfg: ModelConfig, rules: ShardingRules, mesh, opt: Optimizer):
    """(param shardings, opt-state shardings) derived from the spec tree."""
    from ..models.model import model_spec

    spec = model_spec(cfg)
    p_sh = param_shardings(spec, rules, mesh)
    o_sh = param_shardings(opt.state_spec(spec), rules, mesh)
    return p_sh, o_sh


def batch_shardings(rules: ShardingRules, mesh, batch_specs: dict):
    """Data-input shardings: tokens/labels over batch; stub embeds likewise."""

    def sh(path_leaf):
        ndim = len(path_leaf.shape)
        axes = ("batch",) + (None,) * (ndim - 1)
        return named_sharding(mesh, rules.resolve(axes, kind="act"), path_leaf.shape)

    return jax.tree.map(sh, batch_specs)


def cache_shardings(cfg: ModelConfig, rules: ShardingRules, mesh, batch: int, max_seq: int):
    return param_shardings(cache_spec(cfg, batch, max_seq), rules, mesh, kind="act")
