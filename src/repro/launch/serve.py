"""Serving driver: prefill a batch of prompts, decode with a KV cache --
optionally with AxO-approximate arithmetic deployed in every linear layer
(the paper's operators in the serving path, via ``deploy_axo``).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \\
      --batch 4 --prompt-len 24 --gen 16 [--axo-rank 8]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..axo import AXO_LAYERS, AxOOperator, deploy_axo
from ..configs.base import ShapeConfig
from ..configs.registry import ARCH_IDS, get_arch
from ..data.synthetic import SyntheticLM
from ..kernels.ops import on_tpu
from ..models.model import model_spec
from ..models.sharding import BASE_RULES
from ..models.spec import init_params
from ..obs import telemetry as obs
from .steps import make_decode_step, make_prefill_step


def demo_operator(rank: int) -> AxOOperator:
    """The classic 1-column truncated multiplier (drop the lowest
    partial-product column of every row) -- a mild, deterministic Pareto
    design; no DSE run needed for a serving demo."""
    from ..core.operator_model import accurate_config, spec_for

    spec8 = spec_for(8)
    op_cfg = accurate_config(spec8)
    for r in range(spec8.rows):
        op_cfg[r * spec8.cols_removable] = 0
    return AxOOperator.from_config(op_cfg, rank=rank)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--axo-rank", type=int, default=0,
                    help=">0: deploy a rank-R AxO operator into every linear "
                         "layer and report divergence on the decoded trajectory")
    ap.add_argument("--axo-layers", nargs="+", default=list(AXO_LAYERS),
                    choices=list(AXO_LAYERS))
    ap.add_argument("--axo-impl", default=None, choices=["xla", "pallas"])
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON of the serving spans "
                         "(load at ui.perfetto.dev) and print the per-request "
                         "latency histograms")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve GET /metrics (Prometheus text exposition of "
                         "the live obs.GLOBAL state) and GET /healthz (device "
                         "liveness + tuning cache + deployment status) on "
                         "this port; 0 picks an ephemeral port")
    ap.add_argument("--requests", type=int, default=1,
                    help="number of exact serving requests to run (>1 fills "
                         "the latency histograms for scraping)")
    ap.add_argument("--hold", type=float, default=0.0, metavar="SECONDS",
                    help="keep the process (and the metrics endpoint) alive "
                         "this long after serving, so a scraper can collect")
    ap.add_argument("--dse-service", action="store_true",
                    help="mount the persistent DSE service on the metrics "
                         "server: POST /dse submits a (n_bits, op, signed, "
                         "app, const_sf, seed, method) job into the batched "
                         "queue, GET /dse?id=<job> polls its result, GET "
                         "/dse/library reports the operator-library status; "
                         "requires --metrics-port")
    ap.add_argument("--dse-smoke", type=int, default=0, metavar="N",
                    help="after serving, POST N small DSE requests to the "
                         "live endpoint and wait for their fronts (endpoint "
                         "self-test; implies --dse-service)")
    ap.add_argument("--dse-pop", type=int, default=16,
                    help="service GA population per request lane")
    ap.add_argument("--dse-gens", type=int, default=8,
                    help="service GA generations per request lane")
    args = ap.parse_args(argv)
    if args.dse_smoke:
        args.dse_service = True
    if args.dse_service and args.metrics_port is None:
        ap.error("--dse-service requires --metrics-port")

    # one sink for the whole driver: prefill/decode latency histograms and
    # tokens/sec gauges always collect (counters chain to the process
    # aggregate); --trace additionally exports the span tree
    tel = obs.Telemetry("serve", parent=obs.GLOBAL)

    # /metrics scrapes the process-wide aggregate (which sees this driver's
    # sink through the parent chain), so anything else the process records --
    # kernel dispatch counters, pad waste, tuning traffic -- is exposed too
    metrics = None
    if args.metrics_port is not None:
        from ..obs.prom import MetricsServer

        metrics = MetricsServer(tel=obs.GLOBAL, port=args.metrics_port).start()
        print(f"metrics: {metrics.url}/metrics  health: {metrics.url}/healthz")

    # DSE service: job intake + result polling + library status ride the
    # same server; the queue coalesces compatible requests into single
    # run_dse_sweep dispatches and the operator library persists their fronts
    dse_queue = None
    if args.dse_service:
        from ..core.dse import DSESettings
        from ..service import (
            DSEJobQueue, DSERequest, OperatorStore, default_runner,
        )
        from ..service.store import store_status

        dse_store = OperatorStore()
        dse_queue = DSEJobQueue(default_runner(
            settings=DSESettings(pop_size=args.dse_pop, n_gen=args.dse_gens,
                                 backend="jax"),
            store=dse_store,
        ))

        def post_dse(payload: dict) -> dict:
            job_id = dse_queue.submit(DSERequest.from_dict(payload))
            return {"job_id": job_id, "queued": dse_queue.depth()}

        def get_dse(params: dict) -> dict:
            res = dse_queue.result(params["id"])
            return res if res is not None else {"status": "pending"}

        metrics.add_route("POST", "/dse", post_dse)
        metrics.add_route("GET", "/dse", get_dse)
        metrics.add_route("GET", "/dse/library",
                          lambda params: store_status(dse_store))
        print(f"dse service: POST {metrics.url}/dse "
              f"(library: {dse_store.root})")

    cfg = get_arch(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    rules = BASE_RULES
    max_seq = args.prompt_len + args.gen

    params = init_params(model_spec(cfg), seed=args.seed)
    shape = ShapeConfig("serve", max_seq, args.batch, "train")
    data = SyntheticLM(cfg, shape, seed=args.seed)
    b = data.batch(0)
    toks = jnp.asarray(b["tokens"])[:, : args.prompt_len]
    frontend = None
    if "enc_embeds" in b:
        frontend = jnp.asarray(b["enc_embeds"], jnp.bfloat16)
    if "img_embeds" in b:
        frontend = jnp.asarray(b["img_embeds"], jnp.bfloat16)

    prefill = jax.jit(make_prefill_step(cfg, rules, max_seq=max_seq))
    decode = jax.jit(make_decode_step(cfg, rules))

    def serve(pre_fn, dec_fn, label="exact"):
        """Greedy generation; returns (tokens, last-step logits, timings).

        Each call is one request span: prefill latency + per-step decode
        latency land in the telemetry histograms, the request's decode
        throughput in a tokens/sec gauge.
        """
        with tel.span("serve.request", label=label, batch=args.batch,
                      prompt_len=args.prompt_len, gen=args.gen):
            t0 = time.perf_counter()
            with tel.span("serve.prefill"):
                pre_args = (
                    (params, toks) if frontend is None else (params, toks, frontend)
                )
                logits, cache = pre_fn(*pre_args)
                nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            generated, lgs = [nxt], [logits[:, -1]]
            t_pre = time.perf_counter() - t0
            tel.observe("serve.prefill_ms", t_pre * 1e3)
            t0 = time.perf_counter()
            with tel.span("serve.decode", steps=args.gen - 1):
                for i in range(args.prompt_len, args.prompt_len + args.gen - 1):
                    ts = time.perf_counter()
                    logits, cache = dec_fn(params, cache, nxt, jnp.int32(i))
                    nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(
                        jnp.int32
                    )
                    tel.observe(
                        "serve.decode_step_ms",
                        (time.perf_counter() - ts) * 1e3,
                    )
                    generated.append(nxt)
                    lgs.append(logits[:, -1])
            t_dec = time.perf_counter() - t0
            n_tok = args.batch * (args.gen - 1)
            if t_dec > 0:
                tel.gauge("serve.tokens_per_s", n_tok / t_dec)
                tel.observe("serve.tokens_per_s", n_tok / t_dec)
            tel.count("serve.requests")
        return jnp.concatenate(generated, axis=1), lgs, (t_pre, t_dec)

    for _ in range(max(0, args.requests - 1)):
        serve(prefill, decode)  # warm repeats: histogram filler for scraping
    out, exact_lgs, (t_prefill, t_decode) = serve(prefill, decode)
    print(f"arch={cfg.name} prefill({args.batch}x{args.prompt_len})="
          f"{t_prefill*1e3:.1f}ms decode({args.gen - 1} steps)={t_decode*1e3:.1f}ms")
    print("generated token ids (row 0):", np.asarray(out[0]).tolist())
    if metrics is not None:
        metrics.set_deployment({"mode": "exact", "arch": cfg.name})

    if args.axo_rank > 0:
        # deploy the operator into every requested linear layer, rebuild the
        # steps around the deployment, and serve the SAME prompts -- the
        # divergence is scored on the decoded trajectory, not random inputs
        op = demo_operator(args.axo_rank)
        impl = args.axo_impl or ("pallas" if on_tpu() else "xla")
        dep = deploy_axo(params, op, cfg, layers=tuple(args.axo_layers),
                         impl=impl)
        pre_a = jax.jit(make_prefill_step(cfg, rules, max_seq=max_seq, axo=dep))
        dec_a = jax.jit(make_decode_step(cfg, rules, axo=dep))
        out_a, _, _ = serve(pre_a, dec_a, label="axo")  # warm + free-run tokens
        _, axo_lgs, (tp, td) = serve(pre_a, dec_a, label="axo")

        # teacher-forced comparison along the exact trajectory
        pre_args = (params, toks) if frontend is None else (params, toks, frontend)
        logits, cache = pre_a(*pre_args)
        replay = [logits[:, -1]]
        for j in range(out.shape[1] - 1):
            logits, cache = dec_a(params, cache, out[:, j:j + 1],
                                  jnp.int32(args.prompt_len + j))
            replay.append(logits[:, -1])
        top1 = float(np.mean([
            float((jnp.argmax(a, -1) == jnp.argmax(e, -1)).mean())
            for a, e in zip(replay, exact_lgs)]))
        # norms in f32: bf16 logits have no numpy scalar equivalent
        rel = float(np.mean([
            float(jnp.linalg.norm((a - e).astype(jnp.float32))
                  / jnp.maximum(jnp.linalg.norm(e.astype(jnp.float32)), 1e-9))
            for a, e in zip(replay, exact_lgs)]))
        match = float((out_a == out).mean())
        print(f"axo rank={args.axo_rank} ({dep.n_entries} projections, {impl}): "
              f"prefill={tp*1e3:.1f}ms decode={td*1e3:.1f}ms  "
              f"free-run match={match:.2%} teacher-forced top1={top1:.2%} "
              f"logit rel_err={rel:.4f}")
        tel.gauge("serve.axo_top1", top1)
        tel.gauge("serve.axo_free_run_match", match)
        tel.gauge("serve.axo_logit_rel_err", rel)
        if metrics is not None:
            metrics.set_deployment({
                "mode": "axo", "arch": cfg.name, "rank": args.axo_rank,
                "impl": impl, "layers": list(args.axo_layers),
                "projections": dep.n_entries,
                "top1": top1, "free_run_match": match,
            })

    if args.trace is not None:
        tel.to_chrome_trace(args.trace)
        print(f"chrome trace: {args.trace} ({len(tel.spans)} spans; "
              "load at ui.perfetto.dev)")
        for h in ("serve.prefill_ms", "serve.decode_step_ms"):
            s = tel.histogram_summary(h)
            print(f"{h}: n={s['count']} p50={s['p50']:.1f} p90={s['p90']:.1f} "
                  f"max={s['max']:.1f}")
        print(f"serve.tokens_per_s: {tel.gauges['serve.tokens_per_s']:.1f} "
              f"(last request)")

    if args.dse_smoke:
        # endpoint self-test: post a small burst through the live HTTP
        # surface (not the queue object) and wait for every front
        import json as _json
        import urllib.request

        t0 = time.perf_counter()
        jobs = []
        for i in range(args.dse_smoke):
            body = _json.dumps({
                "n_bits": 4, "const_sf": 0.5 + 0.3 * (i % 2), "seed": i // 2,
            }).encode()
            req = urllib.request.Request(
                f"{metrics.url}/dse", data=body,
                headers={"Content-Type": "application/json"},
            )
            with urllib.request.urlopen(req) as resp:
                jobs.append(_json.loads(resp.read())["job_id"])
        if not dse_queue.join(timeout=600):
            raise RuntimeError("dse smoke: jobs did not finish in 600s")
        for jid in jobs:
            with urllib.request.urlopen(f"{metrics.url}/dse?id={jid}") as resp:
                res = _json.loads(resp.read())
            if res["status"] != "done":
                raise RuntimeError(f"dse smoke: {jid} -> {res}")
            print(f"dse {jid}: const_sf={res['request']['const_sf']} "
                  f"seed={res['request']['seed']} hv={res['hv_vpf']:.4g} "
                  f"front={len(res['front'])}")
        print(f"dse smoke: {args.dse_smoke} requests -> "
              f"{obs.GLOBAL.counter('service.batches')} batched dispatch(es) "
              f"in {time.perf_counter() - t0:.1f}s")

    if metrics is not None and args.hold > 0:
        print(f"holding {args.hold:.0f}s for scrapers ({metrics.url}/metrics)")
        time.sleep(args.hold)
    if dse_queue is not None:
        dse_queue.close()
    if metrics is not None:
        metrics.stop()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
