"""Serving driver: prefill a batch of prompts, decode with a KV cache --
optionally with AxO-approximate arithmetic on the LM head (the paper's
operators deployed in the serving path).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \\
      --batch 4 --prompt-len 24 --gen 16 [--axo-rank 8]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..axo import AxOOperator, axo_linear
from ..configs.base import ShapeConfig
from ..configs.registry import ARCH_IDS, get_arch
from ..data.synthetic import SyntheticLM
from ..models.model import model_spec
from ..models.sharding import BASE_RULES
from ..models.spec import init_params
from .steps import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--axo-rank", type=int, default=0,
                    help=">0: rerank the final LM-head matmul through a rank-R "
                         "AxO operator and report the logit divergence")
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    rules = BASE_RULES
    max_seq = args.prompt_len + args.gen

    params = init_params(model_spec(cfg), seed=args.seed)
    shape = ShapeConfig("serve", max_seq, args.batch, "train")
    data = SyntheticLM(cfg, shape, seed=args.seed)
    b = data.batch(0)
    toks = jnp.asarray(b["tokens"])[:, : args.prompt_len]
    frontend = None
    if "enc_embeds" in b:
        frontend = jnp.asarray(b["enc_embeds"], jnp.bfloat16)
    if "img_embeds" in b:
        frontend = jnp.asarray(b["img_embeds"], jnp.bfloat16)

    prefill = jax.jit(make_prefill_step(cfg, rules, max_seq=max_seq))
    decode = jax.jit(make_decode_step(cfg, rules))

    t0 = time.time()
    pre_args = (params, toks) if frontend is None else (params, toks, frontend)
    logits, cache = prefill(*pre_args)
    nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    generated = [nxt]
    t_prefill = time.time() - t0

    t0 = time.time()
    for i in range(args.prompt_len, args.prompt_len + args.gen - 1):
        logits, cache = decode(params, cache, nxt, jnp.int32(i))
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated.append(nxt)
    t_decode = time.time() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} prefill({args.batch}x{args.prompt_len})="
          f"{t_prefill*1e3:.1f}ms decode({args.gen - 1} steps)={t_decode*1e3:.1f}ms")
    print("generated token ids (row 0):", np.asarray(out[0]).tolist())

    if args.axo_rank > 0:
        # deploy an AxO operator on the LM head and compare last-step logits;
        # demo design = the classic 1-column truncated multiplier (drop the
        # lowest partial-product column of every row -- a mild Pareto design)
        from ..core.operator_model import accurate_config, spec_for
        spec8 = spec_for(8)
        op_cfg = accurate_config(spec8)
        for r in range(spec8.rows):
            op_cfg[r * spec8.cols_removable] = 0
        op = AxOOperator.from_config(op_cfg, rank=args.axo_rank)
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            (args.batch, cfg.d_model)), jnp.float32)
        unemb = (params["embed"]["tok"].T if cfg.tie_embeddings
                 else params["embed"]["unembed"]).astype(jnp.float32)
        exact = x @ unemb
        approx = axo_linear(x, unemb, op)
        rel = float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact))
        top1_match = float(
            (jnp.argmax(approx, -1) == jnp.argmax(exact, -1)).mean())
        print(f"axo LM-head rank={args.axo_rank}: rel_err={rel:.4f} "
              f"top1_agreement={top1_match:.2%}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
