"""Aggregate dry-run JSONs into the §Dry-run / §Roofline markdown tables.

  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import json
import os

from ..configs.base import SHAPES
from ..configs.registry import ARCH_IDS

HBM = 16 * 1024**3


def fmt_b(x):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def load(dirname):
    recs = {}
    for f in os.listdir(dirname):
        if not f.endswith(".json"):
            continue
        with open(os.path.join(dirname, f)) as fh:
            r = json.load(fh)
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | status | HBM need/dev | fits 16G | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_IDS:
        for s in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                r = recs.get((a, s, mesh))
                if r is None:
                    lines.append(f"| {a} | {s} | {mesh} | MISSING | | | |")
                    continue
                st = r["status"]
                if st != "ok":
                    short = "skip (full-attn @500k)" if st.startswith("skip") else st[:40]
                    lines.append(f"| {a} | {s} | {mesh} | {short} | - | - | - |")
                    continue
                need = r.get("hbm_need_bytes", 0)
                lines.append(
                    f"| {a} | {s} | {mesh} | ok | {fmt_b(need)} | "
                    f"{'yes' if r.get('fits_v5e_hbm') else 'NO'} | "
                    f"{r.get('t_compile_s', 0):.0f} |"
                )
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | t_comp s | t_mem s | t_coll s | bottleneck | "
        "model TFLOP | useful frac | MFU bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_IDS:
        for s in SHAPES:
            r = recs.get((a, s, "16x16"))
            if r is None or r["status"] != "ok":
                continue
            lines.append(
                f"| {a} | {s} | {r['t_compute_s']:.4g} | {r['t_memory_s']:.4g} | "
                f"{r['t_collective_s']:.4g} | **{r['bottleneck']}** | "
                f"{r['model_flops']/1e12:.3g} | {r['useful_fraction']:.3f} | "
                f"{r['mfu_bound']:.4f} |"
            )
    return "\n".join(lines)


def interesting_cells(recs) -> str:
    """Rank cells for the hillclimb: worst MFU bound / most collective-bound."""
    rows = [r for r in recs.values()
            if r.get("status") == "ok" and r["mesh"] == "16x16"]
    rows.sort(key=lambda r: r.get("mfu_bound", 0))
    out = ["worst roofline fraction (MFU bound):"]
    for r in rows[:5]:
        out.append(f"  {r['arch']} x {r['shape']}: mfu_bound={r['mfu_bound']:.4f} "
                   f"bottleneck={r['bottleneck']}")
    coll = sorted(rows, key=lambda r: -(r["t_collective_s"] /
                                        max(r["t_compute_s"] + r["t_memory_s"], 1e-12)))
    out.append("most collective-bound (t_coll / (t_comp+t_mem)):")
    for r in coll[:5]:
        ratio = r["t_collective_s"] / max(r["t_compute_s"] + r["t_memory_s"], 1e-12)
        out.append(f"  {r['arch']} x {r['shape']}: ratio={ratio:.2f}")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--what", default="all", choices=["all", "dryrun", "roofline",
                                                      "interesting"])
    args = ap.parse_args(argv)
    recs = load(args.dir)
    if args.what in ("all", "dryrun"):
        print("## Dry-run grid\n")
        print(dryrun_table(recs))
        print()
    if args.what in ("all", "roofline"):
        print("## Roofline (single-pod 16x16, probe-corrected)\n")
        print(roofline_table(recs))
        print()
    if args.what in ("all", "interesting"):
        print("## Hillclimb candidates\n")
        print(interesting_cells(recs))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
