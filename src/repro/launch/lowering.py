"""Shared cell-lowering used by the dry-run and the cost probes."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..configs.registry import input_specs
from ..models.model import model_spec
from ..models.sharding import ShardingRules, named_sharding, set_mesh
from ..models.spec import abstract_params, param_shardings
from ..optim import cosine_schedule, make_optimizer
from .steps import (
    abstract_cache,
    batch_shardings,
    cache_shardings,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

__all__ = ["lower_step"]


def lower_step(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: ShardingRules):
    """Lower the cell's step function from ShapeDtypeStructs (no allocation)."""
    spec = model_spec(cfg)
    params_abs = abstract_params(spec)
    p_sh = param_shardings(spec, rules, mesh)
    specs = input_specs(cfg, shape)

    with set_mesh(mesh):
        if shape.kind == "train":
            opt = make_optimizer(cfg.optimizer, cosine_schedule(3e-4))
            o_spec = opt.state_spec(spec)
            opt_abs = abstract_params(o_spec)
            o_sh = param_shardings(o_spec, rules, mesh)
            b_sh = batch_shardings(rules, mesh, specs["batch"])
            step_abs = jax.ShapeDtypeStruct((), jnp.int32)
            fn = make_train_step(cfg, rules, opt)
            jitted = jax.jit(
                fn,
                in_shardings=(p_sh, o_sh, named_sharding(mesh, P()), b_sh),
                donate_argnums=(0, 1),
            )
            return jitted.lower(params_abs, opt_abs, step_abs, specs["batch"])
        if shape.kind == "prefill":
            fn = make_prefill_step(cfg, rules, max_seq=shape.seq_len)
            args = [params_abs, specs["tokens"]]
            shardings = [p_sh, batch_shardings(rules, mesh, specs["tokens"])]
            frontend = specs.get("enc_embeds", specs.get("img_embeds"))
            if frontend is not None:
                args.append(frontend)
                shardings.append(batch_shardings(rules, mesh, frontend))
            jitted = jax.jit(fn, in_shardings=tuple(shardings))
            return jitted.lower(*args)
        if shape.kind == "decode":
            cache_abs = abstract_cache(cfg, shape.global_batch, shape.seq_len)
            c_sh = cache_shardings(cfg, rules, mesh, shape.global_batch, shape.seq_len)
            fn = make_decode_step(cfg, rules)
            jitted = jax.jit(
                fn,
                in_shardings=(
                    p_sh, c_sh,
                    batch_shardings(rules, mesh, specs["tokens"]),
                    named_sharding(mesh, P()),
                ),
                donate_argnums=(1,),
            )
            return jitted.lower(
                params_abs, cache_abs, specs["tokens"], specs["index"]
            )
        raise ValueError(shape.kind)
