"""Loop-corrected cost analysis ("cost probes").

XLA's ``cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count, so a scan-over-layers program under-reports FLOPs/bytes by ~the layer
count, and the HLO text shows each in-loop collective once.  The probes fix
this structurally:

* every probe model runs with ``unroll_loops=True`` (chunked attention's inner
  scan/map become Python loops -- the SSD inter-chunk scan stays, its body is
  elementwise) and every stage at ``repeats=1``;
* probe **P1** = all stages once;  probe **P2[s]** = stage ``s``'s super-block
  layer list doubled;  **P2enc** = encoder depth doubled.

With per-probe measurements m(.), linearity gives the true per-step cost

    true = m(P1) + sum_s (repeats_s - 1) * (m(P2[s]) - m(P1))
                 + (enc_layers - 1)     * (m(P2enc) - m(P1))

applied identically to FLOPs, bytes accessed, and per-kind collective bytes.
Probe programs are 1-2 super-blocks, so the extra compiles are cheap, and the
probes' loop trip counts are all 1 => their cost_analysis is exact.
"""

from __future__ import annotations

from dataclasses import replace

from ..configs.base import EncoderConfig, ModelConfig, ShapeConfig, StageConfig
from .roofline import collective_bytes

__all__ = ["probe_variants", "measure_compiled", "corrected_costs"]

_PROBE_ATTN_CHUNK = 4096   # cap on unrolled blocks when NOT causal-skipping;
                           # total attention FLOPs are chunk-size-invariant
                           # (all nq x nk pairs computed), so coarser probe
                           # chunks measure the same cost with fewer bodies.


def _probe_base(cfg: ModelConfig) -> ModelConfig:
    if cfg.causal_block_skip:
        # the real program skips upper-triangle blocks at ITS chunk size; the
        # probe must unroll at the same granularity to measure the skip.
        return replace(cfg, unroll_loops=True)
    return replace(
        cfg,
        unroll_loops=True,
        attn_q_chunk=max(cfg.attn_q_chunk, _PROBE_ATTN_CHUNK),
        attn_kv_chunk=max(cfg.attn_kv_chunk, _PROBE_ATTN_CHUNK),
    )


def probe_variants(cfg: ModelConfig) -> dict[str, ModelConfig]:
    """{"P1": ..., "P2s<k>": ..., "P2enc": ...} probe configs."""
    base = _probe_base(cfg)
    ones = tuple(StageConfig(repeats=1, layers=s.layers) for s in cfg.stages)
    enc1 = EncoderConfig(n_layers=1, n_ctx=cfg.encoder.n_ctx) if cfg.encoder else None

    out = {"P1": replace(base, stages=ones, encoder=enc1)}
    for k, s in enumerate(cfg.stages):
        doubled = list(ones)
        doubled[k] = StageConfig(repeats=1, layers=s.layers + s.layers)
        out[f"P2s{k}"] = replace(base, stages=tuple(doubled), encoder=enc1)
    if cfg.encoder is not None:
        enc2 = EncoderConfig(n_layers=2, n_ctx=cfg.encoder.n_ctx)
        out["P2enc"] = replace(base, stages=ones, encoder=enc2)
    return out


def measure_compiled(compiled) -> dict:
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, list) else cost
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_total": float(sum(coll.values())),
        **{f"coll_{k}": float(v) for k, v in coll.items()},
    }


def corrected_costs(cfg: ModelConfig, measures: dict[str, dict]) -> dict:
    """Apply the linear correction over probe measurements."""
    m1 = measures["P1"]
    out = dict(m1)
    for k, s in enumerate(cfg.stages):
        mk = measures[f"P2s{k}"]
        w = s.repeats - 1
        for key in out:
            out[key] = out[key] + w * max(mk[key] - m1[key], 0.0)
    if cfg.encoder is not None:
        me = measures["P2enc"]
        w = cfg.encoder.n_layers - 1
        for key in out:
            out[key] = out[key] + w * max(me[key] - m1[key], 0.0)
    return out
