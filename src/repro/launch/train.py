"""Training driver: --arch <id> end-to-end on whatever devices exist.

On this CPU container it trains the REDUCED config of the chosen architecture
(the full configs are dry-run-only by design); on a real fleet the same driver
runs the full config -- everything (mesh, shardings, checkpointing, loop) is
identical, only the config source changes.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \\
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp

from ..configs.base import ShapeConfig
from ..configs.registry import ARCH_IDS, get_arch
from ..data.synthetic import SyntheticLM
from ..models.model import model_spec
from ..models.sharding import BASE_RULES
from ..models.spec import count_params, init_params
from ..optim import cosine_schedule, make_optimizer
from ..train import TrainLoopConfig, train_loop
from .steps import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--int8-accum", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (not reduced) config -- fleet scale only")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = get_arch(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    rules = BASE_RULES

    spec = model_spec(cfg)
    print(f"arch={cfg.name} params={count_params(spec):,} "
          f"tokens/step={shape.tokens:,} optimizer={cfg.optimizer}")

    opt = make_optimizer(
        cfg.optimizer,
        cosine_schedule(args.lr, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps),
    )
    data = SyntheticLM(cfg, shape, seed=args.seed)
    step_jit = jax.jit(make_train_step(cfg, rules, opt, accum_steps=args.accum,
                                       int8_accum=args.int8_accum))

    def init_state():
        params = init_params(spec, seed=args.seed)
        return params, opt.init(params)

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in data.batch(step).items()}

    def step_fn(params, opt_state, step, batch):
        return step_jit(params, opt_state, jnp.int32(int(step)), batch)

    out = train_loop(
        step_fn, init_state, batch_fn,
        TrainLoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir),
    )
    first = out["history"][0][1] if out["history"] else float("nan")
    last = out["history"][-1][1] if out["history"] else float("nan")
    print(f"done: steps={len(out['history'])} loss {first:.4f} -> {last:.4f} "
          f"restarts={out['restarts']} stragglers={out['stragglers']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
