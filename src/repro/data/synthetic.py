"""Deterministic, seekable synthetic LM data pipeline.

The batch for step ``t`` is a pure function of ``(seed, t)`` -- there is no
iterator state to checkpoint or lose, which is the fault-tolerance property the
train loop relies on: after a restart, ``batch(t)`` reproduces the exact batch
bitwise.  Works host-side (numpy, for feeding) and device-side (jit-able, for
fully on-device input pipelines).

The token stream is a Zipf-distributed unigram draw mixed with a first-order
Markov "phrase" structure so the loss curve is non-trivial (a model can learn
it), and labels are next-token targets with the final position masked.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig

__all__ = ["SyntheticLM"]


@dataclass(frozen=True)
class SyntheticLM:
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    zipf_a: float = 1.2
    markov_p: float = 0.7        # P(next = f(prev)) vs fresh unigram draw

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step & 0x7FFFFFFF])
        )

    # -- host-side ----------------------------------------------------------

    def batch(self, step: int) -> dict:
        """Numpy batch for one step: {'tokens','labels'[, stub embeddings]}."""
        b, s = self.shape.global_batch, self.shape.seq_len
        v = self.cfg.vocab
        rng = self._rng(step)

        # Zipf unigram (clipped to vocab) + deterministic "phrase" transitions.
        uni = np.minimum(rng.zipf(self.zipf_a, size=(b, s)), v - 1)
        chain = (uni * 2654435761 + 12345) % v     # cheap deterministic f(prev)
        use_chain = rng.random((b, s)) < self.markov_p
        tokens = uni.copy()
        tokens[:, 1:] = np.where(
            use_chain[:, 1:], chain[:, :-1], uni[:, 1:]
        )
        tokens = tokens.astype(np.int32)

        labels = np.full((b, s), -1, dtype=np.int32)
        labels[:, :-1] = tokens[:, 1:]

        out = {"tokens": tokens, "labels": labels}
        d = self.cfg.d_model
        if self.cfg.encoder is not None:
            out["enc_embeds"] = rng.standard_normal(
                (b, self.cfg.encoder.n_ctx, d)
            ).astype(np.float32) * 0.02
        if self.cfg.n_img_tokens:
            out["img_embeds"] = rng.standard_normal(
                (b, self.cfg.n_img_tokens, d)
            ).astype(np.float32) * 0.02
        return out

    # -- device-side (jit-able) ----------------------------------------------

    def device_batch(self, step):
        """Same interface, pure-JAX (usable inside a jitted input pipeline).

        Not bitwise-identical to the numpy path (different RNG), but equally
        deterministic/seekable; used when feeding from host is the bottleneck.
        """
        b, s = self.shape.global_batch, self.shape.seq_len
        v = self.cfg.vocab
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        # Zipf via inverse-CDF approximation on a truncated support.
        u = jax.random.uniform(k1, (b, s), minval=1e-6, maxval=1.0)
        uni = jnp.clip((u ** (-1.0 / (self.zipf_a - 1.0))).astype(jnp.int32) - 1, 0, v - 1)
        chain = (uni * 2654435761 + 12345) % v
        use_chain = jax.random.uniform(k2, (b, s)) < self.markov_p
        tokens = uni.at[:, 1:].set(
            jnp.where(use_chain[:, 1:], chain[:, :-1], uni[:, 1:])
        )
        labels = jnp.full((b, s), -1, jnp.int32).at[:, :-1].set(tokens[:, 1:])
        out = {"tokens": tokens, "labels": labels}
        d = self.cfg.d_model
        if self.cfg.encoder is not None:
            out["enc_embeds"] = 0.02 * jax.random.normal(
                k3, (b, self.cfg.encoder.n_ctx, d), jnp.bfloat16)
        if self.cfg.n_img_tokens:
            out["img_embeds"] = 0.02 * jax.random.normal(
                k4, (b, self.cfg.n_img_tokens, d), jnp.bfloat16)
        return out
