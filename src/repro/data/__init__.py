from .synthetic import SyntheticLM

__all__ = ["SyntheticLM"]
