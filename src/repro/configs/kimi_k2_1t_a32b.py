"""kimi-k2-1t-a32b [moe]: trillion-param MoE, 61L (1 dense + 60 MoE), d=7168,
64H (GQA kv=8), expert ff=2048, MoE 384e top-8 + 1 shared, vocab=163840.
Paper-table config; adafactor + FSDP are mandatory at this scale.
[arXiv:2501.kimi2; unverified]"""

from .base import ModelConfig, MoEConfig, StageConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    d_model=7168,
    n_heads=64,
    kv_heads=8,
    d_ff=18432,                    # dense (first-layer) FFN width
    vocab=163840,
    stages=(
        StageConfig(repeats=1, layers=(("attn", "dense"),)),
        StageConfig(repeats=60, layers=(("attn", "moe"),)),
    ),
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048, n_shared=1),
    optimizer="adafactor",
    use_fsdp=True,
    source="[arXiv:2501.kimi2; unverified]",
)
