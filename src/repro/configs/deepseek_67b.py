"""deepseek-67b [dense]: llama-arch, 95L, d=8192, 64H (GQA kv=8), ff=22016,
vocab=102400.  [arXiv:2401.02954; hf]"""

from .base import ModelConfig, StageConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    d_model=8192,
    n_heads=64,
    kv_heads=8,
    d_ff=22016,
    vocab=102400,
    stages=(StageConfig(repeats=95, layers=(("attn", "dense"),)),),
    use_fsdp=True,
    source="[arXiv:2401.02954; hf]",
)
