"""Architecture registry: --arch <id> -> ModelConfig, shape grid, input specs,
and per-(arch x shape) sharding-rule resolution.

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins for every
model input (never allocates), which is what the multi-pod dry-run lowers.
"""

from __future__ import annotations

import importlib
from dataclasses import replace

import jax
import jax.numpy as jnp

from ..models.sharding import BASE_RULES, ShardingRules
from .base import ModelConfig, SHAPES, ShapeConfig

__all__ = [
    "ARCH_IDS",
    "get_arch",
    "SHAPES",
    "cell_status",
    "input_specs",
    "rules_for",
    "arch_for_shape",
]

# arch id -> module name
ARCH_IDS = {
    "whisper-medium": "whisper_medium",
    "deepseek-67b": "deepseek_67b",
    "starcoder2-3b": "starcoder2_3b",
    "granite-3-2b": "granite_3_2b",
    "internlm2-1.8b": "internlm2_1_8b",
    "mamba2-130m": "mamba2_130m",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
}

# Archs with sub-quadratic decode state: the only ones that run long_500k.
SUBQUADRATIC = {"mamba2-130m", "jamba-v0.1-52b"}


def get_arch(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCH_IDS)}")
    mod = importlib.import_module(f".{ARCH_IDS[arch_id]}", package=__package__)
    return mod.CONFIG


def cell_status(arch_id: str, shape_name: str) -> str:
    """'run' or a skip reason, per the assignment's shape/skip policy."""
    if shape_name == "long_500k" and arch_id not in SUBQUADRATIC:
        return ("skip: pure full-attention arch -- O(seq) per decoded token over a "
                "524288-token dense KV cache (assignment directs the skip)")
    return "run"


def arch_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Shape-specialized config (RoPE table length, perf policy).

    Causal block skipping (perf opt P1) is gated by measurement: it removes
    37–48% of attention work, a clear win where attention dominates (32k
    prefill: MFU bound +62%), but its per-q-block loops cost extra KV gathers
    that regress collective-bound 4k training cells (internlm2: MFU −24%) --
    so it engages for prefill / long sequences only (EXPERIMENTS.md §Perf P1).
    """
    skip = shape.kind == "prefill" or shape.seq_len >= 16384
    return replace(cfg, max_seq=max(shape.seq_len, cfg.max_seq),
                   causal_block_skip=skip)


def rules_for(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh_model: int = 16,
    mesh_data: int = 16,
) -> ShardingRules:
    """Resolve the logical->mesh rule table for one (arch, shape) cell."""
    rules = BASE_RULES
    if cfg.use_fsdp:
        rules = rules.with_fsdp()
    param: dict = {}
    act: dict = {}

    # Tensor-parallel eligibility: only shard dims the mesh divides evenly.
    if not cfg.shard_heads or cfg.n_heads % mesh_model:
        param["heads"] = ()
        act["heads"] = ()
    if cfg.kv_heads % mesh_model:
        param["kv_heads"] = ()
    if cfg.d_ff and cfg.d_ff % mesh_model:
        param["mlp"] = ()
        act["mlp"] = ()
    if cfg.vocab % mesh_model:
        param["vocab"] = ()
        act["vocab"] = ()
    if not cfg.shard_ssm:
        param["ssm_inner"] = ()
        act["ssm_inner"] = ()
        act["ssm_heads"] = ()

    # Megatron-style sequence parallelism on the residual stream during
    # train/prefill (keeps scan-carried remat tensors 1/TP the size -- without
    # it the per-layer residual checkpoints alone overflow HBM).
    if shape.kind in ("train", "prefill") and shape.seq_len % mesh_model == 0:
        act["res_seq"] = ("model",)

    # Decode: KV caches shard their sequence dim (batch alone cannot cover the
    # mesh); B == 1 long-context additionally spreads over data.  Heads are
    # REPLICATED in decode -- a head-sharded q against a seq-sharded cache
    # makes SPMD all-gather the whole KV per token (measured: ~100x collective
    # blow-up); with heads replicated the attention reductions over the
    # sharded seq dim emit only small (B, H, 1, *) all-reduces.
    if shape.kind == "decode":
        act["kv_seq"] = ("data", "model") if shape.global_batch == 1 else ("model",)
        act["kv_enc"] = ("model",)
        act["heads"] = ()   # SSM states keep their head sharding (no conflict)

    return rules.with_overrides(param=param, act=act)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for the step function's data inputs.

    train   -> {"batch": {tokens, labels[, enc_embeds | img_embeds]}}
    prefill -> {"tokens"[, "enc_embeds" | "img_embeds"]}
    decode  -> {"tokens" (B, 1), "index" ()}   (cache specs come from cache_spec)
    """
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    i32 = jnp.int32

    def sds(shp, dt=i32):
        return jax.ShapeDtypeStruct(shp, dt)

    frontends = {}
    if cfg.encoder is not None:
        frontends["enc_embeds"] = sds((b, cfg.encoder.n_ctx, d), dtype)
    if cfg.n_img_tokens:
        frontends["img_embeds"] = sds((b, cfg.n_img_tokens, d), dtype)

    if shape.kind == "train":
        return {"batch": {"tokens": sds((b, s)), "labels": sds((b, s)), **frontends}}
    if shape.kind == "prefill":
        return {"tokens": sds((b, s)), **frontends}
    if shape.kind == "decode":
        return {"tokens": sds((b, 1)), "index": sds(())}
    raise ValueError(f"unknown shape kind {shape.kind!r}")
