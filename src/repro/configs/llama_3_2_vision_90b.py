"""llama-3.2-vision-90b [vlm]: 100L = 20 x (4 self-attn + 1 gated cross-attn to
image tokens), d=8192, 64H (GQA kv=8), ff=28672, vocab=128256.  Vision tower is
a STUB: input_specs() supplies precomputed patch embeddings (1600 tokens).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

from .base import ModelConfig, StageConfig

_BLOCK = (
    ("attn", "dense"),
    ("attn", "dense"),
    ("attn", "dense"),
    ("attn", "dense"),
    ("xattn", "dense"),
)

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    d_model=8192,
    n_heads=64,
    kv_heads=8,
    d_ff=28672,
    vocab=128256,
    stages=(StageConfig(repeats=20, layers=_BLOCK),),
    n_img_tokens=1600,
    rope_theta=500_000.0,
    optimizer="adafactor",
    use_fsdp=True,
    source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
)
