"""granite-3-2b [dense]: 40L, d=2048, 32H (GQA kv=8), ff=8192, vocab=49155,
tied embeddings.  [hf:ibm-granite/granite-3.0-2b-base; hf]"""

from .base import ModelConfig, StageConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    d_model=2048,
    n_heads=32,
    kv_heads=8,
    d_ff=8192,
    vocab=49155,
    stages=(StageConfig(repeats=40, layers=(("attn", "dense"),)),),
    tie_embeddings=True,
    source="[hf:ibm-granite/granite-3.0-2b-base; hf]",
)
