"""internlm2-1.8b [dense]: 24L, d=2048, 16H (GQA kv=8), ff=8192, vocab=92544.
[arXiv:2403.17297; hf]"""

from .base import ModelConfig, StageConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    d_model=2048,
    n_heads=16,
    kv_heads=8,
    d_ff=8192,
    vocab=92544,
    stages=(StageConfig(repeats=24, layers=(("attn", "dense"),)),),
    source="[arXiv:2403.17297; hf]",
)
