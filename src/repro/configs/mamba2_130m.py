"""mamba2-130m [ssm]: attention-free SSD, 24L, d=768, vocab=50280,
ssm_state=128.  Blocks are mamba-only (no separate MLP), tied embeddings.
SSM inner dims (d_in_proj=3352) don't divide a 16-way TP axis -> the 130M
model's SSM weights stay replicated (shard_ssm=False).
[arXiv:2405.21060; unverified]"""

from .base import ModelConfig, SSMConfig, StageConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    d_model=768,
    n_heads=12,          # unused by the SSD mixer; kept for head-dim accounting
    kv_heads=12,
    d_ff=0,
    vocab=50280,
    stages=(StageConfig(repeats=24, layers=(("mamba", "none"),)),),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=128),
    pos_encoding="none",
    tie_embeddings=True,
    shard_ssm=False,
    source="[arXiv:2405.21060; unverified]",
)
