"""starcoder2-3b [dense]: 30L, d=3072, 24H (GQA kv=2), ff=12288, vocab=49152,
GQA + RoPE.  24 heads don't divide a 16-way TP axis -> heads replicated
(shard_heads=False); mlp/vocab still TP-sharded.  [arXiv:2402.19173; hf]"""

from .base import ModelConfig, StageConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    d_model=3072,
    n_heads=24,
    kv_heads=2,
    d_ff=12288,
    vocab=49152,
    stages=(StageConfig(repeats=30, layers=(("attn", "dense"),)),),
    act="gelu",
    rope_theta=100_000.0,
    shard_heads=False,
    source="[arXiv:2402.19173; hf]",
)
