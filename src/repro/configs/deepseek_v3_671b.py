"""deepseek-v3-671b [moe]: 61L (3 dense + 58 MoE), d=7168, 128H MLA,
expert ff=2048, 1 shared + 256 routed top-8, vocab=129280, MTP head.
MLA runs in absorbed/MQA form (see models.attention).  [arXiv:2412.19437; hf]"""

from .base import MLAConfig, ModelConfig, MoEConfig, StageConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    d_model=7168,
    n_heads=128,
    kv_heads=128,                  # per assignment; MLA replaces per-head KV
    d_ff=18432,                    # dense (first-3-layer) FFN width
    vocab=129280,
    stages=(
        StageConfig(repeats=3, layers=(("mla", "dense"),)),
        StageConfig(repeats=58, layers=(("mla", "moe"),)),
    ),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    mtp=True,
    optimizer="adafactor",
    use_fsdp=True,
    source="[arXiv:2412.19437; hf]",
)
