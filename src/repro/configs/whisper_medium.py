"""whisper-medium [audio]: enc-dec, 24+24L, d=1024, 16H (kv=16), ff=4096,
vocab=51865.  Conv frontend is a STUB: input_specs() supplies precomputed frame
embeddings (1500 frames).  [arXiv:2212.04356; unverified]"""

from .base import EncoderConfig, ModelConfig, StageConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    d_model=1024,
    n_heads=16,
    kv_heads=16,
    d_ff=4096,
    vocab=51865,
    stages=(StageConfig(repeats=24, layers=(("attn_x", "dense"),)),),
    encoder=EncoderConfig(n_layers=24, n_ctx=1500),
    act="gelu",
    pos_encoding="sinusoid",
    source="[arXiv:2212.04356; unverified]",
)
