"""jamba-v0.1-52b [hybrid]: 32L = 4 x (8-layer block: 7 mamba + 1 attn at index
4), MoE 16e top-2 on every other layer, d=4096, 32H (GQA kv=8), ff=14336,
vocab=65536.  No positional encoding (Mamba layers carry position).
[arXiv:2403.19887; hf]"""

from .base import ModelConfig, MoEConfig, SSMConfig, StageConfig

_BLOCK = tuple(
    ("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    d_model=4096,
    n_heads=32,
    kv_heads=8,
    d_ff=14336,
    vocab=65536,
    stages=(StageConfig(repeats=4, layers=_BLOCK),),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=128),
    pos_encoding="none",
    use_fsdp=True,
    source="[arXiv:2403.19887; hf]",
)
