"""Architecture + shape configuration dataclasses.

A model is a sequence of *stages*; each stage is a scan over ``repeats`` copies of
a *super-block*, and a super-block is an ordered list of ``(mixer, mlp)`` layers.
Mixers: ``attn`` (causal GQA), ``attn_nc`` (non-causal, encoder), ``attn_x``
(self + cross, whisper decoder), ``xattn`` (cross-attn only, VLM image layers),
``mla`` (DeepSeek latent attention), ``mamba`` (Mamba-2 SSD).
MLPs: ``dense``, ``moe``, ``none``.

Heterogeneous patterns (Jamba 1:7, VLM every-5th-cross) are expressed inside the
super-block so the expensive repetition is always a single ``lax.scan``.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

__all__ = [
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "EncoderConfig",
    "StageConfig",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
]

Layer = tuple[str, str]  # (mixer, mlp)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0              # shared (always-on) experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder consuming precomputed frame embeddings (stub frontend)."""

    n_layers: int
    n_ctx: int = 1500              # frames after the (stubbed) conv frontend


@dataclass(frozen=True)
class StageConfig:
    repeats: int
    layers: tuple[Layer, ...]

    @property
    def n_layers(self) -> int:
        return self.repeats * len(self.layers)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    stages: tuple[StageConfig, ...]
    head_dim: int | None = None
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    encoder: EncoderConfig | None = None
    n_img_tokens: int = 0          # VLM: precomputed patch-embedding count (stub)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    act: str = "swiglu"            # swiglu | gelu
    pos_encoding: str = "rope"     # rope | sinusoid | none
    tie_embeddings: bool = False
    mtp: bool = False              # DeepSeek-style multi-token-prediction head
    mtp_weight: float = 0.1
    max_seq: int = 8192            # RoPE table length; overridden per shape
    # -- runtime policy -----------------------------------------------------
    remat: bool = True
    optimizer: str = "adamw"       # adamw | adafactor (huge models)
    use_fsdp: bool = False
    shard_heads: bool = True       # False when n_heads doesn't divide the TP axis
    shard_ssm: bool = True         # False when SSM inner dims don't divide TP
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    causal_block_skip: bool = True   # skip fully-masked KV blocks (perf opt P1)
    # Cost-probe mode: every lax.scan / lax.map becomes a Python loop so XLA
    # cost_analysis counts every iteration (while bodies are counted ONCE by
    # XLA) -- used only by launch/costprobe.py, never for real execution.
    unroll_loops: bool = False
    source: str = ""               # provenance note [arXiv/hf; tier]

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.stages)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        scale_stages = tuple(
            StageConfig(repeats=min(s.repeats, 2), layers=s.layers) for s in self.stages
        )
        moe = (
            replace(self.moe, n_experts=min(self.moe.n_experts, 8),
                    top_k=min(self.moe.top_k, 2), d_ff_expert=64)
            if self.moe else None
        )
        mla = (
            MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16)
            if self.mla else None
        )
        ssm = (
            replace(self.ssm, d_state=16, head_dim=8, chunk=16) if self.ssm else None
        )
        enc = EncoderConfig(n_layers=2, n_ctx=16) if self.encoder else None
        return replace(
            self,
            name=self.name + "-smoke",
            d_model=64,
            n_heads=4,
            kv_heads=min(self.kv_heads, 2) if self.kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=256,
            stages=scale_stages,
            moe=moe,
            mla=mla,
            ssm=ssm,
            encoder=enc,
            n_img_tokens=8 if self.n_img_tokens else 0,
            max_seq=64,
            attn_q_chunk=16,
            attn_kv_chunk=16,
            use_fsdp=False,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
