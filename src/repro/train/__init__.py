from .loop import TrainLoopConfig, train_loop

__all__ = ["TrainLoopConfig", "train_loop"]
