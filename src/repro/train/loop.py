"""Fault-tolerant training loop.

Posture for 1000+-node fleets, exercised at CPU scale by the tests:

* **checkpoint/restart**: atomic checkpoints every ``ckpt_every`` steps (async
  write); on any step failure the loop restores the latest checkpoint and
  replays -- the seekable data pipeline makes the replay bitwise-identical.
* **step watchdog / straggler detection**: per-step wall time is tracked
  against a running median; steps slower than ``straggler_factor`` x median are
  logged through ``on_straggler`` -- on a real fleet this is the hook that
  triggers hot-spare swap / re-slicing.
* **fault injection**: ``fault_hook(step)`` may raise to simulate a node loss;
  tests assert losses after recovery equal an uninterrupted run.
* **elastic restarts**: checkpoints are mesh-independent; restore takes the
  *current* shardings, so the loop may come back on a different mesh shape.
"""

from __future__ import annotations

import logging
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from ..checkpoint import CheckpointManager

log = logging.getLogger("repro.train")

__all__ = ["TrainLoopConfig", "train_loop"]


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10
    async_ckpt: bool = True


def train_loop(
    step_fn: Callable,            # (params, opt_state, step, batch) -> (p, o, metrics)
    init_state: Callable,         # () -> (params, opt_state)   (fresh init)
    batch_fn: Callable,           # step -> host batch dict
    cfg: TrainLoopConfig,
    shardings: tuple | None = None,     # (param_sh, opt_sh) for elastic restore
    fault_hook: Callable | None = None,  # step -> None (raise to inject fault)
    on_straggler: Callable | None = None,
    on_metrics: Callable | None = None,
):
    """Run to ``total_steps`` with checkpoint/restart.  Returns final state +
    a record of (step, loss) pairs and restart/straggler counts."""
    mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep, async_save=cfg.async_ckpt)

    params, opt_state = init_state()
    start = 0
    restored, step0 = mgr.restore((params, opt_state), shardings=None)
    if restored is not None:
        params, opt_state = restored
        if shardings is not None:
            params = jax.tree.map(jax.device_put, params, shardings[0])
            opt_state = jax.tree.map(jax.device_put, opt_state, shardings[1])
        start = step0 + 1
        log.info("restored checkpoint at step %d", step0)

    history: list[tuple[int, float]] = []
    durations: list[float] = []
    restarts = 0
    stragglers = 0

    step = start
    while step < cfg.total_steps:
        try:
            if fault_hook is not None:
                fault_hook(step)
            t0 = time.time()
            batch = batch_fn(step)
            params, opt_state, metrics = step_fn(
                params, opt_state, np.int32(step), batch
            )
            loss = float(metrics["loss"])
            dt = time.time() - t0
            durations.append(dt)

            if len(durations) >= 5:
                med = statistics.median(durations[-50:])
                if dt > cfg.straggler_factor * med:
                    stragglers += 1
                    log.warning("straggler step %d: %.3fs vs median %.3fs", step, dt, med)
                    if on_straggler is not None:
                        on_straggler(step, dt, med)

            history.append((step, loss))
            if on_metrics is not None:
                on_metrics(step, metrics)
            if step % cfg.log_every == 0:
                log.info("step %d loss %.4f (%.3fs)", step, loss, dt)
            if (step + 1) % cfg.ckpt_every == 0 or step + 1 == cfg.total_steps:
                mgr.save(step, (params, opt_state))
            step += 1
        except KeyboardInterrupt:
            raise
        except Exception as exc:  # noqa: BLE001 -- any node fault
            restarts += 1
            log.error("step %d failed (%s); restart %d/%d", step, exc, restarts,
                      cfg.max_restarts)
            if restarts > cfg.max_restarts:
                raise
            mgr.wait()
            restored, step0 = mgr.restore((params, opt_state))
            if restored is None:
                params, opt_state = init_state()
                step = 0
            else:
                params, opt_state = restored
                if shardings is not None:
                    params = jax.tree.map(jax.device_put, params, shardings[0])
                    opt_state = jax.tree.map(jax.device_put, opt_state, shardings[1])
                step = step0 + 1
            # drop history at/after the replay point so records stay consistent
            history = [(s, l) for (s, l) in history if s < step]

    mgr.wait()
    return {
        "params": params,
        "opt_state": opt_state,
        "history": history,
        "restarts": restarts,
        "stragglers": stragglers,
    }
