"""Mamba-2 SSD (state-space duality) block, chunked (arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: quadratic attention-like math
inside fixed-size chunks + a linear recurrence across chunk states, all in plain
einsums/scans so XLA maps it onto the MXU.  Decode is the O(1) recurrent update
carrying (conv window, SSD state).  The Pallas ``ssd_scan`` kernel implements the
same math for the TPU deployment path and is validated against this reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import rmsnorm
from .sharding import ShardingRules, constrain
from .spec import ParamSpec

__all__ = [
    "mamba_spec",
    "mamba_apply",
    "mamba_decode",
    "mamba_dims",
    "ssd_chunked",
]


def mamba_dims(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return {
        "d_inner": d_inner,
        "n_heads": n_heads,
        "conv_dim": conv_dim,
        "d_in_proj": 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads,
    }


def mamba_spec(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    dims = mamba_dims(cfg)
    return {
        "in_proj": ParamSpec((cfg.d_model, dims["d_in_proj"]), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((s.d_conv, dims["conv_dim"]), (None, "ssm_inner"), scale=1.0),
        "conv_b": ParamSpec((dims["conv_dim"],), ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec((dims["n_heads"],), (None,), init="ones"),
        "dt_bias": ParamSpec((dims["n_heads"],), (None,), init="zeros"),
        "d_skip": ParamSpec((dims["n_heads"],), (None,), init="ones"),
        "norm": ParamSpec((dims["d_inner"],), ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((dims["d_inner"], cfg.d_model), ("ssm_inner", "embed")),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, window d_conv.  xbc: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):  # tiny static K (4): unrolled adds, no gather
        out = out + pad[:, i : i + xbc.shape[1], :].astype(jnp.float32) * w[i]
    return (out + b).astype(xbc.dtype)


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x (..., Q) -> (..., Q, Q) with out[i, j] = sum_{j < k <= i} x[k]; -inf above diag."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,      # (B, S, H, P) inputs
    dt: jnp.ndarray,     # (B, S, H) positive step sizes
    a: jnp.ndarray,      # (H,) negative decay rates
    bmat: jnp.ndarray,   # (B, S, G, N)
    cmat: jnp.ndarray,   # (B, S, G, N)
    chunk: int,
    init_state: jnp.ndarray | None = None,   # (B, H, P, N)
    unroll: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // q

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    bc = bmat.reshape(b, nc, q, g, n)
    cc = cmat.reshape(b, nc, q, g, n)
    # broadcast groups -> heads
    bh = jnp.repeat(bc, rep, axis=3)        # (B,nc,Q,H,N)
    ch = jnp.repeat(cc, rep, axis=3)

    da = dtc * a.astype(jnp.float32)         # (B,nc,Q,H)
    da_cs = jnp.cumsum(da, axis=2)           # inclusive cumsum over chunk

    # 1) intra-chunk (quadratic within chunk)
    l = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))           # (B,nc,H,Q,Q)
    scores = jnp.einsum("bclhn,bcshn->bchls", ch, bh)         # (B,nc,H,Q,S)
    y_diag = jnp.einsum(
        "bchls,bchls,bcsh,bcshp->bclhp",
        scores, l, dtc, xc.astype(jnp.float32),
    )

    # 2) per-chunk states
    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs)       # (B,nc,Q,H)
    states = jnp.einsum(
        "bcshn,bcsh,bcshp->bchpn", bh, decay_states * dtc, xc.astype(jnp.float32)
    )

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])                 # (B,nc,H)
    s0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def body(carry, inp):
        st_in = carry
        st_chunk, dec = inp                                   # (B,H,P,N), (B,H)
        st_out = st_in * dec[:, :, None, None] + st_chunk
        return st_out, st_in                                  # emit state *entering* chunk

    if unroll:
        st = s0
        prev_list = []
        for c in range(nc):
            st, prev = body(st, (states[:, c], chunk_decay[:, c]))
            prev_list.append(prev)
        final = st
        prev_states = jnp.stack(prev_list, axis=1)            # (B,nc,H,P,N)
    else:
        final, prev_states = jax.lax.scan(
            body, s0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
        )
        prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B,nc,H,P,N)

    # 4) inter-chunk output
    y_off = jnp.einsum(
        "bclhn,bchpn,bclh->bclhp", ch, prev_states, jnp.exp(da_cs)
    )

    y = (y_diag + y_off).reshape(b, sp, h, p)[:, :s]
    return y.astype(x.dtype), final


def mamba_apply(
    p: dict,
    xin: jnp.ndarray,                # (B, S, d_model)
    cfg: ModelConfig,
    rules: ShardingRules,
    init_state: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence forward.  Returns (out, (conv_tail, ssd_state)) for cache."""
    s = cfg.ssm
    dims = mamba_dims(cfg)
    di, h, cd = dims["d_inner"], dims["n_heads"], dims["conv_dim"]

    zxbcdt = xin @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + cd]
    dt_raw = zxbcdt[..., di + cd :]                            # (B,S,H)

    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :di].reshape(*xbc.shape[:2], h, s.head_dim)
    bmat = xbc[..., di : di + s.n_groups * s.d_state].reshape(
        *xbc.shape[:2], s.n_groups, s.d_state
    )
    cmat = xbc[..., di + s.n_groups * s.d_state :].reshape(
        *xbc.shape[:2], s.n_groups, s.d_state
    )
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    # NOTE: the SSD inter-chunk scan body is elementwise-only (the heavy
    # einsums are outside the scan), so XLA's count-while-bodies-once cost
    # undercount is negligible here -- probes keep the scan (unroll=False).
    y, state = ssd_chunked(
        xs, dt, a, bmat, cmat, chunk=s.chunk, init_state=init_state, unroll=False
    )
    y = y + xs * p["d_skip"].astype(xs.dtype)[None, None, :, None]
    y = y.reshape(*y.shape[:2], di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    conv_tail = xbc_tail = None
    # cache: last (d_conv - 1) *pre-activation* conv inputs
    zxbc_raw = zxbcdt[..., di : di + cd]
    conv_tail = zxbc_raw[:, -(s.d_conv - 1) :, :]
    return constrain(out, rules, "batch", "seq", "embed"), (conv_tail, state)


def mamba_decode(
    p: dict,
    xin: jnp.ndarray,                # (B, 1, d_model)
    cfg: ModelConfig,
    rules: ShardingRules,
    conv_state: jnp.ndarray,         # (B, d_conv-1, conv_dim)
    ssd_state: jnp.ndarray,          # (B, H, P, N)
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    """O(1) single-token step."""
    s = cfg.ssm
    dims = mamba_dims(cfg)
    di, h, cd = dims["d_inner"], dims["n_heads"], dims["conv_dim"]

    zxbcdt = xin[:, 0] @ p["in_proj"]                          # (B, d_in_proj)
    z = zxbcdt[..., :di]
    xbc_new = zxbcdt[..., di : di + cd]
    dt_raw = zxbcdt[..., di + cd :]

    window = jnp.concatenate([conv_state, xbc_new[:, None]], axis=1)  # (B, d_conv, cd)
    conv = (window.astype(jnp.float32) * p["conv_w"][None].astype(jnp.float32)).sum(1)
    xbc = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32)).astype(xin.dtype)

    xs = xbc[..., :di].reshape(-1, h, s.head_dim)
    bmat = xbc[..., di : di + s.n_groups * s.d_state].reshape(-1, s.n_groups, s.d_state)
    cmat = xbc[..., di + s.n_groups * s.d_state :].reshape(-1, s.n_groups, s.d_state)
    rep = h // s.n_groups
    bh = jnp.repeat(bmat, rep, axis=1)                         # (B,H,N)
    ch = jnp.repeat(cmat, rep, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                                    # (B,H)

    new_state = ssd_state * decay[:, :, None, None] + jnp.einsum(
        "bhn,bh,bhp->bhpn", bh, dt, xs.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhpn->bhp", ch, new_state)
    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(-1, di).astype(xin.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None]                          # (B,1,d)
    return constrain(out, rules, "batch", "seq", "embed"), (window[:, 1:], new_state)
