"""Attention: XLA-native blockwise (flash-equivalent) GQA, MLA, cross-attention.

The training/prefill path is *chunked* online-softmax attention (lax.scan over KV
blocks inside a map over Q blocks) so a 32k-token prefill never materializes an
(S x S) score matrix -- this is the XLA-level equivalent of the Pallas flash
kernel in ``repro.kernels.flash_attention_kernel`` (the TPU deployment path and
is validated against the same reference).  Decode (Sq == 1) uses direct softmax
over the cache.

Sharding notes (production meshes shard ``heads`` over the ``model`` axis):
KV is repeated group->heads *inside each KV chunk* so every attention einsum
carries a plain ``h`` dim; the repeat is chunk-local (bytes ~ kv_chunk) and lets
SPMD keep all score/accumulator tensors head-sharded with no (g, rep) reshape
ambiguity.

MLA (DeepSeek-V3) is implemented in its **absorbed / MQA-equivalent form**: the
latent cache ``c_kv`` acts as a single shared KV head of width
``kv_lora_rank (+ rope)``; q_nope is absorbed through ``wkv_b``'s K half and the
attention output is re-projected through its V half.  Expanded per-head K/V are
NEVER materialized -- this is what makes the 32k prefill / decode shapes fit, and
it matches how MLA is actually served.

Caches are fixed-capacity buffers updated with dynamic_update_slice, so one
compiled ``serve_step`` serves every position.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import rmsnorm
from .sharding import ShardingRules, constrain
from .spec import ParamSpec

__all__ = [
    "rope_cos_sin",
    "rope_rotate",
    "chunked_attention",
    "direct_attention",
    "attn_spec",
    "attn_apply",
    "mla_spec",
    "mla_apply",
    "xattn_spec",
    "xattn_kv",
    "xattn_apply",
]

NEG_INF = -1e30


@jax.custom_vjp
def _pinned(xs):
    """``optimization_barrier`` with a gradient rule.

    ``jax.lax.optimization_barrier`` has no differentiation rule, so using it on
    the training path raises ``NotImplementedError`` under ``grad``.  The barrier
    only constrains XLA scheduling -- mathematically it is the identity -- so the
    VJP passes cotangents straight through.  (No barrier on the backward pass:
    cotangents for integer leaves are ``float0`` placeholders that
    ``optimization_barrier`` cannot consume, and the backward all-gathers are
    not the ones being pinned.)
    """
    return jax.lax.optimization_barrier(xs)


def _pinned_fwd(xs):
    return _pinned(xs), None


def _pinned_bwd(_, g):
    return (g,)


_pinned.defvjp(_pinned_fwd, _pinned_bwd)


def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions (..., S) int -> cos, sin (..., S, head_dim//2), computed on the fly."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def rope_rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, H, hd); cos/sin (..., S, hd//2)."""
    hd = x.shape[-1]
    c = cos[..., None, :]
    s = sin[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _repeat_kv(k: jnp.ndarray, h: int) -> jnp.ndarray:
    """(..., G, hd) -> (..., H, hd) by repeating each group H/G times."""
    g = k.shape[-2]
    if g == h:
        return k
    return jnp.repeat(k, h // g, axis=-2)


def chunked_attention(
    q: jnp.ndarray,                 # (B, Sq, H, hd)
    k: jnp.ndarray,                 # (B, Skv, G, hd)
    v: jnp.ndarray,                 # (B, Skv, G, hd_v)
    *,
    causal: bool,
    q_positions: jnp.ndarray,       # (Sq,) int32 absolute positions
    kv_len: jnp.ndarray | int,      # number of valid kv entries
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    scale: float | None = None,
    unroll: bool = False,
    q_start: int | None = None,
) -> jnp.ndarray:
    """Online-softmax blockwise attention; fp32 accumulators; O(Sq*hd) memory.

    ``unroll=True`` replaces the scan/map with Python loops (identical math) so
    cost probes see every block's FLOPs; never used on the execution path.

    ``q_start`` (static) enables **causal block skipping**: when the absolute
    position of query row 0 is known at trace time, each q block only scans the
    KV prefix it can attend to -- for nq = nk = n blocks this removes the
    n(n-1)/2 fully-masked upper-triangle block pairs (~48% of attention
    FLOPs/bytes at 32k prefill).  Masked-block results are bit-identical to the
    full scan (they contributed exp(-inf) = 0)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    hd_v = v.shape[-1]
    scale = (1.0 / (hd ** 0.5)) if scale is None else scale

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)

    qp = _pad_to(q, 1, q_chunk)
    qpos = _pad_to(q_positions, 0, q_chunk)
    sq_p = qp.shape[1]
    kp = _pad_to(k, 1, kv_chunk)
    vp = _pad_to(v, 1, kv_chunk)
    skv_p = kp.shape[1]
    kv_pos = jnp.arange(skv_p, dtype=jnp.int32)

    nq, nk = sq_p // q_chunk, skv_p // kv_chunk
    qp = qp.reshape(b, nq, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    qpos = qpos.reshape(nq, q_chunk)
    kp = kp.reshape(b, nk, kv_chunk, k.shape[2], hd).transpose(1, 0, 2, 3, 4)
    vp = vp.reshape(b, nk, kv_chunk, v.shape[2], hd_v).transpose(1, 0, 2, 3, 4)
    kv_pos = kv_pos.reshape(nk, kv_chunk)

    kv_len = jnp.asarray(kv_len, jnp.int32)

    def one_q_block(args, n_kv: int | None = None):
        q_c, qpos_c = args  # (B, Qc, H, hd), (Qc,)

        @jax.checkpoint
        def body(carry, kv_c):
            m, l, acc = carry
            k_c, v_c, kvpos_c = kv_c
            kh = _repeat_kv(k_c, h)                 # chunk-local group->head repeat
            vh = _repeat_kv(v_c, h)
            s = jnp.einsum(
                "bqhk,bshk->bhqs", q_c, kh, preferred_element_type=jnp.float32
            ) * scale
            valid = kvpos_c[None, :] < kv_len
            if causal:
                valid = valid & (qpos_c[:, None] >= kvpos_c[None, :])
            s = jnp.where(valid[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqs,bshk->bhqk", p.astype(vh.dtype), vh,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        nkv = nk if n_kv is None else n_kv
        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, hd_v), jnp.float32)
        if unroll:
            carry = (m0, l0, a0)
            for j in range(nkv):
                carry, _ = body(carry, (kp[j], vp[j], kv_pos[j]))
            m, l, acc = carry
        elif n_kv is not None and n_kv < nk:
            # causal block skipping: a fori_loop over the FULL kv buffer with a
            # static trip count.  (Slicing xs per q block -- kp[:nkv] -- makes
            # sibling while loops with different tuple shapes, which trips an
            # XLA while-CSE bug under SPMD; with fori_loop every loop has
            # identical operands and only the bound constant differs.)
            def body_fori(j, carry):
                new_carry, _ = body(carry, (kp[j], vp[j], kv_pos[j]))
                return new_carry

            m, l, acc = jax.lax.fori_loop(0, nkv, body_fori, (m0, l0, a0))
        else:
            (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kp, vp, kv_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3)  # (B, Qc, H, hd_v)

    if causal and q_start is not None:
        # causal block skipping: q block i sees kv chunks [0, n_need(i)).
        # Pin the (gathered) KV buffers ONCE before the per-block loops --
        # otherwise XLA sinks a fresh seq all-gather into every loop body
        # (measured +50% all-gather bytes on a 4k train cell without this).
        kp, vp, kv_pos = _pinned((kp, vp, kv_pos))
        outs = []
        for i in range(nq):
            last_pos = q_start + (i + 1) * q_chunk - 1
            n_need = max(1, min(nk, last_pos // kv_chunk + 1))
            outs.append(one_q_block((qp[i], qpos[i]), n_kv=n_need))
        out = jnp.stack(outs)
    elif unroll:
        out = jnp.stack([one_q_block((qp[i], qpos[i])) for i in range(nq)])
    else:
        out = jax.lax.map(one_q_block, (qp, qpos))      # (nq, B, Qc, H, hd_v)
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, sq_p, h, hd_v)
    return out[:, :sq].astype(q.dtype)


def direct_attention(
    q: jnp.ndarray,                 # (B, Sq, H, hd) -- decode: Sq small
    k: jnp.ndarray,                 # (B, Skv, G, hd)
    v: jnp.ndarray,
    *,
    causal: bool,
    q_positions: jnp.ndarray,
    kv_len: jnp.ndarray | int,
    scale: float | None = None,
) -> jnp.ndarray:
    """Direct softmax attention over the whole KV; decode path (Sq tiny).

    Works with a seq-sharded KV cache: queries stay in grouped (g, rep) form so
    the KV is never repeated or gathered -- the score/weighted-value einsums
    reduce over the sharded seq dim, SPMD emits only small all-reduces of
    (B, H, Sq, *) tensors.  Decode rules replicate heads so nothing conflicts
    with the cache's seq sharding.
    """
    b, sq, h, hd = q.shape
    g = k.shape[2]
    rep = h // g
    scale = (1.0 / (hd ** 0.5)) if scale is None else scale
    qg = q.reshape(b, sq, g, rep, hd)
    s = jnp.einsum("bqgrk,bsgk->bgrqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    valid = kv_pos[None, :] < jnp.asarray(kv_len, jnp.int32)
    if causal:
        valid = valid & (q_positions[:, None] >= kv_pos[None, :])
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrqs,bsgk->bqgrk", p, v, preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA self-attention layer
# ---------------------------------------------------------------------------


def attn_spec(cfg: ModelConfig) -> dict:
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.resolved_head_dim
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, g, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, g, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }


def attn_apply(
    p: dict,
    x: jnp.ndarray,                       # (B, S, d)
    cfg: ModelConfig,
    rules: ShardingRules,
    *,
    positions: jnp.ndarray,               # (S,) int32
    causal: bool = True,
    use_rope: bool = True,
    cache: dict | None = None,            # {'k','v'}: (B, Smax, G, hd)
    cache_index: jnp.ndarray | None = None,
    q_start: int | None = None,           # static row-0 position (causal skip)
    axo=None,                             # (AxODeployment, layer mixer entries)
):
    """Returns (out, new_cache).

    ``axo`` routes the q/k/v/o projections through the approximate operator's
    cached weight factors (attention *math* -- scores/softmax -- stays exact;
    AxO replaces multiplier arrays, i.e. the matmuls).
    """
    if axo is not None and "wq" in axo[1]:
        dep, ent = axo
        b_, s_ = x.shape[:2]
        h_, hd_ = p["wq"].shape[1], p["wq"].shape[2]
        g_ = p["wk"].shape[1]
        q = dep.apply(x, ent["wq"]).reshape(b_, s_, h_, hd_)
        k = dep.apply(x, ent["wk"]).reshape(b_, s_, g_, hd_)
        v = dep.apply(x, ent["wv"]).reshape(b_, s_, g_, hd_)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = jnp.einsum("bsd,dgk->bsgk", x, p["wk"])
        v = jnp.einsum("bsd,dgk->bsgk", x, p["wv"])
    q = constrain(q, rules, "batch", "seq", "heads", "head_dim")
    k = constrain(k, rules, "batch", "seq", "kv_heads", "head_dim")

    if use_rope:
        cos, sin = rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)
        q = rope_rotate(q, cos, sin)
        k = rope_rotate(k, cos, sin)

    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        kv_len = cache_index + x.shape[1]
    else:
        kv_len = x.shape[1]

    if x.shape[1] <= 4:  # decode path
        out = direct_attention(q, k, v, causal=causal, q_positions=positions, kv_len=kv_len)
    else:
        out = chunked_attention(
            q, k, v, causal=causal, q_positions=positions, kv_len=kv_len,
            q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
            unroll=cfg.unroll_loops,
            q_start=q_start if cfg.causal_block_skip else None,
        )
    if axo is not None and "wo" in axo[1]:
        dep, ent = axo
        out = dep.apply(out.reshape(*out.shape[:2], -1), ent["wo"])
    else:
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return constrain(out, rules, "batch", "seq", "embed"), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 latent attention) -- absorbed / MQA-equivalent form
# ---------------------------------------------------------------------------


def mla_spec(cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = m.nope_head_dim + m.rope_head_dim
    return {
        "wq_a": ParamSpec((d, m.q_lora_rank), ("embed", "lora")),
        "q_norm": ParamSpec((m.q_lora_rank,), ("lora",), init="ones"),
        "wq_b": ParamSpec((m.q_lora_rank, h, qd), ("lora", "heads", "head_dim")),
        "wkv_a": ParamSpec((d, m.kv_lora_rank + m.rope_head_dim), ("embed", "lora")),
        "kv_norm": ParamSpec((m.kv_lora_rank,), ("lora",), init="ones"),
        "wkv_b": ParamSpec(
            (m.kv_lora_rank, h, m.nope_head_dim + m.v_head_dim),
            ("lora", "heads", "head_dim"),
        ),
        "wo": ParamSpec((h, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }


def mla_apply(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    rules: ShardingRules,
    *,
    positions: jnp.ndarray,
    cache: dict | None = None,            # {'ckv': (B,Smax,r), 'kpe': (B,Smax,rope)}
    cache_index: jnp.ndarray | None = None,
    q_start: int | None = None,
    axo=None,                             # (AxODeployment, layer mixer entries)
):
    """Absorbed-form MLA.  The latent c_kv (+ shared rope key) is the entire KV:
    a single shared "KV head" of width r + rope; q_nope is absorbed through the
    K-half of wkv_b so scores live in latent space, and the attention output (in
    latent space) is re-projected through the V-half.  Softmax scale is that of
    the *unabsorbed* head width (nope + rope).

    With ``axo``, the plain last-dim linears (wq_a, wq_b, wkv_a, wo) run on the
    approximate operator; ``wkv_b`` stays exact -- its absorbed halves contract
    per-head against latents, not as a (K, N) linear.
    """
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    a_ent = axo[1] if axo is not None else {}

    def lin(name, fn_exact, v):
        if name in a_ent:
            return axo[0].apply(v, a_ent[name])
        return fn_exact(v)

    q = rmsnorm(lin("wq_a", lambda v: v @ p["wq_a"], x), p["q_norm"], cfg.norm_eps)
    if "wq_b" in a_ent:
        qd = m.nope_head_dim + m.rope_head_dim
        q = axo[0].apply(q, a_ent["wq_b"]).reshape(b, s, h, qd)
    else:
        q = jnp.einsum("bsr,rhk->bshk", q, p["wq_b"])
    q = constrain(q, rules, "batch", "seq", "heads", "head_dim")
    q_nope = q[..., : m.nope_head_dim]
    q_pe = q[..., m.nope_head_dim :]

    kv = lin("wkv_a", lambda v: v @ p["wkv_a"], x)
    ckv = rmsnorm(kv[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    kpe = kv[..., m.kv_lora_rank :][:, :, None, :]   # (B,S,1,rope) shared head

    cos, sin = rope_cos_sin(positions, m.rope_head_dim, cfg.rope_theta)
    q_pe = rope_rotate(q_pe, cos, sin)
    kpe = rope_rotate(kpe, cos, sin)[:, :, 0, :]

    # Absorb q_nope through wkv_b's K half: (B,S,H,nope) x (r,H,nope) -> (B,S,H,r)
    wk_half = p["wkv_b"][..., : m.nope_head_dim]          # (r, H, nope)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, wk_half)
    q_full = jnp.concatenate([q_lat, q_pe], axis=-1)      # (B,S,H,r+rope)

    new_cache = None
    if cache is not None:
        cckv = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_index, axis=1)
        ckpe = jax.lax.dynamic_update_slice_in_dim(
            cache["kpe"], kpe.astype(cache["kpe"].dtype), cache_index, axis=1)
        new_cache = {"ckv": cckv, "kpe": ckpe}
        ckv, kpe = cckv, ckpe
        kv_len = cache_index + s
    else:
        kv_len = s

    # Latent K and V: one shared head (MQA form).
    k_lat = jnp.concatenate([ckv, kpe], axis=-1)[:, :, None, :]  # (B,Skv,1,r+rope)
    v_lat = ckv[:, :, None, :]                                   # (B,Skv,1,r)
    att_scale = 1.0 / ((m.nope_head_dim + m.rope_head_dim) ** 0.5)

    if s <= 4 and cache is not None:
        ctx = direct_attention(
            q_full, k_lat, v_lat, causal=True, q_positions=positions,
            kv_len=kv_len, scale=att_scale,
        )
    else:
        ctx = chunked_attention(
            q_full, k_lat, v_lat, causal=True, q_positions=positions, kv_len=kv_len,
            q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk, scale=att_scale,
            unroll=cfg.unroll_loops,
            q_start=q_start if cfg.causal_block_skip else None,
        )
    # ctx: (B,S,H,r) in latent space; re-project through wkv_b's V half.
    wv_half = p["wkv_b"][..., m.nope_head_dim :]          # (r, H, v_hd)
    out = jnp.einsum("bshr,rhk->bshk", ctx, wv_half)
    if "wo" in a_ent:
        out = axo[0].apply(out.reshape(b, s, -1), a_ent["wo"])
    else:
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return constrain(out, rules, "batch", "seq", "embed"), new_cache


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder / VLM image layers)
# ---------------------------------------------------------------------------


def xattn_spec(cfg: ModelConfig) -> dict:
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.resolved_head_dim
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, g, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, g, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
        "gate": ParamSpec((1,), (None,), init="zeros"),   # VLM-style tanh gate
    }


def xattn_kv(p: dict, enc: jnp.ndarray, axo=None):
    """Precompute cross K/V from encoder/image states (cached for decode)."""
    if axo is not None and "wk" in axo[1]:
        dep, ent = axo
        b_, s_ = enc.shape[:2]
        g_, hd_ = p["wk"].shape[1], p["wk"].shape[2]
        k = dep.apply(enc, ent["wk"]).reshape(b_, s_, g_, hd_)
        v = dep.apply(enc, ent["wv"]).reshape(b_, s_, g_, hd_)
    else:
        k = jnp.einsum("bsd,dgk->bsgk", enc, p["wk"])
        v = jnp.einsum("bsd,dgk->bsgk", enc, p["wv"])
    return k, v


def xattn_apply(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    rules: ShardingRules,
    *,
    kv: tuple[jnp.ndarray, jnp.ndarray],   # precomputed (k, v) from encoder states
    gated: bool = False,
    axo=None,                              # (AxODeployment, layer mixer entries)
):
    if axo is not None and "wq" in axo[1]:
        dep, ent = axo
        b_, s_ = x.shape[:2]
        h_, hd_ = p["wq"].shape[1], p["wq"].shape[2]
        q = dep.apply(x, ent["wq"]).reshape(b_, s_, h_, hd_)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = constrain(q, rules, "batch", "seq", "heads", "head_dim")
    k, v = kv
    if x.shape[1] <= 4:
        out = direct_attention(
            q, k, v, causal=False,
            q_positions=jnp.arange(x.shape[1], dtype=jnp.int32),
            kv_len=k.shape[1],
        )
    else:
        out = chunked_attention(
            q, k, v, causal=False,
            q_positions=jnp.arange(x.shape[1], dtype=jnp.int32),
            kv_len=k.shape[1],
            q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
            unroll=cfg.unroll_loops,
        )
    if axo is not None and "wo" in axo[1]:
        dep, ent = axo
        out = dep.apply(out.reshape(*out.shape[:2], -1), ent["wo"])
    else:
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if gated:
        out = jnp.tanh(p["gate"].astype(out.dtype)) * out
    return constrain(out, rules, "batch", "seq", "embed")
