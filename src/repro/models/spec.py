"""Parameter-spec trees: declare shapes + logical axes once, then materialize as
real arrays (init), ShapeDtypeStructs (dry-run -- no allocation), or
PartitionSpecs (sharding)."""

from __future__ import annotations

import hashlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .sharding import ShardingRules, named_sharding

__all__ = [
    "ParamSpec",
    "init_params",
    "abstract_params",
    "param_pspecs",
    "param_shardings",
    "count_params",
    "stacked",
]


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float = 1.0            # stddev multiplier for normal init
    dtype: str | None = None      # override the tree-level dtype (e.g. fp32 states)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")

    def resolved_dtype(self, default):
        return jnp.dtype(self.dtype) if self.dtype else default


def stacked(spec: ParamSpec, n: int) -> ParamSpec:
    """Add a leading scan-stack axis."""
    return ParamSpec(
        shape=(n, *spec.shape), axes=("stack", *spec.axes), init=spec.init, scale=spec.scale
    )


def _path_seed(path: str, base_seed: int) -> int:
    h = hashlib.blake2s(f"{base_seed}:{path}".encode(), digest_size=8).digest()
    return int.from_bytes(h, "little") % (2**63)


def _leaf_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaf_paths(tree[k], f"{prefix}/{k}")
    else:
        yield prefix, tree


def init_params(tree, seed: int = 0, dtype=jnp.bfloat16):
    """Materialize a spec tree with deterministic per-leaf seeding."""

    def make(path: str, spec: ParamSpec):
        dt = spec.resolved_dtype(dtype)
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        key = jax.random.key(_path_seed(path, seed))
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)

    return _map_with_path(tree, make)


def abstract_params(tree, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.resolved_dtype(dtype)), tree
    )


def param_pspecs(tree, rules: ShardingRules, kind: str = "param"):
    return jax.tree.map(lambda s: rules.resolve(s.axes, kind=kind), tree)


def param_shardings(tree, rules: ShardingRules, mesh, kind: str = "param"):
    return jax.tree.map(
        lambda s: named_sharding(mesh, rules.resolve(s.axes, kind=kind), s.shape),
        tree,
    )


def count_params(tree) -> int:
    return sum(int(np.prod(s.shape)) for _, s in _leaf_paths(tree))


def _map_with_path(tree, fn, prefix=""):
    if isinstance(tree, dict):
        return {k: _map_with_path(v, fn, f"{prefix}/{k}") for k, v in tree.items()}
    return fn(prefix, tree)
