"""Shared neural layers: norms, RoPE, dense/gated MLPs, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .spec import ParamSpec

__all__ = [
    "rmsnorm",
    "rope_freqs",
    "apply_rope",
    "sinusoid_pos",
    "mlp_spec",
    "mlp_apply",
    "embed_spec",
]


def sinusoid_pos(positions: jnp.ndarray, d_model: int, base: float = 10_000.0) -> jnp.ndarray:
    """Transformer sinusoidal absolute position embeddings: (S,) -> (S, d)."""
    half = d_model // 2
    freq = jnp.exp(-jnp.log(base) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt((x32 * x32).mean(-1, keepdims=True) + eps)
    return (x32 * scale).astype(x.dtype) * gamma


def rope_freqs(head_dim: int, max_seq: int, theta: float) -> jnp.ndarray:
    """(max_seq, head_dim//2) complex-free cos/sin stacked -> (max_seq, head_dim)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_seq, dtype=jnp.float32)
    ang = jnp.outer(t, inv)                      # (S, hd/2)
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)  # (S, hd)


def apply_rope(x: jnp.ndarray, freqs: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S) int32."""
    hd = x.shape[-1]
    f = freqs[positions]                         # (..., S, hd)
    cos, sin = f[..., : hd // 2], f[..., hd // 2 :]
    cos = cos[..., None, :]                      # add head axis
    sin = sin[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_spec(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "w_gate": ParamSpec((d, f), ("embed", "mlp")),
            "w_up": ParamSpec((d, f), ("embed", "mlp")),
            "w_down": ParamSpec((f, d), ("mlp", "embed")),
        }
    return {
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }


def mlp_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig, axo=None) -> jnp.ndarray:
    """Dense FFN.  ``axo`` = (AxODeployment, entries) runs each projection on
    the approximate operator's cached weight factors (activations stay exact)."""
    ent = axo[1] if axo is not None else {}

    def lin(name, v):
        if name in ent:
            return axo[0].apply(v, ent[name])
        return v @ p[name]

    if cfg.act == "swiglu":
        h = jax.nn.silu(lin("w_gate", x)) * lin("w_up", x)
    else:
        h = jax.nn.gelu(lin("w_up", x))
    return lin("w_down", h)


def embed_spec(cfg: ModelConfig) -> dict:
    out = {"tok": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        out["unembed"] = ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return out
