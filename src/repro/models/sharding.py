"""Logical-axis sharding (MaxText-style rule tables).

Params and activations are annotated with *logical* axis names; a rule table maps
each logical name to zero or more mesh axes.  Two tables exist because FSDP shards
the same logical dim of a *weight* differently from the matching activation dim.

Mesh axes: ``pod`` (multi-pod only), ``data``, ``model``.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules", "BASE_RULES", "logical_pspec", "constrain",
    "named_sharding", "set_mesh",
]

MeshAxes = tuple[str, ...]


@dataclass(frozen=True)
class ShardingRules:
    """logical name -> tuple of mesh axes (() = replicated)."""

    param_rules: dict[str, MeshAxes] = field(default_factory=dict)
    act_rules: dict[str, MeshAxes] = field(default_factory=dict)

    def with_fsdp(self) -> "ShardingRules":
        """ZeRO-3-style: additionally shard weight 'embed'/'ff_in' dims over data."""
        pr = dict(self.param_rules)
        pr["embed"] = ("data",)
        pr["expert_ff"] = ("data",)   # second expert dim: EP over model, FSDP over data
        return replace(self, param_rules=pr)

    def with_overrides(self, param: dict | None = None, act: dict | None = None) -> "ShardingRules":
        pr = dict(self.param_rules)
        pr.update(param or {})
        ar = dict(self.act_rules)
        ar.update(act or {})
        return ShardingRules(param_rules=pr, act_rules=ar)

    def resolve(self, axes: tuple[str | None, ...], kind: str = "param") -> P:
        table = self.param_rules if kind == "param" else self.act_rules
        used: set[str] = set()
        parts = []
        for name in axes:
            if name is None:
                parts.append(None)
                continue
            mesh_axes = tuple(a for a in table.get(name, ()) if a not in used)
            used.update(mesh_axes)
            if len(mesh_axes) == 0:
                parts.append(None)
            elif len(mesh_axes) == 1:
                parts.append(mesh_axes[0])
            else:
                parts.append(mesh_axes)
        return P(*parts)


BASE_RULES = ShardingRules(
    param_rules={
        # weight dims
        "embed": (),              # replicated unless FSDP
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "experts": ("model",),    # expert parallelism
        "expert_ff": (),
        "stack": (),              # scan-stacked layer axis: never sharded
        "ssm_inner": ("model",),
        "lora": (),
        "head_dim": (),
    },
    act_rules={
        "batch": ("pod", "data"),
        "seq": (),
        "res_seq": (),            # residual-stream seq: ("model",) = Megatron-SP
        "kv_seq": (),             # decode KV caches: ("model",) / ("data","model")
        "kv_enc": (),             # cross-attention KV length (encoder/image tokens)
        "embed": (),
        "heads": ("model",),
        "kv_heads": (),           # KV heads (<= mesh model size only rarely): repl.
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "capacity": (),
        "ssm_inner": ("model",),
        "ssm_heads": ("model",),
        "head_dim": (),
        "lora": (),
    },
)


def logical_pspec(rules: ShardingRules, axes: tuple[str | None, ...], kind: str) -> P:
    return rules.resolve(axes, kind)


def named_sharding(mesh: Mesh, spec: P, shape: tuple[int, ...] | None = None) -> NamedSharding:
    """NamedSharding with two safeguards:

    * mesh axes the mesh doesn't have are dropped ('pod' on the single-pod mesh);
    * if ``shape`` is given, axes whose product doesn't divide the dim are
      pruned greedily (jit in_shardings demand exact divisibility -- e.g. a
      batch-1 long-context cache can't shard its batch dim).
    """

    def keep(i: int, part):
        if part is None:
            return None
        parts = part if isinstance(part, tuple) else (part,)
        parts = tuple(p for p in parts if p in mesh.axis_names)
        if shape is not None:
            kept = []
            dim = shape[i]
            for p in parts:
                n = mesh.shape[p]
                if dim % n == 0:
                    kept.append(p)
                    dim //= n
            parts = tuple(kept)
        if not parts:
            return None
        return parts[0] if len(parts) == 1 else parts

    return NamedSharding(mesh, P(*(keep(i, p) for i, p in enumerate(spec))))


def constrain(x, rules: ShardingRules, *axes: str | None):
    """with_sharding_constraint via logical activation axes (no-op off-mesh)."""
    mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = rules.resolve(tuple(axes), kind="act")
    return jax.lax.with_sharding_constraint(x, named_sharding(mesh, spec))


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` portable across JAX versions.

    Newer JAX hoists shard_map to the top level with a ``check_vma`` flag; on
    0.4.x it lives in ``jax.experimental.shard_map`` with ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )


def set_mesh(mesh: Mesh):
    """Ambient-mesh context manager, portable across JAX versions.

    Newer JAX exposes ``jax.set_mesh``; older releases (e.g. 0.4.x) only have
    the ``with mesh:`` thread-resources context, which ``_current_mesh`` below
    also recognizes.  A ``Mesh`` is itself a context manager, so returning it
    directly gives the fallback.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def _current_mesh() -> Mesh | None:
    """Mesh in scope: ``with mesh:`` (thread resources) or ``use_mesh`` (abstract)."""
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None
