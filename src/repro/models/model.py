"""Model assembly: spec trees, caches, forward (train / prefill / decode), loss.

A model is ``embed -> stages -> final norm -> unembed``; each stage scans a
super-block of layers over ``repeats`` (single compiled block regardless of
depth), with optional remat.  Heterogeneous families are all expressed through
the super-block layer list:

  dense    [(attn, dense)]
  moe      [(attn|mla, moe)]  (+ leading dense stage for DeepSeek-V3)
  ssm      [(mamba, none)]
  hybrid   jamba 8-layer block: 7 mamba + 1 attn, alternating dense/moe MLPs
  encdec   whisper: encoder stage of (attn_nc, dense) + decoder (attn_x, dense)
  vlm      5-layer block: 4 (attn, dense) + 1 (xattn, dense)

Caches are fixed-capacity, stacked over ``repeats`` so the same scan drives
decode.  Modality frontends are STUBS by assignment: whisper consumes
precomputed frame embeddings, the VLM precomputed patch embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, StageConfig
from .attention import (
    attn_apply,
    attn_spec,
    mla_apply,
    mla_spec,
    xattn_apply,
    xattn_kv,
    xattn_spec,
)
from .layers import embed_spec, mlp_apply, mlp_spec, rmsnorm, sinusoid_pos
from .moe import moe_apply, moe_spec
from .sharding import ShardingRules, constrain
from .spec import ParamSpec, stacked
from .ssm import mamba_apply, mamba_decode, mamba_dims, mamba_spec

__all__ = [
    "model_spec",
    "cache_spec",
    "forward",
    "compute_loss",
    "HAS_CACHE",
]

# Which mixer kinds carry decode state.
HAS_CACHE = {"attn": True, "attn_x": True, "xattn": True, "mla": True,
             "mamba": True, "attn_nc": False}


# ---------------------------------------------------------------------------
# Param spec tree
# ---------------------------------------------------------------------------


def _mixer_spec(cfg: ModelConfig, mixer: str) -> dict:
    if mixer in ("attn", "attn_nc"):
        return attn_spec(cfg)
    if mixer == "attn_x":                      # whisper decoder: self + cross
        return {
            "self": attn_spec(cfg),
            "cross": xattn_spec(cfg),
            "norm_x": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        }
    if mixer == "xattn":
        return xattn_spec(cfg)
    if mixer == "mla":
        return mla_spec(cfg)
    if mixer == "mamba":
        return mamba_spec(cfg)
    raise ValueError(f"unknown mixer {mixer!r}")


def _mlp_spec(cfg: ModelConfig, mlp: str) -> dict | None:
    if mlp == "dense":
        return mlp_spec(cfg)
    if mlp == "moe":
        return moe_spec(cfg)
    if mlp == "none":
        return None
    raise ValueError(f"unknown mlp {mlp!r}")


def _layer_spec(cfg: ModelConfig, mixer: str, mlp: str) -> dict:
    out = {
        "norm1": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "mixer": _mixer_spec(cfg, mixer),
    }
    ms = _mlp_spec(cfg, mlp)
    if ms is not None:
        out["norm2"] = ParamSpec((cfg.d_model,), ("embed",), init="ones")
        out["mlp"] = ms
    return out


def _stage_spec(cfg: ModelConfig, stage: StageConfig) -> dict:
    block = {str(i): _layer_spec(cfg, mixer, mlp) for i, (mixer, mlp) in enumerate(stage.layers)}
    return jax.tree.map(
        lambda s: stacked(s, stage.repeats), block,
        is_leaf=lambda s: isinstance(s, ParamSpec),
    )


def model_spec(cfg: ModelConfig) -> dict:
    out = {"embed": embed_spec(cfg)}
    out["stages"] = {str(i): _stage_spec(cfg, s) for i, s in enumerate(cfg.stages)}
    out["norm_f"] = ParamSpec((cfg.d_model,), ("embed",), init="ones")
    if cfg.encoder is not None:
        enc_stage = StageConfig(repeats=cfg.encoder.n_layers, layers=(("attn_nc", "dense"),))
        out["encoder"] = {
            "stage": _stage_spec(cfg, enc_stage),
            "norm_f": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        }
    if cfg.mtp:
        d = cfg.d_model
        out["mtp"] = {
            "norm_h": ParamSpec((d,), ("embed",), init="ones"),
            "norm_e": ParamSpec((d,), ("embed",), init="ones"),
            "proj": ParamSpec((2 * d, d), (None, "embed")),
        }
    return out


# ---------------------------------------------------------------------------
# Cache spec tree
# ---------------------------------------------------------------------------


def _layer_cache_spec(
    cfg: ModelConfig, mixer: str, batch: int, max_seq: int, enc_len: int
) -> dict | None:
    g, hd = cfg.kv_heads, cfg.resolved_head_dim
    kv_axes = ("batch", "kv_seq", "kv_heads", "head_dim")
    enc_axes = ("batch", "kv_enc", "kv_heads", "head_dim")
    if mixer == "attn":
        return {
            "k": ParamSpec((batch, max_seq, g, hd), kv_axes, init="zeros"),
            "v": ParamSpec((batch, max_seq, g, hd), kv_axes, init="zeros"),
        }
    if mixer == "attn_x":
        return {
            "k": ParamSpec((batch, max_seq, g, hd), kv_axes, init="zeros"),
            "v": ParamSpec((batch, max_seq, g, hd), kv_axes, init="zeros"),
            "xk": ParamSpec((batch, enc_len, g, hd), enc_axes, init="zeros"),
            "xv": ParamSpec((batch, enc_len, g, hd), enc_axes, init="zeros"),
        }
    if mixer == "xattn":
        return {
            "xk": ParamSpec((batch, enc_len, g, hd), enc_axes, init="zeros"),
            "xv": ParamSpec((batch, enc_len, g, hd), enc_axes, init="zeros"),
        }
    if mixer == "mla":
        m = cfg.mla
        return {
            "ckv": ParamSpec((batch, max_seq, m.kv_lora_rank),
                             ("batch", "kv_seq", "lora"), init="zeros"),
            "kpe": ParamSpec((batch, max_seq, m.rope_head_dim),
                             ("batch", "kv_seq", None), init="zeros"),
        }
    if mixer == "mamba":
        s = cfg.ssm
        dims = mamba_dims(cfg)
        return {
            "conv": ParamSpec((batch, s.d_conv - 1, dims["conv_dim"]),
                              ("batch", None, "ssm_inner"), init="zeros"),
            "state": ParamSpec(
                (batch, dims["n_heads"], s.head_dim, s.d_state),
                ("batch", "ssm_heads", None, None), init="zeros", dtype="float32",
            ),
        }
    return None


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """Spec tree for the decode cache (same nesting as the param stages tree)."""
    enc_len = cfg.encoder.n_ctx if cfg.encoder is not None else cfg.n_img_tokens
    out = {}
    for si, stage in enumerate(cfg.stages):
        blk = {}
        for i, (mixer, _) in enumerate(stage.layers):
            c = _layer_cache_spec(cfg, mixer, batch, max_seq, enc_len)
            if c is not None:
                blk[str(i)] = c
        out[str(si)] = jax.tree.map(
            lambda s: stacked(s, stage.repeats), blk,
            is_leaf=lambda s: isinstance(s, ParamSpec),
        )
    return out


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------


def _apply_layer(
    mixer: str,
    mlp: str,
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    rules: ShardingRules,
    ctx: dict,
    cache: dict | None,
    axo_layer: dict | None = None,
):
    """Pre-norm residual layer.  Returns (x, aux_delta, new_cache).

    ``axo_layer`` is this layer's entry dict from an ``AxODeployment``
    (``ctx["axo"]``): when present, the named projections run through the
    approximate operator's cached weight factors instead of exact matmuls.
    """
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    new_cache = None
    use_rope = cfg.pos_encoding == "rope"
    dep = ctx.get("axo")

    def ax(part, sub=None):
        if dep is None or not axo_layer or part not in axo_layer:
            return None
        ent = axo_layer[part]
        if sub is not None:
            ent = ent.get(sub) if isinstance(ent, dict) else None
            if ent is None:
                return None
        return (dep, ent)

    if mixer in ("attn", "attn_nc"):
        attn_cache = None
        if cache is not None and mixer == "attn":
            attn_cache = {"k": cache["k"], "v": cache["v"]}
        out, nc = attn_apply(
            p["mixer"], h, cfg, rules,
            positions=ctx["positions"], causal=(mixer == "attn"),
            use_rope=use_rope and mixer == "attn",
            cache=attn_cache, cache_index=ctx["cache_index"],
            q_start=ctx["q_start"], axo=ax("mixer"),
        )
        if nc is not None:
            new_cache = nc
    elif mixer == "attn_x":
        self_cache = None
        if cache is not None:
            self_cache = {"k": cache["k"], "v": cache["v"]}
        out, nc = attn_apply(
            p["mixer"]["self"], h, cfg, rules,
            positions=ctx["positions"], causal=True, use_rope=use_rope,
            cache=self_cache, cache_index=ctx["cache_index"],
            q_start=ctx["q_start"], axo=ax("mixer", "self"),
        )
        x = x + out
        h = rmsnorm(x, p["mixer"]["norm_x"], cfg.norm_eps)
        if ctx["enc_out"] is not None:
            kv = xattn_kv(p["mixer"]["cross"], ctx["enc_out"],
                          axo=ax("mixer", "cross"))
        else:
            kv = (cache["xk"], cache["xv"])
        out = xattn_apply(p["mixer"]["cross"], h, cfg, rules, kv=kv,
                          axo=ax("mixer", "cross"))
        if nc is not None:
            new_cache = dict(nc)
            if ctx["enc_out"] is not None:
                new_cache["xk"], new_cache["xv"] = (
                    kv[0].astype(cache["xk"].dtype), kv[1].astype(cache["xv"].dtype))
            else:
                new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
    elif mixer == "xattn":
        if ctx["enc_out"] is not None:
            kv = xattn_kv(p["mixer"], ctx["enc_out"], axo=ax("mixer"))
        else:
            kv = (cache["xk"], cache["xv"])
        out = xattn_apply(p["mixer"], h, cfg, rules, kv=kv, gated=True,
                          axo=ax("mixer"))
        if cache is not None:
            if ctx["enc_out"] is not None:
                new_cache = {"xk": kv[0].astype(cache["xk"].dtype),
                             "xv": kv[1].astype(cache["xv"].dtype)}
            else:
                new_cache = {"xk": cache["xk"], "xv": cache["xv"]}
    elif mixer == "mla":
        mla_cache = None
        if cache is not None:
            mla_cache = {"ckv": cache["ckv"], "kpe": cache["kpe"]}
        out, nc = mla_apply(
            p["mixer"], h, cfg, rules,
            positions=ctx["positions"], cache=mla_cache, cache_index=ctx["cache_index"],
            q_start=ctx["q_start"], axo=ax("mixer"),
        )
        if nc is not None:
            new_cache = nc
    elif mixer == "mamba":
        if ctx["mode"] == "decode":
            out, (conv, state) = mamba_decode(
                p["mixer"], h, cfg, rules, cache["conv"], cache["state"])
            new_cache = {"conv": conv, "state": state}
        else:
            out, (conv, state) = mamba_apply(p["mixer"], h, cfg, rules)
            if cache is not None:
                new_cache = {"conv": conv.astype(cache["conv"].dtype), "state": state}
    else:
        raise ValueError(f"unknown mixer {mixer!r}")

    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if mlp != "none":
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if mlp == "moe":
            out, aux = moe_apply(p["mlp"], h, cfg, rules, axo=ax("mlp"))
        else:
            out = mlp_apply(p["mlp"], h, cfg, axo=ax("mlp"))
        x = x + out
    x = constrain(x, rules, "batch", "res_seq", "embed")
    return x, aux, new_cache


def _run_stage(
    stage_params: dict,
    stage: StageConfig,
    x: jnp.ndarray,
    cfg: ModelConfig,
    rules: ShardingRules,
    ctx: dict,
    cache: dict | None,
    axo_stage: dict | None = None,
):
    """Scan the super-block over ``repeats``.  Returns (x, aux, new_cache).

    ``axo_stage`` (AxODeployment entries, stacked over ``repeats`` like the
    params) rides through the scan as a third xs element.
    """
    layers = stage.layers

    def block(carry, xs):
        x, aux = carry
        p_blk, c_blk, a_blk = xs
        new_c = {}
        for i, (mixer, mlp) in enumerate(layers):
            li = str(i)
            lc = c_blk.get(li) if c_blk else None
            la = a_blk.get(li) if a_blk else None
            x, da, nc = _apply_layer(
                mixer, mlp, p_blk[li], x, cfg, rules, ctx, lc, axo_layer=la
            )
            aux = aux + da
            if nc is not None:
                new_c[li] = nc
        return (x, aux), new_c

    body = jax.checkpoint(block) if (cfg.remat and ctx["mode"] == "train") else block
    carry0 = (x, jnp.zeros((), jnp.float32))
    xs = (stage_params, cache if cache else {}, axo_stage if axo_stage else {})
    if cfg.unroll_loops:
        # Cost-probe mode: Python loop so cost_analysis counts every repeat.
        carry = carry0
        ys = []
        for r in range(stage.repeats):
            carry, y = body(carry, jax.tree.map(lambda t: t[r], xs))
            ys.append(y)
        (x, aux) = carry
        new_cache = (
            jax.tree.map(lambda *t: jnp.stack(t), *ys) if ys and ys[0] else {}
        )
    else:
        (x, aux), new_cache = jax.lax.scan(body, carry0, xs)
    return x, aux, (new_cache if new_cache else None)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _encode(params: dict, cfg: ModelConfig, rules: ShardingRules,
            enc_embeds: jnp.ndarray, mode: str, axo=None):
    """Whisper-style encoder over precomputed frame embeddings (stub frontend).

    ``mode`` must follow the outer pass: in training the encoder layers remat
    like the decoder's (without this the 24-layer encoder saves every forward
    intermediate for backward -- measured ~15 GB/device at train_4k)."""
    x = enc_embeds
    if cfg.pos_encoding == "sinusoid":
        x = x + sinusoid_pos(
            jnp.arange(x.shape[1], dtype=jnp.int32), cfg.d_model
        ).astype(x.dtype)[None]
    enc_stage = StageConfig(repeats=cfg.encoder.n_layers, layers=(("attn_nc", "dense"),))
    ctx = {
        "mode": mode,
        "positions": jnp.arange(x.shape[1], dtype=jnp.int32),
        "cache_index": None,
        "enc_out": None,
        "q_start": 0,
        "axo": axo,
    }
    x, _, _ = _run_stage(
        params["encoder"]["stage"], enc_stage, x, cfg, rules, ctx, None,
        axo_stage=axo.encoder if axo is not None else None,
    )
    return rmsnorm(x, params["encoder"]["norm_f"], cfg.norm_eps)


def forward(
    params: dict,
    cfg: ModelConfig,
    rules: ShardingRules,
    tokens: jnp.ndarray,                  # (B, S) int32
    *,
    mode: str = "train",                  # train | prefill | decode
    cache: dict | None = None,
    cache_index: jnp.ndarray | None = None,
    enc_embeds: jnp.ndarray | None = None,   # (B, n_ctx, d) whisper stub frontend
    img_embeds: jnp.ndarray | None = None,   # (B, n_img, d) VLM stub frontend
    axo=None,                                # optional axo.deploy.AxODeployment
):
    """Returns (hidden (B,S,d) or last-step hidden for prefill, aux, new_cache)."""
    b, s = tokens.shape
    if cache_index is None:
        cache_index = jnp.zeros((), jnp.int32)
    positions = cache_index + jnp.arange(s, dtype=jnp.int32)

    x = jnp.take(params["embed"]["tok"], tokens, axis=0)
    x = constrain(x, rules, "batch", "res_seq", "embed")
    if cfg.pos_encoding == "sinusoid":
        x = x + sinusoid_pos(positions, cfg.d_model).astype(x.dtype)[None]

    enc_out = None
    if cfg.encoder is not None and enc_embeds is not None:
        enc_out = _encode(params, cfg, rules, enc_embeds, mode, axo)
    elif cfg.n_img_tokens and img_embeds is not None:
        enc_out = img_embeds

    ctx = {
        "mode": mode,
        "positions": positions,
        "cache_index": None if cache is None else cache_index,
        "enc_out": enc_out,
        # static position of query row 0: known (0) for train and from-scratch
        # prefill; unknown for decode (direct path anyway)
        "q_start": 0 if mode in ("train", "prefill") else None,
        "axo": axo,
    }

    aux = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None else None
    for si, stage in enumerate(cfg.stages):
        sc = cache.get(str(si)) if cache is not None else None
        sa = axo.stages.get(str(si)) if axo is not None else None
        x, da, nc = _run_stage(
            params["stages"][str(si)], stage, x, cfg, rules, ctx, sc,
            axo_stage=sa,
        )
        aux = aux + da
        if new_cache is not None:
            new_cache[str(si)] = nc if nc is not None else {}

    x = rmsnorm(x, params["norm_f"], cfg.norm_eps)
    return x, aux, new_cache


def _unembed(params: dict, cfg: ModelConfig, rules: ShardingRules, x: jnp.ndarray,
             axo=None):
    if axo is not None and axo.head is not None:
        logits = axo.apply(x, axo.head)
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"])
    else:
        logits = x @ params["embed"]["unembed"]
    return constrain(logits, rules, "batch", "res_seq", "vocab")


def logits_fn(params, cfg, rules, x, axo=None):
    return _unembed(params, cfg, rules, x, axo=axo)


def _masked_ce(logits: jnp.ndarray, labels: jnp.ndarray):
    """Mean CE over labels >= 0.  logits (B,S,V), labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = lse - tgt
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def compute_loss(
    params: dict,
    cfg: ModelConfig,
    rules: ShardingRules,
    batch: dict,
):
    """Training loss: CE + MoE aux (+ optional DeepSeek-style MTP head loss).

    ``batch``: {"tokens": (B,S), "labels": (B,S)} (+ "enc_embeds"/"img_embeds").
    """
    x, aux, _ = forward(
        params, cfg, rules, batch["tokens"], mode="train",
        enc_embeds=batch.get("enc_embeds"), img_embeds=batch.get("img_embeds"),
    )
    logits = _unembed(params, cfg, rules, x)
    ce = _masked_ce(logits, batch["labels"])
    loss = ce + aux
    metrics = {"ce": ce, "moe_aux": aux}

    if cfg.mtp:
        # DeepSeek-V3-style multi-token prediction: merge hidden state t with the
        # embedding of token t+1, predict label t+1 (i.e. token t+2).
        emb_next = jnp.take(params["embed"]["tok"], batch["tokens"][:, 1:], axis=0)
        h = jnp.concatenate(
            [
                rmsnorm(x[:, :-1], params["mtp"]["norm_h"], cfg.norm_eps),
                rmsnorm(emb_next, params["mtp"]["norm_e"], cfg.norm_eps),
            ],
            axis=-1,
        )
        h = h @ params["mtp"]["proj"]
        mtp_logits = _unembed(params, cfg, rules, h)
        mtp_ce = _masked_ce(mtp_logits, batch["labels"][:, 1:])
        loss = loss + cfg.mtp_weight * mtp_ce
        metrics["mtp_ce"] = mtp_ce

    metrics["loss"] = loss
    return loss, metrics
