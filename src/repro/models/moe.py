"""Mixture-of-Experts with sort-based (one-hot-free) dispatch + shard_map EP.

Dispatch/combine via argsort-by-expert + capacity-bounded scatter/gather -- the
only representation that stays tractable at 256-384 experts x 1M tokens (an
einsum one-hot dispatch tensor would be ~10^15 elements).  Tokens over capacity
are dropped (scatter mode='drop'), matching capacity-factor semantics of
Switch/GShard-family systems.

Two execution paths, one math:

* **reference / single-device**: all experts local, plain dispatch.
* **expert-parallel (EP)**: expert weights are sharded over the ``model`` mesh
  axis; activations are replicated across it (they are batch-sharded over
  ``data``).  A ``shard_map`` over ``model`` gives each shard its E/ep local
  experts; each shard dispatches *its own* experts' tokens from its full local
  activation copy (no all-to-all needed -- the activations are already there),
  computes, and the combine is a single ``psum`` over ``model`` -- the same
  collective volume as a tensor-parallel dense FFN.  Routing (softmax, top-k,
  aux loss) happens *outside* the shard_map so it is computed once under SPMD.

The EP path engages automatically when a mesh with a >1 ``model`` axis is
active and the expert count divides; otherwise the reference path runs.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import mlp_apply, mlp_spec
from .sharding import ShardingRules, constrain, _current_mesh, shard_map
from .spec import ParamSpec

__all__ = ["moe_spec", "moe_apply", "moe_capacity"]


def moe_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    e = cfg.moe
    c = math.ceil(n_tokens * e.top_k / e.n_experts * e.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8 for layout friendliness


def moe_spec(cfg: ModelConfig) -> dict:
    e = cfg.moe
    d, f = cfg.d_model, e.d_ff_expert
    out = {
        "router": ParamSpec((d, e.n_experts), ("embed", "experts")),
        "w_gate": ParamSpec((e.n_experts, d, f), ("experts", "embed", "mlp")),
        "w_up": ParamSpec((e.n_experts, d, f), ("experts", "embed", "mlp")),
        "w_down": ParamSpec((e.n_experts, f, d), ("experts", "mlp", "embed")),
    }
    if e.n_shared:
        out["shared"] = mlp_spec(cfg, d_ff=e.n_shared * f)
    return out


def _dispatch_compute(
    x: jnp.ndarray,          # (T, d) local tokens
    top_i: jnp.ndarray,      # (T, k) global expert ids
    gates: jnp.ndarray,      # (T, k)
    w_gate: jnp.ndarray,     # (E_loc, d, f)
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,
    e0: jnp.ndarray | int,   # first global expert id owned locally
    cap: int,
    axo=None,                # (AxODeployment, expert entry dict) or None
) -> jnp.ndarray:
    """Sort-based dispatch -> expert FFN -> weighted combine for local experts.

    Entries routed to non-local experts get the sentinel bucket ``E_loc`` and are
    dropped by the capacity scatter.  Returns the (T, d) partial output covering
    only locally-owned expert contributions.

    ``axo`` runs each expert's FFN on the approximate operator (a static Python
    loop over the E_loc capacity buffers -- dispatch/combine stay exact).
    """
    t, d = x.shape
    e_loc = w_gate.shape[0]
    k = top_i.shape[1]

    flat_e = top_i.reshape(-1)
    lid = flat_e - e0
    local = (lid >= 0) & (lid < e_loc)
    assign = jnp.where(local, lid, e_loc)                  # sentinel = E_loc
    sort_idx = jnp.argsort(assign)                         # stable
    sorted_e = assign[sort_idx]
    tok = sort_idx // k
    starts = jnp.searchsorted(sorted_e, jnp.arange(e_loc + 1), side="left")
    pos = jnp.arange(t * k) - starts[jnp.minimum(sorted_e, e_loc)]

    buf = jnp.zeros((e_loc, cap, d), x.dtype)
    buf = buf.at[sorted_e, pos].set(x[tok], mode="drop")   # sentinel/over-cap dropped

    if axo is None:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
            "ecd,edf->ecf", buf, w_up
        )
        y = jnp.einsum("ecf,efd->ecd", h, w_down)
    else:
        dep, ent = axo
        ys = []
        for ei in range(e_loc):
            sel = lambda sub: {kk: vv[ei] for kk, vv in sub.items()}
            he = jax.nn.silu(dep.apply(buf[ei], sel(ent["w_gate"]))) * dep.apply(
                buf[ei], sel(ent["w_up"])
            )
            ys.append(dep.apply(he, sel(ent["w_down"])))
        y = jnp.stack(ys).astype(buf.dtype)

    kept = (sorted_e < e_loc) & (pos >= 0) & (pos < cap)
    y_tok = (
        y[jnp.minimum(sorted_e, e_loc - 1), jnp.clip(pos, 0, cap - 1)]
        * kept[:, None].astype(y.dtype)
    )
    w = gates.reshape(-1)[sort_idx].astype(y.dtype)
    return jnp.zeros((t, d), y.dtype).at[tok].add(y_tok * w[:, None])


def _ep_body(cfg: ModelConfig, cap: int, w_gate, w_up, w_down, x, top_i, gates):
    """shard_map body: one model-shard's experts over its local token copy."""
    e_loc = w_gate.shape[0]
    e0 = jax.lax.axis_index("model") * e_loc
    b, s, d = x.shape
    out = _dispatch_compute(
        x.reshape(b * s, d), top_i.reshape(b * s, -1), gates.reshape(b * s, -1),
        w_gate, w_up, w_down, e0, cap,
    )
    return jax.lax.psum(out.reshape(b, s, d), "model")


def _ep_decode_body(cfg: ModelConfig, cap: int,
                    w_gate, w_up, w_down, x, top_i, gates):
    """Weight-stationary decode body (perf opt P2, see EXPERIMENTS.md §Perf).

    Serving with FSDP-sharded expert weights must NOT gather weights per token
    (measured ~660 MB x 61 layers per decoded batch on kimi-1T): with T tokens
    << params, gather the *activations* instead.  Weights stay sharded over
    (experts -> model, embed-d -> data); every shard sees the full (tiny) token
    batch, contracts its local d-slice, and the partial sums are psum'd over
    ``data`` (pre-activation) and ``model`` (expert partition).

    w_gate/w_up: (E_loc, d_loc, f); w_down: (E_loc, f, d_loc); x: (B, S, d) full.
    Returns the (B, S, d_loc) output d-slice for this data shard.
    """
    e_loc = w_gate.shape[0]
    d_loc = w_gate.shape[1]
    e0 = jax.lax.axis_index("model") * e_loc
    d0 = jax.lax.axis_index("data") * d_loc
    b, s, d = x.shape
    t = b * s
    k = top_i.shape[-1]

    xs = jax.lax.dynamic_slice_in_dim(x.reshape(t, d), d0, d_loc, axis=1)
    flat_e = top_i.reshape(-1)
    lid = flat_e - e0
    local = (lid >= 0) & (lid < e_loc)
    assign = jnp.where(local, lid, e_loc)
    sort_idx = jnp.argsort(assign)
    sorted_e = assign[sort_idx]
    tok = sort_idx // k
    starts = jnp.searchsorted(sorted_e, jnp.arange(e_loc + 1), side="left")
    pos = jnp.arange(t * k) - starts[jnp.minimum(sorted_e, e_loc)]

    buf = jnp.zeros((e_loc, cap, d_loc), xs.dtype)
    buf = buf.at[sorted_e, pos].set(xs[tok], mode="drop")

    # contract the local d-slice; psum over data BEFORE the nonlinearity
    pre_g = jax.lax.psum(jnp.einsum("ecd,edf->ecf", buf, w_gate), "data")
    pre_u = jax.lax.psum(jnp.einsum("ecd,edf->ecf", buf, w_up), "data")
    h = jax.nn.silu(pre_g) * pre_u
    y = jnp.einsum("ecf,efd->ecd", h, w_down)          # (E_loc, cap, d_loc)

    kept = (sorted_e < e_loc) & (pos >= 0) & (pos < cap)
    y_tok = (
        y[jnp.minimum(sorted_e, e_loc - 1), jnp.clip(pos, 0, cap - 1)]
        * kept[:, None].astype(y.dtype)
    )
    w = gates.reshape(-1)[sort_idx].astype(y.dtype)
    out = jnp.zeros((t, d_loc), y.dtype).at[tok].add(y_tok * w[:, None])
    return jax.lax.psum(out, "model").reshape(b, s, d_loc)


def _batch_spec(mesh, b: int):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n = math.prod(mesh.shape[a] for a in axes) if axes else 1
    return axes if (axes and b % n == 0) else None


def moe_apply(
    p: dict,
    x: jnp.ndarray,                 # (B, S, d)
    cfg: ModelConfig,
    rules: ShardingRules,
    axo=None,                       # (AxODeployment, layer mlp entries) or None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out (B, S, d), router aux loss scalar).

    ``axo`` swaps the expert FFNs (and the shared experts) onto the approximate
    operator.  The router stays exact -- it picks *which* experts run, a routing
    decision rather than arithmetic -- and AxO serving targets the single-device
    reference path (EP/weight-stationary shard_map paths keep exact experts).
    """
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = e.top_k

    # --- routing (once, under SPMD) -----------------------------------------
    logits = (x @ p["router"]).astype(jnp.float32)          # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                  # (B, S, k)
    gates = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss.
    me = probs.reshape(t, -1).mean(axis=0)                  # (E,)
    ce = (
        jnp.zeros((e.n_experts,), jnp.float32)
        .at[top_i.reshape(-1)]
        .add(1.0)
        / (t * k)
    )
    aux = e.n_experts * jnp.sum(me * ce) * e.router_aux_weight

    mesh = _current_mesh()
    ep_ok = (
        mesh is not None
        and not mesh.empty
        and "model" in mesh.axis_names
        and mesh.shape["model"] > 1
        and e.n_experts % mesh.shape["model"] == 0
    )

    # Decode / tiny-batch serving: weight-stationary path (perf opt P2) --
    # engage when the token batch is far smaller than the expert weights and
    # the weights carry an FSDP (data) shard on their d dim.  Weights stay put
    # (E -> model, d -> data); the tiny activation batch is gathered instead.
    data_n = mesh.shape["data"] if (ep_ok and "data" in mesh.axis_names) else 1
    decode_ws = (
        ep_ok
        and t <= 8192
        and data_n > 1
        and d % data_n == 0
    )

    axo_experts = axo is not None and "experts" in axo[1]
    if axo_experts:
        cap = moe_capacity(t, cfg)
        out = _dispatch_compute(
            x.reshape(t, d), top_i.reshape(t, k), gates.reshape(t, k),
            p["w_gate"], p["w_up"], p["w_down"], 0, cap,
            axo=(axo[0], axo[1]["experts"]),
        ).reshape(b, s, d)
    elif decode_ws:
        cap = moe_capacity(t, cfg)
        out = shard_map(
            partial(_ep_decode_body, cfg, cap),
            mesh=mesh,
            in_specs=(
                P("model", "data", None),      # w_gate (E/ep, d/dp, f)
                P("model", "data", None),      # w_up
                P("model", None, "data"),      # w_down (E/ep, f, d/dp)
                P(None, None, None),           # x: full token batch everywhere
                P(None, None, None),           # top_i
                P(None, None, None),           # gates
            ),
            out_specs=P(None, None, "data"),
            check=False,
        )(p["w_gate"], p["w_up"], p["w_down"], x, top_i, gates)
    elif ep_ok:
        ep = mesh.shape["model"]
        bspec = _batch_spec(mesh, b)
        data_n_tok = (
            math.prod(mesh.shape[a] for a in bspec) if bspec else 1
        )
        cap = moe_capacity(t // data_n_tok, cfg)
        tok_spec = P(bspec, None, None)
        out = shard_map(
            partial(_ep_body, cfg, cap),
            mesh=mesh,
            in_specs=(
                P("model", None, None),   # w_gate
                P("model", None, None),   # w_up
                P("model", None, None),   # w_down
                tok_spec,                 # x
                tok_spec,                 # top_i
                tok_spec,                 # gates
            ),
            out_specs=tok_spec,
            check=False,
        )(p["w_gate"], p["w_up"], p["w_down"], x, top_i, gates)
    else:
        cap = moe_capacity(t, cfg)
        out = _dispatch_compute(
            x.reshape(t, d), top_i.reshape(t, k), gates.reshape(t, k),
            p["w_gate"], p["w_up"], p["w_down"], 0, cap,
        ).reshape(b, s, d)

    if "shared" in p:
        sh_axo = None
        if axo is not None and "shared" in axo[1]:
            sh_axo = (axo[0], axo[1]["shared"])
        out = out + mlp_apply(p["shared"], x, cfg, axo=sh_axo)
    out = constrain(out, rules, "batch", "seq", "embed")
    return out.astype(x.dtype), aux
