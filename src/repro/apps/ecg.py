"""ECG peak detection through an AxO low-pass filter (paper Table 2, Fig. 17).

Deterministic procedural ECG: periodic QRS-like spikes with jittered intervals +
baseline wander + broadband noise.  The 1-D FIR low-pass (windowed sinc) runs on
int8 arithmetic through the operator's product table; peaks are local maxima above
an adaptive threshold.  BEHAV = percentage of reference peaks missed + spurious
detections, where the reference is the *accurate operator's* detection output
(exactly the paper's framing: error introduced by the approximation).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.operator_model import exact_product_table
from .base import AxOApplication, quantize_int8, table_conv1d

__all__ = ["ECGPeakDetection"]


def _synthetic_ecg(n: int, fs: float, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """(signal, true peak indices).  Smooth QRS surrogates with deterministic jitter."""
    rng = np.random.default_rng(seed)
    t = np.arange(n) / fs
    sig = np.zeros(n)
    peaks = []
    pos = fs * 0.4
    while pos < n - fs * 0.3:
        peaks.append(int(pos))
        width = fs * 0.02
        x = (np.arange(n) - pos) / width
        sig += 1.0 * np.exp(-0.5 * x**2)           # R wave
        sig -= 0.18 * np.exp(-0.5 * ((np.arange(n) - pos - 3 * width) / (2 * width)) ** 2)
        pos += fs * (0.75 + 0.25 * rng.random())   # RR interval jitter
    sig += 0.15 * np.sin(2 * np.pi * 0.33 * t)      # baseline wander
    sig += 0.08 * np.sin(2 * np.pi * 50.0 * t)      # mains interference
    sig += 0.05 * rng.standard_normal(n)            # broadband noise
    return sig, np.array(peaks)


def _lowpass_taps(n_taps: int, cutoff: float, fs: float) -> np.ndarray:
    """Hamming-windowed sinc FIR low-pass."""
    m = np.arange(n_taps) - (n_taps - 1) / 2
    h = np.sinc(2 * cutoff / fs * m)
    h *= np.hamming(n_taps)
    return h / h.sum()


def _detect_peaks(y: np.ndarray, min_dist: int, rel_thresh: float = 0.5) -> np.ndarray:
    """Local maxima above rel_thresh x max, separated by >= min_dist samples."""
    if y.size < 3:
        return np.array([], dtype=np.int64)
    thresh = rel_thresh * y.max()
    cand = np.where((y[1:-1] > y[:-2]) & (y[1:-1] >= y[2:]) & (y[1:-1] > thresh))[0] + 1
    picked: list[int] = []
    for i in cand[np.argsort(-y[cand])]:  # strongest first
        if all(abs(i - j) >= min_dist for j in picked):
            picked.append(int(i))
    return np.sort(np.array(picked, dtype=np.int64))


@dataclass
class ECGPeakDetection(AxOApplication):
    name: str = "ecg"
    n_samples: int = 2048
    fs: float = 250.0
    n_taps: int = 15
    cutoff_hz: float = 35.0
    seed: int = 7
    match_tol: int = 10   # samples; +-40 ms at 250 Hz

    _sig: np.ndarray = field(init=False, repr=False)
    _taps: np.ndarray = field(init=False, repr=False)
    _x_codes: np.ndarray = field(init=False, repr=False)
    _h_codes: np.ndarray = field(init=False, repr=False)
    _ref_peaks: np.ndarray | None = field(init=False, repr=False, default=None)
    _prep_bits: int = field(init=False, repr=False, default=0)

    def __post_init__(self) -> None:
        self._sig, _ = _synthetic_ecg(self.n_samples, self.fs, self.seed)
        self._taps = _lowpass_taps(self.n_taps, self.cutoff_hz, self.fs)
        self._prepare(8)

    def _prepare(self, n_bits: int) -> None:
        """(Re)quantize inputs for an ``n_bits`` operator's table-index space."""
        if self._prep_bits == n_bits:
            return
        self._x_codes, _ = quantize_int8(self._sig, n_bits=n_bits)
        self._h_codes, _ = quantize_int8(self._taps, n_bits=n_bits)
        self._ref_peaks = None
        self._prep_bits = n_bits

    def _peaks_from_signal(self, y: np.ndarray) -> np.ndarray:
        return _detect_peaks(y.astype(np.float64), min_dist=int(0.4 * self.fs))

    def _peaks_for_table(self, table: np.ndarray) -> np.ndarray:
        return self._peaks_from_signal(table_conv1d(table, self._x_codes, self._h_codes))

    def set_reference(self, accurate_table: np.ndarray) -> None:
        self._ref_peaks = self._peaks_for_table(accurate_table)

    def _ensure_reference(self) -> None:
        if self._ref_peaks is None:
            # reference = exact integer arithmetic (== accurate operator, tested)
            self.set_reference(exact_product_table(self._prep_bits))

    def _match_score(self, got: np.ndarray) -> float:
        """Greedy strongest-first peak matching -> missed+spurious percentage."""
        ref = self._ref_peaks
        matched = 0
        used = np.zeros(len(got), dtype=bool)
        for p in ref:
            if len(got) == 0:
                break
            j = int(np.argmin(np.abs(got - p) + 1e9 * used))
            if not used[j] and abs(int(got[j]) - int(p)) <= self.match_tol:
                used[j] = True
                matched += 1
        missed = len(ref) - matched
        spurious = len(got) - matched
        return 100.0 * (missed + spurious) / max(len(ref), 1)

    def behav_from_tables(self, tables: np.ndarray) -> np.ndarray:
        tables = np.asarray(tables)
        if tables.ndim == 2:
            tables = tables[None]
        self._prepare(int(tables.shape[-1]).bit_length() - 1)
        self._ensure_reference()
        out = np.empty(len(tables), dtype=np.float64)
        for d, tab in enumerate(tables):
            out[d] = self._match_score(self._peaks_for_table(tab))
        return out

    def behav_jax_from_tables(self, tables) -> np.ndarray:
        """Device batched FIR filtering; peak picking/matching stays on host.

        The filtered signal is an exact integer convolution, so the device
        batch equals the per-table numpy path bit-for-bit; the tiny sequential
        greedy matching (dozens of candidates) reuses the oracle code, making
        the count-based score identical across backends.
        """
        from .fastapp import _as_batch, table_conv1d_jax  # lazy JAX import

        batch = _as_batch(tables)
        self._prepare(batch.n_bits)
        self._ensure_reference()
        y = np.asarray(table_conv1d_jax(batch, self._x_codes, self._h_codes))
        return np.array(
            [self._match_score(self._peaks_from_signal(yd)) for yd in y],
            dtype=np.float64,
        )
