"""Beyond-paper application: a transformer FFN block on AxO arithmetic.

The DSE target the paper never tried: both GEMMs of a GeLU FFN
(``W2 @ gelu(W1 @ x)``) run through the approximate operator's product table.
BEHAV = 100 x relative L2 error of the block output vs. the accurate-operator
int8 pipeline.  This is the bridge to the framework's LM serving path: configs
selected here are exactly what ``repro.axo`` deploys inside the LM architectures.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.operator_model import exact_product_table
from .base import AxOApplication, quantize_int8, table_matmul

__all__ = ["TransformerFFN"]


def _gelu(x: np.ndarray) -> np.ndarray:
    # x*x*x, not x**3: np.power's generic pow is ~17x slower and this runs on
    # every hidden activation of every table evaluated by the BEHAV loop.
    return 0.5 * x * (1.0 + np.tanh(np.sqrt(2.0 / np.pi) * (x + 0.044715 * (x * x * x))))


_DEVICE_REQUANT = None


def _gelu_requant_jax():
    """Jitted float32 GeLU + per-config symmetric quantizer (lazy JAX import).

    Mirrors ``_gelu`` + ``quantize_int8`` for a (D, T, F) batch of GEMM1
    integer outputs: returns masked int32 codes and the per-config scales.
    """
    global _DEVICE_REQUANT
    if _DEVICE_REQUANT is None:
        import functools

        import jax
        import jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("n_bits",))
        def fn(h_int, scale, n_bits: int):
            h = h_int.astype(jnp.float32) * scale
            c = jnp.float32(np.sqrt(2.0 / np.pi))
            h = 0.5 * h * (1.0 + jnp.tanh(c * (h + 0.044715 * (h * h * h))))
            qmax = (1 << (n_bits - 1)) - 1
            amax = jnp.abs(h).max(axis=(1, 2))
            sh = jnp.where(amax > 0, amax / qmax, 1.0)
            q = jnp.clip(
                jnp.round(h / sh[:, None, None]), -qmax - 1, qmax
            ).astype(jnp.int32)
            return q & ((1 << n_bits) - 1), sh

        _DEVICE_REQUANT = fn
    return _DEVICE_REQUANT


@dataclass
class TransformerFFN(AxOApplication):
    name: str = "ffn"
    d_model: int = 64
    d_ff: int = 128
    n_tokens: int = 96
    seed: int = 17
    # "host": GeLU + per-config requantization in host float64, bit-identical
    # to the numpy oracle.  "device": the whole GEMM1 -> GeLU -> requant ->
    # GEMM2 chain stays on device in float32 -- no (D, T, F) host round-trip
    # between the GEMMs, composing with the table-free entry impls.  Device
    # float32 rounds a handful of hidden codes differently near .5 rounding
    # boundaries, so BEHAV agrees to a documented tolerance (see
    # ``behav_jax_from_tables``), not bitwise.
    requant: str = "host"

    _x: np.ndarray = field(init=False, repr=False)
    _w1: np.ndarray = field(init=False, repr=False)
    _w2: np.ndarray = field(init=False, repr=False)
    _x_codes: np.ndarray = field(init=False, repr=False)    # (T, D)
    _w1_codes: np.ndarray = field(init=False, repr=False)   # (D, F)
    _w2_codes: np.ndarray = field(init=False, repr=False)   # (F, D)
    _sx: float = field(init=False, repr=False)
    _s1: float = field(init=False, repr=False)
    _s2: float = field(init=False, repr=False)
    _ref_out: np.ndarray | None = field(init=False, repr=False, default=None)
    _prep_bits: int = field(init=False, repr=False, default=0)

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        self._x = rng.standard_normal((self.n_tokens, self.d_model))
        self._w1 = rng.standard_normal((self.d_model, self.d_ff)) / np.sqrt(self.d_model)
        self._w2 = rng.standard_normal((self.d_ff, self.d_model)) / np.sqrt(self.d_ff)
        self._prepare(8)

    def _prepare(self, n_bits: int) -> None:
        if self._prep_bits == n_bits:
            return
        self._x_codes, self._sx = quantize_int8(self._x, n_bits=n_bits)
        self._w1_codes, self._s1 = quantize_int8(self._w1, n_bits=n_bits)
        self._w2_codes, self._s2 = quantize_int8(self._w2, n_bits=n_bits)
        self._ref_out = None
        self._prep_bits = n_bits

    def _forward(self, table: np.ndarray) -> np.ndarray:
        n_bits = self._prep_bits
        h = table_matmul(table, self._x_codes, self._w1_codes).astype(np.float64)
        h = _gelu(h * (self._sx * self._s1))
        h_codes, sh = quantize_int8(h, n_bits=n_bits)
        y = table_matmul(table, h_codes, self._w2_codes).astype(np.float64)
        return y * (sh * self._s2)

    def _ensure_reference(self) -> None:
        if self._ref_out is None:
            self._ref_out = self._forward(exact_product_table(self._prep_bits))

    def behav_from_tables(self, tables: np.ndarray) -> np.ndarray:
        tables = np.asarray(tables)
        if tables.ndim == 2:
            tables = tables[None]
        self._prepare(int(tables.shape[-1]).bit_length() - 1)
        self._ensure_reference()
        ref = self._ref_out
        denom = float(np.linalg.norm(ref)) or 1.0
        out = np.empty(len(tables), dtype=np.float64)
        for d, tab in enumerate(tables):
            out[d] = 100.0 * float(np.linalg.norm(self._forward(tab) - ref)) / denom
        return out

    def behav_jax_from_tables(self, tables) -> np.ndarray:
        """Both GEMMs on device; GeLU + per-config requantization per ``requant``.

        ``requant="host"`` (default): the intermediate quantization scale
        depends on each config's hidden activations, so it runs in host
        float64 exactly like the oracle's ``quantize_int8`` -- keeping the
        second GEMM's input codes, and hence the final integer outputs,
        bit-identical.  ``requant="device"``: GeLU and the per-config
        symmetric quantizer run jitted in float32 and the (D, T, F) hidden
        tensor never leaves the device between the GEMMs -- composing with
        the table-free ``entry``/``entry_pallas`` impls so the whole chain
        runs without a product-table build.  Tolerance story: float32 can
        round an isolated hidden code one step differently where
        ``h / scale`` lands within a float32 ulp of a .5 boundary, so BEHAV
        agrees with the host path to ~1e-3 percentage points (asserted at
        atol=2e-2 in tests/test_fastapp.py), not bitwise.  Either way the
        per-config hidden codes take ``table_matmul_jax``'s batched-codes
        path.
        """
        from .fastapp import _as_batch, table_matmul_jax  # lazy JAX import

        batch = _as_batch(tables)
        n_bits = batch.n_bits
        self._prepare(n_bits)
        self._ensure_reference()
        ref = self._ref_out
        denom = float(np.linalg.norm(ref)) or 1.0

        h_int = table_matmul_jax(batch, self._x_codes, self._w1_codes)
        if self.requant == "device":
            h_codes, sh = _gelu_requant_jax()(
                h_int, float(self._sx * self._s1), n_bits
            )
            sh = np.asarray(sh, dtype=np.float64)
        else:
            h = np.asarray(h_int).astype(np.float64)
            h = _gelu(h * (self._sx * self._s1))                # (D, T, F)
            d = h.shape[0]
            h_codes = np.empty(h.shape, dtype=np.int32)  # device dtype, exact
            sh = np.empty(d, dtype=np.float64)
            for i in range(d):  # per-config scales, exactly the oracle's
                h_codes[i], sh[i] = quantize_int8(h[i], n_bits=n_bits)
        y = np.asarray(
            table_matmul_jax(batch, h_codes, self._w2_codes)
        ).astype(np.float64)
        d = y.shape[0]
        y *= (sh * self._s2)[:, None, None]
        return np.array(
            [100.0 * float(np.linalg.norm(y[i] - ref)) / denom for i in range(d)],
            dtype=np.float64,
        )
