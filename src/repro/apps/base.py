"""Shared machinery: int8 quantization + table-based approximate arithmetic.

An approximate signed NxN multiplier is fully described by its product table
``T[(a & mask), (b & mask)] -> int``; applications compute every multiply through
that table, so swapping tables swaps operators.  The accurate table reproduces
exact integer arithmetic (tested), so "accurate operator" baselines use the same
code path.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.dataset import Dataset, characterize
from ..core.operator_model import OperatorSpec, accurate_config, product_tables

__all__ = [
    "quantize_int8",
    "table_matmul",
    "table_conv1d",
    "table_conv2d",
    "AxOApplication",
]


def quantize_int8(x: np.ndarray, n_bits: int = 8) -> tuple[np.ndarray, float]:
    """Symmetric per-tensor quantization to signed ``n_bits`` codes.

    Returns (codes, scale) with ``codes`` already masked to table-index space
    (two's complement & (2^n - 1)) and ``x ~= scale * signed(codes)``.
    """
    x = np.asarray(x, dtype=np.float64)
    qmax = (1 << (n_bits - 1)) - 1
    amax = float(np.abs(x).max())
    scale = (amax / qmax) if amax > 0 else 1.0
    q = np.clip(np.round(x / scale), -qmax - 1, qmax).astype(np.int64)
    return (q & ((1 << n_bits) - 1)).astype(np.int64), scale


def table_matmul(table: np.ndarray, a_codes: np.ndarray, b_codes: np.ndarray) -> np.ndarray:
    """(M, K) x (K, N) -> (M, N) int64 via product-table lookups."""
    # gather (M, K, N) then reduce K; fine for the app-scale GEMVs used here.
    prod = table[a_codes[:, :, None], b_codes[None, :, :]].astype(np.int64)
    return prod.sum(axis=1)


def table_conv1d(table: np.ndarray, x_codes: np.ndarray, h_codes: np.ndarray) -> np.ndarray:
    """Valid-mode 1-D convolution (correlation) through the product table."""
    k = h_codes.shape[0]
    win = np.lib.stride_tricks.sliding_window_view(x_codes, k)   # (T-k+1, k)
    prod = table[win, h_codes[None, :]].astype(np.int64)
    return prod.sum(axis=-1)


def table_conv2d(table: np.ndarray, img_codes: np.ndarray, k_codes: np.ndarray) -> np.ndarray:
    """Valid-mode 2-D convolution through the product table."""
    kh, kw = k_codes.shape
    win = np.lib.stride_tricks.sliding_window_view(img_codes, (kh, kw))  # (H', W', kh, kw)
    prod = table[win, k_codes[None, None, :, :]].astype(np.int64)
    return prod.sum(axis=(-1, -2))


@dataclass
class AxOApplication:
    """Base: evaluate BEHAV for batches of configs / product tables."""

    name: str = "base"

    def behav_from_tables(self, tables: np.ndarray) -> np.ndarray:
        """(D, 2^N, 2^N) int32 product tables -> (D,) BEHAV values (minimized)."""
        raise NotImplementedError

    # -- conveniences used by the DSE layer ---------------------------------

    def behav_metric_name(self) -> str:
        return f"APP_{self.name.upper()}"

    def behav(self, spec: OperatorSpec, configs: np.ndarray, batch: int = 128) -> np.ndarray:
        configs = np.atleast_2d(np.asarray(configs))
        out = np.empty(len(configs), dtype=np.float64)
        for lo in range(0, len(configs), batch):
            hi = min(lo + batch, len(configs))
            tables = product_tables(spec, configs[lo:hi])
            out[lo:hi] = self.behav_from_tables(tables)
        return out

    def accurate_behav(self, spec: OperatorSpec) -> float:
        return float(self.behav(spec, accurate_config(spec)[None])[0])

    def characterized_dataset(self, spec: OperatorSpec, base: Dataset) -> Dataset:
        """Attach this app's BEHAV metric to an existing characterized dataset."""
        metrics = dict(base.metrics)
        metrics[self.behav_metric_name()] = self.behav(spec, base.configs)
        return Dataset(configs=base.configs, metrics=metrics, source=base.source)

    def characterize_fn(self, spec: OperatorSpec, ppa_key: str = "PDPLUT"):
        """(D, L) -> (D, 2) [app BEHAV, operator PPA] for dse.run_dse."""

        def fn(configs: np.ndarray) -> np.ndarray:
            ds = characterize(spec, configs)
            b = self.behav(spec, configs)
            return np.stack([b, ds.metrics[ppa_key]], axis=-1)

        return fn
