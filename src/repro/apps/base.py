"""Shared machinery: int8 quantization + table-based approximate arithmetic.

An approximate signed NxN multiplier is fully described by its product table
``T[(a & mask), (b & mask)] -> int``; applications compute every multiply through
that table, so swapping tables swaps operators.  The accurate table reproduces
exact integer arithmetic (tested), so "accurate operator" baselines use the same
code path.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.dataset import Dataset, characterize
from ..core.operator_model import OperatorSpec, accurate_config, product_tables

__all__ = [
    "quantize_int8",
    "table_matmul",
    "table_conv1d",
    "table_conv2d",
    "AxOApplication",
    "characterized_dataset_multi",
]


def quantize_int8(x: np.ndarray, n_bits: int = 8) -> tuple[np.ndarray, float]:
    """Symmetric per-tensor quantization to signed ``n_bits`` codes.

    Returns (codes, scale) with ``codes`` already masked to table-index space
    (two's complement & (2^n - 1)) and ``x ~= scale * signed(codes)``.
    """
    x = np.asarray(x, dtype=np.float64)
    qmax = (1 << (n_bits - 1)) - 1
    amax = float(np.abs(x).max())
    scale = (amax / qmax) if amax > 0 else 1.0
    q = np.clip(np.round(x / scale), -qmax - 1, qmax).astype(np.int64)
    return (q & ((1 << n_bits) - 1)).astype(np.int64), scale


def table_matmul(
    table: np.ndarray, a_codes: np.ndarray, b_codes: np.ndarray, k_chunk: int = 64
) -> np.ndarray:
    """(M, K) x (K, N) -> (M, N) int64 via product-table lookups.

    The K reduction is chunked so the gather scratch stays (M, k_chunk, N)
    instead of the full (M, K, N) product tensor; integer partial sums make the
    result independent of ``k_chunk``.
    """
    m, k = a_codes.shape
    n = b_codes.shape[1]
    out = np.zeros((m, n), dtype=np.int64)
    for lo in range(0, k, k_chunk):
        hi = min(lo + k_chunk, k)
        prod = table[a_codes[:, lo:hi, None], b_codes[None, lo:hi, :]].astype(np.int64)
        out += prod.sum(axis=1)
    return out


def table_conv1d(table: np.ndarray, x_codes: np.ndarray, h_codes: np.ndarray) -> np.ndarray:
    """Valid-mode 1-D convolution (correlation) through the product table."""
    k = h_codes.shape[0]
    win = np.lib.stride_tricks.sliding_window_view(x_codes, k)   # (T-k+1, k)
    prod = table[win, h_codes[None, :]].astype(np.int64)
    return prod.sum(axis=-1)


def table_conv2d(table: np.ndarray, img_codes: np.ndarray, k_codes: np.ndarray) -> np.ndarray:
    """Valid-mode 2-D convolution through the product table."""
    kh, kw = k_codes.shape
    win = np.lib.stride_tricks.sliding_window_view(img_codes, (kh, kw))  # (H', W', kh, kw)
    prod = table[win, k_codes[None, None, :, :]].astype(np.int64)
    return prod.sum(axis=(-1, -2))


@dataclass
class AxOApplication:
    """Base: evaluate BEHAV for batches of configs / product tables."""

    name: str = "base"

    def behav_from_tables(self, tables: np.ndarray) -> np.ndarray:
        """(D, 2^N, 2^N) int32 product tables -> (D,) BEHAV values (minimized)."""
        raise NotImplementedError

    def behav_jax_from_tables(self, tables) -> np.ndarray:
        """(D, 2^N, 2^N) device product tables -> (D,) BEHAV (the jax engine).

        Implemented per app on top of :mod:`repro.apps.fastapp`; the numpy
        ``behav_from_tables`` stays the bit-exact oracle.
        """
        raise NotImplementedError(f"no jax BEHAV engine for app {self.name!r}")

    # -- conveniences used by the DSE layer ---------------------------------

    def behav_metric_name(self) -> str:
        return f"APP_{self.name.upper()}"

    def behav(
        self,
        spec: OperatorSpec,
        configs: np.ndarray,
        batch: int = 128,
        backend="numpy",
    ) -> np.ndarray:
        """(D, L) configs -> (D,) BEHAV.  ``backend`` is a legacy string or an
        ``ExecutionContext``; the jax backend builds the product tables on
        device and scores them through the fastapp engine (config-sharded over
        the context's mesh when one is set); ``"numpy"`` is the oracle."""
        from ..core.engine import as_context

        ctx = as_context(backend)
        if ctx.is_jax:
            from .fastapp import app_behav_jax  # lazy: keeps numpy path JAX-free

            return app_behav_jax(self, spec, configs, batch=batch, ctx=ctx)
        configs = np.atleast_2d(np.asarray(configs))
        out = np.empty(len(configs), dtype=np.float64)
        for lo in range(0, len(configs), batch):
            hi = min(lo + batch, len(configs))
            tables = product_tables(spec, configs[lo:hi])
            out[lo:hi] = self.behav_from_tables(tables)
        return out

    def accurate_behav(self, spec: OperatorSpec) -> float:
        return float(self.behav(spec, accurate_config(spec)[None])[0])

    def characterized_dataset(
        self, spec: OperatorSpec, base: Dataset, backend="numpy"
    ) -> Dataset:
        """Attach this app's BEHAV metric to an existing characterized dataset."""
        metrics = dict(base.metrics)
        metrics[self.behav_metric_name()] = self.behav(spec, base.configs, backend=backend)
        return Dataset(configs=base.configs, metrics=metrics, source=base.source)

    def characterize_fn(
        self, spec: OperatorSpec, ppa_key: str = "PDPLUT", backend="numpy"
    ):
        """(D, L) -> (D, 2) [app BEHAV, operator PPA] for dse.run_dse."""

        def fn(configs: np.ndarray) -> np.ndarray:
            ds = characterize(spec, configs, backend=backend)
            b = self.behav(spec, configs, backend=backend)
            return np.stack([b, ds.metrics[ppa_key]], axis=-1)

        return fn


def characterized_dataset_multi(
    apps,
    spec: OperatorSpec,
    base: Dataset,
    backend="numpy",
    batch: int = 128,
) -> Dataset:
    """Attach *every* app's BEHAV metric with one shared table pass per chunk.

    ``AxOApplication.characterized_dataset`` runs one engine pass per app --
    the product tables of the whole dataset are rebuilt for each of the four
    heads.  Here each config chunk's tables are built once and scored by all
    apps: on ``backend="jax"`` a single device ``TableBatch`` (lazily-shared
    ``small``/full tables) feeds every ``behav_jax_from_tables`` head; on
    ``"numpy"`` the host product tables are likewise built once per chunk.
    Per-app results are identical to the one-app-at-a-time path.
    """
    from ..core.engine import as_context

    ctx = as_context(backend)
    apps = list(apps)
    metrics = dict(base.metrics)
    if ctx.is_jax:
        from .fastapp import multi_app_behav_jax  # lazy: keeps numpy path JAX-free

        vals = multi_app_behav_jax(apps, spec, base.configs, batch=batch, ctx=ctx)
        for app in apps:
            metrics[app.behav_metric_name()] = vals[app.name]
    else:
        configs = np.atleast_2d(np.asarray(base.configs))
        d = len(configs)
        out = {app.name: np.empty(d, dtype=np.float64) for app in apps}
        for lo in range(0, d, batch):
            hi = min(lo + batch, d)
            tables = product_tables(spec, configs[lo:hi])
            for app in apps:
                out[app.name][lo:hi] = app.behav_from_tables(tables)
        for app in apps:
            metrics[app.behav_metric_name()] = out[app.name]
    return Dataset(configs=base.configs, metrics=metrics, source=base.source)
