"""Application substrate for application-specific AxO DSE (paper Table 2).

Each application evaluates one BEHAV metric for a batch of approximate-operator
product tables; PPA always remains the operator's PDPLUT.  All datasets are
deterministic procedural surrogates (no network access) with the same task
structure as the paper's: 1-D conv ECG peak detection, GEMV digit classification,
2-D conv Gaussian smoothing, and a beyond-paper transformer-FFN block.

Every application evaluates through two backends: ``backend="numpy"`` (the
bit-exact oracle, default) and ``backend="jax"`` -- the accelerator-native
engine in :mod:`repro.apps.fastapp` (device-resident product tables, batched
table-matmul/conv primitives, a Pallas table-GEMV kernel).  fastapp is
imported lazily so the numpy path stays JAX-free.
"""

from .base import AxOApplication, quantize_int8, table_conv1d, table_conv2d, table_matmul
from .ecg import ECGPeakDetection
from .mnist import DigitClassification
from .gauss import GaussianSmoothing
from .ffn import TransformerFFN

APPLICATIONS = {
    "ecg": ECGPeakDetection,
    "mnist": DigitClassification,
    "gauss": GaussianSmoothing,
    "ffn": TransformerFFN,
}

__all__ = [
    "AxOApplication",
    "APPLICATIONS",
    "ECGPeakDetection",
    "DigitClassification",
    "GaussianSmoothing",
    "TransformerFFN",
    "quantize_int8",
    "table_conv1d",
    "table_conv2d",
    "table_matmul",
]
