"""Gaussian image smoothing via 2-D conv on AxO arithmetic (Table 2, Fig. 19).

Procedural test image (smooth field + edges + texture), 5x5 Gaussian kernel,
conv through the operator's product table.  BEHAV = AVG_PSNR_RED: PSNR of the
accurate-operator output minus PSNR of the approximate output, both measured
against the float convolution -- matching the paper's "average reduction in PSNR"
(negative values mean the approximation happens to land closer; Fig. 19 notes
useful EvoApprox designs need AVG_PSNR_RED < 0 under that convention).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .base import AxOApplication, quantize_int8, table_conv2d

__all__ = ["GaussianSmoothing"]


def _test_image(side: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:side, 0:side] / side
    img = 0.5 + 0.3 * np.sin(6.0 * xx) * np.cos(4.0 * yy)
    img += np.where(xx + yy > 1.0, 0.25, -0.1)              # hard edge
    img += 0.1 * rng.standard_normal((side, side))          # texture/noise
    lo, hi = img.min(), img.max()
    return (img - lo) / (hi - lo)


def _gauss_kernel(k: int, sigma: float) -> np.ndarray:
    m = np.arange(k) - (k - 1) / 2
    g = np.exp(-0.5 * (m / sigma) ** 2)
    kern = np.outer(g, g)
    return kern / kern.sum()


def _psnr(a: np.ndarray, b: np.ndarray, peak: float) -> float:
    mse = float(((a - b) ** 2).mean())
    if mse <= 0:
        return 99.0  # identical within float: cap as the paper's plots do
    return float(10.0 * np.log10(peak**2 / mse))


@dataclass
class GaussianSmoothing(AxOApplication):
    name: str = "gauss"
    side: int = 96
    ksize: int = 5
    sigma: float = 1.0
    seed: int = 13

    _img: np.ndarray = field(init=False, repr=False)
    _kern: np.ndarray = field(init=False, repr=False)
    _img_codes: np.ndarray = field(init=False, repr=False)
    _k_codes: np.ndarray = field(init=False, repr=False)
    _scale: float = field(init=False, repr=False)
    _float_ref: np.ndarray = field(init=False, repr=False)
    _psnr_accurate: float | None = field(init=False, repr=False, default=None)
    _prep_bits: int = field(init=False, repr=False, default=0)

    def __post_init__(self) -> None:
        self._img = _test_image(self.side, self.seed)
        self._kern = _gauss_kernel(self.ksize, self.sigma)
        # float reference: valid-mode convolution of the *float* image/kernel
        win = np.lib.stride_tricks.sliding_window_view(self._img, (self.ksize, self.ksize))
        self._float_ref = (win * self._kern[None, None]).sum(axis=(-1, -2))
        self._prepare(8)

    def _prepare(self, n_bits: int) -> None:
        if self._prep_bits == n_bits:
            return
        self._img_codes, sx = quantize_int8(self._img, n_bits=n_bits)
        self._k_codes, sk = quantize_int8(self._kern, n_bits=n_bits)
        self._scale = sx * sk
        self._psnr_accurate = None
        self._prep_bits = n_bits

    def _psnr_for_table(self, table: np.ndarray) -> float:
        y = table_conv2d(table, self._img_codes, self._k_codes).astype(np.float64)
        return _psnr(y * self._scale, self._float_ref, peak=1.0)

    def behav_from_tables(self, tables: np.ndarray) -> np.ndarray:
        tables = np.asarray(tables)
        if tables.ndim == 2:
            tables = tables[None]
        self._prepare(int(tables.shape[-1]).bit_length() - 1)
        if self._psnr_accurate is None:
            n = tables.shape[-1]
            u = np.arange(n)
            v = np.where(u >= n // 2, u - n, u)
            exact = np.multiply.outer(v, v).astype(np.int64)
            self._psnr_accurate = self._psnr_for_table(exact)
        out = np.empty(len(tables), dtype=np.float64)
        for d, tab in enumerate(tables):
            out[d] = self._psnr_accurate - self._psnr_for_table(tab)
        return out
