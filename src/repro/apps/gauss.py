"""Gaussian image smoothing via 2-D conv on AxO arithmetic (Table 2, Fig. 19).

Procedural test image (smooth field + edges + texture), 5x5 Gaussian kernel,
conv through the operator's product table.  BEHAV = AVG_PSNR_RED: PSNR of the
accurate-operator output minus PSNR of the approximate output, both measured
against the float convolution -- matching the paper's "average reduction in PSNR"
(negative values mean the approximation happens to land closer; Fig. 19 notes
useful EvoApprox designs need AVG_PSNR_RED < 0 under that convention).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.operator_model import exact_product_table
from .base import AxOApplication, quantize_int8, table_conv2d

__all__ = ["GaussianSmoothing"]


def _test_image(side: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:side, 0:side] / side
    img = 0.5 + 0.3 * np.sin(6.0 * xx) * np.cos(4.0 * yy)
    img += np.where(xx + yy > 1.0, 0.25, -0.1)              # hard edge
    img += 0.1 * rng.standard_normal((side, side))          # texture/noise
    lo, hi = img.min(), img.max()
    return (img - lo) / (hi - lo)


def _gauss_kernel(k: int, sigma: float) -> np.ndarray:
    m = np.arange(k) - (k - 1) / 2
    g = np.exp(-0.5 * (m / sigma) ** 2)
    kern = np.outer(g, g)
    return kern / kern.sum()


def _psnr(a: np.ndarray, b: np.ndarray, peak: float) -> float:
    mse = float(((a - b) ** 2).mean())
    if mse <= 0:
        return 99.0  # identical within float: cap as the paper's plots do
    return float(10.0 * np.log10(peak**2 / mse))


@dataclass
class GaussianSmoothing(AxOApplication):
    name: str = "gauss"
    side: int = 96
    ksize: int = 5
    sigma: float = 1.0
    seed: int = 13

    _img: np.ndarray = field(init=False, repr=False)
    _kern: np.ndarray = field(init=False, repr=False)
    _img_codes: np.ndarray = field(init=False, repr=False)
    _k_codes: np.ndarray = field(init=False, repr=False)
    _scale: float = field(init=False, repr=False)
    _float_ref: np.ndarray = field(init=False, repr=False)
    _psnr_accurate: float | None = field(init=False, repr=False, default=None)
    _prep_bits: int = field(init=False, repr=False, default=0)

    def __post_init__(self) -> None:
        self._img = _test_image(self.side, self.seed)
        self._kern = _gauss_kernel(self.ksize, self.sigma)
        # float reference: valid-mode convolution of the *float* image/kernel
        win = np.lib.stride_tricks.sliding_window_view(self._img, (self.ksize, self.ksize))
        self._float_ref = (win * self._kern[None, None]).sum(axis=(-1, -2))
        self._prepare(8)

    def _prepare(self, n_bits: int) -> None:
        if self._prep_bits == n_bits:
            return
        self._img_codes, sx = quantize_int8(self._img, n_bits=n_bits)
        self._k_codes, sk = quantize_int8(self._kern, n_bits=n_bits)
        self._scale = sx * sk
        self._psnr_accurate = None
        self._prep_bits = n_bits

    def _psnr_from_int(self, y: np.ndarray) -> float:
        """Exact integer conv output -> PSNR vs the float reference (f64 host math)."""
        return _psnr(y.astype(np.float64) * self._scale, self._float_ref, peak=1.0)

    def _psnr_for_table(self, table: np.ndarray) -> float:
        return self._psnr_from_int(table_conv2d(table, self._img_codes, self._k_codes))

    def _ensure_accurate_psnr(self) -> None:
        if self._psnr_accurate is None:
            self._psnr_accurate = self._psnr_for_table(
                exact_product_table(self._prep_bits)
            )

    def behav_from_tables(self, tables: np.ndarray) -> np.ndarray:
        tables = np.asarray(tables)
        if tables.ndim == 2:
            tables = tables[None]
        self._prepare(int(tables.shape[-1]).bit_length() - 1)
        self._ensure_accurate_psnr()
        out = np.empty(len(tables), dtype=np.float64)
        for d, tab in enumerate(tables):
            out[d] = self._psnr_accurate - self._psnr_for_table(tab)
        return out

    def behav_jax_from_tables(self, tables) -> np.ndarray:
        """Device batched table-conv2d; the PSNR combine stays in host float64.

        The conv output is exact integer arithmetic (identical to the numpy
        path), and the float64 PSNR reduction reuses the oracle expression, so
        AVG_PSNR_RED matches bit-for-bit across backends.
        """
        from .fastapp import _as_batch, table_conv2d_jax  # lazy JAX import

        batch = _as_batch(tables)
        self._prepare(batch.n_bits)
        self._ensure_accurate_psnr()
        y = np.asarray(table_conv2d_jax(batch, self._img_codes, self._k_codes))
        return np.array(
            [self._psnr_accurate - self._psnr_from_int(yd) for yd in y],
            dtype=np.float64,
        )
