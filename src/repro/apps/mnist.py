"""Digit classification: dense (GEMV) layer on AxO arithmetic (Table 2, Fig. 18).

MNIST is unavailable offline, so a deterministic procedural surrogate with the
same structure: 10 fixed smooth class prototypes on a 16x16 grid, samples are
shifted/noised prototypes, and the classifier is a ridge-trained linear layer --
i.e. exactly the paper's "last dense layer" GEMV workload.  Inference runs the
GEMV through the operator's product table; BEHAV = classification error (%).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .base import AxOApplication, quantize_int8, table_matmul

__all__ = ["DigitClassification"]


def _prototypes(side: int, n_classes: int, seed: int) -> np.ndarray:
    """Smooth random blobs: (C, side*side) in [0, 1]."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float64)
    protos = []
    for _ in range(n_classes):
        img = np.zeros((side, side))
        for _ in range(4):  # a few Gaussian strokes per class
            cy, cx = rng.uniform(2, side - 2, size=2)
            sy, sx = rng.uniform(1.0, 3.0, size=2)
            img += np.exp(-(((yy - cy) / sy) ** 2 + ((xx - cx) / sx) ** 2))
        img /= img.max()
        protos.append(img.ravel())
    return np.stack(protos)


def _samples(
    protos: np.ndarray, side: int, n_per_class: int, noise: float, seed: int,
    max_shift: int = 1,
) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for c, p in enumerate(protos):
        img = p.reshape(side, side)
        for _ in range(n_per_class):
            dy, dx = rng.integers(-max_shift, max_shift + 1, size=2)
            s = np.roll(np.roll(img, dy, axis=0), dx, axis=1)
            s = s + noise * rng.standard_normal(s.shape)
            xs.append(s.ravel())
            ys.append(c)
    return np.stack(xs), np.array(ys)


@dataclass
class DigitClassification(AxOApplication):
    name: str = "mnist"
    side: int = 16
    n_classes: int = 10
    n_train_per_class: int = 40
    n_test_per_class: int = 25
    noise: float = 0.12
    max_shift: int = 1
    seed: int = 11

    _xte: np.ndarray = field(init=False, repr=False)       # (S, F) float
    _W: np.ndarray = field(init=False, repr=False)         # (F, C) float
    _x_codes: np.ndarray = field(init=False, repr=False)   # (S, F)
    _w_codes: np.ndarray = field(init=False, repr=False)   # (F, C)
    _labels: np.ndarray = field(init=False, repr=False)
    _prep_bits: int = field(init=False, repr=False, default=0)

    def __post_init__(self) -> None:
        protos = _prototypes(self.side, self.n_classes, self.seed)
        xtr, ytr = _samples(
            protos, self.side, self.n_train_per_class, self.noise, self.seed + 1, self.max_shift
        )
        xte, yte = _samples(
            protos, self.side, self.n_test_per_class, self.noise, self.seed + 2, self.max_shift
        )
        # ridge-trained dense layer (float training; int8 inference as in the paper)
        onehot = np.eye(self.n_classes)[ytr] - 1.0 / self.n_classes
        A = xtr.T @ xtr + 1e-2 * np.eye(xtr.shape[1])
        self._xte = xte
        self._W = np.linalg.solve(A, xtr.T @ onehot)        # (F, C)
        self._labels = yte
        self._prepare(8)

    def _prepare(self, n_bits: int) -> None:
        if self._prep_bits == n_bits:
            return
        self._x_codes, _ = quantize_int8(self._xte, n_bits=n_bits)
        self._w_codes, _ = quantize_int8(self._W, n_bits=n_bits)
        self._prep_bits = n_bits

    def behav_from_tables(self, tables: np.ndarray) -> np.ndarray:
        tables = np.asarray(tables)
        if tables.ndim == 2:
            tables = tables[None]
        self._prepare(int(tables.shape[-1]).bit_length() - 1)
        out = np.empty(len(tables), dtype=np.float64)
        for d, tab in enumerate(tables):
            logits = table_matmul(tab, self._x_codes, self._w_codes)
            pred = logits.argmax(axis=1)
            out[d] = 100.0 * (pred != self._labels).mean()
        return out

    def behav_jax_from_tables(self, tables) -> np.ndarray:
        """Batched device GEMV + argmax head: error rates for a table batch.

        Integer logits and first-maximum argmax ties match the oracle, so the
        misclassification counts -- and hence the error percentages -- are
        bit-identical across backends.
        """
        from .fastapp import _as_batch, mismatch_counts  # lazy JAX import

        batch = _as_batch(tables)
        self._prepare(batch.n_bits)
        wrong = np.asarray(
            mismatch_counts(batch, self._x_codes, self._w_codes, self._labels)
        ).astype(np.float64)
        return 100.0 * (wrong / len(self._labels))
