"""Accelerator-native application-BEHAV engine (the apps' ``backend="jax"`` path).

The numpy application substrate scores a ``(D, L)`` config batch one product
table at a time: ``AxOApplication.behav`` builds ``(D, 2^N, 2^N)`` tables on
the host and each app loops D python iterations of fancy-indexed gathers.
After the fastchar engine (PR 1) removed operator-level characterization from
the DSE critical path, this loop dominates every ``run_dse`` with an
application objective.  This module evaluates the same app pipelines in a
handful of device dispatches built around three interchangeable table-
arithmetic implementations:

  ``impl="gemm"`` (default off-TPU) -- **pair-plane masked GEMM**.  The
      operator's row structure gives ``T_d[a, b] = sum_r 4^r S_d[r,
      pair_r(a), b]`` with ``pair_r(a)`` one of 4 values, so a table-matmul
      collapses to R dense f32 GEMMs against the *tiny* per-row config tables
      (``fastchar``'s ``(R, D, 4, 2^N)`` gather) -- no per-element table
      lookups and no full product tables at all.  Every intermediate is an
      integer below 2^24, so the f32 GEMMs are bit-exact (asserted in tests).
  ``impl="xla"`` -- flattened ``jnp.take`` gathers + integer reductions over
      device-resident product tables, tiled over cache-sized config chunks
      with ``lax.map`` like ``fastchar.behav_partials``.  Per-config operand
      codes (the FFN's re-quantized activations) always take this path.
  ``impl="pallas"`` (default on TPU for config-shared matmuls) -- the batched
      table-GEMV kernel in ``kernels.app_kernels`` that keeps each config's
      table VMEM-resident across the K reduction (interpret-mode on CPU).
  ``impl="entry"`` / ``impl="entry_pallas"`` -- **table-free** twins.  The
      per-row ``(4, B)`` planes are synthesized on device directly from the
      ``(D, R)`` config masks by the carry-chain model
      (``fastchar._synth_small_jax`` for the XLA path, in-kernel
      ``_chain_eval`` for the Pallas GEMV), so neither the host row-table
      gather nor the ``(D, 2^N, 2^N)`` product-table build ever runs --
      which is what admits 12-bit operands, where the full table would be
      67 MB *per config*.  Bit-identical to the table paths by construction
      (the synthesized planes equal the gathered ones; asserted in tests).

Per-app BEHAV heads combine integer device outputs (logit argmax mismatch
counts, filtered signals, conv outputs) on the host in float64 with exactly
the oracle's expressions, which keeps every app BEHAV metric bit-identical to
the numpy path (count-based *and* float).

Execution policy rides on the :class:`TableBatch` itself: ``table_batch(...,
ctx=ExecutionContext(...))`` gives every primitive scoring that batch the same
kernel-impl preference and config-axis mesh sharding (``shard_map`` over the D
axis; per-config scores are independent, so sharded results are bit-identical
to the unsharded dispatch).

Everything is opt-in: importing this module pulls in JAX; ``repro.apps``
modules import it lazily when a caller passes ``backend="jax"``.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from ..core.engine import MESH_AXIS, ExecutionContext
from ..core.fastchar import _device_tables, _gather_small, _synth_small_jax
from ..obs import telemetry as obs
from ..core.operator_model import OperatorSpec, config_to_masks, spec_for

__all__ = [
    "TableBatch",
    "table_batch",
    "default_matmul_impl",
    "product_tables_jax",
    "table_matmul_jax",
    "table_conv1d_jax",
    "table_conv2d_jax",
    "mismatch_counts",
    "app_behav_jax",
    "multi_app_behav_jax",
]

MATMUL_IMPLS = ("gemm", "xla", "pallas", "entry", "entry_pallas")
# impls that score straight from the config masks, never building tables
_ENTRY_IMPLS = ("entry", "entry_pallas")


def default_matmul_impl() -> str:
    """Pallas table-GEMV on TPU, pair-plane GEMM elsewhere (interpret-mode
    Pallas is a correctness twin, not a CPU fast path)."""
    from ..kernels.ops import on_tpu

    return "pallas" if on_tpu() else "gemm"


# ---------------------------------------------------------------------------
# Device-resident tables
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_bits",))
def _tables_from_small(small: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """(R, D, 4, B) per-row tables -> (D, 2^N, 2^N) int32 product tables."""
    spec = spec_for(n_bits)
    _, _, _, pair_idx = _device_tables(n_bits)
    approx = None
    for r in range(spec.rows):
        term = jnp.take(small[r], pair_idx[r], axis=1) << (2 * r)  # (D, A, B)
        approx = term if approx is None else approx + term
    return approx


@dataclass
class TableBatch:
    """A config batch on device: per-row tables now, full tables on demand.

    ``small`` (the ``(R, D, 4, 2^N)`` per-row config tables, ~4096 ints per
    config) feeds the pair-plane GEMM paths; the full ``(D, 2^N, 2^N)``
    product tables are only reconstructed when a gather/Pallas path asks.
    """

    masks: jnp.ndarray | None        # (D, R) int32, None when built from tables
    n_bits: int
    ctx: ExecutionContext | None = None  # execution policy for the primitives
    _small: jnp.ndarray | None = field(default=None, repr=False)
    _tables: jnp.ndarray | None = field(default=None, repr=False)
    _entry_small: jnp.ndarray | None = field(default=None, repr=False)

    def __len__(self) -> int:
        src = self.masks if self.masks is not None else self._tables
        return src.shape[0]

    @property
    def n_codes(self) -> int:
        return 1 << self.n_bits

    @property
    def small(self) -> jnp.ndarray:
        if self._small is None:
            if self.masks is None:
                raise ValueError(
                    "TableBatch built from raw product tables has no per-row "
                    "tables; construct it with table_batch(spec, configs) to "
                    "use the pair-plane GEMM paths"
                )
            self._small = _gather_small(self.masks, self.n_bits)
        return self._small

    @property
    def has_small(self) -> bool:
        return self._small is not None or self.masks is not None

    @property
    def entry_small(self) -> jnp.ndarray:
        """Per-row planes synthesized on device from the masks (table-free:
        carry-chain evaluation, no host row-table gather).  Bit-identical to
        ``small``; cached separately so the entry paths share one synthesis
        across every app head scoring this batch."""
        if self._entry_small is None:
            if self.masks is None:
                raise ValueError(
                    "TableBatch built from raw product tables has no config "
                    "masks; construct it with table_batch(spec, configs) to "
                    "use the table-free entry paths"
                )
            self._entry_small = _synth_small_jax(self.masks, self.n_bits)
        return self._entry_small

    @property
    def tables(self) -> jnp.ndarray:
        if self._tables is None:
            self._tables = _tables_from_small(self.small, self.n_bits)
        return self._tables


def table_batch(
    spec: OperatorSpec, configs: np.ndarray, ctx: ExecutionContext | None = None
) -> TableBatch:
    """(D, L) {0,1} configs -> device TableBatch for this operator family.

    The batch carries ``ctx`` so every primitive scoring it inherits the same
    execution policy (kernel impl preference, config-axis mesh sharding)
    without each app head having to thread a context through its signature.
    """
    configs = np.atleast_2d(np.asarray(configs)).astype(np.uint8)
    masks = jnp.asarray(config_to_masks(spec, configs).astype(np.int32))
    return TableBatch(masks=masks, n_bits=spec.n_bits, ctx=ctx)


def _as_batch(tables) -> TableBatch:
    if isinstance(tables, TableBatch):
        return tables
    tables = jnp.asarray(tables, jnp.int32)
    if tables.ndim == 2:  # single table, like the numpy behav_from_tables
        tables = tables[None]
    n_bits = int(tables.shape[-1]).bit_length() - 1
    return TableBatch(masks=None, n_bits=n_bits, _tables=tables)


def product_tables_jax(spec: OperatorSpec, configs: np.ndarray) -> jnp.ndarray:
    """(D, L) {0,1} configs -> device (D, 2^N, 2^N) int32 product tables.

    Bit-identical to ``operator_model.product_tables`` (same row tables, same
    carry-truncation semantics; parity is asserted in tests).
    """
    return table_batch(spec, configs).tables


# ---------------------------------------------------------------------------
# Pair-plane GEMM cores (impl="gemm")
# ---------------------------------------------------------------------------
#
# f32 exactness: every GEMM operand/partial is an integer of magnitude at most
# K * max|S_r| = K * 2^(n_bits+1) (guarded < 2^24 by _gemm_ok), and the int32
# combine of the <= R shifted row results stays below 2^31.


def _gemm_ok(k: int, n_bits: int) -> bool:
    return k * (1 << (n_bits + 1)) < (1 << 24)


def _pair_planes(a: jnp.ndarray, k: int, r: int) -> jnp.ndarray:
    """(..., K) codes -> (..., 4K) f32 one-hot over (pair_r(code), k)."""
    pair = 2 * ((a >> (2 * r)) & 1) + ((a >> (2 * r + 1)) & 1)
    q = pair * k + jnp.arange(k, dtype=jnp.int32)
    lead = a.shape[:-1]
    onehot = jnp.zeros(lead + (4 * k,), jnp.float32)
    idx = tuple(
        jnp.arange(s).reshape((1,) * i + (-1,) + (1,) * (len(lead) - i))
        for i, s in enumerate(lead)
    )
    return onehot.at[idx + (q,)].set(1.0)


@functools.partial(jax.jit, static_argnames=("n_bits",))
def _matmul_gemm(small, a, b, n_bits: int):
    """small (R, D, 4, B); a (M, K); b (K, N) -> (D, M, N) int32."""
    spec = spec_for(n_bits)
    d = small.shape[1]
    k = a.shape[1]
    n = b.shape[1]
    out = None
    for r in range(spec.rows):
        a1 = _pair_planes(a, k, r)                              # (M, 4K)
        w = jnp.take(small[r], b, axis=2).reshape(d, 4 * k, n)  # (D, 4K, N)
        res = jnp.einsum("mq,dqn->dmn", a1, w.astype(jnp.float32))
        term = res.astype(jnp.int32) << (2 * r)
        out = term if out is None else out + term
    return out


@functools.partial(jax.jit, static_argnames=("n_bits",))
def _contract_gemm_flat(small, a, bvec, n_bits: int):
    """small (R, D, 4, B); a (M, K) windows; bvec (K,) taps -> (D, M) int32.

    The N=1 table-matmul (every conv is one): a single (D, 4K) x (4K, M) GEMM
    per row instead of the batched einsum.
    """
    spec = spec_for(n_bits)
    d = small.shape[1]
    k = a.shape[1]
    out = None
    for r in range(spec.rows):
        a1 = _pair_planes(a, k, r)                              # (M, 4K)
        w = jnp.take(small[r], bvec, axis=2).reshape(d, 4 * k)  # (D, 4K)
        res = w.astype(jnp.float32) @ a1.T                      # (D, M)
        term = res.astype(jnp.int32) << (2 * r)
        out = term if out is None else out + term
    return out


# ---------------------------------------------------------------------------
# Flattened-gather cores (impl="xla")
# ---------------------------------------------------------------------------


def _pad_leading(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    pad = (-x.shape[0]) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x


@functools.partial(jax.jit, static_argnames=("d_chunk",))
def _matmul_take_shared(tables, a, b, d_chunk: int):
    """tables (D, A, B); a (M, K); b (K, N) -> (D, M, N) int32.

    (M, N, K) gather order keeps the K reduction contiguous in memory.
    """
    d, _, nb = tables.shape
    m, k = a.shape
    n = b.shape[1]
    idx = (a[:, None, :] * nb + b.T[None, :, :]).reshape(-1)   # (M*N*K,)
    tf = tables.reshape(d // d_chunk, d_chunk, -1)

    def chunk(tc):  # (Dc, A*B) -> (Dc, M, N)
        prod = jnp.take(tc, idx, axis=1)
        return prod.reshape(d_chunk, m, n, k).sum(axis=-1)

    return jax.lax.map(chunk, tf).reshape(d, m, n)


@functools.partial(jax.jit, static_argnames=("d_chunk",))
def _matmul_take_batched(tables, a, b, d_chunk: int):
    """tables (D, A, B); a (D, M, K) per-config codes; b (K, N) -> (D, M, N)."""
    d, _, nb = tables.shape
    _, m, k = a.shape
    n = b.shape[1]
    tf = tables.reshape(d // d_chunk, d_chunk, -1)
    af = a.reshape(d // d_chunk, d_chunk, m, k)

    def chunk(args):
        tc, ac = args
        idx = (ac[:, :, :, None] * nb + b[None, None, :, :]).reshape(d_chunk, -1)
        prod = jnp.take_along_axis(tc, idx, axis=1)
        return prod.reshape(d_chunk, m, k, n).sum(axis=2)

    return jax.lax.map(chunk, (tf, af)).reshape(d, m, n)


# ---------------------------------------------------------------------------
# Table-free cores (impl="entry"): per-row gathers from synthesized planes
# ---------------------------------------------------------------------------
#
# Same (M, N, K)-ordered flattened gathers as the impl="xla" cores, but from
# the device-synthesized (R, D, 4, B) planes instead of the (D, A, B) product
# tables: out[d, m, n] = sum_r small[r, d, pair_r(a[m, k]), b[k, n]] << 2r.
# No (D, A, B) intermediate exists at any point, so working-set memory is
# R * 4 * B ints per config at every operand width.


@functools.partial(jax.jit, static_argnames=("n_bits", "d_chunk"))
def _matmul_entry_shared(small, a, b, n_bits: int, d_chunk: int):
    """small (R, D, 4, B); a (M, K); b (K, N) -> (D, M, N) int32."""
    spec = spec_for(n_bits)
    nb = spec.n_inputs
    d = small.shape[1]
    m, k = a.shape
    n = b.shape[1]
    sf = small.transpose(1, 0, 2, 3).reshape(d // d_chunk, d_chunk, spec.rows, -1)
    idxs = [
        (
            ((2 * ((a >> (2 * r)) & 1) + ((a >> (2 * r + 1)) & 1))[:, None, :])
            * nb
            + b.T[None, :, :]
        ).reshape(-1)
        for r in range(spec.rows)
    ]  # per-row (M*N*K,) flat indices into the (4*B,) planes

    def chunk(sc):  # (Dc, R, 4B) -> (Dc, M, N)
        out = None
        for r in range(spec.rows):
            prod = jnp.take(sc[:, r], idxs[r], axis=1)
            term = prod.reshape(d_chunk, m, n, k).sum(axis=-1) << (2 * r)
            out = term if out is None else out + term
        return out

    return jax.lax.map(chunk, sf).reshape(d, m, n)


@functools.partial(jax.jit, static_argnames=("n_bits", "d_chunk"))
def _matmul_entry_batched(small, a, b, n_bits: int, d_chunk: int):
    """small (R, D, 4, B); a (D, M, K) per-config codes; b (K, N) -> (D, M, N)."""
    spec = spec_for(n_bits)
    nb = spec.n_inputs
    d = small.shape[1]
    _, m, k = a.shape
    n = b.shape[1]
    sf = small.transpose(1, 0, 2, 3).reshape(d // d_chunk, d_chunk, spec.rows, -1)
    af = a.reshape(d // d_chunk, d_chunk, m, k)

    def chunk(args):
        sc, ac = args
        out = None
        for r in range(spec.rows):
            pair = 2 * ((ac >> (2 * r)) & 1) + ((ac >> (2 * r + 1)) & 1)
            idx = (pair[:, :, :, None] * nb + b[None, None, :, :]).reshape(
                d_chunk, -1
            )
            prod = jnp.take_along_axis(sc[:, r], idx, axis=1)
            term = prod.reshape(d_chunk, m, k, n).sum(axis=2) << (2 * r)
            out = term if out is None else out + term
        return out

    return jax.lax.map(chunk, (sf, af)).reshape(d, m, n)


def _pad_small(small: jnp.ndarray, mult: int) -> jnp.ndarray:
    """Pad the D axis (axis 1) of (R, D, 4, B) planes with zeros."""
    pad = (-small.shape[1]) % mult
    if pad:
        z = jnp.zeros((small.shape[0], pad) + small.shape[2:], small.dtype)
        small = jnp.concatenate([small, z], axis=1)
    return small


def _windows_1d(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """(T,) -> (T-k+1, k) valid-mode sliding windows."""
    t = x.shape[0]
    return x[jnp.arange(t - k + 1)[:, None] + jnp.arange(k)[None, :]]


def _windows_2d(img: jnp.ndarray, kh: int, kw: int) -> jnp.ndarray:
    """(H, W) -> (H-kh+1, W-kw+1, kh, kw) valid-mode sliding windows."""
    h, w = img.shape
    oy, ox = h - kh + 1, w - kw + 1
    return img[
        jnp.arange(oy)[:, None, None, None] + jnp.arange(kh)[None, None, :, None],
        jnp.arange(ox)[None, :, None, None] + jnp.arange(kw)[None, None, None, :],
    ]


@jax.jit
def _conv1d_take(tables, x, h):
    d, _, nb = tables.shape
    t, k = x.shape[0], h.shape[0]
    win = _windows_1d(x, k)                                 # (T', k)
    idx = (win * nb + h[None, :]).reshape(-1)
    prod = jnp.take(tables.reshape(d, -1), idx, axis=1)
    return prod.reshape(d, t - k + 1, k).sum(axis=2)


@functools.partial(jax.jit, static_argnames=("d_chunk",))
def _conv2d_take(tables, img, kern, d_chunk: int):
    d, _, nb = tables.shape
    kh, kw = kern.shape
    win = _windows_2d(img, kh, kw)                          # (oy, ox, kh, kw)
    oy, ox = win.shape[0], win.shape[1]
    idx = (win * nb + kern[None, None, :, :]).reshape(-1)
    tf = tables.reshape(d // d_chunk, d_chunk, -1)

    def chunk(tc):
        prod = jnp.take(tc, idx, axis=1)
        return prod.reshape(d_chunk, oy, ox, kh * kw).sum(axis=-1)

    return jax.lax.map(chunk, tf).reshape(d, oy, ox)


# ---------------------------------------------------------------------------
# Public primitives
# ---------------------------------------------------------------------------


def _resolve_impl(impl: str | None, batch: TableBatch, k: int) -> str:
    explicit = impl is not None
    if impl is None and batch.ctx is not None:
        # context preference is auto-with-preference, not a hard per-call ask:
        # it may still fall back when the named impl cannot run this batch
        # (the menu itself comes from the kernel registry's fastapp specs)
        impl = batch.ctx.resolve_impl("fastapp")
    impl = default_matmul_impl() if impl is None else impl
    if impl not in MATMUL_IMPLS:
        raise ValueError(f"unknown fastapp impl {impl!r}")
    if impl == "gemm" and not (batch.has_small and _gemm_ok(k, batch.n_bits)):
        if explicit:  # never silently hand back a different impl than asked for
            raise ValueError(
                "impl='gemm' unavailable: "
                + (
                    f"K={k} exceeds the f32-exactness bound for {batch.n_bits}-bit"
                    if batch.has_small
                    else "TableBatch built from raw tables has no per-row tables"
                )
            )
        impl = "xla"  # auto-selection falls back to the gather path
    if impl in _ENTRY_IMPLS and batch.masks is None:
        if explicit:
            raise ValueError(
                f"impl={impl!r} unavailable: TableBatch built from raw tables "
                "has no config masks to synthesize entries from"
            )
        impl = "xla"
    return impl


def _config_mesh_ctx(batch: TableBatch, d: int) -> ExecutionContext | None:
    """The batch's context iff it shards 'configs' and ``d`` divides evenly."""
    ctx = batch.ctx
    if ctx is None or not ctx.shards("configs") or d % ctx.device_count:
        return None
    return ctx


# Cached jit(shard_map(primitive)) builders, keyed by (frozen) context plus
# the closure's static parameters -- building a fresh shard_map per call would
# retrace and recompile every dispatch.  Builders whose static parameter is a
# *tunable* tile (the gather paths' d_chunk) key on (context, shape bucket)
# instead and keep the tile in the value, so a re-tuned bucket replaces its
# entry in place rather than leaving a stale compiled executable pinned.

_SHARDED_TAKE_CACHE: dict = {}


def _sharded_by_bucket(key, tiles, build):
    hit = _SHARDED_TAKE_CACHE.get(key)
    if hit is not None and hit[0] == tiles:
        return hit[1]
    ctx = next((k for k in key if isinstance(k, ExecutionContext)), None)
    obs.of(ctx).count("shard.rebuild.fastapp")
    fn = build()
    _SHARDED_TAKE_CACHE[key] = (tiles, fn)
    return fn


@functools.lru_cache(maxsize=None)
def _sharded_matmul_gemm(ctx: ExecutionContext, n_bits: int):
    from jax.sharding import PartitionSpec as P

    return jax.jit(ctx.shard_call(
        lambda s, a, b: _matmul_gemm(s, a, b, n_bits),
        in_specs=(P(None, MESH_AXIS), P(), P()), out_specs=P(MESH_AXIS),
    ))


def _sharded_matmul_take_shared(ctx: ExecutionContext, d_chunk: int, bucket):
    from jax.sharding import PartitionSpec as P

    return _sharded_by_bucket(
        ("take_shared", ctx, bucket), d_chunk,
        lambda: jax.jit(ctx.shard_call(
            lambda t, a, b: _matmul_take_shared(t, a, b, d_chunk),
            in_specs=(P(MESH_AXIS), P(), P()), out_specs=P(MESH_AXIS),
        )),
    )


def _sharded_matmul_take_batched(ctx: ExecutionContext, d_chunk: int, bucket):
    from jax.sharding import PartitionSpec as P

    return _sharded_by_bucket(
        ("take_batched", ctx, bucket), d_chunk,
        lambda: jax.jit(ctx.shard_call(
            lambda t, a, b: _matmul_take_batched(t, a, b, d_chunk),
            in_specs=(P(MESH_AXIS), P(MESH_AXIS), P()), out_specs=P(MESH_AXIS),
        )),
    )


def _sharded_matmul_entry_shared(ctx: ExecutionContext, n_bits: int,
                                 d_chunk: int, bucket):
    from jax.sharding import PartitionSpec as P

    return _sharded_by_bucket(
        ("entry_shared", ctx, bucket), d_chunk,
        lambda: jax.jit(ctx.shard_call(
            lambda s, a, b: _matmul_entry_shared(s, a, b, n_bits, d_chunk),
            in_specs=(P(None, MESH_AXIS), P(), P()), out_specs=P(MESH_AXIS),
        )),
    )


def _sharded_matmul_entry_batched(ctx: ExecutionContext, n_bits: int,
                                  d_chunk: int, bucket):
    from jax.sharding import PartitionSpec as P

    return _sharded_by_bucket(
        ("entry_batched", ctx, bucket), d_chunk,
        lambda: jax.jit(ctx.shard_call(
            lambda s, a, b: _matmul_entry_batched(s, a, b, n_bits, d_chunk),
            in_specs=(P(None, MESH_AXIS), P(MESH_AXIS), P()),
            out_specs=P(MESH_AXIS),
        )),
    )


def _sharded_entry_gemv(ctx: ExecutionContext, n_bits: int, k_tile: int,
                        interpret: bool, bucket):
    from jax.sharding import PartitionSpec as P

    from ..kernels.app_kernels import entry_gemv_pallas

    return _sharded_by_bucket(
        ("entry_gemv", ctx, interpret, bucket), k_tile,
        lambda: jax.jit(ctx.shard_call(
            lambda mk, a, b: entry_gemv_pallas(
                mk, a, b, n_bits, k_tile=k_tile, interpret=interpret
            ),
            in_specs=(P(MESH_AXIS), P(), P()), out_specs=P(MESH_AXIS),
        )),
    )


@functools.lru_cache(maxsize=None)
def _sharded_contract_gemm_flat(ctx: ExecutionContext, n_bits: int):
    from jax.sharding import PartitionSpec as P

    return jax.jit(ctx.shard_call(
        lambda s, w, v: _contract_gemm_flat(s, w, v, n_bits),
        in_specs=(P(None, MESH_AXIS), P(), P()), out_specs=P(MESH_AXIS),
    ))


@functools.lru_cache(maxsize=None)
def _sharded_conv1d_take(ctx: ExecutionContext):
    from jax.sharding import PartitionSpec as P

    return jax.jit(ctx.shard_call(
        _conv1d_take, in_specs=(P(MESH_AXIS), P(), P()), out_specs=P(MESH_AXIS),
    ))


@functools.lru_cache(maxsize=None)
def _sharded_conv2d_take(ctx: ExecutionContext, d_chunk: int):
    from jax.sharding import PartitionSpec as P

    return jax.jit(ctx.shard_call(
        lambda t, im, kk: _conv2d_take(t, im, kk, d_chunk),
        in_specs=(P(MESH_AXIS), P(), P()), out_specs=P(MESH_AXIS),
    ))


def table_matmul_jax(
    tables,
    a_codes,
    b_codes,
    d_chunk: int | None = None,
    impl: str | None = None,
    k_tile: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Batched table matmul: (D, M, N) int32, every multiply a table lookup.

    ``tables`` is a ``TableBatch`` (preferred: enables the pair-plane GEMM
    path) or a raw ``(D, 2^N, 2^N)`` array.  ``a_codes`` is ``(M, K)`` (shared
    across configs) or ``(D, M, K)`` (per-config, e.g. the re-quantized hidden
    activations of the FFN app -- always the XLA gather path).  ``None``
    block shapes (the gather path's ``d_chunk``, the Pallas path's
    ``k_tile``) resolve through the kernel registry under the batch
    context's ``tuning`` policy.
    """
    from ..kernels.tuning import tiles_for

    batch = _as_batch(tables)
    a = jnp.asarray(a_codes, jnp.int32)
    b = jnp.asarray(b_codes, jnp.int32)
    d = len(batch)
    m, k, n = a.shape[-2], a.shape[-1], b.shape[1]
    impl = _resolve_impl(impl, batch, k)
    obs.of(batch.ctx).count(f"dispatch.fastapp.{impl}")
    mesh_ctx = _config_mesh_ctx(batch, d)

    if a.ndim == 2 and impl == "gemm":
        if mesh_ctx is not None:
            return _sharded_matmul_gemm(mesh_ctx, batch.n_bits)(batch.small, a, b)
        return _matmul_gemm(batch.small, a, b, batch.n_bits)

    if a.ndim == 2 and impl == "pallas":
        from ..kernels.app_kernels import table_gemv_pallas
        from ..kernels.ops import on_tpu

        interpret = (not on_tpu()) if interpret is None else interpret
        if k_tile is None:
            k_tile = tiles_for(batch.ctx, "fastapp.pallas",
                               n_bits=batch.n_bits, d=d, m=m, k=k, n=n)["k_tile"]
        k_tile = min(k_tile, max(k, 1))
        pad = (-k) % k_tile
        if pad:  # zero codes index table[0, 0] == 0: padding adds nothing
            a = jnp.concatenate([a, jnp.zeros((a.shape[0], pad), jnp.int32)], axis=1)
            b = jnp.concatenate([b, jnp.zeros((pad, b.shape[1]), jnp.int32)], axis=0)
        return table_gemv_pallas(
            batch.tables.reshape(d, -1), a, b, k_tile=k_tile, interpret=interpret
        )

    if a.ndim == 2 and impl == "entry_pallas":
        from ..kernels.app_kernels import entry_gemv_pallas
        from ..kernels.ops import on_tpu

        interpret = (not on_tpu()) if interpret is None else interpret
        if k_tile is None:
            k_tile = tiles_for(batch.ctx, "fastapp.entry_pallas",
                               n_bits=batch.n_bits, d=d, m=m, k=k, n=n)["k_tile"]
        k_tile = min(k_tile, max(k, 1))
        pad = (-k) % k_tile
        if pad:  # zero codes map through entry (0, 0) -> 0: padding is inert
            a = jnp.concatenate([a, jnp.zeros((a.shape[0], pad), jnp.int32)], axis=1)
            b = jnp.concatenate([b, jnp.zeros((pad, b.shape[1]), jnp.int32)], axis=0)
        if mesh_ctx is not None:
            from ..kernels import registry

            bucket = registry.get("fastapp.entry_pallas").bucket(
                n_bits=batch.n_bits, d=d, m=m, k=k, n=n
            )
            return _sharded_entry_gemv(
                mesh_ctx, batch.n_bits, k_tile, interpret, bucket
            )(batch.masks, a, b)
        return entry_gemv_pallas(
            batch.masks, a, b, batch.n_bits, k_tile=k_tile, interpret=interpret
        )

    if impl in _ENTRY_IMPLS:
        # table-free gather path ("entry", or "entry_pallas" with per-config
        # operand codes, which the GEMV kernel does not cover): chunked
        # per-row gathers from the device-synthesized planes
        if d_chunk is None:
            d_chunk = tiles_for(batch.ctx, "fastapp.entry",
                                n_bits=batch.n_bits, d=d, m=m, k=k, n=n)["d_chunk"]
        if mesh_ctx is not None:
            from ..kernels import registry

            # per-shard chunking, same story as the xla gather path: shrink
            # d_chunk so it divides the local config slice exactly (no pad
            # inside the shard), key the cache on the full shape bucket
            dc = math.gcd(d // mesh_ctx.device_count, d_chunk)
            bucket = registry.get("fastapp.entry").bucket(
                n_bits=batch.n_bits, d=d, m=m, k=k, n=n
            ) + (a.ndim,)
            if a.ndim == 3:
                return _sharded_matmul_entry_batched(
                    mesh_ctx, batch.n_bits, dc, bucket
                )(batch.entry_small, a, b)
            return _sharded_matmul_entry_shared(
                mesh_ctx, batch.n_bits, dc, bucket
            )(batch.entry_small, a, b)
        d_chunk = min(d_chunk, d)
        sp = _pad_small(batch.entry_small, d_chunk)
        if a.ndim == 3:
            out = _matmul_entry_batched(
                sp, _pad_leading(a, d_chunk), b, batch.n_bits, d_chunk
            )
        else:
            out = _matmul_entry_shared(sp, a, b, batch.n_bits, d_chunk)
        return out[:d]

    if d_chunk is None:
        d_chunk = tiles_for(batch.ctx, "fastapp.xla",
                            n_bits=batch.n_bits, d=d, m=m, k=k, n=n)["d_chunk"]
    if mesh_ctx is not None and impl == "xla":
        from ..kernels import registry

        # per-shard chunking: shrink d_chunk so it divides the local slice
        dc = math.gcd(d // mesh_ctx.device_count, d_chunk)
        # the full registry shape bucket (n_bits, d, m, k, n) + operand rank:
        # distinct app heads (different m/k/n -> different tuned d_chunk) get
        # distinct entries instead of thrashing one (n_bits, d) slot
        bucket = registry.get("fastapp.xla").bucket(
            n_bits=batch.n_bits, d=d, m=m, k=k, n=n
        ) + (a.ndim,)
        if a.ndim == 3:
            return _sharded_matmul_take_batched(mesh_ctx, dc, bucket)(
                batch.tables, a, b
            )
        return _sharded_matmul_take_shared(mesh_ctx, dc, bucket)(
            batch.tables, a, b
        )

    d_chunk = min(d_chunk, d)
    tp = _pad_leading(batch.tables, d_chunk)
    if a.ndim == 3:
        out = _matmul_take_batched(tp, _pad_leading(a, d_chunk), b, d_chunk)
    else:
        out = _matmul_take_shared(tp, a, b, d_chunk)
    return out[:d]


def table_conv1d_jax(tables, x_codes, h_codes, impl: str | None = None) -> jnp.ndarray:
    """Valid-mode 1-D correlation through per-config tables: (D, T-k+1) int32."""
    batch = _as_batch(tables)
    x = jnp.asarray(x_codes, jnp.int32)
    h = jnp.asarray(h_codes, jnp.int32)
    impl = _resolve_impl(impl, batch, h.shape[0])
    mesh_ctx = _config_mesh_ctx(batch, len(batch))
    if impl in _ENTRY_IMPLS and _gemm_ok(h.shape[0], batch.n_bits):
        # table-free: same flat contract as "gemm", fed by synthesized planes
        # (the sharded builder is shape-generic in the (R, D, 4, B) planes,
        # so the entry path rides the identical shard_map)
        win = _windows_1d(x, h.shape[0])
        if mesh_ctx is not None:
            return _sharded_contract_gemm_flat(mesh_ctx, batch.n_bits)(
                batch.entry_small, win, h
            )
        return _contract_gemm_flat(batch.entry_small, win, h, batch.n_bits)
    if impl == "gemm":
        win = _windows_1d(x, h.shape[0])
        if mesh_ctx is not None:
            return _sharded_contract_gemm_flat(mesh_ctx, batch.n_bits)(
                batch.small, win, h
            )
        return _contract_gemm_flat(batch.small, win, h, batch.n_bits)
    if mesh_ctx is not None and impl == "xla":
        return _sharded_conv1d_take(mesh_ctx)(batch.tables, x, h)
    return _conv1d_take(batch.tables, x, h)


def table_conv2d_jax(
    tables, img_codes, k_codes, d_chunk: int = 16, impl: str | None = None
) -> jnp.ndarray:
    """Valid-mode 2-D convolution through per-config tables: (D, H', W') int32."""
    batch = _as_batch(tables)
    img = jnp.asarray(img_codes, jnp.int32)
    kern = jnp.asarray(k_codes, jnp.int32)
    impl = _resolve_impl(impl, batch, int(kern.size))
    d = len(batch)
    mesh_ctx = _config_mesh_ctx(batch, d)
    if impl in _ENTRY_IMPLS and _gemm_ok(int(kern.size), batch.n_bits):
        kh, kw = kern.shape
        win = _windows_2d(img, kh, kw)
        oy, ox = win.shape[0], win.shape[1]
        if mesh_ctx is not None:
            out = _sharded_contract_gemm_flat(mesh_ctx, batch.n_bits)(
                batch.entry_small, win.reshape(oy * ox, kh * kw),
                kern.reshape(-1),
            )
        else:
            out = _contract_gemm_flat(
                batch.entry_small, win.reshape(oy * ox, kh * kw),
                kern.reshape(-1), batch.n_bits,
            )
        return out.reshape(d, oy, ox)
    if impl == "gemm":
        kh, kw = kern.shape
        win = _windows_2d(img, kh, kw)
        oy, ox = win.shape[0], win.shape[1]
        if mesh_ctx is not None:
            out = _sharded_contract_gemm_flat(mesh_ctx, batch.n_bits)(
                batch.small, win.reshape(oy * ox, kh * kw), kern.reshape(-1)
            )
        else:
            out = _contract_gemm_flat(
                batch.small, win.reshape(oy * ox, kh * kw), kern.reshape(-1),
                batch.n_bits,
            )
        return out.reshape(d, oy, ox)
    if mesh_ctx is not None and impl == "xla":
        dc = math.gcd(d // mesh_ctx.device_count, d_chunk)
        return _sharded_conv2d_take(mesh_ctx, dc)(batch.tables, img, kern)
    d_chunk = min(d_chunk, d)
    out = _conv2d_take(_pad_leading(batch.tables, d_chunk), img, kern, d_chunk)
    return out[:d]


# ---------------------------------------------------------------------------
# Jitted BEHAV heads
# ---------------------------------------------------------------------------


@jax.jit
def _argmax_mismatch(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """(D, S, C) integer logits -> (D,) int32 misclassification counts."""
    return jnp.sum(jnp.argmax(logits, axis=-1) != labels[None, :], axis=-1)


def mismatch_counts(
    tables, x_codes, w_codes, labels, d_chunk: int | None = None,
    impl: str | None = None, interpret: bool | None = None,
) -> jnp.ndarray:
    """Classification head: table-GEMV logits -> per-config mismatch counts.

    Integer argmax over integer logits breaks ties exactly like the numpy
    oracle (first maximum), so the resulting error *counts* are bit-identical.
    """
    logits = table_matmul_jax(
        tables, x_codes, w_codes, d_chunk=d_chunk, impl=impl, interpret=interpret
    )
    return _argmax_mismatch(logits, jnp.asarray(np.asarray(labels), jnp.int32))


# ---------------------------------------------------------------------------
# Batch driver
# ---------------------------------------------------------------------------


def multi_app_behav_jax(
    apps, spec: OperatorSpec, configs: np.ndarray, batch: int = 128,
    ctx: ExecutionContext | None = None,
) -> dict[str, np.ndarray]:
    """(D, L) configs -> {app.name: (D,) BEHAV} with ONE shared TableBatch.

    Scoring several applications one at a time re-runs the table gathers per
    app; here each config chunk is staged as a single device ``TableBatch``
    whose lazily-cached ``small``/``tables`` fields are shared by every app's
    ``behav_jax_from_tables`` head -- the multi-app DSE batching used by
    ``benchmarks/bench_apps.py`` (one engine pass for all four heads).
    """
    apps = list(apps)
    configs = np.atleast_2d(np.asarray(configs)).astype(np.uint8)
    d = len(configs)
    out = {app.name: np.empty(d, dtype=np.float64) for app in apps}
    for lo in range(0, d, batch):
        hi = min(lo + batch, d)
        cfgs = configs[lo:hi]
        bucket = min(batch, 1 << max(len(cfgs) - 1, 1).bit_length())
        if ctx is not None and ctx.shards("configs"):
            # a shard-divisible bucket keeps every chunk on the mesh path
            bucket = max(bucket, ctx.device_count)
            bucket += (-bucket) % ctx.device_count
        pad = bucket - len(cfgs)
        if pad:
            cfgs = np.concatenate([cfgs, np.zeros((pad, cfgs.shape[1]), np.uint8)])
        tb = table_batch(spec, cfgs, ctx=ctx)
        for app in apps:
            out[app.name][lo:hi] = app.behav_jax_from_tables(tb)[: hi - lo]
    return out


def app_behav_jax(
    app, spec: OperatorSpec, configs: np.ndarray, batch: int = 128,
    ctx: ExecutionContext | None = None,
) -> np.ndarray:
    """(D, L) configs -> (D,) app BEHAV through the device engine.

    ``batch`` configs at a time are staged as a device ``TableBatch`` and
    handed to the app's ``behav_jax_from_tables`` head; chunking bounds the
    device working set (a (128, 256, 256) int32 table batch is ~33 MB at N=8)
    exactly like the numpy ``AxOApplication.behav`` batching.  Chunks are
    padded up to power-of-two buckets (capped at ``batch``) so the jitted
    kernels compile at most ~log2(batch) distinct D shapes across a whole DSE
    run, however ragged the validated fronts get.
    """
    return multi_app_behav_jax([app], spec, configs, batch=batch, ctx=ctx)[
        app.name
    ]
