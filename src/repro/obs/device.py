"""On-device metric taps: host sinks that fire once per *dispatch*.

A tap is a function usable inside jitted code (even inside ``lax.fori_loop``
bodies): it stages a ``jax.experimental.io_callback`` whose host side appends
one record to the telemetry's series.  Because ``io_callback`` is an effect,
XLA keeps exactly one callback per dispatch site -- the callback runs every
time the compiled program executes (NOT once at trace time, and not once per
jit cache entry), which is what makes per-generation curves from inside
``CompiledNSGA2``'s ``fori_loop`` possible without hauling per-gen arrays out.

Under ``vmap`` the callback fires once per batch element with unbatched
(per-lane) arguments -- verified behaviour on jax 0.4.x; taps are therefore
kept out of sweep programs by default (lanes would interleave into one
series) and used on the single-run path.

``jax.effects_barrier()`` must run before reading the series: callbacks are
asynchronous on some backends.  :func:`flush` wraps that (and is safe to call
when JAX was never imported).

This module imports JAX lazily so numpy-only processes never pay for it.
"""

from __future__ import annotations

import time

__all__ = ["make_tap", "make_batched_tap", "null_tap", "flush"]


def null_tap(*args, **kwargs) -> None:
    """The disabled tap: stages nothing into the traced program."""
    return None


def make_tap(tel, name: str, fields: tuple):
    """Build an emit function ``tap(*vals)`` for use inside jitted code.

    ``fields`` names the positional values; each host-side firing appends
    ``{field: np_value, ..., "_host_t": perf_counter}`` to
    ``tel.series[name]`` and bumps the ``tap.<name>`` counter.  Calls from
    non-traced (eager) code work too -- io_callback runs the host function
    inline.
    """
    import numpy as np
    from jax.experimental import io_callback

    def _sink(*vals) -> None:
        rec = {f: np.asarray(v) for f, v in zip(fields, vals)}
        rec["_host_t"] = time.perf_counter()
        tel.emit(name, rec)
        tel.count(f"tap.{name}")

    def tap(*vals):
        if len(vals) != len(fields):
            raise TypeError(
                f"tap {name!r} expects {len(fields)} values {fields}, "
                f"got {len(vals)}"
            )
        # unordered: taps must not serialize the compiled program; record
        # order is recovered from the emitted fields (e.g. generation index)
        io_callback(_sink, None, *vals, ordered=False)

    tap.fields = fields
    tap.series = name
    return tap


def make_batched_tap(tel, name: str, fields: tuple):
    """Build a chunk-flushing emit function ``tap(rows, valid)``.

    The per-record tap from :func:`make_tap` stages one ``io_callback`` firing
    per loop iteration; in tight ``fori_loop`` bodies (the tapped GA's
    per-generation hv) the host round-trips dominate the dispatch.  The
    batched variant flushes a whole ``(C, len(fields))`` f32 row-buffer with
    ONE callback: the host side splits the buffer back into per-row records
    -- same series name, same per-record fields, same ``_host_t``/
    ``tap.<name>`` accounting as C individual firings -- and drops rows where
    ``valid`` is false (ragged final chunks pass a mask).
    """
    import numpy as np
    from jax.experimental import io_callback

    def _sink(rows, valid) -> None:
        rows = np.asarray(rows)
        for row in rows[np.asarray(valid).astype(bool)]:
            rec = {f: np.asarray(v) for f, v in zip(fields, row)}
            rec["_host_t"] = time.perf_counter()
            tel.emit(name, rec)
            tel.count(f"tap.{name}")

    def tap(rows, valid):
        # unordered like make_tap: record order within one flush is preserved
        # by the host loop; cross-flush order is recovered from the emitted
        # fields (e.g. the generation index)
        io_callback(_sink, None, rows, valid, ordered=False)

    tap.fields = fields
    tap.series = name
    return tap


def flush() -> None:
    """Wait for outstanding tap callbacks (no-op if JAX is not loaded)."""
    import sys

    jax = sys.modules.get("jax")
    if jax is not None:
        jax.effects_barrier()
