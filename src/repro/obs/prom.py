"""Prometheus text exposition + the serving /metrics and /healthz endpoints.

The serving driver (``launch/serve.py``) fills latency histograms and
throughput gauges on a live :class:`~repro.obs.telemetry.Telemetry`, but
until now an operator could only see them post-mortem (``--trace`` export).
This module makes the process scrapeable while it serves:

  * :func:`render_prometheus` renders a telemetry's counters / gauges /
    histograms as Prometheus **text exposition format 0.0.4** -- counters as
    ``<name>_total``, gauges as plain gauges, histograms as summaries
    (p50/p90/p99 quantile samples plus ``_count``/``_sum``).  Metric names
    are sanitized to the Prometheus charset and prefixed ``repro_``
    (``serve.decode_step_ms`` -> ``repro_serve_decode_step_ms``); a name
    that is both a gauge and a histogram keeps the summary under the base
    name and the gauge under ``<name>_last``.
  * :class:`MetricsServer` is a stdlib ``http.server`` on a background
    thread serving ``GET /metrics`` (live exposition of a telemetry --
    usually ``obs.GLOBAL``, which sees every child sink's counters) and
    ``GET /healthz`` (JSON: device liveness, tuning-cache status, optional
    deployment descriptor).  Application endpoints (the DSE service's
    ``POST /dse`` job intake, ``GET /dse`` result polling) mount through
    :meth:`MetricsServer.add_route`: a route fn takes the JSON body (POST)
    or the query params (GET) as a dict and returns a JSON-able dict.

Stdlib-only, like the rest of ``repro.obs``: the health probe's device check
imports JAX lazily and degrades to ``"unavailable"`` without it.
"""

from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qsl

from . import telemetry as obs

__all__ = [
    "CONTENT_TYPE",
    "render_prometheus",
    "health_payload",
    "MetricsServer",
]

#: the exposition-format content type Prometheus scrapers expect
CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_QUANTILES = (("0.5", "p50"), ("0.9", "p90"), ("0.99", "p99"))


def _prom_name(name: str) -> str:
    """``serve.decode_step_ms`` -> ``repro_serve_decode_step_ms``."""
    clean = _NAME_RE.sub("_", name)
    if not clean or not (clean[0].isalpha() or clean[0] == "_"):
        clean = "_" + clean
    return f"repro_{clean}"


def _fmt(value: float) -> str:
    """Prometheus sample value: floats as-is, +Inf/-Inf/NaN spelled out."""
    if value != value:
        return "NaN"
    if value == float("inf"):
        return "+Inf"
    if value == float("-inf"):
        return "-Inf"
    return repr(float(value))


def render_prometheus(tel: obs.Telemetry | None = None) -> str:
    """The telemetry's metrics in Prometheus text exposition format 0.0.4.

    Counters become ``<name>_total`` counters, gauges stay gauges, histogram
    deques render as summaries (quantiles computed from the retained
    samples).  Spans and device-tap series are not exposed -- they are
    trace-shaped, not scrape-shaped (use ``--trace`` / JSONL export).
    """
    tel = obs.GLOBAL if tel is None else tel
    with tel._lock:
        counters = dict(tel.counters)
        gauges = dict(tel.gauges)
        hist_names = list(tel.histograms)
    lines: list[str] = []

    for name in sorted(counters):
        pn = _prom_name(name) + "_total"
        lines.append(f"# TYPE {pn} counter")
        lines.append(f"{pn} {counters[name]}")

    hist_set = set(hist_names)
    for name in sorted(gauges):
        pn = _prom_name(name)
        if name in hist_set:
            pn += "_last"  # the summary owns the base name
        lines.append(f"# TYPE {pn} gauge")
        lines.append(f"{pn} {_fmt(gauges[name])}")

    for name in sorted(hist_names):
        s = tel.histogram_summary(name)
        if not s.get("count"):
            continue
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} summary")
        for q, key in _QUANTILES:
            lines.append(f'{pn}{{quantile="{q}"}} {_fmt(s[key])}')
        lines.append(f"{pn}_count {s['count']}")
        lines.append(f"{pn}_sum {_fmt(s['mean'] * s['count'])}")

    return "\n".join(lines) + "\n" if lines else "\n"


def _device_health() -> dict:
    """Liveness of the default JAX device: a trivial computation must land.

    JAX-less (or device-less) processes report ``"unavailable"`` rather than
    failing the probe -- the HTTP layer decides what that means for status.
    """
    try:
        import jax
        import jax.numpy as jnp

        dev = jax.devices()[0]
        val = int(jnp.asarray(1) + 1)  # forces a real dispatch + readback
        return {
            "status": "ok" if val == 2 else "error",
            "backend": jax.default_backend(),
            "kind": dev.device_kind,
            "count": jax.device_count(),
        }
    except Exception as exc:
        return {"status": "unavailable", "error": f"{type(exc).__name__}: {exc}"}


def health_payload(tel: obs.Telemetry | None = None,
                   deployment: dict | None = None,
                   check_device: bool = True) -> dict:
    """The ``/healthz`` JSON: device liveness + tuning cache + deployment.

    ``deployment`` is whatever descriptor the server was registered with
    (e.g. the AxO deployment summary from ``launch/serve.py``); ``None``
    reports ``"exact"`` -- no approximate operators deployed is a valid,
    healthy configuration, not a missing one.
    """
    from ..kernels.tuning import cache_status

    tel = obs.GLOBAL if tel is None else tel
    device = _device_health() if check_device else {"status": "skipped"}
    payload = {
        "status": "ok" if device["status"] in ("ok", "skipped") else "degraded",
        "device": device,
        "tuning_cache": cache_status(),
        "deployment": deployment if deployment is not None else {"mode": "exact"},
        "requests": tel.counter("serve.requests"),
    }
    return payload


class MetricsServer:
    """Background HTTP server: ``/metrics`` (Prometheus) + ``/healthz`` (JSON).

    ::

        srv = MetricsServer(tel=obs.GLOBAL, port=9100)
        srv.start()                 # returns once the socket is bound
        srv.set_deployment({...})   # reflected in /healthz
        ...
        srv.stop()

    ``port=0`` binds an ephemeral port (``srv.port`` reports the real one --
    the tests use this).  The handler holds no per-request state; the
    telemetry object is read live on every scrape, so whatever the serving
    loop recorded since the last scrape is visible immediately.
    """

    def __init__(self, tel: obs.Telemetry | None = None, port: int = 9100,
                 host: str = "127.0.0.1", check_device: bool = True) -> None:
        self.tel = obs.GLOBAL if tel is None else tel
        self.host = host
        self.port = port
        self.check_device = check_device
        self.deployment: dict | None = None
        self.routes: dict[tuple[str, str], object] = {}
        self._httpd: ThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None

    def set_deployment(self, deployment: dict | None) -> None:
        self.deployment = deployment

    def add_route(self, method: str, path: str, fn) -> None:
        """Mount ``fn(payload: dict) -> dict`` at (method, path).

        POST routes get the parsed JSON body; GET routes get the query
        params (single values).  The return dict is sent as JSON with 200;
        a ``ValueError``/``KeyError`` raised by the fn maps to 400, any
        other exception to 500.  Routes can be added before or after
        :meth:`start` -- the handler reads the table live.
        """
        self.routes[(method.upper(), path)] = fn

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "MetricsServer":
        if self._httpd is not None:
            return self
        server = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *args):  # no stderr chatter per scrape
                pass

            def _send(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def _send_json(self, code: int, payload: dict) -> None:
                body = (json.dumps(payload, indent=2) + "\n").encode()
                self._send(code, body, "application/json")

            def _route(self, method: str, path: str, payload: dict) -> bool:
                fn = server.routes.get((method, path))
                if fn is None:
                    return False
                try:
                    self._send_json(200, fn(payload))
                except (ValueError, KeyError, TypeError) as exc:
                    self._send_json(
                        400, {"error": f"{type(exc).__name__}: {exc}"}
                    )
                except Exception as exc:  # route bug: report, don't hang
                    self._send_json(
                        500, {"error": f"{type(exc).__name__}: {exc}"}
                    )
                return True

            def do_GET(self):
                path, _, query = self.path.partition("?")
                if path == "/metrics":
                    body = render_prometheus(server.tel).encode()
                    self._send(200, body, CONTENT_TYPE)
                elif path == "/healthz":
                    payload = health_payload(
                        server.tel, server.deployment,
                        check_device=server.check_device,
                    )
                    code = 200 if payload["status"] == "ok" else 503
                    self._send_json(code, payload)
                elif not self._route("GET", path, dict(parse_qsl(query))):
                    self._send(404, b"not found\n", "text/plain")

            def do_POST(self):
                path = self.path.split("?", 1)[0]
                try:
                    n = int(self.headers.get("Content-Length") or 0)
                    payload = json.loads(self.rfile.read(n) or b"{}")
                    if not isinstance(payload, dict):
                        raise ValueError("request body must be a JSON object")
                except ValueError as exc:
                    self._send_json(400, {"error": f"bad request body: {exc}"})
                    return
                if not self._route("POST", path, payload):
                    self._send(404, b"not found\n", "text/plain")

        self._httpd = ThreadingHTTPServer((self.host, self.port), Handler)
        self.port = self._httpd.server_address[1]  # resolve port=0
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-metrics", daemon=True
        )
        self._thread.start()
        self.tel.count("metrics.server_starts")
        return self

    def stop(self) -> None:
        if self._httpd is None:
            return
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
        self._httpd = None
        self._thread = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
