"""Compiled-cost profiling: XLA's own accounting as telemetry gauges.

The registry's analytical cost formulas (``KernelSpec.cost_fn`` -> wrapped
into ``pl.CostEstimate``) and the roofline model both *predict* FLOPs and
bytes; nothing validated those predictions against what XLA actually
compiled.  ApproxFPGAs (PAPERS.md) makes the general point: cost estimators
drift, and an estimator nobody checks against ground truth is worse than no
estimator -- the scheduler/autotuner trusts it.  This module closes that
loop:

  * :func:`profile_fn` compiles a callable via ``jit -> lower -> compile``
    and captures ``cost_analysis()`` (FLOPs, bytes accessed,
    transcendentals) + ``memory_analysis()`` (temp/argument/peak bytes)
    as telemetry **gauges** ``profile.<name>.<stat>`` plus one record in the
    ``profile`` series, using the same extraction as
    :func:`repro.launch.roofline.compiled_cost`;
  * :func:`check_estimate` cross-checks a measurement against an analytical
    estimate and flags any stat diverging **more than 2x** either way
    (counter ``profile.estimate_divergence`` + a WARN-ish gauge per kernel);
  * :func:`profile_registry` runs the check for every registry Pallas engine
    -- ``behav_stats_pallas``, ``table_gemv_pallas``,
    ``dominance_counts_pallas`` -- on small example shapes, comparing
    XLA's numbers against the registered ``cost_fn`` formulas;
  * :func:`trace_capture` wraps a block in ``jax.profiler.trace`` when the
    profiler is available (and a no-op otherwise), so
    ``ExecutionContext(telemetry="on")`` users can grab a device trace
    without importing jax.profiler themselves.

JAX is imported lazily inside the functions (module import stays stdlib-only,
like the rest of ``repro.obs``).  On CPU/interpret-mode the Pallas bodies are
executed via the interpreter, so XLA's accounting of the *wrapper* program
understates the analytical kernel formulas -- divergence flags there are
expected and informational; on real TPUs they mean a stale formula.
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass, field

from . import telemetry as obs

__all__ = [
    "ProfileRecord",
    "profile_fn",
    "check_estimate",
    "profile_registry",
    "trace_capture",
    "DIVERGENCE_RATIO",
]

#: estimate-vs-measured ratio beyond which a kernel's cost formula is flagged
DIVERGENCE_RATIO = 2.0

#: stats cross-checked against analytical estimates (memory stats have no
#: analytical twin -- they are capture-only)
_CHECKED = ("flops", "bytes_accessed")


@dataclass
class ProfileRecord:
    """One profiled compile: XLA's accounting + optional estimate check."""

    name: str
    cost: dict                               # compiled_cost() output
    estimate: dict | None = None             # analytical cost_fn() output
    divergence: dict = field(default_factory=dict)   # stat -> measured/est
    flagged: tuple = ()                      # stats beyond DIVERGENCE_RATIO

    def to_record(self) -> dict:
        return {
            "name": self.name,
            "cost": dict(self.cost),
            "estimate": None if self.estimate is None else dict(self.estimate),
            "divergence": dict(self.divergence),
            "flagged": list(self.flagged),
        }


def _gauge_cost(tel: obs.Telemetry, name: str, cost: dict) -> None:
    for stat, val in cost.items():
        tel.gauge(f"profile.{name}.{stat}", float(val))


def profile_fn(fn, *args, name: str | None = None, tel=None,
               static_argnums=(), **kwargs) -> ProfileRecord:
    """Compile ``fn(*args, **kwargs)`` and record XLA's cost accounting.

    ``fn`` may already be jitted (``jax.jit`` output exposes ``.lower``);
    plain callables are jitted here with ``static_argnums``.  The compiled
    artifact is discarded -- this is a dry-run costing, not a benchmark, so
    it is safe on shapes too big to execute quickly.  Gauges land on ``tel``
    (default: the current telemetry) as ``profile.<name>.flops`` etc., plus
    one record in the ``profile`` series.
    """
    import jax

    from ..launch.roofline import compiled_cost

    tel = obs.current() if tel is None else tel
    label = name or getattr(fn, "__name__", "fn")
    if not hasattr(fn, "lower"):
        fn = jax.jit(fn, static_argnums=static_argnums)
    with tel.span(f"profile.{label}"):
        compiled = fn.lower(*args, **kwargs).compile()
        cost = compiled_cost(compiled)
    _gauge_cost(tel, label, cost)
    rec = ProfileRecord(name=label, cost=cost)
    tel.emit("profile", rec.to_record())
    tel.count("profile.compiles")
    return rec


def check_estimate(record: ProfileRecord, estimate: dict, tel=None,
                   ratio: float = DIVERGENCE_RATIO) -> ProfileRecord:
    """Cross-check XLA's accounting against an analytical estimate.

    For each stat in both records, the divergence is ``measured / estimate``;
    anything outside ``[1/ratio, ratio]`` is flagged (gauge
    ``profile.<name>.divergence.<stat>`` + counter
    ``profile.estimate_divergence``).  A zero estimate with a nonzero
    measurement flags as ``inf``.
    """
    tel = obs.current() if tel is None else tel
    record.estimate = dict(estimate)
    flagged = []
    for stat in _CHECKED:
        if stat not in estimate:
            continue
        est = float(estimate[stat])
        meas = float(record.cost.get(stat, 0.0))
        if est <= 0.0:
            div = float("inf") if meas > 0.0 else 1.0
        else:
            div = meas / est
        record.divergence[stat] = div
        tel.gauge(f"profile.{record.name}.divergence.{stat}", div)
        if not (1.0 / ratio <= div <= ratio):
            flagged.append(stat)
            tel.count("profile.estimate_divergence")
    record.flagged = tuple(flagged)
    return record


# ---------------------------------------------------------------------------
# Registry sweep: every Pallas engine against its own cost formula
# ---------------------------------------------------------------------------


def _char_inputs(n_bits: int):
    """(small, exact, w) for behav_stats_pallas at a tiny config batch."""
    import numpy as np

    import jax.numpy as jnp

    from ..core.fastchar import _device_tables, _gather_small
    from ..core.operator_model import config_to_masks, spec_for

    spec = spec_for(n_bits)
    rng = np.random.default_rng(0)
    cfgs = rng.integers(0, 2, (8, spec.n_luts)).astype(np.uint8)
    masks = config_to_masks(spec, cfgs).astype(np.int32)
    _, exact, w, _ = _device_tables(n_bits)
    small = _gather_small(jnp.asarray(masks), n_bits)
    return small, jnp.asarray(exact), jnp.asarray(w)


def _app_inputs(n_bits: int):
    """(tables_flat, a_codes, b_codes) for table_gemv_pallas."""
    import numpy as np

    import jax.numpy as jnp

    from ..apps.fastapp import product_tables_jax
    from ..core.operator_model import spec_for

    spec = spec_for(n_bits)
    rng = np.random.default_rng(1)
    cfgs = rng.integers(0, 2, (4, spec.n_luts)).astype(np.uint8)
    tables = product_tables_jax(spec, cfgs)             # (D, A, B)
    d = tables.shape[0]
    tables_flat = tables.reshape(d, -1)
    m, k, n = 8, 16, 8
    a = jnp.asarray(rng.integers(0, spec.n_inputs, (m, k)), jnp.int32)
    b = jnp.asarray(rng.integers(0, spec.n_inputs, (k, n)), jnp.int32)
    return tables_flat, a, b


def _moo_inputs(p: int = 128, n_obj: int = 2):
    """(objs, viol, active) for dominance_counts_pallas."""
    import numpy as np

    import jax.numpy as jnp

    rng = np.random.default_rng(2)
    objs = jnp.asarray(rng.standard_normal((p, n_obj)), jnp.float32)
    viol = jnp.asarray(
        np.where(rng.uniform(size=p) < 0.5, 0.0, rng.uniform(0.1, 2.0, size=p)),
        jnp.float32,
    )
    active = jnp.asarray(rng.uniform(size=p) < 0.8)
    return objs, viol, active


def profile_registry(tel=None, n_bits: int = 8,
                     interpret: bool | None = None) -> list[ProfileRecord]:
    """Profile the three registry Pallas engines against their cost formulas.

    Compiles each kernel on a small example shape, captures XLA's
    cost/memory accounting as gauges, and flags estimate-vs-measured
    divergence beyond :data:`DIVERGENCE_RATIO`.  ``interpret=None`` picks
    interpret mode off-TPU (required there); on CPU the flags are expected
    (XLA costs the interpreter wrapper, not the kernel body) and serve as a
    smoke test of the *mechanism* -- on real TPUs a flag means the
    registered formula went stale.
    """
    import functools

    from ..kernels import registry
    from ..kernels.app_kernels import table_gemv_pallas
    from ..kernels.char_kernels import behav_stats_pallas
    from ..kernels.moo_kernels import dominance_counts_pallas
    from ..kernels.ops import on_tpu

    tel = obs.current() if tel is None else tel
    if interpret is None:
        interpret = not on_tpu()
    records: list[ProfileRecord] = []

    # fastchar: BEHAV partial stats
    small, exact, w = _char_inputs(n_bits)
    spec = registry.get("fastchar.pallas")
    d = int(small.shape[1])
    a, b = int(exact.shape[0]), int(exact.shape[1])
    bucket = spec.bucket(n_bits=n_bits, d=d)
    tiles = spec.default_tiles(bucket)
    rec = profile_fn(
        functools.partial(behav_stats_pallas, interpret=interpret, **tiles),
        small, exact, w, name="fastchar.pallas", tel=tel,
    )
    est = spec.cost_estimate(rows=int(small.shape[0]), d=d, a=a, b=b, **tiles)
    records.append(check_estimate(rec, est, tel=tel))

    # fastapp: table-GEMV
    tables_flat, ac, bc = _app_inputs(n_bits)
    spec = registry.get("fastapp.pallas")
    d = int(tables_flat.shape[0])
    m, k = int(ac.shape[0]), int(ac.shape[1])
    n = int(bc.shape[1])
    bucket = spec.bucket(n_bits=n_bits, d=d, m=m, k=k, n=n)
    tiles = spec.default_tiles(bucket)
    tiles["k_tile"] = min(tiles["k_tile"], k)
    rec = profile_fn(
        functools.partial(table_gemv_pallas, interpret=interpret, **tiles),
        tables_flat, ac, bc, name="fastapp.pallas", tel=tel,
    )
    est = spec.cost_estimate(d=d, m=m, k=k, n=n, a=1 << n_bits, **tiles)
    records.append(check_estimate(rec, est, tel=tel))

    # fastmoo: dominance counts
    objs, viol, active = _moo_inputs()
    spec = registry.get("fastmoo.pallas")
    p, n_obj = int(objs.shape[0]), int(objs.shape[1])
    bucket = spec.bucket(p=p, n_obj=n_obj)
    tiles = spec.default_tiles(bucket)
    rec = profile_fn(
        functools.partial(dominance_counts_pallas, interpret=interpret, **tiles),
        objs, viol, active, name="fastmoo.pallas", tel=tel,
    )
    est = spec.cost_estimate(p=p, n_obj=n_obj, **tiles)
    records.append(check_estimate(rec, est, tel=tel))
    return records


@contextlib.contextmanager
def trace_capture(path: str, tel=None):
    """``with trace_capture("/tmp/trace"):`` -- a ``jax.profiler.trace``
    block when the profiler is importable, a no-op otherwise.  Pairs with
    ``Telemetry(annotate=True)`` so spans line up with XLA activity."""
    tel = obs.current() if tel is None else tel
    try:
        import jax.profiler as _prof
    except Exception:
        _prof = None
    if _prof is None:
        yield None
        return
    with tel.span("profile.trace_capture", path=path):
        try:
            _prof.start_trace(path)
        except Exception:
            yield None
            return
        try:
            yield path
        finally:
            _prof.stop_trace()
            tel.count("profile.traces")
