"""Unified telemetry: spans, counters/gauges/histograms, one sink per run.

Every layer of the DSE->serving stack reports here: ``dse.run_dse`` wraps its
stages (characterize / MaP / GA / validate) in **spans**, the kernel
registry/autotuner counts dispatches and cache traffic with **counters**, the
Pallas wrappers record pad-to-block waste **gauges**, and the serving driver
fills per-request latency **histograms**.  ``repro.obs.device`` adds on-device
metric taps (``io_callback`` sinks that fire once per *dispatch*, not once per
trace) used by ``fastmoo.CompiledNSGA2`` for per-generation hypervolume
curves.

Design rules:

  * **One sink.**  A :class:`Telemetry` object is carried by
    ``ExecutionContext(telemetry=...)`` and threaded to every engine.  Code
    without a context reports to the process-wide :data:`GLOBAL` aggregate
    (or whatever :func:`use` has made current); counters on a child telemetry
    propagate to its ``parent`` so process totals stay queryable (the
    ``kernels.tuning.STATS`` back-compat alias reads them there).
  * **Disabled means no-op.**  :data:`NULL` (``telemetry="off"``) swallows
    everything: ``span`` returns a shared reusable context manager, counters
    are ``pass``, and device taps insert *nothing* into traced programs, so
    the off path is the pre-telemetry program bit for bit.
  * **No JAX here.**  This module is stdlib-only (numpy accepted at call
    sites); the optional ``jax.profiler.TraceAnnotation`` passthrough and the
    device taps import JAX lazily, so numpy-only processes stay JAX-free.

Spans are thread- and contextvar-safe: the open-span stack lives in a
``contextvars.ContextVar``, so concurrent threads (or async tasks) nest
correctly without sharing parents.  Export formats: JSONL (one record per
line; see :mod:`repro.obs.export`) and Chrome-trace JSON loadable in Perfetto
(``chrome://tracing``), with counters attached as metadata.
"""

from __future__ import annotations

import contextvars
import functools
import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field

__all__ = [
    "Span",
    "Telemetry",
    "NullTelemetry",
    "GLOBAL",
    "NULL",
    "as_telemetry",
    "current",
    "use",
    "note_trace",
    "record_pad_waste",
]

# open-span stack (tuple of Span) per thread/task; shared mutable state stays
# on the Telemetry object itself, guarded by its lock
_SPAN_STACK: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_obs_span_stack", default=()
)

_MAX_SPANS = 100_000          # ring buffer: long processes never grow unbounded
_MAX_HIST = 100_000
_MAX_SERIES = 1_000_000


@dataclass
class Span:
    """One finished (or open) wall-clock interval."""

    name: str
    t0: float                          # perf_counter seconds (monotonic)
    t1: float | None = None
    span_id: int = 0
    parent_id: int | None = None
    tid: int = 0
    attrs: dict = field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0

    def to_record(self) -> dict:
        return {
            "type": "span",
            "name": self.name,
            "t0": self.t0,
            "t1": self.t1,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "tid": self.tid,
            "attrs": dict(self.attrs),
        }


class _SpanCM:
    """Context manager entering/exiting one span on one telemetry object."""

    __slots__ = ("_tel", "_span", "_token", "_annot")

    def __init__(self, tel: "Telemetry", span: Span):
        self._tel = tel
        self._span = span
        self._token = None
        self._annot = None

    def __enter__(self) -> Span:
        stack = _SPAN_STACK.get()
        if stack:
            self._span.parent_id = stack[-1].span_id
        self._token = _SPAN_STACK.set(stack + (self._span,))
        self._span.t0 = time.perf_counter()
        if self._tel.annotate:
            self._annot = _trace_annotation(self._span.name)
            if self._annot is not None:
                self._annot.__enter__()
        return self._span

    def __exit__(self, *exc) -> None:
        self._span.t1 = time.perf_counter()
        if self._annot is not None:
            self._annot.__exit__(*exc)
        _SPAN_STACK.reset(self._token)
        self._tel._finish_span(self._span)


def _trace_annotation(name: str):
    """A ``jax.profiler.TraceAnnotation`` when JAX is importable, else None --
    spans then line up with XLA activity in a jax.profiler trace."""
    try:
        import jax.profiler

        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return None


class Telemetry:
    """Span + metric sink.  Thread-safe; cheap enough to leave on.

    ``parent`` chains counter/gauge/histogram updates upward (child sinks
    created per run still feed process-wide totals); spans and device-tap
    series stay local to the object that recorded them.  ``device_taps``
    opts compiled programs into on-device metric emission (extra per-step
    work inside e.g. the NSGA-II ``fori_loop``), so it is False unless the
    telemetry was explicitly requested with ``"on"``.
    """

    enabled = True

    def __init__(
        self,
        name: str = "telemetry",
        parent: "Telemetry | None" = None,
        device_taps: bool = False,
        annotate: bool = False,
    ) -> None:
        self.name = name
        self.parent = parent
        self.device_taps = bool(device_taps)
        self.annotate = bool(annotate)
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self.spans: deque = deque(maxlen=_MAX_SPANS)
        self.counters: dict[str, int] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, deque] = {}
        self.series: dict[str, list] = {}

    # -- spans ----------------------------------------------------------------

    def span(self, name: str, **attrs) -> _SpanCM:
        """Context manager: ``with tel.span("dse.ga", pop=64) as s: ...``"""
        sp = Span(
            name=name, t0=0.0, span_id=next(self._ids),
            tid=threading.get_ident(), attrs=attrs,
        )
        return _SpanCM(self, sp)

    def wrap(self, name: str | None = None, **attrs):
        """Decorator twin of :meth:`span`."""

        def deco(fn):
            label = name or fn.__qualname__

            @functools.wraps(fn)
            def inner(*args, **kwargs):
                with self.span(label, **attrs):
                    return fn(*args, **kwargs)

            return inner

        return deco

    def _finish_span(self, span: Span) -> None:
        with self._lock:
            self.spans.append(span)

    # -- metrics --------------------------------------------------------------

    def count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n
        if self.parent is not None:
            self.parent.count(name, n)

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = float(value)
        if self.parent is not None:
            self.parent.gauge(name, value)

    def observe(self, name: str, value: float) -> None:
        """Histogram sample (stored raw; percentiles computed on demand)."""
        with self._lock:
            self.histograms.setdefault(name, deque(maxlen=_MAX_HIST)).append(
                float(value)
            )
        if self.parent is not None:
            self.parent.observe(name, value)

    def set_counter(self, name: str, value: int) -> None:
        """Force a counter value (back-compat STATS writes; not propagated)."""
        with self._lock:
            self.counters[name] = int(value)

    def emit(self, name: str, record: dict) -> None:
        """Append one record to a named series (device taps land here)."""
        with self._lock:
            s = self.series.setdefault(name, [])
            if len(s) < _MAX_SERIES:
                s.append(record)

    # -- device taps (JAX imported lazily) ------------------------------------

    def device_tap(self, name: str, fields: tuple):
        """An emit function usable inside jitted code; see ``obs.device``."""
        from .device import make_tap

        return make_tap(self, name, fields)

    def device_batched_tap(self, name: str, fields: tuple):
        """Chunk-flushing tap ``tap(rows, valid)``; see ``obs.device``."""
        from .device import make_batched_tap

        return make_batched_tap(self, name, fields)

    # -- queries / export -----------------------------------------------------

    def counter(self, name: str) -> int:
        return self.counters.get(name, 0)

    def histogram_summary(self, name: str) -> dict:
        vals = sorted(self.histograms.get(name, ()))
        if not vals:
            return {"count": 0}
        n = len(vals)
        pick = lambda q: vals[min(n - 1, int(q * n))]
        return {
            "count": n,
            "mean": sum(vals) / n,
            "min": vals[0],
            "p50": pick(0.50),
            "p90": pick(0.90),
            "p99": pick(0.99),
            "max": vals[-1],
        }

    def summary(self) -> dict:
        with self._lock:
            return {
                "name": self.name,
                "spans": len(self.spans),
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {
                    k: self.histogram_summary(k) for k in self.histograms
                },
                "series": {k: len(v) for k, v in self.series.items()},
            }

    def clear(self) -> None:
        with self._lock:
            self.spans.clear()
            self.counters.clear()
            self.gauges.clear()
            self.histograms.clear()
            self.series.clear()

    def to_jsonl(self, path: str) -> None:
        from .export import write_jsonl

        write_jsonl(self, path)

    def to_chrome_trace(self, path: str) -> None:
        from .export import write_chrome_trace

        write_chrome_trace(self, path)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        s = self.summary()
        return (f"Telemetry({self.name!r}, spans={s['spans']}, "
                f"counters={len(s['counters'])}, series={s['series']})")


class _NullSpanCM:
    """Shared, reusable no-op span context manager (zero allocation per use)."""

    __slots__ = ()

    def __enter__(self):
        return _NULL_SPAN

    def __exit__(self, *exc):
        return None


_NULL_SPAN = Span(name="<null>", t0=0.0, t1=0.0)
_NULL_CM = _NullSpanCM()


class NullTelemetry(Telemetry):
    """A true no-op sink: ``telemetry="off"``.

    Every method is constant-time and allocation-free; compiled programs
    built against it contain no tap callbacks at all, so the disabled path
    is within noise of a build with no telemetry calls anywhere (<1%
    overhead -- guarded by ``tests/test_obs.py``).
    """

    enabled = False

    def __init__(self) -> None:
        super().__init__(name="null", parent=None, device_taps=False)

    def span(self, name: str, **attrs):
        return _NULL_CM

    def wrap(self, name: str | None = None, **attrs):
        return lambda fn: fn

    def count(self, name: str, n: int = 1) -> None:
        pass

    def gauge(self, name: str, value: float) -> None:
        pass

    def observe(self, name: str, value: float) -> None:
        pass

    def set_counter(self, name: str, value: int) -> None:
        pass

    def emit(self, name: str, record: dict) -> None:
        pass

    def device_tap(self, name: str, fields: tuple):
        from .device import null_tap

        return null_tap

    def device_batched_tap(self, name: str, fields: tuple):
        from .device import null_tap

        return null_tap


#: process-wide aggregate: code without an ExecutionContext reports here, and
#: child telemetries propagate counters here (``tuning.STATS`` reads these)
GLOBAL = Telemetry(name="global")

#: the disabled sink (``telemetry="off"``); a singleton so identity checks work
NULL = NullTelemetry()

_CURRENT: contextvars.ContextVar[Telemetry | None] = contextvars.ContextVar(
    "repro_obs_current", default=None
)


def current() -> Telemetry:
    """The active telemetry: the innermost :func:`use`, else :data:`GLOBAL`."""
    tel = _CURRENT.get()
    return GLOBAL if tel is None else tel


class use:
    """``with use(tel): ...`` makes ``tel`` the current telemetry for code
    that has no ExecutionContext to read it from (jit trace bodies, library
    internals).  Re-entrant and contextvar-scoped."""

    def __init__(self, tel: Telemetry):
        self._tel = tel
        self._token = None

    def __enter__(self) -> Telemetry:
        self._token = _CURRENT.set(self._tel)
        return self._tel

    def __exit__(self, *exc) -> None:
        _CURRENT.reset(self._token)


def as_telemetry(value, default: Telemetry | None = None) -> Telemetry:
    """Normalize the ``ExecutionContext(telemetry=...)`` knob.

    ``None`` -> ``default`` (or :data:`GLOBAL`); ``"on"`` -> a fresh sink with
    device taps enabled, counters chained to :data:`GLOBAL`; ``"off"`` ->
    :data:`NULL`; a :class:`Telemetry` instance passes through unchanged.
    """
    if value is None:
        return GLOBAL if default is None else default
    if isinstance(value, Telemetry):
        return value
    if value == "on":
        return Telemetry(name="run", parent=GLOBAL, device_taps=True)
    if value == "off":
        return NULL
    raise ValueError(
        f"telemetry must be None, 'on', 'off' or a Telemetry, got {value!r}"
    )


def of(ctx) -> Telemetry:
    """The telemetry carried by an ExecutionContext (or the current sink).

    Accepts None and legacy-string backends so shim call sites can forward
    whatever they were given.
    """
    tel = getattr(ctx, "telemetry", None)
    return current() if tel is None or isinstance(tel, str) else tel


def note_trace(name: str) -> None:
    """Count one (re)trace of a jitted function.

    Call this inside the *python body* of a function handed to ``jax.jit``:
    the body only executes when XLA (re)traces, so the counter
    ``jit.retrace.<name>`` is exactly the retrace count -- a cheap cached-
    callable health check (a hot counter here means some argument keeps
    changing shape/dtype and the jit cache never warms).
    """
    current().count(f"jit.retrace.{name}")


def record_pad_waste(kernel: str, logical: tuple, padded: tuple) -> None:
    """Pad-to-block waste fraction of one kernel launch (trace-time).

    ``1 - prod(logical)/prod(padded)``: the fraction of the padded iteration
    space that computes zeros.  Recorded as a gauge (last launch) and a
    histogram (distribution over launches) on the current telemetry.
    """
    num = 1
    den = 1
    for lo, pa in zip(logical, padded):
        num *= int(lo)
        den *= int(pa)
    waste = 0.0 if den == 0 else 1.0 - num / den
    tel = current()
    tel.gauge(f"{kernel}.pad_waste", waste)
    tel.observe(f"{kernel}.pad_waste", waste)
