"""Bench regression sentinel: history store + noise-aware PASS/REGRESSED gate.

PR 7 made ``benchmarks/run.py`` write machine-readable ``BENCH_<date>.json``
reports, but nothing read them: the files were gitignored and discarded, so a
silent 2x regression in any engine would ship unnoticed.  This module is the
analysis half:

  * a **history store** -- every bench run is appended (timestamped, never
    overwritten) under ``experiments/bench_history/`` so the perf trajectory
    of a machine survives across runs (still gitignored; only *baselines*
    under ``benchmarks/baselines/`` are committed),
  * a **regression detector** -- :func:`compare` matches a candidate report
    against a committed baseline suite by suite and issues one verdict per
    suite, gating BOTH wall-clock and quality metrics:

      - wall-clock uses **noise-aware bands**: a suite only counts as
        regressed/improved when the median moves by more than
        ``max(wall_rel * baseline_median, iqr_mult * max(IQRs))`` -- raw
        deltas on shared CI runners are meaningless, the IQR of the repeated
        trials (``benchmarks/run.py --repeats``) is the noise floor,
      - quality metrics (DSE/app hypervolume, serving teacher-forced top-1,
        free-run match) are parsed out of the rows' ``derived`` strings via
        :data:`QUALITY_GATES` and compared with relative tolerances; at fixed
        seed and quick budgets these are deterministic, so drift means the
        *behavior* changed -- BEHAV drift gates the same way perf does.

  * a **CLI** consumed by the CI ``perf-sentinel`` job::

        python -m repro.obs.regress --baseline benchmarks/baselines/cpu-smoke.json \\
            [--candidate PATH|latest] [--out verdict.json] [--wall-warn-only]

    Exit status is non-zero iff the overall verdict is REGRESSED.  With
    ``--wall-warn-only`` wall-clock regressions are reported but demoted to
    warnings (shared runners); quality regressions always hard-fail.

Stdlib-only (like the rest of ``repro.obs``): report JSONs in, verdict JSON
out, no JAX anywhere.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
import time

__all__ = [
    "HISTORY_DIR",
    "QUALITY_GATES",
    "append_history",
    "latest_report",
    "load_report",
    "parse_metrics",
    "wall_stats",
    "compare",
    "main",
]

#: where every bench run lands (gitignored; env-overridable)
HISTORY_DIR = os.environ.get(
    "REPRO_BENCH_HISTORY", os.path.join("experiments", "bench_history")
)

# verdict strings, worst first (suite verdict = worst of its checks)
_ORDER = ("REGRESSED", "IMPROVED", "NEW", "SKIPPED", "PASS")

#: quality gates: (row-name regex, metric key in the derived string,
#: direction, relative tolerance).  ``higher`` means larger is better.
QUALITY_GATES: tuple = (
    # DSE hypervolume (paper Figs. 12/13): PPF = estimated, VPF = validated
    (r"^dse\.fig12_.*_(ga|map|map\+ga)$", "hv_vpf", "higher", 0.02),
    (r"^dse\.fig12_.*_(ga|map|map\+ga)$", "hv_ppf", "higher", 0.02),
    # application-level DSE fronts (Figs. 16-19)
    (r"^apps\.fig16_.*", "hv_vpf", "higher", 0.02),
    # serving: teacher-forced top-1 agreement and free-run token match on
    # real generations (bench_serving); top1 is the headline BEHAV gate
    (r"^serving\.axo_", "top1", "higher", 0.05),
    (r"^serving\.axo_", "match", "higher", 0.10),
    # DSE service (bench_service): deterministic at fixed seed -- the cold
    # sweep and its replay must reproduce the same validated hypervolume
    (r"^service\.(cold_sweep|warm_replay)$", "hv_vpf", "higher", 0.02),
)

_METRIC_RE = re.compile(r"([A-Za-z_][\w]*)=([-+]?[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?)")


def parse_metrics(derived) -> dict[str, float]:
    """Numeric ``key=value`` tokens of a row's ``derived`` string.

    ``"hv_ppf=0.123 hv_vpf=4.5e-2 evals=1000"`` -> three floats; non-numeric
    values and bare numbers (``"12.3 tok/s"``) are ignored.
    """
    if not isinstance(derived, str):
        return {}
    return {k: float(v) for k, v in _METRIC_RE.findall(derived)}


def wall_stats(walls) -> dict:
    """min / median / IQR of repeated suite wall-times (``run.py --repeats``)."""
    xs = sorted(float(w) for w in walls)
    n = len(xs)
    if not n:
        return {"wall_s": 0.0, "wall_s_min": 0.0, "wall_s_median": 0.0,
                "wall_s_iqr": 0.0, "repeats": 0}

    def q(f: float) -> float:  # linear-interpolated quantile
        pos = f * (n - 1)
        lo = int(pos)
        hi = min(lo + 1, n - 1)
        return xs[lo] + (pos - lo) * (xs[hi] - xs[lo])

    med = q(0.5)
    return {
        "wall_s": round(med, 4),
        "wall_s_min": round(xs[0], 4),
        "wall_s_median": round(med, 4),
        "wall_s_iqr": round(q(0.75) - q(0.25), 4),
        "repeats": n,
    }


# ---------------------------------------------------------------------------
# History store
# ---------------------------------------------------------------------------


def append_history(report: dict, history_dir: str | None = None) -> str:
    """Append one bench report to the history store (never overwrites).

    File names carry a UTC timestamp down to seconds plus the pid; if a
    same-second same-pid file already exists a zero-padded sequence suffix
    is added (``_001``, sorting after the bare name), so appends never
    collide and lexicographic order stays chronological.
    """
    d = history_dir or HISTORY_DIR
    os.makedirs(d, exist_ok=True)
    stamp = time.strftime("%Y-%m-%dT%H%M%SZ", time.gmtime())
    base = os.path.join(d, f"BENCH_{stamp}_{os.getpid()}")
    path = base + ".json"
    seq = 0
    while os.path.exists(path):
        seq += 1
        path = f"{base}_{seq:03d}.json"
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def latest_report(history_dir: str | None = None) -> str | None:
    """Path of the newest report in the history store (lexicographic ==
    chronological with the timestamped names), or None when empty."""
    d = history_dir or HISTORY_DIR
    paths = sorted(glob.glob(os.path.join(d, "BENCH_*.json")))
    return paths[-1] if paths else None


def load_report(path: str) -> dict:
    with open(path) as f:
        rep = json.load(f)
    if "suites" not in rep:
        raise ValueError(f"{path}: not a bench report (no 'suites' key)")
    return rep


# ---------------------------------------------------------------------------
# The detector
# ---------------------------------------------------------------------------


def _suite_walls(entry: dict) -> tuple[float, float]:
    """(median, iqr) of a suite entry; pre-repeats reports fall back to the
    single-shot ``wall_s`` with zero IQR."""
    med = float(entry.get("wall_s_median", entry.get("wall_s", 0.0)))
    return med, float(entry.get("wall_s_iqr", 0.0))


def _row_metrics(entry: dict) -> dict[str, dict[str, float]]:
    """{row name: {metric: value}} for every gated quality metric of a suite."""
    out: dict[str, dict[str, float]] = {}
    for r in entry.get("rows") or ():
        name = r.get("name", "")
        vals = parse_metrics(r.get("derived"))
        if not vals:
            continue
        for pat, key, _direction, _tol in QUALITY_GATES:
            if key in vals and re.search(pat, name):
                out.setdefault(name, {})[key] = vals[key]
    return out


def _gate_for(name: str, key: str):
    for pat, k, direction, tol in QUALITY_GATES:
        if k == key and re.search(pat, name):
            return direction, tol
    return None


def _compare_wall(base: dict, cand: dict, wall_rel: float, iqr_mult: float) -> dict:
    b_med, b_iqr = _suite_walls(base)
    c_med, c_iqr = _suite_walls(cand)
    band = max(wall_rel * b_med, iqr_mult * max(b_iqr, c_iqr))
    delta = c_med - b_med
    if delta > band:
        status = "REGRESSED"
    elif -delta > band:
        status = "IMPROVED"
    else:
        status = "PASS"
    return {
        "status": status,
        "baseline_s": b_med,
        "candidate_s": c_med,
        "band_s": round(band, 4),
        "delta_rel": round(delta / b_med, 4) if b_med > 0 else 0.0,
    }


def _compare_quality(base: dict, cand: dict) -> list[dict]:
    b_rows = _row_metrics(base)
    c_rows = _row_metrics(cand)
    checks: list[dict] = []
    for name in sorted(set(b_rows) & set(c_rows)):
        for key in sorted(set(b_rows[name]) & set(c_rows[name])):
            direction, tol = _gate_for(name, key)
            b, c = b_rows[name][key], c_rows[name][key]
            lo = abs(b) * tol
            worse = (b - c) if direction == "higher" else (c - b)
            if worse > lo:
                status = "REGRESSED"
            elif -worse > lo:
                status = "IMPROVED"
            else:
                status = "PASS"
            checks.append({
                "row": name, "metric": key, "status": status,
                "baseline": b, "candidate": c, "tol_rel": tol,
                "direction": direction,
            })
    return checks


def _worst(statuses) -> str:
    statuses = list(statuses) or ["PASS"]
    return min(statuses, key=_ORDER.index)


def compare(
    baseline: dict,
    candidate: dict,
    *,
    wall_rel: float = 0.25,
    iqr_mult: float = 3.0,
    wall_warn_only: bool = False,
) -> dict:
    """The verdict of ``candidate`` measured against ``baseline``.

    Suites present in both reports get a wall-clock check plus one quality
    check per gated metric; suites only in the candidate are ``NEW`` (not a
    failure -- coverage grew), suites only in the baseline are ``SKIPPED``
    (the candidate was a subset run).  A suite that *failed* in the candidate
    is always ``REGRESSED``.  ``overall`` is ``REGRESSED`` iff any gating
    check regressed -- quality always gates; wall-clock gates unless
    ``wall_warn_only`` (then wall regressions land in ``warnings``).
    """
    b_suites = baseline.get("suites", {})
    c_suites = candidate.get("suites", {})
    suites: dict[str, dict] = {}
    warnings: list[str] = []
    gating_failures: list[str] = []

    for name in sorted(set(b_suites) | set(c_suites)):
        base, cand = b_suites.get(name), c_suites.get(name)
        if base is None:
            suites[name] = {"status": "NEW"}
            continue
        if cand is None:
            suites[name] = {"status": "SKIPPED"}
            continue
        if cand.get("failed"):
            suites[name] = {"status": "REGRESSED", "reason": "suite failed"}
            gating_failures.append(f"{name}: suite failed")
            continue
        if base.get("failed"):
            suites[name] = {"status": "NEW", "reason": "baseline suite failed"}
            continue
        wall = _compare_wall(base, cand, wall_rel, iqr_mult)
        quality = _compare_quality(base, cand)
        q_status = _worst(c["status"] for c in quality)
        statuses = [wall["status"], q_status]
        suites[name] = {
            "status": _worst(statuses),
            "wall": wall,
            "quality": quality,
        }
        for c in quality:
            if c["status"] == "REGRESSED":
                gating_failures.append(
                    f"{name}: {c['row']} {c['metric']} "
                    f"{c['baseline']:.6g} -> {c['candidate']:.6g}"
                )
        if wall["status"] == "REGRESSED":
            msg = (f"{name}: wall {wall['baseline_s']:.3f}s -> "
                   f"{wall['candidate_s']:.3f}s (band {wall['band_s']:.3f}s)")
            if wall_warn_only:
                warnings.append(msg)
            else:
                gating_failures.append(msg)

    return {
        "overall": "REGRESSED" if gating_failures else "PASS",
        "failures": gating_failures,
        "warnings": warnings,
        "suites": suites,
        "thresholds": {
            "wall_rel": wall_rel,
            "iqr_mult": iqr_mult,
            "wall_warn_only": wall_warn_only,
        },
        "baseline": {
            "git_sha": baseline.get("git_sha"),
            "device": baseline.get("device"),
            "timestamp_utc": baseline.get("timestamp_utc"),
        },
        "candidate": {
            "git_sha": candidate.get("git_sha"),
            "device": candidate.get("device"),
            "timestamp_utc": candidate.get("timestamp_utc"),
        },
    }


# ---------------------------------------------------------------------------
# CLI (the CI perf-sentinel entry point)
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="Gate a bench run against a committed baseline.",
    )
    ap.add_argument("--baseline", required=True,
                    help="committed baseline report (benchmarks/baselines/...)")
    ap.add_argument("--candidate", default="latest",
                    help="candidate report path, or 'latest' for the newest "
                         "entry in the bench history store")
    ap.add_argument("--history-dir", default=None,
                    help=f"history store (default {HISTORY_DIR})")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the machine-readable verdict JSON here")
    ap.add_argument("--wall-rel", type=float, default=0.25,
                    help="min relative wall-clock move to count (default 0.25)")
    ap.add_argument("--iqr-mult", type=float, default=3.0,
                    help="noise band = this many IQRs (default 3)")
    ap.add_argument("--wall-warn-only", action="store_true",
                    help="wall-clock regressions warn instead of failing "
                         "(quality metrics still hard-fail)")
    args = ap.parse_args(argv)

    cand_path = args.candidate
    if cand_path == "latest":
        cand_path = latest_report(args.history_dir)
        if cand_path is None:
            print("regress: no candidate report in history "
                  f"({args.history_dir or HISTORY_DIR}); run benchmarks first",
                  file=sys.stderr)
            return 2

    verdict = compare(
        load_report(args.baseline),
        load_report(cand_path),
        wall_rel=args.wall_rel,
        iqr_mult=args.iqr_mult,
        wall_warn_only=args.wall_warn_only,
    )
    verdict["candidate"]["path"] = cand_path
    verdict["baseline"]["path"] = args.baseline

    if args.out:
        with open(args.out, "w") as f:
            json.dump(verdict, f, indent=2, sort_keys=True)
            f.write("\n")

    for name, s in sorted(verdict["suites"].items()):
        line = f"{s['status']:9s} {name}"
        wall = s.get("wall")
        if wall:
            line += (f"  wall {wall['baseline_s']:.3f}s -> "
                     f"{wall['candidate_s']:.3f}s ({wall['delta_rel']:+.1%},"
                     f" band {wall['band_s']:.3f}s)")
        print(line)
        for c in s.get("quality", ()):
            if c["status"] != "PASS":
                print(f"          {c['status']}: {c['row']} {c['metric']} "
                      f"{c['baseline']:.6g} -> {c['candidate']:.6g}")
    for w in verdict["warnings"]:
        print(f"WARNING (non-gating): {w}")
    print(f"overall: {verdict['overall']}")
    if verdict["failures"]:
        for msg in verdict["failures"]:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
