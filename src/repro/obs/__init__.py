"""repro.obs -- unified telemetry for the DSE->serving stack.

Collection: spans + counters/gauges/histograms (:mod:`.telemetry`),
JSONL/Chrome-trace export (:mod:`.export`), on-device io_callback metric
taps (:mod:`.device`).  Analysis + exposure: bench-history regression
sentinel (:mod:`.regress`), compiled-cost profiling against the registry's
analytical formulas (:mod:`.profile`), and Prometheus ``/metrics`` +
``/healthz`` endpoints (:mod:`.prom`).  Stdlib-only at import time; JAX is
touched lazily.
"""

from .telemetry import (
    GLOBAL,
    NULL,
    NullTelemetry,
    Span,
    Telemetry,
    as_telemetry,
    current,
    note_trace,
    of,
    record_pad_waste,
    use,
)
from .export import chrome_trace_dict, read_jsonl, write_chrome_trace, write_jsonl
from .device import flush, make_tap, null_tap

# The analysis/exposure layer resolves lazily (PEP 562): `python -m
# repro.obs.regress` would otherwise import .regress twice (package init +
# runpy __main__), and collection-side users shouldn't pay for it.
_LAZY = {
    "MetricsServer": "prom", "health_payload": "prom",
    "render_prometheus": "prom",
    "ProfileRecord": "profile", "check_estimate": "profile",
    "profile_fn": "profile", "profile_registry": "profile",
    "append_history": "regress", "compare": "regress",
    "latest_report": "regress", "load_report": "regress",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f".{mod}", __name__), name)

__all__ = [
    "GLOBAL",
    "NULL",
    "NullTelemetry",
    "Span",
    "Telemetry",
    "as_telemetry",
    "current",
    "note_trace",
    "of",
    "record_pad_waste",
    "use",
    "chrome_trace_dict",
    "read_jsonl",
    "write_chrome_trace",
    "write_jsonl",
    "flush",
    "make_tap",
    "null_tap",
    "MetricsServer",
    "health_payload",
    "render_prometheus",
    "ProfileRecord",
    "check_estimate",
    "profile_fn",
    "profile_registry",
    "append_history",
    "compare",
    "latest_report",
    "load_report",
]
