"""repro.obs -- unified telemetry for the DSE->serving stack.

Spans + counters/gauges/histograms (:mod:`.telemetry`), JSONL/Chrome-trace
export (:mod:`.export`), and on-device io_callback metric taps
(:mod:`.device`).  Stdlib-only at import time; JAX is touched lazily.
"""

from .telemetry import (
    GLOBAL,
    NULL,
    NullTelemetry,
    Span,
    Telemetry,
    as_telemetry,
    current,
    note_trace,
    of,
    record_pad_waste,
    use,
)
from .export import chrome_trace_dict, read_jsonl, write_chrome_trace, write_jsonl
from .device import flush, make_tap, null_tap

__all__ = [
    "GLOBAL",
    "NULL",
    "NullTelemetry",
    "Span",
    "Telemetry",
    "as_telemetry",
    "current",
    "note_trace",
    "of",
    "record_pad_waste",
    "use",
    "chrome_trace_dict",
    "read_jsonl",
    "write_chrome_trace",
    "write_jsonl",
    "flush",
    "make_tap",
    "null_tap",
]
