"""Telemetry export: JSONL records and Chrome-trace JSON (Perfetto-loadable).

Chrome trace format reference: the "Trace Event Format" spec -- complete
events (``ph="X"``) carry microsecond ``ts``/``dur``; counters are emitted as
``ph="C"`` samples so Perfetto draws them as tracks.  Load the file at
https://ui.perfetto.dev or chrome://tracing.
"""

from __future__ import annotations

import json

__all__ = ["write_jsonl", "read_jsonl", "write_chrome_trace", "chrome_trace_dict"]


def _records(tel) -> list[dict]:
    with tel._lock:
        recs = [s.to_record() for s in tel.spans]
        recs += [
            {"type": "counter", "name": k, "value": v}
            for k, v in sorted(tel.counters.items())
        ]
        recs += [
            {"type": "gauge", "name": k, "value": v}
            for k, v in sorted(tel.gauges.items())
        ]
        recs += [
            {"type": "histogram", "name": k, **tel.histogram_summary(k)}
            for k in sorted(tel.histograms)
        ]
        recs += [
            {"type": "series", "name": k, "records": list(v)}
            for k, v in sorted(tel.series.items())
        ]
    return recs


def write_jsonl(tel, path: str) -> None:
    """One JSON record per line: spans first, then counters/gauges/
    histogram summaries/series.  Round-trips through :func:`read_jsonl`."""
    with open(path, "w") as f:
        for rec in _records(tel):
            f.write(json.dumps(rec) + "\n")


def read_jsonl(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def chrome_trace_dict(tel) -> dict:
    """The Chrome-trace object for one telemetry sink.

    Span t0/t1 are perf_counter seconds; the earliest span anchors ts=0 so
    traces are readable regardless of process uptime.  Open spans (t1 None)
    are skipped.  Device-tap series with a numeric field become counter
    tracks sampled along the parent span timeline when they carry their own
    host-arrival timestamps; otherwise they ride in ``otherData``.
    """
    with tel._lock:
        spans = [s for s in tel.spans if s.t1 is not None]
        counters = dict(tel.counters)
        gauges = dict(tel.gauges)
        series = {k: list(v) for k, v in tel.series.items()}
    epoch = min((s.t0 for s in spans), default=0.0)
    events = []
    for s in spans:
        events.append({
            "name": s.name,
            "ph": "X",
            "ts": (s.t0 - epoch) * 1e6,
            "dur": (s.t1 - s.t0) * 1e6,
            "pid": 0,
            "tid": s.tid % 2**31,
            "args": {k: _jsonable(v) for k, v in s.attrs.items()},
        })
    # series records that carry a host timestamp become counter tracks
    for name, recs in series.items():
        for rec in recs:
            ts = rec.get("_host_t")
            if ts is None:
                continue
            vals = {k: _jsonable(v) for k, v in rec.items()
                    if k != "_host_t" and isinstance(_jsonable(v), (int, float))}
            if vals:
                events.append({"name": name, "ph": "C", "ts": (ts - epoch) * 1e6,
                               "pid": 0, "args": vals})
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "telemetry": tel.name,
            "counters": {k: _jsonable(v) for k, v in sorted(counters.items())},
            "gauges": {k: _jsonable(v) for k, v in sorted(gauges.items())},
            "series": {k: len(v) for k, v in sorted(series.items())},
        },
    }


def write_chrome_trace(tel, path: str) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace_dict(tel), f)


def _jsonable(v):
    """Numpy scalars/arrays -> python scalars/lists; everything else as-is
    (json.dumps rejects leftovers loudly, which is what we want)."""
    item = getattr(v, "item", None)
    if item is not None and getattr(v, "ndim", None) == 0:
        return v.item()
    tolist = getattr(v, "tolist", None)
    if tolist is not None:
        return v.tolist()
    return v
