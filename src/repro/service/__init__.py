"""repro.service -- persistent DSE service layer.

The operator library (:mod:`.store`) is a content-addressed, on-disk store of
characterized BEHAV/PPA rows and validated fronts: ``hash(config, spec, app,
const_sf)`` keys schema-versioned JSONL shards under ``experiments/library/``
(env-overridable via ``REPRO_OPERATOR_LIBRARY``).  Known configs skip the
fastchar dispatch entirely, repeated requests return their cached front, and
new sweeps warm-start the GA from the library's nearest cached fronts.

The job queue (:mod:`.queue`) coalesces compatible pending (spec, app,
const_sf, seed) DSE requests into single ``run_dse_sweep`` lane dispatches,
amortizing compile + characterization cost across requests.  It backs the
``POST /dse`` endpoint on ``repro.launch.serve``.
"""

from .store import (
    SCHEMA_VERSION,
    OperatorStore,
    config_key,
    library_dir,
    request_key,
    store_status,
)
from .queue import DSEJobQueue, DSERequest, default_runner

__all__ = [
    "SCHEMA_VERSION",
    "OperatorStore",
    "config_key",
    "library_dir",
    "request_key",
    "store_status",
    "DSEJobQueue",
    "DSERequest",
    "default_runner",
]
