"""Async batched DSE job queue: coalesce requests into sweep lane dispatches.

Clients submit :class:`DSERequest` jobs ((operator spec, app, const_sf, seed)
tuples).  A single worker thread drains the pending queue after a short linger
window, groups compatible jobs -- same operator family, app, and method -- and
dispatches each group as ONE ``run_dse_sweep`` call over the union
``const_sf x seed`` grid, so N compatible requests pay one estimator fit, one
compiled GA program and one characterization batch instead of N.  Lanes the
grid adds beyond what was literally requested are not wasted: their fronts
land in the operator library and serve later traffic.

Telemetry: ``service.jobs`` / ``service.batches`` / ``service.job_errors``
counters, a ``service.queue_depth`` histogram (observed at every submit) and a
``service.batch_lanes`` histogram (lanes per coalesced dispatch).
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time

from .. import obs
from .store import OperatorStore


@dataclasses.dataclass(frozen=True)
class DSERequest:
    """One DSE job: which operator, which app, which constraint, which seed."""

    n_bits: int = 8
    op: str = "mul"
    signed: bool = True
    app: str | None = None
    const_sf: float = 1.0
    seed: int = 0
    method: str = "ga"

    @property
    def group(self) -> tuple:
        """Coalescing key: requests sharing it ride one sweep dispatch."""
        return (self.n_bits, self.op, self.signed, self.app, self.method)

    def spec(self):
        from ..core.operator_model import spec_for

        return spec_for(self.n_bits, op=self.op, signed=self.signed)

    @classmethod
    def from_dict(cls, d: dict) -> "DSERequest":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(f"unknown request fields: {sorted(unknown)}")
        req = cls(**d)
        if req.method not in ("ga", "map+ga"):
            raise ValueError(f"unsupported method {req.method!r}")
        return req


def default_runner(settings=None, store: OperatorStore | None = None,
                   n_train: int = 200):
    """Build the queue's sweep dispatcher around :func:`run_dse_sweep`.

    Training datasets are built once per operator spec and reused across
    batches; ``store`` (shared with the endpoint) gives every dispatch the
    library's request cache, row dedup and warm starts.
    """
    from ..core.dataset import build_training_dataset
    from ..core.dse import DSESettings, run_dse_sweep

    settings = settings or DSESettings(pop_size=16, n_gen=8, backend="jax")
    datasets: dict[str, object] = {}
    lock = threading.Lock()

    def runner(spec, app, method, const_sf_grid, seeds):
        with lock:
            ds = datasets.get(spec.tag)
            if ds is None:
                ds = datasets[spec.tag] = build_training_dataset(
                    spec, n_random=n_train, seed=0,
                    backend=settings.context,
                )
        app_obj = None
        if app is not None:
            from ..apps import APPLICATIONS

            app_obj = APPLICATIONS[app]()
        return run_dse_sweep(
            spec, ds, method, settings=settings, seeds=tuple(seeds),
            const_sf_grid=tuple(const_sf_grid), app=app_obj, store=store,
        )

    return runner


def _payload(req: DSERequest, res) -> dict:
    return {
        "status": "done",
        "request": dataclasses.asdict(req),
        "hv_vpf": float(res.hv_vpf),
        "hv_ppf": float(res.hv_ppf),
        "n_evals": int(res.n_evals),
        "wall_s": float(res.wall_s),
        "front": [[float(b), float(p)] for b, p in res.vpf_objs],
        "configs": ["".join(str(int(b)) for b in c) for c in res.vpf_configs],
    }


class DSEJobQueue:
    """Background worker coalescing pending DSE jobs into sweep dispatches.

    ``runner(spec, app, method, const_sf_grid, seeds) -> list[DSEResult]``
    must return lanes in sweep order (``for const_sf: for seed``) -- exactly
    :func:`repro.core.dse.run_dse_sweep`'s contract.
    """

    def __init__(self, runner, tel=None, linger_s: float = 0.05,
                 max_batch: int = 64):
        self._runner = runner
        self._tel = tel
        self.linger_s = linger_s
        self.max_batch = max_batch
        self._lock = threading.Condition()
        self._pending: list[tuple[str, DSERequest]] = []
        self._results: dict[str, dict] = {}
        self._events: dict[str, threading.Event] = {}
        self._ids = itertools.count(1)
        self._closed = False
        self._worker = threading.Thread(
            target=self._drain_loop, name="dse-queue", daemon=True
        )
        self._worker.start()

    @property
    def tel(self):
        return self._tel if self._tel is not None else obs.current()

    # -- client API -----------------------------------------------------------

    def submit(self, req: DSERequest) -> str:
        """Enqueue one job; returns its id (poll with :meth:`result`)."""
        if self._closed:
            raise RuntimeError("queue is closed")
        with self._lock:
            job_id = f"job-{next(self._ids)}"
            self._events[job_id] = threading.Event()
            self._pending.append((job_id, req))
            tel = self.tel
            tel.count("service.jobs")
            tel.observe("service.queue_depth", float(len(self._pending)))
            self._lock.notify_all()
        return job_id

    def result(self, job_id: str, timeout: float | None = None) -> dict | None:
        """The job's payload dict, or None while still pending/unknown."""
        ev = self._events.get(job_id)
        if ev is None:
            return None
        if timeout:
            ev.wait(timeout)
        return self._results.get(job_id)

    def depth(self) -> int:
        with self._lock:
            return len(self._pending)

    def join(self, timeout: float = 60.0) -> bool:
        """Block until every submitted job has a result (True) or timeout."""
        deadline = time.monotonic() + timeout
        for ev in list(self._events.values()):
            if not ev.wait(max(0.0, deadline - time.monotonic())):
                return False
        return True

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._lock.notify_all()
        self._worker.join(timeout=5.0)

    # -- worker ---------------------------------------------------------------

    def _drain_loop(self) -> None:
        while True:
            with self._lock:
                while not self._pending and not self._closed:
                    self._lock.wait()
                if self._closed and not self._pending:
                    return
            # linger: let a burst of compatible submissions pile up so they
            # coalesce into one dispatch instead of racing the worker
            time.sleep(self.linger_s)
            with self._lock:
                batch = self._pending[: self.max_batch]
                del self._pending[: len(batch)]
            if batch:
                self._dispatch(batch)

    def _dispatch(self, batch: list[tuple[str, DSERequest]]) -> None:
        groups: dict[tuple, list[tuple[str, DSERequest]]] = {}
        for job_id, req in batch:
            groups.setdefault(req.group, []).append((job_id, req))
        tel = self.tel
        for jobs in groups.values():
            req0 = jobs[0][1]
            sfs = sorted({j[1].const_sf for j in jobs})
            seeds = sorted({j[1].seed for j in jobs})
            tel.count("service.batches")
            tel.observe("service.batch_lanes", float(len(sfs) * len(seeds)))
            try:
                results = self._runner(
                    req0.spec(), req0.app, req0.method, sfs, seeds
                )
            except Exception as exc:   # a bad request must not kill the worker
                tel.count("service.job_errors", len(jobs))
                err = {"status": "error",
                       "error": f"{type(exc).__name__}: {exc}"}
                for job_id, req in jobs:
                    self._results[job_id] = dict(
                        err, request=dataclasses.asdict(req)
                    )
                    self._events[job_id].set()
                continue
            for job_id, req in jobs:
                lane = sfs.index(req.const_sf) * len(seeds) + seeds.index(
                    req.seed
                )
                self._results[job_id] = _payload(req, results[lane])
                self._events[job_id].set()
