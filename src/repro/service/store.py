"""Content-addressed operator library: characterized rows + validated fronts.

Every record is keyed by a sha256 over a canonical (sorted-key, separator-
stable) JSON payload of ``(schema, spec.tag, config bits, app, const_sf)`` --
stable across processes, Python hash randomization, and dict-key order.  Two
append-only JSONL shards live under :func:`library_dir` (default
``experiments/library/``, overridable via ``REPRO_OPERATOR_LIBRARY``, the same
idiom as ``REPRO_TUNING_CACHE``):

- ``rows.jsonl``   -- one characterized config per line (true BEHAV/PPA), the
  dedup cache that lets ``run_dse``'s validation skip the fastchar dispatch
  for already-known configs.
- ``fronts.jsonl`` -- one validated front per line (VPF configs/objs + hv,
  plus the estimated PPF), doubling as the full-request result cache (records
  carry the request digest) and the warm-start corpus
  (:meth:`OperatorStore.warm_pool`).

Corrupt or truncated lines never crash a reader: they are skipped with a
warning and a ``service.store_corrupt`` count, mirroring the tuning-cache
recovery story.  Writers append whole lines with a flush per record; a torn
final line (killed process) is exactly the case the reader tolerates.
"""

from __future__ import annotations

import hashlib
import json
import os
import warnings

import numpy as np

from .. import obs

SCHEMA_VERSION = 1
ENV_VAR = "REPRO_OPERATOR_LIBRARY"

_ROWS_SHARD = "rows.jsonl"
_FRONTS_SHARD = "fronts.jsonl"


def library_dir() -> str:
    """On-disk library root (``REPRO_OPERATOR_LIBRARY`` overrides)."""
    return os.environ.get(ENV_VAR, os.path.join("experiments", "library"))


def _digest(payload: dict) -> str:
    """sha256 over canonical JSON: sorted keys, fixed separators, ASCII."""
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode("ascii")).hexdigest()


def _bits(config) -> str:
    return "".join("1" if int(b) else "0" for b in np.asarray(config).ravel())


def _unbits(s: str) -> np.ndarray:
    return np.frombuffer(s.encode("ascii"), np.uint8) - ord("0")


def config_key(spec, config, app: str | None = None,
               const_sf: float | None = None) -> str:
    """Content address of one characterized config.

    ``app=None`` is operator-level characterization; ``const_sf`` is part of
    the address only where the stored value depends on it (fronts) -- row
    lookups pass ``None`` because BEHAV/PPA of a config does not.
    """
    return _digest({
        "schema": SCHEMA_VERSION,
        "kind": "row",
        "spec": spec.tag,
        "config": _bits(config),
        "app": app,
        "const_sf": None if const_sf is None else round(float(const_sf), 9),
    })


def request_key(spec, app: str | None, const_sf: float, seed: int,
                method: str, settings=None, train_fingerprint: str | None = None,
                ) -> str:
    """Content address of one full DSE request (the result-cache key).

    Includes everything that changes the deterministic output: the operator,
    app, constraint factor, seed, method, the search budget + objective keys
    from ``settings``, and a fingerprint of the training dataset (estimators,
    reference point and constraint bounds all derive from it).
    """
    payload = {
        "schema": SCHEMA_VERSION,
        "kind": "request",
        "spec": spec.tag,
        "app": app,
        "const_sf": round(float(const_sf), 9),
        "seed": int(seed),
        "method": method,
        "train": train_fingerprint,
    }
    if settings is not None:
        payload["budget"] = {
            "pop_size": settings.pop_size,
            "n_gen": settings.n_gen,
            "behav_key": settings.behav_key,
            "ppa_key": settings.ppa_key,
            "n_estimator_quad": settings.n_estimator_quad,
        }
    return _digest(payload)


def train_fingerprint(train_ds) -> str:
    """Stable digest of a training dataset (configs + metric arrays)."""
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(train_ds.configs).tobytes())
    for name in sorted(train_ds.metrics):
        h.update(name.encode("ascii"))
        h.update(np.ascontiguousarray(train_ds.metrics[name]).tobytes())
    return h.hexdigest()


class OperatorStore:
    """The persistent, content-addressed operator library.

    Lazily loads both shards on first access; tolerates missing files, corrupt
    lines and unknown schema versions (warn + ``service.store_corrupt``, never
    raise).  All mutation goes through :meth:`put_rows` / :meth:`put_front`,
    which append to disk and update the in-memory index in one step.
    """

    def __init__(self, root: str | None = None, tel=None):
        self.root = root or library_dir()
        self._tel = tel
        self._rows: dict[str, dict] | None = None      # key -> record
        self._fronts: list[dict] | None = None
        self._requests: dict[str, dict] = {}           # request digest -> front record

    # -- telemetry ----------------------------------------------------------

    @property
    def tel(self):
        return self._tel if self._tel is not None else obs.current()

    def _gauge_sizes(self) -> None:
        tel = self.tel
        tel.gauge("service.library_size", float(len(self._rows or ())))
        tel.gauge("service.front_count", float(len(self._fronts or ())))

    # -- shard IO ------------------------------------------------------------

    def _path(self, shard: str) -> str:
        return os.path.join(self.root, shard)

    def _read_shard(self, shard: str) -> list[dict]:
        path = self._path(shard)
        try:
            with open(path, "r", encoding="ascii") as fh:
                lines = fh.readlines()
        except FileNotFoundError:
            return []
        except OSError as exc:
            warnings.warn(f"operator library shard {path} unreadable ({exc}); "
                          "treating as empty", stacklevel=3)
            self.tel.count("service.store_corrupt")
            return []
        records: list[dict] = []
        bad = 0
        for line in lines:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
                if not isinstance(rec, dict) or rec.get("schema") != SCHEMA_VERSION:
                    raise ValueError(f"schema {rec.get('schema')!r}"
                                     if isinstance(rec, dict) else "not a record")
                records.append(rec)
            except (ValueError, TypeError):
                bad += 1
        if bad:
            warnings.warn(f"operator library shard {path}: skipped {bad} "
                          "corrupt/unknown-schema line(s)", stacklevel=3)
            self.tel.count("service.store_corrupt", bad)
        return records

    def _append(self, shard: str, records: list[dict]) -> None:
        if not records:
            return
        os.makedirs(self.root, exist_ok=True)
        path = self._path(shard)
        with open(path, "a", encoding="ascii") as fh:
            for rec in records:
                fh.write(json.dumps(rec, sort_keys=True,
                                    separators=(",", ":")) + "\n")
            fh.flush()

    def _load(self) -> None:
        if self._rows is not None:
            return
        self._rows = {r["key"]: r for r in self._read_shard(_ROWS_SHARD)}
        self._fronts = self._read_shard(_FRONTS_SHARD)
        self._requests = {
            r["request"]: r for r in self._fronts if r.get("request")
        }
        self._gauge_sizes()

    # -- characterized rows ---------------------------------------------------

    def lookup_rows(
        self, spec, configs: np.ndarray, app: str | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """(objs (D, 2) float64, hit (D,) bool): cached BEHAV/PPA per config."""
        self._load()
        D = len(configs)
        objs = np.zeros((D, 2), np.float64)
        hit = np.zeros(D, bool)
        for i, cfg in enumerate(configs):
            rec = self._rows.get(config_key(spec, cfg, app))
            if rec is not None:
                objs[i] = (rec["behav"], rec["ppa"])
                hit[i] = True
        tel = self.tel
        n_hit = int(hit.sum())
        if n_hit:
            tel.count("service.store_hit", n_hit)
        if D - n_hit:
            tel.count("service.store_miss", D - n_hit)
        return objs, hit

    def put_rows(self, spec, configs: np.ndarray, objs: np.ndarray,
                 app: str | None = None) -> int:
        """Persist characterized rows; returns how many were new."""
        self._load()
        fresh: list[dict] = []
        for cfg, (b, p) in zip(configs, np.asarray(objs, np.float64)):
            key = config_key(spec, cfg, app)
            if key in self._rows:
                continue
            rec = {
                "schema": SCHEMA_VERSION,
                "key": key,
                "spec": spec.tag,
                "app": app,
                "config": _bits(cfg),
                "behav": float(b),
                "ppa": float(p),
            }
            self._rows[key] = rec
            fresh.append(rec)
        self._append(_ROWS_SHARD, fresh)
        self._gauge_sizes()
        return len(fresh)

    def cached_characterize(self, spec, fn, app: str | None = None):
        """Wrap a ``configs -> (D, 2)`` objective fn with library dedup.

        Known configs are answered from the store (no fastchar dispatch);
        misses go through ``fn`` in one batch and are persisted.  With an
        empty library every config misses and the wrapped fn is an exact
        pass-through -- the bit-identity guarantee for cold starts.
        """

        def wrapped(configs: np.ndarray) -> np.ndarray:
            if len(configs) == 0:
                return fn(configs)
            objs, hit = self.lookup_rows(spec, configs, app)
            if hit.all():
                return objs
            miss = ~hit
            computed = np.asarray(fn(np.asarray(configs)[miss]), np.float64)
            objs[miss] = computed
            self.put_rows(spec, np.asarray(configs)[miss], computed, app)
            return objs

        return wrapped

    # -- validated fronts + request cache -------------------------------------

    def put_front(
        self, spec, app: str | None, const_sf: float, seed: int, method: str,
        vpf_configs: np.ndarray, vpf_objs: np.ndarray, hv_vpf: float,
        ppf_configs: np.ndarray | None = None,
        ppf_objs: np.ndarray | None = None, hv_ppf: float = 0.0,
        n_evals: int = 0, request: str | None = None,
    ) -> dict:
        """Persist one validated front (and optionally its request digest)."""
        self._load()
        rec = {
            "schema": SCHEMA_VERSION,
            "key": _digest({
                "schema": SCHEMA_VERSION, "kind": "front", "spec": spec.tag,
                "app": app, "const_sf": round(float(const_sf), 9),
                "seed": int(seed), "method": method,
                "configs": [_bits(c) for c in vpf_configs],
            }),
            "spec": spec.tag,
            "app": app,
            "const_sf": float(const_sf),
            "seed": int(seed),
            "method": method,
            "configs": [_bits(c) for c in vpf_configs],
            "objs": np.asarray(vpf_objs, np.float64).tolist(),
            "hv": float(hv_vpf),
            "ppf_configs": [_bits(c) for c in ppf_configs]
            if ppf_configs is not None else [],
            "ppf_objs": np.asarray(ppf_objs, np.float64).tolist()
            if ppf_objs is not None else [],
            "hv_ppf": float(hv_ppf),
            "n_evals": int(n_evals),
            "request": request,
        }
        self._fronts.append(rec)
        if request:
            self._requests[request] = rec
        self._append(_FRONTS_SHARD, [rec])
        self._gauge_sizes()
        return rec

    def lookup_result(self, request: str) -> dict | None:
        """Full-request cache: the front record previously stored under this
        request digest, or None."""
        self._load()
        rec = self._requests.get(request)
        tel = self.tel
        tel.count("service.request_hit" if rec is not None
                  else "service.request_miss")
        return rec

    def fronts(self, spec=None, app: str | None = "*") -> list[dict]:
        """Stored front records, optionally filtered by spec tag / app name."""
        self._load()
        out = list(self._fronts)
        if spec is not None:
            out = [r for r in out if r["spec"] == spec.tag]
        if app != "*":
            out = [r for r in out if r["app"] == app]
        return out

    def nearest_fronts(self, spec, app: str | None, const_sf: float,
                       k: int = 3) -> list[dict]:
        """The k cached fronts nearest to (spec, app, const_sf).

        Same spec tag is mandatory; distance is (app mismatch, |const_sf
        delta|) lexicographic, recency breaking ties -- an exact-app front at
        a nearby constraint beats a cross-app front at the exact constraint.
        """
        cand = self.fronts(spec)
        cand = [r for r in cand if r["configs"]]
        cand.sort(key=lambda r: (r["app"] != app,
                                 abs(r["const_sf"] - float(const_sf))))
        return cand[:k]

    def warm_pool(self, spec, app: str | None, const_sf: float,
                  limit: int = 64, k: int = 3) -> np.ndarray | None:
        """Union of the nearest cached fronts' configs: the GA seed pool.

        Returns None when the library holds nothing relevant (the cold-start
        path stays bit-identical).  Deduplicates preserving nearest-first
        order and caps at ``limit`` members.
        """
        seen: set[str] = set()
        rows: list[np.ndarray] = []
        for rec in self.nearest_fronts(spec, app, const_sf, k=k):
            for bits in rec["configs"]:
                if bits in seen or len(rows) >= limit:
                    continue
                seen.add(bits)
                rows.append(_unbits(bits))
        if not rows:
            return None
        return np.stack(rows).astype(np.uint8)

    # -- seeding + status -----------------------------------------------------

    def seed_fixed_library(self, spec, settings=None, app=None) -> int:
        """Characterize the frozen EvoApprox-style corpus into the store.

        Uses :func:`repro.core.dse.fixed_library` (design members independent
        of any DSE problem) and the default operator-level characterization;
        returns how many rows were newly persisted.
        """
        from ..core.dse import DSESettings, _default_characterize, fixed_library

        settings = settings or DSESettings()
        configs = fixed_library(spec)
        app_name = getattr(app, "name", app)
        _, hit = self.lookup_rows(spec, configs, app_name)
        if hit.all():
            return 0
        fn = (app.characterize_fn(spec, ppa_key=settings.ppa_key,
                                  backend=settings.context)
              if app is not None
              else _default_characterize(spec, settings))
        miss = ~hit
        objs = np.asarray(fn(configs[miss]), np.float64)
        return self.put_rows(spec, configs[miss], objs, app_name)

    def stats(self) -> dict:
        self._load()
        return {
            "root": self.root,
            "rows": len(self._rows),
            "fronts": len(self._fronts),
            "requests": len(self._requests),
            "specs": sorted({r["spec"] for r in self._rows.values()}
                            | {r["spec"] for r in self._fronts}),
        }


def store_status(store: OperatorStore | None = None) -> dict:
    """Health snapshot of the operator library (``/healthz`` payload).

    Never raises: a corrupt/unreadable library reads as empty (the same
    recovery the loader applies) and the traffic counters come from the
    process-wide aggregate.
    """
    try:
        store = store or OperatorStore()
        st = store.stats()
    except Exception as exc:  # pragma: no cover - defensive
        return {"ok": False, "error": f"{type(exc).__name__}: {exc}"}
    st.update({
        "ok": True,
        "hits": obs.GLOBAL.counter("service.store_hit"),
        "misses": obs.GLOBAL.counter("service.store_miss"),
        "request_hits": obs.GLOBAL.counter("service.request_hit"),
        "request_misses": obs.GLOBAL.counter("service.request_miss"),
        "corrupt": obs.GLOBAL.counter("service.store_corrupt"),
    })
    return st
