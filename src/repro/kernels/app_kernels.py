"""Pallas batched table-GEMV kernel for the application-BEHAV engine (fastapp).

Application BEHAV turns a batch of approximate-operator product tables into
app-level quality metrics; its hot loop is integer matmul where every multiply
is a table lookup: ``out[d, m, n] = sum_k T_d[a[m, k], b[k, n]]``.  The XLA
path in :mod:`repro.apps.fastapp` gathers a ``(Dc, M, K, N)`` product tensor
per config chunk; this kernel instead keeps one config's *flattened* product
table resident in VMEM across the whole K reduction and never materializes the
product tensor in HBM.

Grid layout (mirroring ``char_kernels.behav_stats_pallas``):

  grid = (D, K // k_tile); step ``(d, k)`` loads
    table block  (1, A*B)      index (d, 0)   -- constant in k: the per-config
                                                 table stays in VMEM across the
                                                 K reduction.
    a block      (M, k_tile)   index (0, k)   -- operand codes, shared over D.
    b block      (k_tile, N)   index (k, 0)
  and accumulates the partial (M, N) integer product into the (1, M, N) output
  block (revision-in-place over the k grid axis, ``@pl.when(k == 0)`` init).

The lookup itself is one flat ``jnp.take``: ``idx = a * B + b`` broadcast to
(M, k_tile, N).  Accumulation is int32: the approximate product magnitude is
bounded by ``fastchar.max_abs_error_bound + 2^{2N-2}`` (< 2^16 for N=8), so
K <= 2^14 reductions stay exactly representable.

Callers must pad K to a multiple of ``k_tile`` with zero codes: code 0 is the
operand value 0 and every config's table maps (0, 0) -> 0, so padding
contributes nothing to the sums (asserted in tests).  Interpret mode (the
CPU default, see ``kernels.ops.on_tpu``) validates the kernel bit-for-bit
against the XLA path.

``k_tile`` comes from the kernel registry (spec ``"fastapp.pallas"``):
``None`` resolves the registry default for the (M, K, N) shape bucket, and a
context with ``tuning != "off"`` hands tuned tiles down through
``fastapp.table_matmul_jax``.  The registry also supplies the
``pl.CostEstimate`` and TPU compiler params -- the D axis is ``parallel``,
the K axis ``arbitrary`` (it accumulates into a revisited output block), and
the VMEM limit is sized to the resident table plus the gather tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import registry
from ..core.operator_model import _chain_eval, spec_for

__all__ = ["table_gemv_pallas", "entry_gemv_pallas"]


def _kernel(tab_ref, a_ref, b_ref, out_ref, *, n_codes: int):
    """One (d, k) step: gather the (M, kt, N) product tile, reduce, accumulate."""
    k = pl.program_id(1)
    idx = a_ref[...][:, :, None] * n_codes + b_ref[...][None, :, :]  # (M, kt, N)
    prod = jnp.take(tab_ref[0], idx.reshape(-1), axis=0).reshape(idx.shape)
    part = prod.sum(axis=1)[None]                                    # (1, M, N)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = part

    @pl.when(k > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("k_tile", "interpret"))
def table_gemv_pallas(
    tables_flat: jnp.ndarray,     # (D, A*B) int32 flattened product tables
    a_codes: jnp.ndarray,         # (M, K) int32 operand-A codes (config-shared)
    b_codes: jnp.ndarray,         # (K, N) int32 operand-B codes
    k_tile: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched table-matmul: (D, M, N) int32, table VMEM-resident over K.

    K must divide by ``k_tile`` (fastapp pads the codes with zeros); ``None``
    resolves the registry default for this shape bucket.
    """
    d, ab = tables_flat.shape
    m, k = a_codes.shape
    k2, n = b_codes.shape
    n_codes = int(round(ab ** 0.5))
    spec = registry.get("fastapp.pallas")
    if k_tile is None:
        bucket = spec.bucket(n_bits=n_codes.bit_length() - 1, m=m, k=k, n=n)
        k_tile = spec.default_tiles(bucket)["k_tile"]
    assert k == k2, (k, k2)
    assert k % k_tile == 0, (k, k_tile)
    assert n_codes * n_codes == ab, ab

    cost = spec.cost_estimate(d=d, m=m, k=k, n=n, a=n_codes)
    params = spec.compiler_params(m=m, k_tile=k_tile, n=n, a=n_codes)
    grid = (d, k // k_tile)
    return pl.pallas_call(
        functools.partial(_kernel, n_codes=n_codes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ab), lambda i, j: (i, 0)),
            pl.BlockSpec((m, k_tile), lambda i, j: (0, j)),
            pl.BlockSpec((k_tile, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, n), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, m, n), jnp.int32),
        cost_estimate=pl.CostEstimate(**cost),
        compiler_params=pltpu.TPUCompilerParams(**params),
        interpret=interpret,
    )(tables_flat, a_codes, b_codes)


# ---------------------------------------------------------------------------
# Table-free variant: synthesize the VMEM tile from the (D, R) config masks
# ---------------------------------------------------------------------------


def _entry_kernel(masks_ref, a_ref, b_ref, out_ref, *, n_bits: int):
    """One (d, k) step of the table-free GEMV.

    Instead of holding this config's (A*B,) product table in VMEM, synthesize
    its per-row ``(4, B)`` planes from the (1, R) masks block by the
    carry-chain model (``R * 4 * W`` chain steps over the B axis) and gather
    per row: ``prod = sum_r small_r[pair_r(a), b] << 2r``.  VMEM residency
    drops from ``A*B`` ints (64 KB at N=8; 67 MB -- impossible -- at N=12) to
    ``R * 4 * B`` (4 KB at N=8, 393 KB at N=12), which is what unlocks
    wide-operand app BEHAV."""
    spec = spec_for(n_bits)
    k = pl.program_id(1)
    b_in = spec.n_inputs
    half = b_in // 2
    w_bits, cpr = spec.width, spec.cols_removable
    modw = (1 << w_bits) - 1

    b_codes = jax.lax.broadcasted_iota(jnp.int32, (1, b_in), 1)
    b_s = jnp.where(b_codes >= half, b_codes - b_in, b_codes)  # (1, B) signed

    a = a_ref[...]                                             # (M, kt)
    b = b_ref[...]                                             # (kt, N)
    part = None
    for r in range(spec.rows):  # static unroll over partial-product rows
        top = r == spec.rows - 1
        mask_r = masks_ref[0, r]                               # scalar
        bx = -b_s if top else b_s
        planes = []
        for p in range(4):
            a0, a1 = (p >> 1) & 1, p & 1
            t1 = (b_s & modw) if a0 else jnp.zeros_like(b_s)
            t2 = ((bx << 1) & modw) if a1 else jnp.zeros_like(b_s)
            planes.append(_chain_eval(t1, t2, mask_r, w_bits, cpr, jnp, jnp.int32))
        small_r = jnp.concatenate(planes, axis=0).reshape(-1)  # (4*B,) flat
        pair = 2 * ((a >> (2 * r)) & 1) + ((a >> (2 * r + 1)) & 1)
        idx = pair[:, :, None] * b_in + b[None, :, :]          # (M, kt, N)
        prod = jnp.take(small_r, idx.reshape(-1), axis=0).reshape(idx.shape)
        term = prod.sum(axis=1) << (2 * r)
        part = term if part is None else part + term
    part = part[None]                                          # (1, M, N)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = part

    @pl.when(k > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("n_bits", "k_tile", "interpret"))
def entry_gemv_pallas(
    masks: jnp.ndarray,           # (D, R) int32 per-row config masks
    a_codes: jnp.ndarray,         # (M, K) int32 operand-A codes (config-shared)
    b_codes: jnp.ndarray,         # (K, N) int32 operand-B codes
    n_bits: int,
    k_tile: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Table-free twin of :func:`table_gemv_pallas`: (D, M, N) int32.

    Bit-identical to the table kernel (the synthesized planes equal the
    gathered tables), with no (D, A*B) table build or HBM staging.  Zero-code
    K padding still contributes nothing: every config maps (0, 0) -> 0.
    Signed multipliers only.
    """
    op_spec = spec_for(n_bits)
    d, rows = masks.shape
    m, k = a_codes.shape
    k2, n = b_codes.shape
    assert rows == op_spec.rows, (rows, op_spec.rows)
    assert k == k2, (k, k2)
    spec = registry.get("fastapp.entry_pallas")
    if k_tile is None:
        bucket = spec.bucket(n_bits=n_bits, m=m, k=k, n=n)
        k_tile = spec.default_tiles(bucket)["k_tile"]
    assert k % k_tile == 0, (k, k_tile)

    cost = spec.cost_estimate(d=d, m=m, k=k, n=n, a=op_spec.n_inputs,
                              rows=rows, width=op_spec.width)
    params = spec.compiler_params(m=m, k_tile=k_tile, n=n, a=op_spec.n_inputs,
                                  rows=rows)
    grid = (d, k // k_tile)
    return pl.pallas_call(
        functools.partial(_entry_kernel, n_bits=n_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, rows), lambda i, j: (i, 0)),
            pl.BlockSpec((m, k_tile), lambda i, j: (0, j)),
            pl.BlockSpec((k_tile, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, n), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, m, n), jnp.int32),
        cost_estimate=pl.CostEstimate(**cost),
        compiler_params=pltpu.TPUCompilerParams(**params),
        interpret=interpret,
    )(masks, a_codes, b_codes)
