"""Pallas reduction kernel for batched BEHAV characterization (fastchar backend).

The AxOMaP bottleneck is turning thousands of LUT configs into error statistics:
the numpy oracle materializes a ``(D, 2^N, 2^N)`` float64 error table per batch
(134 MB per 256-config batch at N=8) and reduces it on the host.  This kernel
computes the same statistics *without ever materializing the error tables in
HBM*: each grid step reconstructs one ``(Db, Ta, B)`` error-table tile in VMEM
from the tiny per-row config tables and reduces it to per-config partial sums.

Inputs (see ``repro.core.fastchar`` for how they are built):

  small: (R, D, 4, B) int32 -- per-row outputs ``V_r`` of config ``d`` for each
         of the 4 values of the row's multiplier bit-pair, for every B operand.
         This is the result of the vectorized ``jnp.take`` over ``RowTables``;
         it is ~4096 ints per config vs 65536 for the full table.
  exact: (A, B) int32 -- exact signed product table.
  w:     (A, B) f32   -- 1 / max(|exact|, 1), the relative-error weights.

The approximate product of config ``d`` for operand codes ``(a, b)`` is

    P[d, a, b] = sum_r small[r, d, pair_r(a), b] << 2r

where ``pair_r(a) = 2*bit_{2r}(a) + bit_{2r+1}(a)`` selects one of 4 planes.
Plane selection is done with broadcast ``where`` masks over an iota of the A
tile -- no gathers inside the kernel, pure VPU work.

Outputs are *per-A-tile partial* statistics so every integer channel stays
exactly representable in int32 (the host combines tiles in int64 -- that is
what makes four of the five BEHAV metrics bit-identical to the float64 numpy
oracle).  Channels of the (n_ta, D, 8) outputs:

  int32: 0 sum|e|   1 count(e != 0)   2 max|e|
         3 sum hi^2  4 sum hi*lo  5 sum lo^2    (hi = |e| >> 8, lo = |e| & 255,
                                                 so e^2 = 65536*h2 + 512*hl + l2)
  f32:   0 sum |e| * w   (relative error; f32 rounding, combined in f64)

Tile-size rule: callers must pick ``a_tile`` such that
``a_tile * B * max|e| < 2^31`` (see ``fastchar.max_abs_error_bound``).

Block shapes come from the kernel registry (``kernels.registry``, spec
``"fastchar.pallas"``): passing ``a_tile``/``d_block`` as ``None`` resolves
the registry's int32-safe defaults, and contexts with ``tuning != "off"``
hand tuned tiles down through ``fastchar.behav_partials``.  The registry also
supplies the ``pl.CostEstimate`` and TPU compiler params (both grid axes are
``parallel`` -- every (i, j) step owns a disjoint output block -- and the
VMEM limit is sized to double-buffered blocks).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import registry
from ..core.operator_model import _chain_eval, spec_for

__all__ = ["behav_stats_pallas", "behav_stats_entry_pallas", "N_CHAN"]

N_CHAN = 8  # output channel count (padded for lane alignment)


def _kernel(small_ref, exact_ref, w_ref, int_ref, rel_ref, *, rows: int, a_tile: int):
    """One (d_block, a_tile) step: rebuild the error tile, reduce to partials."""
    j = pl.program_id(1)
    b = exact_ref.shape[-1]

    # Absolute A codes covered by this tile, broadcast over the B axis.
    a_ids = jax.lax.broadcasted_iota(jnp.int32, (a_tile, b), 0) + j * a_tile

    approx = None
    for r in range(rows):  # static unroll over partial-product rows
        pair = 2 * ((a_ids >> (2 * r)) & 1) + ((a_ids >> (2 * r + 1)) & 1)
        acc = None
        for p in range(4):  # select one of 4 bit-pair planes, no gathers
            plane = small_ref[r, :, p, :]  # (Db, B)
            term = jnp.where((pair == p)[None, :, :], plane[:, None, :], 0)
            acc = term if acc is None else acc + term
        shifted = acc << (2 * r)
        approx = shifted if approx is None else approx + shifted

    err = approx - exact_ref[...][None]            # (Db, Ta, B) int32
    abs_e = jnp.abs(err)

    hi = abs_e >> 8
    lo = abs_e & 255
    s_abs = abs_e.sum(axis=(1, 2))
    cnt = (err != 0).astype(jnp.int32).sum(axis=(1, 2))
    mx = abs_e.max(axis=(1, 2))
    h2 = (hi * hi).sum(axis=(1, 2))
    hl = (hi * lo).sum(axis=(1, 2))
    l2 = (lo * lo).sum(axis=(1, 2))
    zero = jnp.zeros_like(s_abs)
    int_ref[...] = jnp.stack(
        [s_abs, cnt, mx, h2, hl, l2, zero, zero], axis=-1
    )[None]

    rel = (abs_e.astype(jnp.float32) * w_ref[...][None]).sum(axis=(1, 2))
    zf = jnp.zeros_like(rel)
    rel_ref[...] = jnp.stack([rel, zf, zf, zf, zf, zf, zf, zf], axis=-1)[None]


@functools.partial(jax.jit, static_argnames=("d_block", "a_tile", "interpret"))
def behav_stats_pallas(
    small: jnp.ndarray,           # (R, D, 4, B) int32
    exact: jnp.ndarray,           # (A, B) int32
    w: jnp.ndarray,               # (A, B) f32
    d_block: int | None = None,
    a_tile: int | None = None,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Tiled BEHAV partial statistics; returns (int_partials, rel_partials).

    Shapes: (A // a_tile, D, N_CHAN) int32 and float32.  D must divide by
    ``d_block`` and A by ``a_tile`` (``fastchar`` pads the config batch).
    ``None`` tiles resolve the registry defaults for this shape bucket.
    """
    rows, d, four, b = small.shape
    a = exact.shape[0]
    spec = registry.get("fastchar.pallas")
    if d_block is None or a_tile is None:
        tiles = spec.default_tiles(spec.bucket(n_bits=a.bit_length() - 1, d=d))
        d_block = tiles["d_block"] if d_block is None else d_block
        a_tile = tiles["a_tile"] if a_tile is None else a_tile
    assert four == 4 and exact.shape == (a, b) and w.shape == (a, b)
    assert d % d_block == 0, (d, d_block)
    assert a % a_tile == 0, (a, a_tile)
    n_ta = a // a_tile

    cost = spec.cost_estimate(rows=rows, d=d, a=a, b=b, a_tile=a_tile)
    params = spec.compiler_params(rows=rows, d_block=d_block, a_tile=a_tile, b=b)
    grid = (d // d_block, n_ta)
    return pl.pallas_call(
        functools.partial(_kernel, rows=rows, a_tile=a_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, d_block, 4, b), lambda i, j: (0, i, 0, 0)),
            pl.BlockSpec((a_tile, b), lambda i, j: (j, 0)),
            pl.BlockSpec((a_tile, b), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d_block, N_CHAN), lambda i, j: (j, i, 0)),
            pl.BlockSpec((1, d_block, N_CHAN), lambda i, j: (j, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_ta, d, N_CHAN), jnp.int32),
            jax.ShapeDtypeStruct((n_ta, d, N_CHAN), jnp.float32),
        ],
        cost_estimate=pl.CostEstimate(**cost),
        compiler_params=pltpu.TPUCompilerParams(**params),
        interpret=interpret,
    )(small, exact, w)


# ---------------------------------------------------------------------------
# Table-free variant: reconstruct the tile from the (D, R) config masks
# ---------------------------------------------------------------------------


def _entry_kernel(masks_ref, int_ref, rel_ref, *, n_bits: int, a_tile: int):
    """One (d_block, a_tile) step with NO table inputs: the per-row planes are
    synthesized in VMEM from the config masks by the carry-chain model
    (``R * 4 * W`` chain steps over the B axis), the exact products and
    relative-error weights from an iota.  The only HBM traffic besides the
    outputs is the (d_block, R) masks block -- ~4096x less than the
    ``small``+``exact``+``w`` inputs of the table kernel."""
    spec = spec_for(n_bits)
    j = pl.program_id(1)
    b = spec.n_inputs
    half = b // 2
    w_bits, cpr = spec.width, spec.cols_removable
    modw = (1 << w_bits) - 1

    b_codes = jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
    b_s = jnp.where(b_codes >= half, b_codes - b, b_codes)    # (1, B) signed

    a_ids = jax.lax.broadcasted_iota(jnp.int32, (a_tile, b), 0) + j * a_tile
    b_ids = jax.lax.broadcasted_iota(jnp.int32, (a_tile, b), 1)
    a_sv = jnp.where(a_ids >= half, a_ids - b, a_ids)
    b_sv = jnp.where(b_ids >= half, b_ids - b, b_ids)
    exact = a_sv * b_sv                                       # (Ta, B) int32

    approx = None
    for r in range(spec.rows):  # static unroll over partial-product rows
        top = r == spec.rows - 1
        mask_r = masks_ref[:, r][:, None]                     # (Db, 1)
        bx = -b_s if top else b_s
        pair = 2 * ((a_ids >> (2 * r)) & 1) + ((a_ids >> (2 * r + 1)) & 1)
        acc = None
        for p in range(4):  # synthesize the bit-pair plane, then select it
            a0, a1 = (p >> 1) & 1, p & 1
            t1 = (b_s & modw) if a0 else jnp.zeros_like(b_s)
            t2 = ((bx << 1) & modw) if a1 else jnp.zeros_like(b_s)
            plane = _chain_eval(t1, t2, mask_r, w_bits, cpr, jnp, jnp.int32)
            term = jnp.where((pair == p)[None, :, :], plane[:, None, :], 0)
            acc = term if acc is None else acc + term
        shifted = acc << (2 * r)
        approx = shifted if approx is None else approx + shifted

    err = approx - exact[None]                                # (Db, Ta, B) int32
    abs_e = jnp.abs(err)

    hi = abs_e >> 8
    lo = abs_e & 255
    s_abs = abs_e.sum(axis=(1, 2))
    cnt = (err != 0).astype(jnp.int32).sum(axis=(1, 2))
    mx = abs_e.max(axis=(1, 2))
    h2 = (hi * hi).sum(axis=(1, 2))
    hl = (hi * lo).sum(axis=(1, 2))
    l2 = (lo * lo).sum(axis=(1, 2))
    zero = jnp.zeros_like(s_abs)
    int_ref[...] = jnp.stack(
        [s_abs, cnt, mx, h2, hl, l2, zero, zero], axis=-1
    )[None]

    w = 1.0 / jnp.maximum(jnp.abs(exact), 1).astype(jnp.float32)
    rel = (abs_e.astype(jnp.float32) * w[None]).sum(axis=(1, 2))
    zf = jnp.zeros_like(rel)
    rel_ref[...] = jnp.stack([rel, zf, zf, zf, zf, zf, zf, zf], axis=-1)[None]


@functools.partial(jax.jit, static_argnames=("n_bits", "d_block", "a_tile", "interpret"))
def behav_stats_entry_pallas(
    masks: jnp.ndarray,           # (D, R) int32 per-row config masks
    n_bits: int,
    d_block: int | None = None,
    a_tile: int | None = None,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Table-free twin of :func:`behav_stats_pallas`; same outputs/channels.

    Integer channels are bit-identical to the table kernel (the synthesized
    planes equal the gathered ones); the relative channel divides in f32
    in-kernel instead of staging f64-rounded reciprocals, which agrees with
    the oracle to ~1e-7 relative.  Signed multipliers only.
    """
    op_spec = spec_for(n_bits)
    d, rows = masks.shape
    assert rows == op_spec.rows, (rows, op_spec.rows)
    a = b = op_spec.n_inputs
    spec = registry.get("fastchar.entry_pallas")
    if d_block is None or a_tile is None:
        tiles = spec.default_tiles(spec.bucket(n_bits=n_bits, d=d))
        d_block = tiles["d_block"] if d_block is None else d_block
        a_tile = tiles["a_tile"] if a_tile is None else a_tile
    assert d % d_block == 0, (d, d_block)
    assert a % a_tile == 0, (a, a_tile)
    n_ta = a // a_tile

    cost = spec.cost_estimate(rows=rows, d=d, a=a, b=b, a_tile=a_tile,
                              width=op_spec.width)
    params = spec.compiler_params(rows=rows, d_block=d_block, a_tile=a_tile, b=b)
    grid = (d // d_block, n_ta)
    return pl.pallas_call(
        functools.partial(_entry_kernel, n_bits=n_bits, a_tile=a_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_block, rows), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d_block, N_CHAN), lambda i, j: (j, i, 0)),
            pl.BlockSpec((1, d_block, N_CHAN), lambda i, j: (j, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_ta, d, N_CHAN), jnp.int32),
            jax.ShapeDtypeStruct((n_ta, d, N_CHAN), jnp.float32),
        ],
        cost_estimate=pl.CostEstimate(**cost),
        compiler_params=pltpu.TPUCompilerParams(**params),
        interpret=interpret,
    )(masks)
