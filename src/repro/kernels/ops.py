"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (the container is CPU-only; interpret
mode executes the kernel bodies in Python for correctness validation) and to
False on TPU, where the same BlockSpecs drive real VMEM tiling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# the *_kernel module names keep the pallas_call impls from shadowing the
# identically-named lazy function exports on the package (PEP 562 __getattr__
# in __init__.py only fires for attributes the submodule bindings would
# otherwise occupy)
from .axo_matmul_kernel import axo_matmul_pallas
from .flash_attention_kernel import flash_attention_pallas
from .ssd_scan_kernel import ssd_scan_pallas

__all__ = ["on_tpu", "axo_matmul", "flash_attention", "ssd_scan"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def axo_matmul(a_codes, b_codes, f_table, g_table, signed_vals,
               bm: int | None = None, bn: int | None = None,
               bk: int | None = None, interpret: bool | None = None):
    """Rank-R AxO matmul from integer CODES (table-index space).

    The code->value and code->factor lookups are tiny (2^n entries) and run in
    XLA before the kernel; the kernel itself is pure MXU work.  ``None`` tiles
    resolve the ``axo_matmul.pallas`` registry defaults; arbitrary (M, K, N)
    are padded to the block grid inside the kernel wrapper.
    """
    interpret = (not on_tpu()) if interpret is None else interpret
    a_vals = signed_vals[a_codes].astype(jnp.float32)
    b_vals = signed_vals[b_codes].astype(jnp.float32)
    fa = jnp.moveaxis(f_table[a_codes], -1, 0).astype(jnp.float32)  # (R, M, K)
    gb = jnp.moveaxis(g_table[b_codes], -1, 0).astype(jnp.float32)  # (R, K, N)
    return axo_matmul_pallas(
        a_vals, b_vals, fa, gb, bm=bm, bn=bn, bk=bk, interpret=interpret
    )


def flash_attention(q, k, v, causal: bool = True, scale: float | None = None,
                    bq: int | None = None, bk: int | None = None,
                    interpret: bool | None = None):
    interpret = (not on_tpu()) if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale, bq=bq, bk=bk, interpret=interpret
    )


def ssd_scan(x, dt, a, bmat, cmat, chunk: int = 128,
             interpret: bool | None = None):
    interpret = (not on_tpu()) if interpret is None else interpret
    return ssd_scan_pallas(x, dt, a, bmat, cmat, chunk=chunk, interpret=interpret)
