"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each ``ref_*`` implements the kernel's exact math with plain jax.numpy --
no blocking, no scratch, no pipelining -- and is what the per-kernel tests
``assert_allclose`` against across shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ref_axo_matmul_exact", "ref_axo_matmul_lowrank",
           "ref_flash_attention", "ref_ssd_scan"]


# ---------------------------------------------------------------------------
# AxO matmul
# ---------------------------------------------------------------------------


def ref_axo_matmul_exact(a_codes: jnp.ndarray, b_codes: jnp.ndarray,
                         table: jnp.ndarray) -> jnp.ndarray:
    """Bit-exact approximate-operator matmul through the product table.

    a_codes (M, K), b_codes (K, N) -- two's-complement uint codes.
    table (2^n, 2^n) int32 -- approximate products T[a, b].
    Returns (M, N) int32 = sum_k T[a[m,k], b[k,n]].
    """
    prod = table[a_codes[:, :, None], b_codes[None, :, :]]      # (M, K, N)
    return prod.sum(axis=1, dtype=jnp.int32)


def ref_axo_matmul_lowrank(
    a_codes: jnp.ndarray, b_codes: jnp.ndarray,
    f_table: jnp.ndarray,        # (2^n, R) per-code left factors of E
    g_table: jnp.ndarray,        # (2^n, R) per-code right factors
    signed_vals: jnp.ndarray,    # (2^n,) signed value of each code
) -> jnp.ndarray:
    """Deployment semantics: exact product + rank-R error-table correction.

    out = A.B (exact ints) + sum_r F_r(A) @ G_r(B),  E[a,b] ~ sum_r f_r[a] g_r[b]
    """
    av = signed_vals[a_codes].astype(jnp.float32)               # (M, K)
    bv = signed_vals[b_codes].astype(jnp.float32)               # (K, N)
    exact = av @ bv
    fa = f_table[a_codes]                                        # (M, K, R)
    gb = g_table[b_codes]                                        # (K, N, R)
    corr = jnp.einsum("mkr,knr->mn", fa, gb)
    return exact + corr


# ---------------------------------------------------------------------------
# Flash attention (causal + GQA)
# ---------------------------------------------------------------------------


def ref_flash_attention(
    q: jnp.ndarray,              # (B, H, Sq, hd)
    k: jnp.ndarray,              # (B, G, Skv, hd)
    v: jnp.ndarray,              # (B, G, Skv, hd)
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    b, h, sq, hd = q.shape
    g, skv = k.shape[1], k.shape[2]
    rep = h // g
    scale = (1.0 / (hd ** 0.5)) if scale is None else scale
    kh = jnp.repeat(k, rep, axis=1)
    vh = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kh, preferred_element_type=jnp.float32)
    s = s * scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vh.dtype), vh,
                      preferred_element_type=jnp.float32).astype(q.dtype)


# ---------------------------------------------------------------------------
# SSD (Mamba-2) chunked scan
# ---------------------------------------------------------------------------


def ref_ssd_scan(
    x: jnp.ndarray,              # (B, S, H, P)
    dt: jnp.ndarray,             # (B, S, H) positive
    a: jnp.ndarray,              # (H,) negative
    bmat: jnp.ndarray,           # (B, S, G, N)
    cmat: jnp.ndarray,           # (B, S, G, N)
    init_state: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential (exact) state-space recurrence:
    h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t;  y_t = C_t . h_t."""
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    bh = jnp.repeat(bmat, rep, axis=2).astype(jnp.float32)      # (B,S,H,N)
    ch = jnp.repeat(cmat, rep, axis=2).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    h0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(hprev, t):
        decay = jnp.exp(dtf[:, t] * a[None, :])                 # (B,H)
        upd = jnp.einsum("bhn,bh,bhp->bhpn", bh[:, t], dtf[:, t], xf[:, t])
        hnew = hprev * decay[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", ch[:, t], hnew)
        return hnew, y

    hfin, ys = jax.lax.scan(step, h0, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), hfin
