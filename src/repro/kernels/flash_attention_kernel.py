"""Flash attention Pallas kernel (causal + GQA), TPU BlockSpec tiling.

Grid (B, H, nQ, nK) with the KV axis innermost: online-softmax statistics
(m, l) and the fp32 output accumulator live in VMEM scratch across the KV
steps of one (batch, head, q-block).  Causal blocks entirely above the
diagonal are masked cheaply (their contribution underflows to zero through
exp(-inf)); GQA maps each query head to its KV group via index_map, so KV
blocks are fetched once per group -- never materialized per-head.

Block shapes come from the kernel registry (spec ``"flash_attention.pallas"``,
replacing the historical hard-coded ``bq=bk=128``); ``None`` resolves the
bucket defaults, and the registry also supplies the ``pl.CostEstimate`` and
compiler params.  Arbitrary sequence lengths (e.g. seq 192 with bq=128) are
zero-padded to the block grid: padded *query* rows are computed and sliced
off, padded *KV* positions are masked to -inf via the static true KV length
(a zero-padded key would otherwise contribute exp(0) mass to the softmax).

Oracle: kernels.ref.ref_flash_attention; parity swept over shapes/dtypes in
tests/test_kernels.py (interpret=True executes this exact body on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import registry

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float, causal: bool, kv_len: int, n_k: int,
            bq: int, bk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                        # (bq, hd)
    k = k_ref[0, 0]                        # (bk, hd)
    v = v_ref[0, 0]                        # (bk, hd)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal or kv_len % bk:
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = k_pos < kv_len             # mask zero-padded KV positions
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            valid &= q_pos >= k_pos
        s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _emit():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret", "scale")
)
def flash_attention_pallas(
    q: jnp.ndarray,              # (B, H, Sq, hd)
    k: jnp.ndarray,              # (B, G, Skv, hd)
    v: jnp.ndarray,              # (B, G, Skv, hd)
    causal: bool = True,
    scale: float | None = None,
    bq: int | None = None,
    bk: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    b, h, sq, hd = q.shape
    g, skv = k.shape[1], k.shape[2]
    rep = h // g
    scale = float(1.0 / (hd ** 0.5)) if scale is None else scale
    spec = registry.get("flash_attention.pallas")
    if bq is None or bk is None:
        d = spec.default_tiles(spec.bucket(sq=sq, skv=skv, hd=hd))
        bq = d["bq"] if bq is None else bq
        bk = d["bk"] if bk is None else bk
    # shrink blocks to the padded problem, never below the f32 min sublane/lane
    bq = max(8, min(bq, _round_up(sq, 8)))
    bk = max(128, min(bk, _round_up(skv, 128)))
    sqp, skvp = _round_up(sq, bq), _round_up(skv, bk)
    # static-shape property, so recording at trace time covers every dispatch
    # of this shape; the fraction of the padded (Sq, Skv) score space that is
    # padding (masked to -inf in-kernel)
    from ..obs.telemetry import record_pad_waste

    record_pad_waste("flash_attention", (sq, skv), (sqp, skvp))
    if sqp != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sqp - sq), (0, 0)))
    if skvp != skv:
        # padded KV positions are masked to -inf in-kernel via kv_len
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skvp - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skvp - skv), (0, 0)))
    n_k = skvp // bk

    cost = spec.cost_estimate(b=b, h=h, sq=sqp, skv=skvp, hd=hd, causal=causal)
    params = spec.compiler_params(bq=bq, bk=bk, hd=hd)
    grid = (b, h, sqp // bq, n_k)
    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, kv_len=skv, n_k=n_k,
            bq=bq, bk=bk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            # GQA: query head hi reads KV group hi // rep
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        cost_estimate=pl.CostEstimate(**cost),
        compiler_params=pltpu.TPUCompilerParams(**params),
        interpret=interpret,
    )(q, k, v)
    return out if sqp == sq else out[:, :, :sq]
