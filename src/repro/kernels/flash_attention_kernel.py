"""Flash attention Pallas kernel (causal + GQA), TPU BlockSpec tiling.

Grid (B, H, nQ, nK) with the KV axis innermost: online-softmax statistics
(m, l) and the fp32 output accumulator live in VMEM scratch across the KV
steps of one (batch, head, q-block).  Causal blocks entirely above the
diagonal are masked cheaply (their contribution underflows to zero through
exp(-inf)); GQA maps each query head to its KV group via index_map, so KV
blocks are fetched once per group -- never materialized per-head.

Oracle: kernels.ref.ref_flash_attention; parity swept over shapes/dtypes in
tests/test_kernels.py (interpret=True executes this exact body on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float, causal: bool, n_k: int, bq: int, bk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                        # (bq, hd)
    k = k_ref[0, 0]                        # (bk, hd)
    v = v_ref[0, 0]                        # (bk, hd)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _emit():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret", "scale")
)
def flash_attention_pallas(
    q: jnp.ndarray,              # (B, H, Sq, hd)
    k: jnp.ndarray,              # (B, G, Skv, hd)
    v: jnp.ndarray,              # (B, G, Skv, hd)
    causal: bool = True,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, h, sq, hd = q.shape
    g, skv = k.shape[1], k.shape[2]
    rep = h // g
    scale = float(1.0 / (hd ** 0.5)) if scale is None else scale
    bq, bk = min(bq, sq), min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)
    n_k = skv // bk

    grid = (b, h, sq // bq, n_k)
    return pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, n_k=n_k, bq=bq, bk=bk
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            # GQA: query head hi reads KV group hi // rep
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
