"""Mamba-2 SSD chunked-scan Pallas kernel.

Grid (B, nc) with the chunk axis innermost: the (H, P, N) SSD state lives in
VMEM scratch and is carried across the chunk steps of one batch row (TPU grid
execution is sequential in the minor axis, which is exactly the inter-chunk
recurrence).  Per chunk:

  1. intra-chunk quadratic term   y_diag = (C B^T . L) dt x      (MXU matmuls)
  2. cross-chunk term             y_off  = C . state_in . decays
  3. state update                 state  = decay_chunk * state_in + B^T dt x

All cumulative-decay math is fp32; group->head broadcast happens on the tiny
(Q, G, N) chunk tensors in VMEM.

Oracle: kernels.ref.ref_ssd_scan (sequential recurrence, exact); the chunked
algebra here matches models.ssm.ssd_chunked (the XLA execution path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_pallas"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hfin_ref, state_ref,
            *, n_chunks: int, rep: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)        # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)      # (Q, H)
    a = a_ref[...].astype(jnp.float32)      # (H,)
    bmat = b_ref[0].astype(jnp.float32)     # (Q, G, N)
    cmat = c_ref[0].astype(jnp.float32)     # (Q, G, N)

    q = x.shape[0]
    bh = jnp.repeat(bmat, rep, axis=1)      # (Q, H, N)
    ch = jnp.repeat(cmat, rep, axis=1)

    da = dt * a[None, :]                    # (Q, H)
    da_cs = jnp.cumsum(da, axis=0)          # inclusive

    # 1) intra-chunk (lower-triangular decay kernel L)
    seg = da_cs[:, None, :] - da_cs[None, :, :]            # (Q, Q, H) l - s
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_mat = jnp.where(tri[:, :, None], jnp.exp(seg), 0.0)  # (Q, Q, H)
    scores = jnp.einsum("lhn,shn->hls", ch, bh)            # (H, Q, Q)
    y = jnp.einsum("hls,lsh,sh,shp->lhp",
                   scores, l_mat, dt, x)                   # (Q, H, P)

    # 2) cross-chunk: contribution of the state entering this chunk
    state_in = state_ref[...]                              # (H, P, N)
    y = y + jnp.einsum("lhn,hpn,lh->lhp", ch, state_in, jnp.exp(da_cs))

    # 3) state update
    decay_out = jnp.exp(da_cs[-1:, :] - da_cs)             # (Q, H)
    upd = jnp.einsum("shn,sh,shp->hpn", bh, decay_out * dt, x)
    state_ref[...] = state_in * jnp.exp(da_cs[-1])[:, None, None] + upd

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit():
        hfin_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jnp.ndarray,              # (B, S, H, P)
    dt: jnp.ndarray,             # (B, S, H)
    a: jnp.ndarray,              # (H,)
    bmat: jnp.ndarray,           # (B, S, G, N)
    cmat: jnp.ndarray,           # (B, S, G, N)
    chunk: int = 128,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    grid = (b, nc)
    y, hfin = pl.pallas_call(
        functools.partial(_kernel, n_chunks=nc, rep=rep),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((h,), lambda bi, ci: (0,)),
            pl.BlockSpec((1, chunk, g, n), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, chunk, g, n), lambda bi, ci: (bi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda bi, ci: (bi, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, bmat, cmat)
    return y, hfin
