"""Pallas TPU kernels for the perf-critical compute layers:

* ``axo_matmul``      -- the paper's approximate-operator arithmetic, adapted
                         to the MXU as exact-matmul + rank-R error correction.
* ``flash_attention`` -- blockwise online-softmax attention (causal + GQA).
* ``ssd_scan``        -- Mamba-2 chunked state-space scan.
* ``behav_stats``     -- tiled BEHAV error-statistics reduction over
                         reconstructed error-table tiles (the DSE
                         characterization fast path, see ``char_kernels.py``
                         and ``repro.core.fastchar``).

Each kernel: ``<name>.py`` (pl.pallas_call + BlockSpec) with an ``ops.py``
jit wrapper and a ``ref.py`` pure-jnp oracle.  On this CPU-only container the
kernels validate under ``interpret=True``; on TPU the same BlockSpecs drive
HBM->VMEM pipelining.
"""

from .char_kernels import behav_stats_pallas
from .ops import axo_matmul, flash_attention, on_tpu, ssd_scan

__all__ = ["axo_matmul", "behav_stats_pallas", "flash_attention", "ssd_scan", "on_tpu"]
