"""Pallas TPU kernels for the perf-critical compute layers:

* ``axo_matmul``      -- the paper's approximate-operator arithmetic, adapted
                         to the MXU as exact-matmul + rank-R error correction.
* ``flash_attention`` -- blockwise online-softmax attention (causal + GQA).
* ``ssd_scan``        -- Mamba-2 chunked state-space scan.
* ``behav_stats``     -- tiled BEHAV error-statistics reduction over
                         reconstructed error-table tiles (the DSE
                         characterization fast path, see ``char_kernels.py``
                         and ``repro.core.fastchar``).

Each kernel: ``<name>.py`` (pl.pallas_call + BlockSpec) with an ``ops.py``
jit wrapper and a ``ref.py`` pure-jnp oracle.  On this CPU-only container the
kernels validate under ``interpret=True``; on TPU the same BlockSpecs drive
HBM->VMEM pipelining.

The DSE engine kernels (char/app/moo) register specs with the **kernel
registry** (``registry``): tunable block-shape spaces, safe defaults,
cost-estimate/compiler-params formulas and correctness oracles, searched per
(shape bucket, device) by the **autotuner** (``tuning``) under an
``ExecutionContext(tuning=...)`` policy.  ``registry.describe()`` lists every
registered impl per engine (``examples/operator_dse.py --kernel-impl list``).
"""

import importlib

from . import registry, tuning

__all__ = [
    "axo_matmul",
    "behav_stats_pallas",
    "flash_attention",
    "ssd_scan",
    "on_tpu",
    "registry",
    "tuning",
]

# The kernel modules pull in JAX + Pallas; the registry/tuning modules are
# numpy-only on purpose (ExecutionContext consults engine menus from numpy
# processes).  PEP 562 lazy exports keep `from repro.kernels import
# axo_matmul` working without making `from repro.kernels import registry`
# pay the JAX import.
_LAZY = {
    "axo_matmul": ".ops",
    "flash_attention": ".ops",
    "ssd_scan": ".ops",
    "on_tpu": ".ops",
    "behav_stats_pallas": ".char_kernels",
}


def __getattr__(name):
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name], __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
