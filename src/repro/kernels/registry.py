"""Unified kernel registry: one spec per Pallas/XLA implementation.

PRs 1-4 grew three accelerator kernel families -- the characterization BEHAV
reduction (``char_kernels.behav_stats_pallas`` + its XLA twin), the
application table-GEMV (``app_kernels.table_gemv_pallas`` + gather/GEMM
fallbacks) and the NSGA-II dominance counts (``moo_kernels.
dominance_counts_pallas`` + the dominance-matrix XLA twin) -- and each
hard-coded block shapes chosen for int32-overflow safety, not occupancy.
This module is the single place every implementation registers:

  * its **tunable block-shape space** (ordered ``(param, candidates)`` pairs),
  * **safe defaults** (a function of the shape bucket -- e.g. the char
    engine's int32-safe ``a_tile``),
  * a **constraint** predicate filtering candidates per shape bucket (int32
    partial-sum bounds, divisibility, VMEM fit),
  * **cost-estimate** and **compiler-params** formulas (plain dicts; the
    kernel files wrap them into ``pl.CostEstimate`` /
    ``pltpu.TPUCompilerParams`` -- dimension semantics + VMEM limits),
  * a **correctness oracle** (the reference implementation every tuned tile
    candidate must match bit-for-bit under interpret mode; see
    ``kernels.tuning``).

The registry itself is pure data: importing it pulls in neither JAX nor the
kernel modules (implementations and oracles are referenced by
``"module:attr"`` strings and resolved lazily), so
``repro.core.engine.ExecutionContext`` can consult engine menus without
dragging device code into numpy-only processes.

Engines resolve implementations through
:meth:`repro.core.engine.ExecutionContext.resolve_impl` (which reads the
per-engine menus registered here) and tile shapes through
:func:`repro.kernels.tuning.tiles_for` (which honors the context's
``tuning="off"|"cached"|"search"`` policy).
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from typing import Callable

__all__ = [
    "KernelSpec",
    "register",
    "get",
    "specs_for",
    "impl_names",
    "registered",
    "describe",
    "ENGINES",
]

ENGINES = ("fastchar", "fastapp", "fastmoo", "axo_matmul", "flash_attention")


def _pow2_bucket(x: int, cap: int = 1 << 14) -> int:
    """Smallest power of two >= x (>= 1), capped -- the shape-bucket rule."""
    x = max(int(x), 1)
    b = 1
    while b < x and b < cap:
        b <<= 1
    return b


def _resolve_ref(ref: str):
    mod, attr = ref.split(":")
    return getattr(importlib.import_module(mod), attr)


@dataclass(frozen=True)
class KernelSpec:
    """One registered kernel implementation.

    ``fn_ref`` / ``oracle_ref`` are lazy ``"module:attr"`` references: ``fn``
    is the engine-level entry point the autotuner times (signature
    ``fn(bucket, tiles) -> outputs``, see ``kernels.tuning`` for the per-
    engine harnesses), ``oracle`` the reference implementation parity is
    checked against.  ``tunables`` is the ordered block-shape search space;
    ``defaults_fn(bucket)`` the safe (untuned) tiles; ``constraint(bucket,
    tiles)`` filters candidates; ``cost_fn`` / ``params_fn`` return plain
    dicts the kernel files wrap into ``pl.CostEstimate`` and
    ``pltpu.TPUCompilerParams``.
    """

    name: str                                   # "fastchar.pallas", ...
    engine: str                                 # one of ENGINES
    impl: str                                   # "pallas" | "xla" | "gemm"
    fn_ref: str                                 # harness entry "module:attr"
    oracle_ref: str | None = None               # reference impl "module:attr"
    tunables: tuple = ()                        # ((param, (candidates...)),...)
    defaults_fn: Callable | None = None         # bucket -> {param: value}
    bucket_fn: Callable | None = None           # (**shape) -> hashable bucket
    constraint: Callable | None = None          # (bucket, tiles) -> bool
    cost_fn: Callable | None = None             # (shape kwargs) -> dict
    params_fn: Callable | None = None           # (shape kwargs) -> dict
    tol: float = 1e-6                           # rtol/atol for "close" parity
    description: str = ""

    # -- lazy references ------------------------------------------------------

    @property
    def fn(self):
        return _resolve_ref(self.fn_ref)

    @property
    def oracle(self):
        return None if self.oracle_ref is None else _resolve_ref(self.oracle_ref)

    # -- tile space -----------------------------------------------------------

    @property
    def tunable_names(self) -> tuple:
        return tuple(p for p, _ in self.tunables)

    def bucket(self, **shape):
        """Shape bucket for ``shape`` -- the autotune cache key component."""
        if self.bucket_fn is None:
            return ()
        return self.bucket_fn(**shape)

    def default_tiles(self, bucket) -> dict:
        """Safe tiles for ``bucket``: the spec's defaults, shrunk to the
        largest admissible candidate when they violate the bucket constraint.
        Best-effort when the whole space is inadmissible (a bucket no tile
        satisfies, e.g. blocks that cannot fit VMEM at any k_tile): the raw
        defaults come back unchecked, and it is the *caller's* job to pick a
        different impl for such shapes (the engines' auto-selection does)."""
        tiles = dict(self.defaults_fn(bucket)) if self.defaults_fn else {}
        if tiles and self.constraint is not None and not self.constraint(bucket, tiles):
            cands = self.candidates(bucket)
            if cands:
                return cands[-1]
        return tiles

    def candidates(self, bucket) -> list[dict]:
        """Every admissible tile assignment for ``bucket`` (full product)."""
        combos: list[dict] = [{}]
        for param, values in self.tunables:
            combos = [{**c, param: v} for c in combos for v in values]
        if self.constraint is not None:
            combos = [c for c in combos if self.constraint(bucket, c)]
        return combos

    def cost_estimate(self, **shape) -> dict | None:
        return None if self.cost_fn is None else self.cost_fn(**shape)

    def compiler_params(self, **shape) -> dict | None:
        return None if self.params_fn is None else self.params_fn(**shape)


# ---------------------------------------------------------------------------
# The registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, KernelSpec] = {}


def register(spec: KernelSpec) -> KernelSpec:
    if spec.engine not in ENGINES:
        raise ValueError(f"unknown engine {spec.engine!r} (not in {ENGINES})")
    if spec.name in _REGISTRY:
        raise ValueError(f"kernel {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> KernelSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no kernel {name!r} registered (have {sorted(_REGISTRY)})"
        ) from None


def registered() -> tuple[KernelSpec, ...]:
    return tuple(_REGISTRY.values())


def specs_for(engine: str) -> tuple[KernelSpec, ...]:
    return tuple(s for s in _REGISTRY.values() if s.engine == engine)


def impl_names(engine: str) -> tuple[str, ...]:
    """The engine's impl menu, in registration (= preference-listing) order."""
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r} (not in {ENGINES})")
    return tuple(s.impl for s in _REGISTRY.values() if s.engine == engine)


def describe() -> str:
    """Human-readable registry listing (``operator_dse.py --kernel-impl list``)."""
    lines = []
    for engine in ENGINES:
        lines.append(f"{engine}:")
        for s in specs_for(engine):
            space = ", ".join(
                f"{p} in {list(v)}" for p, v in s.tunables
            ) or "no tunables"
            lines.append(f"  {s.impl:7s} {s.name:16s} {space}")
            if s.description:
                lines.append(f"          {s.description}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Registered specs
# ---------------------------------------------------------------------------
#
# All formulas below are pure host python over the shape bucket; anything that
# needs the operator model imports it lazily (numpy-only, no JAX).


def _char_bound(n_bits: int) -> int:
    from repro.core.operator_model import spec_for

    from_spec = spec_for(n_bits)
    row_mag = 1 << (from_spec.width - 1)
    approx = row_mag * ((4**from_spec.rows - 1) // 3)
    return approx + (1 << (2 * n_bits - 2))


def _char_bucket(*, n_bits: int, d: int):
    return (int(n_bits), _pow2_bucket(d, cap=1024))


def _char_constraint(bucket, tiles) -> bool:
    n_bits, d = bucket
    a = 1 << n_bits
    a_tile, d_block = tiles["a_tile"], tiles["d_block"]
    if a_tile > a or a % a_tile or d_block > d:
        return False
    # int32 safety: every per-tile partial sum must stay < 2^31 (the exact
    # int64 host combine depends on exactly-representable tile partials)
    return a_tile * a * _char_bound(n_bits) < (1 << 31)


def _char_defaults(bucket) -> dict:
    n_bits, d = bucket
    a = 1 << n_bits
    tile = a
    while tile > 1 and tile * a * _char_bound(n_bits) >= (1 << 30):
        tile //= 2
    return {"a_tile": tile, "d_block": min(8, d)}


def _char_cost(*, rows: int, d: int, a: int, b: int, a_tile: int, **_) -> dict:
    # per element of the (D, A, B) error table: R plane-selects + shift-adds,
    # the |e| decomposition and 6 reduction channels; outputs are the two
    # (A/a_tile, D, 8) partial stacks
    return {
        "flops": d * a * b * (6 * rows + 12),
        "bytes_accessed": 4 * (rows * d * 4 * b + 2 * a * b) + 8 * (a // a_tile) * d * 8,
        "transcendentals": 0,
    }


def _char_params(*, rows: int, d_block: int, a_tile: int, b: int, **_) -> dict:
    block_bytes = 4 * (rows * d_block * 4 * b + 2 * a_tile * b + d_block * a_tile * b)
    return {
        # output blocks are disjoint across both grid axes
        "dimension_semantics": ("parallel", "parallel"),
        "vmem_limit_bytes": max(4 << 20, 2 * block_bytes),
    }


def _entry_char_cost(*, rows: int, d: int, a: int, b: int, a_tile: int,
                     width: int, **_) -> dict:
    # the table-kernel reduction plus the in-VMEM synthesis: R*4 carry chains
    # of `width` steps (~6 lane-ops each) over the B axis, re-run per A tile;
    # HBM traffic is just the (D, R) masks and the partial stacks
    return {
        "flops": d * a * b * (6 * rows + 12)
        + (a // a_tile) * d * rows * 4 * b * width * 6,
        "bytes_accessed": 4 * d * rows + 8 * (a // a_tile) * d * 8,
        "transcendentals": 0,
    }


def _entry_char_params(*, rows: int, d_block: int, a_tile: int, b: int, **_) -> dict:
    # masks block + the synthesized per-row planes + the reconstructed tile
    block_bytes = 4 * (d_block * rows + d_block * 4 * b + d_block * a_tile * b)
    return {
        "dimension_semantics": ("parallel", "parallel"),
        "vmem_limit_bytes": max(4 << 20, 2 * block_bytes),
    }


def _app_bucket(*, n_bits: int, d: int, m: int, k: int, n: int):
    return (
        int(n_bits),
        _pow2_bucket(d, cap=1024),
        _pow2_bucket(m),
        _pow2_bucket(k),
        _pow2_bucket(n),
    )


def _app_constraint(bucket, tiles) -> bool:
    n_bits, d, m, k, n = bucket
    k_tile = tiles["k_tile"]
    if k_tile > _pow2_bucket(k):  # never tile wider than the padded K
        return False
    a = 1 << n_bits
    # VMEM fit: the resident flattened table + the (M, k_tile, N) gather tile
    return 4 * (a * a + m * k_tile * n + m * k_tile + k_tile * n) < (12 << 20)


def _app_xla_constraint(bucket, tiles) -> bool:
    # chunks wider than the config batch degenerate to d (min() in the
    # engine), so they would duplicate the d-sized candidate
    return tiles["d_chunk"] <= bucket[1]


def _entry_app_constraint(bucket, tiles) -> bool:
    n_bits, d, m, k, n = bucket
    k_tile = tiles["k_tile"]
    if k_tile > _pow2_bucket(k):
        return False
    a = 1 << n_bits
    # VMEM fit: one row's synthesized (4, B) planes + the gather tile -- no
    # (A, B) table, which is what admits 12-bit operands the table kernel
    # cannot hold (a*a ints would be 67 MB there)
    return 4 * (4 * a + m * k_tile * n + m * k_tile + k_tile * n) < (12 << 20)


def _entry_app_cost(*, d: int, m: int, k: int, n: int, a: int, rows: int,
                    width: int, **_) -> dict:
    return {
        # R gather-accumulate passes over the (M, K, N) tensor + the per-grid-
        # step synthesis (R*4 chains of `width` steps over the B axis; one
        # grid step per default-width K tile)
        "flops": 2 * d * m * k * n * rows
        + d * max(1, k // 64) * rows * 4 * a * width * 6,
        "bytes_accessed": 4 * (d * rows + m * k + k * n + d * m * n),
        "transcendentals": 0,
    }


def _entry_app_params(*, m: int, k_tile: int, n: int, a: int, rows: int, **_) -> dict:
    block_bytes = 4 * (rows + 4 * a + m * k_tile * n + m * k_tile + k_tile * n + m * n)
    return {
        "dimension_semantics": ("parallel", "arbitrary"),
        "vmem_limit_bytes": max(4 << 20, 2 * block_bytes),
    }


def _app_defaults(bucket) -> dict:
    _, _, _, k, _ = bucket
    return {"k_tile": min(64, _pow2_bucket(k))}


def _app_xla_defaults(bucket) -> dict:
    return {"d_chunk": min(8, bucket[1])}


def _app_cost(*, d: int, m: int, k: int, n: int, a: int, **_) -> dict:
    return {
        "flops": 2 * d * m * k * n,
        "bytes_accessed": 4 * (d * a * a + m * k + k * n + d * m * n),
        "transcendentals": 0,
    }


def _app_params(*, m: int, k_tile: int, n: int, a: int, **_) -> dict:
    block_bytes = 4 * (a * a + m * k_tile * n + m * k_tile + k_tile * n + m * n)
    return {
        # the k axis accumulates into a revisited output block: sequential
        "dimension_semantics": ("parallel", "arbitrary"),
        "vmem_limit_bytes": max(4 << 20, 2 * block_bytes),
    }


def _axo_bucket(*, m: int, k: int, n: int, rank: int):
    return (
        _pow2_bucket(m),
        _pow2_bucket(k),
        _pow2_bucket(n),
        _pow2_bucket(rank, cap=64),
    )


def _axo_constraint(bucket, tiles) -> bool:
    m, k, n, rank = bucket
    bm, bn, bk = tiles["bm"], tiles["bn"], tiles["bk"]
    # blocks never exceed the padded problem (the kernel pads M to sublane
    # multiples of 8 and K/N to lane multiples of 128, then to the block)
    if bm > max(8, m) or bn > max(128, n) or bk > max(128, k):
        return False
    # VMEM fit: a/b value blocks + the rank-stacked factor blocks + f32
    # accumulator scratch and output block
    return 4 * ((1 + rank) * (bm * bk + bk * bn) + 2 * bm * bn) < (12 << 20)


def _axo_defaults(bucket) -> dict:
    m, _, _, _ = bucket
    return {"bm": min(128, max(8, m)), "bn": 128, "bk": 128}


def _axo_cost(*, m: int, k: int, n: int, rank: int, **_) -> dict:
    return {
        # the exact product plus one (bm, bk) x (bk, bn) matmul per rank term
        "flops": 2 * m * n * k * (1 + rank),
        "bytes_accessed": 4 * ((1 + rank) * (m * k + k * n) + m * n),
        "transcendentals": 0,
    }


def _axo_params(*, bm: int, bn: int, bk: int, rank: int, **_) -> dict:
    block_bytes = 4 * ((1 + rank) * (bm * bk + bk * bn) + 2 * bm * bn)
    return {
        # the K axis accumulates into a revisited output block: sequential
        "dimension_semantics": ("parallel", "parallel", "arbitrary"),
        "vmem_limit_bytes": max(4 << 20, 2 * block_bytes),
    }


def _flash_bucket(*, sq: int, skv: int, hd: int):
    return (_pow2_bucket(sq), _pow2_bucket(skv), _pow2_bucket(hd, cap=256))


def _flash_constraint(bucket, tiles) -> bool:
    sq, skv, hd = bucket
    bq, bk = tiles["bq"], tiles["bk"]
    if bq > max(8, sq) or bk > max(128, skv):
        return False
    # q/acc/o blocks + k/v blocks + the (bq, bk) score matrix and m/l rows
    return 4 * (3 * bq * hd + 2 * bk * hd + 2 * bq * bk + 2 * bq) < (12 << 20)


def _flash_defaults(bucket) -> dict:
    sq, _, _ = bucket
    return {"bq": min(128, max(8, sq)), "bk": 128}


def _flash_cost(*, b: int, h: int, sq: int, skv: int, hd: int,
                causal: bool = True, **_) -> dict:
    pairs = b * h * sq * skv // (2 if causal else 1)
    return {
        "flops": 4 * pairs * hd,  # qk^T and pv, 2 flops/MAC each
        "bytes_accessed": 4 * (2 * b * h * sq * hd + 2 * b * h * skv * hd),
        "transcendentals": pairs,  # one exp per unmasked score
    }


def _flash_params(*, bq: int, bk: int, hd: int, **_) -> dict:
    block_bytes = 4 * (3 * bq * hd + 2 * bk * hd + 2 * bq * bk + 2 * bq)
    return {
        # KV blocks revisit the q block's scratch (online softmax): sequential
        "dimension_semantics": ("parallel", "parallel", "parallel", "arbitrary"),
        "vmem_limit_bytes": max(4 << 20, 2 * block_bytes),
    }


def _moo_bucket(*, p: int, n_obj: int):
    return (_pow2_bucket(p), int(n_obj))


def _moo_constraint(bucket, tiles) -> bool:
    p, _ = bucket
    tile, j_tile = tiles["tile"], tiles["j_tile"]
    return tile <= p and j_tile <= p


def _moo_defaults(bucket) -> dict:
    p, _ = bucket
    # the 2-D-friendly layout: j (dominator) tiles sized to the 128 lanes
    return {"tile": min(64, p), "j_tile": min(128, p)}


def _moo_cost(*, p: int, n_obj: int, **_) -> dict:
    return {
        "flops": p * p * (4 * n_obj + 8),
        "bytes_accessed": 4 * (2 * p * n_obj + 4 * p),
        "transcendentals": 0,
    }


def _moo_params(*, tile: int, j_tile: int, n_obj: int, **_) -> dict:
    block_bytes = 4 * (2 * (tile + j_tile) * (n_obj + 2) + tile * j_tile)
    return {
        # j revisits the output block (accumulation): sequential
        "dimension_semantics": ("parallel", "arbitrary"),
        "vmem_limit_bytes": max(4 << 20, 2 * block_bytes),
    }


# -- fastchar: BEHAV characterization partials ------------------------------

register(KernelSpec(
    name="fastchar.xla",
    engine="fastchar",
    impl="xla",
    fn_ref="repro.kernels.tuning:_run_fastchar",
    oracle_ref="repro.kernels.tuning:_oracle_fastchar",
    tunables=(
        ("a_tile", (8, 16, 32, 64, 128, 256)),
        ("d_block", (2, 4, 8, 16, 32)),
    ),
    defaults_fn=_char_defaults,
    bucket_fn=_char_bucket,
    constraint=_char_constraint,
    description="lax.map-chunked XLA twin of the Pallas BEHAV reduction",
))

register(KernelSpec(
    name="fastchar.pallas",
    engine="fastchar",
    impl="pallas",
    fn_ref="repro.kernels.tuning:_run_fastchar",
    oracle_ref="repro.kernels.tuning:_oracle_fastchar",
    tunables=(
        ("a_tile", (8, 16, 32, 64, 128, 256)),
        ("d_block", (2, 4, 8, 16, 32)),
    ),
    defaults_fn=_char_defaults,
    bucket_fn=_char_bucket,
    constraint=_char_constraint,
    cost_fn=_char_cost,
    params_fn=_char_params,
    description="tiled error-table reconstruction + per-A-tile partial stats",
))

register(KernelSpec(
    name="fastchar.entry",
    engine="fastchar",
    impl="entry",
    fn_ref="repro.kernels.tuning:_run_fastchar",
    oracle_ref="repro.kernels.tuning:_oracle_fastchar",
    tunables=(
        ("a_tile", (8, 16, 32, 64, 128, 256)),
        ("d_block", (2, 4, 8, 16, 32)),
    ),
    defaults_fn=_char_defaults,
    bucket_fn=_char_bucket,
    constraint=_char_constraint,
    description="table-free XLA twin: per-row planes synthesized from masks",
))

register(KernelSpec(
    name="fastchar.entry_pallas",
    engine="fastchar",
    impl="entry_pallas",
    fn_ref="repro.kernels.tuning:_run_fastchar",
    oracle_ref="repro.kernels.tuning:_oracle_fastchar",
    tunables=(
        ("a_tile", (8, 16, 32, 64, 128, 256)),
        ("d_block", (2, 4, 8, 16, 32)),
    ),
    defaults_fn=_char_defaults,
    bucket_fn=_char_bucket,
    constraint=_char_constraint,
    cost_fn=_entry_char_cost,
    params_fn=_entry_char_params,
    description="table-free BEHAV kernel: masks-only input, in-VMEM synthesis",
))

# -- fastapp: table arithmetic ----------------------------------------------

register(KernelSpec(
    name="fastapp.gemm",
    engine="fastapp",
    impl="gemm",
    fn_ref="repro.kernels.tuning:_run_fastapp",
    oracle_ref="repro.kernels.tuning:_oracle_fastapp",
    tunables=(),
    bucket_fn=_app_bucket,
    description="pair-plane masked f32 GEMMs over the tiny per-row tables",
))

register(KernelSpec(
    name="fastapp.xla",
    engine="fastapp",
    impl="xla",
    fn_ref="repro.kernels.tuning:_run_fastapp",
    oracle_ref="repro.kernels.tuning:_oracle_fastapp",
    tunables=(("d_chunk", (2, 4, 8, 16, 32)),),
    defaults_fn=_app_xla_defaults,
    bucket_fn=_app_bucket,
    constraint=_app_xla_constraint,
    description="flattened jnp.take gathers tiled by lax.map config chunks",
))

register(KernelSpec(
    name="fastapp.pallas",
    engine="fastapp",
    impl="pallas",
    fn_ref="repro.kernels.tuning:_run_fastapp",
    oracle_ref="repro.kernels.tuning:_oracle_fastapp",
    tunables=(("k_tile", (16, 32, 64, 128, 256)),),
    defaults_fn=_app_defaults,
    bucket_fn=_app_bucket,
    constraint=_app_constraint,
    cost_fn=_app_cost,
    params_fn=_app_params,
    description="K-tiled batched table-GEMV, per-config table VMEM-resident",
))

register(KernelSpec(
    name="fastapp.entry",
    engine="fastapp",
    impl="entry",
    fn_ref="repro.kernels.tuning:_run_fastapp",
    oracle_ref="repro.kernels.tuning:_oracle_fastapp",
    tunables=(("d_chunk", (2, 4, 8, 16, 32)),),
    defaults_fn=_app_xla_defaults,
    bucket_fn=_app_bucket,
    constraint=_app_xla_constraint,
    description="table-free gathers from device-synthesized per-row planes",
))

register(KernelSpec(
    name="fastapp.entry_pallas",
    engine="fastapp",
    impl="entry_pallas",
    fn_ref="repro.kernels.tuning:_run_fastapp",
    oracle_ref="repro.kernels.tuning:_oracle_fastapp",
    tunables=(("k_tile", (16, 32, 64, 128, 256)),),
    defaults_fn=_app_defaults,
    bucket_fn=_app_bucket,
    constraint=_entry_app_constraint,
    cost_fn=_entry_app_cost,
    params_fn=_entry_app_params,
    description="table-free K-tiled GEMV: VMEM tile synthesized from masks",
))

# -- axo_matmul: AxO serving matmul (exact product + rank-R error factors) --

register(KernelSpec(
    name="axo_matmul.xla",
    engine="axo_matmul",
    impl="xla",
    fn_ref="repro.kernels.tuning:_run_axo",
    oracle_ref="repro.kernels.tuning:_oracle_axo",
    tunables=(),
    bucket_fn=_axo_bucket,
    tol=1e-5,
    description="ref_axo_matmul_lowrank: einsum exact product + rank terms",
))

register(KernelSpec(
    name="axo_matmul.pallas",
    engine="axo_matmul",
    impl="pallas",
    fn_ref="repro.kernels.tuning:_run_axo",
    oracle_ref="repro.kernels.tuning:_oracle_axo",
    tunables=(
        ("bm", (8, 16, 32, 64, 128, 256)),
        ("bn", (128, 256)),
        ("bk", (128, 256)),
    ),
    defaults_fn=_axo_defaults,
    bucket_fn=_axo_bucket,
    constraint=_axo_constraint,
    cost_fn=_axo_cost,
    params_fn=_axo_params,
    tol=1e-5,
    description="K-blocked AxO matmul, rank terms unrolled in VMEM scratch",
))

# -- flash_attention: serving attention -------------------------------------

register(KernelSpec(
    name="flash_attention.xla",
    engine="flash_attention",
    impl="xla",
    fn_ref="repro.kernels.tuning:_run_flash",
    oracle_ref="repro.kernels.tuning:_oracle_flash",
    tunables=(),
    bucket_fn=_flash_bucket,
    tol=5e-6,
    description="ref_flash_attention: materialized-score softmax attention",
))

register(KernelSpec(
    name="flash_attention.pallas",
    engine="flash_attention",
    impl="pallas",
    fn_ref="repro.kernels.tuning:_run_flash",
    oracle_ref="repro.kernels.tuning:_oracle_flash",
    tunables=(
        ("bq", (8, 16, 32, 64, 128, 256)),
        ("bk", (128, 256, 512)),
    ),
    defaults_fn=_flash_defaults,
    bucket_fn=_flash_bucket,
    constraint=_flash_constraint,
    cost_fn=_flash_cost,
    params_fn=_flash_params,
    tol=5e-6,
    description="online-softmax GQA attention, KV-blocked with m/l scratch",
))

# -- fastmoo: dominance counts ----------------------------------------------

register(KernelSpec(
    name="fastmoo.xla",
    engine="fastmoo",
    impl="xla",
    fn_ref="repro.kernels.tuning:_run_fastmoo",
    oracle_ref="repro.kernels.tuning:_oracle_fastmoo",
    tunables=(),
    bucket_fn=_moo_bucket,
    description="(P, P, n_obj) dominance-matrix counts (masked column sums)",
))

register(KernelSpec(
    name="fastmoo.pallas",
    engine="fastmoo",
    impl="pallas",
    fn_ref="repro.kernels.tuning:_run_fastmoo",
    oracle_ref="repro.kernels.tuning:_oracle_fastmoo",
    tunables=(
        ("tile", (8, 16, 32, 64, 128)),
        ("j_tile", (8, 16, 32, 64, 128)),
    ),
    defaults_fn=_moo_defaults,
    bucket_fn=_moo_bucket,
    constraint=_moo_constraint,
    cost_fn=_moo_cost,
    params_fn=_moo_params,
    description="tiled dominance counts, 2-D-friendly (tile, j_tile) blocks",
))
