"""Pallas tiled dominance-count kernel for the device NSGA-II engine (fastmoo).

Non-dominated sorting is the per-generation hot spot of an on-device NSGA-II:
every front-peeling round needs, for each point, the number of still-active
points that constraint-dominate it.  The naive formulation compares all pairs
at once and materializes a ``(P, P, n_obj)`` comparison tensor; this kernel
computes the same counts tile-by-tile so only a ``(Ti, Tj)`` comparison tile
ever exists at a time, mirroring ``char_kernels``/``app_kernels`` (interpret
mode is the validated CPU path, the XLA twin in ``core.fastmoo`` is the
off-TPU fast path).

Constraint domination (matching ``moo.fast_nondominated_sort``): j dominates i
iff

  * both feasible (viol <= 0) and j's objectives weakly dominate i's with at
    least one strict improvement, or
  * j is feasible and i is not, or
  * both infeasible and viol_j < viol_i.

Inputs are passed twice (row tile and column tile of the same arrays), like a
self-attention kernel:

  objs: (P, n_obj) f32,  viol: (P, 1) f32,  active: (P, 1) i32 mask -- only
  active *dominators* are counted (every row of the output is computed).

Block layout is 2-D-friendly: the comparison tile is ``(tile, j_tile)`` with
the **dominator** (j) axis innermost, so with the registry default
``j_tile=128`` every tile maps onto full TPU vector lanes instead of the
lane-hostile ``(tile, 1)`` columns of the original square tiling.  Both tile
sizes come from the kernel registry (spec ``"fastmoo.pallas"``; ``None``
resolves the bucket defaults, tuned contexts hand winners down through
``fastmoo.constraint_ranks``), as do the ``pl.CostEstimate`` and compiler
params (i is ``parallel``, j ``arbitrary``: it accumulates into a revisited
output block).

Output: (P, 1) int32 -- per-point count of active dominators.  Grid is
``(P // tile, P // j_tile)``; P must divide by both tiles (fastmoo pads with
inactive +inf-violation points, which are infeasible, inactive and never
counted).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import registry

__all__ = ["dominance_counts_pallas"]


def _kernel(oi_ref, vi_ref, oj_ref, vj_ref, aj_ref, out_ref, *, n_obj: int):
    """One (i, j) step: count active j-tile dominators of each i-tile point."""
    j = pl.program_id(1)

    vi = vi_ref[...][:, 0]                       # (Ti,)
    vj = vj_ref[...][:, 0]                       # (Tj,)
    fi = vi <= 0.0
    fj = vj <= 0.0

    le = None
    lt = None
    for k in range(n_obj):                       # static unroll over objectives
        ok_i = oi_ref[...][:, k]                 # (Ti,)
        ok_j = oj_ref[...][:, k]                 # (Tj,)
        le_k = ok_j[None, :] <= ok_i[:, None]    # (Ti, Tj): j lanes innermost
        lt_k = ok_j[None, :] < ok_i[:, None]
        le = le_k if le is None else le & le_k
        lt = lt_k if lt is None else lt | lt_k

    obj_dom = le & lt
    both_feas = fi[:, None] & fj[None, :]
    both_infeas = (~fi)[:, None] & (~fj)[None, :]
    dom = (both_feas & obj_dom)
    dom |= (~fi)[:, None] & fj[None, :]
    dom |= both_infeas & (vj[None, :] < vi[:, None])

    act = aj_ref[...][:, 0] != 0                 # (Tj,)
    part = (dom & act[None, :]).astype(jnp.int32).sum(axis=1)[:, None]

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("tile", "j_tile", "interpret"))
def dominance_counts_pallas(
    objs: jnp.ndarray,            # (P, n_obj) f32
    viol: jnp.ndarray,            # (P,) f32
    active: jnp.ndarray,          # (P,) bool/i32 -- dominators to count
    tile: int | None = None,
    j_tile: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Per-point count of active constraint-dominators: (P,) int32.

    P must divide by ``tile`` and ``j_tile`` (fastmoo pads with inactive
    +inf-violation points); ``None`` tiles resolve the registry defaults for
    this population bucket.
    """
    p, n_obj = objs.shape
    spec = registry.get("fastmoo.pallas")
    if tile is None or j_tile is None:
        tiles = spec.default_tiles(spec.bucket(p=p, n_obj=n_obj))
        tile = (tiles["tile"] if tile is None else tile)
        j_tile = (tiles["j_tile"] if j_tile is None else j_tile)
    tile, j_tile = min(tile, p), min(j_tile, p)
    assert p % tile == 0, (p, tile)
    assert p % j_tile == 0, (p, j_tile)
    v2 = viol.astype(jnp.float32).reshape(p, 1)
    a2 = active.astype(jnp.int32).reshape(p, 1)

    cost = spec.cost_estimate(p=p, n_obj=n_obj)
    params = spec.compiler_params(tile=tile, j_tile=j_tile, n_obj=n_obj)
    grid = (p // tile, p // j_tile)
    out = pl.pallas_call(
        functools.partial(_kernel, n_obj=n_obj),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, n_obj), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((j_tile, n_obj), lambda i, j: (j, 0)),
            pl.BlockSpec((j_tile, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((j_tile, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, 1), jnp.int32),
        cost_estimate=pl.CostEstimate(**cost),
        compiler_params=pltpu.TPUCompilerParams(**params),
        interpret=interpret,
    )(objs.astype(jnp.float32), v2, objs.astype(jnp.float32), v2, a2)
    return out[:, 0]
