"""Pallas tiled dominance-count kernel for the device NSGA-II engine (fastmoo).

Non-dominated sorting is the per-generation hot spot of an on-device NSGA-II:
every front-peeling round needs, for each point, the number of still-active
points that constraint-dominate it.  The naive formulation compares all pairs
at once and materializes a ``(P, P, n_obj)`` comparison tensor; this kernel
computes the same counts tile-by-tile so only a ``(Tj, Ti)`` comparison tile
ever exists at a time, mirroring ``char_kernels``/``app_kernels`` (interpret
mode is the validated CPU path, the XLA twin in ``core.fastmoo`` is the
off-TPU fast path).

Constraint domination (matching ``moo.fast_nondominated_sort``): j dominates i
iff

  * both feasible (viol <= 0) and j's objectives weakly dominate i's with at
    least one strict improvement, or
  * j is feasible and i is not, or
  * both infeasible and viol_j < viol_i.

Inputs are passed twice (row tile and column tile of the same arrays), like a
self-attention kernel:

  objs: (P, n_obj) f32,  viol: (P, 1) f32,  active: (P, 1) i32 mask -- only
  active *dominators* are counted (every row of the output is computed).

Output: (P, 1) int32 -- per-point count of active dominators.  Grid is
``(P // tile, P // tile)``; the j axis accumulates into the output block
(``@pl.when(j == 0)`` init), the standard revisiting-output reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dominance_counts_pallas"]


def _kernel(oi_ref, vi_ref, oj_ref, vj_ref, aj_ref, out_ref, *, n_obj: int):
    """One (i, j) step: count active j-tile dominators of each i-tile point."""
    j = pl.program_id(1)

    vi = vi_ref[...][:, 0]                       # (Ti,)
    vj = vj_ref[...][:, 0]                       # (Tj,)
    fi = vi <= 0.0
    fj = vj <= 0.0

    le = None
    lt = None
    for k in range(n_obj):                       # static unroll over objectives
        ok_i = oi_ref[...][:, k]                 # (Ti,)
        ok_j = oj_ref[...][:, k]                 # (Tj,)
        le_k = ok_j[:, None] <= ok_i[None, :]    # (Tj, Ti)
        lt_k = ok_j[:, None] < ok_i[None, :]
        le = le_k if le is None else le & le_k
        lt = lt_k if lt is None else lt | lt_k

    obj_dom = le & lt
    both_feas = fj[:, None] & fi[None, :]
    both_infeas = (~fj)[:, None] & (~fi)[None, :]
    dom = (both_feas & obj_dom)
    dom |= fj[:, None] & (~fi)[None, :]
    dom |= both_infeas & (vj[:, None] < vi[None, :])

    act = aj_ref[...][:, 0] != 0                 # (Tj,)
    part = (dom & act[:, None]).astype(jnp.int32).sum(axis=0)[:, None]

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def dominance_counts_pallas(
    objs: jnp.ndarray,            # (P, n_obj) f32
    viol: jnp.ndarray,            # (P,) f32
    active: jnp.ndarray,          # (P,) bool/i32 -- dominators to count
    tile: int = 64,
    interpret: bool = True,
) -> jnp.ndarray:
    """Per-point count of active constraint-dominators: (P,) int32.

    P must divide by ``tile`` (fastmoo's populations are powers of two; pad
    with inactive +inf-violation points otherwise).
    """
    p, n_obj = objs.shape
    assert p % tile == 0, (p, tile)
    v2 = viol.astype(jnp.float32).reshape(p, 1)
    a2 = active.astype(jnp.int32).reshape(p, 1)

    grid = (p // tile, p // tile)
    out = pl.pallas_call(
        functools.partial(_kernel, n_obj=n_obj),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, n_obj), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, n_obj), lambda i, j: (j, 0)),
            pl.BlockSpec((tile, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((tile, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, 1), jnp.int32),
        interpret=interpret,
    )(objs.astype(jnp.float32), v2, objs.astype(jnp.float32), v2, a2)
    return out[:, 0]
