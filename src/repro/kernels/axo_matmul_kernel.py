"""AxO matmul Pallas kernel -- the paper's operator, TPU-adapted.

An FPGA realizes the approximate multiplier in LUT fabric; a TPU's MXU only
does exact MACs.  The TPU-native decomposition (DESIGN.md §3.2) is

    T[a, b] = a*b + E[a, b]          (E = exact 2^n x 2^n error table)
    E[a, b] ~ sum_r f_r[a] * g_r[b]  (rank-R SVD of E)

so   AxO-matmul(A, B) = A.B  +  sum_r F_r(A) @ G_r(B)

where F_r(A)[m,k] = f_r[A[m,k]] is a per-element 2^n-entry table lookup.  The
correction is R extra MXU matmuls over feature maps -- systolic-friendly, no
gathers in the inner loop (the lookups hit a VMEM-resident (2^n, R) table).

Kernel: classic (M, N, K) blocked matmul; the K grid axis is innermost so the
fp32 accumulator lives in a VMEM scratch across K steps.  Block shapes come
from the kernel registry (spec ``"axo_matmul.pallas"``; ``None`` resolves the
bucket defaults, tuned contexts hand winners down through ``axo_linear`` /
``AxODeployment``), as do the ``pl.CostEstimate`` and compiler params.
Arbitrary (M, K, N) are handled by zero-padding every operand to the block
grid and slicing the output -- exact, because padded *values* and *factors*
are all zero, so padded rows/columns contribute nothing to any dot product
(decode-shaped M=4 activations included; M pads to the f32 sublane multiple
of 8, K/N to lane multiples of 128).

The bit-exact table path (a gather per (m, k, n)) exists only in ref.py as the
oracle; rank sweep accuracy is characterized by repro.axo.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import registry

__all__ = ["axo_matmul_pallas"]


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _kernel(a_ref, b_ref, fa_ref, gb_ref, o_ref, acc_ref, *, n_k: int, rank: int):
    """One (bm, bn) output tile; accumulates over the K grid axis.

    a_ref:  (bm, bk) f32   signed values of A's codes
    b_ref:  (bk, bn) f32   signed values of B's codes
    fa_ref: (R, bm, bk) f32  left error factors F_r(A), precomputed lookups
    gb_ref: (R, bk, bn) f32  right error factors G_r(B)
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    for r in range(rank):                       # static unroll: R extra matmuls
        acc = acc + jnp.dot(
            fa_ref[r], gb_ref[r], preferred_element_type=jnp.float32
        )
    acc_ref[...] += acc

    @pl.when(k_step == n_k - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret"),
)
def axo_matmul_pallas(
    a_vals: jnp.ndarray,         # (M, K) f32 signed operand values
    b_vals: jnp.ndarray,         # (K, N) f32
    fa: jnp.ndarray,             # (R, M, K) f32 left error factors
    gb: jnp.ndarray,             # (R, K, N) f32 right error factors
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Blocked AxO matmul; see module docstring.  Returns (M, N) f32."""
    m, k = a_vals.shape
    n = b_vals.shape[1]
    rank = fa.shape[0]
    spec = registry.get("axo_matmul.pallas")
    if bm is None or bn is None or bk is None:
        d = spec.default_tiles(spec.bucket(m=m, k=k, n=n, rank=rank))
        bm = d["bm"] if bm is None else bm
        bn = d["bn"] if bn is None else bn
        bk = d["bk"] if bk is None else bk
    # shrink blocks to the padded problem, never below the f32 min tile (8, 128)
    bm = max(8, min(bm, _round_up(m, 8)))
    bn = max(128, min(bn, _round_up(n, 128)))
    bk = max(128, min(bk, _round_up(k, 128)))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    # static-shape property, so recording at trace time covers every dispatch
    # of this shape; the fraction of the padded (M, N, K) iteration space
    # spent multiplying zeros
    from ..obs.telemetry import record_pad_waste

    record_pad_waste("axo_matmul", (m, n, k), (mp, np_, kp))
    if (mp, np_, kp) != (m, n, k):
        # exact: padded values and factors are zero, contributing 0 products
        a_vals = jnp.pad(a_vals, ((0, mp - m), (0, kp - k)))
        b_vals = jnp.pad(b_vals, ((0, kp - k), (0, np_ - n)))
        fa = jnp.pad(fa, ((0, 0), (0, mp - m), (0, kp - k)))
        gb = jnp.pad(gb, ((0, 0), (0, kp - k), (0, np_ - n)))
    n_k = kp // bk

    cost = spec.cost_estimate(m=mp, k=kp, n=np_, rank=rank)
    params = spec.compiler_params(bm=bm, bn=bn, bk=bk, rank=rank)
    grid = (mp // bm, np_ // bn, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, rank=rank),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((rank, bm, bk), lambda i, j, kk: (0, i, kk)),
            pl.BlockSpec((rank, bk, bn), lambda i, j, kk: (0, kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        cost_estimate=pl.CostEstimate(**cost),
        compiler_params=pltpu.TPUCompilerParams(**params),
        interpret=interpret,
    )(a_vals, b_vals, fa, gb)
    return out if (mp, np_) == (m, n) else out[:m, :n]
