"""Block-shape autotuner for the kernel registry (``kernels.registry``).

Every registered kernel declares a tunable tile space; this module searches
that space per ``(shape bucket, backend, device kind)`` and persists winners
to an on-disk JSON cache, so a context constructed with
``ExecutionContext(tuning="cached")`` pays the search once per device and
every later process reuses the tuned tiles with zero re-searches.

Policies (the context's ``tuning`` field):

  * ``"off"``    -- registry defaults (the int32-safe shapes), never touches
                    the cache.  The historical behavior.
  * ``"cached"`` -- use the cached winner for this (kernel, device, bucket);
                    on a miss, search once and persist.
  * ``"search"`` -- ignore any persisted winner: re-search once per process
                    per bucket and overwrite the cache.

The search is correctness-gated: every candidate runs under Pallas interpret
mode (for ``impl="pallas"``) against the spec's oracle before it is timed,
and a candidate that is not **bit-identical** on the integer channels (and
~1e-6-close on the one f32 channel of the char engine) is discarded.  On
CPU-only hosts the Pallas timings are interpret-mode (a correctness proxy,
not TPU performance -- the cache is keyed by device kind precisely so a TPU
host re-tunes with real timings).

``tiles_for`` is the engine-facing entry point: ``fastchar.behav_partials``,
``fastapp.table_matmul_jax`` and ``fastmoo.constraint_ranks`` all resolve
their block shapes through it instead of module constants.
"""

from __future__ import annotations

import functools
import json
import logging
import os
import time
from collections.abc import MutableMapping

import numpy as np

from . import registry
from ..obs import telemetry as obs

logger = logging.getLogger("repro.kernels.tuning")

__all__ = [
    "TUNING_POLICIES",
    "TuningCache",
    "default_cache",
    "device_key",
    "tiles_for",
    "autotune",
    "run_case",
    "oracle_case",
    "cache_status",
    "STATS",
    "reset_stats",
]

TUNING_POLICIES = ("off", "cached", "search")


class _StatsView(MutableMapping):
    """Back-compat alias for the old module-global ``STATS`` dict.

    The real counters now live on the process-wide telemetry aggregate
    (``repro.obs.GLOBAL``) under the ``tuning.*`` names below; this view
    keeps ``STATS["searches"]``-style reads/writes (and the tests built on
    them) working unchanged.  New code should read the telemetry counters.
    """

    _KEYS = {
        "searches": "tuning.search",
        "cache_hits": "tuning.cache_hit",
        "candidates_timed": "tuning.candidate_timed",
    }

    def __getitem__(self, key: str) -> int:
        return obs.GLOBAL.counter(self._KEYS[key])

    def __setitem__(self, key: str, value: int) -> None:
        obs.GLOBAL.set_counter(self._KEYS[key], value)

    def __delitem__(self, key: str) -> None:
        raise TypeError("STATS keys are fixed")

    def __iter__(self):
        return iter(self._KEYS)

    def __len__(self) -> int:
        return len(self._KEYS)

    def __repr__(self) -> str:
        return repr(dict(self))


# Process-wide tuning telemetry (tests assert "zero re-searches" through it);
# a live view over the repro.obs.GLOBAL counters, not a plain dict.
STATS = _StatsView()

# In-process memo of resolved tiles: engines call tiles_for on every dispatch
# (table_matmul_jax per config chunk), so both the JSON re-read of "cached"
# and the full candidate sweep of "search" must happen at most once per
# (kernel, device, bucket) per process.  "search" still ignores any *on-disk*
# winner -- a fresh process re-tunes -- which is the policy's contract.
_MEMO: dict[str, dict] = {}

# Harness input caps: parity holds at any size, and off-TPU Pallas timings
# are interpret-mode anyway, so Pallas search inputs are bucket-shaped but
# bounded (interpret executes one python step per grid cell); the XLA twins
# are cheap to time at their real bucket sizes.
_MAX_CHAR_D = {"pallas": 64, "xla": 256, "entry": 256, "entry_pallas": 64}
_MAX_APP_D = {"pallas": 8, "xla": 64, "gemm": 8, "entry": 64, "entry_pallas": 8}
_MAX_APP_MKN = (64, 256, 64)
_MAX_MOO_P = 128
_MAX_AXO_MKN = (32, 192, 160)
_MAX_AXO_RANK = 8
_MAX_FLASH_SHD = (64, 192, 64)
_TIMING_REPS = 3


def reset_stats() -> None:
    """Zero the tuning counters and drop the in-process tile memo (the tests'
    stand-in for starting a fresh process against the same disk cache)."""
    for k in STATS:
        STATS[k] = 0
    obs.GLOBAL.set_counter("tuning.cache_miss", 0)
    obs.GLOBAL.set_counter("tuning.cache_corrupt", 0)
    _MEMO.clear()


# ---------------------------------------------------------------------------
# On-disk cache (keyed by device kind)
# ---------------------------------------------------------------------------


def device_key() -> str:
    """``<backend>:<device kind>`` of the default JAX device, fs-sanitized."""
    import jax

    kind = jax.devices()[0].device_kind.replace(" ", "_").replace("/", "_")
    return f"{jax.default_backend()}:{kind}"


class TuningCache:
    """JSON tile cache: ``{cache key: {"tiles": {...}, meta...}}`` per device.

    Writes are atomic (tmp + replace) so concurrent tuners at worst lose a
    record, never corrupt the file.  The in-memory copy makes repeat lookups
    free within a process; a fresh process re-reads the file (that is the
    cross-run round-trip the tests assert).
    """

    def __init__(self, path: str) -> None:
        self.path = path
        self._data: dict | None = None

    def _load(self) -> dict:
        if self._data is None:
            try:
                with open(self.path) as f:
                    self._data = json.load(f)
            except FileNotFoundError:
                self._data = {}  # first run on this device: normal
            except (OSError, ValueError) as exc:
                # an existing-but-unreadable cache silently degraded to
                # "re-tune everything" before; surface it (the re-tune still
                # happens, so this stays a warning, not an error)
                logger.warning(
                    "tuning cache %s unreadable (%s: %s) -- ignoring it and "
                    "re-tuning", self.path, type(exc).__name__, exc,
                )
                obs.current().count("tuning.cache_corrupt")
                self._data = {}
        return self._data

    def get(self, key: str) -> dict | None:
        return self._load().get(key)

    def put(self, key: str, record: dict) -> None:
        data = self._load()
        data[key] = record
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)


def cache_dir() -> str:
    return os.environ.get(
        "REPRO_TUNING_CACHE", os.path.join("experiments", "cache", "kernel_tuning")
    )


def default_cache() -> TuningCache:
    """The per-device cache file under ``REPRO_TUNING_CACHE`` (env-overridable)."""
    fname = device_key().replace(":", "_") + ".json"
    return TuningCache(os.path.join(cache_dir(), fname))


def _cache_key(spec: registry.KernelSpec, bucket) -> str:
    return f"{spec.name}|{device_key()}|{'x'.join(str(b) for b in bucket)}"


def cache_status(cache: TuningCache | None = None) -> dict:
    """Health snapshot of the on-disk tuning cache (``/healthz`` payload).

    Reports the per-device cache path, whether it exists on disk, how many
    tuned winners it holds, and the process's cache-traffic counters.  Never
    raises: a corrupt or unreadable cache reads as zero entries (the same
    recovery `_load` applies), and a JAX-less process reports the device key
    as unavailable.
    """
    try:
        cache = cache or default_cache()
        path = cache.path
        entries = len(cache._load())
        exists = os.path.exists(path)
    except Exception as exc:  # no jax / no device: still a valid health answer
        return {"ok": False, "error": f"{type(exc).__name__}: {exc}"}
    return {
        "ok": True,
        "path": path,
        "exists": exists,
        "entries": entries,
        "hits": obs.GLOBAL.counter("tuning.cache_hit"),
        "misses": obs.GLOBAL.counter("tuning.cache_miss"),
        "searches": obs.GLOBAL.counter("tuning.search"),
        "corrupt": obs.GLOBAL.counter("tuning.cache_corrupt"),
    }


# ---------------------------------------------------------------------------
# Per-engine search harnesses (deterministic bucket-shaped cases)
# ---------------------------------------------------------------------------
#
# Each harness returns ``(exact, close)``: tuples of numpy arrays that must be
# bit-identical / ~1e-6-close to the oracle's.  The registry's fn_ref/
# oracle_ref point here so the specs stay importable without JAX.


def _char_case(bucket, impl):
    from repro.core.operator_model import spec_for

    n_bits, d = bucket
    spec = spec_for(n_bits)
    d = min(d, _MAX_CHAR_D[impl])
    rng = np.random.default_rng(n_bits * 1000 + d)
    cfgs = rng.integers(0, 2, (d, spec.n_luts)).astype(np.uint8)
    cfgs[0] = 0
    cfgs[-1] = 1
    return spec, cfgs


def _run_fastchar(spec_reg, bucket, tiles):
    from repro.core.fastchar import behav_metrics_jax

    spec, cfgs = _char_case(bucket, spec_reg.impl)
    out = behav_metrics_jax(
        spec, cfgs, impl=spec_reg.impl,
        a_tile=tiles["a_tile"], d_block=tiles["d_block"],
    )
    return (
        (out["AVG_ABS_ERR"], out["PROB_ERR"], out["MAX_ABS_ERR"], out["MSE"]),
        (out["AVG_ABS_REL_ERR"],),
    )


def _oracle_fastchar(spec_reg, bucket):
    from repro.core.metrics import behav_metrics

    spec, cfgs = _char_case(bucket, spec_reg.impl)
    out = behav_metrics(spec, cfgs)
    return (
        (out["AVG_ABS_ERR"], out["PROB_ERR"], out["MAX_ABS_ERR"], out["MSE"]),
        (out["AVG_ABS_REL_ERR"],),
    )


def _app_case(bucket, impl):
    from repro.core.operator_model import spec_for

    n_bits, d, m, k, n = bucket
    spec = spec_for(n_bits)
    d = min(d, _MAX_APP_D[impl])  # d_chunk candidates must stay discriminable
    m, k, n = (min(x, cap) for x, cap in zip((m, k, n), _MAX_APP_MKN))
    rng = np.random.default_rng(n_bits * 100 + m + k + n)
    cfgs = rng.integers(0, 2, (d, spec.n_luts)).astype(np.uint8)
    a = rng.integers(0, spec.n_inputs, (m, k)).astype(np.int32)
    b = rng.integers(0, spec.n_inputs, (k, n)).astype(np.int32)
    return spec, cfgs, a, b


def _run_fastapp(spec_reg, bucket, tiles):
    from repro.apps.fastapp import table_batch, table_matmul_jax

    spec, cfgs, a, b = _app_case(bucket, spec_reg.impl)
    batch = table_batch(spec, cfgs)
    out = table_matmul_jax(
        batch, a, b, impl=spec_reg.impl,
        d_chunk=tiles.get("d_chunk", 8), k_tile=tiles.get("k_tile"),
    )
    return ((np.asarray(out, np.int64),), ())


def _oracle_fastapp(spec_reg, bucket):
    from repro.apps.base import table_matmul
    from repro.core.operator_model import product_tables

    spec, cfgs, a, b = _app_case(bucket, spec_reg.impl)
    tables = product_tables(spec, cfgs)
    out = np.stack([table_matmul(t, a, b) for t in tables])
    return ((out,), ())


def _awkward(x: int, cap: int) -> int:
    """Bucket-shaped but deliberately non-divisible case size (pad coverage)."""
    x = min(x, cap)
    return x - x // 8 if x > 8 else x


@functools.lru_cache(maxsize=None)
def _axo_factors(rank):
    from repro.core.operator_model import error_tables, spec_for

    spec = spec_for(8)
    rng = np.random.default_rng(11)
    cfg = rng.integers(0, 2, spec.n_luts).astype(np.uint8)
    err = error_tables(spec, cfg[None])[0].astype(np.float64)
    u, s, vt = np.linalg.svd(err)
    f = (u[:, :rank] * s[:rank]).astype(np.float32)
    g = vt[:rank].T.astype(np.float32)
    return spec, f, g


def _axo_case(bucket):
    m, k, n, rank = bucket
    m, k, n = (_awkward(x, cap) for x, cap in zip((m, k, n), _MAX_AXO_MKN))
    rank = min(rank, _MAX_AXO_RANK)
    spec, f, g = _axo_factors(rank)
    rng = np.random.default_rng(m + 3 * k + 7 * n + rank)
    a = rng.integers(0, spec.n_inputs, (m, k)).astype(np.int32)
    b = rng.integers(0, spec.n_inputs, (k, n)).astype(np.int32)
    # outputs are O(k * qmax^2); normalize so the spec tol gates relative error
    scale = float(k) * 127.0 * 127.0
    return spec, f, g, a, b, scale


def _run_axo(spec_reg, bucket, tiles):
    import jax.numpy as jnp

    spec, f, g, a, b, scale = _axo_case(bucket)
    sv = jnp.asarray(spec.operand_values, jnp.float32)
    args = (jnp.asarray(a), jnp.asarray(b), jnp.asarray(f), jnp.asarray(g), sv)
    if spec_reg.impl == "pallas":
        from .ops import axo_matmul

        out = axo_matmul(*args, **tiles)
    else:
        from .ref import ref_axo_matmul_lowrank

        out = ref_axo_matmul_lowrank(*args)
    return ((), (np.asarray(out, np.float64) / scale,))


def _oracle_axo(spec_reg, bucket):
    spec, f, g, a, b, scale = _axo_case(bucket)
    sv = np.asarray(spec.operand_values, np.float64)
    out = sv[a] @ sv[b]
    out += np.einsum("mkr,knr->mn", f.astype(np.float64)[a],
                     g.astype(np.float64)[b])
    return ((), (out / scale,))


def _flash_case(bucket):
    sq, skv, hd = bucket
    sq, skv, hd = (_awkward(x, cap)
                   for x, cap in zip((sq, skv, hd), _MAX_FLASH_SHD))
    causal = sq == skv  # causal masking assumes aligned q/k positions
    b, h, g = 1, 2, 1
    rng = np.random.default_rng(sq + 3 * skv + 7 * hd)
    q = rng.standard_normal((b, h, sq, hd)).astype(np.float32)
    k = rng.standard_normal((b, g, skv, hd)).astype(np.float32)
    v = rng.standard_normal((b, g, skv, hd)).astype(np.float32)
    return q, k, v, causal


def _run_flash(spec_reg, bucket, tiles):
    import jax.numpy as jnp

    q, k, v, causal = _flash_case(bucket)
    args = (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    if spec_reg.impl == "pallas":
        from .ops import flash_attention

        out = flash_attention(*args, causal=causal, **tiles)
    else:
        from .ref import ref_flash_attention

        out = ref_flash_attention(*args, causal=causal)
    return ((), (np.asarray(out, np.float64),))


def _oracle_flash(spec_reg, bucket):
    q, k, v, causal = _flash_case(bucket)
    qf, kf, vf = (x.astype(np.float64) for x in (q, k, v))
    rep = qf.shape[1] // kf.shape[1]
    kf = np.repeat(kf, rep, axis=1)
    vf = np.repeat(vf, rep, axis=1)
    sq, hd = qf.shape[2], qf.shape[3]
    s = np.einsum("bhqd,bhkd->bhqk", qf, kf) / np.sqrt(hd)
    if causal:
        skv = kf.shape[2]
        s = np.where(np.arange(sq)[:, None] >= np.arange(skv)[None, :],
                     s, -np.inf)
    s -= s.max(-1, keepdims=True)
    p = np.exp(s)
    p /= p.sum(-1, keepdims=True)
    return ((), (np.einsum("bhqk,bhkd->bhqd", p, vf),))


def _moo_case(bucket):
    p, n_obj = bucket
    p = min(p, _MAX_MOO_P)
    rng = np.random.default_rng(p * 10 + n_obj)
    objs = rng.standard_normal((p, n_obj)).astype(np.float32)
    viol = np.where(
        rng.uniform(size=p) < 0.5, 0.0, rng.uniform(0.1, 2.0, size=p)
    ).astype(np.float32)
    active = rng.uniform(size=p) < 0.8
    return objs, viol, active


def _run_fastmoo(spec_reg, bucket, tiles):
    import jax.numpy as jnp

    objs, viol, active = _moo_case(bucket)
    if spec_reg.impl == "pallas":
        from .moo_kernels import dominance_counts_pallas
        from .ops import on_tpu

        tile, j_tile = tiles["tile"], tiles["j_tile"]
        p = objs.shape[0]
        step = max(tile, j_tile)
        pad = (-p) % step
        if pad:
            objs = np.concatenate([objs, np.zeros((pad, objs.shape[1]), objs.dtype)])
            viol = np.concatenate([viol, np.full(pad, np.inf, viol.dtype)])
            active = np.concatenate([active, np.zeros(pad, bool)])
        out = dominance_counts_pallas(
            jnp.asarray(objs), jnp.asarray(viol), jnp.asarray(active),
            tile=min(tile, objs.shape[0]), j_tile=min(j_tile, objs.shape[0]),
            interpret=not on_tpu(),
        )[:p]
    else:
        from repro.core.fastmoo import dominance_matrix

        dom = dominance_matrix(jnp.asarray(objs), jnp.asarray(viol))
        out = (np.asarray(dom) & active[:, None]).sum(axis=0)
    return ((np.asarray(out, np.int64),), ())


def _oracle_fastmoo(spec_reg, bucket):
    objs, viol, active = _moo_case(bucket)
    p = objs.shape[0]
    feas = viol <= 0
    dom = np.zeros((p, p), bool)  # [i, j] = i constraint-dominates j
    le = (objs[:, None, :] <= objs[None, :, :]).all(-1)
    lt = (objs[:, None, :] < objs[None, :, :]).any(-1)
    dom |= (feas[:, None] & feas[None, :]) & le & lt
    dom |= feas[:, None] & ~feas[None, :]
    dom |= (~feas[:, None] & ~feas[None, :]) & (viol[:, None] < viol[None, :])
    return (((dom & active[:, None]).sum(axis=0).astype(np.int64),), ())


def run_case(spec: registry.KernelSpec, bucket, tiles) -> tuple:
    """Run the spec's deterministic bucket case with candidate ``tiles``."""
    return spec.fn(spec, bucket, tiles)


def oracle_case(spec: registry.KernelSpec, bucket) -> tuple:
    """The oracle's outputs for the same deterministic bucket case."""
    return spec.oracle(spec, bucket)


def parity_ok(spec: registry.KernelSpec, bucket, tiles, oracle=None) -> bool:
    """Candidate parity gate: integer channels bit-identical, float channels
    within the spec's ``tol`` (rtol and atol)."""
    exact_o, close_o = oracle if oracle is not None else oracle_case(spec, bucket)
    exact_r, close_r = run_case(spec, bucket, tiles)
    for r, o in zip(exact_r, exact_o):
        if not np.array_equal(np.asarray(r), np.asarray(o)):
            return False
    for r, o in zip(close_r, close_o):
        if not np.allclose(np.asarray(r), np.asarray(o),
                           rtol=spec.tol, atol=spec.tol):
            return False
    return True


# ---------------------------------------------------------------------------
# The search
# ---------------------------------------------------------------------------


def autotune(spec: registry.KernelSpec, bucket) -> dict:
    """Search the spec's admissible tile space for ``bucket``.

    Every candidate is parity-gated against the oracle, then timed
    (best-of-N, post-warmup).  Returns the cache record::

        {"tiles": {...}, "us": float, "device": str, "candidates": int,
         "rejected": int, "timings": {"a_tile=..,d_block=..": us, ...}}
    """
    obs.current().count("tuning.search")
    cands = spec.candidates(bucket)
    if not cands:
        return {"tiles": spec.default_tiles(bucket), "us": None,
                "device": device_key(), "candidates": 0, "rejected": 0,
                "timings": {}}
    oracle = oracle_case(spec, bucket)
    timings: dict[str, float] = {}
    best_tiles, best_us, rejected = None, float("inf"), 0
    for tiles in cands:
        if not parity_ok(spec, bucket, tiles, oracle=oracle):
            rejected += 1
            continue
        run_case(spec, bucket, tiles)  # warm the jit cache at this shape
        us = float("inf")
        for _ in range(_TIMING_REPS):
            t0 = time.perf_counter()
            run_case(spec, bucket, tiles)
            us = min(us, (time.perf_counter() - t0) * 1e6)
        obs.current().count("tuning.candidate_timed")
        label = ",".join(f"{k}={v}" for k, v in tiles.items())
        timings[label] = round(us, 1)
        if us < best_us:
            best_tiles, best_us = tiles, us
    if best_tiles is None:  # every candidate failed parity: keep safe defaults
        return {"tiles": spec.default_tiles(bucket), "us": None,
                "device": device_key(), "candidates": len(cands),
                "rejected": rejected, "timings": timings}
    return {"tiles": best_tiles, "us": round(best_us, 1),
            "device": device_key(), "candidates": len(cands),
            "rejected": rejected, "timings": timings}


def tiles_for(ctx, name: str, cache: TuningCache | None = None, **shape) -> dict:
    """Resolve the block shapes of kernel ``name`` for ``shape`` under ``ctx``.

    ``ctx`` is an ``ExecutionContext`` or None (None / ``tuning="off"`` ->
    registry defaults).  Engines call this at dispatch time (host python, not
    inside a trace -- a ``tuning="search"`` policy launches kernels).
    """
    spec = registry.get(name)
    bucket = spec.bucket(**shape)
    tel = obs.of(ctx)
    tel.count(f"registry.dispatch.{name}")
    if not spec.tunables:
        return {}
    policy = getattr(ctx, "tuning", None) or "off"
    if policy not in TUNING_POLICIES:
        raise ValueError(f"unknown tuning policy {policy!r}")
    if policy == "off":
        return spec.default_tiles(bucket)
    key = _cache_key(spec, bucket)
    memo_key = f"{policy}|{key}" if cache is None else None
    if memo_key is not None and memo_key in _MEMO:
        return dict(_MEMO[memo_key])
    cache = cache or default_cache()
    if policy == "cached":
        rec = cache.get(key)
        if rec is not None:
            tel.count("tuning.cache_hit")
            tiles = dict(rec["tiles"])
            if memo_key is not None:
                _MEMO[memo_key] = tiles
            return dict(tiles)
        tel.count("tuning.cache_miss")
    with tel.span(f"tuning.autotune.{name}", bucket=list(bucket)), obs.use(tel):
        rec = autotune(spec, bucket)
    cache.put(key, rec)
    tiles = dict(rec["tiles"])
    if memo_key is not None:
        _MEMO[memo_key] = tiles
    return dict(tiles)
