"""AdamW with fp32 moments (params may be bf16); decoupled weight decay."""

from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp

from ..models.spec import ParamSpec
from .base import Optimizer

__all__ = ["adamw"]


def adamw(
    lr_fn,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def one(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / bc1
            vh = v / bc2
            upd = -lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))
            return upd, m, v

        flat, tdef = jax.tree.flatten(params)
        gs = tdef.flatten_up_to(grads)
        ms = tdef.flatten_up_to(state["m"])
        vs = tdef.flatten_up_to(state["v"])
        out = [one(g, m, v, p) for g, m, v, p in zip(gs, ms, vs, flat)]
        upds = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return upds, {"m": new_m, "v": new_v}

    def state_spec(spec_tree):
        f32 = lambda s: replace(s, init="zeros", dtype="float32")
        return {
            "m": jax.tree.map(f32, spec_tree, is_leaf=lambda s: isinstance(s, ParamSpec)),
            "v": jax.tree.map(f32, spec_tree, is_leaf=lambda s: isinstance(s, ParamSpec)),
        }

    return Optimizer(init=init, update=update, state_spec=state_spec)
