"""Optimizer interface: pure functions over pytrees + state-spec derivation."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from ..models.spec import ParamSpec

__all__ = ["Optimizer", "apply_updates"]


@dataclass(frozen=True)
class Optimizer:
    """init(params) -> state;  update(grads, state, params, step) -> (updates, state).

    ``updates`` are deltas to *add* to params.  ``state_spec(param_spec_tree)``
    mirrors the state tree with ParamSpec leaves so shardings/abstract values can
    be derived without allocating (dry-run path).
    """

    init: Callable
    update: Callable
    state_spec: Callable


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)
