"""LR schedules (pure functions of the step scalar)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_schedule"]


def cosine_schedule(
    peak_lr: float,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    min_ratio: float = 0.1,
):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * (step + 1) / max(warmup_steps, 1)
        frac = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr
