"""Optimizers and distributed-training numerics.

Minimal optax-like interface over raw pytrees, plus a ``state_spec`` hook so the
launcher can derive optimizer-state shardings the same way it derives parameter
shardings (required to dry-run lower a full train step without allocation).
"""

from .adamw import adamw
from .adafactor import adafactor
from .base import Optimizer, apply_updates
from .clip import clip_by_global_norm, global_norm
from .compress import compress_int8, decompress_int8, compressed_psum
from .schedule import cosine_schedule

__all__ = [
    "Optimizer",
    "adamw",
    "adafactor",
    "apply_updates",
    "clip_by_global_norm",
    "global_norm",
    "cosine_schedule",
    "compress_int8",
    "decompress_int8",
    "compressed_psum",
    "make_optimizer",
]


def make_optimizer(name: str, lr_fn, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr_fn, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
