"""int8 gradient compression with error feedback.

Two deployments:

* **Accumulator compression** (pjit path): microbatch gradient-accumulation
  buffers are stored int8 + per-tensor scale with a local error-feedback
  residual -- 4x less accumulator HBM than fp32 and bounded bias (the residual
  re-enters the next microbatch).
* **``compressed_psum``** (shard_map path): a two-phase collective for explicit
  data-parallel reductions -- psum the per-shard absmax (tiny), quantize with
  the shared global scale, psum int32, dequantize.  Exact w.r.t. the shared
  scale; quantization error is returned so callers keep it as error feedback.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "compressed_psum"]


def compress_int8(x: jnp.ndarray, error: jnp.ndarray | None = None):
    """x (+ carried error) -> (q int8, scale f32 scalar, new_error f32)."""
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_error = xf - q.astype(jnp.float32) * scale
    return q, scale, new_error


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name: str):
    """int8-compressed psum for use inside shard_map.

    Returns (reduced fp32 tensor, local quantization error for error feedback).
    Wire format per element: 1 byte (int8) instead of 4 (fp32), plus one scalar.
    """
    xf = x.astype(jnp.float32)
    gmax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
    scale = jnp.maximum(gmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    err = xf - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale, err
