"""Adafactor (factored second moments) -- the only optimizer whose state fits
the 671B/1T archs on a 256-chip pod (see DESIGN.md §5 memory honesty).

Matrices (ndim >= 2) store row/col second-moment factors over the last two
dims; vectors store the full second moment.  First moment omitted (beta1=0),
update clipping by RMS as in the paper (Shazeer & Stern, 2018).
"""

from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp

from ..models.spec import ParamSpec
from .base import Optimizer

__all__ = ["adafactor"]


def _is_factored(shape: tuple[int, ...]) -> bool:
    return len(shape) >= 2


def adafactor(
    lr_fn,
    decay: float = 0.99,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        def one(p):
            if _is_factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(one, params)

    def update(grads, state, params, step):
        lr = lr_fn(step)
        # step-dependent decay as in the paper: min(decay, 1 - step^-0.8)
        t = (step + 1).astype(jnp.float32)
        beta = jnp.minimum(decay, 1.0 - t ** -0.8)

        def one(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _is_factored(g.shape):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                # rank-1 reconstruction of the second moment
                denom = vr[..., :, None] * vc[..., None, :] / jnp.maximum(
                    vr.mean(axis=-1)[..., None, None], eps
                )
                upd = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(upd * upd) + eps)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            upd = -lr * (upd + weight_decay * p.astype(jnp.float32))
            return upd, new_s

        flat, tdef = jax.tree.flatten(params)
        gs = tdef.flatten_up_to(grads)
        ss = tdef.flatten_up_to(state)
        out = [one(g, s, p) for g, s, p in zip(gs, ss, flat)]
        return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])

    def state_spec(spec_tree):
        def one(s: ParamSpec):
            if _is_factored(s.shape):
                return {
                    "vr": ParamSpec(s.shape[:-1], s.axes[:-1], init="zeros", dtype="float32"),
                    "vc": ParamSpec(s.shape[:-2] + s.shape[-1:], s.axes[:-2] + s.axes[-1:],
                                    init="zeros", dtype="float32"),
                }
            return {"v": ParamSpec(s.shape, s.axes, init="zeros", dtype="float32")}

        return jax.tree.map(one, spec_tree, is_leaf=lambda s: isinstance(s, ParamSpec))

    return Optimizer(init=init, update=update, state_spec=state_spec)
