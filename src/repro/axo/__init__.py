from .deploy import AxOOperator, axo_linear, quantize_tensor

__all__ = ["AxOOperator", "axo_linear", "quantize_tensor"]
