from .deploy import (
    AXO_LAYERS,
    AxODeployment,
    AxOOperator,
    axo_linear,
    deploy_axo,
    quantize_tensor,
)

__all__ = [
    "AXO_LAYERS",
    "AxODeployment",
    "AxOOperator",
    "axo_linear",
    "deploy_axo",
    "quantize_tensor",
]
