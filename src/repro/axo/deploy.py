"""AxO deployment: run LM linear layers on a DSE-selected approximate operator.

The bridge from the paper's DSE output (a LUT config) to the framework's
serving path:

  1. ``AxOOperator.from_config``: behavioral-model product table -> error table
     ``E = T - ab`` -> rank-R SVD factors ``(f, g)`` + the signed-value table.
     R is a quality knob characterized with the same BEHAV metrics as the
     operator itself (``rank_behav``).
  2. ``axo_linear``: per-tensor symmetric int8 quantization of activations and
     weights, then the AxO matmul -- the Pallas kernel (registry-tiled, padded
     to blocks for arbitrary shapes), or its jnp reference (identical math) --
     and dequantization.
  3. ``deploy_axo``: walk a model's param tree and build an
     :class:`AxODeployment` -- per-layer **cached** weight codes/scales and
     pre-gathered ``G_r(W)`` factors for every attention q/k/v/o, MLP and MoE
     expert projection (plus the LM head), so decode steps never requantize or
     re-gather weights per token.  The deployment threads through
     ``models.model.forward(axo=...)`` and the ``launch.steps`` builders.

The bit-exact table path (exhaustive gather) stays available for validation;
production uses the rank-R MXU path (DESIGN.md §3.2).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.operator_model import (
    OperatorSpec,
    error_tables,
    exact_product_table,
    product_tables,
    spec_for,
)
from ..kernels import ops
from ..kernels import ref as kref
from ..kernels.axo_matmul_kernel import axo_matmul_pallas
from ..kernels.tuning import tiles_for
from ..obs import telemetry as obs

__all__ = [
    "AxOOperator",
    "AxODeployment",
    "AXO_LAYERS",
    "quantize_tensor",
    "axo_linear",
    "deploy_axo",
]


@dataclass(frozen=True)
class AxOOperator:
    """A deployable approximate multiplier: rank-R factorized error tables."""

    n_bits: int
    rank: int
    f_table: np.ndarray          # (2^n, R) float32
    g_table: np.ndarray          # (2^n, R) float32
    signed_vals: np.ndarray      # (2^n,) int32
    table: np.ndarray            # (2^n, 2^n) int32 exact approximate products

    @staticmethod
    def from_config(config: np.ndarray, rank: int = 8, n_bits: int = 8) -> "AxOOperator":
        spec = spec_for(n_bits)
        table = product_tables(spec, np.asarray(config)[None])[0]
        err = error_tables(spec, np.asarray(config)[None])[0].astype(np.float64)
        u, s, vt = np.linalg.svd(err)
        r = min(rank, len(s))
        f = (u[:, :r] * s[:r]).astype(np.float32)
        g = vt[:r].T.astype(np.float32)
        return AxOOperator(
            n_bits=n_bits, rank=r, f_table=f, g_table=g,
            signed_vals=spec.operand_values.astype(np.int32), table=table,
        )

    # -- quality of the rank knob --------------------------------------------

    def rank_table(self) -> np.ndarray:
        """Rank-R reconstruction of the product table (float)."""
        exact = exact_product_table(self.n_bits).astype(np.float64)
        return exact + self.f_table.astype(np.float64) @ self.g_table.astype(np.float64).T

    def rank_behav(self) -> dict:
        """BEHAV metrics of the rank-R approximation vs the TRUE operator table
        (how much fidelity the factorization itself costs)."""
        t_true = self.table.astype(np.float64)
        t_rank = self.rank_table()
        d = np.abs(t_rank - t_true)
        exact = np.maximum(np.abs(exact_product_table(self.n_bits)), 1).astype(np.float64)
        return {
            "AVG_ABS_ERR": float(d.mean()),
            "AVG_ABS_REL_ERR": float(100.0 * (d / exact).mean()),
            "MAX_ABS_ERR": float(d.max()),
        }


def quantize_tensor(x: jnp.ndarray, n_bits: int = 8) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8-style quantization -> (codes, scale).

    Codes are already masked into table-index (two's complement) space.
    """
    qmax = (1 << (n_bits - 1)) - 1
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int32)
    return q & ((1 << n_bits) - 1), scale


def axo_linear(
    x: jnp.ndarray,              # (..., K) float activations
    w: jnp.ndarray,              # (K, N) float weights
    op: AxOOperator,
    use_kernel: bool = True,
    ctx=None,                    # optional dse.context.ExecutionContext
) -> jnp.ndarray:
    """y = x @ w evaluated through the approximate operator's arithmetic.

    The kernel path handles *arbitrary* shapes: the Pallas wrapper pads every
    operand to the registry-selected block grid and slices the output (the old
    ``% 128`` gate silently demoted decode-shaped inputs -- M=4, or any
    head_dim < 128 -- to the slow reference path).  ``ctx`` may override the
    impl via its kernel menu and supplies tuned tiles through ``tiles_for``.
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[1]
    xq, sx = quantize_tensor(x.reshape(-1, k), op.n_bits)
    wq, sw = quantize_tensor(w, op.n_bits)
    f = jnp.asarray(op.f_table)
    g = jnp.asarray(op.g_table)
    sv = jnp.asarray(op.signed_vals, jnp.float32)
    impl = "pallas" if use_kernel else "xla"
    if ctx is not None:
        impl = ctx.resolve_impl("axo_matmul", impl)
    # trace-time resolution count: one per (re)trace per call site, the
    # serving-path analogue of the registry dispatch counters
    obs.of(ctx).count(f"dispatch.axo_linear.{impl}")
    if impl == "pallas":
        tiles = tiles_for(ctx, "axo_matmul.pallas",
                          m=xq.shape[0], k=k, n=n, rank=op.rank)
        y = ops.axo_matmul(xq, wq, f, g, sv, **tiles)
    else:
        y = kref.ref_axo_matmul_lowrank(xq, wq, f, g, sv)
    return (y * (sx * sw)).reshape(*lead, n).astype(x.dtype)


# ---------------------------------------------------------------------------
# Whole-model deployment
# ---------------------------------------------------------------------------

#: parts of the network ``deploy_axo`` can swap onto the approximate operator
AXO_LAYERS = ("attn", "mlp", "moe", "head")


@dataclass(frozen=True)
class AxODeployment:
    """DSE-selected operator deployed into every linear layer of a model.

    Weights are quantized ONCE at deploy time: each entry caches the weight's
    signed value matrix ``bv = signed_vals[Wq]`` (K, N), the pre-gathered
    right factors ``gb = G_r(Wq)`` (R, K, N) and the weight scale -- decode
    steps only quantize the (tiny) activation and gather its left factors.
    Entries for stacked layers carry a leading ``repeats`` axis so they ride
    through ``jax.lax.scan`` next to the params.

    ``stages[str(si)][str(li)]`` mirrors ``params["stages"]`` with per-layer
    ``{"mixer": ..., "mlp": ...}`` entry dicts; ``encoder`` mirrors the
    optional encoder stage; ``head`` is a single (d, vocab) entry.
    """

    op: AxOOperator
    impl: str                            # "pallas" | "xla"
    layers: tuple
    f_table: jnp.ndarray                 # (2^n, R) f32, device-resident
    signed_vals: jnp.ndarray             # (2^n,) f32
    stages: dict = field(default_factory=dict)
    encoder: dict | None = None
    head: dict | None = None
    ctx: object | None = None            # ExecutionContext for tuned tiles
    n_entries: int = 0

    def apply(self, x: jnp.ndarray, entry: dict) -> jnp.ndarray:
        """x @ W through the approximate operator, W cached in ``entry``."""
        lead = x.shape[:-1]
        k = x.shape[-1]
        bv = entry["bv"]
        n = bv.shape[-1]
        xq, sx = quantize_tensor(
            x.reshape(-1, k).astype(jnp.float32), self.op.n_bits
        )
        av = self.signed_vals[xq]                       # (M, K)
        fa = jnp.moveaxis(self.f_table[xq], -1, 0)      # (R, M, K)
        obs.of(self.ctx).count(f"dispatch.axo_apply.{self.impl}")
        if self.impl == "pallas":
            tiles = tiles_for(self.ctx, "axo_matmul.pallas",
                              m=av.shape[0], k=k, n=n, rank=self.op.rank)
            y = axo_matmul_pallas(
                av, bv, fa, entry["gb"],
                interpret=not ops.on_tpu(), **tiles,
            )
        else:
            y = av @ bv + jnp.einsum("rmk,rkn->mn", fa, entry["gb"])
        y = y * (sx * entry["scale"])
        return y.reshape(*lead, n).astype(x.dtype)


def deploy_axo(
    params: dict,
    op: AxOOperator,
    cfg,
    *,
    layers: tuple = AXO_LAYERS,
    impl: str = "pallas",
    ctx=None,
) -> AxODeployment:
    """Build an :class:`AxODeployment` for ``params`` of a model ``cfg``.

    Walks ``cfg.stages`` next to ``params["stages"]`` and prepares a cached
    entry for every deployable projection:

    * ``"attn"``  -- attention wq/wk/wv/wo (dense, no-cache, cross- and
      self-halves of attn_x, gated xattn) and MLA wq_a/wq_b/wkv_a/wo.  MLA's
      ``wkv_b`` stays exact: the absorbed form contracts its two halves
      per-head against latents, not as a plain last-dim linear.  Mamba mixers
      are out of scope (conv/SSM, no K->N linear on the hot path).
    * ``"mlp"``   -- dense FFN w_gate/w_up/w_down, plus MoE *shared* experts.
    * ``"moe"``   -- routed expert banks (per-expert entries; the router stays
      exact -- approximating the argmax selector changes *which* experts run,
      which is a routing decision, not arithmetic).
    * ``"head"``  -- the unembedding (tied: embed.T).

    ``impl="pallas"`` runs the padded registry-tiled kernel; ``"xla"`` runs
    the jnp reference contraction (identical math, faster under CPU jit).
    """
    unknown = set(layers) - set(AXO_LAYERS)
    if unknown:
        raise ValueError(f"unknown AxO layer groups {sorted(unknown)}; "
                         f"choose from {AXO_LAYERS}")
    if impl not in ("pallas", "xla"):
        raise ValueError(f"impl must be 'pallas' or 'xla', got {impl!r}")
    f_dev = jnp.asarray(op.f_table, jnp.float32)
    g_dev = jnp.asarray(op.g_table, jnp.float32)
    sv_dev = jnp.asarray(op.signed_vals, jnp.float32)
    count = [0]

    def prep(w2d):
        """(K, N) weight -> cached codes/values/factors entry."""
        wq, sw = quantize_tensor(jnp.asarray(w2d, jnp.float32), op.n_bits)
        count[0] += 1
        return {
            "bv": sv_dev[wq],                           # (K, N)
            "gb": jnp.moveaxis(g_dev[wq], -1, 0),       # (R, K, N)
            "scale": sw,
        }

    def prep_r(w, tail2=None):
        """Stacked (repeats, ...) weight -> entry with a leading repeats axis."""
        if tail2 is not None:
            w = w.reshape(w.shape[0], *tail2)
        return jax.vmap(prep)(w)

    def prep_experts(w):
        """(repeats, E, K, N) expert bank -> doubly-stacked entry."""
        return jax.vmap(jax.vmap(prep))(w)

    def attn_entries(mp):
        rep, d, h, hd = mp["wq"].shape
        g = mp["wk"].shape[2]
        return {
            "wq": prep_r(mp["wq"], (d, h * hd)),
            "wk": prep_r(mp["wk"], (d, g * hd)),
            "wv": prep_r(mp["wv"], (d, g * hd)),
            "wo": prep_r(mp["wo"], (h * hd, mp["wo"].shape[3])),
        }

    def mla_entries(mp):
        r_q, h, qd = mp["wq_b"].shape[1:]
        _, v_hd, d = mp["wo"].shape[1:]
        return {
            "wq_a": prep_r(mp["wq_a"]),
            "wq_b": prep_r(mp["wq_b"], (r_q, h * qd)),
            "wkv_a": prep_r(mp["wkv_a"]),
            "wo": prep_r(mp["wo"], (mp["wo"].shape[1] * v_hd, d)),
        }

    def mlp_entries(mp):
        return {k: prep_r(mp[k])
                for k in ("w_gate", "w_up", "w_down") if k in mp}

    def layer_entries(mixer, mlp, lp):
        ent = {}
        if "attn" in layers:
            if mixer in ("attn", "attn_nc", "xattn"):
                ent["mixer"] = attn_entries(lp["mixer"])
            elif mixer == "attn_x":
                ent["mixer"] = {
                    "self": attn_entries(lp["mixer"]["self"]),
                    "cross": attn_entries(lp["mixer"]["cross"]),
                }
            elif mixer == "mla":
                ent["mixer"] = mla_entries(lp["mixer"])
        if mlp == "dense" and "mlp" in layers:
            ent["mlp"] = mlp_entries(lp["mlp"])
        elif mlp == "moe":
            sub = {}
            if "mlp" in layers and "shared" in lp["mlp"]:
                sub["shared"] = mlp_entries(lp["mlp"]["shared"])
            if "moe" in layers:
                sub["experts"] = {
                    k: prep_experts(lp["mlp"][k])
                    for k in ("w_gate", "w_up", "w_down")
                }
            if sub:
                ent["mlp"] = sub
        return ent

    stages = {}
    for si, stage in enumerate(cfg.stages):
        sp = params["stages"][str(si)]
        stages[str(si)] = {
            str(li): layer_entries(mixer, mlp, sp[str(li)])
            for li, (mixer, mlp) in enumerate(stage.layers)
        }

    encoder = None
    if getattr(cfg, "encoder", None) is not None and "encoder" in params:
        ep = params["encoder"]["stage"]
        encoder = {
            str(li): layer_entries("attn_nc", "dense", ep[str(li)])
            for li in range(len(ep))
        }

    head = None
    if "head" in layers:
        w = (params["embed"]["tok"].T if cfg.tie_embeddings
             else params["embed"]["unembed"])
        head = prep(w)

    return AxODeployment(
        op=op, impl=impl, layers=tuple(layers),
        f_table=f_dev, signed_vals=sv_dev,
        stages=stages, encoder=encoder, head=head,
        ctx=ctx, n_entries=count[0],
    )
