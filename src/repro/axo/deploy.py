"""AxO deployment: run LM linear layers on a DSE-selected approximate operator.

The bridge from the paper's DSE output (a LUT config) to the framework's
serving path:

  1. ``AxOOperator.from_config``: behavioral-model product table -> error table
     ``E = T - ab`` -> rank-R SVD factors ``(f, g)`` + the signed-value table.
     R is a quality knob characterized with the same BEHAV metrics as the
     operator itself (``rank_behav``).
  2. ``axo_linear``: per-tensor symmetric int8 quantization of activations and
     weights, then the AxO matmul -- the Pallas kernel on TPU, its jnp
     reference (identical math) otherwise -- and dequantization.

The bit-exact table path (exhaustive gather) stays available for validation;
production uses the rank-R MXU path (DESIGN.md §3.2).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from ..core.operator_model import (
    OperatorSpec,
    error_tables,
    exact_product_table,
    product_tables,
    spec_for,
)
from ..kernels.ops import axo_matmul
from ..kernels.ref import ref_axo_matmul_lowrank

__all__ = ["AxOOperator", "quantize_tensor", "axo_linear"]


@dataclass(frozen=True)
class AxOOperator:
    """A deployable approximate multiplier: rank-R factorized error tables."""

    n_bits: int
    rank: int
    f_table: np.ndarray          # (2^n, R) float32
    g_table: np.ndarray          # (2^n, R) float32
    signed_vals: np.ndarray      # (2^n,) int32
    table: np.ndarray            # (2^n, 2^n) int32 exact approximate products

    @staticmethod
    def from_config(config: np.ndarray, rank: int = 8, n_bits: int = 8) -> "AxOOperator":
        spec = spec_for(n_bits)
        table = product_tables(spec, np.asarray(config)[None])[0]
        err = error_tables(spec, np.asarray(config)[None])[0].astype(np.float64)
        u, s, vt = np.linalg.svd(err)
        r = min(rank, len(s))
        f = (u[:, :r] * s[:r]).astype(np.float32)
        g = vt[:r].T.astype(np.float32)
        return AxOOperator(
            n_bits=n_bits, rank=r, f_table=f, g_table=g,
            signed_vals=spec.operand_values.astype(np.int32), table=table,
        )

    # -- quality of the rank knob --------------------------------------------

    def rank_table(self) -> np.ndarray:
        """Rank-R reconstruction of the product table (float)."""
        exact = exact_product_table(self.n_bits).astype(np.float64)
        return exact + self.f_table.astype(np.float64) @ self.g_table.astype(np.float64).T

    def rank_behav(self) -> dict:
        """BEHAV metrics of the rank-R approximation vs the TRUE operator table
        (how much fidelity the factorization itself costs)."""
        t_true = self.table.astype(np.float64)
        t_rank = self.rank_table()
        d = np.abs(t_rank - t_true)
        exact = np.maximum(np.abs(exact_product_table(self.n_bits)), 1).astype(np.float64)
        return {
            "AVG_ABS_ERR": float(d.mean()),
            "AVG_ABS_REL_ERR": float(100.0 * (d / exact).mean()),
            "MAX_ABS_ERR": float(d.max()),
        }


def quantize_tensor(x: jnp.ndarray, n_bits: int = 8) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8-style quantization -> (codes, scale).

    Codes are already masked into table-index (two's complement) space.
    """
    qmax = (1 << (n_bits - 1)) - 1
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int32)
    return q & ((1 << n_bits) - 1), scale


def axo_linear(
    x: jnp.ndarray,              # (..., K) float activations
    w: jnp.ndarray,              # (K, N) float weights
    op: AxOOperator,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """y = x @ w evaluated through the approximate operator's arithmetic."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    xq, sx = quantize_tensor(x.reshape(-1, k), op.n_bits)
    wq, sw = quantize_tensor(w, op.n_bits)
    f = jnp.asarray(op.f_table)
    g = jnp.asarray(op.g_table)
    sv = jnp.asarray(op.signed_vals, jnp.float32)
    if use_kernel and all(
        d % 128 == 0 for d in (xq.shape[0], k, w.shape[1])
    ):
        y = axo_matmul(xq, wq, f, g, sv)
    else:
        y = ref_axo_matmul_lowrank(xq, wq, f, g, sv)
    return (y * (sx * sw)).reshape(*lead, w.shape[1]).astype(x.dtype)
