"""fastapp parity: the JAX application-BEHAV engine vs the numpy oracle.

The engine promises *bit-identical* BEHAV for count-based app metrics (MNIST
error rate, ECG peak score) and <= 1e-6 agreement for float metrics (gauss
AVG_PSNR_RED, FFN relative L2) -- in practice the float metrics are also
bit-identical because every device output is exact integer arithmetic and the
float combines reuse the oracle's host expressions.  Parity is exercised
exhaustively: all 1024 configs of the 4x4 operator for each of the four apps.
"""

import numpy as np
import pytest

from repro.apps import APPLICATIONS
from repro.apps.fastapp import (
    TableBatch,
    app_behav_jax,
    mismatch_counts,
    product_tables_jax,
    table_batch,
    table_conv1d_jax,
    table_conv2d_jax,
    table_matmul_jax,
)
from repro.core.dataset import gen_random
from repro.core.miqcp import _all_configs
from repro.core.operator_model import accurate_config, product_tables, spec_for

# Small app instances keep the 1024-config numpy oracle sweeps fast while
# exercising the same code paths as the paper-sized defaults.
SMALL_APPS = {
    "ecg": dict(n_samples=512),
    "mnist": dict(side=8, n_train_per_class=12, n_test_per_class=6),
    "gauss": dict(side=32),
    "ffn": dict(d_model=16, d_ff=32, n_tokens=12),
}
COUNT_APPS = ("ecg", "mnist")     # count-based metrics: must be bit-identical
FLOAT_APPS = ("gauss", "ffn")     # float metrics: <= 1e-6


def small_app(name):
    return APPLICATIONS[name](**SMALL_APPS[name])


# ---------------------------------------------------------------------------
# Device product tables
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_bits", [4, 8])
def test_product_tables_device_parity(n_bits):
    spec = spec_for(n_bits)
    cfgs = np.concatenate(
        [
            gen_random(spec, 16, seed=0),
            np.zeros((1, spec.n_luts), np.uint8),
            accurate_config(spec)[None],
        ]
    )
    np.testing.assert_array_equal(
        np.asarray(product_tables_jax(spec, cfgs)), product_tables(spec, cfgs)
    )


def test_table_batch_lazy_pieces():
    spec = spec_for(4)
    batch = table_batch(spec, gen_random(spec, 5, seed=1))
    assert len(batch) == 5 and batch.n_bits == 4 and batch.n_codes == 16
    assert batch.small.shape == (spec.rows, 5, 4, 16)
    assert batch.tables.shape == (5, 16, 16)
    # raw-tables batches cannot serve the pair-plane (small) paths
    raw = TableBatch(masks=None, n_bits=4, _tables=batch.tables)
    with pytest.raises(ValueError):
        _ = raw.small


# ---------------------------------------------------------------------------
# Exhaustive 4x4 backend parity, all four apps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(APPLICATIONS))
def test_exhaustive_4x4_backend_parity(name):
    """Every 4x4 config: the jax engine reproduces the oracle across the space."""
    spec = spec_for(4)
    cfgs = _all_configs(spec.n_luts)
    app = small_app(name)
    oracle = app.behav(spec, cfgs, backend="numpy")
    fast = app.behav(spec, cfgs, backend="jax")
    if name in COUNT_APPS:
        np.testing.assert_array_equal(oracle, fast)
    else:
        np.testing.assert_allclose(fast, oracle, rtol=1e-6, atol=1e-9)


@pytest.mark.parametrize("name", sorted(APPLICATIONS))
def test_degenerate_shapes(name):
    """D=1 batches and single-sample datasets evaluate identically."""
    spec = spec_for(8)
    kwargs = dict(SMALL_APPS[name])
    if name == "mnist":
        kwargs["n_test_per_class"] = 1     # one sample per class
    if name == "ffn":
        kwargs["n_tokens"] = 1             # single-token dataset
    if name == "ecg":
        kwargs["n_samples"] = 300          # single reference peak
    app = APPLICATIONS[name](**kwargs)
    cfg = gen_random(spec, 1, seed=2)      # D=1
    np.testing.assert_allclose(
        app.behav(spec, cfg, backend="jax"),
        app.behav(spec, cfg, backend="numpy"),
        rtol=1e-6,
        atol=1e-9,
    )


def test_behav_jax_batch_chunking_invariance():
    """Results must not depend on the device batch chunking."""
    spec = spec_for(4)
    app = small_app("mnist")
    cfgs = gen_random(spec, 37, seed=3)    # odd D
    ref = app_behav_jax(app, spec, cfgs, batch=128)
    for b in (8, 16, 37):
        np.testing.assert_array_equal(ref, app_behav_jax(app, spec, cfgs, batch=b))


def test_unknown_backend_raises():
    spec = spec_for(4)
    app = small_app("gauss")
    with pytest.raises(ValueError):
        app.behav(spec, accurate_config(spec)[None], backend="torch")


# ---------------------------------------------------------------------------
# Primitive impl parity: pair-plane GEMM vs XLA gathers vs Pallas GEMV
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def batch8():
    spec = spec_for(8)
    return spec, table_batch(spec, gen_random(spec, 6, seed=4))


def test_matmul_impl_parity(batch8):
    spec, batch = batch8
    rng = np.random.default_rng(5)
    a = rng.integers(0, spec.n_inputs, (23, 100))   # K=100: pallas pads to 50|...
    b = rng.integers(0, spec.n_inputs, (100, 7))
    outs = {
        impl: np.asarray(table_matmul_jax(batch, a, b, impl=impl, interpret=True))
        for impl in ("gemm", "xla", "pallas", "entry", "entry_pallas")
    }
    np.testing.assert_array_equal(outs["gemm"], outs["xla"])
    np.testing.assert_array_equal(outs["pallas"], outs["xla"])
    np.testing.assert_array_equal(outs["entry"], outs["xla"])
    np.testing.assert_array_equal(outs["entry_pallas"], outs["xla"])
    # oracle cross-check on one config
    from repro.apps.base import table_matmul

    tab = np.asarray(batch.tables)[2]
    np.testing.assert_array_equal(outs["xla"][2], table_matmul(tab, a, b))


def test_matmul_per_config_codes(batch8):
    spec, batch = batch8
    rng = np.random.default_rng(6)
    a = rng.integers(0, spec.n_inputs, (len(batch), 9, 33))
    b = rng.integers(0, spec.n_inputs, (33, 5))
    out = np.asarray(table_matmul_jax(batch, a, b))
    tabs = np.asarray(batch.tables)
    ref = np.stack(
        [tabs[d][a[d][:, :, None], b[None, :, :]].sum(axis=1) for d in range(len(batch))]
    )
    np.testing.assert_array_equal(out, ref)


def test_conv_impl_parity(batch8):
    spec, batch = batch8
    rng = np.random.default_rng(7)
    x = rng.integers(0, spec.n_inputs, 200)
    h = rng.integers(0, spec.n_inputs, 15)
    img = rng.integers(0, spec.n_inputs, (24, 24))
    k = rng.integers(0, spec.n_inputs, (5, 5))
    np.testing.assert_array_equal(
        np.asarray(table_conv1d_jax(batch, x, h, impl="gemm")),
        np.asarray(table_conv1d_jax(batch, x, h, impl="xla")),
    )
    np.testing.assert_array_equal(
        np.asarray(table_conv2d_jax(batch, img, k, impl="gemm")),
        np.asarray(table_conv2d_jax(batch, img, k, impl="xla")),
    )
    from repro.apps.base import table_conv1d, table_conv2d

    tab = np.asarray(batch.tables)[0]
    np.testing.assert_array_equal(
        np.asarray(table_conv1d_jax(batch, x, h))[0], table_conv1d(tab, x, h)
    )
    np.testing.assert_array_equal(
        np.asarray(table_conv2d_jax(batch, img, k))[0], table_conv2d(tab, img, k)
    )


def test_mismatch_counts_all_impls(batch8):
    spec, batch = batch8
    app = APPLICATIONS["mnist"]()
    app._prepare(spec.n_bits)
    outs = [
        np.asarray(
            mismatch_counts(
                batch, app._x_codes, app._w_codes, app._labels,
                impl=impl, interpret=True,
            )
        )
        for impl in ("gemm", "xla", "pallas")
    ]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_unknown_impl_raises(batch8):
    spec, batch = batch8
    with pytest.raises(ValueError):
        table_matmul_jax(batch, np.zeros((2, 4), int), np.zeros((4, 2), int), impl="cuda")


# ---------------------------------------------------------------------------
# Table-free entry impls
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["entry", "entry_pallas"])
def test_entry_matmul_exhaustive_4x4(impl):
    """All 1024 4x4 configs through the table-free matmul, bit-identical to
    the numpy oracle (no table is ever built on the entry paths)."""
    from repro.apps.base import table_matmul

    spec = spec_for(4)
    cfgs = _all_configs(spec.n_luts)
    batch = table_batch(spec, cfgs)
    rng = np.random.default_rng(20)
    a = rng.integers(0, spec.n_inputs, (5, 24))
    b = rng.integers(0, spec.n_inputs, (24, 3))
    out = np.asarray(table_matmul_jax(batch, a, b, impl=impl, interpret=True))
    tables = product_tables(spec, cfgs)
    ref = np.stack([table_matmul(t, a, b) for t in tables])
    np.testing.assert_array_equal(out, ref)


def test_entry_matmul_per_config_codes(batch8):
    """Per-config operand codes (the FFN's requantized activations) ride the
    table-free batched gather; both entry impls route there."""
    spec, batch = batch8
    rng = np.random.default_rng(21)
    a = rng.integers(0, spec.n_inputs, (len(batch), 9, 33))
    b = rng.integers(0, spec.n_inputs, (33, 5))
    ref = np.asarray(table_matmul_jax(batch, a, b, impl="xla"))
    for impl in ("entry", "entry_pallas"):
        np.testing.assert_array_equal(
            np.asarray(table_matmul_jax(batch, a, b, impl=impl)), ref
        )


def test_entry_conv_parity(batch8):
    spec, batch = batch8
    rng = np.random.default_rng(22)
    x = rng.integers(0, spec.n_inputs, 120)
    h = rng.integers(0, spec.n_inputs, 9)
    img = rng.integers(0, spec.n_inputs, (16, 16))
    k = rng.integers(0, spec.n_inputs, (3, 3))
    np.testing.assert_array_equal(
        np.asarray(table_conv1d_jax(batch, x, h, impl="entry")),
        np.asarray(table_conv1d_jax(batch, x, h, impl="xla")),
    )
    np.testing.assert_array_equal(
        np.asarray(table_conv2d_jax(batch, img, k, impl="entry")),
        np.asarray(table_conv2d_jax(batch, img, k, impl="xla")),
    )


def test_entry_never_builds_tables(batch8):
    """The whole point: a batch scored through impl='entry' must finish with
    its full product tables still unbuilt."""
    spec = spec_for(8)
    batch = table_batch(spec, gen_random(spec, 4, seed=23))
    rng = np.random.default_rng(23)
    a = rng.integers(0, spec.n_inputs, (6, 32))
    b = rng.integers(0, spec.n_inputs, (32, 4))
    table_matmul_jax(batch, a, b, impl="entry")
    table_matmul_jax(batch, a, b, impl="entry_pallas", interpret=True)
    assert batch._tables is None
    assert batch._small is None  # no host row-table gather either


def test_entry_requires_masks(batch8):
    spec, batch = batch8
    raw = TableBatch(masks=None, n_bits=spec.n_bits, _tables=batch.tables)
    a = np.zeros((2, 4), int)
    b = np.zeros((4, 2), int)
    for impl in ("entry", "entry_pallas"):
        with pytest.raises(ValueError, match="masks"):
            table_matmul_jax(raw, a, b, impl=impl)
    # auto-selection (impl=None via ctx) falls back instead of raising
    from repro.core.engine import ExecutionContext

    raw2 = TableBatch(
        masks=None, n_bits=spec.n_bits, _tables=batch.tables,
        ctx=ExecutionContext(backend="jax", kernel_impl="entry"),
    )
    np.testing.assert_array_equal(
        np.asarray(table_matmul_jax(raw2, a, b)),
        np.asarray(table_matmul_jax(batch, a, b, impl="xla")),
    )


def test_ffn_device_requant_matches_host_within_tolerance():
    """FFN GEMM1 -> GeLU -> requant -> GEMM2 fully on device: BEHAV agrees
    with the bit-exact host-f64 requant path to the documented tolerance, and
    the chain composes with the table-free entry impl (no table build)."""
    from repro.apps.ffn import TransformerFFN
    from repro.core.engine import ExecutionContext

    spec = spec_for(8)
    rng = np.random.default_rng(11)
    cfgs = np.ones((6, spec.n_luts), dtype=np.uint8)
    for i in range(1, 6):  # mild approximations: flip i random LUTs
        cfgs[i, rng.choice(spec.n_luts, size=i, replace=False)] = 0
    tabs = product_tables(spec, cfgs)

    host = TransformerFFN(d_model=16, d_ff=24, n_tokens=12)
    dev = TransformerFFN(d_model=16, d_ff=24, n_tokens=12, requant="device")
    bh = host.behav_jax_from_tables(tabs)
    bd = dev.behav_jax_from_tables(tabs)
    np.testing.assert_allclose(bd, bh, atol=2e-2)

    # same chain through the table-free engine: tables stay unbuilt
    ctx = ExecutionContext(backend="jax", kernel_impl="entry")
    batch = table_batch(spec, cfgs, ctx=ctx)
    be = TransformerFFN(
        d_model=16, d_ff=24, n_tokens=12, requant="device"
    ).behav_jax_from_tables(batch)
    np.testing.assert_allclose(be, bd, atol=1e-9)
    assert batch._tables is None


# ---------------------------------------------------------------------------
# numpy oracle: K-chunked matmul invariance
# ---------------------------------------------------------------------------


def test_numpy_table_matmul_k_chunk_invariance():
    from repro.apps.base import table_matmul

    spec = spec_for(4)
    tab = product_tables(spec, gen_random(spec, 1, seed=8))[0]
    rng = np.random.default_rng(9)
    a = rng.integers(0, spec.n_inputs, (11, 150))
    b = rng.integers(0, spec.n_inputs, (150, 3))
    ref = table_matmul(tab, a, b, k_chunk=150)
    for kc in (1, 7, 64, 1000):
        np.testing.assert_array_equal(ref, table_matmul(tab, a, b, k_chunk=kc))


# ---------------------------------------------------------------------------
# DSE wiring
# ---------------------------------------------------------------------------


def test_characterize_fn_backend(batch8):
    spec = spec_for(4)
    app = small_app("gauss")
    cfgs = gen_random(spec, 5, seed=10)
    out_np = app.characterize_fn(spec, backend="numpy")(cfgs)
    out_jx = app.characterize_fn(spec, backend="jax")(cfgs)
    np.testing.assert_allclose(out_jx[:, 0], out_np[:, 0], rtol=1e-6, atol=1e-9)
    # operator PPA is shared numpy machinery: identical by construction
    np.testing.assert_array_equal(out_jx[:, 1], out_np[:, 1])


def test_run_dse_app_backend_smoke():
    from repro.core.dataset import build_training_dataset
    from repro.core.dse import DSESettings, run_dse

    spec = spec_for(4)
    app = small_app("mnist")
    base = build_training_dataset(spec, n_random=120, seed=0)
    ds = app.characterized_dataset(spec, base, backend="jax")
    bkey = app.behav_metric_name()
    np.testing.assert_array_equal(
        ds.metrics[bkey], app.behav(spec, base.configs, backend="numpy")
    )
    st = DSESettings(
        behav_key=bkey, const_sf=1.0, pop_size=12, n_gen=3, n_quad_grid=(0,),
        pool_size=2, seed=0, backend="jax",
    )
    r = run_dse(spec, ds, "ga", settings=st, app=app)
    assert r.hv_ppf >= 0.0 and r.hv_vpf >= 0.0 and r.n_evals > 0


def test_dse_settings_backend_validated_eagerly():
    from repro.core.dse import DSESettings

    with pytest.raises(ValueError, match="backend must be 'numpy' or 'jax'"):
        DSESettings(backend="torch")
    for ok in ("numpy", "jax"):
        assert DSESettings(backend=ok).backend == ok


def test_characterized_dataset_multi_matches_per_app():
    """One shared table pass per chunk == four one-app-at-a-time passes."""
    from repro.apps.base import characterized_dataset_multi
    from repro.core.dataset import Dataset

    spec = spec_for(4)
    cfgs = np.concatenate([gen_random(spec, 9, seed=3), accurate_config(spec)[None]])
    base = Dataset(configs=cfgs, metrics={}, source=np.zeros(len(cfgs)))
    apps = [small_app(n) for n in ("ecg", "mnist", "gauss", "ffn")]
    for backend in ("numpy", "jax"):
        multi = characterized_dataset_multi(apps, spec, base, backend=backend, batch=4)
        for app in apps:
            want = app.characterized_dataset(spec, base, backend=backend)
            key = app.behav_metric_name()
            np.testing.assert_allclose(
                multi.metrics[key], want.metrics[key], rtol=1e-9, atol=1e-12,
                err_msg=f"{app.name} {backend}",
            )
    with pytest.raises(ValueError):
        characterized_dataset_multi(apps, spec, base, backend="torch")
