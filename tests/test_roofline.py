"""Roofline machinery: HLO collective parsing, term math, flops accounting."""

import numpy as np

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, cell_status, get_arch, input_specs
from repro.launch.accounting import param_counts
from repro.launch.roofline import HW, Roofline, collective_bytes, model_flops

HLO_SNIPPET = """
HloModule jit_step
ENTRY %main {
  %p0 = bf16[16,4096]{1,0} parameter(0)
  %ag = bf16[256,4096]{1,0} all-gather(bf16[16,4096]{1,0} %p0), replica_groups={}
  %ar = f32[8192]{0} all-reduce(f32[8192]{0} %x), to_apply=%add
  %rs.1 = f32[512]{0} reduce-scatter(f32[8192]{0} %y), dimensions={0}
  %a2a = bf16[4,128]{1,0} all-to-all(bf16[4,128]{1,0} %z), dimensions={0}
  %cp = u32[64]{0} collective-permute(u32[64]{0} %w), source_target_pairs={{0,1}}
  %ars = f32[8192]{0} all-reduce-start(f32[8192]{0} %x2), to_apply=%add
  %ard = f32[8192]{0} all-reduce-done(f32[8192]{0} %ars)
  %noise = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
}
"""


def test_collective_bytes_parses_each_kind():
    out = collective_bytes(HLO_SNIPPET)
    assert out["all-gather"] == 16 * 4096 * 2
    # plain all-reduce + the -start form; the -done handle is NOT counted
    assert out["all-reduce"] == 8192 * 4 * 2
    assert out["reduce-scatter"] == 8192 * 4
    assert out["all-to-all"] == 4 * 128 * 2
    assert out["collective-permute"] == 64 * 4


def test_roofline_terms_and_bottleneck():
    hw = HW(peak_flops=100.0, hbm_bw=10.0, link_bw=1.0)
    rl = Roofline(
        arch="x", shape="y", mesh="m", chips=4,
        hlo_flops=200.0, hlo_bytes=50.0, coll_bytes=2.0,
        model_flops=400.0, hw=hw,
    )
    assert rl.t_compute == 2.0
    assert rl.t_memory == 5.0
    assert rl.t_collective == 2.0
    assert rl.bottleneck == "memory"
    np.testing.assert_allclose(rl.useful_fraction, 400.0 / 800.0)
    np.testing.assert_allclose(rl.mfu_bound, 400.0 / (4 * 100.0 * 5.0))


def test_model_flops_kinds():
    cfg = get_arch("internlm2-1.8b")
    shape = SHAPES["train_4k"]
    n = 1_000_000
    assert model_flops(cfg, shape, n, "train") == 6.0 * n * shape.tokens
    assert model_flops(cfg, shape, n, "prefill") == 2.0 * n * shape.tokens
    assert model_flops(cfg, SHAPES["decode_32k"], n, "decode") == \
        2.0 * n * SHAPES["decode_32k"].global_batch


def test_param_counts_match_known_scales():
    """Analytic parameter counts land near the published model sizes."""
    expect = {
        "deepseek-67b": (60e9, 75e9),
        "internlm2-1.8b": (1.5e9, 2.2e9),
        "granite-3-2b": (2.0e9, 3.0e9),
        "starcoder2-3b": (2.5e9, 3.5e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.15e12),
        "deepseek-v3-671b": (0.6e12, 0.72e12),
        "llama-3.2-vision-90b": (80e9, 100e9),
    }
    for arch, (lo, hi) in expect.items():
        n = param_counts(get_arch(arch))["total"]
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"


def test_moe_active_far_below_total():
    c = param_counts(get_arch("kimi-k2-1t-a32b"))
    assert c["active"] < 0.06 * c["total"]
    c = param_counts(get_arch("deepseek-v3-671b"))
    assert c["active"] < 0.08 * c["total"]


def test_cell_grid_covers_40_with_8_documented_skips():
    cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    assert len(cells) == 40
    skips = [c for c in cells if cell_status(*c) != "run"]
    assert len(skips) == 8
    assert all(s == "long_500k" for _, s in skips)
    assert ("mamba2-130m", "long_500k") not in skips
    assert ("jamba-v0.1-52b", "long_500k") not in skips


def test_input_specs_shapes():
    cfg = get_arch("whisper-medium")
    sp = input_specs(cfg, SHAPES["train_4k"])
    assert sp["batch"]["tokens"].shape == (256, 4096)
    assert sp["batch"]["enc_embeds"].shape == (256, 1500, 1024)
    spd = input_specs(cfg, SHAPES["decode_32k"])
    assert spd["tokens"].shape == (128, 1)
    assert spd["index"].shape == ()
