"""Optimizers, schedules, clipping, int8 compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adafactor,
    adamw,
    apply_updates,
    clip_by_global_norm,
    compress_int8,
    cosine_schedule,
    decompress_int8,
    global_norm,
)


@pytest.mark.parametrize("make_opt", [adamw, adafactor])
def test_optimizer_minimizes_quadratic(make_opt):
    opt = make_opt(lambda step: 0.1, weight_decay=0.0)
    target = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                               jnp.float32), "b": jnp.ones((8,), jnp.float32)}
    params = jax.tree.map(jnp.zeros_like, target)
    state = opt.init(params)

    @jax.jit
    def step(params, state, t):
        loss, g = jax.value_and_grad(
            lambda p: sum(jnp.sum((a - b) ** 2) for a, b in
                          zip(jax.tree.leaves(p), jax.tree.leaves(target)))
        )(params)
        upd, state = opt.update(g, state, params, t)
        return apply_updates(params, upd), state, loss

    losses = []
    for t in range(60):
        params, state, loss = step(params, state, jnp.int32(t))
        losses.append(float(loss))
    assert losses[-1] < 0.05 * losses[0]


@pytest.mark.parametrize("make_opt", [adamw, adafactor])
def test_state_spec_mirrors_state_tree(make_opt):
    from repro.models.spec import ParamSpec, abstract_params

    opt = make_opt(lambda s: 1e-3)
    spec_tree = {"a": ParamSpec((4, 6), ("embed", "mlp")),
                 "b": ParamSpec((5,), ("embed",))}
    params = {"a": jnp.zeros((4, 6)), "b": jnp.zeros((5,))}
    state = opt.init(params)
    abs_state = abstract_params(opt.state_spec(spec_tree))
    assert jax.tree.structure(state) == jax.tree.structure(abs_state)
    for real, abst in zip(jax.tree.leaves(state), jax.tree.leaves(abs_state)):
        assert real.shape == abst.shape and real.dtype == abst.dtype


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup_steps=10, total_steps=100, min_ratio=0.1)
    assert float(lr(jnp.int32(0))) < float(lr(jnp.int32(9)))
    np.testing.assert_allclose(float(lr(jnp.int32(10))), 1e-3, rtol=1e-2)
    assert float(lr(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(90 + 160), rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # below the threshold: untouched
    same, _ = clip_by_global_norm(tree, 1e6)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0)


def test_compress_int8_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 5, jnp.float32)
    q, scale, err = compress_int8(x)
    y = decompress_int8(q, scale)
    assert float(jnp.max(jnp.abs(x - y))) <= float(scale) / 2 + 1e-6
    np.testing.assert_allclose(np.asarray(x - y), np.asarray(err), atol=1e-6)


def test_error_feedback_removes_bias():
    """Accumulating with error feedback: the summed quantized stream converges
    to the true sum (bias cancels), unlike naive requantization."""
    rng = np.random.default_rng(1)
    xs = [jnp.asarray(rng.standard_normal(256), jnp.float32) for _ in range(50)]
    err = jnp.zeros(256)
    total = jnp.zeros(256)
    for x in xs:
        q, s, err = compress_int8(x, err)
        total = total + decompress_int8(q, s)
    true = sum(xs)
    resid = float(jnp.max(jnp.abs(total - true)))
    # the residual is bounded by the final error-feedback buffer (one quantum)
    assert resid <= float(jnp.max(jnp.abs(err))) + 1e-5
