"""Persistent DSE service: operator library, job queue, serve endpoint.

The store's hard guarantees, in test order: content addresses are stable
across processes and key orderings; rows and fronts round-trip through disk;
corrupt/truncated shards degrade to warnings + counters (never a crash); and
an EMPTY library leaves ``run_dse``/``run_dse_sweep`` bit-identical to
``store=None`` at fixed seed -- the cold-start regression gate.
"""

import json
import os
import subprocess
import sys
import urllib.request

import numpy as np
import pytest

from repro import obs
from repro.core.dataset import build_training_dataset, gen_random
from repro.core.dse import DSESettings, fixed_library, run_dse, run_dse_sweep
from repro.core.operator_model import spec_for
from repro.service import (
    DSEJobQueue,
    DSERequest,
    OperatorStore,
    config_key,
    default_runner,
    request_key,
    store_status,
)
from repro.service.store import SCHEMA_VERSION, train_fingerprint

SPEC = spec_for(4)


@pytest.fixture()
def store(tmp_path):
    return OperatorStore(root=str(tmp_path / "library"),
                         tel=obs.Telemetry("svc-test"))


@pytest.fixture(scope="module")
def dse_setup():
    ds = build_training_dataset(SPEC, n_random=150, seed=0)
    st = DSESettings(const_sf=0.8, pop_size=16, n_gen=6, backend="jax", seed=0)
    return ds, st


# ---------------------------------------------------------------------------
# Content addressing
# ---------------------------------------------------------------------------


class TestHashing:
    def test_key_is_order_and_type_stable(self):
        cfg = gen_random(SPEC, 1, seed=0)[0]
        k1 = config_key(SPEC, cfg, app="ecg", const_sf=0.5)
        k2 = config_key(SPEC, list(int(b) for b in cfg), app="ecg",
                        const_sf=0.5)
        assert k1 == k2
        assert config_key(SPEC, cfg) != k1            # app is part of the address
        assert config_key(SPEC, cfg, app="ecg") != k1  # and so is const_sf

    def test_key_stable_across_processes(self):
        """sha256 of canonical JSON: immune to hash randomization."""
        prog = (
            "import sys; sys.path.insert(0, 'src');"
            "from repro.core.operator_model import spec_for;"
            "from repro.service import config_key, request_key;"
            "import numpy as np;"
            "spec = spec_for(4);"
            "cfg = np.ones(spec.n_luts, np.uint8);"
            "print(config_key(spec, cfg, app='ecg'));"
            "print(request_key(spec, 'ecg', 0.5, 3, 'ga'))"
        )
        outs = set()
        for seed in ("0", "1", "4242"):
            env = dict(os.environ, PYTHONHASHSEED=seed)
            outs.add(subprocess.run(
                [sys.executable, "-c", prog], env=env, cwd=os.getcwd(),
                capture_output=True, text=True, check=True,
            ).stdout)
        assert len(outs) == 1

    def test_request_key_separates_budget_and_data(self, dse_setup):
        ds, st = dse_setup
        fp = train_fingerprint(ds)
        base = request_key(SPEC, None, 0.8, 0, "ga", st, fp)
        assert base == request_key(SPEC, None, 0.8, 0, "ga", st, fp)
        st2 = DSESettings(const_sf=0.8, pop_size=32, n_gen=6, backend="jax")
        assert base != request_key(SPEC, None, 0.8, 0, "ga", st2, fp)
        assert base != request_key(SPEC, None, 0.8, 1, "ga", st, fp)
        assert base != request_key(SPEC, None, 0.8, 0, "ga", st, "other")


# ---------------------------------------------------------------------------
# Row/front round-trip + corruption tolerance
# ---------------------------------------------------------------------------


class TestStoreRoundTrip:
    def test_rows_round_trip_and_dedup(self, store):
        cfgs = gen_random(SPEC, 8, seed=1)
        objs = np.arange(16, dtype=np.float64).reshape(8, 2)
        assert store.put_rows(SPEC, cfgs, objs) == 8
        assert store.put_rows(SPEC, cfgs, objs) == 0  # content-addressed dedup
        # fresh instance = fresh process: must read back identically
        again = OperatorStore(root=store.root, tel=store.tel)
        got, hit = again.lookup_rows(SPEC, cfgs)
        assert hit.all()
        np.testing.assert_array_equal(got, objs)
        assert store.tel.counter("service.store_hit") == 8

    def test_cached_characterize_skips_known_configs(self, store):
        cfgs = gen_random(SPEC, 6, seed=2)
        calls = []

        def fn(c):
            calls.append(len(c))
            return np.ones((len(c), 2))

        wrapped = store.cached_characterize(SPEC, fn)
        wrapped(cfgs)
        wrapped(cfgs)                      # all hits: no dispatch
        wrapped(gen_random(SPEC, 9, seed=3)[6:])  # 3 fresh
        assert calls == [6, 3]

    def test_front_round_trip_with_request_cache(self, store):
        cfgs = gen_random(SPEC, 4, seed=4)
        objs = np.random.default_rng(0).random((4, 2))
        store.put_front(SPEC, "ecg", 0.5, 7, "ga", cfgs, objs, hv_vpf=1.25,
                        n_evals=99, request="req-abc")
        again = OperatorStore(root=store.root, tel=store.tel)
        rec = again.lookup_result("req-abc")
        assert rec is not None and rec["hv"] == 1.25 and rec["seed"] == 7
        np.testing.assert_array_equal(
            np.asarray(rec["objs"]), objs
        )
        pool = again.warm_pool(SPEC, "ecg", 0.5)
        np.testing.assert_array_equal(pool, cfgs)

    def test_nearest_fronts_prefers_app_then_const_sf(self, store):
        c = gen_random(SPEC, 1, seed=5)
        o = np.ones((1, 2))
        store.put_front(SPEC, "ecg", 0.5, 0, "ga", c, o, 1.0)
        store.put_front(SPEC, None, 0.52, 0, "ga", c + 0, o, 1.0)
        store.put_front(SPEC, None, 0.9, 0, "ga", c + 0, o, 1.0)
        recs = store.nearest_fronts(SPEC, None, 0.5, k=3)
        assert [r["app"] for r in recs] == [None, None, "ecg"]
        assert recs[0]["const_sf"] == 0.52

    def test_corrupt_lines_warn_and_count_never_crash(self, store):
        cfgs = gen_random(SPEC, 3, seed=6)
        store.put_rows(SPEC, cfgs, np.ones((3, 2)))
        path = os.path.join(store.root, "rows.jsonl")
        with open(path, "a") as fh:
            fh.write("{not json}\n")
            fh.write(json.dumps({"schema": SCHEMA_VERSION + 99, "key": "x"}) + "\n")
            fh.write('{"schema": 1, "key": "truncat')  # torn final line
        tel = obs.Telemetry("svc-corrupt")
        with pytest.warns(UserWarning, match="corrupt"):
            again = OperatorStore(root=store.root, tel=tel)
            _, hit = again.lookup_rows(SPEC, cfgs)
        assert hit.all()                    # the valid lines survived
        assert tel.counter("service.store_corrupt") == 3

    def test_missing_library_reads_as_empty(self, tmp_path):
        store = OperatorStore(root=str(tmp_path / "nope"),
                              tel=obs.Telemetry("svc-missing"))
        _, hit = store.lookup_rows(SPEC, gen_random(SPEC, 2, seed=0))
        assert not hit.any()
        assert store.warm_pool(SPEC, None, 0.5) is None

    def test_seed_fixed_library(self, store):
        n = store.seed_fixed_library(SPEC)
        assert n == len(fixed_library(SPEC))
        assert store.seed_fixed_library(SPEC) == 0  # idempotent
        assert store.warm_pool(SPEC, None, 0.5) is None  # rows, not fronts

    def test_store_status_payload(self, store):
        store.put_rows(SPEC, gen_random(SPEC, 2, seed=7), np.ones((2, 2)))
        st = store_status(store)
        assert st["ok"] and st["rows"] == 2 and st["specs"] == ["mul4"]


# ---------------------------------------------------------------------------
# Cold-start bit-identity + warm-start behavior (the regression gates)
# ---------------------------------------------------------------------------


class TestDSEIntegration:
    def test_empty_library_run_dse_bit_identical(self, dse_setup, store):
        ds, st = dse_setup
        base = run_dse(SPEC, ds, "ga", settings=st)
        cold = run_dse(SPEC, ds, "ga", settings=st, store=store)
        np.testing.assert_array_equal(base.ppf_configs, cold.ppf_configs)
        np.testing.assert_array_equal(base.vpf_configs, cold.vpf_configs)
        np.testing.assert_array_equal(base.vpf_objs, cold.vpf_objs)
        assert base.hv_vpf == cold.hv_vpf and base.hv_ppf == cold.hv_ppf

    def test_empty_library_sweep_bit_identical(self, dse_setup, tmp_path):
        ds, st = dse_setup
        grid = dict(seeds=(0, 1), const_sf_grid=(0.5, 0.8))
        base = run_dse_sweep(SPEC, ds, "ga", settings=st, **grid)
        cold = run_dse_sweep(
            SPEC, ds, "ga", settings=st,
            store=OperatorStore(root=str(tmp_path / "lib2"),
                                tel=obs.Telemetry("svc-sweep")),
            **grid,
        )
        assert len(base) == len(cold) == 4
        for a, b in zip(base, cold):
            np.testing.assert_array_equal(a.vpf_configs, b.vpf_configs)
            np.testing.assert_array_equal(a.vpf_objs, b.vpf_objs)
            assert a.hv_vpf == b.hv_vpf

    def test_repeat_request_hits_cache_and_skips_search(self, dse_setup, store):
        ds, st = dse_setup
        first = run_dse(SPEC, ds, "ga", settings=st, store=store)
        again = run_dse(SPEC, ds, "ga", settings=st, store=store)
        assert store.tel.counter("service.request_hit") == 1
        assert "store" in again.timings and "ga" not in again.timings
        np.testing.assert_array_equal(first.vpf_configs, again.vpf_configs)
        np.testing.assert_array_equal(first.ppf_configs, again.ppf_configs)
        assert first.hv_vpf == again.hv_vpf

    def test_validation_dedups_rows_on_second_run(self, dse_setup, store):
        ds, st = dse_setup
        run_dse(SPEC, ds, "ga", settings=st, store=store)
        hits0 = store.tel.counter("service.store_hit")
        # different seed: new search, but overlapping fronts re-validate from
        # the library instead of re-dispatching fastchar
        import dataclasses

        st2 = dataclasses.replace(st, seed=9)
        run_dse(SPEC, ds, "ga", settings=st2, store=store)
        assert store.tel.counter("service.store_hit") > hits0

    def test_warm_start_uses_library_and_does_not_hurt(self, dse_setup, store):
        import dataclasses

        ds, st = dse_setup
        run_dse(SPEC, ds, "ga", settings=st, store=store)
        st2 = dataclasses.replace(st, seed=11)
        cold = run_dse(SPEC, ds, "ga", settings=st2)
        warm = run_dse(SPEC, ds, "ga", settings=st2, store=store)
        assert warm.hv_vpf >= cold.hv_vpf * 0.98  # seeding must not hurt
        assert store.warm_pool(SPEC, None, st.const_sf) is not None

    def test_caller_characterize_fn_disables_store(self, dse_setup, store):
        ds, st = dse_setup
        fn = lambda c: np.ones((len(c), 2))  # noqa: E731
        run_dse(SPEC, ds, "ga", settings=st, characterize_fn=fn, store=store)
        assert store.stats()["rows"] == 0 and store.stats()["fronts"] == 0


# ---------------------------------------------------------------------------
# Job queue coalescing
# ---------------------------------------------------------------------------


class TestJobQueue:
    def test_coalesces_compatible_jobs_into_one_dispatch(self, store):
        tel = store.tel
        st = DSESettings(pop_size=16, n_gen=4, backend="jax")
        q = DSEJobQueue(default_runner(settings=st, store=store, n_train=100),
                        tel=tel, linger_s=0.2)
        try:
            ids = [q.submit(DSERequest(n_bits=4, const_sf=sf, seed=s))
                   for sf in (0.5, 0.8) for s in (0, 1)]
            assert q.join(timeout=300)
            res = [q.result(i) for i in ids]
            assert all(r["status"] == "done" for r in res)
            assert tel.counter("service.jobs") == 4
            assert tel.counter("service.batches") == 1
            # lane mapping: each job got ITS (const_sf, seed) lane back
            for i, r in zip(ids, res):
                assert r["request"]["const_sf"] in (0.5, 0.8)
                assert r["hv_vpf"] > 0
        finally:
            q.close()

    def test_incompatible_groups_dispatch_separately(self, store):
        tel = store.tel
        st = DSESettings(pop_size=16, n_gen=4, backend="jax")
        q = DSEJobQueue(default_runner(settings=st, store=store, n_train=100),
                        tel=tel, linger_s=0.2)
        try:
            a = q.submit(DSERequest(n_bits=4, method="ga"))
            b = q.submit(DSERequest(n_bits=4, method="map+ga"))
            assert q.join(timeout=300)
            assert q.result(a)["status"] == "done"
            assert q.result(b)["status"] == "done"
            assert tel.counter("service.batches") == 2
        finally:
            q.close()

    def test_bad_request_yields_error_payload_not_crash(self, store):
        q = DSEJobQueue(default_runner(store=store), tel=store.tel,
                        linger_s=0.01)
        try:
            jid = q.submit(DSERequest(n_bits=4, op="bogus"))
            assert q.join(timeout=60)
            res = q.result(jid)
            assert res["status"] == "error" and "error" in res
            assert store.tel.counter("service.job_errors") == 1
        finally:
            q.close()

    def test_request_validation(self):
        with pytest.raises(ValueError, match="unknown request fields"):
            DSERequest.from_dict({"n_bits": 4, "bogus": 1})
        with pytest.raises(ValueError, match="method"):
            DSERequest.from_dict({"method": "map"})


# ---------------------------------------------------------------------------
# HTTP endpoint round-trip (MetricsServer routes)
# ---------------------------------------------------------------------------


class TestServeEndpoint:
    def test_post_get_round_trip(self, store):
        from repro.obs.prom import MetricsServer

        st = DSESettings(pop_size=16, n_gen=4, backend="jax")
        q = DSEJobQueue(default_runner(settings=st, store=store, n_train=100),
                        tel=store.tel, linger_s=0.05)
        srv = MetricsServer(port=0, check_device=False)
        srv.add_route("POST", "/dse", lambda p: {
            "job_id": q.submit(DSERequest.from_dict(p))})
        srv.add_route("GET", "/dse", lambda p: q.result(p["id"])
                      or {"status": "pending"})
        srv.add_route("GET", "/dse/library", lambda p: store_status(store))
        srv.start()
        try:
            body = json.dumps({"n_bits": 4, "const_sf": 0.5}).encode()
            req = urllib.request.Request(
                f"{srv.url}/dse", data=body,
                headers={"Content-Type": "application/json"},
            )
            with urllib.request.urlopen(req) as resp:
                jid = json.loads(resp.read())["job_id"]
            assert q.join(timeout=300)
            with urllib.request.urlopen(f"{srv.url}/dse?id={jid}") as resp:
                res = json.loads(resp.read())
            assert res["status"] == "done" and res["hv_vpf"] > 0
            with urllib.request.urlopen(f"{srv.url}/dse/library") as resp:
                lib = json.loads(resp.read())
            assert lib["ok"] and lib["rows"] > 0
        finally:
            q.close()
            srv.stop()

    def test_bad_post_body_is_400_unknown_route_404(self):
        from repro.obs.prom import MetricsServer

        srv = MetricsServer(port=0, check_device=False)
        srv.add_route("POST", "/dse", lambda p: DSERequest.from_dict(p) and {})
        srv.start()
        try:
            req = urllib.request.Request(
                f"{srv.url}/dse", data=b"{\"bogus\": 1}",
                headers={"Content-Type": "application/json"},
            )
            with pytest.raises(urllib.error.HTTPError) as e:
                urllib.request.urlopen(req)
            assert e.value.code == 400
            with pytest.raises(urllib.error.HTTPError) as e:
                urllib.request.urlopen(
                    urllib.request.Request(f"{srv.url}/nope", data=b"{}"))
            assert e.value.code == 404
        finally:
            srv.stop()
