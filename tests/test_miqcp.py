"""MILP/MIQCP solver correctness (enumeration is the exact reference)."""

import numpy as np
import pytest

from repro.core.correlation import rank_quadratic_terms
from repro.core.dataset import build_training_dataset
from repro.core.miqcp import (
    MapProblem,
    QuadExpr,
    build_problems,
    solve_bnb,
    solve_enumerate,
    solve_pool,
    solve_tabu,
    solve_tabu_multi,
)
from repro.core.operator_model import spec_for
from repro.core.regression import fit_poly


def _problems(n_quad: int, const_sf: float, wt):
    spec = spec_for(4)
    ds = build_training_dataset(spec, n_random=200, seed=0)
    X = ds.configs.astype(float)
    yb = ds.metrics["AVG_ABS_REL_ERR"]
    yp = ds.metrics["PDPLUT"]
    rb = rank_quadratic_terms(X, yb)[:n_quad]
    rp = rank_quadratic_terms(X, yp)[:n_quad]
    bm = fit_poly(X, yb, quad_pairs=rb)
    pm = fit_poly(X, yp, quad_pairs=rp)
    return build_problems(
        bm, pm, float(yb.max()), float(yp.max()), const_sf,
        wt_grid=np.asarray(wt), n_quad=n_quad,
    )


def test_quadexpr_value_and_flip_deltas():
    rng = np.random.default_rng(0)
    L = 8
    expr = QuadExpr(
        const=rng.standard_normal(),
        lin=rng.standard_normal(L),
        quad=np.triu(rng.standard_normal((L, L)), k=1),
    )
    l = rng.integers(0, 2, L).astype(float)
    deltas = expr.flip_deltas(l)
    for k in range(L):
        l2 = l.copy()
        l2[k] = 1 - l2[k]
        np.testing.assert_allclose(deltas[k], expr.value(l2) - expr.value(l), atol=1e-9)


@pytest.mark.parametrize("n_quad", [0, 4])
@pytest.mark.parametrize("const_sf", [0.5, 1.0])
def test_tabu_and_bnb_match_enumeration_on_4x4(n_quad, const_sf):
    for prob in _problems(n_quad, const_sf, [0.0, 0.5, 1.0]):
        exact = solve_enumerate(prob)
        tabu = solve_tabu(prob, seed=0)
        bnb = solve_bnb(prob, node_budget=500_000)
        if exact.best is None:
            assert tabu.best is None or prob.feasible(tabu.best[None])[0]
            continue
        # bnb is exact within budget on these small instances
        np.testing.assert_allclose(bnb.best_obj, exact.best_obj, rtol=1e-9)
        # tabu is a heuristic: must be feasible and close
        assert tabu.best is not None
        assert prob.feasible(tabu.best[None])[0]
        assert tabu.best_obj >= exact.best_obj - 1e-9
        assert tabu.best_obj <= exact.best_obj + 0.15 * (abs(exact.best_obj) + 1e-3)


def test_solution_pools_are_feasible_and_unique():
    for prob in _problems(4, 1.0, [0.25, 0.75]):
        res = solve_enumerate(prob, pool_size=8)
        if len(res.pool):
            assert prob.feasible(res.pool).all()
            assert len(np.unique(res.pool, axis=0)) == len(res.pool)


@pytest.mark.parametrize("n_quad", [0, 4])
def test_tabu_jax_backend_matches_numpy_pool_contract(n_quad):
    """The lockstep device tabu must find the numpy path's best solution.

    Starts advance in lockstep (one batched neighborhood dispatch per
    iteration) instead of serially, so deep pool membership can differ on
    near-ties; the best config/objective and the pool invariants (feasible,
    unique, contains the best) are the parity contract.
    """
    for prob in _problems(n_quad, 1.0, [0.0, 0.5, 1.0]):
        t_np = solve_tabu(prob, seed=0)
        t_jx = solve_tabu(prob, seed=0, backend="jax")
        assert (t_np.best is None) == (t_jx.best is None)
        if t_np.best is None:
            continue
        scale = abs(t_np.best_obj) + 1e-3
        assert abs(t_jx.best_obj - t_np.best_obj) <= 1e-6 * scale
        assert prob.feasible(t_jx.best[None])[0]
        assert prob.feasible(t_jx.pool).all()
        assert len(np.unique(t_jx.pool, axis=0)) == len(t_jx.pool)
        assert (t_jx.pool == t_jx.best).all(axis=1).any()
        # pool quality: the device pool's best equals the overall best
        np.testing.assert_allclose(
            prob.obj.value(t_jx.pool).min(), t_jx.best_obj, atol=1e-9
        )


def test_tabu_unknown_backend_raises():
    prob = _problems(0, 1.0, [0.5])[0]
    with pytest.raises(ValueError):
        solve_tabu(prob, backend="torch")


def test_tabu_multi_identical_best_on_4x4_battery():
    """Cross-problem lockstep tabu == serial numpy per problem on a battery.

    The whole battery advances as one (problems x starts, L) batch -- one
    vmapped neighborhood dispatch per iteration for ALL problems
    (``fastchar.tabu_neighbor_values_multi_jax``).  Problems are independent,
    so each problem's best config/objective must match the serial numpy
    oracle's exactly on the 4x4 battery (2 n_quad x 2 const_sf x 2 wt_B = 8
    problems); deep pool tails can differ on near-ties like the
    single-problem jax path, but every pool must stay feasible/unique and
    contain its best.
    """
    problems = []
    for n_quad in (0, 4):
        for const_sf in (0.5, 1.0):
            problems.extend(_problems(n_quad, const_sf, [0.25, 0.75]))
    seeds = list(range(len(problems)))
    multi = solve_tabu_multi(problems, seeds=seeds)
    assert len(multi) == len(problems)
    for prob, sd, res in zip(problems, seeds, multi):
        serial = solve_tabu(prob, seed=sd)  # the numpy oracle
        assert (serial.best is None) == (res.best is None)
        if serial.best is None:
            continue
        np.testing.assert_array_equal(serial.best, res.best)
        scale = abs(serial.best_obj) + 1e-3
        assert abs(res.best_obj - serial.best_obj) <= 1e-6 * scale
        assert prob.feasible(res.pool).all()
        assert len(np.unique(res.pool, axis=0)) == len(res.pool)
        assert (res.pool == res.best).all(axis=1).any()


def test_tabu_multi_battery_matches_single_problem_lockstep():
    """One-problem battery == the single-problem jax lockstep path exactly."""
    for prob in _problems(4, 1.0, [0.5]):
        single = solve_tabu(prob, seed=3, backend="jax")
        (multi,) = solve_tabu_multi([prob], seeds=[3])
        assert (single.best is None) == (multi.best is None)
        if single.best is None:
            continue
        np.testing.assert_array_equal(single.best, multi.best)
        np.testing.assert_array_equal(single.pool, multi.pool)


def _linear_problem(L: int, seed: int, max_behav: float = 2.0) -> MapProblem:
    """A random linear MaP instance at arbitrary L (tabu-sized when L > 22)."""
    rng = np.random.default_rng(seed)
    lin_b = rng.standard_normal(L)
    lin_p = rng.standard_normal(L)
    return MapProblem(
        obj=QuadExpr(0.0, 0.5 * lin_b + 0.5 * lin_p, np.zeros((L, L))),
        behav=QuadExpr(0.0, lin_b, np.zeros((L, L))),
        ppa=QuadExpr(0.0, lin_p, np.zeros((L, L))),
        max_behav=max_behav, max_ppa=2.0, wt_b=0.5, const_sf=1.0, n_quad=0,
    )


def test_solve_pool_jax_batches_tabu_batteries():
    """solve_pool under a jax context routes L>16 batteries through the
    lockstep multi solver and unions the same per-problem pools."""
    L = 24  # tabu-sized (enumeration refuses L > 22, solve() cuts at 16)
    problems = [_linear_problem(L, seed=k) for k in range(3)]
    pool_jax = solve_pool(problems, seed=0, pool_size=4, backend="jax")
    expected = solve_tabu_multi(
        problems, seeds=[0, 1, 2], pool_size=4
    )
    manual = np.concatenate([r.pool for r in expected if len(r.pool)])
    _, idx = np.unique(manual, axis=0, return_index=True)
    np.testing.assert_array_equal(pool_jax, manual[np.sort(idx)])


def test_tabu_multi_rejects_mixed_sizes():
    with pytest.raises(ValueError, match="same-L"):
        solve_tabu_multi(
            [_linear_problem(24, seed=0), _problems(0, 1.0, [0.5])[0]],
            seeds=[0, 1],
        )


def test_solve_pool_jax_mixed_sizes_falls_back_per_problem():
    """A mixed-L battery cannot lockstep; solve_pool must keep the pre-multi
    per-problem dispatch (exact enumeration for the small instance) instead of
    erroring inside solve_tabu_multi.  The big lane is made infeasible so the
    union concat only sees the small problem's pool, as before this PR."""
    big = _linear_problem(24, seed=0, max_behav=-1e9)  # no feasible point
    small = _problems(0, 1.0, [0.5])[0]
    pool = solve_pool([big, small], seed=0, pool_size=4, backend="jax")
    ref = solve_pool([big, small], seed=0, pool_size=4, backend="numpy")
    assert pool.shape[1] == small.n
    assert len(pool) and len(ref)


def test_tight_constraints_reduce_feasible_pool():
    loose = _problems(0, 1.5, [0.5])[0]
    tight = _problems(0, 0.2, [0.5])[0]
    n_loose = len(solve_enumerate(loose, pool_size=512).pool)
    n_tight = len(solve_enumerate(tight, pool_size=512).pool)
    assert n_tight <= n_loose
