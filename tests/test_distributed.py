"""Distributed semantics on simulated devices (subprocess keeps the main
pytest at 1 device -- the dry-run flag must never leak into other tests)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_moe_ep_shard_map_matches_reference():
    """Expert-parallel shard_map MoE == single-device reference dispatch."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_arch
        from repro.models.moe import moe_spec, moe_apply
        from repro.models.sharding import BASE_RULES, set_mesh
        from repro.models.spec import init_params

        cfg = get_arch("jamba-v0.1-52b").reduced()   # 8 experts top-2
        p = init_params(moe_spec(cfg), seed=0, dtype=jnp.float32)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)), jnp.float32)

        ref, aux_ref = moe_apply(p, x, cfg, BASE_RULES)  # no mesh -> reference

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with set_mesh(mesh):
            ep, aux_ep = jax.jit(lambda p, x: moe_apply(p, x, cfg, BASE_RULES))(p, x)

        err = float(jnp.max(jnp.abs(ref - ep)))
        print("ERR", err, float(aux_ref), float(aux_ep))
        assert err < 2e-4, err
        assert abs(float(aux_ref) - float(aux_ep)) < 1e-5
    """)
    assert "ERR" in out


@pytest.mark.slow
def test_mini_dryrun_lowers_and_compiles():
    """A reduced arch lowers + compiles on a (2, 4) mesh with the real
    dry-run plumbing (shardings, donation, cost/memory analysis)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs.base import ShapeConfig
        from repro.configs.registry import get_arch, rules_for
        from repro.launch.lowering import lower_step
        from repro.models.sharding import BASE_RULES

        cfg = get_arch("internlm2-1.8b").reduced()
        shape = ShapeConfig("mini_train", 64, 8, "train")
        rules = rules_for(cfg, shape, mesh_model=4, mesh_data=2)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        lowered = lower_step(cfg, shape, mesh, rules)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, list) else cost
        print("FLOPS", float(cost.get("flops", 0)))
        assert float(cost.get("flops", 0)) > 0
        print("MEM", compiled.memory_analysis().temp_size_in_bytes)
    """)
    assert "FLOPS" in out and "MEM" in out


@pytest.mark.slow
def test_train_step_numerically_equal_on_mesh_vs_single():
    """SPMD execution on 8 simulated devices == single-device math."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ShapeConfig
        from repro.configs.registry import get_arch
        from repro.data.synthetic import SyntheticLM
        from repro.launch.steps import make_train_step
        from repro.models.model import model_spec
        from repro.models.sharding import BASE_RULES, named_sharding, set_mesh
        from repro.models.spec import init_params, param_shardings
        from repro.optim import make_optimizer, cosine_schedule
        from jax.sharding import PartitionSpec as P

        cfg = get_arch("granite-3-2b").reduced()
        params = init_params(model_spec(cfg), seed=0, dtype=jnp.float32)
        data = SyntheticLM(cfg, ShapeConfig("t", 32, 8, "train"))
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        opt = make_optimizer("adamw", cosine_schedule(1e-3))
        fn = make_train_step(cfg, BASE_RULES, opt)

        p1, o1, m1 = jax.jit(fn)(params, opt.init(params), jnp.int32(0), batch)
        loss_single = float(m1["loss"])

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with set_mesh(mesh):
            spec = model_spec(cfg)
            p_sh = param_shardings(spec, BASE_RULES, mesh)
            params_m = jax.device_put(params, p_sh)
            o_sh = param_shardings(opt.state_spec(spec), BASE_RULES, mesh)
            opt_m = jax.device_put(opt.init(params), o_sh)
            batch_m = jax.device_put(
                batch, jax.tree.map(
                    lambda x: named_sharding(mesh, P("data"), x.shape), batch))
            p2, o2, m2 = jax.jit(fn, in_shardings=(p_sh, o_sh, None, None))(
                params_m, opt_m, jnp.int32(0), batch_m)
        loss_mesh = float(m2["loss"])
        print("LOSS", loss_single, loss_mesh)
        assert abs(loss_single - loss_mesh) < 5e-3 * max(1.0, abs(loss_single))
    """)
    assert "LOSS" in out
