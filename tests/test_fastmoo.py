"""fastmoo parity: the device NSGA-II engine vs the numpy oracle GA.

The engine's contract is *behavioral*: identical operators (constraint-
dominated sorting, crowding, binary tournament, single-point crossover,
bit-flip mutation, rank-then-crowding environmental selection) and an exact
on-device feasible-archive hypervolume -- but ``jax.random`` streams differ
from numpy's, so end-to-end runs are asserted at hypervolume parity (<= 2% on
seeded surrogate-driven runs), while every deterministic building block
(ranks, crowding, hypervolume, dominance counts) must match the oracle
exactly.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import fastmoo
from repro.core.moo import (
    crowding_distance,
    fast_nondominated_sort,
    hypervolume_2d,
    nsga2,
)

jax.config.update("jax_platform_name", "cpu")


def _rand_objs_viol(n, seed, infeas_p=0.4):
    rng = np.random.default_rng(seed)
    objs = rng.random((n, 2))
    viol = np.where(rng.random(n) < infeas_p, rng.random(n), 0.0)
    return objs, viol


# ---------------------------------------------------------------------------
# Deterministic building blocks: exact parity with moo.py
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_constraint_ranks_match_oracle(seed):
    objs, viol = _rand_objs_viol(48, seed)
    want = fast_nondominated_sort(objs, viol)
    got = np.asarray(
        fastmoo.constraint_ranks(
            jnp.asarray(objs, jnp.float32), jnp.asarray(viol, jnp.float32)
        )
    )
    np.testing.assert_array_equal(want, got)


def test_constraint_ranks_all_feasible_and_all_infeasible():
    objs, _ = _rand_objs_viol(32, 3, infeas_p=0.0)
    for viol in (np.zeros(32), 0.1 + np.random.default_rng(3).random(32)):
        want = fast_nondominated_sort(objs, viol)
        got = np.asarray(
            fastmoo.constraint_ranks(
                jnp.asarray(objs, jnp.float32), jnp.asarray(viol, jnp.float32)
            )
        )
        np.testing.assert_array_equal(want, got)


@pytest.mark.parametrize("seed", [0, 1])
def test_crowding_matches_oracle_per_front(seed):
    objs, viol = _rand_objs_viol(40, seed)
    rank = fast_nondominated_sort(objs, viol)
    want = np.zeros(40)
    for r in np.unique(rank):
        idx = np.where(rank == r)[0]
        want[idx] = crowding_distance(objs[idx])
    got = np.asarray(
        fastmoo.crowding_distance_jax(
            jnp.asarray(objs, jnp.float32), jnp.asarray(rank, jnp.int32)
        )
    )
    np.testing.assert_array_equal(np.isinf(want), np.isinf(got))
    fin = np.isfinite(want)
    np.testing.assert_allclose(want[fin], got[fin], rtol=1e-5)


def test_crowding_constant_objective_column():
    objs = np.stack([np.linspace(0, 1, 6), np.full(6, 0.3)], axis=-1)
    rank = np.zeros(6, np.int64)
    want = crowding_distance(objs)
    got = np.asarray(
        fastmoo.crowding_distance_jax(
            jnp.asarray(objs, jnp.float32), jnp.asarray(rank, jnp.int32)
        )
    )
    np.testing.assert_array_equal(np.isinf(want), np.isinf(got))
    fin = np.isfinite(want)
    np.testing.assert_allclose(want[fin], got[fin], rtol=1e-5)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_device_hypervolume_matches_oracle(seed):
    objs, viol = _rand_objs_viol(60, seed, infeas_p=0.5)
    ref = np.array([1.2, 1.1])
    want = hypervolume_2d(objs[viol <= 0], ref)
    got = float(
        fastmoo.hypervolume_2d_jax(
            jnp.asarray(objs, jnp.float32),
            jnp.asarray(viol <= 0),
            jnp.asarray(ref, jnp.float32),
        )
    )
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_device_hypervolume_duplicates_and_empty():
    ref = np.array([1.0, 1.0])
    pts = np.array([[0.5, 0.5], [0.5, 0.5], [2.0, 2.0]])
    got = float(
        fastmoo.hypervolume_2d_jax(
            jnp.asarray(pts, jnp.float32),
            jnp.ones(3, bool),
            jnp.asarray(ref, jnp.float32),
        )
    )
    np.testing.assert_allclose(got, 0.25, rtol=1e-6)
    # nothing valid -> zero volume
    assert float(
        fastmoo.hypervolume_2d_jax(
            jnp.asarray(pts, jnp.float32),
            jnp.zeros(3, bool),
            jnp.asarray(ref, jnp.float32),
        )
    ) == 0.0


# ---------------------------------------------------------------------------
# Incremental nondominated-front buffer (the per-generation tap hv path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 3])
def test_front_update_hypervolume_matches_full_recompute(seed):
    ref = jnp.asarray([1.2, 1.1], jnp.float32)
    cap = 64
    buf_x = jnp.full((cap,), jnp.inf, jnp.float32)
    buf_y = jnp.full((cap,), jnp.inf, jnp.float32)
    all_objs, all_viol = [], []
    rng = np.random.default_rng(seed)
    for _ in range(5):  # stream batches in, as gen_step does with children
        objs, viol = _rand_objs_viol(20, int(rng.integers(1 << 30)))
        buf_x, buf_y = fastmoo.front_update(
            buf_x, buf_y, jnp.asarray(objs, jnp.float32),
            jnp.asarray(viol, jnp.float32), ref,
        )
        all_objs.append(objs)
        all_viol.append(viol)
        seen = np.concatenate(all_objs)
        feas = np.concatenate(all_viol) <= 0
        want = float(
            fastmoo.hypervolume_2d_jax(
                jnp.asarray(seen, jnp.float32), jnp.asarray(feas), ref
            )
        )
        got = float(fastmoo.front_hypervolume(buf_x, buf_y, ref))
        np.testing.assert_allclose(got, want, rtol=1e-5)


def test_front_update_keeps_strict_staircase():
    ref = jnp.asarray([10.0, 10.0], jnp.float32)
    buf_x = jnp.full((8,), jnp.inf, jnp.float32)
    buf_y = jnp.full((8,), jnp.inf, jnp.float32)
    # (2,2) dominates (3,3); (1,5) and (5,1) are incomparable; (2,9) is a
    # duplicate-x with worse y; infeasible and out-of-ref points are dropped
    objs = jnp.asarray([[2, 2], [3, 3], [1, 5], [5, 1], [2, 9],
                        [0.1, 0.1], [11, 0.5]], jnp.float32)
    viol = jnp.asarray([0, 0, 0, 0, 0, 1, 0], jnp.float32)
    bx, by = fastmoo.front_update(buf_x, buf_y, objs, viol, ref)
    kept = np.isfinite(np.asarray(bx))
    pts = sorted(zip(np.asarray(bx)[kept].tolist(),
                     np.asarray(by)[kept].tolist()))
    assert pts == [(1.0, 5.0), (2.0, 2.0), (5.0, 1.0)]
    # members are packed at the front of the buffer, padding strictly +inf
    assert kept.sum() == 3 and kept[:3].all() and not kept[3:].any()


def test_front_buffer_capacity_truncates_worst():
    ref = jnp.asarray([100.0, 100.0], jnp.float32)
    cap = 4
    buf_x = jnp.full((cap,), jnp.inf, jnp.float32)
    buf_y = jnp.full((cap,), jnp.inf, jnp.float32)
    # 8 mutually nondominated points on a line: only cap of them can stay
    xs = np.arange(8, dtype=np.float32)
    objs = jnp.asarray(np.stack([xs, 8.0 - xs], axis=1))
    viol = jnp.zeros(8, jnp.float32)
    bx, by = fastmoo.front_update(buf_x, buf_y, objs, viol, ref)
    assert bx.shape == (cap,)
    kept = np.isfinite(np.asarray(bx))
    assert kept.sum() == cap
    # truncation keeps the lexicographically smallest-x members
    np.testing.assert_array_equal(np.asarray(bx), xs[:cap])


def test_runner_front_capacity_default_and_override():
    r = fastmoo.CompiledNSGA2(_toy_objs_jax, n_bits=4, pop_size=16, n_gen=4)
    assert r.front_capacity == 4 * 16
    r2 = fastmoo.CompiledNSGA2(_toy_objs_jax, n_bits=4, pop_size=16, n_gen=4,
                               front_capacity=32)
    assert r2.front_capacity == 32


# ---------------------------------------------------------------------------
# Pallas dominance-count kernel (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tile", [16, 64])
def test_dominance_counts_pallas_matches_matrix(tile):
    from repro.kernels.moo_kernels import dominance_counts_pallas

    objs, viol = _rand_objs_viol(64, 4)
    active = np.random.default_rng(4).random(64) < 0.7
    dom = np.asarray(
        fastmoo.dominance_matrix(
            jnp.asarray(objs, jnp.float32), jnp.asarray(viol, jnp.float32)
        )
    )
    want = (dom & active[:, None]).sum(0)
    got = np.asarray(
        dominance_counts_pallas(
            jnp.asarray(objs, jnp.float32),
            jnp.asarray(viol, jnp.float32),
            jnp.asarray(active),
            tile=tile,
            interpret=True,
        )
    )
    np.testing.assert_array_equal(want, got)


@pytest.mark.parametrize("n", [64, 96, 40])  # 96/40: tile-padding paths
def test_pallas_rank_impl_matches_xla(n):
    objs, viol = _rand_objs_viol(n, 5)
    o = jnp.asarray(objs, jnp.float32)
    v = jnp.asarray(viol, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(fastmoo.constraint_ranks(o, v, impl="xla")),
        np.asarray(fastmoo.constraint_ranks(o, v, impl="pallas", interpret=True)),
    )


# ---------------------------------------------------------------------------
# End-to-end engine behavior
# ---------------------------------------------------------------------------


def _toy_objs_jax(X):
    a = X[:, :8].sum(axis=1)
    b = (1.0 - X[:, 8:]).sum(axis=1)
    return jnp.stack([a, b], axis=-1)


def _toy_objs_np(pop):
    a = pop[:, :8].sum(axis=1).astype(float)
    b = (1 - pop[:, 8:]).sum(axis=1).astype(float)
    return np.stack([a, b], axis=-1)


def test_nsga2_jax_toy_hypervolume_parity():
    ref = np.array([9.0, 9.0])
    r_np = nsga2(_toy_objs_np, n_bits=16, pop_size=24, n_gen=30, seed=0, hv_ref=ref)
    r_jx = nsga2(None, n_bits=16, pop_size=24, n_gen=30, seed=0, hv_ref=ref,
                 backend="jax", objs_device_fn=_toy_objs_jax)
    # same archive bookkeeping as the oracle
    assert r_jx.archive_configs.shape == r_np.archive_configs.shape
    assert [n for n, _ in r_jx.hv_history] == [n for n, _ in r_np.hv_history]
    hv_np = r_np.hv_history[-1][1]
    hv_jx = r_jx.hv_history[-1][1]
    assert abs(hv_jx - hv_np) <= 0.02 * hv_np
    # hv history is monotone (archive only grows)
    hvs = [h for _, h in r_jx.hv_history]
    assert all(b >= a - 1e-6 for a, b in zip(hvs, hvs[1:]))


def test_nsga2_jax_seeded_initial_population_is_used():
    init = np.zeros((4, 16), np.uint8)
    r = nsga2(None, n_bits=16, pop_size=8, n_gen=1, seed=0, backend="jax",
              objs_device_fn=_toy_objs_jax, initial_population=init)
    assert (r.archive_configs[:8].sum(1) == 0).sum() >= 4


def test_nsga2_jax_requires_device_fn_and_even_pop():
    with pytest.raises(ValueError):
        nsga2(_toy_objs_np, n_bits=16, backend="jax")
    with pytest.raises(ValueError):
        fastmoo.CompiledNSGA2(_toy_objs_jax, n_bits=16, pop_size=7)
    with pytest.raises(ValueError):
        nsga2(_toy_objs_np, n_bits=16, backend="torch")
    # host constraint callables would be silently dropped -> rejected
    with pytest.raises(ValueError, match="max_behav"):
        nsga2(None, n_bits=16, backend="jax", objs_device_fn=_toy_objs_jax,
              violation_fn=lambda p: np.zeros(len(p)))
    with pytest.raises(ValueError, match="max_behav"):
        nsga2(None, n_bits=16, backend="jax", objs_device_fn=_toy_objs_jax,
              eval_viol_fn=lambda p: (np.zeros((len(p), 2)), np.zeros(len(p))))


def test_nsga2_jax_constraints_shape_archive():
    """Tight bounds must mark violating archive entries infeasible."""
    r = nsga2(None, n_bits=16, pop_size=16, n_gen=5, seed=0, backend="jax",
              objs_device_fn=_toy_objs_jax, max_behav=4.0, max_ppa=4.0)
    feas = r.archive_viol <= 0
    assert feas.any()
    assert (r.archive_objs[feas, 0] <= 4.0 + 1e-6).all()
    infeas = (r.archive_objs[:, 0] > 4.0 + 1e-6)
    assert (r.archive_viol[infeas] > 0).all()


def test_sweep_lanes_match_single_runs():
    runner = fastmoo.CompiledNSGA2(
        _toy_objs_jax, n_bits=16, pop_size=16, n_gen=8,
        hv_ref=np.array([9.0, 9.0]),
    )
    seeds = [0, 1, 0]
    bounds = [(1e30, 1e30), (1e30, 1e30), (5.0, 5.0)]
    lanes = runner.run_sweep(seeds, bounds)
    for seed, (mb, mp), lane in zip(seeds, bounds, lanes):
        single = runner.run(seed=seed, max_behav=mb, max_ppa=mp)
        np.testing.assert_array_equal(lane.archive_configs, single.archive_configs)
        np.testing.assert_allclose(
            lane.archive_objs, single.archive_objs, rtol=1e-6
        )
        np.testing.assert_allclose(
            [h for _, h in lane.hv_history],
            [h for _, h in single.hv_history],
            rtol=1e-6,
        )


# ---------------------------------------------------------------------------
# Surrogate-driven runs through the DSE layer (8-bit acceptance parity)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fitted8():
    from repro.core.automl import fit_estimators
    from repro.core.dataset import BEHAV_KEY, PPA_KEY, build_training_dataset
    from repro.core.operator_model import spec_for

    spec = spec_for(8)
    ds = build_training_dataset(spec, n_random=150, seed=0, backend="jax")
    ests = fit_estimators(
        ds.configs.astype(np.float64),
        {BEHAV_KEY: ds.metrics[BEHAV_KEY], PPA_KEY: ds.metrics[PPA_KEY]},
        n_quad=16,
        seed=0,
    )
    return spec, ds, ests


@pytest.mark.slow
def test_hv_parity_8bit_surrogate(fitted8):
    """Acceptance: feasible-archive hv within 2% of the numpy oracle (L=36)."""
    from repro.core.dataset import BEHAV_KEY, PPA_KEY
    from repro.core.fastchar import compile_surrogate_batch

    spec, ds, ests = fitted8
    mb = float(ds.metrics[BEHAV_KEY].max())
    mp = float(ds.metrics[PPA_KEY].max())
    ref = np.array([1.05 * mb, 1.05 * mp])
    fn = compile_surrogate_batch(ests, BEHAV_KEY, PPA_KEY, mb, mp)

    r_np = nsga2(None, n_bits=spec.n_luts, pop_size=32, n_gen=30, seed=0,
                 eval_viol_fn=fn, hv_ref=ref)
    r_jx = nsga2(None, n_bits=spec.n_luts, pop_size=32, n_gen=30, seed=0,
                 backend="jax", objs_device_fn=fn.objs_fn,
                 max_behav=mb, max_ppa=mp, hv_ref=ref)
    hv_np = r_np.hv_history[-1][1]
    hv_jx = r_jx.hv_history[-1][1]
    assert hv_np > 0
    assert abs(hv_jx - hv_np) <= 0.02 * hv_np


@pytest.mark.slow
def test_run_dse_sweep_single_dispatch(fitted8):
    """Multi-seed / multi-constraint grid end-to-end through run_dse_sweep."""
    from repro.core.dse import DSESettings, run_dse, run_dse_sweep

    spec, ds, ests = fitted8
    st = DSESettings(pop_size=16, n_gen=6, n_quad_grid=(0,), pool_size=2,
                     seed=0, backend="jax")
    results = run_dse_sweep(
        spec, ds, "ga", settings=st, seeds=(0, 1), const_sf_grid=(0.5, 1.5),
        estimators=ests,
    )
    assert len(results) == 4
    sfs = [r.settings.const_sf for r in results]
    assert sfs == [0.5, 0.5, 1.5, 1.5]
    assert [r.settings.seed for r in results] == [0, 1, 0, 1]
    for r in results:
        assert r.n_evals == 16 * 7
        assert r.hv_ppf >= 0 and r.hv_vpf >= 0
    # a sweep lane reproduces the equivalent single run_dse call
    single = run_dse(spec, ds, "ga", settings=st, estimators=ests)
    lane = [r for r in results if r.settings.seed == 0][0]
    assert lane.settings.const_sf == 0.5
    st05 = DSESettings(pop_size=16, n_gen=6, n_quad_grid=(0,), pool_size=2,
                       seed=0, backend="jax", const_sf=0.5)
    single05 = run_dse(spec, ds, "ga", settings=st05, estimators=ests)
    np.testing.assert_allclose(lane.hv_ppf, single05.hv_ppf, rtol=1e-5)


def test_run_dse_ga_backend_numpy_override(fitted8):
    """backend='jax' + ga_backend='numpy' keeps the host GA (hybrid path)."""
    from repro.core.dse import DSESettings, run_dse

    spec, ds, ests = fitted8
    st = DSESettings(pop_size=12, n_gen=3, n_quad_grid=(0,), pool_size=2,
                     seed=0, backend="jax", ga_backend="numpy")
    r = run_dse(spec, ds, "ga", settings=st, estimators=ests)
    assert r.n_evals == 12 * 4
    with pytest.raises(ValueError):
        DSESettings(ga_backend="torch")
