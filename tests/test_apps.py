"""Application substrate: ECG / MNIST / GAUSS / FFN behavioral metrics."""

import numpy as np
import pytest

from repro.apps import APPLICATIONS
from repro.core.dataset import gen_random
from repro.core.operator_model import accurate_config, spec_for


@pytest.mark.parametrize("name", sorted(APPLICATIONS))
@pytest.mark.parametrize("n_bits", [4, 8])
def test_accurate_operator_is_the_reference(name, n_bits):
    """The accurate config reproduces the reference pipeline exactly, so its
    BEHAV penalty must be the per-app floor (0 for error-vs-accurate apps)."""
    spec = spec_for(n_bits)
    app = APPLICATIONS[name]()
    acc = app.behav(spec, accurate_config(spec)[None])[0]
    if name == "mnist":
        # classification error vs true labels: floor is the int8-accurate error
        assert acc < 15.0
    else:
        assert acc == 0.0


@pytest.mark.parametrize("name", sorted(APPLICATIONS))
def test_destroying_the_operator_destroys_behaviour(name):
    spec = spec_for(8)
    app = APPLICATIONS[name]()
    zero = app.behav(spec, np.zeros((1, spec.n_luts), np.uint8))[0]
    acc = app.behav(spec, accurate_config(spec)[None])[0]
    assert zero > acc


@pytest.mark.parametrize("name", sorted(APPLICATIONS))
def test_behav_batch_consistency(name):
    spec = spec_for(4)
    app = APPLICATIONS[name]()
    cfgs = gen_random(spec, 6, seed=3)
    batch = app.behav(spec, cfgs)
    singles = np.array([app.behav(spec, c[None])[0] for c in cfgs])
    np.testing.assert_allclose(batch, singles)


def test_characterize_fn_interface():
    spec = spec_for(4)
    app = APPLICATIONS["gauss"]()
    fn = app.characterize_fn(spec)
    out = fn(gen_random(spec, 4, seed=1))
    assert out.shape == (4, 2)
    assert np.isfinite(out).all()
