"""Per-kernel parity vs pure-jnp oracles (interpret=True on CPU), with
shape/dtype sweeps as required for each Pallas kernel."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.operator_model import error_tables, exact_product_table, spec_for
from repro.kernels import axo_matmul, flash_attention, ssd_scan
from repro.kernels.ref import (
    ref_axo_matmul_exact,
    ref_axo_matmul_lowrank,
    ref_flash_attention,
    ref_ssd_scan,
)

RNG = np.random.default_rng(0)


def _factors(n_bits: int, rank: int, seed: int = 0):
    spec = spec_for(n_bits)
    rng = np.random.default_rng(seed)
    cfg = rng.integers(0, 2, spec.n_luts).astype(np.uint8)
    err = error_tables(spec, cfg[None])[0].astype(np.float64)
    u, s, vt = np.linalg.svd(err)
    f = (u[:, :rank] * s[:rank]).astype(np.float32)
    g = vt[:rank].T.astype(np.float32)
    table = (exact_product_table(n_bits).astype(np.int64) + err.astype(np.int64))
    return spec, f, g, table.astype(np.int32)


# ---------------------------------------------------------------------------
# axo_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rank", [1, 2, 8])
@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 128, 384)])
def test_axo_matmul_kernel_matches_ref(rank, mkn):
    m, k, n = mkn
    spec, f, g, _ = _factors(8, rank)
    a = RNG.integers(0, 256, (m, k))
    b = RNG.integers(0, 256, (k, n))
    sv = jnp.asarray(spec.operand_values, jnp.float32)
    out = axo_matmul(jnp.asarray(a), jnp.asarray(b), jnp.asarray(f), jnp.asarray(g), sv)
    ref = ref_axo_matmul_lowrank(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(f), jnp.asarray(g), sv)
    scale = float(jnp.max(jnp.abs(ref))) + 1.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5 * scale, rtol=1e-5)


def test_axo_matmul_block_shapes_are_equivalent():
    spec, f, g, _ = _factors(8, 4)
    a = RNG.integers(0, 256, (256, 256))
    b = RNG.integers(0, 256, (256, 256))
    sv = jnp.asarray(spec.operand_values, jnp.float32)
    outs = [
        np.asarray(axo_matmul(jnp.asarray(a), jnp.asarray(b), jnp.asarray(f),
                              jnp.asarray(g), sv, bm=bm, bn=bn, bk=bk))
        for bm, bn, bk in [(128, 128, 128), (256, 128, 128), (128, 256, 256)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-2)


@pytest.mark.parametrize("mkn", [
    (4, 128, 128),      # decode microbatch: the old % 128 gate rejected M=4
    (100, 130, 70),     # every axis awkward
    (192, 256, 64),     # head_dim-sized N
    (1, 64, 129),       # single row, lane spill
])
def test_axo_matmul_pads_awkward_shapes(mkn):
    """The wrapper pads to the block grid and slices -- parity with the
    reference at shapes the kernel grid cannot tile natively."""
    m, k, n = mkn
    spec, f, g, _ = _factors(8, 3)
    a = RNG.integers(0, 256, (m, k))
    b = RNG.integers(0, 256, (k, n))
    sv = jnp.asarray(spec.operand_values, jnp.float32)
    out = axo_matmul(jnp.asarray(a), jnp.asarray(b), jnp.asarray(f),
                     jnp.asarray(g), sv)
    ref = ref_axo_matmul_lowrank(jnp.asarray(a), jnp.asarray(b),
                                 jnp.asarray(f), jnp.asarray(g), sv)
    assert out.shape == (m, n)
    scale = float(jnp.max(jnp.abs(ref))) + 1.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5 * scale, rtol=1e-5)


def test_lowrank_error_converges_to_exact_table():
    """Rank sweep: residual vs the bit-exact table path must shrink with R."""
    a = RNG.integers(0, 256, (64, 64))
    b = RNG.integers(0, 256, (64, 64))
    errs = []
    for rank in (1, 4, 16, 64):
        spec, f, g, table = _factors(8, rank, seed=1)
        sv = jnp.asarray(spec.operand_values, jnp.float32)
        low = ref_axo_matmul_lowrank(jnp.asarray(a), jnp.asarray(b),
                                     jnp.asarray(f), jnp.asarray(g), sv)
        exact = ref_axo_matmul_exact(jnp.asarray(a), jnp.asarray(b),
                                     jnp.asarray(table)).astype(jnp.float32)
        errs.append(float(jnp.linalg.norm(low - exact) / jnp.linalg.norm(exact)))
    assert errs[-1] < 1e-4
    assert errs == sorted(errs, reverse=True)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [
    (2, 4, 4, 256, 64),     # MHA
    (1, 8, 2, 384, 128),    # GQA 4:1
    (2, 4, 1, 128, 64),     # MQA
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(shape, causal, dtype):
    b, h, g, s, hd = shape
    q = jnp.asarray(RNG.standard_normal((b, h, s, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, g, s, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, g, s, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal)
    ref = ref_flash_attention(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("shape", [
    (1, 2, 2, 192, 64),     # seq not a multiple of the default bq
    (2, 2, 1, 100, 32),     # awkward seq + head_dim
    (1, 4, 4, 56, 16),      # shorter than any native block
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_pads_awkward_shapes(shape, causal):
    """Padded KV columns are masked to -inf (static kv_len), so parity must
    hold for sequence lengths the block grid cannot tile natively."""
    b, h, g, s, hd = shape
    q = jnp.asarray(RNG.standard_normal((b, h, s, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, g, s, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, g, s, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal)
    ref = ref_flash_attention(q, k, v, causal=causal)
    assert out.shape == (b, h, s, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


def test_flash_attention_block_shape_invariance():
    q = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    a = flash_attention(q, k, v, bq=128, bk=128)
    b = flash_attention(q, k, v, bq=64, bk=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-6)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [
    (2, 256, 4, 1, 16, 32, 64),
    (1, 128, 8, 2, 8, 16, 32),
    (1, 64, 4, 4, 8, 8, 64),    # chunk == S (single chunk)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_sequential_ref(shape, dtype):
    b, s, h, g, p, n, chunk = shape
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    bm = jnp.asarray(RNG.standard_normal((b, s, g, n)), dtype)
    cm = jnp.asarray(RNG.standard_normal((b, s, g, n)), dtype)
    y, hf = ssd_scan(x, dt, a, bm, cm, chunk=chunk)
    yr, hr = ref_ssd_scan(x, dt, a, bm, cm)
    tol = 2e-5 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# behav stats (characterization reduction)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_bits,d,d_block,a_tile", [
    (4, 8, 8, 16),       # single A tile
    (4, 16, 4, 8),       # multi-tile, small blocks
    (8, 8, 8, 64),       # 8x8 default tiling
    (8, 16, 8, 32),      # 8x8 alternate tiling
])
def test_behav_stats_kernel_matches_xla_twin(n_bits, d, d_block, a_tile):
    """Pallas kernel partials (interpret=True) vs the jit'd XLA twin: integer
    channels bit-equal, f32 relative-error channel allclose."""
    from repro.core.fastchar import _device_tables, _gather_small, _partials_xla
    from repro.core.operator_model import config_to_masks, spec_for
    from repro.kernels.char_kernels import behav_stats_pallas

    spec = spec_for(n_bits)
    rng = np.random.default_rng(n_bits * 100 + d)
    cfgs = rng.integers(0, 2, (d, spec.n_luts)).astype(np.uint8)
    cfgs[0] = 0
    cfgs[-1] = 1
    masks = jnp.asarray(config_to_masks(spec, cfgs).astype(np.int32))

    _, exact, w, _ = _device_tables(n_bits)
    small = _gather_small(masks, n_bits)
    int_k, rel_k = behav_stats_pallas(
        small, jnp.asarray(exact), jnp.asarray(w),
        d_block=d_block, a_tile=a_tile, interpret=True,
    )
    int_x, rel_x = _partials_xla(masks, n_bits, a_tile, d_block)
    np.testing.assert_array_equal(np.asarray(int_k), np.asarray(int_x))
    np.testing.assert_allclose(
        np.asarray(rel_k), np.asarray(rel_x), rtol=1e-6, atol=1e-6
    )


def test_behav_stats_kernel_block_shapes_are_equivalent():
    """Combined metrics are invariant to (d_block, a_tile) kernel tiling."""
    from repro.core.fastchar import behav_metrics_jax
    from repro.core.operator_model import spec_for

    spec = spec_for(4)
    rng = np.random.default_rng(7)
    cfgs = rng.integers(0, 2, (8, spec.n_luts)).astype(np.uint8)
    outs = [
        behav_metrics_jax(spec, cfgs, impl="pallas", interpret=True,
                          d_block=db, a_tile=at)
        for db, at in [(8, 16), (4, 8), (2, 4)]
    ]
    for o in outs[1:]:
        for k in outs[0]:
            if k == "AVG_ABS_REL_ERR":
                np.testing.assert_allclose(o[k], outs[0][k], rtol=1e-6)
            else:
                np.testing.assert_array_equal(o[k], outs[0][k], err_msg=k)


def test_ssd_scan_matches_xla_chunked_path():
    """Kernel vs the model's XLA ssd_chunked (the execution path)."""
    from repro.models.ssm import ssd_chunked

    b, s, h, g, p, n = 2, 128, 4, 1, 16, 32
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    bm = jnp.asarray(RNG.standard_normal((b, s, g, n)), jnp.float32)
    cm = jnp.asarray(RNG.standard_normal((b, s, g, n)), jnp.float32)
    y1, h1 = ssd_scan(x, dt, a, bm, cm, chunk=32)
    y2, h2 = ssd_chunked(x, dt, a, bm, cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-5)
