"""Per-arch smoke tests: REDUCED same-family configs, one forward/train step on
CPU, asserting output shapes + no NaNs (full configs only ever dry-run)."""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCH_IDS, get_arch
from repro.data.synthetic import SyntheticLM
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models.model import compute_loss, forward, logits_fn, model_spec
from repro.models.sharding import BASE_RULES
from repro.models.spec import count_params, init_params
from repro.optim import cosine_schedule, make_optimizer

SHAPE = ShapeConfig("smoke", 32, 2, "train")
RULES = BASE_RULES


def _setup(arch_id, dtype=jnp.bfloat16, seed=0):
    cfg = get_arch(arch_id).reduced()
    params = init_params(model_spec(cfg), seed=seed, dtype=dtype)
    data = SyntheticLM(cfg, SHAPE)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    return cfg, params, batch


@pytest.mark.parametrize("arch_id", sorted(ARCH_IDS))
def test_forward_loss_shapes_and_finiteness(arch_id):
    cfg, params, batch = _setup(arch_id)
    loss, metrics = jax.jit(lambda p, b: compute_loss(p, cfg, RULES, b))(params, batch)
    assert jnp.isfinite(loss), metrics
    assert 0.0 < float(loss) < 20.0
    assert count_params(model_spec(cfg)) > 0


@pytest.mark.parametrize("arch_id", sorted(ARCH_IDS))
def test_one_train_step_updates_params_finite(arch_id):
    cfg, params, batch = _setup(arch_id)
    opt = make_optimizer(cfg.optimizer, cosine_schedule(1e-3, warmup_steps=1))
    step_fn = jax.jit(make_train_step(cfg, RULES, opt))
    opt_state = opt.init(params)
    new_params, _, metrics = step_fn(params, opt_state, jnp.int32(0), batch)
    assert jnp.isfinite(metrics["loss"])
    # at least one leaf moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


def test_train_loss_decreases_internlm2():
    cfg, params, _ = _setup("internlm2-1.8b", dtype=jnp.float32)
    data = SyntheticLM(cfg, SHAPE, seed=1)
    opt = make_optimizer("adamw", cosine_schedule(3e-3, warmup_steps=2, total_steps=30))
    step_fn = jax.jit(make_train_step(cfg, RULES, opt))
    opt_state = opt.init(params)
    losses = []
    for t in range(12):
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}  # overfit one batch
        params, opt_state, metrics = step_fn(params, opt_state, jnp.int32(t), batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses


@pytest.mark.parametrize("arch_id", ["granite-3-2b", "mamba2-130m", "whisper-medium",
                                     "deepseek-v3-671b"])
def test_prefill_decode_matches_full_context_fp32(arch_id):
    """Decode math is exact in fp32: prefill 12 + decode 4 == full forward.

    MoE capacity scales with the token count, so capacity DROPS would differ
    legitimately between a prefix prefill and the full pass -- the exactness
    invariant holds in the no-drop regime (capacity_factor high)."""
    from dataclasses import replace

    cfg, params, batch = _setup(arch_id, dtype=jnp.float32)
    if cfg.moe is not None:
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=16.0))
        params = init_params(model_spec(cfg), seed=0, dtype=jnp.float32)
    toks = batch["tokens"]
    kw = {}
    if "enc_embeds" in batch:
        kw["frontend"] = batch["enc_embeds"].astype(jnp.float32)
    if "img_embeds" in batch:
        kw["frontend"] = batch["img_embeds"].astype(jnp.float32)

    fwd_kw = {}
    if cfg.encoder is not None:
        fwd_kw["enc_embeds"] = kw["frontend"]
    if cfg.n_img_tokens:
        fwd_kw["img_embeds"] = kw["frontend"]
    x, _, _ = jax.jit(partial(forward, cfg=cfg, rules=RULES, mode="train"))(
        params, tokens=toks, **fwd_kw)
    ref = logits_fn(params, cfg, RULES, x)

    pre = jax.jit(make_prefill_step(cfg, RULES, max_seq=toks.shape[1]))
    dec = jax.jit(make_decode_step(cfg, RULES))
    args = (params, toks[:, :12], kw["frontend"]) if kw else (params, toks[:, :12])
    lg, cache = pre(*args)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(ref[:, 11]), atol=2e-3, rtol=1e-3)
    for i in range(12, 16):
        lg, cache = dec(params, cache, toks[:, i:i + 1], jnp.int32(i))
        if i < toks.shape[1] - 1:
            np.testing.assert_allclose(
                np.asarray(lg[:, 0]), np.asarray(ref[:, i]), atol=2e-3, rtol=1e-3)


def test_mtp_loss_present_for_dsv3():
    cfg, params, batch = _setup("deepseek-v3-671b")
    _, metrics = jax.jit(lambda p, b: compute_loss(p, cfg, RULES, b))(params, batch)
    assert "mtp_ce" in metrics and jnp.isfinite(metrics["mtp_ce"])
