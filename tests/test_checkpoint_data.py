"""Checkpointing (atomicity, retention, elastic template restore) and the
deterministic seekable data pipeline."""

import os

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.checkpoint.ckpt import latest_step
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_arch
from repro.data.synthetic import SyntheticLM


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32),
                   "b": jnp.asarray(rng.standard_normal(4), jnp.bfloat16)},
        "opt": [jnp.zeros(3), jnp.ones(2, jnp.int32)],
    }


def test_save_restore_bitwise_roundtrip(tmp_path):
    import jax

    tree = _tree()
    save_tree(str(tmp_path), 7, tree)
    got = restore_tree(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_no_tmp_litter_and_latest_step(tmp_path):
    tree = _tree()
    save_tree(str(tmp_path), 1, tree)
    save_tree(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    assert not [f for f in os.listdir(tmp_path) if ".tmp" in f]


def test_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    tree = _tree()
    for s in range(5):
        mgr.save(s, tree)
    mgr.wait()
    steps = sorted(int(f[5:13]) for f in os.listdir(tmp_path) if f.endswith(".json"))
    assert steps == [3, 4]
    got, step = mgr.restore(tree)
    assert step == 4 and got is not None


def test_restore_is_mesh_independent_layout(tmp_path):
    """Leaves are saved unsharded -> restoring onto any template works."""
    tree = _tree(1)
    save_tree(str(tmp_path), 0, tree)
    # a template with same structure but abstract leaves
    import jax

    template = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), tree)
    got = restore_tree(str(tmp_path), 0, template)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_is_deterministic_and_seekable():
    cfg = get_arch("internlm2-1.8b").reduced()
    shape = ShapeConfig("t", 64, 4, "train")
    d1 = SyntheticLM(cfg, shape, seed=3)
    d2 = SyntheticLM(cfg, shape, seed=3)
    for step in (0, 17, 123456):
        b1, b2 = d1.batch(step), d2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    # different steps differ
    assert not np.array_equal(d1.batch(0)["tokens"], d1.batch(1)["tokens"])


def test_labels_are_shifted_tokens():
    cfg = get_arch("granite-3-2b").reduced()
    d = SyntheticLM(cfg, ShapeConfig("t", 32, 2, "train"), seed=0)
    b = d.batch(5)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()
    assert (b["tokens"] >= 0).all() and (b["tokens"] < cfg.vocab).all()


def test_frontend_stubs_present():
    wcfg = get_arch("whisper-medium").reduced()
    b = SyntheticLM(wcfg, ShapeConfig("t", 16, 2, "train")).batch(0)
    assert b["enc_embeds"].shape == (2, wcfg.encoder.n_ctx, wcfg.d_model)
    vcfg = get_arch("llama-3.2-vision-90b").reduced()
    b = SyntheticLM(vcfg, ShapeConfig("t", 16, 2, "train")).batch(0)
    assert b["img_embeds"].shape == (2, vcfg.n_img_tokens, vcfg.d_model)
