"""AxO deployment: rank-R factorization quality and axo_linear semantics."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.axo import AxOOperator, axo_linear, quantize_tensor
from repro.core.operator_model import accurate_config, spec_for

RNG = np.random.default_rng(0)


def _random_config(seed=0):
    spec = spec_for(8)
    return np.random.default_rng(seed).integers(0, 2, spec.n_luts).astype(np.uint8)


def test_accurate_operator_has_zero_error_tables():
    spec = spec_for(8)
    op = AxOOperator.from_config(accurate_config(spec), rank=4)
    b = op.rank_behav()
    assert b["MAX_ABS_ERR"] < 1e-6
    # axo_linear == plain quantized matmul for the accurate operator
    x = jnp.asarray(RNG.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((16, 4)), jnp.float32)
    y = axo_linear(x, w, op)
    xq, sx = quantize_tensor(x)
    wq, sw = quantize_tensor(w)
    half = 128
    xs = jnp.where(xq >= half, xq - 256, xq).astype(jnp.float32)
    ws = jnp.where(wq >= half, wq - 256, wq).astype(jnp.float32)
    ref = (xs @ ws) * (sx * sw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_rank_behav_improves_with_rank():
    cfg = _random_config(1)
    errs = [AxOOperator.from_config(cfg, rank=r).rank_behav()["AVG_ABS_ERR"]
            for r in (1, 4, 16, 64)]
    # non-increasing (ties possible once R exceeds the error table's true rank)
    for lo, hi in zip(errs[1:], errs[:-1]):
        assert lo <= hi * (1 + 1e-9)
    assert errs[-1] < 0.05 * (errs[0] + 1e-9)


def test_axo_linear_converges_to_true_operator_semantics():
    """With growing rank, axo_linear approaches the bit-exact table matmul."""
    from repro.kernels.ref import ref_axo_matmul_exact

    cfg = _random_config(2)
    x = jnp.asarray(RNG.standard_normal((16, 32)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((32, 8)), jnp.float32)
    rel = []
    for r in (1, 8, 32):
        op = AxOOperator.from_config(cfg, rank=r)
        xq, sx = quantize_tensor(x)
        wq, sw = quantize_tensor(w)
        true = ref_axo_matmul_exact(xq, wq, jnp.asarray(op.table)).astype(
            jnp.float32) * (sx * sw)
        y = axo_linear(x, w, op)
        rel.append(float(jnp.linalg.norm(y - true) / jnp.linalg.norm(true)))
    assert rel == sorted(rel, reverse=True)
    assert rel[-1] < 0.02


def test_axo_linear_uses_kernel_on_aligned_shapes():
    cfg = _random_config(3)
    op = AxOOperator.from_config(cfg, rank=4)
    x = jnp.asarray(RNG.standard_normal((128, 128)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((128, 128)), jnp.float32)
    y_kernel = axo_linear(x, w, op, use_kernel=True)
    y_ref = axo_linear(x, w, op, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_axo_linear_batched_shape():
    op = AxOOperator.from_config(_random_config(4), rank=2)
    x = jnp.asarray(RNG.standard_normal((2, 5, 16)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((16, 6)), jnp.float32)
    assert axo_linear(x, w, op).shape == (2, 5, 6)
