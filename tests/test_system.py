"""End-to-end system behaviour: the paper's DSE feeding the framework's
serving arithmetic, plus a short fault-tolerant LM training run."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import TransformerFFN
from repro.axo import AxOOperator, axo_linear
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_arch
from repro.core.dataset import build_training_dataset
from repro.core.dse import DSESettings, hv_reference, map_solution_pool, run_dse
from repro.core.operator_model import spec_for
from repro.data.synthetic import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models.model import model_spec
from repro.models.spec import init_params
from repro.models.sharding import BASE_RULES
from repro.optim import cosine_schedule, make_optimizer
from repro.train import TrainLoopConfig, train_loop


def test_dse_to_deployment_pipeline():
    """Paper loop end-to-end: characterize -> MaP+GA DSE -> pick a Pareto
    config -> deploy it as serving arithmetic via rank-R axo_linear."""
    spec = spec_for(4)
    ds = build_training_dataset(spec, n_random=200, seed=0)
    st = DSESettings(const_sf=1.0, pop_size=16, n_gen=8, n_quad_grid=(0, 4),
                     pool_size=4, seed=0)
    pool = map_solution_pool(spec, ds, st)
    res = run_dse(spec, ds, "map+ga", settings=st, map_pool=pool)
    assert len(res.vpf_configs) > 0

    # deploy the lowest-BEHAV front point inside an FFN block
    best = res.vpf_configs[int(np.argmin(res.vpf_objs[:, 0]))]
    op = AxOOperator.from_config(best, rank=8, n_bits=4)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((16, 32)) * 0.3, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((32, 16)) * 0.3, jnp.float32)
    h = jax.nn.gelu(axo_linear(x, w1, op))
    y = axo_linear(h, w2, op)

    # Correctness contract: the deployed rank-R path tracks the BIT-EXACT
    # approximate-operator pipeline (same quantization, table semantics).
    # Deviation from the float pipeline is the *operator's* BEHAV cost the
    # DSE deliberately traded -- it is characterized, not asserted small.
    from repro.axo import quantize_tensor
    from repro.kernels.ref import ref_axo_matmul_exact

    def table_layer(inp, w):
        iq, si = quantize_tensor(inp, op.n_bits)
        wq, sw = quantize_tensor(w, op.n_bits)
        return ref_axo_matmul_exact(iq, wq, jnp.asarray(op.table)).astype(
            jnp.float32) * (si * sw)

    h_t = jax.nn.gelu(table_layer(x, w1))
    y_t = table_layer(h_t, w2)
    rel_exact = float(jnp.linalg.norm(y - y_t)
                      / max(float(jnp.linalg.norm(y_t)), 1e-9))
    assert rel_exact < 0.15, rel_exact  # rank-8 of a 16x16 error table ~ exact

    ref = jax.nn.gelu(x @ w1) @ w2
    rel_float = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    assert np.isfinite(rel_float)  # characterized, not bounded: the DSE's trade

    # the FFN application's BEHAV agrees in direction: this config scores
    # better than the all-zeros (destroyed) operator
    app = TransformerFFN()
    b = app.behav(spec, np.stack([best, np.ones_like(best)]))
    # the accurate operator is the app-level floor; the selected design's
    # app-level penalty is finite and characterized (the relative-L2 metric
    # saturates near 100 for aggressive approximations, so no ordering vs the
    # destroyed operator is implied)
    assert b[1] == 0.0
    assert np.isfinite(b[0]) and b[0] >= b[1]


def test_fault_tolerant_lm_training(tmp_path):
    """A real (reduced) LM trained through the fault-tolerant loop with an
    injected failure finishes and matches the clean run's loss history."""
    cfg = get_arch("granite-3-2b").reduced()
    shape = ShapeConfig("t", 32, 2, "train")
    data = SyntheticLM(cfg, shape, seed=0)
    opt = make_optimizer("adamw", cosine_schedule(1e-3))
    step_jit = jax.jit(make_train_step(cfg, BASE_RULES, opt))

    def init_state():
        params = init_params(model_spec(cfg), seed=0, dtype=jnp.float32)
        return params, opt.init(params)

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in data.batch(step).items()}

    def step_fn(params, opt_state, step, batch):
        return step_jit(params, opt_state, jnp.int32(int(step)), batch)

    clean = train_loop(
        step_fn, init_state, batch_fn,
        TrainLoopConfig(total_steps=8, ckpt_every=4,
                        ckpt_dir=str(tmp_path / "clean"), async_ckpt=False),
    )

    fired = {"n": 0}

    def fault(step):
        if step == 5 and fired["n"] == 0:
            fired["n"] += 1
            raise RuntimeError("injected failure")

    faulty = train_loop(
        step_fn, init_state, batch_fn,
        TrainLoopConfig(total_steps=8, ckpt_every=4,
                        ckpt_dir=str(tmp_path / "faulty"), async_ckpt=False),
        fault_hook=fault,
    )
    assert faulty["restarts"] == 1
    clean_losses = [l for _, l in clean["history"]]
    faulty_losses = [l for _, l in faulty["history"]]
    np.testing.assert_allclose(faulty_losses, clean_losses, rtol=1e-5)
