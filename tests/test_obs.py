"""Telemetry subsystem acceptance (repro.obs).

Four contracts:

  * **Spans** nest via a contextvar stack (thread-isolated), round-trip
    through JSONL, and export to Chrome-trace JSON with parent containment.
  * **Device taps** are per-*dispatch* ``io_callback`` sinks: a tap inside a
    ``fori_loop`` fires N times per compiled-program execution (never once
    per trace), and a disabled (NULL) tap stages nothing -- the program is
    bit-identical to an uninstrumented build.
  * **CompiledNSGA2** with ``telemetry="on"`` emits a per-generation
    feasible-front hypervolume curve (incremental front buffer, O(front)
    per generation) that is monotone; the checkpoint hv history stays
    archive-based and **bit-identical** to the untapped program's.
  * **run_dse** stage spans cover >= 95% of the run's wall clock, and
    ``DSEResult.timings`` records the stages regardless of telemetry state.
"""

import json
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.engine import ExecutionContext
from repro.obs import device as obs_device
from repro.obs import telemetry as tm
from repro.obs.export import chrome_trace_dict, read_jsonl

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Spans: nesting, threads, export round-trip
# ---------------------------------------------------------------------------


def test_span_nesting_parent_ids():
    tel = tm.Telemetry("t")
    with tel.span("outer", method="ga") as outer:
        with tel.span("inner") as inner:
            pass
        with tel.span("inner2") as inner2:
            pass
    spans = {s.name: s for s in tel.spans}
    assert set(spans) == {"outer", "inner", "inner2"}
    assert spans["outer"].parent_id is None
    assert spans["inner"].parent_id == spans["outer"].span_id
    assert spans["inner2"].parent_id == spans["outer"].span_id
    assert spans["outer"].attrs == {"method": "ga"}
    # children finished before the parent, and lie inside it
    assert spans["outer"].t0 <= spans["inner"].t0
    assert spans["inner"].t1 <= spans["outer"].t1
    assert outer.duration_s >= inner.duration_s + inner2.duration_s


def test_wrap_decorator():
    tel = tm.Telemetry("t")

    @tel.wrap("work.unit", kind="test")
    def work(x):
        return x + 1

    assert work(2) == 3
    (sp,) = tel.spans
    assert sp.name == "work.unit" and sp.attrs == {"kind": "test"}


def test_span_stack_is_thread_isolated():
    tel = tm.Telemetry("t")

    def worker():
        with tel.span("in-thread"):
            pass

    with tel.span("root"):
        th = threading.Thread(target=worker)
        th.start()
        th.join()
    spans = {s.name: s for s in tel.spans}
    # a fresh thread starts with an empty span stack: no cross-thread parent
    assert spans["in-thread"].parent_id is None
    assert spans["in-thread"].tid != spans["root"].tid


def test_jsonl_round_trip(tmp_path):
    tel = tm.Telemetry("t")
    with tel.span("a", n=3):
        with tel.span("b"):
            pass
    tel.count("c.x", 2)
    tel.gauge("g.y", 0.5)
    tel.observe("h.z", 1.0)
    tel.observe("h.z", 3.0)
    tel.emit("s.w", {"gen": 1, "hv": 0.25})
    path = tmp_path / "tel.jsonl"
    tel.to_jsonl(str(path))
    recs = read_jsonl(str(path))
    by_type = {}
    for r in recs:
        by_type.setdefault(r["type"], []).append(r)
    names = {r["name"] for r in by_type["span"]}
    assert names == {"a", "b"}
    b = next(r for r in by_type["span"] if r["name"] == "b")
    a = next(r for r in by_type["span"] if r["name"] == "a")
    assert b["parent_id"] == a["span_id"]
    assert by_type["counter"] == [{"type": "counter", "name": "c.x", "value": 2}]
    assert by_type["gauge"][0]["value"] == 0.5
    hist = by_type["histogram"][0]
    assert hist["count"] == 2 and hist["min"] == 1.0 and hist["max"] == 3.0
    assert by_type["series"][0]["records"] == [{"gen": 1, "hv": 0.25}]


def test_chrome_trace_structure(tmp_path):
    tel = tm.Telemetry("t")
    with tel.span("root", pop=16):
        with tel.span("child"):
            time.sleep(0.001)
    tel.count("dispatch.x", 4)
    d = chrome_trace_dict(tel)
    events = {e["name"]: e for e in d["traceEvents"]}
    assert events["root"]["ph"] == "X" and events["child"]["ph"] == "X"
    # child interval contained in root's, in the epoch-anchored us timeline
    r, c = events["root"], events["child"]
    assert r["ts"] <= c["ts"]
    assert c["ts"] + c["dur"] <= r["ts"] + r["dur"] + 1e-3
    assert r["args"] == {"pop": 16}
    assert d["otherData"]["counters"]["dispatch.x"] == 4
    # the file is plain JSON (what Perfetto loads)
    path = tmp_path / "trace.json"
    tel.to_chrome_trace(str(path))
    with open(path) as f:
        assert json.load(f)["traceEvents"]


# ---------------------------------------------------------------------------
# Metrics + the context plumbing
# ---------------------------------------------------------------------------


def test_counters_propagate_to_parent_spans_stay_local():
    parent = tm.Telemetry("parent")
    child = tm.Telemetry("child", parent=parent)
    child.count("k", 3)
    child.gauge("g", 1.5)
    child.observe("h", 2.0)
    with child.span("s"):
        pass
    assert parent.counter("k") == 3 and child.counter("k") == 3
    assert parent.gauges["g"] == 1.5
    assert parent.histogram_summary("h")["count"] == 1
    assert len(parent.spans) == 0 and len(child.spans) == 1
    # set_counter is a local write (STATS back-compat), not propagated
    child.set_counter("k", 0)
    assert child.counter("k") == 0 and parent.counter("k") == 3


def test_as_telemetry_and_context_normalization():
    assert tm.as_telemetry(None) is tm.GLOBAL
    assert tm.as_telemetry("off") is tm.NULL
    on = tm.as_telemetry("on")
    assert on.device_taps and on.parent is tm.GLOBAL
    assert tm.as_telemetry(on) is on
    with pytest.raises(ValueError):
        tm.as_telemetry("loud")

    ctx = ExecutionContext(backend="jax", telemetry="on")
    assert isinstance(ctx.telemetry, tm.Telemetry) and ctx.telemetry.device_taps
    assert ctx.tel is ctx.telemetry
    off = ExecutionContext(backend="jax", telemetry="off")
    assert off.telemetry is tm.NULL
    plain = ExecutionContext(backend="jax")
    assert plain.telemetry is None and plain.tel is tm.current()
    # contexts stay hashable (they key jit/memo caches all over the stack)
    assert hash(ctx) != 0 or True
    import dataclasses

    assert dataclasses.replace(ctx, tuning="off").telemetry is ctx.telemetry


def test_use_makes_a_sink_current():
    tel = tm.Telemetry("scoped")
    assert tm.current() is tm.GLOBAL
    with tm.use(tel):
        assert tm.current() is tel
        tm.current().count("seen")
    assert tm.current() is tm.GLOBAL
    assert tel.counter("seen") == 1 and tel.parent is None


def test_note_trace_counts_retraces_not_calls():
    tel = tm.Telemetry("t")
    with tm.use(tel):

        @jax.jit
        def f(x):
            tm.note_trace("f")
            return x + 1

        f(jnp.ones(2))
        f(jnp.ones(2))
        f(jnp.ones(2))
        assert tel.counter("jit.retrace.f") == 1
        f(jnp.ones(3))  # new shape -> one retrace
        assert tel.counter("jit.retrace.f") == 2


def test_record_pad_waste_from_kernel_launch():
    from repro.kernels.axo_matmul_kernel import axo_matmul_pallas

    tel = tm.Telemetry("t")
    rng = np.random.default_rng(0)
    m, k, n, rank = 4, 40, 12, 1
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    fa = rng.standard_normal((rank, m, k)).astype(np.float32)
    gb = rng.standard_normal((rank, k, n)).astype(np.float32)
    with tm.use(tel):
        axo_matmul_pallas(jnp.asarray(a), jnp.asarray(b), jnp.asarray(fa),
                          jnp.asarray(gb), interpret=True)
    # m=4->8, k=40->128, n=12->128: heavy padding on this tiny launch
    waste = tel.gauges["axo_matmul.pad_waste"]
    assert 0.9 < waste < 1.0
    assert tel.histogram_summary("axo_matmul.pad_waste")["count"] == 1


# ---------------------------------------------------------------------------
# The disabled path is a true no-op
# ---------------------------------------------------------------------------


def test_null_telemetry_records_nothing():
    tel = tm.NULL
    with tel.span("x", a=1):
        tel.count("c")
        tel.gauge("g", 1.0)
        tel.observe("h", 1.0)
        tel.emit("s", {"v": 1})
    assert not tel.counters and not tel.gauges
    assert not tel.histograms and not tel.series and not tel.spans
    assert tel.span("a") is tel.span("b")  # shared reusable CM
    fn = tel.wrap("w")(lambda: 7)
    assert fn() == 7 and not tel.spans


def test_null_tap_stages_nothing_into_the_program():
    live = tm.Telemetry("live")
    tap_live = live.device_tap("t", ("x",))
    tap_null = tm.NULL.device_tap("t", ("x",))

    def g_live(x):
        tap_live(x)
        return x * 2

    def g_null(x):
        tap_null(x)
        return x * 2

    def g_bare(x):
        return x * 2

    x = jnp.float32(1.0)
    assert "callback" in str(jax.make_jaxpr(g_live)(x))
    # disabled telemetry: the traced program is the uninstrumented program
    assert str(jax.make_jaxpr(g_null)(x)) == str(jax.make_jaxpr(g_bare)(x))


def test_disabled_telemetry_overhead_guard():
    """Per-op bound: instrumented hot paths make tens of telemetry calls per
    millisecond-scale dispatch, so sub-microsecond no-op calls keep the
    disabled path under the 1% acceptance budget with a wide margin."""
    tel = tm.NULL
    n = 50_000
    t0 = time.perf_counter()
    for i in range(n):
        with tel.span("x", a=i):
            tel.count("c")
            tel.observe("h", 1.0)
            tel.gauge("g", 1.0)
    per_op = (time.perf_counter() - t0) / (4 * n)
    assert per_op < 5e-6, f"null telemetry op took {per_op * 1e6:.2f}us"


# ---------------------------------------------------------------------------
# Device taps: once per dispatch, never once per trace
# ---------------------------------------------------------------------------


def test_tap_fires_per_dispatch_inside_fori_loop():
    tel = tm.Telemetry("t")
    tap = tel.device_tap("loop", ("i", "x"))

    @jax.jit
    def f(x):
        def body(i, acc):
            tap(i, acc)
            return acc + 1.0

        return jax.lax.fori_loop(0, 4, body, x)

    for _ in range(3):
        f(jnp.float32(0.0))
    obs_device.flush()
    # 4 loop iterations x 3 dispatches -- NOT 4 (per trace) or 1
    recs = tel.series["loop"]
    assert len(recs) == 12
    assert tel.counter("tap.loop") == 12
    assert sorted(int(r["i"]) for r in recs[:4]) == [0, 1, 2, 3]
    assert all("_host_t" in r for r in recs)


def test_tap_under_vmap_fires_per_lane():
    tel = tm.Telemetry("t")
    tap = tel.device_tap("lane", ("x",))

    @jax.jit
    def f(xs):
        def one(x):
            tap(x)
            return x * 2

        return jax.vmap(one)(xs)

    f(jnp.arange(3, dtype=jnp.float32))
    obs_device.flush()
    # one firing per batch element with the unbatched value -- the reason
    # sweep programs stay untapped (lanes would interleave into one series)
    recs = tel.series["lane"]
    assert len(recs) == 3
    assert sorted(float(r["x"]) for r in recs) == [0.0, 1.0, 2.0]


def test_tap_arity_is_checked():
    tap = tm.Telemetry("t").device_tap("t", ("a", "b"))
    with pytest.raises(TypeError):
        tap(jnp.float32(1.0))


def test_batched_tap_flushes_rows_and_drops_masked():
    tel = tm.Telemetry("t")
    tap = tel.device_batched_tap("chunk", ("g", "v"))

    @jax.jit
    def f():
        rows = jnp.stack(
            [
                jnp.array([0.0, 10.0], jnp.float32),
                jnp.array([1.0, 11.0], jnp.float32),
                jnp.array([-1.0, 0.0], jnp.float32),  # padding row
            ]
        )
        tap(rows, rows[:, 0] >= 0.0)
        return rows.sum()

    for _ in range(2):
        f()
    obs_device.flush()
    # one flush per dispatch -> 2 valid rows each; the masked padding row
    # never reaches the series or the counter
    recs = tel.series["chunk"]
    assert len(recs) == 4
    assert tel.counter("tap.chunk") == 4
    assert [int(r["g"]) for r in recs[:2]] == [0, 1]
    assert [float(r["v"]) for r in recs[:2]] == [10.0, 11.0]
    assert all("_host_t" in r for r in recs)


# ---------------------------------------------------------------------------
# Per-generation hypervolume from inside CompiledNSGA2's fori_loop
# ---------------------------------------------------------------------------


def _toy_objs(X):
    a = X[:, :8].sum(axis=1)
    b = (1.0 - X[:, 8:]).sum(axis=1)
    return jnp.stack([a, b], axis=-1)


def test_tapped_nsga2_per_generation_hv_curve():
    from repro.core.fastmoo import CompiledNSGA2

    ref = np.array([9.0, 9.0])
    ctx = ExecutionContext(backend="jax", telemetry="on")
    runner = CompiledNSGA2(_toy_objs, n_bits=16, pop_size=16, n_gen=10,
                           hv_ref=ref, ctx=ctx)
    assert runner._tapped
    r = runner.run(seed=0)
    tel = ctx.telemetry
    taps = tel.series["fastmoo.gen"]
    # one record per generation per dispatch
    assert len(taps) == 10
    assert [int(t["gen"]) for t in taps] == list(range(10))
    hvs = [float(t["hv"]) for t in taps]
    # front only grows -> per-generation hv is monotone non-decreasing
    assert all(b >= a for a, b in zip(hvs, hvs[1:]))
    # the tap hv comes from the incremental front buffer: equal to the
    # archive-based checkpoint up to f32 summation order (the checkpoint
    # history itself stays bitwise archive-based, asserted below)
    assert np.isclose(hvs[-1], r.hv_history[-1][1], rtol=1e-6)
    # constraint-violation stats + front size ride along
    assert all(float(t["pop_feas"]) == 1.0 for t in taps)  # unconstrained run
    assert all(int(t["arc_feasible"]) > 0 for t in taps)
    fronts = [int(t["front"]) for t in taps]
    assert all(0 < f <= runner.front_capacity for f in fronts)

    # a second dispatch accumulates (per dispatch, not per trace)
    runner.run(seed=1)
    assert len(tel.series["fastmoo.gen"]) == 20
    assert tel.counter("dispatch.fastmoo.run") == 2

    # the tapped program's recorded history matches the untapped program's
    plain = CompiledNSGA2(_toy_objs, n_bits=16, pop_size=16, n_gen=10,
                          hv_ref=ref)
    assert not plain._tapped
    r_plain = plain.run(seed=0)
    np.testing.assert_array_equal(
        [h for _, h in r.hv_history], [h for _, h in r_plain.hv_history]
    )


def test_untapped_context_emits_no_series():
    from repro.core.fastmoo import CompiledNSGA2

    tel = tm.Telemetry("quiet")  # device_taps defaults to False
    ctx = ExecutionContext(backend="jax", telemetry=tel)
    runner = CompiledNSGA2(_toy_objs, n_bits=16, pop_size=16, n_gen=4,
                           hv_ref=np.array([9.0, 9.0]), ctx=ctx)
    assert not runner._tapped
    runner.run(seed=0)
    assert "fastmoo.gen" not in tel.series
    assert tel.counter("dispatch.fastmoo.run") == 1  # counters still flow
    assert any(s.name == "fastmoo.run" for s in tel.spans)


# ---------------------------------------------------------------------------
# run_dse: stage spans, coverage, DSEResult.timings
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def ds4():
    from repro.core.dataset import build_training_dataset
    from repro.core.operator_model import spec_for

    spec = spec_for(4)
    ds = build_training_dataset(spec, n_random=80, seed=0, backend="jax")
    return spec, ds


def test_run_dse_spans_cover_wall_time(ds4, tmp_path):
    from repro.core.dse import DSESettings, run_dse

    spec, ds = ds4
    tel = tm.Telemetry("run", device_taps=True)
    st = DSESettings(pop_size=8, n_gen=3, n_quad_grid=(0,), pool_size=2,
                     seed=0, backend="jax")
    r = run_dse(spec, ds, "map+ga", settings=st, telemetry=tel)

    spans = list(tel.spans)
    root = next(s for s in spans if s.name == "dse.run")
    stage_names = {s.name for s in spans if s.parent_id == root.span_id}
    assert {"dse.characterize", "dse.map", "dse.ga", "dse.validate"} <= stage_names
    stage_total = sum(s.duration_s for s in spans
                      if s.parent_id == root.span_id)
    # acceptance: stage spans account for >= 95% of the run's wall clock
    assert stage_total >= 0.95 * root.duration_s

    # per-stage timings are recorded on the result and add up to wall_s
    assert set(r.timings) == {"characterize", "map", "ga", "validate"}
    assert all(v >= 0.0 for v in r.timings.values())
    assert sum(r.timings.values()) <= r.wall_s
    assert sum(r.timings.values()) >= 0.95 * r.wall_s

    # engines reported their dispatches into the same sink
    assert any(k.startswith("dispatch.") for k in tel.counters)
    assert any(k.startswith("registry.dispatch.") for k in tel.counters)

    # ... and the whole run exports as one Perfetto-loadable trace
    path = tmp_path / "dse_trace.json"
    tel.to_chrome_trace(str(path))
    with open(path) as f:
        names = {e["name"] for e in json.load(f)["traceEvents"]}
    assert "dse.run" in names and "dse.ga" in names


def test_run_dse_timings_without_telemetry(ds4):
    from repro.core.dse import DSESettings, run_dse

    spec, ds = ds4
    st = DSESettings(pop_size=8, n_gen=2, n_quad_grid=(0,), pool_size=2,
                     seed=0, backend="jax")
    # telemetry "off": stage timings still land on the result
    r = run_dse(spec, ds, "ga", settings=st, telemetry="off")
    assert set(r.timings) == {"characterize", "ga", "validate"}  # no map stage
    assert sum(r.timings.values()) <= r.wall_s
    assert all(v >= 0.0 for v in r.timings.values())


def test_run_dse_sweep_lane_timings(ds4):
    from repro.core.dse import DSESettings, run_dse_sweep

    spec, ds = ds4
    tel = tm.Telemetry("sweep")
    st = DSESettings(pop_size=8, n_gen=2, n_quad_grid=(0,), pool_size=2,
                     seed=0, backend="jax",
                     context=ExecutionContext(backend="jax", telemetry=tel))
    results = run_dse_sweep(spec, ds, "ga", settings=st, seeds=(0, 1),
                            const_sf_grid=(0.5, 1.5))
    assert len(results) == 4
    for r in results:
        # shared stages carry the whole-sweep duration; validate is per-lane
        assert {"characterize", "ga", "validate"} <= set(r.timings)
        assert r.timings["validate"] <= r.timings["ga"] + r.wall_s
    shared = {k: results[0].timings[k] for k in ("characterize", "ga")}
    assert all(r.timings["characterize"] == shared["characterize"]
               for r in results)
    names = {s.name for s in tel.spans}
    assert {"dse.sweep", "dse.characterize", "dse.ga", "dse.validate"} <= names
