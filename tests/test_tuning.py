"""Autotune cache acceptance: same (shape bucket, device) -> cache hit with
zero re-searches on the second resolution, on-disk round-trip, and cached
tiles bit-identical to default tiles under interpret mode."""

import json
import logging
import os

import numpy as np
import pytest

from repro.core.engine import ExecutionContext
from repro.kernels import registry, tuning
from repro.obs import telemetry as obs


@pytest.fixture()
def cache_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path))
    tuning.reset_stats()
    return tmp_path


def _cache_files(tmp_path):
    return [f for f in os.listdir(tmp_path) if f.endswith(".json")]


CTX = ExecutionContext(backend="jax", tuning="cached")
SHAPE = dict(n_bits=4, d=8, m=8, k=8, n=4)


def test_off_policy_never_touches_the_cache(cache_env):
    tiles = tuning.tiles_for(
        ExecutionContext(backend="jax"), "fastapp.xla", **SHAPE
    )
    assert tiles == {"d_chunk": 8}
    assert tuning.STATS["searches"] == 0
    assert not _cache_files(cache_env)


def test_cached_policy_searches_once_then_hits(cache_env):
    tiles1 = tuning.tiles_for(CTX, "fastapp.xla", **SHAPE)
    assert tuning.STATS["searches"] == 1
    assert len(_cache_files(cache_env)) == 1

    # same bucket (m=7 buckets to 8): NO re-search
    tiles2 = tuning.tiles_for(CTX, "fastapp.xla", n_bits=4, d=8, m=7, k=8, n=4)
    assert tiles2 == tiles1
    assert tuning.STATS["searches"] == 1


def test_second_run_round_trips_the_disk_cache(cache_env):
    """A fresh resolution (fresh TuningCache, as a new process would build)
    reuses the persisted winner with zero re-searches."""
    tiles1 = tuning.tiles_for(CTX, "fastapp.xla", **SHAPE)
    assert tuning.STATS["searches"] == 1

    tuning.reset_stats()  # "second run": only the on-disk state survives
    tiles2 = tuning.tiles_for(CTX, "fastapp.xla", **SHAPE)
    assert tiles2 == tiles1
    assert tuning.STATS["searches"] == 0
    assert tuning.STATS["cache_hits"] == 1

    # the record itself is the documented shape, keyed by device kind
    path = os.path.join(cache_env, _cache_files(cache_env)[0])
    with open(path) as f:
        data = json.load(f)
    (key,) = data.keys()
    assert key.startswith("fastapp.xla|") and tuning.device_key() in key
    assert data[key]["tiles"] == tiles1
    assert data[key]["candidates"] >= 1


def test_corrupt_cache_warns_counts_and_retunes(cache_env, caplog):
    """An unreadable cache file must not silently degrade: it logs a warning,
    bumps tuning.cache_corrupt, and the resolution re-tunes as on a miss."""
    tiles1 = tuning.tiles_for(CTX, "fastapp.xla", **SHAPE)
    path = os.path.join(cache_env, _cache_files(cache_env)[0])
    with open(path, "w") as f:
        f.write("{not json")

    tuning.reset_stats()  # "second run" against the corrupted disk state
    with caplog.at_level(logging.WARNING, logger="repro.kernels.tuning"):
        tiles2 = tuning.tiles_for(CTX, "fastapp.xla", **SHAPE)
    # the re-tune ran (winners are timing-dependent; same tunable keys)
    assert set(tiles2) == set(tiles1)
    assert "unreadable" in caplog.text and path in caplog.text
    assert obs.GLOBAL.counter("tuning.cache_corrupt") == 1
    assert tuning.STATS["searches"] == 1 and tuning.STATS["cache_hits"] == 0
    # the re-tune re-persisted a readable cache
    with open(path) as f:
        assert json.load(f)


def test_stats_view_tracks_telemetry_counters(cache_env):
    """STATS is a live view over the repro.obs.GLOBAL counters (the old
    module-global dict API keeps working)."""
    assert dict(tuning.STATS) == {
        "searches": 0, "cache_hits": 0, "candidates_timed": 0,
    }
    obs.GLOBAL.count("tuning.search", 2)
    assert tuning.STATS["searches"] == 2
    tuning.STATS["searches"] = 0
    assert obs.GLOBAL.counter("tuning.search") == 0
    assert len(tuning.STATS) == 3 and set(tuning.STATS) == {
        "searches", "cache_hits", "candidates_timed",
    }


def test_search_policy_ignores_disk_but_memoizes_in_process(cache_env):
    ctx = ExecutionContext(backend="jax", tuning="search")
    tuning.tiles_for(ctx, "fastapp.xla", **SHAPE)
    # repeat dispatches in the same process reuse the in-memory winner --
    # engines call tiles_for per dispatch, so search must not re-run per call
    tuning.tiles_for(ctx, "fastapp.xla", **SHAPE)
    assert tuning.STATS["searches"] == 1
    # a fresh process ("search" ignores the persisted winner) re-tunes
    tuning.reset_stats()
    tuning.tiles_for(ctx, "fastapp.xla", **SHAPE)
    assert tuning.STATS["searches"] == 1 and tuning.STATS["cache_hits"] == 0


def test_cached_policy_memoizes_within_process(cache_env):
    tuning.tiles_for(CTX, "fastapp.xla", **SHAPE)
    tuning.tiles_for(CTX, "fastapp.xla", **SHAPE)
    tuning.tiles_for(CTX, "fastapp.xla", **SHAPE)
    # one search, then in-memory hits: the JSON file is not re-read per call
    assert tuning.STATS["searches"] == 1
    assert tuning.STATS["cache_hits"] == 0


@pytest.mark.parametrize(
    "name,shape",
    [
        ("fastchar.pallas", dict(n_bits=4, d=8)),
        ("fastapp.pallas", dict(n_bits=4, d=8, m=8, k=24, n=8)),
        ("fastmoo.pallas", dict(p=48, n_obj=2)),
    ],
)
def test_cached_tiles_bit_identical_to_default_tiles(cache_env, name, shape):
    """Whatever winner the search persists, interpret-mode results match the
    registry-default tiles bit-for-bit (the engines may swap tiles freely)."""
    spec = registry.get(name)
    bucket = spec.bucket(**shape)
    cached = tuning.tiles_for(CTX, name, **shape)
    assert tuning.STATS["searches"] >= 1
    default = spec.default_tiles(bucket)

    exact_c, close_c = tuning.run_case(spec, bucket, cached)
    exact_d, close_d = tuning.run_case(spec, bucket, default)
    for c, d in zip(exact_c, exact_d):
        np.testing.assert_array_equal(np.asarray(c), np.asarray(d))
    for c, d in zip(close_c, close_d):
        np.testing.assert_allclose(np.asarray(c), np.asarray(d), rtol=1e-6)


def test_search_records_are_parity_gated(cache_env):
    """autotune() only crowns candidates that pass the oracle gate; the
    record reports how many were timed vs rejected."""
    spec = registry.get("fastmoo.pallas")
    bucket = spec.bucket(p=32, n_obj=2)
    rec = tuning.autotune(spec, bucket)
    assert rec["tiles"] in spec.candidates(bucket)
    assert rec["rejected"] == 0
    assert rec["candidates"] == len(spec.candidates(bucket))
    assert len(rec["timings"]) == rec["candidates"]


def test_engine_entry_points_accept_tuned_context(cache_env):
    """behav_metrics_jax under tuning="cached" matches the untuned result
    bit-for-bit (integer metrics) on the 4-bit operator."""
    from repro.core.fastchar import behav_metrics_jax
    from repro.core.operator_model import spec_for

    spec = spec_for(4)
    rng = np.random.default_rng(3)
    cfgs = rng.integers(0, 2, (8, spec.n_luts)).astype(np.uint8)
    base = behav_metrics_jax(spec, cfgs)
    tuned = behav_metrics_jax(spec, cfgs, ctx=CTX)
    for k in base:
        if k == "AVG_ABS_REL_ERR":
            np.testing.assert_allclose(tuned[k], base[k], rtol=1e-6)
        else:
            np.testing.assert_array_equal(tuned[k], base[k], err_msg=k)
