"""Contracts for the observability analysis layer (PR 8).

  * **Regression sentinel** (``repro.obs.regress``): suite verdicts are
    PASS / REGRESSED / IMPROVED / NEW / SKIPPED; wall-clock moves gate only
    beyond the noise band (max of a relative floor and a multiple of the
    trial IQR); quality metrics (hv, top-1) parsed from the rows' derived
    strings gate with relative tolerance and always hard-fail; the CLI
    writes a machine-readable verdict and exits non-zero iff REGRESSED.
  * **History store**: appends never overwrite; ``latest`` is chronological.
  * **Prometheus exposition** (``repro.obs.prom``): counters render as
    ``_total``, histograms as summaries with quantile labels, names are
    sanitized to the Prometheus charset; ``/metrics`` + ``/healthz`` round-
    trip over real HTTP against the live telemetry.
  * **Compiled-cost profiling** (``repro.obs.profile``): ``profile_fn``
    captures XLA ``cost_analysis()`` numbers as gauges under jit, and
    ``check_estimate`` flags >2x estimate-vs-measured divergence both ways.
"""

import json
import pathlib
import re
import urllib.request

import numpy as np
import pytest

from repro.obs import regress
from repro.obs import telemetry as tm
from repro.obs.prom import MetricsServer, health_payload, render_prometheus


# ---------------------------------------------------------------------------
# Fixtures: synthetic bench reports
# ---------------------------------------------------------------------------


def _suite(median, iqr=0.01, rows=()):
    return {
        "wall_s": median, "wall_s_min": median * 0.97,
        "wall_s_median": median, "wall_s_iqr": iqr,
        "repeats": 3, "rows": list(rows),
    }


def _report(suites, sha="abc1234"):
    return {
        "timestamp_utc": "2026-08-08T00:00:00Z", "git_sha": sha,
        "device": "cpu:cpux1", "quick": True, "seed": 0,
        "suites": suites,
    }


def _dse_row(hv_ppf, hv_vpf):
    return {"name": "dse.fig12_sf0.5_ga", "us_per_call": 1e6,
            "derived": f"hv_ppf={hv_ppf:.5g} hv_vpf={hv_vpf:.5g} evals=1344"}


def _serving_row(top1, match):
    return {"name": "serving.axo_t1_r8_b4", "us_per_call": 1e6,
            "derived": f"12.3 tok/s match={match:.2f} top1={top1:.2f} rel=0.0123"}


# ---------------------------------------------------------------------------
# Metric parsing + wall stats
# ---------------------------------------------------------------------------


def test_parse_metrics_extracts_numeric_tokens():
    m = regress.parse_metrics("hv_ppf=0.5 hv_vpf=4.5e-2 evals=1000 note=fast")
    assert m == {"hv_ppf": 0.5, "hv_vpf": 4.5e-2, "evals": 1000.0}
    # bare numbers and non-strings are ignored, not crashes
    assert regress.parse_metrics("12.3 tok/s match=0.98") == {"match": 0.98}
    assert regress.parse_metrics(None) == {}
    assert regress.parse_metrics("") == {}


def test_wall_stats_min_median_iqr():
    s = regress.wall_stats([3.0, 1.0, 2.0])
    assert s["wall_s_min"] == 1.0
    assert s["wall_s_median"] == 2.0 == s["wall_s"]
    assert s["wall_s_iqr"] == pytest.approx(1.0)
    assert s["repeats"] == 3
    # single trial: zero IQR, median = the trial
    s1 = regress.wall_stats([5.0])
    assert s1["wall_s_median"] == 5.0 and s1["wall_s_iqr"] == 0.0
    assert regress.wall_stats([])["repeats"] == 0


# ---------------------------------------------------------------------------
# Verdicts
# ---------------------------------------------------------------------------


def test_verdicts_pass_regressed_improved_new_skipped():
    base = _report({
        "same": _suite(1.0), "slow": _suite(1.0), "fast": _suite(1.0),
        "gone": _suite(1.0),
    })
    cand = _report({
        "same": _suite(1.01), "slow": _suite(2.0), "fast": _suite(0.4),
        "fresh": _suite(1.0),
    }, sha="def5678")
    v = regress.compare(base, cand)
    assert v["suites"]["same"]["status"] == "PASS"
    assert v["suites"]["slow"]["status"] == "REGRESSED"
    assert v["suites"]["fast"]["status"] == "IMPROVED"
    assert v["suites"]["fresh"]["status"] == "NEW"
    assert v["suites"]["gone"]["status"] == "SKIPPED"
    assert v["overall"] == "REGRESSED"
    assert any("slow" in f for f in v["failures"])
    # NEW and IMPROVED do not fail the run
    v2 = regress.compare(
        _report({"fast": _suite(1.0)}), _report({"fast": _suite(0.4)})
    )
    assert v2["overall"] == "PASS"


def test_noise_band_scales_with_iqr():
    # a 40% move on a noisy suite (IQR ~ the move) is NOT a regression...
    base = _report({"noisy": _suite(1.0, iqr=0.2)})
    cand = _report({"noisy": _suite(1.4, iqr=0.2)})
    v = regress.compare(base, cand, wall_rel=0.25, iqr_mult=3.0)
    assert v["suites"]["noisy"]["status"] == "PASS"
    assert v["suites"]["noisy"]["wall"]["band_s"] == pytest.approx(0.6)
    # ...but the same move on a tight suite is
    v2 = regress.compare(
        _report({"tight": _suite(1.0, iqr=0.01)}),
        _report({"tight": _suite(1.4, iqr=0.01)}),
    )
    assert v2["suites"]["tight"]["status"] == "REGRESSED"
    # the candidate's own noise widens the band too (max of the two IQRs)
    v3 = regress.compare(
        _report({"s": _suite(1.0, iqr=0.01)}),
        _report({"s": _suite(1.4, iqr=0.2)}),
    )
    assert v3["suites"]["s"]["status"] == "PASS"


def test_quality_gate_hv_and_top1():
    base = _report({
        "dse": _suite(1.0, rows=[_dse_row(0.5, 0.4)]),
        "serving": _suite(1.0, rows=[_serving_row(0.97, 0.9)]),
    })
    # hv drop beyond 2% -> REGRESSED even though wall is identical
    cand = _report({
        "dse": _suite(1.0, rows=[_dse_row(0.5, 0.3)]),
        "serving": _suite(1.0, rows=[_serving_row(0.97, 0.9)]),
    })
    v = regress.compare(base, cand)
    assert v["suites"]["dse"]["status"] == "REGRESSED"
    assert v["suites"]["serving"]["status"] == "PASS"
    checks = {c["metric"]: c["status"] for c in v["suites"]["dse"]["quality"]}
    assert checks["hv_vpf"] == "REGRESSED" and checks["hv_ppf"] == "PASS"
    # top1 is a higher-better gate: a drop regresses, a rise improves
    cand2 = _report({
        "dse": _suite(1.0, rows=[_dse_row(0.5, 0.4)]),
        "serving": _suite(1.0, rows=[_serving_row(0.80, 0.9)]),
    })
    v2 = regress.compare(base, cand2)
    assert v2["suites"]["serving"]["status"] == "REGRESSED"
    assert v2["overall"] == "REGRESSED"
    # within-tolerance wiggle passes (2% on hv, 5% on top1)
    cand3 = _report({
        "dse": _suite(1.0, rows=[_dse_row(0.5, 0.396)]),
        "serving": _suite(1.0, rows=[_serving_row(0.95, 0.9)]),
    })
    assert regress.compare(base, cand3)["overall"] == "PASS"


def test_wall_warn_only_demotes_wall_but_not_quality():
    base = _report({
        "slow": _suite(1.0),
        "dse": _suite(1.0, rows=[_dse_row(0.5, 0.4)]),
    })
    cand = _report({
        "slow": _suite(3.0),
        "dse": _suite(1.0, rows=[_dse_row(0.5, 0.2)]),
    })
    v = regress.compare(base, cand, wall_warn_only=True)
    # the wall regression is reported but only warns...
    assert v["suites"]["slow"]["status"] == "REGRESSED"
    assert any("slow" in w for w in v["warnings"])
    assert not any("slow" in f for f in v["failures"])
    # ...while the hv regression still hard-fails
    assert v["overall"] == "REGRESSED"
    assert any("hv_vpf" in f for f in v["failures"])
    # with only the wall regression, warn-only means overall PASS
    v2 = regress.compare(
        _report({"slow": _suite(1.0)}), _report({"slow": _suite(3.0)}),
        wall_warn_only=True,
    )
    assert v2["overall"] == "PASS" and v2["warnings"]


def test_failed_candidate_suite_regresses():
    base = _report({"s": _suite(1.0)})
    cand = _report({"s": {"wall_s": 0.1, "failed": True}})
    v = regress.compare(base, cand)
    assert v["suites"]["s"]["status"] == "REGRESSED"
    assert v["overall"] == "REGRESSED"
    # a failed BASELINE suite cannot gate anything: candidate counts as NEW
    v2 = regress.compare(cand, base)
    assert v2["suites"]["s"]["status"] == "NEW"
    assert v2["overall"] == "PASS"


def test_pre_repeats_reports_still_compare():
    # PR 7 reports had only single-shot wall_s: zero-IQR fallback applies
    old = _report({"s": {"wall_s": 1.0, "rows": []}})
    new = _report({"s": _suite(1.1)})
    v = regress.compare(old, new)
    assert v["suites"]["s"]["status"] == "PASS"
    assert v["suites"]["s"]["wall"]["baseline_s"] == 1.0


# ---------------------------------------------------------------------------
# History store + CLI
# ---------------------------------------------------------------------------


def test_history_append_and_latest(tmp_path):
    d = str(tmp_path / "hist")
    assert regress.latest_report(d) is None
    p1 = regress.append_history(_report({"s": _suite(1.0)}), d)
    p2 = regress.append_history(_report({"s": _suite(2.0)}), d)
    assert p1 != p2
    latest = regress.latest_report(d)
    assert latest == sorted([p1, p2])[-1]
    rep = regress.load_report(latest)
    assert "suites" in rep
    with pytest.raises(ValueError):
        bad = tmp_path / "bad.json"
        bad.write_text("{}")
        regress.load_report(str(bad))


def test_cli_verdict_roundtrip_and_exit_codes(tmp_path, capsys):
    base_p = tmp_path / "baseline.json"
    hist = str(tmp_path / "hist")
    base_p.write_text(json.dumps(_report({
        "dse": _suite(1.0, rows=[_dse_row(0.5, 0.4)]),
    })))
    # green: identical candidate via the history store's "latest"
    regress.append_history(_report({
        "dse": _suite(1.02, rows=[_dse_row(0.5, 0.4)]),
    }), hist)
    out = tmp_path / "verdict.json"
    rc = regress.main([
        "--baseline", str(base_p), "--candidate", "latest",
        "--history-dir", hist, "--out", str(out), "--wall-warn-only",
    ])
    assert rc == 0
    v = json.loads(out.read_text())
    assert v["overall"] == "PASS"
    assert v["suites"]["dse"]["status"] == "PASS"
    assert v["candidate"]["path"].startswith(hist)
    capsys.readouterr()

    # red: inject a synthetic hv regression (the CI sentinel's red-path check)
    regress.append_history(_report({
        "dse": _suite(1.0, rows=[_dse_row(0.5, 0.2)]),
    }), hist)
    rc = regress.main([
        "--baseline", str(base_p), "--candidate", "latest",
        "--history-dir", hist, "--out", str(out), "--wall-warn-only",
    ])
    assert rc == 1
    v = json.loads(out.read_text())
    assert v["overall"] == "REGRESSED" and v["failures"]
    captured = capsys.readouterr()
    assert "REGRESSED" in captured.out

    # empty history is a usage error, not a pass
    assert regress.main([
        "--baseline", str(base_p), "--history-dir", str(tmp_path / "empty"),
    ]) == 2


def test_committed_baseline_is_a_valid_report():
    import os

    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "baselines", "cpu-smoke.json")
    rep = regress.load_report(path)
    assert rep["quick"] is True
    assert rep["suites"], "baseline must contain at least one suite"
    for name, entry in rep["suites"].items():
        assert "wall_s_median" in entry, name
        assert entry.get("repeats", 0) >= 3, name
    # the baseline must carry gated quality metrics for hv and top-1
    joined = json.dumps(rep)
    assert "hv_vpf=" in joined and "top1=" in joined
    # comparing the baseline against itself is a clean PASS
    v = regress.compare(rep, rep)
    assert v["overall"] == "PASS"
    assert all(s["status"] == "PASS" for s in v["suites"].values())


# ---------------------------------------------------------------------------
# Prometheus exposition + /metrics + /healthz
# ---------------------------------------------------------------------------


def test_render_prometheus_format():
    tel = tm.Telemetry("t")
    tel.count("serve.requests", 3)
    tel.gauge("serve.tokens_per_s", 123.5)
    tel.gauge("axo_matmul.pad_waste", 0.25)
    for x in range(100):
        tel.observe("serve.decode_step_ms", float(x))
    tel.observe("serve.tokens_per_s", 123.5)  # gauge/hist name collision
    text = render_prometheus(tel)

    assert "# TYPE repro_serve_requests_total counter" in text
    assert "repro_serve_requests_total 3" in text
    assert "# TYPE repro_axo_matmul_pad_waste gauge" in text
    # summary with quantile labels + count/sum
    assert '# TYPE repro_serve_decode_step_ms summary' in text
    assert 'repro_serve_decode_step_ms{quantile="0.5"}' in text
    assert 'repro_serve_decode_step_ms{quantile="0.99"}' in text
    assert "repro_serve_decode_step_ms_count 100" in text
    # collision: summary keeps the base name, gauge moves to _last
    assert "# TYPE repro_serve_tokens_per_s_last gauge" in text
    assert "# TYPE repro_serve_tokens_per_s summary" in text
    # every sample line is name[{labels}] value -- no empty values
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        assert name and float(value) == float(value)  # parses, NaN-safe


def test_render_prometheus_sanitizes_names():
    tel = tm.Telemetry("t")
    tel.count("jit.retrace.fastmoo.run")
    tel.gauge("weird-name with spaces", 1.0)
    text = render_prometheus(tel)
    assert "repro_jit_retrace_fastmoo_run_total 1" in text
    assert "repro_weird_name_with_spaces 1.0" in text


def test_metrics_and_healthz_http_roundtrip():
    tel = tm.Telemetry("serve-test")
    tel.count("serve.requests", 2)
    tel.observe("serve.prefill_ms", 12.0)
    with MetricsServer(tel=tel, port=0, check_device=False) as srv:
        assert srv.port != 0  # ephemeral port resolved
        r = urllib.request.urlopen(f"{srv.url}/metrics")
        assert r.status == 200
        assert r.headers["Content-Type"].startswith("text/plain; version=0.0.4")
        body = r.read().decode()
        assert "repro_serve_requests_total 2" in body

        # a request recorded AFTER start is visible on the next scrape
        tel.count("serve.requests", 5)
        body = urllib.request.urlopen(f"{srv.url}/metrics").read().decode()
        assert "repro_serve_requests_total 7" in body

        h = urllib.request.urlopen(f"{srv.url}/healthz")
        assert h.status == 200
        payload = json.loads(h.read().decode())
        assert payload["status"] == "ok"
        assert payload["deployment"] == {"mode": "exact"}
        assert payload["tuning_cache"]["ok"] is True
        assert payload["requests"] == 7

        srv.set_deployment({"mode": "axo", "rank": 8})
        payload = json.loads(
            urllib.request.urlopen(f"{srv.url}/healthz").read().decode()
        )
        assert payload["deployment"]["rank"] == 8

        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{srv.url}/nope")


def test_healthz_device_liveness_real_probe():
    # with the real device check on, the CPU backend must report ok
    payload = health_payload(check_device=True)
    assert payload["status"] == "ok"
    assert payload["device"]["status"] == "ok"
    assert payload["device"]["count"] >= 1


# ---------------------------------------------------------------------------
# Committed alerting rules (launch/alerts.yml)
# ---------------------------------------------------------------------------


_ALERTS_PATH = pathlib.Path(__file__).resolve().parents[1] / "launch" / "alerts.yml"
_DURATION_RE = re.compile(r"^\d+(ms|s|m|h|d|w|y)$")


def _load_alert_groups():
    text = _ALERTS_PATH.read_text()
    try:
        import yaml
    except ImportError:
        # structural fallback: the committed file is plain block YAML, so a
        # minimal indentation walk recovers the rule dicts we assert on
        groups, rule = [], None
        for line in text.splitlines():
            s = line.strip()
            if s.startswith("- name:"):
                groups.append({"name": s.split(":", 1)[1].strip(), "rules": []})
            elif s.startswith("- alert:"):
                rule = {"alert": s.split(":", 1)[1].strip()}
                groups[-1]["rules"].append(rule)
            elif rule is not None and s.startswith(
                ("expr:", "for:", "severity:", "summary:", "description:")
            ):
                k, v = s.split(":", 1)
                if k == "severity":
                    rule.setdefault("labels", {})[k] = v.strip()
                elif k in ("summary", "description"):
                    # block scalars (>-) read as a truthy marker -- enough
                    # for the presence assertions
                    rule.setdefault("annotations", {})[k] = v.strip() or ">-"
                else:
                    rule[k] = v.strip()
        return groups
    doc = yaml.safe_load(text)
    assert isinstance(doc, dict) and "groups" in doc
    return doc["groups"]


def test_alert_rules_syntax():
    """Prometheus rule-file shape: groups -> rules, each with alert/expr/for,
    a severity label, and both annotations."""
    groups = _load_alert_groups()
    assert len(groups) >= 2
    n_rules = 0
    for g in groups:
        assert g["name"].startswith("repro_serve")
        for r in g["rules"]:
            n_rules += 1
            assert re.fullmatch(r"[A-Za-z_][A-Za-z0-9_]*", r["alert"])
            assert r["expr"].strip()
            assert _DURATION_RE.match(str(r["for"]))
            assert r["labels"]["severity"] in ("warning", "critical")
            ann = r.get("annotations", {})
            assert ann.get("summary") and ann.get("description")
    assert n_rules >= 6


def test_alert_rules_reference_live_exposition_names():
    """Every repro_* metric an alert expression references must be a name the
    serving telemetry actually exposes through render_prometheus."""
    tel = tm.Telemetry("serve-alerts")
    # the exact series launch/serve.py records (see its tel.* call sites)
    tel.count("serve.requests")
    tel.observe("serve.prefill_ms", 1.0)
    tel.observe("serve.decode_step_ms", 1.0)
    tel.gauge("serve.tokens_per_s", 1.0)
    tel.observe("serve.tokens_per_s", 1.0)
    tel.gauge("serve.axo_top1", 1.0)
    tel.gauge("serve.axo_free_run_match", 1.0)
    tel.gauge("serve.axo_logit_rel_err", 0.0)
    # the exact series the DSE service records (repro.service.store / .queue)
    tel.count("service.store_hit")
    tel.count("service.store_miss")
    tel.count("service.store_corrupt")
    tel.count("service.request_hit")
    tel.count("service.request_miss")
    tel.count("service.jobs")
    tel.count("service.batches")
    tel.count("service.job_errors")
    tel.gauge("service.library_size", 1.0)
    tel.gauge("service.front_count", 1.0)
    tel.observe("service.queue_depth", 1.0)
    tel.observe("service.batch_lanes", 1.0)
    exposed = {
        line.split("{", 1)[0].split(" ")[0]
        for line in render_prometheus(tel).splitlines()
        if line and not line.startswith("#")
    }

    referenced = set()
    for g in _load_alert_groups():
        for r in g["rules"]:
            referenced |= set(re.findall(r"\brepro_[a-z0-9_]+", str(r["expr"])))
    assert referenced  # the rules do gate repro_* metrics
    missing = referenced - exposed
    assert not missing, f"alert rules reference unexposed metrics: {missing}"


# ---------------------------------------------------------------------------
# Compiled-cost profiling
# ---------------------------------------------------------------------------


def test_profile_fn_captures_cost_gauges_under_jit():
    import jax
    import jax.numpy as jnp

    from repro.obs.profile import profile_fn

    tel = tm.Telemetry("prof")

    def matmul(a, b):
        return a @ b

    a = jnp.ones((64, 64), jnp.float32)
    b = jnp.ones((64, 64), jnp.float32)
    rec = profile_fn(matmul, a, b, name="mm", tel=tel)
    # a 64^3 matmul is 2*64^3 flops by XLA's own accounting
    assert rec.cost["flops"] == pytest.approx(2 * 64**3)
    assert rec.cost["bytes_accessed"] > 0
    assert rec.cost["peak_bytes"] >= rec.cost["argument_bytes"] > 0
    assert tel.gauges["profile.mm.flops"] == rec.cost["flops"]
    assert tel.counter("profile.compiles") == 1
    assert tel.series["profile"][0]["name"] == "mm"
    # an already-jitted callable goes straight to lower()
    rec2 = profile_fn(jax.jit(matmul), a, b, name="mm2", tel=tel)
    assert rec2.cost["flops"] == rec.cost["flops"]


def test_check_estimate_flags_2x_divergence_both_ways():
    from repro.obs.profile import ProfileRecord, check_estimate

    tel = tm.Telemetry("prof")
    rec = ProfileRecord("k", {"flops": 1000.0, "bytes_accessed": 500.0})
    # within 2x both ways: no flags
    ok = check_estimate(
        ProfileRecord("k", dict(rec.cost)),
        {"flops": 600.0, "bytes_accessed": 900.0}, tel=tel,
    )
    assert ok.flagged == ()
    # >2x under-estimate and >2x over-estimate both flag
    bad = check_estimate(
        ProfileRecord("k", dict(rec.cost)),
        {"flops": 400.0, "bytes_accessed": 1100.0}, tel=tel,
    )
    assert set(bad.flagged) == {"flops", "bytes_accessed"}
    assert bad.divergence["flops"] == pytest.approx(2.5)
    assert tel.counter("profile.estimate_divergence") == 2
    assert tel.gauges["profile.k.divergence.flops"] == pytest.approx(2.5)
    # zero estimate with nonzero measurement flags as inf
    z = check_estimate(
        ProfileRecord("k", dict(rec.cost)), {"flops": 0.0}, tel=tel
    )
    assert z.divergence["flops"] == float("inf") and "flops" in z.flagged


def test_profile_registry_covers_all_three_pallas_engines():
    from repro.obs.profile import profile_registry

    tel = tm.Telemetry("prof")
    with tm.use(tel):
        records = profile_registry()
    names = {r.name for r in records}
    assert names == {"fastchar.pallas", "fastapp.pallas", "fastmoo.pallas"}
    for r in records:
        # XLA produced real numbers for every engine...
        assert r.cost["flops"] > 0, r.name
        assert r.cost["bytes_accessed"] > 0, r.name
        assert r.cost["peak_bytes"] > 0, r.name
        # ...the registered formula produced an estimate...
        assert r.estimate is not None and r.estimate["flops"] > 0, r.name
        # ...and the divergence check ran on both checked stats
        assert set(r.divergence) == {"flops", "bytes_accessed"}, r.name
        assert tel.gauges[f"profile.{r.name}.flops"] == r.cost["flops"]
    assert tel.counter("profile.compiles") == 3
