"""Multi-device sharded execution parity (ExecutionContext meshes).

Every sharded batch axis in the stack carries fully independent entries
(per-config characterization/scoring, per-lane GA runs), so sharded dispatch
must be **bit-identical** to the unsharded jax path.  These tests need forced
host devices to exercise real meshes on CPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_sharding.py

and skip cleanly in a single-device process (JAX device count is fixed at
first init, so the flag cannot be set from inside the test session).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.dse import DSESettings, run_dse_sweep
from repro.core.engine import ExecutionContext
from repro.core.dataset import build_training_dataset
from repro.core.fastchar import behav_metrics_jax
from repro.core.fastmoo import UNBOUNDED, CompiledNSGA2
from repro.core.metrics import behav_metrics
from repro.core.moo import nsga2
from repro.core.operator_model import spec_for
from repro.apps import APPLICATIONS
from repro.apps.fastapp import multi_app_behav_jax

N_DEV = len(jax.devices())
MESH_SIZES = [n for n in (2, 4, 8) if n <= N_DEV]

pytestmark = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >= 2 JAX devices: run with "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _ctx(n, **kw):
    return ExecutionContext(backend="jax", n_devices=n, **kw)


# ---------------------------------------------------------------------------
# Sharded characterization (fastchar D axis)
# ---------------------------------------------------------------------------


class TestShardedCharacterization:
    @pytest.fixture(scope="class")
    def batch(self):
        spec = spec_for(8)
        rng = np.random.default_rng(0)
        cfgs = rng.integers(0, 2, (64, spec.n_luts)).astype(np.uint8)
        return spec, cfgs, behav_metrics_jax(spec, cfgs, impl="xla")

    @pytest.mark.parametrize("n_dev", MESH_SIZES)
    def test_sharded_behav_partials_bit_identical(self, batch, n_dev):
        spec, cfgs, base = batch
        sharded = behav_metrics_jax(spec, cfgs, ctx=_ctx(n_dev))
        for k in base:
            np.testing.assert_array_equal(base[k], sharded[k], err_msg=k)

    def test_odd_batch_pads_onto_the_mesh(self, batch):
        spec, cfgs, base = batch
        sharded = behav_metrics(spec, cfgs[:37], backend=_ctx(N_DEV))
        for k in base:
            np.testing.assert_array_equal(base[k][:37], sharded[k], err_msg=k)

    def test_sharded_pallas_interpret_matches_unsharded(self, batch):
        spec, cfgs, _ = batch
        ctx = _ctx(MESH_SIZES[0], kernel_impl="pallas")
        base = behav_metrics_jax(spec, cfgs[:16], impl="pallas")
        sharded = behav_metrics_jax(spec, cfgs[:16], ctx=ctx)
        for k in base:
            np.testing.assert_array_equal(base[k], sharded[k], err_msg=k)


# ---------------------------------------------------------------------------
# Sharded application BEHAV (fastapp D axis)
# ---------------------------------------------------------------------------


class TestShardedAppBehav:
    @pytest.fixture(scope="class")
    def batch(self):
        spec = spec_for(8)
        rng = np.random.default_rng(1)
        cfgs = rng.integers(0, 2, (16, spec.n_luts)).astype(np.uint8)
        apps = [APPLICATIONS[n]() for n in sorted(APPLICATIONS)]
        return spec, cfgs, apps, multi_app_behav_jax(apps, spec, cfgs)

    @pytest.mark.parametrize("n_dev", MESH_SIZES)
    def test_all_apps_sharded_bit_identical(self, batch, n_dev):
        spec, cfgs, apps, base = batch
        sharded = multi_app_behav_jax(apps, spec, cfgs, ctx=_ctx(n_dev))
        for name in base:
            np.testing.assert_array_equal(base[name], sharded[name], err_msg=name)

    def test_gather_impl_sharded_bit_identical(self, batch):
        spec, cfgs, apps, base = batch
        ctx = _ctx(MESH_SIZES[-1], kernel_impl="xla")
        sharded = multi_app_behav_jax(apps, spec, cfgs, ctx=ctx)
        for name in base:
            np.testing.assert_array_equal(base[name], sharded[name], err_msg=name)


# ---------------------------------------------------------------------------
# Sharded table-free entry paths (fastchar + fastapp config axis)
# ---------------------------------------------------------------------------


class TestShardedEntryPaths:
    """The entry/entry_pallas impls ride the same config-axis shard_map as the
    table impls: every path must be bit-identical to its unsharded dispatch."""

    @pytest.fixture(scope="class")
    def batch(self):
        from repro.core.dataset import gen_random

        spec = spec_for(8)
        cfgs = gen_random(spec, 16, seed=4)
        rng = np.random.default_rng(5)
        operands = dict(
            a2=rng.integers(0, spec.n_inputs, (7, 48)),
            b=rng.integers(0, spec.n_inputs, (48, 5)),
            a3=rng.integers(0, spec.n_inputs, (16, 7, 48)),
            x=rng.integers(0, spec.n_inputs, 120),
            h=rng.integers(0, spec.n_inputs, 9),
            img=rng.integers(0, spec.n_inputs, (16, 16)),
            k=rng.integers(0, spec.n_inputs, (3, 3)),
        )
        return spec, cfgs, operands

    @pytest.mark.parametrize("n_dev", MESH_SIZES)
    def test_fastchar_entry_sharded_bit_identical(self, batch, n_dev):
        spec, cfgs, _ = batch
        base = behav_metrics_jax(spec, cfgs, impl="entry")
        sharded = behav_metrics_jax(
            spec, cfgs, ctx=_ctx(n_dev, kernel_impl="entry")
        )
        for k in base:
            np.testing.assert_array_equal(base[k], sharded[k], err_msg=k)

    @pytest.mark.parametrize("n_dev", MESH_SIZES)
    def test_fastapp_entry_matmul_and_conv_sharded(self, batch, n_dev):
        from repro.apps.fastapp import (
            table_batch, table_conv1d_jax, table_conv2d_jax, table_matmul_jax,
        )

        spec, cfgs, o = batch
        base = table_batch(spec, cfgs)
        sb = table_batch(spec, cfgs, ctx=_ctx(n_dev, kernel_impl="entry"))
        # shared codes, per-config codes, 1-D and 2-D convs
        np.testing.assert_array_equal(
            np.asarray(table_matmul_jax(base, o["a2"], o["b"], impl="entry")),
            np.asarray(table_matmul_jax(sb, o["a2"], o["b"])),
        )
        np.testing.assert_array_equal(
            np.asarray(table_matmul_jax(base, o["a3"], o["b"], impl="entry")),
            np.asarray(table_matmul_jax(sb, o["a3"], o["b"])),
        )
        np.testing.assert_array_equal(
            np.asarray(table_conv1d_jax(base, o["x"], o["h"], impl="entry")),
            np.asarray(table_conv1d_jax(sb, o["x"], o["h"])),
        )
        np.testing.assert_array_equal(
            np.asarray(table_conv2d_jax(base, o["img"], o["k"], impl="entry")),
            np.asarray(table_conv2d_jax(sb, o["img"], o["k"])),
        )

    @pytest.mark.parametrize("n_dev", MESH_SIZES)
    def test_fastapp_entry_pallas_gemv_sharded(self, batch, n_dev):
        from repro.apps.fastapp import table_batch, table_matmul_jax

        spec, cfgs, o = batch
        base = table_batch(spec, cfgs)
        sb = table_batch(
            spec, cfgs, ctx=_ctx(n_dev, kernel_impl="entry_pallas")
        )
        np.testing.assert_array_equal(
            np.asarray(table_matmul_jax(
                base, o["a2"], o["b"], impl="entry_pallas", interpret=True
            )),
            np.asarray(table_matmul_jax(sb, o["a2"], o["b"], interpret=True)),
        )

    def test_all_apps_entry_sharded_bit_identical(self):
        spec = spec_for(8)
        rng = np.random.default_rng(1)
        cfgs = rng.integers(0, 2, (16, spec.n_luts)).astype(np.uint8)
        apps = [APPLICATIONS[n]() for n in sorted(APPLICATIONS)]
        base = multi_app_behav_jax(apps, spec, cfgs)
        sharded = multi_app_behav_jax(
            apps, spec, cfgs, ctx=_ctx(N_DEV, kernel_impl="entry")
        )
        for name in base:
            np.testing.assert_array_equal(
                base[name], sharded[name], err_msg=name
            )


# ---------------------------------------------------------------------------
# Lane-sharded GA sweeps (fastmoo lane axis)
# ---------------------------------------------------------------------------


def _toy_objs(X):
    return jnp.stack([X.sum(-1), (1.0 - X).sum(-1)], axis=-1)


class TestLaneShardedSweep:
    L = 20
    REF = np.array([24.0, 24.0])

    def _runner(self, ctx=None):
        return CompiledNSGA2(
            _toy_objs, n_bits=self.L, pop_size=16, n_gen=8, hv_ref=self.REF,
            ctx=ctx,
        )

    def test_lane_sharded_sweep_bit_identical(self):
        seeds = list(range(2 * N_DEV))
        bounds = [(UNBOUNDED, UNBOUNDED)] * len(seeds)
        base = self._runner().run_sweep(seeds, bounds)
        sharded = self._runner(_ctx(N_DEV)).run_sweep(seeds, bounds)
        for a, b in zip(base, sharded):
            np.testing.assert_array_equal(a.population, b.population)
            np.testing.assert_array_equal(a.archive_configs, b.archive_configs)
            np.testing.assert_array_equal(a.archive_objs, b.archive_objs)
            np.testing.assert_array_equal(a.archive_viol, b.archive_viol)
            assert a.hv_history == b.hv_history

    def test_ragged_lane_count_pads_and_drops(self):
        seeds = list(range(N_DEV + 1))  # not divisible by the mesh
        bounds = [(UNBOUNDED, UNBOUNDED)] * len(seeds)
        base = self._runner().run_sweep(seeds, bounds)
        sharded = self._runner(_ctx(N_DEV)).run_sweep(seeds, bounds)
        assert len(sharded) == len(seeds)
        for a, b in zip(base, sharded):
            np.testing.assert_array_equal(a.archive_configs, b.archive_configs)

    def test_hv_parity_vs_numpy_oracle(self):
        """Sharded device GA vs host oracle GA: hypervolume parity (RNG differs)."""

        def eval_np(X):
            X = np.asarray(X, np.float64)
            return np.stack([X.sum(-1), (1.0 - X).sum(-1)], axis=-1)

        oracle = nsga2(
            eval_np, n_bits=self.L, pop_size=48, n_gen=40, seed=0,
            hv_ref=self.REF,
        )
        ga = CompiledNSGA2(
            _toy_objs, n_bits=self.L, pop_size=48, n_gen=40, hv_ref=self.REF,
            ctx=_ctx(N_DEV),
        ).run_sweep([0], [(UNBOUNDED, UNBOUNDED)])[0]
        hv_np = oracle.hv_history[-1][1]
        hv_jx = ga.hv_history[-1][1]
        assert hv_np > 0
        assert abs(hv_jx - hv_np) <= 0.02 * hv_np


# ---------------------------------------------------------------------------
# End-to-end: run_dse_sweep through a fully sharded context
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_run_dse_sweep_sharded_end_to_end_matches_unsharded():
    spec = spec_for(4)
    ds = build_training_dataset(spec, n_random=80, seed=0)
    kw = dict(
        pop_size=8, n_gen=3, n_quad_grid=(0,), pool_size=2, n_estimator_quad=4,
    )
    base = run_dse_sweep(
        spec, ds, method="ga",
        settings=DSESettings(context=ExecutionContext(backend="jax"), **kw),
        seeds=(0, 1), const_sf_grid=(0.5, 1.0),
    )
    sharded = run_dse_sweep(
        spec, ds, method="ga",
        settings=DSESettings(context=_ctx(N_DEV), **kw),
        seeds=(0, 1), const_sf_grid=(0.5, 1.0),
    )
    assert len(base) == len(sharded) == 4
    for a, b in zip(base, sharded):
        np.testing.assert_array_equal(a.vpf_configs, b.vpf_configs)
        np.testing.assert_allclose(a.vpf_objs, b.vpf_objs)
        np.testing.assert_allclose(a.hv_vpf, b.hv_vpf)
