"""BEHAV metrics + simulated-synthesis PPA model invariants."""

import numpy as np

from repro.core.dataset import build_training_dataset, gen_pattern, gen_random
from repro.core.metrics import behav_metrics
from repro.core.operator_model import accurate_config, spec_for
from repro.core.ppa import merge_tree_luts, ppa_metrics


def test_accurate_config_has_zero_behav_error():
    spec = spec_for(4)
    m = behav_metrics(spec, accurate_config(spec)[None])
    for k in ("AVG_ABS_ERR", "AVG_ABS_REL_ERR", "PROB_ERR", "MAX_ABS_ERR", "MSE"):
        assert m[k][0] == 0.0, k


def test_more_removal_is_worse_on_average():
    spec = spec_for(4)
    rng = np.random.default_rng(0)
    light = rng.integers(0, 2, (64, spec.n_luts)).astype(np.uint8) | (
        rng.random((64, spec.n_luts)) < 0.8
    ).astype(np.uint8)
    heavy = (rng.random((64, spec.n_luts)) < 0.2).astype(np.uint8)
    m_light = behav_metrics(spec, light)["AVG_ABS_ERR"].mean()
    m_heavy = behav_metrics(spec, heavy)["AVG_ABS_ERR"].mean()
    assert m_heavy > m_light


def test_ppa_metrics_structure():
    spec = spec_for(4)
    rng = np.random.default_rng(1)
    cfgs = rng.integers(0, 2, (32, spec.n_luts)).astype(np.uint8)
    m = ppa_metrics(spec, cfgs)
    assert (m["POWER"] > 0).all() and (m["CPD"] > 0).all()
    np.testing.assert_allclose(m["PDP"], m["POWER"] * m["CPD"])
    np.testing.assert_allclose(m["PDPLUT"], m["PDP"] * m["LUTS"])
    merge, _, _ = merge_tree_luts(spec)
    np.testing.assert_allclose(
        m["LUTS"], cfgs.sum(axis=1) + spec.rows + merge
    )


def test_removing_luts_never_increases_lut_count_or_power():
    spec = spec_for(4)
    full = accurate_config(spec)[None]
    none = np.zeros_like(full)
    m_full = ppa_metrics(spec, full)
    m_none = ppa_metrics(spec, none)
    assert m_none["LUTS"][0] < m_full["LUTS"][0]
    assert m_none["POWER"][0] < m_full["POWER"][0]
    assert m_none["CPD"][0] <= m_full["CPD"][0]


def test_pattern_dataset_widens_ppa_range():
    """The paper's Fig. 7 claim: PATTERN sampling widens the metric range."""
    spec = spec_for(8)
    rand = gen_random(spec, 150, seed=0)
    pat = gen_pattern(spec)
    m_rand = ppa_metrics(spec, rand)["PDPLUT"]
    m_pat = ppa_metrics(spec, pat)["PDPLUT"]
    assert m_pat.min() < m_rand.min()
    span_pat = m_pat.max() - m_pat.min()
    span_rand = m_rand.max() - m_rand.min()
    assert span_pat > span_rand


def test_dataset_build_dedup_and_cache(tmp_path):
    spec = spec_for(4)
    path = str(tmp_path / "ds.npz")
    ds = build_training_dataset(spec, n_random=100, seed=0, cache_path=path)
    ds2 = build_training_dataset(spec, n_random=100, seed=0, cache_path=path)
    assert len(ds) == len(ds2)
    np.testing.assert_array_equal(ds.configs, ds2.configs)
    assert len(np.unique(ds.configs, axis=0)) == len(ds)
