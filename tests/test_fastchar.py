"""fastchar parity: the JAX batched characterization engine vs the numpy oracle.

AVG_ABS_ERR / PROB_ERR / MAX_ABS_ERR / MSE must match the float64 numpy oracle
*bit-for-bit* (integer partials combined in int64); AVG_ABS_REL_ERR accumulates
its weights in f32 on device and must agree to ~1e-6 relative.
"""

import numpy as np
import pytest

from repro.core.dataset import characterize
from repro.core.fastchar import (
    behav_metrics_jax,
    behav_metrics_sampled,
    compile_surrogate_batch,
    default_a_tile,
    entry_fn,
    map_problem_values_jax,
    max_abs_error_bound,
)
from repro.core.metrics import BEHAV_METRICS, behav_metrics
from repro.core.miqcp import _all_configs
from repro.core.operator_model import accurate_config, spec_for

EXACT_KEYS = ("AVG_ABS_ERR", "PROB_ERR", "MAX_ABS_ERR", "MSE")
REL_KEY = "AVG_ABS_REL_ERR"


def assert_parity(oracle, fast, rel_tol=1e-5):
    for k in EXACT_KEYS:
        np.testing.assert_array_equal(oracle[k], fast[k], err_msg=k)
    np.testing.assert_allclose(oracle[REL_KEY], fast[REL_KEY], rtol=rel_tol, atol=1e-12)


# ---------------------------------------------------------------------------
# BEHAV parity vs the numpy oracle
# ---------------------------------------------------------------------------


def test_parity_4x4_exhaustive_all_1024_configs():
    """Every 4x4 config: the fast path reproduces the oracle over the whole space."""
    spec = spec_for(4)
    cfgs = _all_configs(spec.n_luts)
    oracle = behav_metrics(spec, cfgs)
    fast = behav_metrics_jax(spec, cfgs, impl="xla")
    assert_parity(oracle, fast)


def test_parity_8x8_random_256_configs():
    spec = spec_for(8)
    rng = np.random.default_rng(0)
    cfgs = rng.integers(0, 2, (256, spec.n_luts)).astype(np.uint8)
    oracle = behav_metrics(spec, cfgs)
    fast = behav_metrics_jax(spec, cfgs, impl="xla")
    assert_parity(oracle, fast)


@pytest.mark.parametrize("n_bits", [4, 8])
def test_parity_degenerate_configs(n_bits):
    """All-zeros (every LUT removed) and all-ones (accurate) corner configs."""
    spec = spec_for(n_bits)
    cfgs = np.stack([np.zeros(spec.n_luts, np.uint8), accurate_config(spec)])
    oracle = behav_metrics(spec, cfgs)
    fast = behav_metrics_jax(spec, cfgs, impl="xla")
    assert_parity(oracle, fast)
    # the accurate config is error-free on both paths
    for k in BEHAV_METRICS:
        assert fast[k][1] == 0.0, k


def test_parity_pallas_impl_8x8():
    """Interpret-mode Pallas kernel path end-to-end (small batch: it is the
    correctness twin of the XLA impl, not the CPU fast path)."""
    spec = spec_for(8)
    rng = np.random.default_rng(1)
    cfgs = rng.integers(0, 2, (16, spec.n_luts)).astype(np.uint8)
    oracle = behav_metrics(spec, cfgs)
    fast = behav_metrics_jax(spec, cfgs, impl="pallas", interpret=True)
    assert_parity(oracle, fast)


def test_chunking_and_padding_invariance():
    """Results must not depend on batch_size chunking or d_block padding."""
    spec = spec_for(4)
    rng = np.random.default_rng(2)
    cfgs = rng.integers(0, 2, (37, spec.n_luts)).astype(np.uint8)  # odd D
    ref = behav_metrics_jax(spec, cfgs, impl="xla", batch_size=1024)
    for bs, db in ((8, 8), (16, 4), (37, 8)):
        out = behav_metrics_jax(spec, cfgs, impl="xla", batch_size=bs, d_block=db)
        for k in EXACT_KEYS:
            np.testing.assert_array_equal(ref[k], out[k], err_msg=f"{k} bs={bs}")
        np.testing.assert_allclose(ref[REL_KEY], out[REL_KEY], rtol=1e-6)


def test_a_tile_bound_is_int32_safe():
    for n_bits in (2, 4, 8):
        spec = spec_for(n_bits)
        tile = default_a_tile(spec)
        assert spec.n_inputs % tile == 0
        assert tile * spec.n_inputs * max_abs_error_bound(spec) < 2**30


def test_characterize_backend_switch_matches():
    spec = spec_for(4)
    rng = np.random.default_rng(3)
    cfgs = rng.integers(0, 2, (24, spec.n_luts)).astype(np.uint8)
    ds_np = characterize(spec, cfgs, backend="numpy")
    ds_jx = characterize(spec, cfgs, backend="jax")
    for k in EXACT_KEYS:
        np.testing.assert_array_equal(ds_np.metrics[k], ds_jx.metrics[k], err_msg=k)
    np.testing.assert_allclose(
        ds_np.metrics[REL_KEY], ds_jx.metrics[REL_KEY], rtol=1e-5
    )
    # PPA stays on the shared numpy tables: identical by construction
    for k in ("POWER", "CPD", "LUTS", "PDP", "PDPLUT"):
        np.testing.assert_array_equal(ds_np.metrics[k], ds_jx.metrics[k], err_msg=k)


def test_unknown_backend_and_impl_raise():
    spec = spec_for(4)
    cfg = accurate_config(spec)[None]
    with pytest.raises(ValueError):
        behav_metrics(spec, cfg, backend="torch")
    with pytest.raises(ValueError):
        behav_metrics_jax(spec, cfg, impl="cuda")


# ---------------------------------------------------------------------------
# Table-free engines: entry / entry_pallas parity, entry_fn, sampled BEHAV
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["entry", "entry_pallas"])
def test_parity_entry_4x4_exhaustive_all_1024_configs(impl):
    """Every 4x4 config through the table-free engines: bit-identical to the
    oracle with no table build anywhere in the dispatch."""
    spec = spec_for(4)
    cfgs = _all_configs(spec.n_luts)
    oracle = behav_metrics(spec, cfgs)
    fast = behav_metrics_jax(spec, cfgs, impl=impl)
    assert_parity(oracle, fast)


@pytest.mark.parametrize("impl", ["entry", "entry_pallas"])
def test_parity_entry_8x8_random(impl):
    spec = spec_for(8)
    rng = np.random.default_rng(5)
    d = 64 if impl == "entry" else 16  # interpret-mode Pallas is slow
    cfgs = rng.integers(0, 2, (d, spec.n_luts)).astype(np.uint8)
    oracle = behav_metrics(spec, cfgs)
    fast = behav_metrics_jax(spec, cfgs, impl=impl)
    assert_parity(oracle, fast)


def test_entry_fn_is_jittable_and_exact():
    """entry_fn(config, a, b) matches simulate_product element-wise and the
    exact product under the accurate config."""
    from repro.core.operator_model import simulate_product

    spec = spec_for(8)
    fn = entry_fn(spec)
    rng = np.random.default_rng(6)
    a = rng.integers(-128, 128, 64).astype(np.int32)
    b = rng.integers(-128, 128, 64).astype(np.int32)
    acc = np.asarray(fn(accurate_config(spec), a, b))
    np.testing.assert_array_equal(acc, a.astype(np.int64) * b)
    cfg = rng.integers(0, 2, spec.n_luts).astype(np.uint8)
    out = np.asarray(fn(cfg, a, b))
    for i in range(8):
        assert out[i] == simulate_product(spec, int(a[i]), int(b[i]), cfg)


def test_entry_fn_rejects_int32_unsafe_widths():
    with pytest.raises(ValueError, match="overflow"):
        entry_fn(spec_for(16))


def test_exhaustive_engine_rejects_wide_or_nonmul_specs():
    with pytest.raises(ValueError, match="behav_metrics_sampled"):
        behav_metrics_jax(spec_for(12), np.ones((2, spec_for(12).n_luts), np.uint8))
    spec_add = spec_for(8, op="add")
    with pytest.raises(ValueError, match="behav_metrics_sampled"):
        behav_metrics_jax(spec_add, np.ones((2, spec_add.n_luts), np.uint8))


def test_sampled_behav_ci_calibrated_against_exhaustive_8bit():
    """The sampled estimator's bootstrap CIs must cover the exhaustive ground
    truth for the well-behaved channels (the heavy-tailed relative-error
    channel is documented as a diagnostic band, not asserted)."""
    spec = spec_for(8)
    rng = np.random.default_rng(11)
    cfgs = rng.integers(0, 2, (12, spec.n_luts)).astype(np.uint8)
    cfgs[0] = accurate_config(spec)
    ref = behav_metrics(spec, cfgs)
    met, ci = behav_metrics_sampled(spec, cfgs, n_samples=32768, seed=3)
    # accurate config: every sampled stat is exactly zero
    for k in BEHAV_METRICS:
        assert met[k][0] == 0.0, k
    # sample max never exceeds the true max
    assert (met["MAX_ABS_ERR"] <= ref["MAX_ABS_ERR"]).all()
    for key in ("AVG_ABS_ERR", "PROB_ERR", "MSE"):
        lo, hi = ci[key]
        cover = np.mean((ref[key][1:] >= lo[1:]) & (ref[key][1:] <= hi[1:]))
        assert cover >= 0.7, (key, cover)
        rel = np.abs(met[key][1:] - ref[key][1:]) / np.maximum(ref[key][1:], 1e-9)
        assert rel.max() < 0.05, (key, rel.max())


def test_sampled_behav_12bit_runs_in_bounded_memory():
    """12-bit characterization streams (D, s_block, R) int32 chunks -- the
    exhaustive (D, 2^12, 2^12) tensor never exists."""
    spec = spec_for(12)
    rng = np.random.default_rng(12)
    cfgs = rng.integers(0, 2, (4, spec.n_luts)).astype(np.uint8)
    cfgs[0] = accurate_config(spec)
    met, ci = behav_metrics_sampled(spec, cfgs, n_samples=8192, seed=1)
    assert met["AVG_ABS_ERR"][0] == 0.0 and met["PROB_ERR"][0] == 0.0
    assert np.isfinite(met["MSE"]).all() and (met["MSE"] >= 0).all()
    lo, hi = ci["AVG_ABS_ERR"]
    assert (lo <= met["AVG_ABS_ERR"]).all() and (met["AVG_ABS_ERR"] <= hi).all()


def test_sampled_behav_supports_adders():
    spec = spec_for(8, op="add")
    rng = np.random.default_rng(13)
    cfgs = rng.integers(0, 2, (6, spec.n_luts)).astype(np.uint8)
    cfgs[0] = accurate_config(spec)
    ref = behav_metrics(spec, cfgs)  # numpy oracle handles adders exhaustively
    met, _ = behav_metrics_sampled(spec, cfgs, n_samples=32768, seed=2)
    assert met["AVG_ABS_ERR"][0] == 0.0
    rel = np.abs(met["AVG_ABS_ERR"][1:] - ref["AVG_ABS_ERR"][1:]) / np.maximum(
        ref["AVG_ABS_ERR"][1:], 1e-9
    )
    assert rel.max() < 0.05


# ---------------------------------------------------------------------------
# Batched surrogate evaluation (NSGA-II one-dispatch path)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fitted():
    from repro.core.automl import fit_estimators
    from repro.core.dataset import build_training_dataset

    spec = spec_for(4)
    ds = build_training_dataset(spec, n_random=200, seed=0)
    keys = ("AVG_ABS_REL_ERR", "PDPLUT")
    ests = fit_estimators(
        ds.configs.astype(np.float64),
        {k: ds.metrics[k] for k in keys},
        n_quad=16,
        seed=0,
    )
    return spec, ds, ests


def test_surrogate_batch_matches_numpy_estimators(fitted):
    spec, ds, ests = fitted
    mb = float(ds.metrics["AVG_ABS_REL_ERR"].max())
    mp = float(ds.metrics["PDPLUT"].max())
    fn = compile_surrogate_batch(ests, "AVG_ABS_REL_ERR", "PDPLUT", mb, mp)

    rng = np.random.default_rng(4)
    X = rng.integers(0, 2, (64, spec.n_luts)).astype(np.float64)
    objs, viol = fn(X)
    assert objs.shape == (64, 2) and viol.shape == (64,)

    ref_b = ests["AVG_ABS_REL_ERR"].predict(X)
    ref_p = ests["PDPLUT"].predict(X)
    scale_b = max(np.abs(ref_b).max(), 1.0)
    scale_p = max(np.abs(ref_p).max(), 1.0)
    np.testing.assert_allclose(objs[:, 0], ref_b, atol=1e-4 * scale_b)
    np.testing.assert_allclose(objs[:, 1], ref_p, atol=1e-4 * scale_p)

    ref_viol = (
        np.maximum(0.0, ref_b - mb) / max(abs(mb), 1e-9)
        + np.maximum(0.0, ref_p - mp) / max(abs(mp), 1e-9)
    )
    np.testing.assert_allclose(viol, ref_viol, atol=1e-5)
    assert (viol >= 0).all()


def test_nsga2_accepts_batched_eval_viol_fn(fitted):
    from repro.core.moo import nsga2

    spec, ds, ests = fitted
    mb = float(ds.metrics["AVG_ABS_REL_ERR"].max())
    mp = float(ds.metrics["PDPLUT"].max())
    fn = compile_surrogate_batch(ests, "AVG_ABS_REL_ERR", "PDPLUT", mb, mp)
    res = nsga2(None, n_bits=spec.n_luts, pop_size=12, n_gen=4, seed=0,
                eval_viol_fn=fn)
    assert res.population.shape == (12, spec.n_luts)
    assert len(res.archive_configs) == 12 * 5  # init + 4 generations
    assert np.isfinite(res.archive_objs).all()


def test_run_dse_jax_backend_smoke(fitted):
    from repro.core.dse import DSESettings, run_dse

    spec, ds, _ = fitted
    st = DSESettings(const_sf=1.0, pop_size=12, n_gen=4, n_quad_grid=(0,),
                     pool_size=2, seed=0, backend="jax")
    r = run_dse(spec, ds, "map+ga", settings=st)
    assert r.hv_ppf >= 0.0 and r.hv_vpf >= 0.0
    assert r.n_evals > 0
    if len(r.vpf_objs):
        assert np.isfinite(r.vpf_objs).all()


# ---------------------------------------------------------------------------
# Batched MaP enumeration scoring
# ---------------------------------------------------------------------------


def test_map_problem_values_match_quadexpr(fitted):
    from repro.core.correlation import rank_quadratic_terms
    from repro.core.miqcp import build_problems, solve_enumerate
    from repro.core.regression import fit_poly

    spec, ds, _ = fitted
    X = ds.configs.astype(np.float64)
    yb = ds.metrics["AVG_ABS_REL_ERR"]
    yp = ds.metrics["PDPLUT"]
    quad = rank_quadratic_terms(X, yb)[:4]
    bm = fit_poly(X, yb, quad_pairs=quad)
    pm = fit_poly(X, yp, quad_pairs=quad)
    problems = build_problems(
        bm, pm, float(yb.max()), float(yp.max()), 1.0,
        wt_grid=np.array([0.5]), n_quad=4,
    )
    prob = problems[0]
    cfgs = _all_configs(spec.n_luts)

    obj, vb, vp = map_problem_values_jax(prob, cfgs)
    np.testing.assert_allclose(obj, prob.obj.value(cfgs), atol=1e-4)
    np.testing.assert_allclose(vb, prob.behav.value(cfgs), atol=1e-4)
    np.testing.assert_allclose(vp, prob.ppa.value(cfgs), atol=1e-4)

    res_np = solve_enumerate(prob, pool_size=4, backend="numpy")
    res_jx = solve_enumerate(prob, pool_size=4, backend="jax")
    assert abs(res_np.best_obj - res_jx.best_obj) < 1e-4
    assert prob.feasible(res_jx.pool).all()
