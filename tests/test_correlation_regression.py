"""Correlation analysis (paper Alg. 1) and polynomial regression."""

import numpy as np

from repro.core.correlation import (
    bivariate_correlation,
    multivariate_correlation,
    rank_quadratic_terms,
)
from repro.core.regression import MinMaxScaler, fit_poly, r2_score


def test_bivariate_matches_numpy_corrcoef():
    rng = np.random.default_rng(0)
    X = rng.integers(0, 2, (200, 6)).astype(float)
    y = X @ rng.standard_normal(6) + 0.1 * rng.standard_normal(200)
    r = bivariate_correlation(X, y)
    for j in range(6):
        np.testing.assert_allclose(r[j], np.corrcoef(X[:, j], y)[0, 1], atol=1e-12)


def test_multivariate_is_sqrt_r2_of_pair_regression():
    rng = np.random.default_rng(1)
    X = rng.integers(0, 2, (300, 5)).astype(float)
    y = 2 * X[:, 0] - 3 * X[:, 3] + 0.05 * rng.standard_normal(300)
    m = multivariate_correlation(X, y)
    # pair (0, 3) explains nearly everything
    assert m[0, 3] > 0.99
    # vs a weak pair
    assert m[1, 2] < m[0, 3]
    # symmetric with |bivariate| on the diagonal
    np.testing.assert_allclose(m, m.T, atol=1e-12)
    np.testing.assert_allclose(np.diag(m), np.abs(bivariate_correlation(X, y)), atol=1e-9)


def test_rank_quadratic_terms_orders_by_multivariate_r():
    rng = np.random.default_rng(2)
    X = rng.integers(0, 2, (300, 5)).astype(float)
    y = 4 * X[:, 1] * X[:, 4] + 0.1 * rng.standard_normal(300)
    ranked = rank_quadratic_terms(X, y)
    assert ranked[0] == (1, 4)
    assert len(ranked) == 10  # C(5,2)


def test_fit_poly_recovers_exact_quadratic():
    rng = np.random.default_rng(3)
    X = rng.integers(0, 2, (400, 6)).astype(float)
    y = 1.5 + X[:, 0] - 2 * X[:, 2] + 3 * X[:, 1] * X[:, 5]
    model = fit_poly(X, y, quad_pairs=[(1, 5)], alpha=1e-10)
    pred = model.predict(X)
    assert r2_score(y, pred) > 0.999999


def test_more_correlated_quads_fit_faster():
    """Paper Fig. 2: adding correlation-ranked quadratic terms raises R^2
    faster than adding them in reverse order."""
    rng = np.random.default_rng(4)
    X = rng.integers(0, 2, (400, 8)).astype(float)
    y = (
        2 * X[:, 0] * X[:, 1] + 1.2 * X[:, 2] * X[:, 3] + X[:, 4]
        + 0.05 * rng.standard_normal(400)
    )
    ranked = rank_quadratic_terms(X, y)
    fwd = [r2_score(y, fit_poly(X, y, quad_pairs=ranked[:k]).predict(X))
           for k in (1, 2, 4)]
    rev = [r2_score(y, fit_poly(X, y, quad_pairs=ranked[::-1][:k]).predict(X))
           for k in (1, 2, 4)]
    assert fwd[0] > rev[0]
    assert fwd[1] > rev[1]


def test_minmax_scaler_roundtrip():
    rng = np.random.default_rng(5)
    y = rng.standard_normal(100) * 37 + 11
    sc = MinMaxScaler.fit(y)
    z = sc.transform(y)
    assert z.min() >= -1e-12 and z.max() <= 1 + 1e-12
    np.testing.assert_allclose(sc.inverse(z), y, atol=1e-9)
