"""Kernel registry: per-engine menus, constrained tile spaces, and the
acceptance property -- every registered impl is bit-identical to its oracle
under interpret mode for ALL admissible tile candidates (the full tunable
space at a small bucket, exhaustively enumerated)."""

import numpy as np
import pytest

from repro.core.engine import ExecutionContext
from repro.kernels import registry, tuning

# Small buckets keep the exhaustive candidate sweep fast (4-bit operator,
# tiny populations) while still spanning multi-tile grids in every axis.
SMALL_BUCKETS = {
    "fastchar": dict(n_bits=4, d=8),
    "fastapp": dict(n_bits=4, d=8, m=8, k=24, n=8),
    "fastmoo": dict(p=48, n_obj=2),
    "axo_matmul": dict(m=24, k=160, n=136, rank=3),       # awkward on purpose
    "flash_attention": dict(sq=40, skv=40, hd=16),
}


# ---------------------------------------------------------------------------
# Registry contents + menus
# ---------------------------------------------------------------------------


def test_every_engine_has_registered_impls():
    assert registry.impl_names("fastchar") == (
        "xla", "pallas", "entry", "entry_pallas"
    )
    assert registry.impl_names("fastapp") == (
        "gemm", "xla", "pallas", "entry", "entry_pallas"
    )
    assert registry.impl_names("fastmoo") == ("xla", "pallas")
    assert registry.impl_names("axo_matmul") == ("xla", "pallas")
    assert registry.impl_names("flash_attention") == ("xla", "pallas")
    with pytest.raises(ValueError):
        registry.impl_names("fastray")


def test_get_unknown_kernel_raises():
    with pytest.raises(KeyError, match="no kernel"):
        registry.get("fastchar.cuda")


def test_duplicate_registration_rejected():
    spec = registry.get("fastchar.pallas")
    with pytest.raises(ValueError, match="already registered"):
        registry.register(spec)


def test_describe_lists_every_spec():
    text = registry.describe()
    for s in registry.registered():
        assert s.name in text


def test_resolve_impl_engine_names_and_legacy_tuples():
    ctx = ExecutionContext(backend="jax", kernel_impl="gemm")
    # engine names read the registry menus
    assert ctx.resolve_impl("fastapp") == "gemm"
    assert ctx.resolve_impl("fastchar") is None
    assert ctx.resolve_impl("fastmoo", "xla") == "xla"
    # legacy tuple form keeps working
    assert ctx.resolve_impl(("gemm", "xla")) == "gemm"
    assert ctx.resolve_impl(("xla", "pallas"), "xla") == "xla"


def test_tuning_policy_validated_eagerly():
    assert ExecutionContext(tuning="cached").tuning == "cached"
    with pytest.raises(ValueError, match="tuning"):
        ExecutionContext(tuning="always")


# ---------------------------------------------------------------------------
# Tile spaces
# ---------------------------------------------------------------------------


def test_char_candidates_respect_int32_bound():
    spec = registry.get("fastchar.pallas")
    bucket = spec.bucket(n_bits=8, d=256)
    cands = spec.candidates(bucket)
    assert cands, "8-bit bucket must admit candidates"
    for tiles in cands:
        a_tile = tiles["a_tile"]
        assert 256 % a_tile == 0
        assert a_tile * 256 * 59904 < (1 << 31)  # max_abs_error_bound(8x8)
    # the full 256-wide A tile overflows int32 partials and must be excluded
    assert not any(t["a_tile"] == 256 for t in cands)


def test_default_tiles_are_admissible_everywhere():
    for spec in registry.registered():
        if not spec.tunables:
            continue
        engine_shape = SMALL_BUCKETS[spec.engine]
        for shape in (engine_shape,):
            bucket = spec.bucket(**shape)
            tiles = spec.default_tiles(bucket)
            assert spec.constraint is None or spec.constraint(bucket, tiles), (
                spec.name, bucket, tiles
            )


def test_cost_and_compiler_params_are_plain_dicts():
    spec = registry.get("fastchar.pallas")
    cost = spec.cost_estimate(rows=2, d=8, a=16, b=16, a_tile=8)
    assert set(cost) == {"flops", "bytes_accessed", "transcendentals"}
    params = spec.compiler_params(rows=2, d_block=4, a_tile=8, b=16)
    assert params["dimension_semantics"] == ("parallel", "parallel")
    assert params["vmem_limit_bytes"] >= (4 << 20)
    gemv = registry.get("fastapp.pallas")
    assert gemv.compiler_params(m=8, k_tile=16, n=8, a=16)[
        "dimension_semantics"
    ] == ("parallel", "arbitrary")


# ---------------------------------------------------------------------------
# Acceptance property: oracle parity over the whole tile space
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", [s.name for s in registry.registered()])
def test_every_tile_candidate_matches_oracle(name):
    """Exhaustive property over the admissible tile space: each candidate's
    integer outputs are bit-identical to the oracle (f32 channels ~1e-6)."""
    spec = registry.get(name)
    bucket = spec.bucket(**SMALL_BUCKETS[spec.engine])
    oracle = tuning.oracle_case(spec, bucket)
    cands = spec.candidates(bucket) or [spec.default_tiles(bucket)]
    assert len(cands) >= 1
    for tiles in cands:
        exact_r, close_r = tuning.run_case(spec, bucket, tiles)
        for r, o in zip(exact_r, oracle[0]):
            np.testing.assert_array_equal(
                np.asarray(r), np.asarray(o),
                err_msg=f"{name} tiles={tiles}",
            )
        for r, o in zip(close_r, oracle[1]):
            scale = float(np.max(np.abs(np.asarray(o)))) + 1.0
            np.testing.assert_allclose(
                np.asarray(r), np.asarray(o),
                rtol=spec.tol, atol=spec.tol * scale,
                err_msg=f"{name} tiles={tiles}",
            )


def test_entry_gemv_admits_12bit_where_table_kernel_cannot():
    """The table-free GEMV's VMEM constraint (per-row planes, no (A, B)
    table) admits 12-bit operands; the table kernel's resident 67 MB table
    excludes every candidate at that width."""
    shape = dict(n_bits=12, d=4, m=8, k=64, n=8)
    table = registry.get("fastapp.pallas")
    entry = registry.get("fastapp.entry_pallas")
    assert not table.candidates(table.bucket(**shape))
    assert entry.candidates(entry.bucket(**shape))


def test_moo_2d_friendly_default_layout():
    """The dominance kernel's registered default is the (tile, 128) layout on
    big-population buckets (j = lane axis), shrinking with the bucket."""
    spec = registry.get("fastmoo.pallas")
    assert spec.default_tiles(spec.bucket(p=512, n_obj=2)) == {
        "tile": 64, "j_tile": 128,
    }
    assert spec.default_tiles(spec.bucket(p=16, n_obj=2)) == {
        "tile": 16, "j_tile": 16,
    }
