"""ExecutionContext: the unified execution policy and its back-compat shims.

The legacy ``backend=``/``ga_backend=`` strings must keep working everywhere
and resolve to the equivalent ``ExecutionContext``; bad backend / mesh / axis
combinations must fail eagerly at construction, not deep inside an engine.
"""

import numpy as np
import pytest

from repro.core.dse import DSESettings
from repro.core.engine import (
    MESH_AXIS,
    ExecutionContext,
    as_context,
)


# ---------------------------------------------------------------------------
# Construction + eager validation
# ---------------------------------------------------------------------------


def test_default_context_is_numpy_unsharded():
    ctx = ExecutionContext()
    assert ctx.backend == "numpy"
    assert not ctx.is_jax
    assert ctx.resolved_ga_backend == "numpy"
    assert ctx.device_count == 1
    assert not ctx.shards("configs") and not ctx.shards("lanes")


def test_ga_backend_follows_backend_unless_overridden():
    assert ExecutionContext(backend="jax").resolved_ga_backend == "jax"
    assert (
        ExecutionContext(backend="jax", ga_backend="numpy").resolved_ga_backend
        == "numpy"
    )


@pytest.mark.parametrize(
    "kwargs",
    [
        dict(backend="torch"),
        dict(ga_backend="torch"),
        dict(kernel_impl="cuda"),
        dict(prng_impl="mersenne"),
        dict(backend="jax", shard_axes=("configs", "configs"), n_devices=1),
        dict(backend="jax", shard_axes=("rows",)),
        dict(backend="jax", n_devices=0),
        dict(backend="jax", n_devices=-2),
    ],
)
def test_bad_policy_fails_eagerly(kwargs):
    with pytest.raises(ValueError):
        ExecutionContext(**kwargs)


def test_sharding_requires_jax_backend():
    with pytest.raises(ValueError, match="requires backend='jax'"):
        ExecutionContext(backend="numpy", n_devices=4)


def test_mesh_with_no_shard_axes_is_rejected():
    with pytest.raises(ValueError, match="nothing to shard"):
        ExecutionContext(backend="jax", n_devices=2, shard_axes=())


def test_too_many_devices_fails_at_construction():
    import jax

    too_many = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="devices"):
        ExecutionContext(backend="jax", n_devices=too_many)


def test_shards_only_named_axes():
    ctx = ExecutionContext(backend="jax", shard_axes=("lanes",), n_devices=1)
    assert not ctx.shards("configs")
    with pytest.raises(ValueError):
        ctx.shards("batteries")


def test_kernel_impl_resolves_per_engine_menu():
    ctx = ExecutionContext(backend="jax", kernel_impl="gemm")
    # fastapp's menu includes gemm; fastchar's does not -> engine default
    assert ctx.resolve_impl(("gemm", "xla", "pallas")) == "gemm"
    assert ctx.resolve_impl(("xla", "pallas")) is None
    assert ctx.resolve_impl(("xla", "pallas"), "xla") == "xla"


def test_mesh_axis_name_and_single_device_mesh():
    ctx = ExecutionContext(backend="jax", n_devices=1)
    assert ctx.mesh().axis_names == (MESH_AXIS,)
    assert len(ctx.devices()) == 1


def test_prng_policy_key_kinds():
    import jax

    ctx = ExecutionContext(backend="jax")
    np.testing.assert_array_equal(
        np.asarray(ctx.prng_key(7)), np.asarray(jax.random.PRNGKey(7))
    )
    k = ExecutionContext(backend="jax", prng_impl="rbg").prng_key(7)
    assert jax.dtypes.issubdtype(k.dtype, jax.dtypes.prng_key)


# ---------------------------------------------------------------------------
# The as_context shim
# ---------------------------------------------------------------------------


def test_as_context_normalizes_legacy_strings():
    ctx = as_context("jax")
    assert isinstance(ctx, ExecutionContext) and ctx.backend == "jax"
    assert as_context("numpy", ga_backend="jax").resolved_ga_backend == "jax"
    assert as_context(None).backend == "numpy"


def test_as_context_passes_contexts_through():
    ctx = ExecutionContext(backend="jax")
    assert as_context(ctx) is ctx
    default = ExecutionContext(backend="jax", ga_backend="numpy")
    assert as_context(None, default=default) is default


def test_as_context_rejects_conflicting_ga_backend():
    ctx = ExecutionContext(backend="jax", ga_backend="numpy")
    with pytest.raises(ValueError, match="conflicting"):
        as_context(ctx, ga_backend="jax")


def test_as_context_rejects_bad_strings():
    with pytest.raises(ValueError, match="backend must be 'numpy' or 'jax'"):
        as_context("torch")


# ---------------------------------------------------------------------------
# DSESettings integration (eager validation + mirroring)
# ---------------------------------------------------------------------------


def test_dse_settings_strings_build_equivalent_context():
    st = DSESettings(backend="jax", ga_backend="numpy")
    assert isinstance(st.context, ExecutionContext)
    assert st.context.backend == "jax"
    assert st.context.ga_backend == "numpy"
    assert st.resolved_ga_backend == "numpy"


def test_dse_settings_context_mirrors_legacy_fields():
    ctx = ExecutionContext(backend="jax")
    st = DSESettings(context=ctx)
    assert st.backend == "jax" and st.ga_backend is None
    assert st.context is ctx


def test_dse_settings_conflicting_policy_is_rejected():
    ctx = ExecutionContext(backend="numpy")
    with pytest.raises(ValueError, match="conflicting"):
        DSESettings(backend="jax", context=ctx)
    # an explicit numpy string against a jax context is just as conflicting
    with pytest.raises(ValueError, match="conflicting"):
        DSESettings(backend="numpy", context=ExecutionContext(backend="jax"))
    with pytest.raises(ValueError, match="conflicting"):
        DSESettings(
            ga_backend="numpy",
            context=ExecutionContext(backend="jax", ga_backend="jax"),
        )
    with pytest.raises(TypeError):
        DSESettings(context="jax")


def test_dse_settings_matching_strings_alongside_context_are_accepted():
    ctx = ExecutionContext(backend="jax")
    # ga_backend='jax' agrees with the context's *resolved* GA backend
    st = DSESettings(backend="jax", ga_backend="jax", context=ctx)
    assert st.context is ctx and st.resolved_ga_backend == "jax"


@pytest.mark.parametrize("bad", ["torch", "", "JAX"])
def test_dse_settings_bad_backend_strings_fail_eagerly(bad):
    with pytest.raises(ValueError, match="backend must be 'numpy' or 'jax'"):
        DSESettings(backend=bad)


def test_dse_settings_bad_mesh_fails_eagerly():
    import jax

    too_many = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="devices"):
        DSESettings(
            context=ExecutionContext(backend="jax", n_devices=too_many)
        )


def test_dse_settings_replace_keeps_context():
    import dataclasses

    st = DSESettings(backend="jax")
    st2 = dataclasses.replace(st, const_sf=0.5)
    assert st2.context.backend == "jax"
    assert st2.const_sf == 0.5


# ---------------------------------------------------------------------------
# Shim acceptance across the stack (strings land on the same context logic)
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# PRNG policy threaded into dataset generation (ROADMAP follow-on)
# ---------------------------------------------------------------------------


def test_gen_random_default_impl_parity():
    """Under the default PRNG policy, context-threaded generation is
    bit-identical to the legacy numpy stream (caches stay valid)."""
    from repro.core.dataset import gen_random
    from repro.core.operator_model import spec_for

    spec = spec_for(4)
    legacy = gen_random(spec, 16, seed=5)
    for ctx in (None, ExecutionContext(), ExecutionContext(backend="jax")):
        np.testing.assert_array_equal(gen_random(spec, 16, seed=5, ctx=ctx), legacy)


def test_gen_random_named_prng_impl_generates_on_device():
    """A named prng_impl switches to jax.random generation keyed by the
    context's typed keys: deterministic per seed, threefry matches the raw
    PRNGKey stream, rbg differs from the legacy numpy stream."""
    import jax

    from repro.core.dataset import gen_random
    from repro.core.operator_model import spec_for

    spec = spec_for(4)
    ctx3 = ExecutionContext(backend="jax", prng_impl="threefry2x32")
    out = gen_random(spec, 16, seed=5, ctx=ctx3)
    np.testing.assert_array_equal(out, gen_random(spec, 16, seed=5, ctx=ctx3))
    ref = np.asarray(
        jax.random.randint(jax.random.PRNGKey(5), (16, spec.n_luts), 0, 2,
                           dtype="uint8")
    )
    np.testing.assert_array_equal(out, ref)

    rbg = gen_random(spec, 16, seed=5, ctx=ExecutionContext(backend="jax",
                                                            prng_impl="rbg"))
    assert rbg.shape == (16, spec.n_luts) and set(np.unique(rbg)) <= {0, 1}
    assert not np.array_equal(rbg, gen_random(spec, 16, seed=5))


def test_build_training_dataset_threads_context_prng(tmp_path):
    """build_training_dataset forwards the context to gen_random: default
    policy keeps the historical configs; a named impl changes the RANDOM set."""
    from repro.core.dataset import build_training_dataset
    from repro.core.operator_model import spec_for

    spec = spec_for(4)
    base = build_training_dataset(spec, n_random=8, seed=1,
                                  include_pattern=False)
    via_ctx = build_training_dataset(
        spec, n_random=8, seed=1, include_pattern=False,
        backend=ExecutionContext(backend="jax"),
    )
    np.testing.assert_array_equal(base.configs, via_ctx.configs)
    for k in base.metrics:
        np.testing.assert_allclose(base.metrics[k], via_ctx.metrics[k],
                                   rtol=1e-6)

    rbg = build_training_dataset(
        spec, n_random=8, seed=1, include_pattern=False,
        backend=ExecutionContext(backend="jax", prng_impl="rbg"),
    )
    assert not np.array_equal(base.configs, rbg.configs)


def test_metrics_and_solver_shims_accept_strings_and_contexts():
    from repro.core.metrics import behav_metrics
    from repro.core.operator_model import spec_for

    spec = spec_for(4)
    cfgs = np.ones((2, spec.n_luts), dtype=np.uint8)
    ref = behav_metrics(spec, cfgs, backend="numpy")
    via_ctx = behav_metrics(spec, cfgs, backend=ExecutionContext())
    for k in ref:
        np.testing.assert_array_equal(ref[k], via_ctx[k])
    with pytest.raises(ValueError, match="backend must be 'numpy' or 'jax'"):
        behav_metrics(spec, cfgs, backend="torch")
