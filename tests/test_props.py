"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional test dependency (see pyproject.toml); when it is
not installed the whole module is skipped instead of aborting collection.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.apps.base import quantize_int8
from repro.core.moo import hypervolume_2d, pareto_mask
from repro.core.operator_model import (
    config_to_masks,
    masks_to_config,
    product_tables,
    simulate_product,
    spec_for,
)
from repro.core.ppa import ppa_metrics

SPEC4 = spec_for(4)


@given(st.integers(0, 2**10 - 1), st.integers(-8, 7), st.integers(-8, 7))
@settings(max_examples=60, deadline=None)
def test_table_equals_bit_oracle_everywhere(cfg_code, a, b):
    cfg = np.array([(cfg_code >> i) & 1 for i in range(10)], np.uint8)
    table = product_tables(SPEC4, cfg[None])[0]
    assert table[a & 15, b & 15] == simulate_product(SPEC4, a, b, cfg)


@given(st.lists(st.integers(0, 2**10 - 1), min_size=1, max_size=8))
@settings(max_examples=30, deadline=None)
def test_masks_roundtrip_prop(codes):
    cfgs = np.array(
        [[(c >> i) & 1 for i in range(10)] for c in codes], np.uint8
    )
    np.testing.assert_array_equal(
        masks_to_config(SPEC4, config_to_masks(SPEC4, cfgs)), cfgs
    )


@given(st.integers(0, 2**10 - 1))
@settings(max_examples=40, deadline=None)
def test_ppa_monotone_in_lut_superset(cfg_code):
    """Adding a LUT back never reduces LUT count and never reduces power."""
    cfg = np.array([(cfg_code >> i) & 1 for i in range(10)], np.uint8)
    if cfg.all():
        return
    j = int(np.argmin(cfg))
    sup = cfg.copy()
    sup[j] = 1
    m = ppa_metrics(SPEC4, np.stack([cfg, sup]))
    assert m["LUTS"][1] == m["LUTS"][0] + 1
    assert m["POWER"][1] >= m["POWER"][0] - 1e-9


@given(
    st.lists(
        st.tuples(st.floats(0, 1, allow_nan=False), st.floats(0, 1, allow_nan=False)),
        min_size=1, max_size=30,
    )
)
@settings(max_examples=50, deadline=None)
def test_pareto_mask_invariants(points):
    pts = np.array(points, np.float64)
    mask = pareto_mask(pts)
    assert mask.any()  # at least one non-dominated point
    kept = pts[mask]
    # no kept point dominates another kept point (strictly)
    for i in range(len(kept)):
        for j in range(len(kept)):
            if i != j:
                assert not (np.all(kept[j] <= kept[i]) and np.any(kept[j] < kept[i]))


@given(
    st.lists(
        st.tuples(st.floats(0, 1, allow_nan=False), st.floats(0, 1, allow_nan=False)),
        min_size=1, max_size=20,
    ),
    st.tuples(st.floats(0, 1, allow_nan=False), st.floats(0, 1, allow_nan=False)),
)
@settings(max_examples=50, deadline=None)
def test_hypervolume_bounds_and_pareto_invariance(points, extra):
    pts = np.array(points, np.float64)
    ref = np.array([1.0, 1.0])
    hv = hypervolume_2d(pts, ref)
    assert 0.0 <= hv <= 1.0 + 1e-12
    # adding any point never decreases HV
    hv2 = hypervolume_2d(np.vstack([pts, np.array(extra)]), ref)
    assert hv2 >= hv - 1e-12
    # dominated points contribute nothing: HV of the Pareto subset is equal
    hv3 = hypervolume_2d(pts[pareto_mask(pts)], ref)
    assert abs(hv3 - hv) < 1e-12


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1, max_size=100),
       st.sampled_from([4, 8]))
@settings(max_examples=50, deadline=None)
def test_quantize_roundtrip_bound(values, n_bits):
    x = np.array(values, np.float64)
    codes, scale = quantize_int8(x, n_bits=n_bits)
    half = 1 << (n_bits - 1)
    signed = np.where(codes >= half, codes - (1 << n_bits), codes)
    err = np.abs(x - scale * signed)
    assert (err <= scale / 2 + 1e-9).all()
    assert (codes >= 0).all() and (codes < (1 << n_bits)).all()
