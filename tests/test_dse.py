"""End-to-end DSE pipeline (paper §5.3): MaP / GA / MaP+GA on the 4x4 operator."""

import numpy as np
import pytest

from repro.core.dataset import build_training_dataset
from repro.core.dse import (
    CONST_SF_GRID,
    DSESettings,
    fixed_library,
    hv_reference,
    map_solution_pool,
    run_dse,
)
from repro.core.operator_model import spec_for


@pytest.fixture(scope="module")
def setup():
    spec = spec_for(4)
    ds = build_training_dataset(spec, n_random=300, seed=0)
    settings = DSESettings(
        const_sf=0.5, pop_size=24, n_gen=12, n_quad_grid=(0, 4),
        pool_size=4, seed=0,
    )
    pool = map_solution_pool(spec, ds, settings)
    return spec, ds, settings, pool


def test_map_pool_nonempty_and_feasible_units(setup):
    spec, ds, settings, pool = setup
    assert len(pool) > 0
    assert pool.shape[1] == spec.n_luts
    assert set(np.unique(pool)) <= {0, 1}


def test_methods_produce_validated_fronts(setup):
    spec, ds, settings, pool = setup
    ref = hv_reference(ds, settings)
    results = {}
    for method in ("ga", "map", "map+ga"):
        r = run_dse(spec, ds, method, settings=settings, map_pool=pool, ref=ref)
        results[method] = r
        assert r.hv_ppf >= 0 and r.hv_vpf >= 0
        if len(r.vpf_objs):
            # VPF is truly nondominated under true metrics
            from repro.core.moo import pareto_mask
            assert pareto_mask(r.vpf_objs).all()
    # the paper's headline: MaP-seeding does not hurt and typically helps
    assert results["map+ga"].hv_ppf >= results["ga"].hv_ppf * 0.95


def test_map_ga_beats_ga_on_tight_constraints():
    """Paper Fig. 12: the MaP advantage is largest under tight constraints."""
    spec = spec_for(4)
    ds = build_training_dataset(spec, n_random=300, seed=0)
    st = DSESettings(const_sf=0.2, pop_size=24, n_gen=12, n_quad_grid=(0, 4),
                     pool_size=4, seed=1)
    pool = map_solution_pool(spec, ds, st)
    ref = hv_reference(ds, st)
    hv_ga = run_dse(spec, ds, "ga", settings=st, ref=ref).hv_vpf
    hv_mapga = run_dse(spec, ds, "map+ga", settings=st, map_pool=pool, ref=ref).hv_vpf
    assert hv_mapga >= hv_ga * 0.99


def test_fixed_library_is_frozen_and_valid():
    spec = spec_for(8)
    lib1 = fixed_library(spec)
    lib2 = fixed_library(spec)
    np.testing.assert_array_equal(lib1, lib2)
    assert lib1.shape[1] == spec.n_luts
    assert len(np.unique(lib1, axis=0)) == len(lib1)


def test_const_sf_grid_matches_paper():
    assert CONST_SF_GRID == (0.2, 0.5, 0.8, 1.0, 1.2, 1.5)
