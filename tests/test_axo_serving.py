"""End-to-end AxO serving: registry-backed kernel dispatch at decode shapes,
whole-model deployment entry structure, and generation fidelity of a
fully-deployed reduced LM vs the exact serving path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.axo import AXO_LAYERS, AxOOperator, axo_linear, deploy_axo
from repro.axo import deploy as deploy_mod
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_arch
from repro.core.operator_model import accurate_config, spec_for
from repro.data.synthetic import SyntheticLM
from repro.kernels import ops, registry
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.model import forward, logits_fn, model_spec
from repro.models.sharding import BASE_RULES
from repro.models.spec import init_params

RNG = np.random.default_rng(0)


def _mild_op(rank=16):
    """1-column truncation of the first CC row: a mild Pareto design."""
    spec8 = spec_for(8)
    cfg = accurate_config(spec8)
    cfg[0] = 0
    return AxOOperator.from_config(cfg, rank=rank)


def _granite():
    cfg = get_arch("granite-3-2b").reduced()
    params = init_params(model_spec(cfg), seed=0, dtype=jnp.float32)
    return cfg, params


# ---------------------------------------------------------------------------
# Registry integration
# ---------------------------------------------------------------------------


def test_serving_kernels_are_registered():
    assert "pallas" in registry.impl_names("axo_matmul")
    assert "pallas" in registry.impl_names("flash_attention")
    axo = registry.get("axo_matmul.pallas")
    assert set(dict(axo.tunables)) == {"bm", "bn", "bk"}
    fa = registry.get("flash_attention.pallas")
    assert set(dict(fa.tunables)) == {"bq", "bk"}
    # both expose cost/VMEM formulas for the autotuner
    cost = axo.cost_estimate(m=128, k=128, n=128, rank=4, bm=128, bn=128, bk=128)
    assert cost["flops"] > 0 and cost["bytes_accessed"] > 0


def test_axo_linear_decode_shape_dispatches_pallas(monkeypatch):
    """M=4, K=N=128 (a decode microbatch) must hit the Pallas kernel -- the
    historical ``% 128`` gate demoted it to the reference path."""
    calls = []
    real = ops.axo_matmul

    def spy(*a, **kw):
        calls.append((a[0].shape, a[1].shape))
        return real(*a, **kw)

    monkeypatch.setattr(ops, "axo_matmul", spy)
    op = _mild_op(rank=2)
    x = jnp.asarray(RNG.standard_normal((4, 128)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((128, 128)), jnp.float32)
    y = axo_linear(x, w, op, use_kernel=True)
    assert calls == [((4, 128), (128, 128))]
    ref = axo_linear(x, w, op, use_kernel=False)
    assert calls == [((4, 128), (128, 128))]   # ref path stays off-kernel
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_deployment_decode_shape_dispatches_pallas(monkeypatch):
    calls = []
    real = deploy_mod.axo_matmul_pallas

    def spy(*a, **kw):
        calls.append(a[0].shape)
        return real(*a, **kw)

    monkeypatch.setattr(deploy_mod, "axo_matmul_pallas", spy)
    cfg, params = _granite()
    dep = deploy_axo(params, _mild_op(rank=2), cfg,
                     layers=("head",), impl="pallas")
    x = jnp.asarray(RNG.standard_normal((4, cfg.d_model)), jnp.float32)
    dep.apply(x, dep.head)
    assert calls == [(4, cfg.d_model)]


# ---------------------------------------------------------------------------
# Deployment structure + per-entry semantics
# ---------------------------------------------------------------------------


def test_deploy_entry_counts_and_validation():
    cfg, params = _granite()
    op = _mild_op(rank=4)
    # granite reduced: 1 attn/dense block -> wq wk wv wo + gate/up/down + head
    assert deploy_axo(params, op, cfg).n_entries == 8
    assert deploy_axo(params, op, cfg, layers=("head",)).n_entries == 1
    assert deploy_axo(params, op, cfg, layers=("attn",)).n_entries == 4
    with pytest.raises(ValueError, match="unknown AxO layer"):
        deploy_axo(params, op, cfg, layers=("attn", "lstm"))
    with pytest.raises(ValueError, match="impl"):
        deploy_axo(params, op, cfg, impl="cuda")


def test_deployment_entries_cache_weight_factors():
    """Entries carry pre-gathered signed values and G_r(W) with the stacked
    repeats axis; head is unstacked (d, vocab)."""
    cfg, params = _granite()
    op = _mild_op(rank=3)
    dep = deploy_axo(params, op, cfg, impl="xla")
    rep = cfg.stages[0].repeats
    d = cfg.d_model
    ent = dep.stages["0"]["0"]["mixer"]["wq"]
    assert ent["bv"].shape[:2] == (rep, d)
    assert ent["gb"].shape[:3] == (rep, 3, d)
    assert ent["scale"].shape == (rep,)
    assert dep.head["bv"].shape[0] == d
    assert dep.head["gb"].shape[0] == 3


def test_head_apply_matches_axo_linear():
    """dep.apply on the cached head entry == axo_linear on the raw weight."""
    cfg, params = _granite()
    op = _mild_op(rank=8)
    dep = deploy_axo(params, op, cfg, layers=("head",), impl="xla")
    w = (params["embed"]["tok"].T if cfg.tie_embeddings
         else params["embed"]["unembed"]).astype(jnp.float32)
    x = jnp.asarray(RNG.standard_normal((6, cfg.d_model)), jnp.float32)
    got = dep.apply(x, dep.head)
    want = axo_linear(x, w, op, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_deployment_pallas_matches_xla_contraction():
    cfg, params = _granite()
    op = _mild_op(rank=4)
    dep_p = deploy_axo(params, op, cfg, layers=("head",), impl="pallas")
    dep_x = deploy_axo(params, op, cfg, layers=("head",), impl="xla")
    x = jnp.asarray(RNG.standard_normal((4, cfg.d_model)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(dep_p.apply(x, dep_p.head)),
        np.asarray(dep_x.apply(x, dep_x.head)),
        rtol=1e-5, atol=1e-4,
    )


def test_deep_arch_deploys_mla_and_moe():
    """deepseek reduced exercises the MLA + MoE expert walk."""
    cfg = get_arch("deepseek-v3-671b").reduced()
    params = init_params(model_spec(cfg), seed=0, dtype=jnp.float32)
    dep = deploy_axo(params, _mild_op(rank=2), cfg, impl="xla")
    assert dep.n_entries == 18
    li = next(iter(dep.stages["0"]))
    mixer = dep.stages["0"][li]["mixer"]
    assert set(mixer) == {"wq_a", "wq_b", "wkv_a", "wo"}   # wkv_b stays exact


# ---------------------------------------------------------------------------
# End-to-end: fully-deployed reduced model serving fidelity
# ---------------------------------------------------------------------------


def _generate(prefill, decode, params, toks, gen):
    plen = toks.shape[1]
    logits, cache = prefill(params, toks)
    nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out, lgs = [nxt], [logits[:, -1]]
    for i in range(plen, plen + gen - 1):
        logits, cache = decode(params, cache, nxt, jnp.int32(i))
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(nxt)
        lgs.append(logits[:, -1])
    return jnp.concatenate(out, 1), lgs


def _replay(prefill, decode, params, toks, trajectory):
    plen = toks.shape[1]
    logits, cache = prefill(params, toks)
    lgs = [logits[:, -1]]
    for j in range(trajectory.shape[1] - 1):
        logits, cache = decode(params, cache, trajectory[:, j:j + 1],
                               jnp.int32(plen + j))
        lgs.append(logits[:, -1])
    return lgs


def test_fully_deployed_generation_tracks_exact():
    """Rank-16 mild-design deployment in EVERY linear layer: teacher-forced
    greedy decisions along the exact trajectory stay within the top-1
    agreement bound (int8 quantization + mild operator error)."""
    cfg, params = _granite()
    rules = BASE_RULES
    batch, plen, gen = 2, 8, 6
    max_seq = plen + gen
    data = SyntheticLM(cfg, ShapeConfig("serve", max_seq, batch, "train"), seed=0)
    toks = jnp.asarray(data.batch(0)["tokens"])[:, :plen]

    prefill = jax.jit(make_prefill_step(cfg, rules, max_seq=max_seq))
    decode = jax.jit(make_decode_step(cfg, rules))
    exact_toks, exact_lgs = _generate(prefill, decode, params, toks, gen)

    dep = deploy_axo(params, _mild_op(rank=16), cfg,
                     layers=AXO_LAYERS, impl="xla")
    assert dep.n_entries == 8
    pre_a = jax.jit(make_prefill_step(cfg, rules, max_seq=max_seq, axo=dep))
    dec_a = jax.jit(make_decode_step(cfg, rules, axo=dep))
    rep = _replay(pre_a, dec_a, params, toks, exact_toks)
    top1 = float(np.mean([
        (jnp.argmax(a, -1) == jnp.argmax(e, -1)).mean()
        for a, e in zip(rep, exact_lgs)]))
    rel = float(np.mean([
        jnp.linalg.norm(a - e) / jnp.maximum(jnp.linalg.norm(e), 1e-9)
        for a, e in zip(rep, exact_lgs)]))
    assert top1 >= 0.5, (top1, rel)
    assert rel < 0.5, (top1, rel)


def test_head_only_deployment_changes_only_logits():
    """Head-only deployment leaves hidden states bit-identical; logits differ
    only by the quantized head matmul."""
    cfg, params = _granite()
    toks = jnp.asarray(
        SyntheticLM(cfg, ShapeConfig("smoke", 16, 2, "train")).batch(0)["tokens"])
    dep = deploy_axo(params, _mild_op(rank=16), cfg,
                     layers=("head",), impl="xla")
    x_ref, _, _ = forward(params, cfg, BASE_RULES, toks, mode="train")
    x_axo, _, _ = forward(params, cfg, BASE_RULES, toks, mode="train", axo=dep)
    np.testing.assert_array_equal(np.asarray(x_ref), np.asarray(x_axo))
    lg_ref = logits_fn(params, cfg, BASE_RULES, x_ref)
    lg_axo = logits_fn(params, cfg, BASE_RULES, x_axo, axo=dep)
    rel = float(jnp.linalg.norm(lg_axo - lg_ref) / jnp.linalg.norm(lg_ref))
    assert 0 < rel < 0.1
