"""Fault-tolerant train loop: checkpoint/restart equivalence, straggler watch."""

import time

import numpy as np
import pytest

from repro.train import TrainLoopConfig, train_loop


def _toy_problem():
    """Tiny quadratic 'training' with a deterministic seekable batch fn."""
    target = np.arange(8, dtype=np.float64)

    def init_state():
        return np.zeros(8), np.zeros(8)  # params, momentum

    def batch_fn(step):
        rng = np.random.default_rng(step)
        return rng.standard_normal(8) * 0.01

    def step_fn(params, opt, step, batch):
        grad = 2 * (params - target) + batch
        opt = 0.9 * opt + grad
        params = params - 0.05 * opt
        loss = float(((params - target) ** 2).sum())
        return params, opt, {"loss": loss}

    return init_state, batch_fn, step_fn


def test_uninterrupted_run_converges(tmp_path):
    init_state, batch_fn, step_fn = _toy_problem()
    cfg = TrainLoopConfig(total_steps=60, ckpt_every=20,
                          ckpt_dir=str(tmp_path), async_ckpt=False)
    out = train_loop(step_fn, init_state, batch_fn, cfg)
    assert out["history"][-1][1] < out["history"][0][1]
    assert out["restarts"] == 0


def test_fault_injection_recovers_bitwise(tmp_path):
    init_state, batch_fn, step_fn = _toy_problem()
    # clean reference run
    cfg_a = TrainLoopConfig(total_steps=50, ckpt_every=10,
                            ckpt_dir=str(tmp_path / "a"), async_ckpt=False)
    ref = train_loop(step_fn, init_state, batch_fn, cfg_a)

    # faulting run: dies once at step 23 (after the step-19 checkpoint)
    fired = {"n": 0}

    def fault(step):
        if step == 23 and fired["n"] == 0:
            fired["n"] += 1
            raise RuntimeError("injected node failure")

    cfg_b = TrainLoopConfig(total_steps=50, ckpt_every=10,
                            ckpt_dir=str(tmp_path / "b"), async_ckpt=False)
    out = train_loop(step_fn, init_state, batch_fn, cfg_b, fault_hook=fault)
    assert out["restarts"] == 1
    # the final state and loss history match the uninterrupted run exactly:
    # checkpoint/restart + seekable data => bitwise-identical replay
    np.testing.assert_array_equal(out["params"], ref["params"])
    assert [l for _, l in out["history"]] == [l for _, l in ref["history"]]


def test_exhausted_restarts_reraise(tmp_path):
    init_state, batch_fn, step_fn = _toy_problem()

    def always_fault(step):
        raise RuntimeError("dead node")

    cfg = TrainLoopConfig(total_steps=10, ckpt_every=5, max_restarts=2,
                          ckpt_dir=str(tmp_path), async_ckpt=False)
    with pytest.raises(RuntimeError):
        train_loop(step_fn, init_state, batch_fn, cfg, fault_hook=always_fault)


def test_straggler_detection(tmp_path):
    init_state, batch_fn, step_fn = _toy_problem()
    seen = []

    def slow_step(params, opt, step, batch):
        if int(step) == 30:
            time.sleep(0.3)
        return step_fn(params, opt, step, batch)

    cfg = TrainLoopConfig(total_steps=40, ckpt_every=100, straggler_factor=3.0,
                          ckpt_dir=str(tmp_path), async_ckpt=False)
    out = train_loop(slow_step, init_state, batch_fn, cfg,
                     on_straggler=lambda s, dt, med: seen.append(s))
    assert out["stragglers"] >= 1
    assert 30 in seen
