"""Multi-objective tooling: Pareto masks, hypervolume, NSGA-II."""

import numpy as np

from repro.core.moo import (
    crowding_distance,
    fast_nondominated_sort,
    hypervolume_2d,
    nsga2,
    pareto_mask,
)


def _brute_pareto(pts):
    n = len(pts)
    keep = np.ones(n, bool)
    for i in range(n):
        for j in range(n):
            if i != j and np.all(pts[j] <= pts[i]) and np.any(pts[j] < pts[i]):
                keep[i] = False
                break
    return keep


def test_pareto_mask_matches_bruteforce():
    rng = np.random.default_rng(0)
    for _ in range(20):
        pts = rng.random((40, 2))
        got = pareto_mask(pts)
        want = _brute_pareto(pts)
        np.testing.assert_array_equal(got, want)


def test_hypervolume_known_values():
    ref = np.array([1.0, 1.0])
    assert hypervolume_2d(np.array([[0.0, 0.0]]), ref) == 1.0
    assert hypervolume_2d(np.array([[0.5, 0.5]]), ref) == 0.25
    hv = hypervolume_2d(np.array([[0.0, 0.5], [0.5, 0.0]]), ref)
    np.testing.assert_allclose(hv, 0.75)
    # points beyond the reference contribute nothing
    assert hypervolume_2d(np.array([[2.0, 2.0]]), ref) == 0.0


def test_hypervolume_monotone_in_points():
    rng = np.random.default_rng(1)
    pts = rng.random((30, 2))
    ref = np.array([1.5, 1.5])
    hv = [hypervolume_2d(pts[:k], ref) for k in range(1, 31)]
    assert all(b >= a - 1e-12 for a, b in zip(hv, hv[1:]))


def test_nondominated_sort_feasibility_first():
    objs = np.array([[0.0, 0.0], [1.0, 1.0], [-5.0, -5.0]])
    viol = np.array([0.0, 0.0, 1.0])  # best objectives but infeasible
    rank = fast_nondominated_sort(objs, viol)
    assert rank[0] == 0
    assert rank[2] > rank[1] or rank[2] > rank[0]


def test_crowding_extremes_are_infinite():
    objs = np.array([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
    d = crowding_distance(objs)
    assert np.isinf(d[0]) and np.isinf(d[-1])


def test_nsga2_improves_hypervolume_on_toy_problem():
    # minimize (popcount of first half, popcount of second half inverted)
    def eval_fn(pop):
        a = pop[:, :8].sum(axis=1).astype(float)
        b = (1 - pop[:, 8:]).sum(axis=1).astype(float)
        return np.stack([a, b], axis=-1)

    ref = np.array([9.0, 9.0])
    res = nsga2(eval_fn, n_bits=16, pop_size=24, n_gen=30, seed=0, hv_ref=ref)
    hv = [h for _, h in res.hv_history]
    assert hv[-1] > hv[0]
    assert hv[-1] > 0.9 * 81  # near-full front discovered


def test_nondominated_sort_all_infeasible_ranks_by_violation():
    """With no feasible point, fronts follow pure violation ordering."""
    objs = np.array([[0.0, 0.0], [9.0, 9.0], [5.0, 5.0], [1.0, 1.0]])
    viol = np.array([0.4, 0.1, 0.2, 0.3])
    rank = fast_nondominated_sort(objs, viol)
    # smaller violation dominates regardless of objectives
    np.testing.assert_array_equal(rank, np.argsort(np.argsort(viol)))


def test_nondominated_sort_duplicate_points_share_a_front():
    """Exact duplicates never dominate each other (<= holds, < does not)."""
    objs = np.array([[1.0, 1.0], [1.0, 1.0], [0.5, 2.0], [2.0, 2.0]])
    rank = fast_nondominated_sort(objs, np.zeros(4))
    assert rank[0] == rank[1] == 0
    assert rank[2] == 0          # incomparable with the duplicates
    assert rank[3] == 1          # dominated by the duplicates


def test_hypervolume_against_bruteforce_grid_oracle():
    """Monte-Carlo-free oracle: count dominated cells of a fine uniform grid."""
    rng = np.random.default_rng(7)
    ref = np.array([1.0, 1.0])
    for _ in range(3):
        pts = rng.random((12, 2)) * 0.9
        n = 400
        xs = (np.arange(n) + 0.5) / n
        gx, gy = np.meshgrid(xs, xs, indexing="ij")
        covered = np.zeros((n, n), dtype=bool)
        for x, y in pts:
            covered |= (gx >= x) & (gy >= y)
        brute = covered.mean()  # fraction of the [0,1]^2 reference box
        hv = hypervolume_2d(pts, ref)
        assert abs(hv - brute) < 2.0 / n  # grid discretization error bound


def test_crowding_constant_objective_column_contributes_nothing():
    objs = np.stack([np.arange(5, dtype=float), np.full(5, 2.0)], axis=-1)
    d = crowding_distance(objs)
    assert np.isinf(d[0]) and np.isinf(d[-1])
    # interior distances come only from the varying column (span-normalized)
    np.testing.assert_allclose(d[1:-1], [0.5, 0.5, 0.5])


def test_crowding_all_constant_objectives():
    objs = np.ones((5, 2))
    d = crowding_distance(objs)
    assert np.isinf(d).sum() >= 2 and (d[np.isfinite(d)] == 0).all()


def test_nsga2_seeded_initial_population_is_used():
    def eval_fn(pop):
        return np.stack([pop.sum(1).astype(float), (1 - pop).sum(1).astype(float)], -1)

    init = np.zeros((4, 12), np.uint8)
    res = nsga2(eval_fn, n_bits=12, pop_size=8, n_gen=1, seed=0,
                initial_population=init)
    # the all-zeros seed is optimal in objective 0 and must survive gen 1
    assert (res.archive_configs.sum(1) == 0).any()
