"""Unsigned operator variants: ``spec_for(n_bits, op=..., signed=False)``.

The unsigned family drops the two's-complement operand interpretation (codes
ARE magnitudes) and the final-row / adder sign handling; everything else --
the per-row LUT decomposition, column-removal config space, entry synthesis
-- is shared with the signed operators.  Exhaustive bit-match against the
independent :func:`simulate_product` oracle at 4/6/8 bits.
"""

import numpy as np
import pytest

from repro.core.dataset import characterize, gen_random
from repro.core.operator_model import (
    accurate_config,
    entry_product,
    exact_table,
    product_tables,
    simulate_product,
    spec_for,
)


def _config_to_masks(spec, configs):
    from repro.core.operator_model import config_to_masks

    return config_to_masks(spec, configs)


class TestSpecFamily:
    def test_tags(self):
        assert spec_for(8).tag == "mul8"
        assert spec_for(8, signed=False).tag == "mul8u"
        assert spec_for(6, op="add", signed=False).tag == "add6u"

    def test_spec_for_caches_distinct_variants(self):
        assert spec_for(8) is spec_for(8)
        assert spec_for(8) == spec_for(8, op="mul", signed=True)
        assert spec_for(8) != spec_for(8, signed=False)

    def test_unsigned_operand_values_are_magnitudes(self):
        for n in (4, 6, 8):
            u = spec_for(n, signed=False).operand_values
            np.testing.assert_array_equal(u, np.arange(1 << n))

    def test_signed_operand_values_unchanged(self):
        v = spec_for(4).operand_values
        assert v.min() == -8 and v.max() == 7  # two's complement regression


@pytest.mark.parametrize("n_bits", [4, 6, 8])
@pytest.mark.parametrize("op", ["mul", "add"])
class TestAccurateExhaustive:
    def test_accurate_config_is_exact(self, n_bits, op):
        """The all-ones config must compute true unsigned a*b / a+b over the
        ENTIRE operand grid (exhaustive at every bit width)."""
        spec = spec_for(n_bits, op=op, signed=False)
        tab = product_tables(spec, accurate_config(spec)[None])[0]
        u = np.arange(1 << n_bits, dtype=np.int64)
        want = u[:, None] * u[None, :] if op == "mul" else u[:, None] + u[None, :]
        np.testing.assert_array_equal(tab, want)
        np.testing.assert_array_equal(exact_table(spec), want)


@pytest.mark.parametrize("n_bits", [4, 6, 8])
@pytest.mark.parametrize("op", ["mul", "add"])
def test_random_configs_match_simulate_oracle(n_bits, op):
    """product_tables (entry-synthesis route) vs the independent bit-level
    simulator on random approximate configs: exhaustive operand grid at 4
    bits, dense random sampling at 6/8 bits."""
    spec = spec_for(n_bits, op=op, signed=False)
    rng = np.random.default_rng(n_bits)
    cfgs = gen_random(spec, 4, seed=n_bits)
    tabs = product_tables(spec, cfgs)
    if n_bits == 4:
        pairs = [(a, b) for a in range(16) for b in range(16)]
    else:
        n = 1 << n_bits
        pairs = list(zip(rng.integers(0, n, 200), rng.integers(0, n, 200)))
    for cfg, tab in zip(cfgs, tabs):
        for a, b in pairs:
            assert tab[a, b] == simulate_product(spec, int(a), int(b), cfg), (
                f"{spec.tag} a={a} b={b}"
            )


@pytest.mark.parametrize("n_bits", [4, 6])
def test_entry_product_matches_tables_unsigned(n_bits):
    """The vectorized entry synthesis equals the table route element-wise."""
    spec = spec_for(n_bits, op="mul", signed=False)
    cfgs = gen_random(spec, 6, seed=1)
    masks = _config_to_masks(spec, cfgs)
    codes = np.arange(1 << n_bits)
    vals = entry_product(
        spec, masks[:, None, None, :], codes[None, :, None], codes[None, None, :]
    )
    np.testing.assert_array_equal(vals, product_tables(spec, cfgs))


def test_unsigned_characterization_end_to_end():
    """The numpy characterization pipeline accepts unsigned specs: finite
    metrics, zero error on the accurate config."""
    spec = spec_for(6, signed=False)
    cfgs = np.concatenate([accurate_config(spec)[None], gen_random(spec, 3, seed=2)])
    ds = characterize(spec, cfgs)
    for name, vals in ds.metrics.items():
        assert np.isfinite(vals).all(), name
    for err_key in ("AVG_ABS_ERR", "MAX_ABS_ERR"):
        if err_key in ds.metrics:
            assert ds.metrics[err_key][0] == 0.0


def test_signed_tables_regression_unaffected():
    """Adding the signed flag must not move the signed 8x8 tables."""
    spec = spec_for(8)
    cfgs = gen_random(spec, 3, seed=3)
    tabs = product_tables(spec, cfgs)
    v = spec.operand_values
    acc = product_tables(spec, accurate_config(spec)[None])[0]
    np.testing.assert_array_equal(acc, v[:, None] * v[None, :])
    for cfg, tab in zip(cfgs, tabs):
        for a, b in [(0, 0), (3, 250), (128, 128), (255, 1), (77, 200)]:
            # signed simulate_product takes operand VALUES, tables take codes
            assert tab[a, b] == simulate_product(spec, int(v[a]), int(v[b]), cfg)
