"""Operator-model correctness: exactness, oracle parity, representations."""

import numpy as np
import pytest

from repro.core.operator_model import (
    accurate_config,
    config_to_masks,
    entry_product,
    entry_row_values,
    error_tables,
    exact_product_table,
    exact_table,
    masks_to_config,
    product_tables,
    simulate_product,
    spec_for,
)


@pytest.mark.parametrize("n_bits,expected_l", [(4, 10), (8, 36)])
def test_removable_lut_counts_match_paper(n_bits, expected_l):
    assert spec_for(n_bits).n_luts == expected_l


@pytest.mark.parametrize("n_bits", [2, 4, 8])
def test_accurate_config_is_exact(n_bits):
    spec = spec_for(n_bits)
    table = product_tables(spec, accurate_config(spec)[None])[0]
    np.testing.assert_array_equal(table, exact_product_table(n_bits))


def test_all_zero_config_keeps_only_sign_columns():
    """Removing every removable LUT leaves only the always-accurate top (sign)
    column of each row -- the outputs collapse onto that column's weight."""
    spec = spec_for(4)
    table = product_tables(spec, np.zeros((1, spec.n_luts), np.uint8))[0]
    assert not np.array_equal(table, exact_product_table(4))
    w = spec.width
    # every surviving contribution is a multiple of the sign-column weight
    assert (table % (1 << (w - 1)) == 0).all()
    # and the oracle agrees
    cfg = np.zeros(spec.n_luts, np.uint8)
    for a in (-8, -3, 0, 5, 7):
        for b in (-8, -1, 0, 4, 7):
            assert table[a & 15, b & 15] == simulate_product(spec, a, b, cfg)


@pytest.mark.parametrize("seed", range(5))
def test_table_matches_bit_level_oracle_4x4(seed):
    spec = spec_for(4)
    rng = np.random.default_rng(seed)
    cfg = rng.integers(0, 2, spec.n_luts).astype(np.uint8)
    table = product_tables(spec, cfg[None])[0]
    for a in range(-8, 8):
        for b in range(-8, 8):
            assert table[a & 15, b & 15] == simulate_product(spec, a, b, cfg)


def test_table_matches_oracle_8x8_sampled():
    spec = spec_for(8)
    rng = np.random.default_rng(0)
    cfg = rng.integers(0, 2, spec.n_luts).astype(np.uint8)
    table = product_tables(spec, cfg[None])[0]
    for _ in range(50):
        a = int(rng.integers(-128, 128))
        b = int(rng.integers(-128, 128))
        assert table[a & 255, b & 255] == simulate_product(spec, a, b, cfg)


def test_masks_roundtrip():
    spec = spec_for(8)
    rng = np.random.default_rng(1)
    cfgs = rng.integers(0, 2, (32, spec.n_luts)).astype(np.uint8)
    masks = config_to_masks(spec, cfgs)
    np.testing.assert_array_equal(masks_to_config(spec, masks), cfgs)


def test_error_tables_are_table_minus_exact():
    spec = spec_for(4)
    rng = np.random.default_rng(2)
    cfgs = rng.integers(0, 2, (8, spec.n_luts)).astype(np.uint8)
    err = error_tables(spec, cfgs)
    tabs = product_tables(spec, cfgs)
    np.testing.assert_array_equal(
        err, tabs.astype(np.int64) - exact_product_table(4)[None]
    )


def test_batch_table_consistency():
    """Batched characterization equals per-config characterization."""
    spec = spec_for(4)
    rng = np.random.default_rng(3)
    cfgs = rng.integers(0, 2, (16, spec.n_luts)).astype(np.uint8)
    batch = product_tables(spec, cfgs)
    for i in range(len(cfgs)):
        np.testing.assert_array_equal(batch[i], product_tables(spec, cfgs[i][None])[0])


# ---------------------------------------------------------------------------
# Table-free entry synthesis + generalized operator kinds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_bits", [4, 6, 8])
def test_entry_product_matches_tables_exhaustively(n_bits):
    """The table-free entry function IS the table: for every (config, a, b),
    ``entry_product`` equals the ``product_tables`` entry bit-for-bit."""
    spec = spec_for(n_bits)
    rng = np.random.default_rng(n_bits)
    cfgs = rng.integers(0, 2, (4, spec.n_luts)).astype(np.uint8)
    cfgs[0] = 1
    tables = product_tables(spec, cfgs).astype(np.int64)
    masks = config_to_masks(spec, cfgs).astype(np.int64)
    codes = np.arange(spec.n_inputs, dtype=np.int64)
    got = entry_product(
        spec, masks[:, None, None, :], codes[:, None], codes[None, :]
    )
    np.testing.assert_array_equal(got, tables)


def test_entry_row_values_combine_to_product():
    spec = spec_for(8)
    rng = np.random.default_rng(7)
    cfg = rng.integers(0, 2, spec.n_luts).astype(np.uint8)
    masks = config_to_masks(spec, cfg[None]).astype(np.int64)[0]
    a = rng.integers(-128, 128, 200)
    b = rng.integers(-128, 128, 200)
    rows = entry_row_values(spec, masks, a, b)           # (200, R)
    total = sum(rows[:, r] << (2 * r) for r in range(spec.rows))
    np.testing.assert_array_equal(total, entry_product(spec, masks, a, b))


def test_entry_product_accepts_signed_values_and_codes():
    """Negative int operands carry the same low bits as their codes (the
    row decomposition only reads ``n_bits`` low bits)."""
    spec = spec_for(8)
    rng = np.random.default_rng(8)
    cfg = rng.integers(0, 2, spec.n_luts).astype(np.uint8)
    masks = config_to_masks(spec, cfg[None]).astype(np.int64)[0]
    vals = rng.integers(-128, 128, 100)
    codes = vals & (spec.n_inputs - 1)
    np.testing.assert_array_equal(
        entry_product(spec, masks, vals, vals[::-1]),
        entry_product(spec, masks, codes, codes[::-1]),
    )


def test_adder_spec_shapes():
    spec = spec_for(8, op="add")
    assert (spec.rows, spec.width, spec.cols_removable) == (1, 9, 8)
    assert spec.n_luts == 8
    # odd widths are fine for adders (the evenness constraint is mul-only)
    assert spec_for(5, op="add").n_luts == 5


def test_adder_accurate_config_is_exact():
    spec = spec_for(6, op="add")
    table = product_tables(spec, accurate_config(spec)[None])[0]
    np.testing.assert_array_equal(table, exact_table(spec))


def test_adder_tables_match_bit_level_oracle_exhaustively():
    spec = spec_for(4, op="add")
    rng = np.random.default_rng(9)
    for _ in range(5):
        cfg = rng.integers(0, 2, spec.n_luts).astype(np.uint8)
        table = product_tables(spec, cfg[None])[0]
        for a in range(-8, 8):
            for b in range(-8, 8):
                assert table[a & 15, b & 15] == simulate_product(spec, a, b, cfg)


def test_exact_table_matches_legacy_product_table():
    np.testing.assert_array_equal(
        exact_table(spec_for(8)), exact_product_table(8)
    )
    spec = spec_for(4, op="add")
    assert exact_table(spec)[(-3) & 15, 7] == 4
