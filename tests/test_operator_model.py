"""Operator-model correctness: exactness, oracle parity, representations."""

import numpy as np
import pytest

from repro.core.operator_model import (
    accurate_config,
    config_to_masks,
    error_tables,
    exact_product_table,
    masks_to_config,
    product_tables,
    simulate_product,
    spec_for,
)


@pytest.mark.parametrize("n_bits,expected_l", [(4, 10), (8, 36)])
def test_removable_lut_counts_match_paper(n_bits, expected_l):
    assert spec_for(n_bits).n_luts == expected_l


@pytest.mark.parametrize("n_bits", [2, 4, 8])
def test_accurate_config_is_exact(n_bits):
    spec = spec_for(n_bits)
    table = product_tables(spec, accurate_config(spec)[None])[0]
    np.testing.assert_array_equal(table, exact_product_table(n_bits))


def test_all_zero_config_keeps_only_sign_columns():
    """Removing every removable LUT leaves only the always-accurate top (sign)
    column of each row -- the outputs collapse onto that column's weight."""
    spec = spec_for(4)
    table = product_tables(spec, np.zeros((1, spec.n_luts), np.uint8))[0]
    assert not np.array_equal(table, exact_product_table(4))
    w = spec.width
    # every surviving contribution is a multiple of the sign-column weight
    assert (table % (1 << (w - 1)) == 0).all()
    # and the oracle agrees
    cfg = np.zeros(spec.n_luts, np.uint8)
    for a in (-8, -3, 0, 5, 7):
        for b in (-8, -1, 0, 4, 7):
            assert table[a & 15, b & 15] == simulate_product(spec, a, b, cfg)


@pytest.mark.parametrize("seed", range(5))
def test_table_matches_bit_level_oracle_4x4(seed):
    spec = spec_for(4)
    rng = np.random.default_rng(seed)
    cfg = rng.integers(0, 2, spec.n_luts).astype(np.uint8)
    table = product_tables(spec, cfg[None])[0]
    for a in range(-8, 8):
        for b in range(-8, 8):
            assert table[a & 15, b & 15] == simulate_product(spec, a, b, cfg)


def test_table_matches_oracle_8x8_sampled():
    spec = spec_for(8)
    rng = np.random.default_rng(0)
    cfg = rng.integers(0, 2, spec.n_luts).astype(np.uint8)
    table = product_tables(spec, cfg[None])[0]
    for _ in range(50):
        a = int(rng.integers(-128, 128))
        b = int(rng.integers(-128, 128))
        assert table[a & 255, b & 255] == simulate_product(spec, a, b, cfg)


def test_masks_roundtrip():
    spec = spec_for(8)
    rng = np.random.default_rng(1)
    cfgs = rng.integers(0, 2, (32, spec.n_luts)).astype(np.uint8)
    masks = config_to_masks(spec, cfgs)
    np.testing.assert_array_equal(masks_to_config(spec, masks), cfgs)


def test_error_tables_are_table_minus_exact():
    spec = spec_for(4)
    rng = np.random.default_rng(2)
    cfgs = rng.integers(0, 2, (8, spec.n_luts)).astype(np.uint8)
    err = error_tables(spec, cfgs)
    tabs = product_tables(spec, cfgs)
    np.testing.assert_array_equal(
        err, tabs.astype(np.int64) - exact_product_table(4)[None]
    )


def test_batch_table_consistency():
    """Batched characterization equals per-config characterization."""
    spec = spec_for(4)
    rng = np.random.default_rng(3)
    cfgs = rng.integers(0, 2, (16, spec.n_luts)).astype(np.uint8)
    batch = product_tables(spec, cfgs)
    for i in range(len(cfgs)):
        np.testing.assert_array_equal(batch[i], product_tables(spec, cfgs[i][None])[0])
