"""Beyond-paper: Pallas kernel parity + interpret-mode call costs, plus the
kernel-registry autotune comparison (default vs searched block shapes for the
three DSE engine kernels).

CPU interpret-mode wall times are NOT TPU performance; the derived column is
the oracle parity (the roofline tables in EXPERIMENTS.md carry the perf
story).  The autotune rows time the CPU-meaningful impls (the XLA twins; the
dominance kernel's Pallas interpret timing is labelled as such) -- on TPU the
same search runs against real Mosaic timings and fills the pending columns in
EXPERIMENTS.md.

Standalone (the CI ``kernel-tuning`` smoke step):

  PYTHONPATH=src python -m benchmarks.bench_kernels --quick
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core.operator_model import error_tables, spec_for
from repro.kernels import axo_matmul, flash_attention, ssd_scan, registry, tuning
from repro.kernels.ref import (
    ref_axo_matmul_lowrank,
    ref_flash_attention,
    ref_ssd_scan,
)

from .common import BenchCtx, emit, row, timed

RNG = np.random.default_rng(0)


def _autotune_rows(quick: bool) -> list[dict]:
    """Default-tiles vs searched-tiles timings per engine kernel family."""
    shapes = {
        "fastchar.xla": dict(n_bits=8, d=64 if quick else 256),
        "fastapp.xla": dict(n_bits=8, d=32 if quick else 64, m=64,
                            k=64 if quick else 256, n=10),
        "fastmoo.pallas": dict(p=64 if quick else 128, n_obj=2),
    }
    rows = []
    for name, shape in shapes.items():
        spec = registry.get(name)
        bucket = spec.bucket(**shape)
        rec = tuning.autotune(spec, bucket)
        default = spec.default_tiles(bucket)
        d_label = ",".join(f"{k}={v}" for k, v in default.items())
        d_us = rec["timings"].get(d_label)
        t_label = ",".join(f"{k}={v}" for k, v in rec["tiles"].items())
        speedup = (d_us / rec["us"]) if d_us and rec["us"] else float("nan")
        note = "interpret-mode" if name.endswith("pallas") else "xla"
        rows.append(row(
            f"kernels.autotune.{spec.engine}",
            rec["us"] or 0.0,
            f"tuned[{t_label}] vs default[{d_label}]={d_us}us "
            f"speedup={speedup:.2f}x ({note}, {rec['candidates']} cands)",
        ))
    return rows


def run(ctx: BenchCtx) -> list[dict]:
    rows = []

    # axo_matmul
    spec = spec_for(8)
    cfg = RNG.integers(0, 2, spec.n_luts).astype(np.uint8)
    err = error_tables(spec, cfg[None])[0].astype(np.float64)
    u, s, vt = np.linalg.svd(err)
    r_ = 4
    f = jnp.asarray((u[:, :r_] * s[:r_]).astype(np.float32))
    g = jnp.asarray(vt[:r_].T.astype(np.float32))
    sv = jnp.asarray(spec.operand_values, jnp.float32)
    a = jnp.asarray(RNG.integers(0, 256, (256, 256)))
    b = jnp.asarray(RNG.integers(0, 256, (256, 256)))
    out, us = timed(lambda: axo_matmul(a, b, f, g, sv).block_until_ready())
    ref = ref_axo_matmul_lowrank(a, b, f, g, sv)
    errv = float(jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    rows.append(row("kernels.axo_matmul_256_r4", us, f"rel_err={errv:.2e}"))

    # flash attention
    q = jnp.asarray(RNG.standard_normal((2, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 2, 512, 64)), jnp.float32)
    out, us = timed(lambda: flash_attention(q, k, v, causal=True).block_until_ready())
    ref = ref_flash_attention(q, k, v, causal=True)
    errv = float(jnp.max(jnp.abs(out - ref)))
    rows.append(row("kernels.flash_gqa_512", us, f"abs_err={errv:.2e}"))

    # ssd scan
    x = jnp.asarray(RNG.standard_normal((2, 512, 8, 16)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (2, 512, 8)), jnp.float32)
    av = jnp.asarray(-RNG.uniform(0.5, 2.0, (8,)), jnp.float32)
    bm = jnp.asarray(RNG.standard_normal((2, 512, 1, 32)), jnp.float32)
    cm = jnp.asarray(RNG.standard_normal((2, 512, 1, 32)), jnp.float32)
    (y, hf), us = timed(lambda: tuple(
        t.block_until_ready() for t in ssd_scan(x, dt, av, bm, cm, chunk=128)))
    yr, hr = ref_ssd_scan(x, dt, av, bm, cm)
    errv = float(jnp.max(jnp.abs(y - yr)))
    rows.append(row("kernels.ssd_scan_512", us, f"abs_err={errv:.2e}"))

    rows.extend(_autotune_rows(ctx.quick))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    size = ap.add_mutually_exclusive_group()
    size.add_argument("--quick", action="store_true",
                      help="small autotune buckets (the default; the CI "
                           "smoke setting)")
    size.add_argument("--full", action="store_true",
                      help="EXPERIMENTS.md-sized autotune buckets")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    emit(run(BenchCtx(quick=not args.full)))
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
