"""Paper Figs. 12/13: GA vs MaP vs MaP+GA hypervolume across constraint
scaling factors (PPF = estimated front, VPF = re-characterized front)."""

from __future__ import annotations

import numpy as np

from repro.core.automl import fit_estimators
from repro.core.dataset import BEHAV_KEY, PPA_KEY
from repro.core.dse import DSESettings, hv_reference, map_solution_pool, run_dse

from .common import BenchCtx, row


def run(ctx: BenchCtx) -> list[dict]:
    ds = ctx.ds8()
    spec = ctx.spec8
    X = ds.configs.astype(np.float64)
    estimators = fit_estimators(
        X, {BEHAV_KEY: ds.metrics[BEHAV_KEY], PPA_KEY: ds.metrics[PPA_KEY]},
        n_quad=32, seed=ctx.seed,
    )
    rows = []
    for const_sf in ctx.const_sf_grid:
        st = DSESettings(
            const_sf=const_sf, pop_size=48, n_gen=ctx.n_gen,
            n_quad_grid=(0, 4, 16) if ctx.quick else (0, 4, 8, 16, 32),
            pool_size=6, seed=ctx.seed,
        )
        ref = hv_reference(ds, st)
        pool = map_solution_pool(spec, ds, st)
        res = {}
        for method in ("ga", "map", "map+ga"):
            r = run_dse(spec, ds, method, settings=st, estimators=estimators,
                        map_pool=pool, ref=ref)
            res[method] = r
            rows.append(row(
                f"dse.fig12_sf{const_sf}_{method}", r.wall_s * 1e6,
                f"hv_ppf={r.hv_ppf:.5g} hv_vpf={r.hv_vpf:.5g} evals={r.n_evals}",
            ))
        ga, mg = res["ga"], res["map+ga"]
        if ga.hv_vpf > 1e-9:
            gain = f"{100.0 * (mg.hv_vpf - ga.hv_vpf) / ga.hv_vpf:+.1f}%"
        else:
            gain = f"ga_vpf=0, map+ga_vpf={mg.hv_vpf:.4g}"
        rows.append(row(f"dse.fig12_sf{const_sf}_gain_mapga_vs_ga", 0.0, gain))
        # Fig. 13: HV progression -- MaP+GA should lead at equal evals
        for tag, r in (("ga", ga), ("map+ga", mg)):
            if r.hv_history:
                mid = r.hv_history[len(r.hv_history) // 2]
                rows.append(row(
                    f"dse.fig13_sf{const_sf}_{tag}_progress", 0.0,
                    f"evals={mid[0]} hv={mid[1]:.5g} final={r.hv_history[-1][1]:.5g}",
                ))
    return rows
