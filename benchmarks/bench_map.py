"""Paper Fig. 11: MaP solution-pool quality (hypervolume, metric extremes) as
quadratic terms are added to the MIQCP formulations (const_sf = 0.5)."""

from __future__ import annotations

import numpy as np

from repro.core.correlation import rank_quadratic_terms
from repro.core.dataset import BEHAV_KEY, PPA_KEY, characterize
from repro.core.dse import DSESettings, hv_reference
from repro.core.miqcp import build_problems, solve_pool
from repro.core.moo import hypervolume_2d, pareto_mask
from repro.core.regression import fit_poly

from .common import BenchCtx, row, timed


def run(ctx: BenchCtx) -> list[dict]:
    ds = ctx.ds8()
    spec = ctx.spec8
    X = ds.configs.astype(np.float64)
    yb = ds.metrics[BEHAV_KEY]
    yp = ds.metrics[PPA_KEY]
    ranked_b = rank_quadratic_terms(X, yb)
    ranked_p = rank_quadratic_terms(X, yp)
    settings = DSESettings(const_sf=0.5)
    ref = hv_reference(ds, settings)
    max_b, max_p = 0.5 * yb.max(), 0.5 * yp.max()

    rows = []
    wt = np.arange(0.0, 1.0001, 0.1 if ctx.quick else 0.05)
    for n_quad in (0, 4, 16) if ctx.quick else (0, 4, 8, 16, 32, 64):
        bm = fit_poly(X, yb, quad_pairs=ranked_b[:n_quad])
        pm = fit_poly(X, yp, quad_pairs=ranked_p[:n_quad])
        problems = build_problems(bm, pm, float(yb.max()), float(yp.max()),
                                  0.5, wt_grid=wt, n_quad=n_quad)
        pool, us = timed(solve_pool, problems, ctx.seed, 8)
        if len(pool) == 0:
            rows.append(row(f"map.fig11_q{n_quad}", us, "pool=0"))
            continue
        objs = characterize(spec, pool).objectives()
        feas = (objs[:, 0] <= max_b) & (objs[:, 1] <= max_p)
        hv = hypervolume_2d(objs[feas], ref) if feas.any() else 0.0
        kind = "MILP" if n_quad == 0 else f"MIQCP(q={n_quad})"
        rows.append(row(
            f"map.fig11_q{n_quad}", us,
            f"{kind} pool={len(pool)} feas={int(feas.sum())} tot_hv={hv:.4g} "
            f"min_behav={objs[:,0].min():.3g} min_ppa={objs[:,1].min():.4g}",
        ))
    return rows
