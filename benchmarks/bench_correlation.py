"""Paper Figs. 1/9: bivariate + multivariate correlation analysis of the
signed 8x8 characterization data."""

from __future__ import annotations

import numpy as np

from repro.core.correlation import (
    bivariate_correlation,
    multivariate_correlation,
    rank_quadratic_terms,
)

from .common import BenchCtx, row, timed


def run(ctx: BenchCtx) -> list[dict]:
    ds = ctx.ds8()
    X = ds.configs.astype(np.float64)
    rows = []
    for metric in ("PDPLUT", "AVG_ABS_REL_ERR"):
        y = ds.metrics[metric]
        r, us_b = timed(bivariate_correlation, X, y)
        m, us_m = timed(multivariate_correlation, X, y)
        tag = "ppa" if metric == "PDPLUT" else "behav"
        rows.append(row(f"correlation.fig9_bivar_{tag}", us_b,
                        f"max|r|={np.abs(r).max():.3f} spread={np.abs(r).std():.3f}"))
        top = np.argsort(np.abs(r))[::-1][:3]
        rows.append(row(f"correlation.fig9_top_luts_{tag}", 0.0,
                        "|".join(f"LUT_{i}:{r[i]:+.3f}" for i in top)))
        iu = np.triu_indices_from(m, k=1)
        rows.append(row(f"correlation.fig9_multivar_{tag}", us_m,
                        f"max_pair_r={m[iu].max():.3f}"))
        ranked = rank_quadratic_terms(X, y)
        rows.append(row(f"correlation.fig9_best_pair_{tag}", 0.0,
                        f"{ranked[0]}"))
    # the paper's qualitative claim: BEHAV correlation concentrates on fewer
    # LUTs than PPA (a few LUTs dominate the error)
    r_ppa = np.abs(bivariate_correlation(X, ds.metrics["PDPLUT"]))
    r_beh = np.abs(bivariate_correlation(X, ds.metrics["AVG_ABS_REL_ERR"]))
    conc = lambda r: float((np.sort(r)[::-1][:4].sum()) / max(r.sum(), 1e-12))
    rows.append(row("correlation.fig9_top4_share_ppa", 0.0, f"{conc(r_ppa):.3f}"))
    rows.append(row("correlation.fig9_top4_share_behav", 0.0, f"{conc(r_beh):.3f}"))
    return rows
