"""Device NSGA-II engine throughput: numpy oracle GA vs the fastmoo engine.

The GA generation loop is the post-PR-1/2 serial bottleneck of ``run_dse``:
even with the jitted surrogate (one fitness dispatch per generation), sorting,
selection, crossover, mutation and environmental selection round-trip to host
numpy.  Headline rows: wall-clock of a full surrogate-driven NSGA-II run on
the 8-bit operator (L=36) for

  * ``ga_numpy``  -- the numpy oracle end to end,
  * ``ga_hybrid`` -- numpy GA + one-dispatch jit surrogate (the PR-1 path),
  * ``ga_jax``    -- the whole run as one compiled dispatch (fastmoo),

plus feasible-archive hypervolume parity between the oracle and the engine,
and the multi-seed/multi-constraint sweep: N lanes as one vmapped dispatch vs
the same lanes run back-to-back on the already-compiled single-run program.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.automl import fit_estimators
from repro.core.dataset import BEHAV_KEY, PPA_KEY
from repro.core.fastchar import compile_surrogate_batch
from repro.core.fastmoo import CompiledNSGA2
from repro.core.moo import nsga2

from .common import BenchCtx, row


def run(ctx: BenchCtx) -> list[dict]:
    spec = ctx.spec8
    ds = ctx.ds8()
    rows: list[dict] = []
    pop = 64 if ctx.quick else 256
    gens = 20 if ctx.quick else 250
    evals = pop * (gens + 1)

    yb = ds.metrics[BEHAV_KEY]
    yp = ds.metrics[PPA_KEY]
    ests = fit_estimators(
        ds.configs.astype(np.float64),
        {BEHAV_KEY: yb, PPA_KEY: yp},
        n_quad=24,
        seed=ctx.seed,
    )
    mb, mp = float(yb.max()), float(yp.max())
    ref = np.array([1.05 * mb, 1.05 * mp])

    def eval_fn(cfgs):
        X = cfgs.astype(np.float64)
        return np.stack([ests[BEHAV_KEY].predict(X), ests[PPA_KEY].predict(X)], -1)

    def viol_fn(cfgs):
        o = eval_fn(cfgs)
        return (
            np.maximum(0.0, o[:, 0] - mb) / mb + np.maximum(0.0, o[:, 1] - mp) / mp
        )

    # -- numpy oracle GA ------------------------------------------------------
    t0 = time.perf_counter()
    r_np = nsga2(eval_fn, n_bits=spec.n_luts, pop_size=pop, n_gen=gens,
                 seed=ctx.seed, violation_fn=viol_fn, hv_ref=ref)
    t_np = time.perf_counter() - t0
    rows.append(row("fastmoo.ga_numpy", t_np * 1e6, f"{evals / t_np:.0f} evals/s"))

    # -- numpy GA + jit surrogate (the PR-1 hybrid) ---------------------------
    fn = compile_surrogate_batch(ests, BEHAV_KEY, PPA_KEY, mb, mp)
    fn(ds.configs[:pop].astype(np.float64))  # compile
    t0 = time.perf_counter()
    nsga2(None, n_bits=spec.n_luts, pop_size=pop, n_gen=gens, seed=ctx.seed,
          eval_viol_fn=fn, hv_ref=ref)
    t_hy = time.perf_counter() - t0
    rows.append(row("fastmoo.ga_hybrid", t_hy * 1e6, f"{evals / t_hy:.0f} evals/s"))

    # -- fully-jitted device GA ----------------------------------------------
    runner = CompiledNSGA2(fn.objs_fn, n_bits=spec.n_luts, pop_size=pop,
                           n_gen=gens, hv_ref=ref)
    runner.run(seed=ctx.seed, max_behav=mb, max_ppa=mp)  # compile
    t0 = time.perf_counter()
    r_jx = runner.run(seed=ctx.seed, max_behav=mb, max_ppa=mp)
    t_jx = time.perf_counter() - t0
    rows.append(row("fastmoo.ga_jax", t_jx * 1e6, f"{evals / t_jx:.0f} evals/s"))
    rows.append(row("fastmoo.ga_speedup_vs_numpy", 0.0, f"{t_np / t_jx:.1f}x"))
    rows.append(row("fastmoo.ga_speedup_vs_hybrid", 0.0, f"{t_hy / t_jx:.1f}x"))

    # -- telemetry overhead: NULL sink vs per-generation device taps ----------
    # off = the compiled untapped program under the disabled sink; on = a
    # sink with device_taps, whose program maintains an incremental
    # nondominated-front buffer and emits its hv EVERY generation through
    # io_callback -- O(front) per generation instead of re-sorting the whole
    # P*(G+1) archive (EXPERIMENTS.md §Telemetry)
    from repro.core.engine import ExecutionContext
    from repro.obs import telemetry as obs

    with obs.use(obs.NULL):
        t0 = time.perf_counter()
        runner.run(seed=ctx.seed, max_behav=mb, max_ppa=mp)
        t_off = time.perf_counter() - t0
    ctx_on = ExecutionContext(backend="jax", telemetry="on")
    runner_on = CompiledNSGA2(fn.objs_fn, n_bits=spec.n_luts, pop_size=pop,
                              n_gen=gens, hv_ref=ref, ctx=ctx_on)
    runner_on.run(seed=ctx.seed, max_behav=mb, max_ppa=mp)  # compile
    t0 = time.perf_counter()
    runner_on.run(seed=ctx.seed, max_behav=mb, max_ppa=mp)
    t_on = time.perf_counter() - t0
    rows.append(row("fastmoo.ga_telemetry_off", t_off * 1e6,
                    f"{evals / t_off:.0f} evals/s"))
    rows.append(row("fastmoo.ga_telemetry_tapped", t_on * 1e6,
                    f"{(t_on - t_off) / t_off:+.2%} vs off"))

    hv_np = r_np.hv_history[-1][1]
    hv_jx = r_jx.hv_history[-1][1]
    rows.append(row(
        "fastmoo.hv_parity_rel_diff", 0.0,
        f"{abs(hv_jx - hv_np) / max(abs(hv_np), 1e-9):.2e}"
        f" (numpy={hv_np:.5g} jax={hv_jx:.5g})",
    ))

    # -- (seeds x const_sf) sweep: one vmapped dispatch vs back-to-back runs --
    seeds = (0, 1) if ctx.quick else (0, 1, 2, 3)
    sf_grid = (0.5, 1.5) if ctx.quick else (0.2, 0.5, 1.0)
    lane_seeds = [s for _ in sf_grid for s in seeds]
    bounds = [(sf * mb, sf * mp) for sf in sf_grid for _ in seeds]
    n_lanes = len(lane_seeds)

    runner.run_sweep(lane_seeds, bounds)  # compile the vmapped program
    t0 = time.perf_counter()
    runner.run_sweep(lane_seeds, bounds)
    t_sweep = time.perf_counter() - t0

    t0 = time.perf_counter()
    for s, (b, p) in zip(lane_seeds, bounds):
        runner.run(seed=s, max_behav=b, max_ppa=p)
    t_loop = time.perf_counter() - t0
    rows.append(row("fastmoo.sweep_vmapped", t_sweep * 1e6,
                    f"{n_lanes} lanes, {n_lanes * evals / t_sweep:.0f} evals/s"))
    rows.append(row("fastmoo.sweep_sequential", t_loop * 1e6,
                    f"{n_lanes * evals / t_loop:.0f} evals/s"))
    rows.append(row("fastmoo.sweep_speedup", 0.0, f"{t_loop / t_sweep:.1f}x"))
    return rows
