"""Paper Figs. 16-19: application-specific DSE (ECG / MNIST / GAUSS, plus the
beyond-paper transformer-FFN target) -- AxOMaP vs GA vs the frozen library."""

from __future__ import annotations

import numpy as np

from repro.apps import APPLICATIONS
from repro.core.automl import fit_estimators
from repro.core.dataset import PPA_KEY, characterize
from repro.core.dse import (
    DSESettings,
    fixed_library,
    hv_reference,
    map_solution_pool,
    run_dse,
)
from repro.core.moo import hypervolume_2d

from .common import BenchCtx, row


def run(ctx: BenchCtx) -> list[dict]:
    ds = ctx.ds8()
    spec = ctx.spec8
    rows = []
    apps = ("ecg", "mnist", "gauss") if ctx.quick else ("ecg", "mnist", "gauss", "ffn")
    sf_grid = (0.5, 1.5)
    lib = fixed_library(spec)

    for name in apps:
        app = APPLICATIONS[name]()
        app_ds = app.characterized_dataset(spec, ds)
        bkey = app.behav_metric_name()
        X = app_ds.configs.astype(np.float64)
        estimators = fit_estimators(
            X, {bkey: app_ds.metrics[bkey], PPA_KEY: app_ds.metrics[PPA_KEY]},
            n_quad=24, seed=ctx.seed,
        )
        char_fn = app.characterize_fn(spec)
        lib_objs = char_fn(lib)

        for const_sf in sf_grid:
            st = DSESettings(
                behav_key=bkey, const_sf=const_sf, pop_size=32,
                n_gen=max(10, ctx.n_gen // 2),
                n_quad_grid=(0, 8), pool_size=4, seed=ctx.seed,
            )
            ref = hv_reference(app_ds, st)
            max_b = const_sf * app_ds.metrics[bkey].max()
            max_p = const_sf * app_ds.metrics[PPA_KEY].max()
            pool = map_solution_pool(spec, app_ds, st)
            hv = {}
            for method in ("ga", "map+ga"):
                r = run_dse(spec, app_ds, method, settings=st,
                            estimators=estimators, map_pool=pool,
                            characterize_fn=char_fn, ref=ref)
                hv[method] = r.hv_vpf
            feas = (lib_objs[:, 0] <= max_b) & (lib_objs[:, 1] <= max_p)
            hv["evoapprox-style"] = (
                hypervolume_2d(lib_objs[feas], ref) if feas.any() else 0.0
            )
            for k, v in hv.items():
                rows.append(row(f"apps.fig16_{name}_sf{const_sf}_{k}", 0.0,
                                f"hv_vpf={v:.5g}"))
            if hv["ga"] > 1e-9:
                gain = f"{100.0 * (hv['map+ga'] - hv['ga']) / hv['ga']:+.1f}%"
            else:
                gain = f"ga=0, map+ga={hv['map+ga']:.4g} (denominator empty)"
            rows.append(row(f"apps.fig16_{name}_sf{const_sf}_gain", 0.0, gain))
            rows.append(row(f"apps.fig1x_{name}_sf{const_sf}_lib_feasible", 0.0,
                            f"{int(feas.sum())}/{len(lib)}"))
    return rows
