"""Paper Figs. 16-19: application-specific DSE (ECG / MNIST / GAUSS, plus the
beyond-paper transformer-FFN target) -- AxOMaP vs GA vs the frozen library.

Runs on the accelerator-native app engine (``backend="jax"``: fastchar
characterization + fastapp application BEHAV + one-dispatch NSGA-II fitness);
a numpy-vs-jax hypervolume parity row on the MNIST target keeps the two
backends honest against each other at identical seeds."""

from __future__ import annotations

import numpy as np

from repro.apps import APPLICATIONS
from repro.apps.base import characterized_dataset_multi
from repro.core.automl import fit_estimators
from repro.core.dataset import PPA_KEY, characterize
from repro.core.dse import (
    DSESettings,
    fixed_library,
    hv_reference,
    map_solution_pool,
    run_dse,
)
from repro.core.moo import hypervolume_2d

from .common import BenchCtx, row

BACKEND = "jax"  # the app-engine path; "numpy" reproduces the oracle baseline


def run(ctx: BenchCtx) -> list[dict]:
    ds = ctx.ds8()
    spec = ctx.spec8
    rows = []
    apps = ("ecg", "mnist", "gauss") if ctx.quick else ("ecg", "mnist", "gauss", "ffn")
    sf_grid = (0.5, 1.5)
    lib = fixed_library(spec)

    # one shared TableBatch pass attaches every app's BEHAV metric at once
    app_objs = {name: APPLICATIONS[name]() for name in apps}
    multi_ds = characterized_dataset_multi(
        app_objs.values(), spec, ds, backend=BACKEND
    )

    for name in apps:
        app = app_objs[name]
        app_ds = multi_ds
        bkey = app.behav_metric_name()
        X = app_ds.configs.astype(np.float64)
        estimators = fit_estimators(
            X, {bkey: app_ds.metrics[bkey], PPA_KEY: app_ds.metrics[PPA_KEY]},
            n_quad=24, seed=ctx.seed,
        )
        char_fn = app.characterize_fn(spec, backend=BACKEND)
        lib_objs = char_fn(lib)

        for const_sf in sf_grid:
            st = DSESettings(
                behav_key=bkey, const_sf=const_sf, pop_size=32,
                n_gen=max(10, ctx.n_gen // 2),
                n_quad_grid=(0, 8), pool_size=4, seed=ctx.seed,
                backend=BACKEND,
            )
            ref = hv_reference(app_ds, st)
            max_b = const_sf * app_ds.metrics[bkey].max()
            max_p = const_sf * app_ds.metrics[PPA_KEY].max()
            pool = map_solution_pool(spec, app_ds, st)
            hv = {}
            for method in ("ga", "map+ga"):
                r = run_dse(spec, app_ds, method, settings=st,
                            estimators=estimators, map_pool=pool,
                            app=app, ref=ref)
                hv[method] = r.hv_vpf
            feas = (lib_objs[:, 0] <= max_b) & (lib_objs[:, 1] <= max_p)
            hv["evoapprox-style"] = (
                hypervolume_2d(lib_objs[feas], ref) if feas.any() else 0.0
            )
            for k, v in hv.items():
                rows.append(row(f"apps.fig16_{name}_sf{const_sf}_{k}", 0.0,
                                f"hv_vpf={v:.5g}"))
            if hv["ga"] > 1e-9:
                gain = f"{100.0 * (hv['map+ga'] - hv['ga']) / hv['ga']:+.1f}%"
            else:
                gain = f"ga=0, map+ga={hv['map+ga']:.4g} (denominator empty)"
            rows.append(row(f"apps.fig16_{name}_sf{const_sf}_gain", 0.0, gain))
            rows.append(row(f"apps.fig1x_{name}_sf{const_sf}_lib_feasible", 0.0,
                            f"{int(feas.sum())}/{len(lib)}"))

    # -- backend parity: same seeds, numpy oracle vs jax engine (MNIST) ------
    app = APPLICATIONS["mnist"]()
    bkey = app.behav_metric_name()
    hv_bk = {}
    for backend in ("numpy", "jax"):
        app_ds = app.characterized_dataset(spec, ds, backend=backend)
        # ga_backend pinned to numpy: this row isolates the characterization /
        # app-BEHAV engines at identical GA trajectories (the device GA has its
        # own RNG stream; its hv parity is bench_fastmoo's job)
        st = DSESettings(
            behav_key=bkey, const_sf=1.5, pop_size=24, n_gen=10,
            n_quad_grid=(0,), pool_size=2, seed=ctx.seed, backend=backend,
            ga_backend="numpy",
        )
        r = run_dse(spec, app_ds, "ga", settings=st, app=app,
                    ref=hv_reference(app_ds, st))
        hv_bk[backend] = r.hv_vpf
        rows.append(row(f"apps.backend_parity_mnist_{backend}", 0.0,
                        f"hv_vpf={r.hv_vpf:.6g}"))
    denom = max(abs(hv_bk["numpy"]), 1e-9)
    rows.append(row("apps.backend_parity_mnist_rel_diff", 0.0,
                    f"{abs(hv_bk['jax'] - hv_bk['numpy']) / denom:.2e}"))
    return rows
