"""Shared benchmark context: cached 8x8 characterization dataset, timers."""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.dataset import Dataset, build_training_dataset
from repro.core.operator_model import OperatorSpec, spec_for

CACHE_DIR = os.environ.get("REPRO_CACHE", "experiments/cache")


@dataclass
class BenchCtx:
    quick: bool = True
    seed: int = 0
    _ds8: Dataset | None = field(default=None, repr=False)
    _ds4: Dataset | None = field(default=None, repr=False)

    @property
    def spec8(self) -> OperatorSpec:
        return spec_for(8)

    @property
    def spec4(self) -> OperatorSpec:
        return spec_for(4)

    def ds8(self) -> Dataset:
        """The paper's signed 8x8 training dataset (RANDOM + PATTERN), cached."""
        if self._ds8 is None:
            n = 1200 if self.quick else 4000
            path = os.path.join(CACHE_DIR, f"ds8_{n}_{self.seed}.npz")
            self._ds8 = build_training_dataset(
                self.spec8, n_random=n, seed=self.seed, cache_path=path)
        return self._ds8

    def ds4(self) -> Dataset:
        if self._ds4 is None:
            path = os.path.join(CACHE_DIR, f"ds4_{self.seed}.npz")
            self._ds4 = build_training_dataset(
                self.spec4, n_random=400, seed=self.seed, cache_path=path)
        return self._ds4

    @property
    def n_gen(self) -> int:
        return 40 if self.quick else 250

    @property
    def const_sf_grid(self):
        return (0.2, 0.5, 1.0) if self.quick else (0.2, 0.5, 0.8, 1.0, 1.2, 1.5)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def row(name: str, us: float, derived) -> dict:
    return {"name": name, "us_per_call": us, "derived": derived}


def emit(rows: list[dict]) -> None:
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}", flush=True)
